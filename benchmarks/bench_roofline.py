"""Deliverable (g): the roofline table from the dry-run artifacts.

Reads ``experiments/dryrun/*.json`` (produced by ``repro.launch.dryrun``) and
emits one CSV row per (arch × shape × mesh): the three roofline terms, the
dominant bottleneck, and MODEL_FLOPS/HLO_FLOPS.  Also writes the markdown
table consumed by EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os

from ._util import Reporter

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells(mesh: str | None = "single") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(path))
        if mesh is not None and r.get("mesh") != mesh:
            continue
        if r.get("status") not in ("compiled", "skipped"):
            continue
        if r.get("status") == "compiled" and "roofline" not in r:
            continue  # auxiliary cells (e.g. the dataframe pipeline)
        cells.append(r)
    return cells


def markdown_table(cells: list[dict]) -> str:
    hdr = ("| arch | shape | status | compute_s | memory_s | collective_s | "
           "bottleneck | useful | roofline_frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for r in cells:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skip: {r['reason'][:48]}… "
                        "| – | – | – | – | – | – |")
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {rl['compute_s']:.3e} | {rl['memory_s']:.3e} "
            f"| {rl['collective_s']:.3e} | {rl['bottleneck']} "
            f"| {rl['useful_flops_fraction']:.3f} "
            f"| {rl['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def run(rep: Reporter, smoke: bool = False) -> None:
    cells = load_cells("single")
    if not cells:
        rep.add("roofline/no_dryrun_artifacts", 0.0,
                "run: python -m repro.launch.dryrun")
        return
    for r in cells:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            rep.add(name, 0.0, "skipped:" + r["reason"][:60])
            continue
        rl = r["roofline"]
        rep.add(name, rl["step_s"] * 1e6,
                f"bottleneck={rl['bottleneck']} useful={rl['useful_flops_fraction']:.3f} "
                f"frac={rl['roofline_fraction']:.4f}")
    if smoke:
        return   # don't overwrite the recorded table from a sanity run
    out = os.path.join(DRYRUN_DIR, "..", "roofline_table.md")
    with open(out, "w") as f:
        f.write(markdown_table(cells))
