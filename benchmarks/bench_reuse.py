"""Paper §6.2: materialization & reuse across a session.

A session issues Q queries sharing an expensive sub-expression (selection +
sort); with the reuse cache each subsequent query pays only its private tail,
without it the shared prefix recomputes every time.
"""
from __future__ import annotations

import time

from repro.core import DataFrame, EvalMode, Session, set_session

from ._util import Reporter

_N = 400_000
_Q = 5


def _session_run(optimize_reuse: bool, n: int = _N) -> float:
    s = set_session(Session(mode=EvalMode.LAZY, default_row_parts=8,
                            cache_budget_bytes=(1 << 30) if optimize_reuse else 0))
    try:
        df = DataFrame({"k": [i % 50 for i in range(n)],
                        "v": [float(i % 997) for i in range(n)]})
        base = df[df["v"] > 3.0].sort_values("v")   # shared sub-expression
        t0 = time.perf_counter()
        for q in range(_Q):
            base.groupby("k").agg({"v": ["sum"] if q % 2 else ["mean"]}).collect()
        return time.perf_counter() - t0
    finally:
        s.close()


def run(rep: Reporter, smoke: bool = False) -> None:
    n = 20_000 if smoke else _N
    cold = _session_run(optimize_reuse=False, n=n)
    warm = _session_run(optimize_reuse=True, n=n)
    rep.add("reuse/session_no_cache", cold * 1e6, f"queries={_Q}")
    rep.add("reuse/session_with_cache", warm * 1e6,
            f"speedup={cold / warm:.2f}x")
