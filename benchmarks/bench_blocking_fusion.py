"""Barrier fusion (fusing row-local chains THROUGH blocking operators) vs
per-node evaluation.

Three chains over a multi-block frame, each executed two ways on the same
frame store:

  * ``map→filter→groupby`` — producer fusion: the row-local sweep runs inside
    the same per-block program as the ``segment_reduce`` partial aggregation
    (``FusedGroupBy``), one dispatch per partition for the whole pre-shuffle
    stage;
  * ``sort→filter→project`` — consumer fusion: selections filter the
    permutation *index* before the payload gather and the projection prunes
    the gathered columns (``FusedSort``).  The bench asserts via ``ExecStats``
    that the fused path gathers strictly fewer rows;
  * ``window→map`` — stage fusion: the consumer map runs inside the carry
    application's per-block program (``FusedWindow``).

The unfused baseline (``Executor(optimize=False)``) is the per-node path:
every operator materializes, hashes and caches its own ``PartitionedFrame``.
Numbers land in ``BENCH_blocking_fusion.json``.
"""
from __future__ import annotations

import os

# standalone runs mirror benchmarks/run.py: one partition ↔ one core (the
# single-threaded XLA intra-op baseline), set before jax initializes
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import numpy as np
import jax.numpy as jnp

from repro.core import algebra as alg
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame

from ._util import Reporter, time_us, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_blocking_fusion.json")


def _mixed_frame(n_rows: int, seed: int = 9) -> Frame:
    rng = np.random.default_rng(seed)
    cols = [
        Column(jnp.asarray(rng.integers(0, 8, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.integers(-1000, 1000, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.standard_normal(n_rows).astype(np.float32)), Domain.FLOAT),
        Column(jnp.asarray(rng.standard_normal(n_rows).astype(np.float32)), Domain.FLOAT),
    ]
    return Frame(cols, RangeLabels(n_rows), labels_from_values(["k", "v", "x", "y"]))


def _scale(name: str, a: float, b: float) -> alg.Udf:
    def fn(cols, frame):
        out = dict(cols)
        c = cols[name]
        out[name] = Column(c.data * a + b, Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name=f"scale_{name}_{a}_{b}", fn=fn,
                   deps=frozenset([name]), elementwise=True)


def _chains(src: alg.Node) -> dict[str, alg.Node]:
    return {
        "map_filter_groupby": alg.GroupBy(
            alg.Selection(alg.Map(src, _scale("x", 2.0, 1.0)),
                          alg.col("v") > alg.lit(0)),
            ("k",), [("x", "sum", "xs"), ("x", "mean", "xm"), ("v", "count", "vc")]),
        "sort_filter_project": alg.Projection(
            alg.Selection(alg.Sort(src, ("v",)), alg.col("v") > alg.lit(750)),
            ("k", "v")),
        "window_map": alg.Map(
            alg.Window(src, "cumsum", ("x",)), _scale("x", 0.5, -1.0)),
    }


def _assert_equal(a: Frame, b: Frame, chain: str) -> None:
    ad, bd = a.to_pydict(), b.to_pydict()
    assert list(ad) == list(bd), chain
    assert a.row_labels.to_list() == b.row_labels.to_list(), chain
    for k in ad:
        np.testing.assert_array_equal(np.asarray(ad[k]), np.asarray(bd[k]),
                                      err_msg=f"{chain}/{k}")


def _bench(rep: Reporter, n_rows: int, row_parts: int, reps: int) -> dict:
    pf = PartitionedFrame.from_frame(_mixed_frame(n_rows), row_parts=row_parts)
    store = {"bench": pf}
    src = alg.Source("bench", nrows=pf.nrows, ncols=pf.ncols)

    out: dict = {"rows": n_rows, "row_parts": row_parts, "chains": {}}
    for chain, plan in _chains(src).items():
        fused_ex = Executor(store, optimize=True)
        plain_ex = Executor(store, optimize=False)

        # correctness gate + ExecStats attribution before timing
        a = fused_ex.evaluate(plan).to_frame()
        b = plain_ex.evaluate(plan).to_frame()
        _assert_equal(a, b, chain)
        assert fused_ex.stats.barrier_fused_groups >= 1, f"{chain}: not barrier-fused"
        if chain == "sort_filter_project":
            # THE consumer-fusion win, asserted: strictly fewer payload rows
            assert 0 < fused_ex.stats.gather_rows < plain_ex.stats.gather_rows, (
                fused_ex.stats.gather_rows, plain_ex.stats.gather_rows)
        # one-source-of-truth counter invariant
        s = fused_ex.stats
        assert s.fused_stage_ops == (s.producer_stage_ops + s.consumer_stage_ops
                                     + _pipeline_ops(fused_ex, plan))

        def run(ex):
            ex.cache.clear()      # fresh evaluation; reuse is measured elsewhere
            return ex.evaluate(plan)

        # interleave A/B passes (best-of overall): shields the ratio from
        # drift on a shared machine
        t_unfused, t_fused = float("inf"), float("inf")
        for _ in range(3):
            t_unfused = min(t_unfused, time_us(lambda: run(plain_ex), reps=reps))
            t_fused = min(t_fused, time_us(lambda: run(fused_ex), reps=reps))
        speedup = t_unfused / max(t_fused, 1e-9)
        rep.add(f"blocking_fusion/{chain}/unfused[{n_rows}x{row_parts}]",
                t_unfused, "")
        rep.add(f"blocking_fusion/{chain}/fused[{n_rows}x{row_parts}]",
                t_fused, f"speedup={speedup:.2f}x")
        out["chains"][chain] = {
            "unfused_us": round(t_unfused, 1),
            "fused_us": round(t_fused, 1),
            "speedup": round(speedup, 3),
            "barrier_fused_groups": s.barrier_fused_groups,
            "producer_stage_ops": s.producer_stage_ops,
            "consumer_stage_ops": s.consumer_stage_ops,
            "gather_rows_fused": s.gather_rows or None,
            "gather_rows_unfused": plain_ex.stats.gather_rows or None,
        }
    return out


def _pipeline_ops(ex: Executor, plan: alg.Node) -> int:
    return sum(len(n.params["stages"]) for n in ex._prepared(plan).walk()
               if n.op == "fused_pipeline")


def run(rep: Reporter, smoke: bool = False) -> None:
    if smoke:
        # sanity only: don't overwrite the recorded full-size numbers
        _bench(rep, 20_000, 4, reps=1)
        return
    # many-partition regime (partitions ≫ cores): per-operator pool rounds,
    # intermediate PartitionedFrames and per-stage dispatch are what barrier
    # fusion removes; the shuffle/aggregation compute is identical either way
    results = [
        _bench(rep, 100_000, 16, reps=5),
        _bench(rep, 200_000, 16, reps=5),
    ]
    write_bench_json(_JSON_PATH, {
        "benchmark": "barrier fusion through blocking operators",
        "results": results})


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, single rep (CI sanity mode)")
    args = ap.parse_args()
    rep = Reporter()
    print("name,us_per_call,derived")
    run(rep, smoke=args.smoke)


if __name__ == "__main__":
    main()
