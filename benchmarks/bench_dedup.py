"""Block-parallel + barrier-fused DIFFERENCE / DROP-DUPLICATES vs the serial
seed path.

Two chains over a duplicate-heavy multi-block frame, each executed three ways
on the same frame store:

  * ``serial_seed`` — ``REPRO_BLOCK_DEDUP=0`` + per-node plans: the pre-PR-4
    behavior (producer chain materializes per operator, then the dedup
    operator concatenates the whole frame and runs single-threaded host
    numpy);
  * ``block``       — per-node plans on the block-parallel path: per-block
    key extraction through the scheduling layer, one joint factorization,
    blockwise keep-mask filters;
  * ``fused``       — the block-parallel path with barrier fusion: the
    producer chain runs inside the per-block key-extraction program
    (``FusedDropDuplicates`` / ``FusedDifference``).

All three produce identical frames (asserted before timing, along with the
``ExecStats`` dedup counters and the PR-2 stage-op invariant).  Numbers land
in ``BENCH_dedup.json``; the headline is fused vs serial_seed on
map→filter→drop_duplicates (target ≥ 1.5×).
"""
from __future__ import annotations

import os

# standalone runs mirror benchmarks/run.py: one partition ↔ one core, set
# before jax initializes
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import numpy as np
import jax.numpy as jnp

from repro.core import algebra as alg
from repro.core import schedule
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame

from ._util import Reporter, time_us, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_dedup.json")

MODES = {
    "serial_seed": {"env": {"REPRO_BLOCK_DEDUP": "0"}, "optimize": False},
    "block": {"env": {"REPRO_BLOCK_DEDUP": "1"}, "optimize": False},
    "fused": {"env": {"REPRO_BLOCK_DEDUP": "1"}, "optimize": True},
}


class _mode:
    def __init__(self, name: str):
        self.env = MODES[name]["env"]
        self.saved: dict = {}

    def __enter__(self):
        for k, v in self.env.items():
            self.saved[k] = os.environ.get(k)
            os.environ[k] = v

    def __exit__(self, *exc):
        for k, old in self.saved.items():
            if old is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = old
        return False


def _dup_frame(n_rows: int, seed: int = 11) -> Frame:
    """Duplicate-heavy mixed frame: every column draws from a small pool, so
    dedup is selective and the coded key hashing has real work per block."""
    rng = np.random.default_rng(seed)
    strings = [f"s{i:02d}" for i in range(12)]
    cols = [
        Column(jnp.asarray(rng.integers(0, 8, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.integers(0, 20, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray((rng.integers(0, 12, n_rows) * np.float32(0.25))
                           .astype(np.float32)), Domain.FLOAT),
        Column(jnp.asarray(rng.integers(0, 12, n_rows, dtype=np.int32)),
               Domain.STR, None, tuple(strings)),
    ]
    return Frame(cols, RangeLabels(n_rows),
                 labels_from_values(["k", "v", "x", "s"]))


def _scale() -> alg.Udf:
    def fn(cols, frame):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * 2.0 + 1.0, Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name="dedup_bench_scale", fn=fn,
                   deps=frozenset(["x"]), elementwise=True)


def _chains(lsrc: alg.Node, rsrc: alg.Node) -> dict[str, alg.Node]:
    return {
        "map_filter_dropdup": alg.DropDuplicates(
            alg.Selection(alg.Map(lsrc, _scale()),
                          alg.col("v") > alg.lit(10)), None),
        "map_difference": alg.Difference(alg.Map(lsrc, _scale()), rsrc),
    }


def _assert_equal(a: Frame, b: Frame, ctx: str) -> None:
    ad, bd = a.to_pydict(), b.to_pydict()
    assert list(ad) == list(bd), ctx
    assert a.row_labels.to_list() == b.row_labels.to_list(), ctx
    for k in ad:
        np.testing.assert_array_equal(np.asarray(ad[k]), np.asarray(bd[k]),
                                      err_msg=f"{ctx}/{k}")


def _pipeline_ops(ex: Executor, plan: alg.Node) -> int:
    return sum(len(n.params["stages"]) for n in ex._prepared(plan).walk()
               if n.op == "fused_pipeline")


def _bench(rep: Reporter, n_rows: int, row_parts: int, reps: int) -> dict:
    pf = PartitionedFrame.from_frame(_dup_frame(n_rows), row_parts=row_parts)
    rf = PartitionedFrame.from_frame(_dup_frame(max(n_rows // 4, 1), seed=12),
                                     row_parts=max(row_parts // 4, 1))
    store = {"l": pf, "r": rf}
    lsrc = alg.Source("l", nrows=pf.nrows, ncols=pf.ncols)
    rsrc = alg.Source("r", nrows=rf.nrows, ncols=rf.ncols)

    out: dict = {"rows": n_rows, "row_parts": row_parts,
                 "pool_workers": schedule.pool_width(), "chains": {}}
    for chain, plan in _chains(lsrc, rsrc).items():
        # correctness gate + counter attribution before timing
        frames, stats = {}, {}
        for mode in MODES:
            with _mode(mode):
                ex = Executor(store, optimize=MODES[mode]["optimize"])
                frames[mode] = ex.evaluate(plan).to_frame()
                stats[mode] = ex.stats
                s = ex.stats
                assert s.fused_stage_ops == (_pipeline_ops(ex, plan)
                                             + s.producer_stage_ops
                                             + s.consumer_stage_ops), (chain, mode)
        _assert_equal(frames["serial_seed"], frames["block"], chain)
        _assert_equal(frames["serial_seed"], frames["fused"], chain)
        assert stats["fused"].barrier_fused_groups >= 1, f"{chain}: not fused"
        assert stats["fused"].producer_stage_ops >= 1, chain
        # block-parallel key extraction covered the whole (staged) input
        assert stats["block"].dedup_blocks > stats["serial_seed"].dedup_blocks, chain
        assert stats["fused"].dedup_key_rows > 0, chain

        execs = {}
        for mode in MODES:
            with _mode(mode):
                execs[mode] = Executor(store, optimize=MODES[mode]["optimize"])

        def run(mode):
            ex = execs[mode]
            ex.cache.clear()      # fresh evaluation; reuse is measured elsewhere
            with _mode(mode):
                return ex.evaluate(plan)

        # interleave MANY short passes and take each mode's MEDIAN pass-best:
        # adjacent passes see similar background load on a shared box, and a
        # median is robust to the occasional polluted (or lucky) window that
        # a min-of-everything would latch onto
        samples: dict[str, list[float]] = {m: [] for m in MODES}
        for _ in range(8):
            for mode in MODES:
                samples[mode].append(time_us(lambda m=mode: run(m), reps=reps))
        times = {m: float(np.median(v)) for m, v in samples.items()}

        entry: dict = {"modes": {}}
        for mode in MODES:
            speedup = times["serial_seed"] / max(times[mode], 1e-9)
            rep.add(f"dedup/{chain}/{mode}[{n_rows}x{row_parts}]",
                    times[mode], f"speedup={speedup:.2f}x")
            s = stats[mode]
            entry["modes"][mode] = {
                "us": round(times[mode], 1),
                "speedup_vs_serial_seed": round(speedup, 3),
                "dedup_blocks": s.dedup_blocks,
                "dedup_key_rows": s.dedup_key_rows,
                "gather_rows": s.gather_rows,
            }
        out["chains"][chain] = entry
    return out


def run(rep: Reporter, smoke: bool = False) -> None:
    # Pin a ≤8-worker pool for THIS suite (the win needs a multi-worker pool
    # regardless of the host), restoring the surrounding pool afterwards.
    saved = os.environ.get("REPRO_POOL_WORKERS")
    os.environ["REPRO_POOL_WORKERS"] = saved or str(min(8, os.cpu_count() or 4))
    schedule.reset_pool()
    try:
        if smoke:
            # sanity only: don't overwrite the recorded full-size numbers
            _bench(rep, 20_000, 8, reps=1)
            return
        results = [
            _bench(rep, 100_000, 16, reps=2),
            _bench(rep, 200_000, 16, reps=2),
        ]
        write_bench_json(_JSON_PATH, {
            "benchmark":
            "block-parallel + fused DIFFERENCE/DROP-DUPLICATES "
            "vs the serial seed path",
            "pool_workers": schedule.pool_width(),
            "results": results})
    finally:
        if saved is None:
            os.environ.pop("REPRO_POOL_WORKERS", None)
        else:
            os.environ["REPRO_POOL_WORKERS"] = saved
        schedule.reset_pool()


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, single rep (CI sanity mode)")
    args = ap.parse_args()
    rep = Reporter()
    print("name,us_per_call,derived")
    run(rep, smoke=args.smoke)


if __name__ == "__main__":
    main()
