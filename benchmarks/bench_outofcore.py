"""Out-of-core block store + chunk-parallel streaming CSV ingest.

Two scenarios, numbers landing in ``BENCH_outofcore.json``:

  * ``ingest`` — ``api.read_csv`` (chunk-parallel streaming parser into
    store blocks) vs the seed parser (``REPRO_CSV_STREAM=0``: whole file as
    host lists + per-value Python casts) on a 100k×16 CSV with a 2-worker
    pool.  Headline target: streaming ≥ 1.5× the seed parser.

  * ``outofcore`` — a map→filter→groupby→drop-duplicates pipeline over a
    dataset 4× the configured ``REPRO_MEM_BUDGET``: must complete (the seed
    engine simply could not open larger-than-memory data), stay bit-identical
    to the unbudgeted run, report ``spills > 0`` with
    ``peak_resident_bytes`` within budget + one block, and the run records
    the residency-governed slowdown factor (the price of 4× memory headroom).

Correctness is asserted before timing, as in the other suites.
"""
from __future__ import annotations

import os
import tempfile

# standalone runs mirror benchmarks/run.py: one partition ↔ one core, set
# before jax initializes
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import numpy as np

from repro.core import EvalMode, Session, set_session
from repro.core import schedule
from repro.core.api import read_csv
from repro.core.store import get_store, reset_store

from ._util import Reporter, time_us, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_outofcore.json")


def _write_csv(path: str, n_rows: int, n_cols: int = 16, seed: int = 7) -> None:
    """Mixed-domain CSV: 8 int, 4 float (exactly-representable), 2 str,
    2 bool columns → 16 wide at the default."""
    rng = np.random.default_rng(seed)
    n_int = n_cols // 2
    n_flt = n_cols // 4
    n_str = (n_cols - n_int - n_flt) // 2
    n_bool = n_cols - n_int - n_flt - n_str
    header = ([f"i{j}" for j in range(n_int)] + [f"f{j}" for j in range(n_flt)]
              + [f"s{j}" for j in range(n_str)] + [f"b{j}" for j in range(n_bool)])
    ints = rng.integers(0, 50, (n_rows, n_int))
    flts = rng.integers(0, 64, (n_rows, n_flt)) * 0.25
    strs = rng.integers(0, 20, (n_rows, n_str))
    bools = rng.integers(0, 2, (n_rows, n_bool))
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for i in range(n_rows):
            row = ([str(v) for v in ints[i]]
                   + [str(v) for v in flts[i]]
                   + [f"cat{v:02d}" for v in strs[i]]
                   + [("true" if v else "false") for v in bools[i]])
            f.write(",".join(row) + "\n")


# =============================================================================
# scenario 1: streaming vs seed CSV ingest
# =============================================================================
def _bench_ingest(rep: Reporter, n_rows: int, reps: int) -> dict:
    tmp = tempfile.mkdtemp(prefix="repro-bench-csv-")
    path = os.path.join(tmp, "wide.csv")
    _write_csv(path, n_rows)

    def ingest(stream: bool):
        env0 = os.environ.get("REPRO_CSV_STREAM")
        os.environ["REPRO_CSV_STREAM"] = "1" if stream else "0"
        try:
            s = set_session(Session(mode=EvalMode.LAZY))
            df = read_csv(path)
            out = df.collect()
            s.close()
            return out
        finally:
            if env0 is None:
                os.environ.pop("REPRO_CSV_STREAM", None)
            else:
                os.environ["REPRO_CSV_STREAM"] = env0

    # correctness gate: the streaming parse is value-identical to the seed
    # parse on this (plain LF, unquoted) file
    a, b = ingest(True), ingest(False)
    assert a.to_pydict() == b.to_pydict(), "stream/seed parse divergence"
    assert a.row_labels.to_list() == b.row_labels.to_list()

    samples = {"stream": [], "seed": []}
    for _ in range(3):          # interleaved passes, median (see bench_dedup)
        samples["stream"].append(time_us(lambda: ingest(True),
                                         reps=reps, warmup=0))
        samples["seed"].append(time_us(lambda: ingest(False),
                                       reps=reps, warmup=0))
    t_stream = float(np.median(samples["stream"]))
    t_seed = float(np.median(samples["seed"]))
    speedup = t_seed / max(t_stream, 1e-9)
    rep.add(f"outofcore/ingest/stream[{n_rows}x16]", t_stream,
            f"speedup={speedup:.2f}x")
    rep.add(f"outofcore/ingest/seed[{n_rows}x16]", t_seed, "baseline")
    return {"rows": n_rows, "cols": 16,
            "csv_bytes": os.path.getsize(path),
            "stream_us": round(t_stream, 1), "seed_us": round(t_seed, 1),
            "speedup": round(speedup, 3),
            "pool_workers": schedule.pool_width()}


# =============================================================================
# scenario 2: pipeline over data 4× the memory budget
# =============================================================================
def _pipeline(path: str):
    s = set_session(Session(mode=EvalMode.LAZY))
    df = read_csv(path)
    df["y"] = df["f0"] * 2.0 + 1.0
    out = (df[df["i1"] > 10].groupby("i0")
           .agg({"y": "sum", "f1": "mean", "i2": "count"})
           .drop_duplicates())
    got = out.collect()
    total = s.frames["frame_0"].nbytes()
    stats = s.executor.stats
    # snapshot while the frames are live: _handles is a WeakSet, and close()
    # vacates the default-session slot, so the handles are collectable after
    biggest = max((h.nbytes for h in get_store()._handles), default=0)
    s.close()
    return got, total, stats, biggest


def _bench_outofcore(rep: Reporter, n_rows: int, reps: int) -> dict:
    tmp = tempfile.mkdtemp(prefix="repro-bench-ooc-")
    path = os.path.join(tmp, "big.csv")
    _write_csv(path, n_rows)

    os.environ.pop("REPRO_MEM_BUDGET", None)
    reset_store()
    ref, total, _, _ = _pipeline(path)
    budget = total // 4                       # the dataset is 4× this budget

    os.environ["REPRO_MEM_BUDGET"] = str(budget)
    reset_store()
    try:
        got, _, st, one_block = _pipeline(path)
        ss = get_store().stats
        # acceptance gates: completes, bit-identical, spilled, peak bounded
        assert got.to_pydict() == ref.to_pydict(), "budgeted run diverged"
        assert st.spills > 0 and st.faults > 0, "budget never engaged"
        assert one_block > 0
        assert ss.peak_resident_bytes <= budget + one_block, (
            ss.peak_resident_bytes, budget, one_block)

        t_budget = float(np.median([
            time_us(lambda: _pipeline(path)[0], reps=reps, warmup=0)
            for _ in range(3)]))
        os.environ.pop("REPRO_MEM_BUDGET", None)
        reset_store()
        t_free = float(np.median([
            time_us(lambda: _pipeline(path)[0], reps=reps, warmup=0)
            for _ in range(3)]))
        factor = t_budget / max(t_free, 1e-9)
        rep.add(f"outofcore/pipeline/budgeted[{n_rows}x16]", t_budget,
                f"slowdown={factor:.2f}x spills={st.spills}")
        rep.add(f"outofcore/pipeline/unbudgeted[{n_rows}x16]", t_free,
                "all-resident baseline")
        return {"rows": n_rows, "device_bytes": total, "budget": budget,
                "budgeted_us": round(t_budget, 1),
                "unbudgeted_us": round(t_free, 1),
                "slowdown": round(factor, 3),
                "spills": st.spills, "faults": st.faults,
                "spilled_bytes": st.spilled_bytes,
                "peak_resident_bytes": ss.peak_resident_bytes,
                "pool_workers": schedule.pool_width()}
    finally:
        os.environ.pop("REPRO_MEM_BUDGET", None)
        reset_store()


def run(rep: Reporter, smoke: bool = False) -> None:
    # Pin a 2-worker pool (the acceptance configuration) regardless of host.
    saved = os.environ.get("REPRO_POOL_WORKERS")
    os.environ["REPRO_POOL_WORKERS"] = "2"
    schedule.reset_pool()
    try:
        if smoke:
            # sanity only: don't overwrite the recorded full-size numbers
            _bench_ingest(rep, 4_000, reps=1)
            _bench_outofcore(rep, 6_000, reps=1)
            return
        ingest = _bench_ingest(rep, 100_000, reps=1)
        ooc = _bench_outofcore(rep, 100_000, reps=1)
        # gate BEFORE writing: a noisy run must not overwrite the recorded
        # numbers with a sub-threshold artifact
        assert ingest["speedup"] >= 1.5, (
            f"ingest speedup regressed: {ingest['speedup']:.2f}x < 1.5x")
        write_bench_json(_JSON_PATH, {
            "benchmark":
            "out-of-core block store + streaming CSV ingest "
            "(spill/fault residency under REPRO_MEM_BUDGET)",
            "pool_workers": schedule.pool_width(),
            "ingest": ingest, "outofcore": ooc})
    finally:
        if saved is None:
            os.environ.pop("REPRO_POOL_WORKERS", None)
        else:
            os.environ["REPRO_POOL_WORKERS"] = saved
        schedule.reset_pool()


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, single rep (CI sanity mode)")
    args = ap.parse_args()
    rep = Reporter()
    print("name,us_per_call,derived")
    run(rep, smoke=args.smoke)


if __name__ == "__main__":
    main()
