"""Fault-tolerance layer: retry-machinery overhead + chaos completion price.

Two scenarios, numbers landing in ``BENCH_faults.json``:

  * ``overhead`` — the retry/deadline machinery with injection DISABLED
    (the production path) vs ``REPRO_TASK_RETRIES=0`` (machinery compiled
    out of the dispatch path) on a dispatch-heavy workload.  Headline gate:
    ≤ 1% — a zero-fault run must not pay for robustness it isn't using.

  * ``chaos`` — the acceptance pipeline (map→filter→groupby→drop-duplicates
    over a CSV, 4× the memory budget) under a seeded 5%-rate fault plan
    (worker exceptions + corrupt spill reads + ENOSPC spill writes): must
    complete bit-identical to the fault-free run, and the run records the
    recovery slowdown factor plus the injected/retried/recomputed counters.

Correctness is asserted before timing, as in the other suites.
"""
from __future__ import annotations

import os
import tempfile

# standalone runs mirror benchmarks/run.py: one partition ↔ one core, set
# before jax initializes
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import numpy as np

from repro.core import EvalMode, Session, set_session
from repro.core import faults, schedule
from repro.core.api import read_csv
from repro.core.store import get_store, reset_store

from ._util import Reporter, time_us, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_faults.json")

_CHAOS_PLAN = "worker:0.05,corrupt:0.05,enospc:0.05"
_CHAOS_SEED = 11


# =============================================================================
# scenario 1: retry machinery at 0% faults — the production-path tax
# =============================================================================
def _bench_overhead(rep: Reporter, nblocks: int, block_rows: int,
                    reps: int) -> dict:
    """Dispatch-heavy workload: ``nblocks`` pool tasks each doing real numpy
    work, so the guarded-dispatch bookkeeping (try/except + knob reads) is
    measured against a realistic per-block cost."""
    rng = np.random.default_rng(0)
    blocks = [rng.standard_normal(block_rows) for _ in range(nblocks)]

    def work(x):
        return float(np.sort(x)[block_rows // 2])

    def sweep():
        return schedule.dispatch_blocks(work, blocks)

    schedule.configure_retries(clear=True)
    ref = sweep()
    schedule.configure_retries(retries=0)
    assert sweep() == ref, "retries=0 path diverged"
    schedule.configure_retries(clear=True)

    samples = {"guarded": [], "bare": []}
    for _ in range(5):          # interleaved passes, median (see bench_dedup)
        schedule.configure_retries(clear=True)     # default: retries=2
        samples["guarded"].append(time_us(sweep, reps=reps, warmup=0))
        schedule.configure_retries(retries=0)      # machinery disabled
        samples["bare"].append(time_us(sweep, reps=reps, warmup=0))
    schedule.configure_retries(clear=True)
    t_guard = float(np.median(samples["guarded"]))
    t_bare = float(np.median(samples["bare"]))
    overhead = t_guard / max(t_bare, 1e-9) - 1.0
    rep.add(f"faults/overhead/guarded[{nblocks}x{block_rows}]", t_guard,
            f"overhead={overhead * 100:.2f}%")
    rep.add(f"faults/overhead/retries0[{nblocks}x{block_rows}]", t_bare,
            "baseline")
    return {"nblocks": nblocks, "block_rows": block_rows,
            "guarded_us": round(t_guard, 1), "retries0_us": round(t_bare, 1),
            "overhead_pct": round(overhead * 100, 3),
            "pool_workers": schedule.pool_width()}


# =============================================================================
# scenario 2: completion under a seeded 5% fault plan, 4×-budget pipeline
# =============================================================================
def _write_csv(path: str, n: int, seed: int = 7) -> None:
    rng = np.random.default_rng(seed)
    k = rng.integers(0, 8, n)
    v = rng.integers(0, 50, n)
    x = rng.integers(0, 12, n) * 0.25
    with open(path, "w") as f:
        f.write("k,v,x\n")
        for i in range(n):
            f.write(f"{k[i]},{v[i]},{x[i]}\n")


def _pipeline(path: str):
    s = set_session(Session(mode=EvalMode.LAZY))
    df = read_csv(path)
    df["y"] = df["x"] * 2.0 + 1.0
    out = (df[df["v"] > 10].groupby("k")
           .agg({"y": "sum", "x": "mean"}).drop_duplicates())
    got = out.collect()
    total = s.frames["frame_0"].nbytes()
    stats = s.executor.stats
    s.close()
    return got, total, stats


def _bench_chaos(rep: Reporter, n_rows: int, reps: int) -> dict:
    tmp = tempfile.mkdtemp(prefix="repro-bench-faults-")
    path = os.path.join(tmp, "big.csv")
    _write_csv(path, n_rows)

    os.environ.pop("REPRO_MEM_BUDGET", None)
    faults.reset()
    reset_store()
    ref, total, _ = _pipeline(path)

    os.environ["REPRO_MEM_BUDGET"] = str(total // 4)
    os.environ["REPRO_RETRY_BACKOFF_MS"] = "1"
    try:
        reset_store()
        got_clean, _, _ = _pipeline(path)          # budgeted, fault-free
        assert got_clean.to_pydict() == ref.to_pydict(), (
            "budgeted run diverged")

        faults.configure(plan=_CHAOS_PLAN, seed=_CHAOS_SEED)
        reset_store()
        got, _, st = _pipeline(path)
        # the acceptance gate: completes bit-identical under injected chaos
        assert got.to_pydict() == ref.to_pydict(), "chaos run diverged"
        assert st.faults_injected > 0, "the 5% plan never fired"
        ss = get_store().stats
        assert ss.leaked_spill_files == 0

        t_chaos = float(np.median([
            time_us(lambda: _pipeline(path)[0], reps=reps, warmup=0)
            for _ in range(3)]))
        faults.reset()
        reset_store()
        t_clean = float(np.median([
            time_us(lambda: _pipeline(path)[0], reps=reps, warmup=0)
            for _ in range(3)]))
        factor = t_chaos / max(t_clean, 1e-9)
        rep.add(f"faults/chaos/5pct[{n_rows}]", t_chaos,
                f"slowdown={factor:.2f}x injected={st.faults_injected}")
        rep.add(f"faults/chaos/clean[{n_rows}]", t_clean,
                "fault-free budgeted baseline")
        return {"rows": n_rows, "plan": _CHAOS_PLAN, "seed": _CHAOS_SEED,
                "budget": total // 4,
                "chaos_us": round(t_chaos, 1), "clean_us": round(t_clean, 1),
                "slowdown": round(factor, 3),
                "faults_injected": st.faults_injected,
                "retries": st.retries, "task_failures": st.task_failures,
                "checksum_failures": st.checksum_failures,
                "recomputed_blocks": st.recomputed_blocks,
                "budget_overruns": st.budget_overruns,
                "pool_workers": schedule.pool_width()}
    finally:
        os.environ.pop("REPRO_MEM_BUDGET", None)
        os.environ.pop("REPRO_RETRY_BACKOFF_MS", None)
        faults.reset()
        reset_store()


def run(rep: Reporter, smoke: bool = False) -> None:
    # Pin a 2-worker pool (the acceptance configuration) regardless of host.
    saved = os.environ.get("REPRO_POOL_WORKERS")
    os.environ["REPRO_POOL_WORKERS"] = "2"
    schedule.reset_pool()
    faults.reset()
    try:
        if smoke:
            # sanity only: don't overwrite the recorded full-size numbers,
            # and don't gate the overhead ratio at tiny sizes (noise-bound)
            _bench_overhead(rep, 32, 20_000, reps=1)
            _bench_chaos(rep, 6_000, reps=1)
            return
        overhead = _bench_overhead(rep, 64, 100_000, reps=3)
        chaos = _bench_chaos(rep, 60_000, reps=1)
        # gate BEFORE writing: the zero-fault production path must not pay
        # for the retry machinery (ISSUE 6 acceptance: ≤ 1%)
        assert overhead["overhead_pct"] <= 1.0, (
            f"retry machinery overhead {overhead['overhead_pct']:.2f}% > 1%")
        write_bench_json(_JSON_PATH, {
            "benchmark":
            "fault-tolerant execution (retry/recompute/"
            "degradation) — zero-fault overhead + 5%-chaos "
            "completion",
            "pool_workers": schedule.pool_width(),
            "overhead": overhead, "chaos": chaos})
    finally:
        if saved is None:
            os.environ.pop("REPRO_POOL_WORKERS", None)
        else:
            os.environ["REPRO_POOL_WORKERS"] = saved
        schedule.reset_pool()
        faults.reset()
