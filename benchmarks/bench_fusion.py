"""Fused blockwise pipelines (paper §5 "Pipelining") vs per-node evaluation.

A 4-operator row-local chain — MAP → SELECTION → PROJECTION → MAP — over a
multi-block frame, executed two ways on the same frame store:

  * **unfused** (``Executor(optimize=False)``): the per-node path — every
    operator materializes, hashes and caches its own ``PartitionedFrame``,
    so the chain costs four full partition sweeps;
  * **fused** (``Executor(optimize=True)``): the fusion pass collapses the
    chain into one ``FusedPipeline`` group run as a single per-block program
    (one pool dispatch, values on device across stages, one cache entry).

Also times the zero-copy row regroup against the legacy concat+resplit
repartition it replaced.  Numbers land in ``BENCH_fusion.json`` so the win is
recorded alongside the ``ExecStats`` fusion counters that attribute it.
"""
from __future__ import annotations

import os

import numpy as np
import jax.numpy as jnp

from repro.core import algebra as alg
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame

from ._util import Reporter, time_us, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fusion.json")


def _mixed_frame(n_rows: int, seed: int = 3) -> Frame:
    rng = np.random.default_rng(seed)
    cols = [
        Column(jnp.asarray(rng.integers(0, 5, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.integers(-1000, 1000, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.standard_normal(n_rows).astype(np.float32)), Domain.FLOAT),
        Column(jnp.asarray(rng.standard_normal(n_rows).astype(np.float32)), Domain.FLOAT),
    ]
    return Frame(cols, RangeLabels(n_rows), labels_from_values(["k", "v", "f", "g"]))


def _scale(name: str, a: float, b: float) -> alg.Udf:
    def fn(cols, frame):
        out = dict(cols)
        c = cols[name]
        out[name] = Column(c.data * a + b, Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name=f"scale_{name}_{a}_{b}", fn=fn,
                   deps=frozenset([name]), elementwise=True)


def _chain(src: alg.Node) -> alg.Node:
    m1 = alg.Map(src, _scale("f", 2.0, 1.0))
    sel = alg.Selection(m1, alg.col("v") > alg.lit(0))
    proj = alg.Projection(sel, ("v", "f", "g"))
    return alg.Map(proj, _scale("g", 0.5, -1.0))


def _bench(rep: Reporter, n_rows: int, row_parts: int, reps: int) -> dict:
    pf = PartitionedFrame.from_frame(_mixed_frame(n_rows), row_parts=row_parts)
    store = {"bench": pf}
    src = alg.Source("bench", nrows=pf.nrows, ncols=pf.ncols)
    plan = _chain(src)

    fused_ex = Executor(store, optimize=True)
    plain_ex = Executor(store, optimize=False)

    def run(ex):
        ex.cache.clear()          # fresh evaluation; reuse is measured elsewhere
        return ex.evaluate(plan)

    # correctness gate before timing: both paths must agree exactly
    a = fused_ex.evaluate(plan).to_frame().to_pydict()
    b = plain_ex.evaluate(plan).to_frame().to_pydict()
    assert list(a) == list(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))

    # interleave A/B passes (best-of overall): shields the ratio from drift
    # on a shared machine — one slow burst can't bias a single side
    t_unfused, t_fused = float("inf"), float("inf")
    for _ in range(2):
        t_unfused = min(t_unfused, time_us(lambda: run(plain_ex), reps=reps))
        t_fused = min(t_fused, time_us(lambda: run(fused_ex), reps=reps))
    speedup = t_unfused / max(t_fused, 1e-9)
    rep.add(f"fusion/chain4/unfused[{n_rows}x{row_parts}]", t_unfused, "")
    rep.add(f"fusion/chain4/fused[{n_rows}x{row_parts}]", t_fused,
            f"speedup={speedup:.2f}x")

    # zero-copy row regroup vs the legacy concat + re-split it replaced
    half = max(1, row_parts // 2)
    t_zero = time_us(lambda: pf.repartition(row_parts=half), reps=reps)
    t_copy = time_us(
        lambda: PartitionedFrame.from_frame(pf.to_frame(), half), reps=reps)
    rep.add(f"fusion/repartition/zero_copy[{row_parts}->{half}]", t_zero,
            f"vs_full_copy={t_copy / max(t_zero, 1e-9):.2f}x")

    return {
        "rows": n_rows,
        "row_parts": row_parts,
        "chain_ops": 4,
        "unfused_us": round(t_unfused, 1),
        "fused_us": round(t_fused, 1),
        "speedup": round(speedup, 3),
        "fused_groups": fused_ex.stats.fused_groups,
        "fused_stage_ops": fused_ex.stats.fused_stage_ops,
        "repartition_zero_copy_us": round(t_zero, 1),
        "repartition_full_copy_us": round(t_copy, 1),
    }


def run(rep: Reporter, smoke: bool = False) -> None:
    if smoke:
        # sanity only: don't overwrite the recorded full-size numbers
        _bench(rep, 20_000, 4, reps=1)
        return
    # many-partition regime: per-operator sweep overhead (pool rounds,
    # intermediate PartitionedFrames, cache stores, per-stage dispatch) is
    # what fusion removes; block compute itself is identical in both paths
    results = [
        _bench(rep, 100_000, 16, reps=5),
        _bench(rep, 200_000, 32, reps=5),
    ]
    write_bench_json(_JSON_PATH, {
        "benchmark": "fused blockwise pipelines", "results": results})


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, single rep (CI sanity mode)")
    args = ap.parse_args()
    rep = Reporter()
    print("name,us_per_call,derived")
    run(rep, smoke=args.smoke)


if __name__ == "__main__":
    main()
