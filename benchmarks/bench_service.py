"""Multi-session query service: concurrent-tenant throughput vs one tenant.

The serving-tier argument (``core/service.py``), numbers landing in
``BENCH_service.json``: interactive tenants spend most of their wall-clock
*thinking* between statements, so one service hosting many sessions over a
2-worker pool should deliver far more aggregate queries/second than a single
session — think time overlaps other tenants' compute, the admission
controller keeps the pool fed fairly, and cross-session MQO (tenants sharing
plan prefixes over a shared table) turns repeated work into cache hits.

Headline gate (ISSUE 9 acceptance): 16-session aggregate qps ≥ 3× the
1-session qps on the same 2-worker pool, same per-tenant query stream and
think time.  Correctness is asserted before timing: every tenant's results
must be bit-identical to a serial, isolated run of its stream.
"""
from __future__ import annotations

import os
import threading
import time

# standalone runs mirror benchmarks/run.py: one partition ↔ one core, set
# before jax initializes
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import numpy as np

from repro.core import EvalMode, QueryService, Session, schedule
from repro.core.algebra import GroupBy, Map, Selection, Udf, col, lit
from repro.core.dtypes import Domain
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values

from ._util import Reporter, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_service.json")

_TENANT_CLASSES = 4      # sessions i and i+4 share a query stream (MQO seam)


def _table(n: int, seed: int = 0) -> Frame:
    rng = np.random.default_rng(seed)
    return Frame(
        [Column(np.asarray(rng.integers(0, 16, n, dtype=np.int32)), Domain.INT),
         Column(np.asarray(rng.standard_normal(n)), Domain.FLOAT),
         Column(np.asarray(rng.standard_normal(n)), Domain.FLOAT)],
        RangeLabels(n), labels_from_values(["k", "x", "y"]))


def _query(shared, tenant_class: int, j: int):
    """One statement of a tenant's stream: filter → map → groupby.  Plans
    are distinct per (tenant_class, j) but SHARED across the sessions of a
    class — the cross-session MQO surface."""
    scale = 1.0 + tenant_class + 0.25 * j

    def fn(cols, frame, scale=scale):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * scale + 1.0, Domain.FLOAT, c.mask, None)
        return out

    udf = Udf(name=f"svc_q_c{tenant_class}_j{j}", fn=fn,
              deps=frozenset(["x"]), elementwise=True)
    return GroupBy(Selection(Map(shared, udf), col("k") < lit(12)),
                   ("k",), [("x", "sum", "x"), ("y", "mean", "y")])


def _run_stream(session, shared, tenant_class: int, queries: int,
                think_s: float) -> list:
    """A tenant's interactive loop: submit (async, admission-controlled) →
    think → inspect.  Returns the collected results."""
    out = []
    for j in range(queries):
        node = session.statement(_query(shared, tenant_class, j))
        time.sleep(think_s)              # think time: other tenants' window
        out.append(session.collect(node).to_pydict())
    return out


def _measure(n_sessions: int, queries: int, think_s: float, rows: int,
             expected: list | None = None):
    """Wall-clock one service run of ``n_sessions`` concurrent tenants;
    returns (qps, results-per-session, service)."""
    svc = QueryService(background_workers=2)
    try:
        shared = svc.register_frame(_table(rows), row_parts=4)
        sessions = [svc.session(mode=EvalMode.OPPORTUNISTIC)
                    for _ in range(n_sessions)]
        results: list = [None] * n_sessions
        errors: list = []

        def tenant(i: int) -> None:
            try:
                results[i] = _run_stream(sessions[i], shared,
                                         i % _TENANT_CLASSES, queries, think_s)
            except BaseException as e:   # noqa: BLE001 - surfaced below
                errors.append((i, e))

        t0 = time.perf_counter()
        if n_sessions == 1:
            tenant(0)
        else:
            threads = [threading.Thread(target=tenant, args=(i,))
                       for i in range(n_sessions)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0][1]
        if expected is not None:
            for i, got in enumerate(results):
                assert got == expected[i % _TENANT_CLASSES], (
                    f"tenant {i} diverged from its serial isolated run")
        # per-session attribution must sum to the service's global counters
        per = sum(s.stats.evaluated_nodes for s in sessions)
        assert per == svc.stats.evaluated_nodes, (per, svc.stats.evaluated_nodes)
        qps = (n_sessions * queries) / wall
        return qps, wall, svc.stats
    finally:
        svc.close()


def _serial_reference(queries: int, rows: int) -> list:
    """Each tenant class's stream, run serially in an isolated session."""
    expected = []
    for c in range(_TENANT_CLASSES):
        s = Session(mode=EvalMode.LAZY)
        try:
            shared = s.register_frame(_table(rows), row_parts=4)
            expected.append([s.collect(_query(shared, c, j)).to_pydict()
                             for j in range(queries)])
        finally:
            s.close()
    return expected


def _bench(rep: Reporter, n_sessions: int, queries: int, think_ms: float,
           rows: int, *, gate: bool) -> dict:
    think_s = think_ms / 1000.0
    expected = _serial_reference(queries, rows)

    qps1, wall1, _ = _measure(1, queries, think_s, rows, expected)
    qpsN, wallN, stats = _measure(n_sessions, queries, think_s, rows, expected)
    ratio = qpsN / max(qps1, 1e-9)

    rep.add(f"service/qps/1session[{queries}q,{think_ms:g}ms]",
            wall1 * 1e6 / queries, f"qps={qps1:.1f}")
    rep.add(f"service/qps/{n_sessions}sessions[{queries}q,{think_ms:g}ms]",
            wallN * 1e6 / (n_sessions * queries),
            f"qps={qpsN:.1f} ratio={ratio:.2f}x "
            f"mqo_hits={stats.cache_hits} joins={stats.inflight_joins}")
    if gate:
        assert ratio >= 3.0, (
            f"{n_sessions}-session qps only {ratio:.2f}x the 1-session qps "
            "(acceptance floor: 3x)")
    return {"sessions": n_sessions, "queries_per_session": queries,
            "think_ms": think_ms, "rows": rows,
            "qps_1session": round(qps1, 2),
            f"qps_{n_sessions}sessions": round(qpsN, 2),
            "ratio": round(ratio, 3),
            "wall_1session_s": round(wall1, 4),
            f"wall_{n_sessions}sessions_s": round(wallN, 4),
            "mqo_cache_hits": stats.cache_hits,
            "inflight_joins": stats.inflight_joins,
            "pool_workers": schedule.pool_width()}


def run(rep: Reporter, smoke: bool = False) -> None:
    # Pin the acceptance configuration (2-worker pool) regardless of host.
    saved = os.environ.get("REPRO_POOL_WORKERS")
    os.environ["REPRO_POOL_WORKERS"] = "2"
    schedule.reset_pool()
    try:
        if smoke:
            # sanity only: tiny stream, no ratio gate (noise-bound at this
            # size), no JSON overwrite
            _bench(rep, 4, 2, 10.0, 20_000, gate=False)
            return
        result = _bench(rep, 16, 8, 30.0, 100_000, gate=True)
        write_bench_json(_JSON_PATH, {
            "benchmark":
            "concurrent multi-session query service — aggregate "
            "qps of 16 think-time tenants vs 1 on a 2-worker "
            "pool (admission control + cross-session MQO)",
            "service": result})
    finally:
        if saved is None:
            os.environ.pop("REPRO_POOL_WORKERS", None)
        else:
            os.environ["REPRO_POOL_WORKERS"] = saved
        schedule.reset_pool()
