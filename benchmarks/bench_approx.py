"""Paper §6.1.3: approximate execution — latency to a ±1%-accurate estimate
vs the exact aggregate, via progressive (online-aggregation-style) evaluation.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.partition import PartitionedFrame
from repro.data.synthetic import taxi_like_frame

from ._util import Reporter


def run(rep: Reporter, smoke: bool = False) -> None:
    from repro.core.approx import progressive_aggregate

    n = 20_000 if smoke else 1_000_000
    frame = taxi_like_frame(n, seed=4)
    pf = PartitionedFrame.from_frame(frame, row_parts=8 if smoke else 32)

    t0 = time.perf_counter()
    exact = None
    for est in progressive_aggregate(pf, "f0", "mean"):
        exact = est  # final
    exact_s = time.perf_counter() - t0
    exact_val = exact.value

    # target: CI half-width ≤ 1% of the column's std (≈N(0,1) here)
    t0 = time.perf_counter()
    hit_s, hit_frac = None, None
    for est in progressive_aggregate(pf, "f0", "mean"):
        if est.final or (est.ci_high - est.ci_low) <= 0.02:
            hit_s = time.perf_counter() - t0
            hit_frac = est.fraction
            break
    rep.add("approx/mean_exact_scan", exact_s * 1e6, f"value={exact_val:.4f}")
    rep.add("approx/mean_to_1pct_std", hit_s * 1e6,
            f"rows_frac={hit_frac:.3f} speedup={exact_s / hit_s:.1f}x")
