"""Statement tracing: disabled-path overhead + traced chaos exactness.

Two scenarios, numbers landing in ``BENCH_trace.json``:

  * ``overhead``  — the bench_scheduling fused chain (map→filter→groupby,
                    200k rows × 64 partitions on a pinned ≤8-worker pool) run
                    with tracing *disabled* vs a stripped baseline where
                    ``trace.current`` is monkeypatched to a constant-None
                    lambda (approximating the pre-instrumentation code path).
                    The disabled path must cost ≤1% — it allocates no spans,
                    only a handful of resolution checks per dispatch.
  * ``chaos``     — a traced lazy statement under a seeded fault plan with a
                    4x-over-budget spill pipeline: asserts the span-attached
                    counter deltas sum *exactly* to the global ExecStats
                    movement for the statement, exports the Chrome trace and
                    validates it against the trace-event schema.

Passes are interleaved (A/B/A/B…) and best-of, shielding the ratio from
thermal/load drift on a shared box.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

# standalone runs mirror benchmarks/run.py: one partition ↔ one core, set
# before jax initializes
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import numpy as np
import jax.numpy as jnp

from repro.core import algebra as alg
from repro.core import schedule
from repro.core import trace as trace_mod
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.session import Session

from ._util import Reporter, time_us, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_trace.json")

_DELTA_KEYS = ("spills", "faults", "spilled_bytes", "checksum_failures",
               "recomputed_blocks", "budget_overruns", "faults_injected")


def _mk_frame(n_rows: int, seed: int = 5) -> Frame:
    rng = np.random.default_rng(seed)
    cols = [
        Column(jnp.asarray(rng.integers(0, 8, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.integers(-1000, 1000, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.standard_normal(n_rows).astype(np.float32)), Domain.FLOAT),
    ]
    return Frame(cols, RangeLabels(n_rows), labels_from_values(["k", "v", "x"]))


def _scale() -> alg.Udf:
    def fn(cols, frame):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * 2.0 + 1.0, Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name="trace_bench_scale", fn=fn,
                   deps=frozenset(["x"]), elementwise=True)


def _chain(src: alg.Node) -> alg.Node:
    return alg.GroupBy(
        alg.Selection(alg.Map(src, _scale()), alg.col("v") > alg.lit(0)),
        ("k",), [("x", "sum", "xs"), ("x", "mean", "xm"), ("v", "count", "vc")])


def _overhead(rep: Reporter, n_rows: int, row_parts: int, reps: int) -> dict:
    """Tracing-disabled vs stripped baseline on the bench_scheduling chain."""
    from repro.core.partition import PartitionedFrame
    pf = PartitionedFrame.from_frame(_mk_frame(n_rows), row_parts=row_parts)
    store = {"bench": pf}
    plan = _chain(alg.Source("bench", nrows=pf.nrows, ncols=pf.ncols))
    ex = Executor(store, optimize=True)

    def run():
        ex.cache.clear()
        return ex.evaluate(plan)

    real_current = trace_mod.current
    stripped = lambda *a, **k: None  # noqa: E731
    impls = {"stripped": stripped, "disabled": real_current}
    times = {"stripped": float("inf"), "disabled": float("inf")}
    try:
        # Interleave stripped (current ≡ None) vs disabled (real resolution),
        # alternating the order each pass: min-of-many on both sides cancels
        # the ±10% load drift a shared/1-core box shows between back-to-back
        # passes of *identical* code.
        order = list(impls)
        for i in range(8):
            for mode in (order if i % 2 == 0 else order[::-1]):
                trace_mod.current = impls[mode]
                times[mode] = min(times[mode], time_us(run, reps=reps))
    finally:
        trace_mod.current = real_current

    assert trace_mod.current is real_current
    overhead_pct = (times["disabled"] / max(times["stripped"], 1e-9) - 1) * 100
    rep.add(f"trace/disabled_overhead[{n_rows}x{row_parts}]",
            times["disabled"], f"overhead={overhead_pct:+.2f}%")
    return {"rows": n_rows, "row_parts": row_parts,
            "pool_workers": schedule.pool_width(),
            "stripped_us": round(times["stripped"], 1),
            "disabled_us": round(times["disabled"], 1),
            "overhead_pct": round(overhead_pct, 2)}


def _chaos(rep: Reporter, n_rows: int) -> dict:
    """Traced statement under faults + 4x-over-budget spill: exactness +
    Chrome-schema validity of the export."""
    import repro.core.api as api
    data = {"a": np.arange(n_rows, dtype=np.float64),
            "b": (np.arange(n_rows) % 97).astype(np.float64)}
    nbytes = n_rows * 8 * 2
    s = Session(mode="lazy", trace=True, mem_budget_bytes=nbytes // 4,
                fault_plan="worker:0.2,corrupt:0.5,enospc:0.5", fault_seed=7)
    try:
        df = api.from_pydict(data, session=s)
        q = df[df["a"] > 1000.0].groupby("b").agg({"a": ["sum", "mean"]})
        st0 = dataclasses.replace(s.stats)
        us = time_us(q.collect, reps=1, warmup=0)
        st1 = s.stats
        tr = s.tracer
        assert tr is not None and tr.open_spans() == 0, "leaked open spans"

        stmt = tr.last_stmt
        totals = tr.counter_totals(stmt)
        deltas = {k: getattr(st1, k) - getattr(st0, k) for k in _DELTA_KEYS}
        exact = all(totals.get(k, 0) == deltas[k] for k in _DELTA_KEYS)
        assert exact, f"span deltas != ExecStats: {totals} vs {deltas}"

        with tempfile.TemporaryDirectory() as td:
            path = s.trace_json(os.path.join(td, "chaos_trace.json"))
            import json
            doc = json.load(open(path))
        n_events = trace_mod.validate_chrome_trace(doc)
        prof = tr.profile(stmt)
        rep.add(f"trace/chaos[{n_rows}]", us,
                f"spans={prof['spans']} events={n_events} exact={exact}")
        return {"rows": n_rows, "wall_us": round(us, 1),
                "spans": prof["spans"], "chrome_events": n_events,
                "faults_fired": len(prof["faults_fired"]),
                "store": prof["store"],
                "counter_deltas": {k: int(deltas[k]) for k in _DELTA_KEYS},
                "deltas_exact": exact}
    finally:
        s.close()


def run(rep: Reporter, smoke: bool = False) -> None:
    # Pin a ≤8-worker pool for THIS suite only (same regime as the
    # bench_scheduling workload the overhead criterion is defined on), and
    # restore the surrounding pool afterwards.
    saved = os.environ.get("REPRO_POOL_WORKERS")
    os.environ["REPRO_POOL_WORKERS"] = saved or str(min(8, os.cpu_count() or 4))
    schedule.reset_pool()
    try:
        if smoke:
            # sanity only: don't overwrite the recorded full-size numbers
            _overhead(rep, 20_000, 16, reps=1)
            _chaos(rep, 50_000)
            return
        overhead = _overhead(rep, 200_000, 64, reps=5)
        chaos = _chaos(rep, 200_000)
        write_bench_json(_JSON_PATH, {
            "benchmark":
            "statement tracing — disabled-path overhead on the "
            "bench_scheduling chain + traced chaos exactness "
            "(span counter deltas == ExecStats)",
            "pool_workers": schedule.pool_width(),
            "overhead": overhead, "chaos": chaos})
    finally:
        if saved is None:
            os.environ.pop("REPRO_POOL_WORKERS", None)
        else:
            os.environ["REPRO_POOL_WORKERS"] = saved
        schedule.reset_pool()


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, single rep (CI sanity mode)")
    args = ap.parse_args()
    rep = Reporter()
    print("name,us_per_call,derived")
    run(rep, smoke=args.smoke)


if __name__ == "__main__":
    main()
