"""Paper §6.1.1–6.1.2: opportunistic evaluation & prefix computation.

Measures what the user *feels*: time from "statement typed" to "result
visible", with think time between statements.  Eager pays at statement time;
lazy pays at inspect time; opportunistic hides the work inside think time.
Plus: head(k) via prefix computation vs full evaluation.
"""
from __future__ import annotations

import time

from repro.core import DataFrame, EvalMode, Session, set_session

from ._util import Reporter

_N = 600_000
_THINK_S = 0.35


def _workflow(mode: str, n: int = _N, think_s: float = _THINK_S) -> tuple[float, float]:
    """Returns (statement_latency_s, inspect_latency_s) summed over steps."""
    s = set_session(Session(mode=mode, default_row_parts=8))
    try:
        data = {"v": list(range(n)), "w": [float(i % 97) for i in range(n)]}
        t0 = time.perf_counter()
        df = DataFrame(data)
        q = df[df["v"] % 3 == 0]
        q2 = q.cumsum(cols=["w"])
        stmt_s = time.perf_counter() - t0
        time.sleep(think_s)           # the user thinks / types
        t1 = time.perf_counter()
        q2.head(5)                    # then inspects
        inspect_s = time.perf_counter() - t1
        return stmt_s, inspect_s
    finally:
        s.close()


def run(rep: Reporter, smoke: bool = False) -> None:
    n = 20_000 if smoke else _N
    think = 0.05 if smoke else _THINK_S
    for mode in (EvalMode.EAGER, EvalMode.LAZY, EvalMode.OPPORTUNISTIC):
        stmt_s, inspect_s = _workflow(mode, n, think)
        rep.add(f"opportunistic/{mode}/statement", stmt_s * 1e6,
                f"inspect_us={inspect_s * 1e6:.0f}")
        rep.add(f"opportunistic/{mode}/inspect", inspect_s * 1e6,
                f"total_us={(stmt_s + inspect_s) * 1e6:.0f}")

    # prefix computation: head(5) on a selective plan, lazy session
    s = set_session(Session(mode=EvalMode.LAZY, default_row_parts=16))
    try:
        df = DataFrame({"v": list(range(n))})
        q = df[df["v"] > 100]
        t0 = time.perf_counter()
        q.head(5)
        prefix_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        q.collect()
        full_s = time.perf_counter() - t1
        rep.add("prefix/head5", prefix_s * 1e6,
                f"full_eval_us={full_s * 1e6:.0f} speedup={full_s / max(prefix_s, 1e-9):.1f}x")
    finally:
        s.close()
