"""Paper Figure 6: map / groupby(n) / groupby(1) / transpose — eager
single-partition execution (the pandas stand-in: one core, one block) vs
Modin-style block-partitioned parallel execution, across dataset scales.

The paper measured 12×/19×/30× and a transpose pandas could not run at all;
on this container the parallelism budget is the core count, so the expected
speedup ceiling is ≈ #cores for compute-bound ops.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import algebra as alg
from repro.core.partition import PartitionedFrame
from repro.core.physical import run_node
from repro.data.synthetic import numeric_matrix_frame, taxi_like_frame

from ._util import Reporter, time_us

_SCALES = (100_000, 1_000_000)
_SMOKE_SCALES = (5_000,)


def _exec(pf: PartitionedFrame, node_fn) -> PartitionedFrame:
    src = alg.Source("bench", 0, 0)

    def ev(node):
        if node.op == "source":
            return pf
        return run_node(node, [ev(c) for c in node.children])

    return ev(node_fn(src))


def _fillna_udf():
    import jax.numpy as jnp
    from repro.core.dtypes import Domain
    from repro.core.frame import Column, Frame
    from repro.core.labels import labels_from_values

    def fn(cols, frame):
        out = {}
        for n, c in cols.items():
            if c.domain is Domain.FLOAT and c.mask is not None:
                out[n] = Column(jnp.where(c.mask, c.data, 0.0), c.domain, None, None)
            else:
                out[n] = c
        return Frame(list(out.values()), frame.row_labels,
                     labels_from_values(list(out.keys())))

    return alg.Udf.wrap(fn, name="bench_fillna", elementwise=True)


def run(rep: Reporter, smoke: bool = False) -> None:
    cores = os.cpu_count() or 4
    for n in (_SMOKE_SCALES if smoke else _SCALES):
        frame = taxi_like_frame(n, seed=0)
        single = PartitionedFrame.from_frame(frame, row_parts=1)
        multi = PartitionedFrame.from_frame(frame, row_parts=cores)

        cases = {
            "map": lambda src: alg.Map(src, _fillna_udf()),
            "groupby_n": lambda src: alg.GroupBy(
                src, ("passenger_count",), [("f0", "count", "cnt")]),
            "groupby_1": lambda src: alg.GroupBy(src, (), [("f0", "count", "cnt")]),
        }
        for name, build in cases.items():
            t1 = time_us(lambda: _exec(single, build))
            tp = time_us(lambda: _exec(multi, build))
            rep.add(f"fig6/{name}/rows={n}/eager1p", t1,
                    f"rows_per_s={n / (t1 / 1e6):.3e}")
            rep.add(f"fig6/{name}/rows={n}/partitioned", tp,
                    f"speedup={t1 / tp:.2f}x")

        # transpose: homogeneous matrix frame (paper: taxi data replicated)
        mat = numeric_matrix_frame(n // 10, 64, seed=0)
        ms = PartitionedFrame.from_frame(mat, row_parts=1)
        mm = PartitionedFrame.from_frame(mat, row_parts=cores, col_parts=2)
        build_t = lambda src: alg.Transpose(src)
        t1 = time_us(lambda: _exec(ms, build_t))
        tp = time_us(lambda: _exec(mm, build_t))
        rep.add(f"fig6/transpose/rows={n // 10}x64/eager1p", t1,
                f"cells_per_s={(n // 10) * 64 / (t1 / 1e6):.3e}")
        rep.add(f"fig6/transpose/rows={n // 10}x64/partitioned", tp,
                f"speedup={t1 / tp:.2f}x")
