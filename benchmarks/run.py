"""Benchmark harness — one module per paper table/figure.

  fig6          paper Fig. 6: map/groupby(n)/groupby(1)/transpose, eager-1p
                (pandas stand-in) vs block-partitioned parallel
  opportunistic paper §6.1.1/6.1.2: eager vs lazy vs opportunistic + prefix
  rewrite       paper §5: transpose-elimination rewrites
  reuse         paper §6.2: session materialization/reuse
  approx        paper §6.1.3: progressive aggregation to ±1%
  roofline      deliverable (g): table from the dry-run artifacts
  fusion        paper §5: fused row-local pipelines vs per-node evaluation
                (also writes BENCH_fusion.json)
  blocking_fusion  barrier fusion through GROUPBY/SORT/JOIN/WINDOW
                (also writes BENCH_blocking_fusion.json)
  scheduling    adaptive block scheduling: coalesced pool dispatch +
                plan-time grid sizing vs per-block dispatch
                (also writes BENCH_scheduling.json)
  dedup         block-parallel + barrier-fused DIFFERENCE/DROP-DUPLICATES
                vs the serial seed path (also writes BENCH_dedup.json)
  outofcore     memory-governed spill/fault residency (REPRO_MEM_BUDGET) +
                chunk-parallel streaming CSV ingest vs the seed parser
                (also writes BENCH_outofcore.json)
  faults        fault-tolerant execution: retry-machinery overhead at 0%
                faults + completion under a seeded 5% chaos plan
                (also writes BENCH_faults.json)
  shuffle       shuffle-native JOIN/SORT: grace-hash + sample-sort exchange
                (serial_seed vs shuffled vs fused) + 4x-budget join
                (also writes BENCH_shuffle.json)
  service       concurrent multi-session query service: 16 think-time
                tenants vs 1 on a 2-worker pool — admission control +
                cross-session MQO (also writes BENCH_service.json)
  trace         statement tracing: disabled-path overhead on the scheduling
                chain + traced chaos span/ExecStats exactness
                (also writes BENCH_trace.json)

Prints ``name,us_per_call,derived`` CSV.  Select with ``--only fig6,reuse``.
``--smoke`` runs every suite at tiny sizes with no JSON/artifact overwrite —
the CI gate (scripts/check.sh) uses it so each bench at least executes.
"""
from __future__ import annotations

import os

# Single-threaded XLA intra-op execution (MUST precede jax init): the paper's
# baseline is single-core pandas; with default settings XLA:CPU multithreads
# single-partition ops internally, which would hide exactly the parallelism
# Modin-style partitioning adds.  One partition ↔ one core, as in Modin's
# worker model.
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import argparse  # noqa: E402
import sys  # noqa: E402

from ._util import Reporter


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny row counts, no JSON overwrite (CI sanity mode)")
    args, _ = ap.parse_known_args()

    from . import (bench_approx, bench_blocking_fusion, bench_dedup,
                   bench_faults, bench_fig6, bench_fusion,
                   bench_opportunistic, bench_outofcore, bench_reuse,
                   bench_rewrite, bench_roofline, bench_scheduling,
                   bench_service, bench_shuffle, bench_trace)
    suites = {
        "fig6": bench_fig6.run,
        "opportunistic": bench_opportunistic.run,
        "rewrite": bench_rewrite.run,
        "reuse": bench_reuse.run,
        "approx": bench_approx.run,
        "roofline": bench_roofline.run,
        "fusion": bench_fusion.run,
        "blocking_fusion": bench_blocking_fusion.run,
        "scheduling": bench_scheduling.run,
        "dedup": bench_dedup.run,
        "outofcore": bench_outofcore.run,
        "faults": bench_faults.run,
        "shuffle": bench_shuffle.run,
        "service": bench_service.run,
        "trace": bench_trace.run,
    }
    picked = suites if args.only == "all" else {
        k: suites[k] for k in args.only.split(",")}

    rep = Reporter()
    print("name,us_per_call,derived")
    failures = []
    for name, fn in picked.items():
        try:
            fn(rep, smoke=args.smoke)
        except Exception as e:  # keep the harness going; record the failure
            rep.add(f"{name}/ERROR", 0.0, repr(e)[:120])
            failures.append(name)
    sys.stdout.flush()
    if args.smoke and failures:   # the CI gate must notice a broken bench
        raise SystemExit(f"smoke failures: {', '.join(failures)}")


if __name__ == "__main__":
    main()
