"""Benchmark plumbing: timing + the CSV contract (name,us_per_call,derived),
plus the provenance stamp every BENCH_*.json carries (commit, pool width,
knob overrides) so recorded numbers can be traced back to a configuration."""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Callable

import jax


def _force(result) -> None:
    """Block until a Frame / PartitionedFrame / pytree is computed."""
    from repro.core.frame import Frame
    from repro.core.partition import PartitionedFrame
    if isinstance(result, PartitionedFrame):
        for row in result.parts:
            for blk in row:
                for c in blk.columns:
                    jax.block_until_ready(c.data)
    elif isinstance(result, Frame):
        for c in result.columns:
            jax.block_until_ready(c.data)
    else:
        jax.block_until_ready(result)


def time_us(fn: Callable, *, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        _force(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _force(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_meta() -> dict:
    """Provenance for BENCH_*.json: commit hash, pool width, and whichever
    REPRO_* knobs were overridden when the numbers were recorded."""
    from repro.core import schedule
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=repo,
            capture_output=True, text=True, timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        commit = "unknown"
    return {
        "commit": commit,
        "pool_workers": schedule.pool_width(),
        "knobs": {k: v for k, v in sorted(os.environ.items())
                  if k.startswith("REPRO_")},
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def write_bench_json(path: str, payload: dict) -> None:
    """Write one BENCH_*.json with the provenance stamp under ``meta``."""
    doc = dict(payload)
    doc["meta"] = bench_meta()
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


class Reporter:
    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us: float, derived: str = ""):
        self.rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    def dump(self) -> str:
        return "\n".join(f"{n},{u:.1f},{d}" for n, u, d in self.rows)
