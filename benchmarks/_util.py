"""Benchmark plumbing: timing + the CSV contract (name,us_per_call,derived)."""
from __future__ import annotations

import time
from typing import Callable

import jax


def _force(result) -> None:
    """Block until a Frame / PartitionedFrame / pytree is computed."""
    from repro.core.frame import Frame
    from repro.core.partition import PartitionedFrame
    if isinstance(result, PartitionedFrame):
        for row in result.parts:
            for blk in row:
                for c in blk.columns:
                    jax.block_until_ready(c.data)
    elif isinstance(result, Frame):
        for c in result.columns:
            jax.block_until_ready(c.data)
    else:
        jax.block_until_ready(result)


def time_us(fn: Callable, *, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        _force(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _force(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


class Reporter:
    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us: float, derived: str = ""):
        self.rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    def dump(self) -> str:
        return "\n".join(f"{n},{u:.1f},{d}" for n, u, d in self.rows)
