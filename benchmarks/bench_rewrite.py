"""Paper §5 rewrite rules: the transpose-elimination pair.

TRANSPOSE∘SORT∘TRANSPOSE (reorder columns) and TRANSPOSE∘SELECTION∘TRANSPOSE
(drop columns) executed literally vs through the rewriter (COLUMN_SORT /
COLUMN_FILTER — "a MAP and RENAME"): the rewrite turns two full O(m·n) data
transposes into one metadata-sized column permutation.
"""
from __future__ import annotations

from repro.core import algebra as alg
from repro.core.partition import PartitionedFrame
from repro.core.physical import run_node
from repro.core.rewrite import optimize
from repro.data.synthetic import numeric_matrix_frame

from ._util import Reporter, time_us


def _exec(pf, node):
    def ev(n):
        if n.op == "source":
            return pf
        return run_node(n, [ev(c) for c in n.children])
    return ev(node)


def run(rep: Reporter, smoke: bool = False) -> None:
    rows, cols = (2_000, 16) if smoke else (50_000, 64)
    frame = numeric_matrix_frame(rows, cols, seed=1)
    pf = PartitionedFrame.from_frame(frame, row_parts=8)
    src = alg.Source("bench", rows, cols)

    tst = alg.Transpose(alg.Sort(alg.Transpose(src), (0,), True))
    opt = optimize(tst)
    assert opt.op == "column_sort"
    t_raw = time_us(lambda: _exec(pf, tst), reps=2)
    t_opt = time_us(lambda: _exec(pf, opt), reps=2)
    rep.add("rewrite/T-SORT-T/literal", t_raw, "")
    rep.add("rewrite/T-SORT-T/column_sort", t_opt, f"speedup={t_raw / t_opt:.1f}x")

    tsel = alg.Transpose(alg.Selection(alg.Transpose(src),
                                       alg.col(0) > alg.lit(0.0)))
    opt2 = optimize(tsel)
    assert opt2.op == "column_filter"
    t_raw2 = time_us(lambda: _exec(pf, tsel), reps=2)
    t_opt2 = time_us(lambda: _exec(pf, opt2), reps=2)
    rep.add("rewrite/T-SEL-T/literal", t_raw2, "")
    rep.add("rewrite/T-SEL-T/column_filter", t_opt2,
            f"speedup={t_raw2 / t_opt2:.1f}x")
