"""Adaptive block scheduling vs per-block pool dispatch (ROADMAP: "Pool
scheduling when partitions ≫ cores").

One fused chain (map→filter→groupby, the `FusedGroupBy` producer-fusion shape
from PR 2) executed three ways on the same frame store, sweeping the row grid
through partitions ∈ {4, 16, 64, 256} on a few-worker pool:

  * ``per_block``   — REPRO_COALESCE=0, REPRO_ADAPT_GRID=0: one pool task per
                      block and the incoming grid kept as-is (the pre-
                      scheduling behavior, the baseline);
  * ``coalesced``   — coalesced dispatch only: several blocks per pool task,
                      grid unchanged;
  * ``adaptive``    — coalesced dispatch + plan-time grid sizing: the partial
                      pass regroups the staged blocks to ≈ workers.

All three produce bit-identical frames (asserted before timing — coalescing
repackages pool tasks without changing per-block processing, and the fused /
unfused plans make the same regroup decision), and the PR-2
``fused_stage_ops`` counter invariant is asserted under coalescing.  A
windowed carry chain rides along as a second shape (seams ≫ workers).
Numbers land in ``BENCH_scheduling.json``.
"""
from __future__ import annotations

import os

# standalone runs mirror benchmarks/run.py: one partition ↔ one core, set
# before jax initializes
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import numpy as np
import jax.numpy as jnp

from repro.core import algebra as alg
from repro.core import schedule
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame
from repro.core.physical import _frames_bit_equal

from ._util import Reporter, time_us, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_scheduling.json")

MODES = {
    "per_block": {"REPRO_COALESCE": "0", "REPRO_ADAPT_GRID": "0"},
    "coalesced": {"REPRO_COALESCE": "1", "REPRO_ADAPT_GRID": "0"},
    "adaptive": {"REPRO_COALESCE": "1", "REPRO_ADAPT_GRID": "1"},
}


class _mode:
    def __init__(self, name: str):
        self.env = MODES[name]
        self.saved: dict = {}

    def __enter__(self):
        for k, v in self.env.items():
            self.saved[k] = os.environ.get(k)
            os.environ[k] = v

    def __exit__(self, *exc):
        for k, old in self.saved.items():
            if old is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = old
        return False


def _mk_frame(n_rows: int, seed: int = 5) -> Frame:
    rng = np.random.default_rng(seed)
    cols = [
        Column(jnp.asarray(rng.integers(0, 8, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.integers(-1000, 1000, n_rows, dtype=np.int32)), Domain.INT),
        Column(jnp.asarray(rng.standard_normal(n_rows).astype(np.float32)), Domain.FLOAT),
    ]
    return Frame(cols, RangeLabels(n_rows), labels_from_values(["k", "v", "x"]))


def _scale() -> alg.Udf:
    def fn(cols, frame):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * 2.0 + 1.0, Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name="sched_bench_scale", fn=fn,
                   deps=frozenset(["x"]), elementwise=True)


def _chains(src: alg.Node) -> dict[str, alg.Node]:
    return {
        "map_filter_groupby": alg.GroupBy(
            alg.Selection(alg.Map(src, _scale()), alg.col("v") > alg.lit(0)),
            ("k",), [("x", "sum", "xs"), ("x", "mean", "xm"),
                     ("v", "count", "vc")]),
        "filter_window_map": alg.Map(
            alg.Window(alg.Selection(src, alg.col("v") % alg.lit(3) > alg.lit(0)),
                       "cumsum", ("x",)), _scale()),
    }


def _bench(rep: Reporter, n_rows: int, row_parts: int, reps: int) -> dict:
    pf = PartitionedFrame.from_frame(_mk_frame(n_rows), row_parts=row_parts)
    store = {"bench": pf}
    src = alg.Source("bench", nrows=pf.nrows, ncols=pf.ncols)
    out: dict = {"rows": n_rows, "row_parts": row_parts,
                 "pool_workers": schedule.pool_width(), "chains": {}}

    for chain, plan in _chains(src).items():
        # correctness gate: the three modes are bit-identical, and the PR-2
        # counter invariant holds under coalescing
        frames, stats = {}, {}
        for mode in MODES:
            with _mode(mode):
                ex = Executor(store, optimize=True)
                frames[mode] = ex.evaluate(plan).to_frame().induce()
                stats[mode] = ex.stats
                pipeline_ops = sum(len(n.params["stages"])
                                   for n in ex._prepared(plan).walk()
                                   if n.op == "fused_pipeline")
                s = ex.stats
                assert s.fused_stage_ops == (pipeline_ops + s.producer_stage_ops
                                             + s.consumer_stage_ops), (chain, mode)
        # coalescing repackages pool tasks without touching block contents:
        # bit-identical.  Grid adaptation regroups the partial/scan blocks, so
        # float reductions legally reassociate: allclose (the adaptive plan is
        # still bit-identical to its *unfused* counterpart, which makes the
        # same regroup decision — asserted in tests/test_scheduling.py).
        assert _frames_bit_equal(frames["per_block"], frames["coalesced"]), chain
        a, b = frames["per_block"].to_pydict(), frames["adaptive"].to_pydict()
        assert list(a) == list(b), chain
        for k in a:
            np.testing.assert_allclose(np.asarray(a[k], dtype=np.float64),
                                       np.asarray(b[k], dtype=np.float64),
                                       rtol=1e-4, atol=1e-3, err_msg=f"{chain}/{k}")

        times: dict[str, float] = {m: float("inf") for m in MODES}
        execs = {}
        for mode in MODES:
            with _mode(mode):
                execs[mode] = Executor(store, optimize=True)

        def run(mode):
            ex = execs[mode]
            ex.cache.clear()
            with _mode(mode):
                return ex.evaluate(plan)

        # interleave A/B/C passes: shields ratios from drift on a shared box
        for _ in range(3):
            for mode in MODES:
                times[mode] = min(times[mode],
                                  time_us(lambda m=mode: run(m), reps=reps))

        entry = {"modes": {}, "dispatch_stats": {}}
        for mode in MODES:
            speedup = times["per_block"] / max(times[mode], 1e-9)
            rep.add(f"scheduling/{chain}/{mode}[{n_rows}x{row_parts}]",
                    times[mode], f"speedup={speedup:.2f}x")
            entry["modes"][mode] = {"us": round(times[mode], 1),
                                    "speedup_vs_per_block": round(speedup, 3)}
            s = stats[mode]
            entry["dispatch_stats"][mode] = {
                "dispatches": s.dispatches,
                "dispatched_blocks": s.dispatched_blocks,
                "blocks_per_dispatch": round(s.blocks_per_dispatch, 2),
            }
        out["chains"][chain] = entry
    return out


def run(rep: Reporter, smoke: bool = False) -> None:
    # Pin a ≤8-worker pool for THIS suite only (the sweep must exercise the
    # partitions ≫ workers regime regardless of the host's core count), and
    # restore the surrounding pool afterwards so sibling suites in
    # benchmarks/run.py keep their configured width.
    saved = os.environ.get("REPRO_POOL_WORKERS")
    os.environ["REPRO_POOL_WORKERS"] = saved or str(min(8, os.cpu_count() or 4))
    schedule.reset_pool()
    try:
        if smoke:
            # sanity only: don't overwrite the recorded full-size numbers
            _bench(rep, 20_000, 16, reps=1)
            return
        results = [_bench(rep, 200_000, p, reps=5) for p in (4, 16, 64, 256)]
        write_bench_json(_JSON_PATH, {
            "benchmark": "adaptive block scheduling vs per-block dispatch",
            "pool_workers": schedule.pool_width(),
            "results": results})
    finally:
        if saved is None:
            os.environ.pop("REPRO_POOL_WORKERS", None)
        else:
            os.environ["REPRO_POOL_WORKERS"] = saved
        schedule.reset_pool()


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, single rep (CI sanity mode)")
    args = ap.parse_args()
    rep = Reporter()
    print("name,us_per_call,derived")
    run(rep, smoke=args.smoke)


if __name__ == "__main__":
    main()
