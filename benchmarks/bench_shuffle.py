"""Shuffle-native JOIN / SORT (grace-hash + sample-sort exchange) vs the
serial seed path.

Two consumer chains over a 100k-row × 16-block frame, each executed three
ways on the same frame store:

  * ``serial_seed`` — ``REPRO_SHUFFLE=0`` + per-node plans + the seed's
    dict-loop join matcher re-instated: the pre-PR-8 behavior (both inputs
    concatenated with ``to_frame()``, single-threaded host matching, full
    payload gather before the filter);
  * ``shuffled``    — per-node plans on the exchange path: per-block key
    frames, hash/range bucketization through the scheduling layer,
    per-bucket local kernels, distributed payload gather;
  * ``fused``       — the exchange path with barrier fusion
    (``FusedJoin`` / ``FusedSort``): the consumer filter prunes match /
    permutation indices BEFORE the payload gather (for SORT the filter even
    precedes the exchange, so dropped rows never leave their source block).

All three produce identical frames (asserted before timing, along with
exact ``ExecStats`` exchange attribution).  A second scenario reruns the
join with inputs 4× ``REPRO_MEM_BUDGET`` — the seed path cannot bound its
residency (it concatenates both inputs); the exchange path must complete
bit-identically with peak resident bytes ≤ budget + one block.  Numbers
land in ``BENCH_shuffle.json``; the headline is fused vs serial_seed on
each chain (target ≥ 1.5×, 2 workers).
"""
from __future__ import annotations

import os
import shutil
import tempfile

# standalone runs mirror benchmarks/run.py: one partition ↔ one core, set
# before jax initializes
os.environ.setdefault(
    "XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import numpy as np

from repro.core import algebra as alg
from repro.core import physical
from repro.core import schedule
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame
from repro.core.store import get_store, reset_store

from ._util import Reporter, time_us, write_bench_json

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_shuffle.json")

MODES = {
    "serial_seed": {"env": {"REPRO_SHUFFLE": "0"}, "optimize": False,
                    "seed_matcher": True},
    "shuffled": {"env": {"REPRO_SHUFFLE": "1"}, "optimize": False,
                 "seed_matcher": False},
    "fused": {"env": {"REPRO_SHUFFLE": "1"}, "optimize": True,
              "seed_matcher": False},
}


def _seed_match_ids(lids: np.ndarray, rids: np.ndarray, how: str):
    """The seed's dict-loop matcher (the pre-PR ``_join_indices`` core),
    re-instated under ``serial_seed`` so the baseline measures the seed
    path rather than this PR's vectorized matcher.  Same contract and same
    emission order as ``physical._match_ids``."""
    groups: dict[int, list[int]] = {}
    for pos, gid in enumerate(rids):
        groups.setdefault(int(gid), []).append(pos)
    lidx_l: list[int] = []
    ridx_l: list[int] = []
    lnull: list[int] = []
    rnull: list[bool] = []
    for i, gid in enumerate(lids):
        match = groups.get(int(gid))
        if match:
            for r in match:          # right order breaks ties
                lidx_l.append(i)
                ridx_l.append(r)
                rnull.append(True)
        elif how in ("left", "outer"):
            lidx_l.append(i)
            ridx_l.append(0)
            rnull.append(False)
    if how in ("right", "outer"):
        lseen = set(np.unique(lids).tolist())
        for r, gid in enumerate(rids):
            if int(gid) not in lseen:
                lidx_l.append(0)
                lnull.append(len(lidx_l) - 1)
                ridx_l.append(r)
                rnull.append(True)
    lidx = np.asarray(lidx_l, dtype=np.int64)
    ridx = np.asarray(ridx_l, dtype=np.int64)
    rvalid = np.asarray(rnull, dtype=bool)
    lvalid = np.ones(len(lidx), dtype=bool)
    lvalid[np.asarray(lnull, dtype=np.int64)] = False
    return lidx, ridx, lvalid, rvalid


class _mode:
    def __init__(self, name: str):
        spec = MODES[name]
        self.env = spec["env"]
        self.patch = spec["seed_matcher"]
        self.saved: dict = {}
        self._orig = None

    def __enter__(self):
        for k, v in self.env.items():
            self.saved[k] = os.environ.get(k)
            os.environ[k] = v
        if self.patch:
            self._orig = physical._match_ids
            physical._match_ids = _seed_match_ids

    def __exit__(self, *exc):
        for k, old in self.saved.items():
            if old is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = old
        if self.patch:
            physical._match_ids = self._orig
        return False


def _join_frames(n_rows: int, seed: int = 3) -> tuple[Frame, Frame]:
    """Left n×3, right (n/4)×2, int keys over a shared range: roughly half
    the left rows find a match, duplicate keys on both sides."""
    rng = np.random.default_rng(seed)
    lf = Frame([Column(np.asarray(rng.integers(0, n_rows // 2, n_rows),
                                  dtype=np.int64), Domain.INT),
                Column(rng.normal(size=n_rows), Domain.FLOAT),
                Column(rng.normal(size=n_rows), Domain.FLOAT)],
               RangeLabels(n_rows), labels_from_values(["k", "a", "a2"]))
    nr = max(n_rows // 4, 1)
    rf = Frame([Column(np.asarray(rng.integers(0, n_rows // 2, nr),
                                  dtype=np.int64), Domain.INT),
                Column(rng.normal(size=nr), Domain.FLOAT)],
               RangeLabels(nr), labels_from_values(["k", "b"]))
    return lf, rf


def _chains(lsrc: alg.Node, rsrc: alg.Node) -> dict[str, alg.Node]:
    # a > 1.0 keeps ~16% of rows: selective enough that index-filtering
    # before the payload gather is a real win, dense enough to be honest
    pred = alg.col("a") > alg.lit(1.0)
    return {
        "filter_join": alg.Selection(
            alg.Join(lsrc, rsrc, on=["k"], how="inner"), pred),
        "filter_sort": alg.Selection(
            alg.Sort(lsrc, ["k", "a"], True), pred),
    }


def _assert_equal(a: Frame, b: Frame, ctx: str) -> None:
    ad, bd = a.to_pydict(), b.to_pydict()
    assert list(ad) == list(bd), ctx
    assert a.row_labels.to_list() == b.row_labels.to_list(), ctx
    for k in ad:
        np.testing.assert_array_equal(np.asarray(ad[k]), np.asarray(bd[k]),
                                      err_msg=f"{ctx}/{k}")


def _bench(rep: Reporter, n_rows: int, row_parts: int, reps: int) -> dict:
    lf, rf = _join_frames(n_rows)
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=row_parts),
             "r": PartitionedFrame.from_frame(rf,
                                              row_parts=max(row_parts // 4, 1))}
    lsrc = alg.Source("l", nrows=store["l"].nrows, ncols=store["l"].ncols)
    rsrc = alg.Source("r", nrows=store["r"].nrows, ncols=store["r"].ncols)

    out: dict = {"rows": n_rows, "row_parts": row_parts,
                 "pool_workers": schedule.pool_width(), "chains": {}}
    for chain, plan in _chains(lsrc, rsrc).items():
        # correctness gate + exchange attribution before timing
        frames, stats = {}, {}
        for mode in MODES:
            with _mode(mode):
                ex = Executor(store, optimize=MODES[mode]["optimize"])
                frames[mode] = ex.evaluate(plan).to_frame()
                stats[mode] = ex.stats
        _assert_equal(frames["serial_seed"], frames["shuffled"], chain)
        _assert_equal(frames["serial_seed"], frames["fused"], chain)
        assert stats["serial_seed"].shuffle_buckets == 0, chain
        assert stats["shuffled"].shuffle_buckets > 0, chain
        assert stats["shuffled"].shuffle_bytes > 0, chain
        assert stats["fused"].shuffle_buckets > 0, chain
        assert stats["fused"].barrier_fused_groups >= 1, f"{chain}: not fused"

        execs = {m: Executor(store, optimize=MODES[m]["optimize"])
                 for m in MODES}

        def run(mode):
            ex = execs[mode]
            ex.cache.clear()      # fresh evaluation; reuse is measured elsewhere
            with _mode(mode):
                return ex.evaluate(plan)

        # interleave MANY short passes and take each mode's MEDIAN pass-best
        # (robust to polluted windows on a shared box)
        samples: dict[str, list[float]] = {m: [] for m in MODES}
        for _ in range(8):
            for mode in MODES:
                samples[mode].append(time_us(lambda m=mode: run(m), reps=reps))
        times = {m: float(np.median(v)) for m, v in samples.items()}

        entry: dict = {"modes": {}}
        for mode in MODES:
            speedup = times["serial_seed"] / max(times[mode], 1e-9)
            rep.add(f"shuffle/{chain}/{mode}[{n_rows}x{row_parts}]",
                    times[mode], f"speedup={speedup:.2f}x")
            s = stats[mode]
            entry["modes"][mode] = {
                "us": round(times[mode], 1),
                "speedup_vs_serial_seed": round(speedup, 3),
                "shuffle_buckets": s.shuffle_buckets,
                "shuffle_bytes": s.shuffle_bytes,
                "skew_splits": s.skew_splits,
                "gather_rows": s.gather_rows,
            }
        out["chains"][chain] = entry
    return out


# =============================================================================
# scenario 2: join over inputs 4× the memory budget
# =============================================================================
def _budget_frames(n_rows: int) -> tuple[Frame, Frame]:
    """Mostly disjoint key ranges: the out-of-core property under test is
    INPUT residency, so a selective join keeps the output small."""
    rng = np.random.default_rng(0)
    lhi = n_rows // 2
    rlo, rhi = int(n_rows * 0.4833), int(n_rows * 0.9833)
    lf = Frame([Column(np.asarray(rng.integers(0, lhi, n_rows),
                                  dtype=np.int64), Domain.INT),
                Column(rng.normal(size=n_rows), Domain.FLOAT),
                Column(rng.normal(size=n_rows), Domain.FLOAT)],
               RangeLabels(n_rows), labels_from_values(["k", "a", "a2"]))
    rf = Frame([Column(np.asarray(rng.integers(rlo, rhi, n_rows),
                                  dtype=np.int64), Domain.INT),
                Column(rng.normal(size=n_rows), Domain.FLOAT)],
               RangeLabels(n_rows), labels_from_values(["k", "b"]))
    return lf, rf


def _budget_report(rep: Reporter, n_rows: int, row_parts: int) -> dict:
    lf, rf = _budget_frames(n_rows)
    plan = alg.Join(alg.Source("l"), alg.Source("r"), on=["k"], how="inner")
    spill_tmp = tempfile.mkdtemp(prefix="repro-bench-shuffle-")
    saved_budget = os.environ.pop("REPRO_MEM_BUDGET", None)
    saved_dir = os.environ.get("REPRO_SPILL_DIR")
    os.environ["REPRO_SPILL_DIR"] = spill_tmp

    def run():
        store = {"l": PartitionedFrame.from_frame(lf, row_parts=row_parts),
                 "r": PartitionedFrame.from_frame(rf, row_parts=row_parts)}
        total = store["l"].nbytes() + store["r"].nbytes()
        ex = Executor(store)
        got = ex.evaluate(plan).to_frame().to_pydict()
        return got, total, ex.stats, store

    try:
        reset_store()
        ref, total, st0, keep0 = run()
        assert st0.spills == 0, "unbudgeted control run spilled"
        budget = total // 4                   # inputs are 4× this budget
        os.environ["REPRO_MEM_BUDGET"] = str(budget)
        reset_store()
        got, _, st, keep = run()
        ss = get_store().stats
        one_block = max(schedule.budget_max_block_bytes(),
                        max((h.nbytes for h in get_store()._handles),
                            default=0))
        # acceptance gates: completes, bit-identical, spilled, peak bounded
        assert got == ref, "4x-budget join diverged from the unbudgeted run"
        assert st.spills > 0 and st.faults > 0, "budget never engaged"
        assert ss.peak_resident_bytes <= budget + one_block, (
            ss.peak_resident_bytes, budget, one_block)
        rep.add(f"shuffle/join_4x_budget[{n_rows}x{row_parts}]",
                0.0, f"completed peak={ss.peak_resident_bytes} "
                     f"budget={budget} spills={st.spills}")
        return {"rows": n_rows, "row_parts": row_parts,
                "device_bytes": total, "budget": budget,
                "completed": True, "bit_identical": True,
                "spills": st.spills, "faults": st.faults,
                "peak_resident_bytes": ss.peak_resident_bytes,
                "peak_bound": budget + one_block,
                "shuffle_buckets": st.shuffle_buckets,
                "shuffle_bytes": st.shuffle_bytes,
                "pool_workers": schedule.pool_width()}
    finally:
        if saved_budget is None:
            os.environ.pop("REPRO_MEM_BUDGET", None)
        else:
            os.environ["REPRO_MEM_BUDGET"] = saved_budget
        if saved_dir is None:
            os.environ.pop("REPRO_SPILL_DIR", None)
        else:
            os.environ["REPRO_SPILL_DIR"] = saved_dir
        reset_store()
        shutil.rmtree(spill_tmp, ignore_errors=True)


def run(rep: Reporter, smoke: bool = False) -> None:
    # Pin the 2-worker pool the acceptance targets are defined at,
    # restoring the surrounding pool afterwards.
    saved = os.environ.get("REPRO_POOL_WORKERS")
    os.environ["REPRO_POOL_WORKERS"] = "2"
    schedule.reset_pool()
    try:
        if smoke:
            # sanity only: don't overwrite the recorded full-size numbers
            _bench(rep, 8_000, 8, reps=1)
            _budget_report(rep, 4_000, 8)
            return
        results = _bench(rep, 100_000, 16, reps=2)
        budget = _budget_report(rep, 40_000, 16)
        write_bench_json(_JSON_PATH, {
            "benchmark":
            "shuffle-native JOIN/SORT (grace-hash + sample-sort "
            "exchange) vs the serial seed path",
            "pool_workers": schedule.pool_width(),
            "results": results, "join_4x_budget": budget})
    finally:
        if saved is None:
            os.environ.pop("REPRO_POOL_WORKERS", None)
        else:
            os.environ["REPRO_POOL_WORKERS"] = saved
        schedule.reset_pool()


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, single rep (CI sanity mode)")
    args = ap.parse_args()
    rep = Reporter()
    print("name,us_per_call,derived")
    run(rep, smoke=args.smoke)


if __name__ == "__main__":
    main()
