"""Quickstart: the paper's Figure-1 workflow, end to end.

An analyst explores iPhone price/rating/feature relationships: ingest →
point-fix a data error (C1) → transpose (C2) → clean a column with map (C3) →
load a second table (C4) → one-hot encode (A1) → join (A2) → covariance (A3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import DataFrame, EvalMode, Session, get_dummies, set_session


def main():
    set_session(Session(mode=EvalMode.OPPORTUNISTIC))

    # In[1] — ingest the scraped comparison chart (products as columns)
    products = DataFrame({
        "iPhone 11 Pro": ["5.8-inch", "12MP", "120MP", "Yes"],
        "iPhone 11 Pro Max": ["6.5-inch", "12MP", "12MP", "Yes"],
        "iPhone XR": ["6.1-inch", "12MP", "7MP", "No"],
        "iPhone 8 Plus": ["5.5-inch", "12MP", "7MP", "No"],
    }, row_labels=["Display", "Camera", "Front Camera", "Wireless Charging"])
    print("Out[1]:", products.head(4).to_pydict())

    # C1 — ordered point update: the 120MP front camera is a data-entry error
    products.iloc[2, 0] = "12MP"
    print("Out[2]: front camera fixed →", products.iloc[2, 0])

    # C2 — matrix-like transpose: products become rows
    products = products.T
    print("Out[3]:", products.head(4).to_pydict())

    # C3 — column transformation via a user-defined map (+ schema induction)
    products["Wireless Charging"] = products["Wireless Charging"].map(
        lambda v: 1 if v == "Yes" else 0)
    print("Out[4]:", products.collect().induce().schema)

    # C4 — read the second dataset (prices & ratings)
    prices = DataFrame({
        "model": ["iPhone 11 Pro", "iPhone 11 Pro Max", "iPhone XR",
                  "iPhone 8 Plus"],
        "price": [999, 1099, 599, 449],
        "rating": [4.5, 4.6, 4.4, 4.3],
    })
    print("Out[5]:", prices.head(4).to_pydict())

    # A1 — one-hot encode categorical features
    one_hot = get_dummies(products.reset_index("model"), ["Display"])
    print("Out[6] cols:", one_hot.columns)

    # A2 — join with prices on the model name
    joined = one_hot.merge(prices, on="model")

    # A3 — covariance across the numeric features (a matrix dataframe)
    numeric = joined[[c for c in joined.columns
                      if c not in ("model", "Camera", "Front Camera")]]
    cov = numeric.cov()
    print("Out[7] covariance matrix:")
    names = cov.col_labels.to_list()
    for name, row in zip(names, cov.to_records()):
        print(f"  {name:22s}", " ".join(f"{v:8.2f}" for v in row))


if __name__ == "__main__":
    main()
