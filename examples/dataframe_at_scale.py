"""Scalability demo (paper §4.3): the Fig.-6 operator mix on a taxi-like
frame, eager single-partition (the pandas stand-in) vs block-partitioned
parallel execution, plus the billions-of-columns transpose trick and
progressive approximate aggregation.

Run:  PYTHONPATH=src python examples/dataframe_at_scale.py [--rows 2000000]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, "src")

from repro.core import DataFrame, EvalMode, Session, set_session
from repro.core.approx import progressive_aggregate
from repro.core.partition import PartitionedFrame
from repro.data.synthetic import numeric_matrix_frame, taxi_like_frame


def timed(label, fn):
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    print(f"  {label:42s} {dt*1e3:9.1f} ms")
    return out, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000)
    args = ap.parse_args()
    cores = os.cpu_count() or 4
    print(f"rows={args.rows:,} cores={cores}")

    frame = taxi_like_frame(args.rows, seed=0)

    print("\n— eager single partition (pandas stand-in) —")
    s1 = set_session(Session(mode=EvalMode.EAGER, default_row_parts=1))
    d1 = DataFrame(frame)
    _, t_map1 = timed("map (fillna)", lambda: d1.fillna(0.0).collect())
    _, t_gb1 = timed("groupby(n) count", lambda: d1.groupby("passenger_count").count().collect())
    s1.close()

    print(f"\n— block-partitioned ({cores} row parts) —")
    s2 = set_session(Session(mode=EvalMode.EAGER, default_row_parts=cores))
    d2 = DataFrame(frame)
    _, t_mapN = timed("map (fillna)", lambda: d2.fillna(0.0).collect())
    _, t_gbN = timed("groupby(n) count", lambda: d2.groupby("passenger_count").count().collect())
    print(f"  speedups: map {t_map1/t_mapN:.2f}x, groupby {t_gb1/t_gbN:.2f}x")

    print("\n— transpose: wide output via grid metadata swap —")
    mat = numeric_matrix_frame(200_000, 32, seed=1)
    dm = DataFrame(mat)
    t, _ = timed("transpose 200k×32 → 32×200k", lambda: dm.T.collect())
    print(f"  result shape: {t.shape} (200k columns)")

    print("\n— progressive approximate aggregation (§6.1.3) —")
    pf = PartitionedFrame.from_frame(frame, row_parts=32)
    t0 = time.perf_counter()
    for est in progressive_aggregate(pf, "f0", "mean"):
        print(f"  {est.fraction*100:5.1f}% rows: mean≈{est.value:+.4f} "
              f"[{est.ci_low:+.4f}, {est.ci_high:+.4f}]"
              + ("  (exact)" if est.final else ""))
        if est.fraction > 0.25 and not est.final:
            break
    print(f"  early estimate in {1e3*(time.perf_counter()-t0):.0f} ms")
    s2.close()


if __name__ == "__main__":
    main()
