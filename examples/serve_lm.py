"""Batched serving example: continuous-batching greedy decoding over a small
model with more requests than slots (slots recycle as requests finish).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_smoke_config
from repro.data.tokenizer import HashTokenizer
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main():
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = HashTokenizer(cfg.vocab)

    engine = ServeEngine(model, params, max_batch=4, max_seq=96)
    prompts = [
        "how do dataframes scale",
        "transpose a billion columns",
        "group by passenger count",
        "opportunistic evaluation hides think time",
        "prefix computation returns the head quickly",
        "reuse caches intermediate results",
    ]
    reqs = [Request(rid=i, prompt_ids=tok.encode(p), max_new_tokens=12)
            for i, p in enumerate(prompts)]

    t0 = time.monotonic()
    for r in reqs:
        engine.submit(r)
    engine.run_until_done()
    dt = time.monotonic() - t0

    for r in reqs:
        print(f"req {r.rid}: {len(r.out_ids)} tokens → {r.out_ids[:8]}…")
    m = engine.metrics
    print(f"steps={m['steps']} prefill_tokens={m['prefill_tokens']} "
          f"tokens_out={m['tokens_out']} wall={dt:.2f}s "
          f"({m['tokens_out']/dt:.1f} tok/s with batch={engine.max_batch})")


if __name__ == "__main__":
    main()
