"""End-to-end training driver: train a ~100M-param llama-style model for a
few hundred steps on a synthetic corpus, fed by the dataframe pipeline
(filter → dedup → tokenize-count → length-sort, evaluated opportunistically
so batch i+1 is prepared during step i), with async checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(Reduce --steps for a quick look; ~100M params on CPU is slow but real.)
"""
import argparse
import dataclasses
import sys
import tempfile
import time

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data import DataPipeline, PipelineConfig, synthetic_corpus
from repro.models import build_model
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # ~100M params: a narrow yi-6b family member (same block structure)
    cfg = dataclasses.replace(
        get_config("yi-6b"), n_layers=6, d_model=512, n_heads=8, n_kv=4,
        d_ff=1536, vocab=8192, train_microbatches=1)
    model = build_model(cfg)
    total, _ = cfg.param_count()
    print(f"model: {cfg.name}-mini, {total/1e6:.1f}M params")

    corpus = synthetic_corpus(20_000, seed=0, mean_len=48)
    pipe = DataPipeline(corpus, cfg.vocab,
                        PipelineConfig(seq_len=args.seq_len,
                                       global_batch=args.batch,
                                       shard_docs=2048))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                         checkpoint_dir=ckpt_dir, checkpoint_every=100,
                         log_every=10)
        trainer = Trainer(model, tc)
        t0 = time.monotonic()
        trainer.fit(pipe.batches(), steps=args.steps)
        wall = time.monotonic() - t0

    first = trainer.history[0]["loss"]
    last = trainer.history[-1]["loss"]
    print(f"steps={args.steps} wall={wall:.1f}s "
          f"loss {first:.3f} → {last:.3f}")
    print("pipeline:", pipe.stats())
    assert last < first, "training should reduce the loss"


if __name__ == "__main__":
    main()
