#!/usr/bin/env bash
# Tier-1 gate: full test suite + fused-pipeline benchmark smoke run.
# Usage: scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# fused-vs-unfused sanity at small size (also refreshes BENCH_fusion.json;
# full-size numbers: python -m benchmarks.run --only fusion)
python -m benchmarks.bench_fusion --smoke
