#!/usr/bin/env bash
# CI gate.  Fast default: test suite minus the @pytest.mark.slow equivalence
# sweeps, plus the benchmark smoke run (every bench suite executes at tiny
# sizes; no JSON/artifact overwrite).
#
#   scripts/check.sh          fast gate (-m "not slow" + bench smoke)
#   scripts/check.sh --full   everything, including the slow sweeps
#                             (same coverage as tier-1: pytest -x -q)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# bytecode must never be tracked (a batch slipped into 9172b4e once).
# No grep -q pipe: under pipefail an early-exit grep can SIGPIPE git ls-files
# and flip the pipeline status exactly when violations exist.
tracked_pyc=$(git ls-files -- '*.pyc' '*.pyo' '*__pycache__*')
if [[ -n "$tracked_pyc" ]]; then
    echo "ERROR: tracked .pyc/__pycache__ files:" >&2
    echo "$tracked_pyc" >&2
    exit 1
fi

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

# out-of-core spill smoke: a tiny pipeline under REPRO_MEM_BUDGET=1 must
# complete (spilling every block), and the store teardown must leave ZERO
# spill files behind.
spill_tmp=$(mktemp -d)
REPRO_MEM_BUDGET=1 REPRO_SPILL_DIR="$spill_tmp" REPRO_POOL_WORKERS=2 \
python - <<'PY'
import os, tempfile
from repro.core import EvalMode, Session, set_session
from repro.core.api import read_csv
from repro.core.store import get_store, reset_store

csv = os.path.join(tempfile.mkdtemp(), "smoke.csv")
with open(csv, "w") as f:
    f.write("k,v,x\n")
    for i in range(2000):
        f.write(f"{i % 5},{i % 37},{(i % 8) * 0.25}\n")
s = set_session(Session(mode=EvalMode.LAZY))
df = read_csv(csv)
df["y"] = df["x"] * 2.0 + 1.0
out = df[df["v"] > 3].groupby("k").agg({"y": "sum"}).drop_duplicates()
got = out.collect().to_pydict()
assert len(got["k"]) == 5, got
assert get_store().stats.spills > 0, "budget=1 never spilled"
s.close()
reset_store()
PY
leaked=$(find "$spill_tmp" -type f | wc -l)
if [[ "$leaked" -ne 0 ]]; then
    echo "ERROR: $leaked leaked spill file(s) under $spill_tmp" >&2
    find "$spill_tmp" -type f >&2
    exit 1
fi
rm -rf "$spill_tmp"

# full-size numbers: python -m benchmarks.run  (writes BENCH_*.json)
python -m benchmarks.run --smoke
