#!/usr/bin/env bash
# CI gate.  Fast default: test suite minus the @pytest.mark.slow equivalence
# sweeps, plus the benchmark smoke run (every bench suite executes at tiny
# sizes; no JSON/artifact overwrite).
#
#   scripts/check.sh          fast gate (-m "not slow" + bench smoke)
#   scripts/check.sh --full   everything, including the slow sweeps
#                             (same coverage as tier-1: pytest -x -q)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# bytecode must never be tracked (a batch slipped into 9172b4e once).
# No grep -q pipe: under pipefail an early-exit grep can SIGPIPE git ls-files
# and flip the pipeline status exactly when violations exist.
tracked_pyc=$(git ls-files -- '*.pyc' '*.pyo' '*__pycache__*')
if [[ -n "$tracked_pyc" ]]; then
    echo "ERROR: tracked .pyc/__pycache__ files:" >&2
    echo "$tracked_pyc" >&2
    exit 1
fi

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

# full-size numbers: python -m benchmarks.run  (writes BENCH_*.json)
python -m benchmarks.run --smoke
