#!/usr/bin/env bash
# CI gate.  Fast default: test suite minus the @pytest.mark.slow equivalence
# sweeps, plus the benchmark smoke run (every bench suite executes at tiny
# sizes; no JSON/artifact overwrite).
#
#   scripts/check.sh          fast gate (-m "not slow" + bench smoke)
#   scripts/check.sh --full   everything, including the slow sweeps
#                             (same coverage as tier-1: pytest -x -q)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

# full-size numbers: python -m benchmarks.run  (writes BENCH_*.json)
python -m benchmarks.run --smoke
