#!/usr/bin/env bash
# CI gate.  Fast default: test suite minus the @pytest.mark.slow equivalence
# sweeps, plus the benchmark smoke run (every bench suite executes at tiny
# sizes; no JSON/artifact overwrite).
#
#   scripts/check.sh          fast gate (-m "not slow" + bench smoke)
#   scripts/check.sh --full   everything, including the slow sweeps
#                             (same coverage as tier-1: pytest -x -q)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# bytecode must never be tracked (a batch slipped into 9172b4e once).
# No grep -q pipe: under pipefail an early-exit grep can SIGPIPE git ls-files
# and flip the pipeline status exactly when violations exist.
tracked_pyc=$(git ls-files -- '*.pyc' '*.pyo' '*__pycache__*')
if [[ -n "$tracked_pyc" ]]; then
    echo "ERROR: tracked .pyc/__pycache__ files:" >&2
    echo "$tracked_pyc" >&2
    exit 1
fi

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

# out-of-core spill smoke: a tiny pipeline under REPRO_MEM_BUDGET=1 must
# complete (spilling every block), and the store teardown must leave ZERO
# spill files behind.
spill_tmp=$(mktemp -d)
REPRO_MEM_BUDGET=1 REPRO_SPILL_DIR="$spill_tmp" REPRO_POOL_WORKERS=2 \
python - <<'PY'
import os, tempfile
from repro.core import EvalMode, Session, set_session
from repro.core.api import read_csv
from repro.core.store import get_store, reset_store

csv = os.path.join(tempfile.mkdtemp(), "smoke.csv")
with open(csv, "w") as f:
    f.write("k,v,x\n")
    for i in range(2000):
        f.write(f"{i % 5},{i % 37},{(i % 8) * 0.25}\n")
s = set_session(Session(mode=EvalMode.LAZY))
df = read_csv(csv)
df["y"] = df["x"] * 2.0 + 1.0
out = df[df["v"] > 3].groupby("k").agg({"y": "sum"}).drop_duplicates()
got = out.collect().to_pydict()
assert len(got["k"]) == 5, got
assert get_store().stats.spills > 0, "budget=1 never spilled"
s.close()
reset_store()
PY
leaked=$(find "$spill_tmp" -type f | wc -l)
if [[ "$leaked" -ne 0 ]]; then
    echo "ERROR: $leaked leaked spill file(s) under $spill_tmp" >&2
    find "$spill_tmp" -type f >&2
    exit 1
fi
rm -rf "$spill_tmp"

# chaos smoke: the same 4×-budget spill pipeline under a seeded fault plan
# (worker exceptions + corrupt spill reads + ENOSPC spill writes) must
# complete bit-identical to the fault-free run with faults actually
# injected; a zero-fault control run must not touch the retry machinery;
# and the teardown must again leave ZERO spill files behind.
chaos_tmp=$(mktemp -d)
REPRO_SPILL_DIR="$chaos_tmp" REPRO_POOL_WORKERS=2 REPRO_RETRY_BACKOFF_MS=1 \
python - <<'PY'
import os, tempfile
from repro.core import EvalMode, Session, set_session, faults
from repro.core.api import read_csv
from repro.core.store import get_store, reset_store

csv = os.path.join(tempfile.mkdtemp(), "chaos.csv")
with open(csv, "w") as f:
    f.write("k,v,x\n")
    for i in range(6000):
        f.write(f"{i % 7},{i % 41},{(i % 12) * 0.25}\n")

def run():
    s = set_session(Session(mode=EvalMode.LAZY))
    df = read_csv(csv)
    df["y"] = df["x"] * 2.0 + 1.0
    out = df[df["v"] > 3].groupby("k").agg({"y": "sum", "x": "mean"}
                                           ).drop_duplicates()
    got = out.collect().to_pydict()
    total = s.frames["frame_0"].nbytes()
    st = s.executor.stats
    s.close()
    return got, total, st

ref, total, st0 = run()                      # fault-free, unbudgeted
assert st0.faults_injected == 0 and st0.retries == 0, (
    "zero-fault control touched the retry machinery")

os.environ["REPRO_MEM_BUDGET"] = str(max(total // 4, 1))
faults.configure(plan="worker:0.2,corrupt:0.5,enospc:0.5", seed=7)
reset_store()
got, _, st = run()
assert got == ref, "chaos run diverged from the fault-free run"
assert st.faults_injected > 0, "the fault plan never fired"
assert get_store().stats.leaked_spill_files == 0
faults.reset()
reset_store()
PY
leaked=$(find "$chaos_tmp" -type f | wc -l)
if [[ "$leaked" -ne 0 ]]; then
    echo "ERROR: $leaked leaked spill file(s) under $chaos_tmp (chaos)" >&2
    find "$chaos_tmp" -type f >&2
    exit 1
fi
rm -rf "$chaos_tmp"

# shuffle smoke: a budgeted shuffled JOIN (grace-hash exchange) must complete
# bit-identical to its unbudgeted run with spills actually engaged, exchange
# attribution recorded, and ZERO spill files left behind.
shuffle_tmp=$(mktemp -d)
REPRO_SPILL_DIR="$shuffle_tmp" REPRO_POOL_WORKERS=2 \
python - <<'PY'
import os
import numpy as np
from repro.core import algebra as alg
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame
from repro.core.store import get_store, reset_store

rng = np.random.default_rng(11)
n = 4000
lf = Frame([Column(np.asarray(rng.integers(0, n // 2, n), dtype=np.int64),
                   Domain.INT),
            Column(rng.normal(size=n), Domain.FLOAT)],
           RangeLabels(n), labels_from_values(["k", "a"]))
rf = Frame([Column(np.asarray(rng.integers(n // 4, 3 * n // 4, n),
                              dtype=np.int64), Domain.INT),
            Column(rng.normal(size=n), Domain.FLOAT)],
           RangeLabels(n), labels_from_values(["k", "b"]))
plan = alg.Join(alg.Source("l"), alg.Source("r"), on=["k"], how="inner")

def run():
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=8),
             "r": PartitionedFrame.from_frame(rf, row_parts=8)}
    total = store["l"].nbytes() + store["r"].nbytes()
    ex = Executor(store)
    got = ex.evaluate(plan).to_frame().to_pydict()
    return got, total, ex.stats

reset_store()
ref, total, st0 = run()
assert st0.shuffle_buckets > 0, "exchange path never engaged"
assert st0.spills == 0, "unbudgeted control run spilled"

os.environ["REPRO_MEM_BUDGET"] = str(max(total // 4, 1))
reset_store()
got, _, st = run()
assert got == ref, "budgeted shuffled join diverged from the unbudgeted run"
assert st.spills > 0, "4x budget never spilled"
assert get_store().stats.leaked_spill_files == 0
reset_store()
PY
leaked=$(find "$shuffle_tmp" -type f | wc -l)
if [[ "$leaked" -ne 0 ]]; then
    echo "ERROR: $leaked leaked spill file(s) under $shuffle_tmp (shuffle)" >&2
    find "$shuffle_tmp" -type f >&2
    exit 1
fi
rm -rf "$shuffle_tmp"

# concurrency smoke: 4 tenant sessions — each with its OWN seeded fault plan
# — run a pipeline concurrently on ONE budgeted QueryService (shared byte
# budget, shared executor).  Every concurrent result must be bit-identical
# to that tenant's serial isolated run, per-session spill attribution must
# sum to the service's global counters, and the shared store's teardown must
# leave ZERO spill files behind.
svc_tmp=$(mktemp -d)
SVC_TMP="$svc_tmp" REPRO_POOL_WORKERS=2 REPRO_RETRY_BACKOFF_MS=1 \
python - <<'PY'
import os, threading
import numpy as np
from repro.core import EvalMode, QueryService, Session
from repro.core.algebra import GroupBy, Map, Selection, Udf, col, lit
from repro.core.dtypes import Domain
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.store import get_store

def table(seed, n=3000):
    rng = np.random.default_rng(seed)
    return Frame(
        [Column(np.asarray(rng.integers(0, 8, n, dtype=np.int32)), Domain.INT),
         Column(np.asarray(rng.standard_normal(n)), Domain.FLOAT)],
        RangeLabels(n), labels_from_values(["k", "x"]))

def plan(src, i):
    def fn(cols, frame, s=1.0 + i):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * s + 1.0, Domain.FLOAT, c.mask, None)
        return out
    udf = Udf(name=f"ci_svc_{i}", fn=fn, deps=frozenset(["x"]),
              elementwise=True)
    return GroupBy(Selection(Map(src, udf), col("k") < lit(6)),
                   ("k",), [("x", "sum", "x"), ("x", "count", "n")])

expected = []                            # serial isolated reference per tenant
for i in range(4):
    s = Session(mode=EvalMode.LAZY)
    src = s.register_frame(table(i), row_parts=4)
    expected.append(s.collect(plan(src, i)).to_pydict())
    s.close()

svc = QueryService(background_workers=2, mem_budget_bytes=8192,
                   spill_dir=os.environ["SVC_TMP"])
sessions = [svc.session(mode=EvalMode.OPPORTUNISTIC, task_retries=2,
                        fault_plan="worker:0.3", fault_seed=i)
            for i in range(4)]
results = [None] * 4
errors = []

def tenant(i):
    try:
        s = sessions[i]
        src = s.register_frame(table(i), row_parts=4)
        node = s.statement(plan(src, i))
        results[i] = s.collect(node).to_pydict()
    except BaseException as e:
        errors.append((i, e))

threads = [threading.Thread(target=tenant, args=(i,)) for i in range(4)]
for t in threads:
    t.start()
for t in threads:
    t.join()
assert not errors, errors
for i in range(4):
    assert results[i] == expected[i], f"tenant {i} diverged under concurrency"
assert svc.stats.spills > 0, "shared budget never spilled"
assert sum(s.stats.spills for s in sessions) == svc.stats.spills, \
    "per-session spill attribution does not sum to the global counter"
assert svc.stats.faults_injected > 0, "per-session fault plans never fired"
assert get_store().stats.spills == 0, "process store was touched"
svc.close()
PY
leaked=$(find "$svc_tmp" -type f | wc -l)
if [[ "$leaked" -ne 0 ]]; then
    echo "ERROR: $leaked leaked spill file(s) under $svc_tmp (service)" >&2
    find "$svc_tmp" -type f >&2
    exit 1
fi
rm -rf "$svc_tmp"

# trace smoke: the chaos spill pipeline again, this time under the statement
# tracer — the traced run must stay bit-identical to the untraced run, the
# exported span tree must validate against the Chrome trace-event schema
# with no span left open, and the teardown must leave ZERO spill files.
trace_tmp=$(mktemp -d)
REPRO_SPILL_DIR="$trace_tmp" REPRO_POOL_WORKERS=2 REPRO_RETRY_BACKOFF_MS=1 \
python - <<'PY'
import json, os, tempfile
import numpy as np
from repro.core import EvalMode, Session, trace
import repro.core.api as api

n = 20_000
data = {"a": np.arange(n, dtype=np.float64),
        "b": (np.arange(n) % 53).astype(np.float64)}

def run(traced):
    s = Session(mode=EvalMode.LAZY, trace=traced,
                mem_budget_bytes=n * 8 // 2,
                fault_plan="worker:0.2,corrupt:0.5,enospc:0.5", fault_seed=7)
    try:
        df = api.from_pydict(data, session=s)
        q = df[df["a"] > 100.0].groupby("b").agg({"a": ["sum", "mean"]})
        got = q.collect().to_pydict()
        tr = s.tracer
        if traced:
            assert tr is not None and tr.open_spans() == 0, "leaked open spans"
            path = s.trace_json(os.path.join(tempfile.mkdtemp(), "t.json"))
            doc = json.load(open(path))
            n_ev = trace.validate_chrome_trace(doc)
            assert n_ev > 0, "traced chaos run exported an empty span tree"
            os.remove(path)
        else:
            assert tr is None, "tracing leaked into the untraced run"
        return got
    finally:
        s.close()

ref = run(traced=False)
got = run(traced=True)
assert got == ref, "traced chaos run diverged from the untraced run"
PY
leaked=$(find "$trace_tmp" -type f | wc -l)
if [[ "$leaked" -ne 0 ]]; then
    echo "ERROR: $leaked leaked spill file(s) under $trace_tmp (trace)" >&2
    find "$trace_tmp" -type f >&2
    exit 1
fi
rm -rf "$trace_tmp"

# full-size numbers: python -m benchmarks.run  (writes BENCH_*.json)
python -m benchmarks.run --smoke
