#!/usr/bin/env bash
# Tier-1 gate: full test suite + benchmark smoke run (every bench suite
# executes at tiny sizes; no JSON/artifact overwrite).
# Usage: scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# full-size numbers: python -m benchmarks.run  (writes BENCH_*.json)
python -m benchmarks.run --smoke
