"""Approximate / progressive execution (paper §6.1.3)."""
import numpy as np

from repro.core.approx import first_k_groups, progressive_aggregate
from repro.core.frame import Frame
from repro.core.partition import PartitionedFrame


def _pf(n=10_000, parts=8, seed=0):
    rng = np.random.default_rng(seed)
    f = Frame.from_pydict({
        "v": rng.standard_normal(n).tolist(),
        "k": rng.choice(["a", "b", "c", "d"], n).tolist(),
    })
    return PartitionedFrame.from_frame(f, row_parts=parts), f


def test_progressive_mean_converges_with_shrinking_ci():
    pf, f = _pf()
    ests = list(progressive_aggregate(pf, "v", "mean"))
    assert len(ests) == pf.row_parts
    widths = [e.ci_high - e.ci_low for e in ests[:-1]]
    assert widths[0] >= widths[-1]            # CI shrinks as rows accumulate
    exact = float(np.mean(np.asarray(f.col("v").data)))
    assert abs(ests[-1].value - exact) < 1e-5
    assert ests[-1].final


def test_progressive_sum_final_exact():
    pf, f = _pf(seed=3)
    *_, last = progressive_aggregate(pf, "v", "sum")
    exact = float(np.sum(np.asarray(f.col("v").data)))
    np.testing.assert_allclose(last.value, exact, rtol=1e-4, atol=1e-3)


def test_progressive_estimates_cover_truth():
    pf, f = _pf(seed=7)
    exact = float(np.mean(np.asarray(f.col("v").data)))
    ests = list(progressive_aggregate(pf, "v", "mean"))
    covered = sum(1 for e in ests if e.ci_low <= exact <= e.ci_high)
    # 95% CIs on correlated prefixes: expect most, not all, to cover
    assert covered >= pf.row_parts - 3
    assert abs(ests[-1].value - exact) < 1e-4            # final is exact


def test_first_k_groups_input_order():
    f = Frame.from_pydict({"k": ["x", "y", "x", "z", "w"]})
    pf = PartitionedFrame.from_frame(f, row_parts=2)
    assert first_k_groups(pf, "k", 3) == ["x", "y", "z"]
