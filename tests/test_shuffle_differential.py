"""Differential suite for the shuffle/exchange layer (PR 8) — the gate for
grace-hash JOIN and sample-sort SORT (``core/shuffle.py``).

Properties asserted:

  * **serial bit-identity** — shuffled results (values AND row labels) are
    identical to the ``REPRO_SHUFFLE=0`` whole-frame oracle, across partition
    grids {1, W, 4W} × fused/unfused plans, for how ∈ {inner, left, right,
    outer, cross}, null keys, 2^53 wide-int keys, duplicate-key tie order,
    and ascending/descending sorts with NaN placement;
  * **pandas oracle** — inner/left joins and sorts are order- and
    index-identical to pandas; right/outer joins (where pandas applies its
    own ordering) match as row multisets;
  * **no whole-frame concat** — the spy from ``test_dedup_differential``
    extended to JOIN/SORT: ``PartitionedFrame.to_frame`` is never called on
    an input (the ISSUE 8 acceptance criterion itself);
  * **exact exchange attribution** — ``ExecStats.shuffle_buckets`` counts
    2·B (join) / B (sort) / 0 (cross), ``shuffle_bytes`` is exactly
    ``rows × (n_keys + 1) × 8``, and ``skew_splits`` fires on a hot key;
  * **out-of-core** — a join over inputs 4× ``REPRO_MEM_BUDGET`` completes
    bit-identical with peak residency ≤ budget + one block;
  * **chaos** — a seeded corrupt/missing-spill plan during the exchange
    recomputes bit-identically through bucket/chunk lineage.
"""
from __future__ import annotations

import math
import os

import numpy as np
import pytest

from repro.core import algebra as alg
from repro.core import faults, schedule, shuffle
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame
from repro.core.store import get_store, reset_store

try:
    import pandas as pd
    HAVE_PANDAS = True
except ImportError:
    HAVE_PANDAS = False

HOWS = ("inner", "left", "right", "outer")


@pytest.fixture(autouse=True)
def _shuffle_env(monkeypatch):
    for knob in ("REPRO_SHUFFLE", "REPRO_SHUFFLE_BUCKETS",
                 "REPRO_SHUFFLE_SKEW_FACTOR"):
        monkeypatch.delenv(knob, raising=False)
    shuffle.configure(clear=True)
    yield monkeypatch
    shuffle.configure(clear=True)


# =============================================================================
# helpers
# =============================================================================
def _grids() -> tuple[int, ...]:
    w = schedule.pool_width()
    return (1, w, 4 * w)


def _canon(v):
    """NaN-safe scalar for list equality (NaN != NaN would make bit-identical
    float results compare unequal)."""
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    return v


def _frame_lists(f: Frame) -> tuple[list, dict]:
    return (f.row_labels.to_list(),
            {k: [_canon(v) for v in vals] for k, vals in f.to_pydict().items()})


def _eval(plan, store, optimize=True) -> tuple[list, dict]:
    return _frame_lists(Executor(store, optimize=optimize)
                        .evaluate(plan()).to_frame())


def _sweep_vs_serial(plan, frames: dict[str, Frame], ctx: str,
                     monkeypatch) -> tuple[list, dict]:
    """Shuffled result across grids {1, W, 4W} × fused/unfused must be
    bit-identical (values and labels) to the serial whole-frame oracle.
    Returns the oracle for further (pandas) comparison."""
    monkeypatch.setenv("REPRO_SHUFFLE", "0")
    try:
        store = {fid: PartitionedFrame.from_frame(f, row_parts=2)
                 for fid, f in frames.items()}
        ref = _eval(plan, store, optimize=False)
    finally:
        monkeypatch.delenv("REPRO_SHUFFLE")
    for rp in _grids():
        store = {fid: PartitionedFrame.from_frame(f, row_parts=rp)
                 for fid, f in frames.items()}
        for optimize in (True, False):
            got = _eval(plan, store, optimize=optimize)
            assert got == ref, f"{ctx}[grid={rp},opt={optimize}]"
    return ref


def _gen_join_case(seed: int, *, nulls: bool, nl=None, nr=None):
    rng = np.random.default_rng(seed)
    nl = int(rng.integers(1, 60)) if nl is None else nl
    nr = int(rng.integers(0, 60)) or 1 if nr is None else nr
    pool = int(rng.choice([3, 8, 40]))

    def keys(n):
        ks = rng.integers(0, pool, n).tolist()
        if nulls:
            mask = rng.random(n) < 0.25
            ks = [None if m else k for k, m in zip(ks, mask)]
        return ks

    ldata = {"k": keys(nl), "a": (rng.integers(0, 100, nl) * 0.25).tolist()}
    rdata = {"k": keys(nr), "b": (rng.integers(0, 100, nr) * 0.5).tolist()}
    return Frame.from_pydict(ldata), Frame.from_pydict(rdata), ldata, rdata


def _join_plan(how, on=("k",), left_on=None, right_on=None):
    return lambda: alg.Join(alg.Source("l"), alg.Source("r"),
                            on=list(on) if on else None, how=how,
                            left_on=left_on, right_on=right_on)


# =============================================================================
# serial bit-identity: join
# =============================================================================
@pytest.mark.parametrize("how", HOWS)
@pytest.mark.parametrize("seed", (0, 1))
def test_join_matches_serial_oracle(how, seed, monkeypatch):
    lf, rf, *_ = _gen_join_case(seed, nulls=False)
    _sweep_vs_serial(_join_plan(how), {"l": lf, "r": rf},
                     f"join[{how},seed={seed}]", monkeypatch)


@pytest.mark.parametrize("how", HOWS)
def test_join_null_keys_match_serial(how, monkeypatch):
    lf, rf, *_ = _gen_join_case(7, nulls=True)
    _sweep_vs_serial(_join_plan(how), {"l": lf, "r": rf},
                     f"join-null[{how}]", monkeypatch)


def test_cross_join_matches_serial(monkeypatch):
    lf, rf, *_ = _gen_join_case(3, nulls=False, nl=17, nr=9)
    ref = _sweep_vs_serial(_join_plan("inner", on=None),
                           {"l": lf, "r": rf}, "cross", monkeypatch)
    assert len(ref[0]) == 17 * 9


def test_join_left_on_right_on_matches_serial(monkeypatch):
    """left_on/right_on keeps BOTH key columns (drop_right is empty)."""
    lf, rf, *_ = _gen_join_case(11, nulls=False)
    rf = Frame(rf.columns, rf.row_labels, labels_from_values(["k2", "b"]))
    plan = _join_plan("inner", on=None, left_on=["k"], right_on=["k2"])
    ref = _sweep_vs_serial(plan, {"l": lf, "r": rf}, "left_on", monkeypatch)
    assert list(ref[1]) == ["k", "a", "k2", "b"]


def test_join_wide_int_keys_2p53(monkeypatch):
    """Keys past 2^53 lose float64 round-trip exactness — the wide-int hash
    path must keep distinct 2^53+1 vs 2^53+2 keys distinct, shuffled and
    serial alike."""
    base = 1 << 53
    lk = [base + 1, base + 2, base + 3, base + 1, 5]
    rk = [base + 2, base + 1, base + 4, 5]
    lf = Frame([Column(np.asarray(lk, dtype=np.int64), Domain.INT),
                Column(np.arange(5.0), Domain.FLOAT)],
               RangeLabels(5), labels_from_values(["k", "a"]))
    rf = Frame([Column(np.asarray(rk, dtype=np.int64), Domain.INT),
                Column(np.arange(4.0), Domain.FLOAT)],
               RangeLabels(4), labels_from_values(["k", "b"]))
    for how in HOWS:
        ref = _sweep_vs_serial(_join_plan(how), {"l": lf, "r": rf},
                               f"wide[{how}]", monkeypatch)
        if how == "inner":
            # two left base+1 rows each match one right row, plus base+2
            # and 5: exactly 4 matches — base+3 / base+4 stay distinct
            assert len(ref[0]) == 4


def test_join_duplicate_key_tie_order(monkeypatch):
    """All-duplicate keys: the left-major / right-tie emission order must
    survive the exchange bit-identically."""
    lf = Frame.from_pydict({"k": [1, 1, 1, 1], "a": [0.0, 1.0, 2.0, 3.0]})
    rf = Frame.from_pydict({"k": [1, 1, 1], "b": [10.0, 20.0, 30.0]})
    ref = _sweep_vs_serial(_join_plan("inner"), {"l": lf, "r": rf},
                           "ties", monkeypatch)
    assert ref[1]["a"] == [0.0] * 3 + [1.0] * 3 + [2.0] * 3 + [3.0] * 3
    assert ref[1]["b"] == [10.0, 20.0, 30.0] * 4


# =============================================================================
# pandas oracle: join
# =============================================================================
@pytest.mark.skipif(not HAVE_PANDAS, reason="pandas not installed")
@pytest.mark.parametrize("how", HOWS)
@pytest.mark.parametrize("nulls", (False, True))
def test_join_matches_pandas(how, nulls, monkeypatch):
    lf, rf, ldata, rdata = _gen_join_case(5, nulls=nulls)
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=4),
             "r": PartitionedFrame.from_frame(rf, row_parts=3)}
    labels, got = _eval(_join_plan(how), store)

    lp = pd.DataFrame({k: pd.Series(v, dtype=float)
                       for k, v in ldata.items()})
    rp = pd.DataFrame({k: pd.Series(v, dtype=float)
                       for k, v in rdata.items()})
    exp = pd.merge(lp, rp, on="k", how=how)
    cols = {c: [None if (isinstance(v, float) and math.isnan(v)) else v
                for v in exp[c]] for c in exp.columns}

    assert list(got) == list(cols)
    def rows(d):
        names = list(d)
        return sorted(zip(*[[(x is None, x if x is not None else 0.0)
                             for x in d[n]] for n in names]))
    if how in ("inner", "left"):
        # pandas preserves left-major order here; ours must match exactly
        assert got == {k: [_canon(x) for x in v] for k, v in cols.items()}
        assert labels == list(range(len(exp)))
    else:
        # right/outer: pandas applies its own ordering — compare multisets
        assert rows(got) == rows(cols)


@pytest.mark.skipif(not HAVE_PANDAS, reason="pandas not installed")
def test_cross_join_matches_pandas():
    lf = Frame.from_pydict({"a": [1.0, 2.0, 3.0]})
    rf = Frame.from_pydict({"b": [10.0, 20.0]})
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=2),
             "r": PartitionedFrame.from_frame(rf, row_parts=1)}
    _, got = _eval(_join_plan("inner", on=None), store)
    exp = pd.merge(pd.DataFrame({"a": [1.0, 2.0, 3.0]}),
                   pd.DataFrame({"b": [10.0, 20.0]}), how="cross")
    assert got == {c: list(exp[c]) for c in exp.columns}


# =============================================================================
# serial bit-identity + pandas oracle: sort
# =============================================================================
def _gen_sort_case(seed: int, *, nulls: bool, n=50):
    rng = np.random.default_rng(seed)
    ks = rng.integers(0, 8, n).tolist()
    if nulls:
        mask = rng.random(n) < 0.25
        ks = [None if m else k for k, m in zip(ks, mask)]
    data = {"k": ks, "x": (rng.integers(0, 6, n) * 0.5).tolist(),
            "p": list(range(n))}
    return Frame.from_pydict(data), data


@pytest.mark.parametrize("ascending", (True, False))
@pytest.mark.parametrize("nulls", (False, True))
def test_sort_matches_serial_oracle(ascending, nulls, monkeypatch):
    f, _ = _gen_sort_case(2, nulls=nulls)
    plan = lambda: alg.Sort(alg.Source("s"), ["k", "x"], ascending)
    _sweep_vs_serial(plan, {"s": f}, f"sort[asc={ascending},nulls={nulls}]",
                     monkeypatch)


@pytest.mark.skipif(not HAVE_PANDAS, reason="pandas not installed")
@pytest.mark.parametrize("ascending", (True, False))
@pytest.mark.parametrize("nulls", (False, True))
def test_sort_matches_pandas(ascending, nulls):
    f, data = _gen_sort_case(4, nulls=nulls)
    store = {"s": PartitionedFrame.from_frame(f, row_parts=4)}
    plan = lambda: alg.Sort(alg.Source("s"), ["k", "x"], ascending)
    labels, got = _eval(plan, store)

    pdf = pd.DataFrame({"k": pd.Series(data["k"], dtype=float),
                        "x": pd.Series(data["x"], dtype=float),
                        "p": pd.Series(data["p"], dtype=float)})
    exp = pdf.sort_values(["k", "x"], ascending=ascending, kind="stable",
                          na_position="last")
    assert labels == list(exp.index)           # stable ties, NaN placement
    kexp = [None if math.isnan(v) else v for v in exp["k"]]
    assert got["k"] == kexp
    assert got["p"] == list(exp["p"])


def test_sort_all_equal_keys_is_stable(monkeypatch):
    f = Frame.from_pydict({"k": [7] * 40, "p": list(range(40))})
    plan = lambda: alg.Sort(alg.Source("s"), ["k"], True)
    ref = _sweep_vs_serial(plan, {"s": f}, "sort-tied", monkeypatch)
    assert ref[1]["p"] == list(range(40))


# =============================================================================
# fused variants (consumer chains through the exchange)
# =============================================================================
def test_fused_join_filter_project_matches_serial(monkeypatch):
    lf, rf, *_ = _gen_join_case(9, nulls=False, nl=40, nr=40)
    def plan():
        j = alg.Join(alg.Source("l"), alg.Source("r"), on=["k"], how="left")
        s = alg.Selection(j, alg.col("a") > alg.lit(5.0))
        return alg.Projection(s, ["k", "a"])
    _sweep_vs_serial(plan, {"l": lf, "r": rf}, "fused-join", monkeypatch)


def test_fused_join_right_side_predicate_matches_serial(monkeypatch):
    lf, rf, *_ = _gen_join_case(13, nulls=True, nl=35, nr=30)
    def plan():
        j = alg.Join(alg.Source("l"), alg.Source("r"), on=["k"], how="outer")
        return alg.Selection(j, alg.col("b") < alg.lit(30.0))
    _sweep_vs_serial(plan, {"l": lf, "r": rf}, "fused-join-right", monkeypatch)


def test_fused_sort_filter_project_matches_serial(monkeypatch):
    f, _ = _gen_sort_case(6, nulls=True)
    def plan():
        s = alg.Sort(alg.Source("s"), ["k", "x"], False)
        sel = alg.Selection(s, alg.col("x") > alg.lit(0.5))
        return alg.Projection(sel, ["k", "p"])
    _sweep_vs_serial(plan, {"s": f}, "fused-sort", monkeypatch)


# =============================================================================
# satellite 2: the no-whole-frame-concat spy
# =============================================================================
def test_no_to_frame_on_join_sort_inputs(monkeypatch):
    """The acceptance criterion itself: shuffled JOIN and SORT never
    concatenate an input (``PartitionedFrame.to_frame`` is never called
    during evaluation)."""
    lf, rf, *_ = _gen_join_case(8, nulls=True, nl=45, nr=35)
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=4),
             "r": PartitionedFrame.from_frame(rf, row_parts=3)}
    calls = []
    orig = PartitionedFrame.to_frame

    def spy(self):
        calls.append(1)
        return orig(self)

    monkeypatch.setattr(PartitionedFrame, "to_frame", spy)
    for how in HOWS:
        Executor(store).evaluate(alg.Join(alg.Source("l"), alg.Source("r"),
                                          on=["k"], how=how))
    Executor(store).evaluate(alg.Join(alg.Source("l"), alg.Source("r"),
                                      on=None, how="inner"))        # cross
    Executor(store).evaluate(alg.Sort(alg.Source("l"), ["k", "a"], True))
    Executor(store).evaluate(alg.Sort(alg.Source("l"), ["a"], False))
    # fused variants too
    Executor(store, optimize=True).evaluate(
        alg.Selection(alg.Join(alg.Source("l"), alg.Source("r"),
                               on=["k"], how="inner"),
                      alg.col("a") > alg.lit(1.0)))
    Executor(store, optimize=True).evaluate(
        alg.Selection(alg.Sort(alg.Source("l"), ["k"], True),
                      alg.col("a") > alg.lit(1.0)))
    assert not calls


# =============================================================================
# exact exchange attribution
# =============================================================================
def test_join_shuffle_stats_exact(monkeypatch):
    monkeypatch.setenv("REPRO_SHUFFLE_BUCKETS", "3")
    nl, nr = 40, 25
    lf, rf, *_ = _gen_join_case(1, nulls=False, nl=nl, nr=nr)
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=4),
             "r": PartitionedFrame.from_frame(rf, row_parts=3)}
    ex = Executor(store)
    ex.evaluate(alg.Join(alg.Source("l"), alg.Source("r"), on=["k"],
                         how="inner"))
    # 2·B bucket frames; every input row in exactly one bucket; one float64
    # key column + the int64 position column = (K+1)·8 bytes per row
    assert ex.stats.shuffle_buckets == 2 * 3
    assert ex.stats.shuffle_bytes == (nl + nr) * 2 * 8
    assert ex.stats.skew_splits == 0 or ex.stats.skew_splits > 0  # counted


def test_sort_shuffle_stats_exact(monkeypatch):
    monkeypatch.setenv("REPRO_SHUFFLE_BUCKETS", "3")
    n = 48
    rng = np.random.default_rng(0)
    f = Frame.from_pydict({"k": rng.normal(size=n).tolist(),
                           "p": list(range(n))})
    store = {"s": PartitionedFrame.from_frame(f, row_parts=4)}
    ex = Executor(store)
    ex.evaluate(alg.Sort(alg.Source("s"), ["k"], True))
    # continuous keys ⇒ distinct splitters ⇒ exactly B range buckets
    assert ex.stats.shuffle_buckets == 3
    assert ex.stats.shuffle_bytes == n * 2 * 8
    assert ex.stats.skew_splits == 0


def test_cross_join_needs_no_exchange():
    lf = Frame.from_pydict({"a": [1.0, 2.0, 3.0]})
    rf = Frame.from_pydict({"b": [1.0, 2.0]})
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=2),
             "r": PartitionedFrame.from_frame(rf, row_parts=1)}
    ex = Executor(store)
    ex.evaluate(alg.Join(alg.Source("l"), alg.Source("r"), on=None,
                         how="inner"))
    assert ex.stats.shuffle_buckets == 0
    assert ex.stats.shuffle_bytes == 0


def test_serial_oracle_has_no_shuffle_stats(monkeypatch):
    monkeypatch.setenv("REPRO_SHUFFLE", "0")
    lf, rf, *_ = _gen_join_case(1, nulls=False)
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=4),
             "r": PartitionedFrame.from_frame(rf, row_parts=3)}
    ex = Executor(store)
    ex.evaluate(alg.Join(alg.Source("l"), alg.Source("r"), on=["k"],
                         how="inner"))
    assert ex.stats.shuffle_buckets == 0
    assert ex.stats.shuffle_bytes == 0


# =============================================================================
# skew handling
# =============================================================================
def test_join_skew_split_on_hot_key(monkeypatch):
    """One dominant key: the hash bucket holding it splits into part-tasks
    (skew_splits > 0) and the result stays bit-identical to serial."""
    monkeypatch.setenv("REPRO_SHUFFLE_BUCKETS", "8")
    rng = np.random.default_rng(0)
    n = 400
    lf = Frame.from_pydict({"k": [1] * (n - 10) + rng.integers(2, 50, 10).tolist(),
                            "a": (rng.integers(0, 9, n) * 0.5).tolist()})
    rf = Frame.from_pydict({"k": [1] * (n - 10) + rng.integers(2, 50, 10).tolist(),
                            "b": (rng.integers(0, 9, n) * 0.25).tolist()})
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=4),
             "r": PartitionedFrame.from_frame(rf, row_parts=4)}
    plan = lambda: alg.Join(alg.Source("l"), alg.Source("r"), on=["k"],
                            how="outer")
    ex = Executor(store)
    got = _frame_lists(ex.evaluate(plan()).to_frame())
    assert ex.stats.skew_splits > 0
    monkeypatch.setenv("REPRO_SHUFFLE", "0")
    ref = _eval(plan, store)
    assert got == ref


def test_sort_skew_split_on_hot_value(monkeypatch):
    """One dominant primary value: the range bucket holding it refines on
    the next key column (skew_splits > 0), result bit-identical."""
    monkeypatch.setenv("REPRO_SHUFFLE_BUCKETS", "8")
    rng = np.random.default_rng(1)
    n = 400
    f = Frame.from_pydict({"k": [3] * (n - 8) + list(range(8)),
                           "x": rng.normal(size=n).tolist(),
                           "p": list(range(n))})
    store = {"s": PartitionedFrame.from_frame(f, row_parts=4)}
    plan = lambda: alg.Sort(alg.Source("s"), ["k", "x"], True)
    ex = Executor(store)
    got = _frame_lists(ex.evaluate(plan()).to_frame())
    assert ex.stats.skew_splits > 0
    monkeypatch.setenv("REPRO_SHUFFLE", "0")
    ref = _eval(plan, store)
    assert got == ref


# =============================================================================
# out-of-core: 4×-budget join; chaos during the exchange
# =============================================================================
def _big_join_frames(n=6000, selective=True):
    """Inputs sized to dominate the budget; ``selective`` keeps the key
    ranges mostly disjoint so the *output* stays small — the out-of-core
    property under test is input residency, not output size."""
    rng = np.random.default_rng(0)
    lhi, rlo, rhi = (3000, 2900, 5900) if selective else (500, 0, 500)
    lf = Frame([Column(np.asarray(rng.integers(0, lhi, n), dtype=np.int64),
                       Domain.INT),
                Column(rng.normal(size=n), Domain.FLOAT),
                Column(rng.normal(size=n), Domain.FLOAT)],
               RangeLabels(n), labels_from_values(["k", "a", "a2"]))
    rf = Frame([Column(np.asarray(rng.integers(rlo, rhi, n), dtype=np.int64),
                       Domain.INT),
                Column(rng.normal(size=n), Domain.FLOAT)],
               RangeLabels(n), labels_from_values(["k", "b"]))
    return lf, rf


@pytest.mark.spill
def test_join_4x_budget_completes_within_bound(monkeypatch, tmp_path):
    """A join whose inputs are 4× the memory budget completes bit-identical
    to the unbudgeted run with peak residency ≤ budget + one block."""
    monkeypatch.setenv("REPRO_POOL_WORKERS", "2")
    monkeypatch.setenv("REPRO_SPILL_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_MEM_BUDGET", raising=False)
    schedule.reset_pool()
    lf, rf = _big_join_frames()
    plan = lambda: alg.Join(alg.Source("l"), alg.Source("r"), on=["k"],
                            how="inner")

    def run():
        store = {"l": PartitionedFrame.from_frame(lf, row_parts=8),
                 "r": PartitionedFrame.from_frame(rf, row_parts=8)}
        total = store["l"].nbytes() + store["r"].nbytes()
        ex = Executor(store)
        got = _frame_lists(ex.evaluate(plan()).to_frame())
        return got, total, ex.stats, store

    try:
        reset_store()
        ref, total, st0, _keep0 = run()
        assert st0.spills == 0 and st0.peak_resident_bytes == 0

        budget = total // 4                  # inputs are 4× the budget
        monkeypatch.setenv("REPRO_MEM_BUDGET", str(budget))
        reset_store()
        got, _, st, _keep = run()
        assert got == ref                    # bit-identical
        assert st.spills > 0 and st.faults > 0
        store_stats = get_store().stats
        one_block = schedule.budget_max_block_bytes()
        biggest = max((h.nbytes for h in get_store()._handles), default=0)
        assert store_stats.peak_resident_bytes <= budget + max(one_block,
                                                               biggest)
    finally:
        reset_store()
        schedule.reset_pool()


@pytest.mark.spill
@pytest.mark.parametrize("kind", ("corrupt", "missing"))
def test_chaos_spill_fault_during_exchange_recomputes(kind, monkeypatch,
                                                      tmp_path):
    """Seeded corrupt/missing spill files during a budgeted shuffled join
    must recompute through bucket/chunk lineage bit-identically."""
    monkeypatch.setenv("REPRO_POOL_WORKERS", "2")
    monkeypatch.setenv("REPRO_SPILL_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_RETRY_BACKOFF_MS", "1")
    monkeypatch.delenv("REPRO_MEM_BUDGET", raising=False)
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    faults.reset()
    schedule.reset_pool()
    lf, rf = _big_join_frames(4000, selective=False)
    plan = lambda: alg.Join(alg.Source("l"), alg.Source("r"), on=["k"],
                            how="left")

    def run():
        store = {"l": PartitionedFrame.from_frame(lf, row_parts=8),
                 "r": PartitionedFrame.from_frame(rf, row_parts=8)}
        total = store["l"].nbytes() + store["r"].nbytes()
        ex = Executor(store)
        got = _frame_lists(ex.evaluate(plan()).to_frame())
        return got, total, ex.stats

    try:
        reset_store()
        ref, total, _ = run()

        monkeypatch.setenv("REPRO_MEM_BUDGET", str(total // 4))
        monkeypatch.setenv("REPRO_FAULT_PLAN", f"{kind}:0.4")
        monkeypatch.setenv("REPRO_FAULT_SEED", "7")
        faults.reset()
        reset_store()
        got, _, st = run()
        assert got == ref                    # recovered bit-identical
        assert faults.injected_total() > 0   # the chaos actually fired
        assert st.recomputed_blocks > 0      # ...and lineage recovered it
    finally:
        monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
        faults.reset()
        reset_store()
        schedule.reset_pool()
