"""THE distribution invariant (paper §4.2): executing any algebra plan on a
block-partitioned frame must equal executing it on a single partition.
Property-based via hypothesis: random frames × random operator pipelines ×
random grid shapes."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import algebra as alg
from repro.core.frame import Frame
from repro.core.partition import PartitionedFrame
from repro.core.physical import run_node


def _mk_frame(keys, vals, floats):
    return Frame.from_pydict({
        "k": keys,
        "v": vals,
        "f": floats,
    })


def _run(frame: Frame, row_parts: int, build):
    pf = PartitionedFrame.from_frame(frame, row_parts=row_parts)
    src = alg.Source("f0", nrows=frame.nrows, ncols=frame.ncols)

    class _Exec:
        def __init__(self, pf):
            self.pf = pf

        def eval(self, node):
            if node.op == "source":
                return self.pf
            return run_node(node, [self.eval(c) for c in node.children])

    return _Exec(pf).eval(build(src)).to_frame().to_pydict()


keys_st = st.lists(st.sampled_from(["a", "b", "c", "d"]), min_size=1, max_size=40)


@settings(max_examples=25, deadline=None)
@given(keys=keys_st, parts=st.integers(1, 5), data=st.data())
def test_groupby_partition_invariant(keys, parts, data):
    n = len(keys)
    vals = data.draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
    floats = data.draw(st.lists(st.floats(-10, 10, allow_nan=False, width=32),
                                min_size=n, max_size=n))
    f = _mk_frame(keys, vals, floats)

    def build(src):
        return alg.GroupBy(src, ("k",), [("v", "sum", "vs"), ("v", "count", "vc"),
                                         ("f", "max", "fm")])

    a = _run(f, 1, build)
    b = _run(f, parts, build)
    assert a["k"] == b["k"]
    np.testing.assert_allclose(a["vs"], b["vs"], rtol=1e-5, atol=1e-5)
    assert a["vc"] == b["vc"]
    np.testing.assert_allclose(a["fm"], b["fm"], rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(keys=keys_st, parts=st.integers(1, 5), thresh=st.integers(-40, 40),
       data=st.data())
def test_selection_map_window_pipeline_invariant(keys, parts, thresh, data):
    n = len(keys)
    vals = data.draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
    floats = data.draw(st.lists(st.floats(-10, 10, allow_nan=False, width=32),
                                min_size=n, max_size=n))
    f = _mk_frame(keys, vals, floats)

    def build(src):
        sel = alg.Selection(src, alg.col("v") >= alg.lit(thresh))
        win = alg.Window(sel, "cumsum", cols=("v",))
        return alg.Projection(win, ("k", "v"))

    a = _run(f, 1, build)
    b = _run(f, parts, build)
    assert a["k"] == b["k"]
    np.testing.assert_allclose(a["v"], b["v"], rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(parts=st.integers(1, 5), rows=st.integers(1, 30), cols=st.integers(1, 6))
def test_transpose_partition_invariant(parts, rows, cols):
    rng = np.random.default_rng(rows * 31 + cols)
    mat = rng.standard_normal((rows, cols)).astype(np.float32)
    import jax.numpy as jnp
    from repro.core.dtypes import Domain
    f = Frame.from_matrix(jnp.asarray(mat), Domain.FLOAT)

    def build(src):
        return alg.Transpose(src)

    a = _run(f, 1, build)
    b = _run(f, parts, build)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(parts=st.integers(1, 4), periods=st.integers(1, 3), data=st.data())
def test_diff_shift_halo_invariant(parts, periods, data):
    n = data.draw(st.integers(2, 40))
    vals = data.draw(st.lists(st.floats(-5, 5, allow_nan=False, width=32),
                              min_size=n, max_size=n))
    f = Frame.from_pydict({"v": vals})

    def build_diff(src):
        return alg.Window(src, "diff", cols=("v",), periods=periods)

    a = _run(f, 1, build_diff)
    b = _run(f, parts, build_diff)
    assert len(a["v"]) == len(b["v"])
    for x, y in zip(a["v"], b["v"]):
        if x is None or y is None:
            assert x == y
        else:
            assert abs(x - y) < 1e-5


@settings(max_examples=15, deadline=None)
@given(parts=st.integers(1, 4), k=st.integers(1, 10), data=st.data())
def test_limit_prefix_invariant(parts, k, data):
    n = data.draw(st.integers(1, 30))
    vals = data.draw(st.lists(st.integers(-9, 9), min_size=n, max_size=n))
    f = Frame.from_pydict({"v": vals})

    def build(src):
        return alg.Limit(src, k)

    a = _run(f, 1, build)
    b = _run(f, parts, build)
    assert a["v"] == b["v"] == vals[:k]
