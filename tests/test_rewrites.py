"""Rewrite rules (paper §5): each rule must (a) fire on its pattern and
(b) preserve semantics vs the unrewritten plan."""
import numpy as np
import pytest

from repro.core import DataFrame, EvalMode, Session, set_session
from repro.core import algebra as alg
from repro.core.rewrite import infer_columns, optimize


@pytest.fixture
def sess():
    s = set_session(Session(mode=EvalMode.EAGER, default_row_parts=2,
                            optimize=False))  # compare plans manually
    yield s
    s.close()


def _eval(sess, node):
    return sess.executor.evaluate(node).to_frame().to_pydict()


def test_r1_double_transpose_eliminated(sess):
    d = DataFrame({"a": [1.0, 2.0], "b": [3.0, 4.0]})
    plan = alg.Transpose(alg.Transpose(d._node))
    opt = optimize(plan)
    assert opt.op == "source"
    assert _eval(sess, plan) == _eval(sess, opt)


def test_r2_transpose_sort_transpose_to_column_sort(sess):
    d = DataFrame({"a": [3.0, 1.0], "b": [1.0, 2.0], "c": [2.0, 3.0]},
                  row_labels=["r0", "r1"])
    plan = alg.Transpose(alg.Sort(alg.Transpose(d._node), ("r0",), True))
    opt = optimize(plan)
    assert opt.op == "column_sort"
    got, want = _eval(sess, opt), _eval(sess, plan)
    assert list(got.keys()) == list(want.keys()) == ["b", "c", "a"]
    for k in got:
        np.testing.assert_allclose(got[k], want[k])


def test_r3_transpose_selection_transpose_to_column_filter(sess):
    d = DataFrame({"a": [3.0, 1.0], "b": [1.0, 2.0], "c": [2.0, 3.0]},
                  row_labels=["r0", "r1"])
    plan = alg.Transpose(alg.Selection(alg.Transpose(d._node),
                                       alg.col("r0") > alg.lit(1.5)))
    opt = optimize(plan)
    assert opt.op == "column_filter"
    got, want = _eval(sess, opt), _eval(sess, plan)
    assert list(got.keys()) == ["a", "c"]
    for k in got:
        np.testing.assert_allclose(got[k], [float(v) for v in want[k]])


def test_r4_selection_fusion(sess):
    d = DataFrame({"v": [1, 2, 3, 4, 5]})
    plan = alg.Selection(alg.Selection(d._node, alg.col("v") > alg.lit(1)),
                         alg.col("v") < alg.lit(5))
    opt = optimize(plan)
    assert opt.op == "selection" and opt.children[0].op == "source"
    assert _eval(sess, opt) == _eval(sess, plan) == {"v": [2, 3, 4]}


def test_r5_selection_through_union(sess):
    a = DataFrame({"v": [1, 5]})
    b = DataFrame({"v": [2, 6]})
    plan = alg.Selection(alg.Union(a._node, b._node), alg.col("v") > alg.lit(3))
    opt = optimize(plan)
    assert opt.op == "union"
    assert _eval(sess, opt) == _eval(sess, plan) == {"v": [5, 6]}


def test_r7_cross_filter_to_join(sess):
    a = DataFrame({"x": [1, 2, 3], "p": [7, 8, 9]})
    b = DataFrame({"y": [2, 3, 4]})
    plan = alg.Selection(alg.Join(a._node, b._node, on=None, how="inner"),
                         alg.BinExpr("==", alg.col("x"), alg.col("y")))
    opt = optimize(plan, sess.executor._source_columns)
    assert opt.op == "join" and opt.params["left_on"] == ("x",)
    assert _eval(sess, opt) == _eval(sess, plan)


def test_r8_map_fusion(sess):
    d = DataFrame({"v": [1.0, 2.0]})

    def plus1(cols, frame):
        from repro.core.frame import Column, Frame
        from repro.core.labels import labels_from_values
        from repro.core.dtypes import Domain
        c = cols["v"]
        return Frame([Column(c.data + 1.0, Domain.FLOAT)], frame.row_labels,
                     labels_from_values(["v"]))

    def times2(cols, frame):
        from repro.core.frame import Column, Frame
        from repro.core.labels import labels_from_values
        from repro.core.dtypes import Domain
        c = cols["v"]
        return Frame([Column(c.data * 2.0, Domain.FLOAT)], frame.row_labels,
                     labels_from_values(["v"]))

    u1 = alg.Udf.wrap(plus1, name="plus1", elementwise=True)
    u2 = alg.Udf.wrap(times2, name="times2", elementwise=True)
    plan = alg.Map(alg.Map(d._node, u1), u2)
    opt = optimize(plan)
    assert opt.op == "map" and opt.children[0].op == "source"  # fused
    assert _eval(sess, opt) == _eval(sess, plan) == {"v": [4.0, 6.0]}


def test_r10_r11_limit_rules(sess):
    d = DataFrame({"v": list(range(100))})
    plan = alg.Limit(alg.Limit(d._node, 10), 5)
    opt = optimize(plan)
    assert opt.op == "limit" and opt.params["k"] == 5
    plan2 = alg.Limit(alg.Projection(d._node, ("v",)), 3)
    opt2 = optimize(plan2)
    assert opt2.op == "projection" and opt2.children[0].op == "limit"
    assert _eval(sess, opt2) == {"v": [0, 1, 2]}


def test_infer_columns_through_static_ops(sess):
    d = DataFrame({"a": [1], "b": [2]})
    n = alg.Rename(alg.Projection(d._node, ("a", "b")), {"a": "x"})
    cols = infer_columns(n, sess.executor._source_columns)
    assert cols == ["x", "b"]
    assert infer_columns(alg.Transpose(d._node), sess.executor._source_columns) is None
