"""Operator-level semantics vs independent python references (paper Table 1:
ordered analogs of relational algebra + WINDOW + the 4 dataframe operators)."""
import numpy as np
import pytest

from repro.core import DataFrame, Domain
from repro.core import algebra as alg


@pytest.fixture
def df(eager_session):
    return DataFrame({
        "k": ["a", "b", "a", "c", "b", "a", None, "c"],
        "v": [3, 1, 4, 1, 5, 9, 2, 6],
        "w": [0.5, None, 1.5, 2.0, None, 3.0, 3.5, 4.0],
    })


def test_selection_preserves_order(df):
    out = df[df["v"] > 2].collect()
    assert out.col("v").to_pylist() == [3, 4, 5, 9, 6]
    # null comparisons are False (w > 1 drops null rows)
    out = df[df["w"] > 1].collect()
    assert out.col("v").to_pylist() == [4, 1, 9, 2, 6]


def test_projection(df):
    out = df[["w", "k"]].collect()
    assert out.col_labels.to_list() == ["w", "k"]
    assert out.ncols == 2


def test_union_ordered_by_left_then_right(eager_session):
    a = DataFrame({"x": [1, 2]})
    b = DataFrame({"x": [3, 4]})
    assert a.append(b).collect().col("x").to_pylist() == [1, 2, 3, 4]
    assert b.append(a).collect().col("x").to_pylist() == [3, 4, 1, 2]


def test_difference(eager_session):
    a = DataFrame({"x": [1, 2, 3, 2, 4]})
    b = DataFrame({"x": [2, 4]})
    assert a.difference(b).collect().col("x").to_pylist() == [1, 3]


def test_cross_product_nested_order(eager_session):
    a = DataFrame({"x": [1, 2]})
    b = DataFrame({"y": [10, 20]})
    out = a.cross(b).collect()
    assert out.col("x").to_pylist() == [1, 1, 2, 2]
    assert out.col("y").to_pylist() == [10, 20, 10, 20]


def test_join_inner_left_order_ties_by_right(eager_session):
    left = DataFrame({"k": ["a", "b", "a"], "lv": [1, 2, 3]})
    right = DataFrame({"k": ["a", "a", "c"], "rv": [10, 20, 30]})
    out = left.merge(right, on="k").collect()
    # left order outer; both right "a" matches in right order
    assert out.col("lv").to_pylist() == [1, 1, 3, 3]
    assert out.col("rv").to_pylist() == [10, 20, 10, 20]


def test_join_left_and_outer_nulls(eager_session):
    left = DataFrame({"k": ["a", "b"], "lv": [1, 2]})
    right = DataFrame({"k": ["a", "c"], "rv": [10, 30]})
    lo = left.merge(right, on="k", how="left").collect()
    assert lo.col("rv").to_pylist() == [10, None]
    oo = left.merge(right, on="k", how="outer").collect()
    assert oo.col("lv").to_pylist() == [1, 2, None]
    assert oo.col("rv").to_pylist() == [10, None, 30]


def test_drop_duplicates_keeps_first(eager_session):
    d = DataFrame({"x": [1, 2, 1, 3, 2], "y": [0, 0, 0, 0, 0]})
    assert d.drop_duplicates().collect().col("x").to_pylist() == [1, 2, 3]


def test_groupby_sorted_key_order_and_null_keys_dropped(df):
    out = df.groupby("k").agg({"v": ["sum", "count", "mean"],
                               "w": ["min", "max"]}).collect()
    assert out.col("k").to_pylist() == ["a", "b", "c"]
    assert out.col("v_sum").to_pylist() == [16.0, 6.0, 7.0]
    assert out.col("v_count").to_pylist() == [3, 2, 2]
    # w has nulls: count excludes them; min/max over valid values only
    assert out.col("w_min").to_pylist() == [0.5, None, 2.0]
    assert out.col("w_max").to_pylist() == [3.0, None, 4.0]


def test_groupby_global_aggregate(df):
    assert df["v"].sum() == 31.0
    assert df["v"].count() == 8
    assert df["w"].count() == 6  # nulls excluded
    assert df["v"].max() == 9.0


def test_sort_stable(eager_session):
    d = DataFrame({"k": [2, 1, 2, 1], "tag": [0, 1, 2, 3]})
    out = d.sort_values("k").collect()
    assert out.col("tag").to_pylist() == [1, 3, 0, 2]  # stable within key
    out = d.sort_values("k", ascending=False).collect()
    assert out.col("tag").to_pylist() == [0, 2, 1, 3]


def test_rename(df):
    out = df.rename(columns={"v": "value"}).collect()
    assert "value" in out.col_labels.to_list()


def test_window_cumsum_diff_shift(eager_session):
    d = DataFrame({"v": [1, 2, 3, 4, 5, 6, 7]})
    assert d.cumsum().collect().col("v").to_pylist() == [1, 3, 6, 10, 15, 21, 28]
    assert d.diff().collect().col("v").to_pylist() == [None, 1, 1, 1, 1, 1, 1]
    assert d.shift(2).collect().col("v").to_pylist() == [None, None, 1, 2, 3, 4, 5]
    roll = d.rolling_sum(3).collect().col("v").to_pylist()
    assert roll == [None, None, 6, 9, 12, 15, 18]


def test_transpose_roundtrip_heterogeneous(eager_session):
    d = DataFrame({"i": [1, 2, 3], "f": [1.5, 2.5, 3.5]})
    tt = d.T.T.collect().induce()
    assert tt.schema == (Domain.INT, Domain.FLOAT)
    assert tt.to_pydict() == {"i": [1, 2, 3], "f": [1.5, 2.5, 3.5]}


def test_transpose_swaps_labels(eager_session):
    d = DataFrame({"a": [1, 2], "b": [3, 4]}, row_labels=["r0", "r1"])
    t = d.T.collect()
    assert t.row_labels.to_list() == ["a", "b"]
    assert t.col_labels.to_list() == ["r0", "r1"]
    assert t.col("r0").to_pylist() == [1, 3]


def test_to_from_labels_inverse(eager_session):
    d = DataFrame({"k": ["x", "y", "z"], "v": [1, 2, 3]})
    rt = d.set_index("k").reset_index("k").collect()
    assert rt.to_pydict() == {"k": ["x", "y", "z"], "v": [1, 2, 3]}


def test_from_labels_schema_induction_on_labels(eager_session):
    # positional labels become an int column (paper: labels interpreted via S)
    d = DataFrame({"v": [5, 6]})
    out = d.reset_index("idx").collect().induce()
    assert out.col("idx").to_pylist() == [0, 1]
    assert out.schema[0] is Domain.INT


def test_map_one_to_many_columns(eager_session):
    from repro.core import get_dummies
    d = DataFrame({"c": ["p", "q", "p"], "v": [1, 2, 3]})
    out = get_dummies(d, ["c"]).collect()
    assert out.col("c_p").to_pylist() == [1, 0, 1]
    assert out.col("c_q").to_pylist() == [0, 1, 0]
    assert out.col("v").to_pylist() == [1, 2, 3]


def test_agg_union_composition(eager_session):
    # paper §3.4: agg == one GROUPBY per function + UNION in listed order
    d = DataFrame({"v": [1.0, 2.0, 3.0], "u": [4.0, 5.0, 6.0]})
    out = d.agg(["sum", "min"]).collect()
    assert out.col("v").to_pylist() == [6.0, 1.0]
    assert out.col("u").to_pylist() == [15.0, 4.0]


def test_pivot(eager_session):
    d = DataFrame({
        "year": [2001, 2001, 2002, 2002],
        "month": ["jan", "feb", "jan", "feb"],
        "sales": [100, 110, 150, 200],
    })
    out = d.pivot(index="year", columns="month", values="sales").collect()
    assert out.row_labels.to_list() == [2001, 2002]
    assert out.col("jan").to_pylist() == [100, 150]
    assert out.col("feb").to_pylist() == [110, 200]
