"""Differential tests: ``api.read_csv`` (chunk-parallel streaming parser)
against ``pandas.read_csv`` — the satellite correctness gaps of the seed
parser (quoted fields containing the separator, CRLF line endings,
empty-string vs missing) plus schema induction, usecols pushdown, and
chunk-boundary invariance.

Comparison normalizes representation differences that are storage policy,
not semantics: our floats are float32 (compared with float32-level
tolerance), our nulls are ``None`` where pandas uses NaN, and our bool
domain prints Python bools.  Test data avoids the few spots where the
engine's S(·) intentionally differs from pandas' inference (e.g. a column
of only ``0``/``1`` induces BOOL here, int64 there — a seed-era contract
the budget-0 fast path must keep).
"""
import math
import os

import numpy as np
import pytest

pd = pytest.importorskip("pandas")

from repro.core import EvalMode, Session, set_session
from repro.core.api import _read_csv_seed, read_csv


@pytest.fixture
def session():
    s = set_session(Session(mode=EvalMode.LAZY))
    yield s
    s.close()


def _norm(v):
    if v is None:
        return None
    if isinstance(v, float) and math.isnan(v):
        return None
    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    return v


def _assert_matches_pandas(df, pdf, float_rtol=1e-6):
    assert df.columns == list(pdf.columns)
    ours = df.to_pydict()
    for name in pdf.columns:
        mine = [_norm(v) for v in ours[name]]
        theirs = [_norm(v) for v in pdf[name].tolist()]
        assert len(mine) == len(theirs), name
        for i, (a, b) in enumerate(zip(mine, theirs)):
            if a is None or b is None:
                assert a is None and b is None, (name, i, a, b)
            elif isinstance(b, float) and not isinstance(b, bool):
                assert a == pytest.approx(b, rel=float_rtol), (name, i)
            else:
                assert a == b, (name, i, a, b)


def _write(tmp_path, text, name="t.csv", binary=False):
    p = tmp_path / name
    if binary:
        p.write_bytes(text)
    else:
        p.write_text(text)
    return str(p)


# =============================================================================
# the three satellite gaps
# =============================================================================
def test_quoted_separator_fields(tmp_path, session):
    p = _write(tmp_path,
               'a,b,c\n1,"x,y",3\n2,"p,q,r",4\n3,plain,5\n')
    _assert_matches_pandas(read_csv(p), pd.read_csv(p))


def test_quoted_quotes_and_mixed_quoting(tmp_path, session):
    p = _write(tmp_path,
               'a,s\n1,"say ""hi"", ok"\n2,"tail"\n3,bare\n')
    pdf = pd.read_csv(p)
    assert pdf["s"].tolist()[0] == 'say "hi", ok'
    _assert_matches_pandas(read_csv(p), pdf)


def test_crlf_line_endings(tmp_path, session):
    # the streaming parser reads raw byte ranges (no universal-newline
    # translation layer), so it must strip \r itself
    p = _write(tmp_path, b'a,b\r\n1,x\r\n2,y\r\n3,z\r\n', binary=True)
    _assert_matches_pandas(read_csv(p), pd.read_csv(p))


def test_empty_vs_missing_default_na(tmp_path, session):
    # pandas default: both a missing field and a quoted "" become null
    p = _write(tmp_path, 'a,b,c\n"",x,\n1,,z\n2,"",w\n')
    _assert_matches_pandas(read_csv(p), pd.read_csv(p))


def test_empty_vs_missing_keep_default_na_false(tmp_path, session):
    # keep_default_na=False: both surface as empty *strings*, and a numeric-
    # looking column with empties becomes a string column — pandas semantics
    p = _write(tmp_path, 'a,b\n"",x\n1,\n2,y\n')
    _assert_matches_pandas(read_csv(p, keep_default_na=False),
                           pd.read_csv(p, keep_default_na=False))
    got = read_csv(p, keep_default_na=False).to_pydict()
    assert got["a"] == ["", "1", "2"]      # not None — the seed conflated


def test_missing_numeric_becomes_masked_not_zero(tmp_path, session):
    p = _write(tmp_path, 'x,y\n1,2.5\n,4.25\n5,\n')
    df = read_csv(p)
    _assert_matches_pandas(df, pd.read_csv(p))
    assert df.to_pydict()["x"] == [1, None, 5]


# =============================================================================
# schema induction parity
# =============================================================================
def test_schema_induction_matches_pandas(tmp_path, session):
    p = _write(tmp_path,
               "i,f,b,s\n"
               "1,1.5,true,alpha\n"
               "2,2.25,false,beta\n"
               "3,-3.75,true,alpha\n")
    df = read_csv(p)
    pdf = pd.read_csv(p)
    _assert_matches_pandas(df, pdf)
    assert df.dtypes == ["int", "float", "bool", "str"]


def test_mixed_chunk_domains_vote_like_global_induction(tmp_path, session):
    """A column whose early rows look INT but whose late rows are FLOAT (or
    STR) must induce the same domain the whole-column S(·) would — the
    per-chunk castability vote is conjunctive, not first-chunk-wins."""
    n = 3000
    lines = ["v,w"]
    for i in range(n):
        lines.append(f"{i % 7},{i % 5}")
    lines.append("2.5,tail")               # floats/strings only at the end
    p = _write(tmp_path, "\n".join(lines) + "\n")
    os.environ["REPRO_CSV_CHUNK_BYTES"] = "512"   # force many chunks
    try:
        df = read_csv(p)
    finally:
        del os.environ["REPRO_CSV_CHUNK_BYTES"]
    pdf = pd.read_csv(p)
    _assert_matches_pandas(df, pdf)
    assert df.dtypes[0] == "float" and df.dtypes[1] == "str"


def test_chunk_boundary_invariance(tmp_path, session):
    """The parse must be invariant to where the byte-range chunk boundaries
    land (including boundaries inside quoted fields)."""
    rng = np.random.default_rng(5)
    lines = ["k,v,s"]
    for i in range(500):
        s = f'"s,{i % 13}"' if i % 3 == 0 else f"s{i % 13}"
        lines.append(f"{i % 9},{rng.integers(0, 100)},{s}")
    p = _write(tmp_path, "\n".join(lines) + "\n")
    ref = read_csv(p).to_pydict()
    for cb in (64, 777, 10 ** 9):
        os.environ["REPRO_CSV_CHUNK_BYTES"] = str(cb)
        try:
            assert read_csv(p).to_pydict() == ref, cb
        finally:
            del os.environ["REPRO_CSV_CHUNK_BYTES"]
    _assert_matches_pandas(read_csv(p), pd.read_csv(p))


# =============================================================================
# projection pushdown + misc
# =============================================================================
def test_usecols_pushdown(tmp_path, session):
    p = _write(tmp_path, 'a,b,c,d\n1,x,2.5,t\n2,y,3.5,f\n')
    _assert_matches_pandas(read_csv(p, usecols=["a", "c"]),
                           pd.read_csv(p, usecols=["a", "c"]))
    # file order kept even if usecols is shuffled (pandas semantics)
    df = read_csv(p, usecols=["c", "a"])
    assert df.columns == ["a", "c"]
    with pytest.raises(KeyError):
        read_csv(p, usecols=["a", "nope"])


def test_alternate_separator(tmp_path, session):
    p = _write(tmp_path, 'a;b\n1;"x;y"\n2;z\n')
    _assert_matches_pandas(read_csv(p, sep=";"), pd.read_csv(p, sep=";"))


def test_multichar_separator_with_quotes(tmp_path, session):
    # the quoted-line tokenizer must advance by len(sep), like str.split
    p = _write(tmp_path, 'a||b||c\n1||"x||y"||3\n2||z||4\n')
    got = read_csv(p, sep="||").to_pydict()
    assert got == {"a": [1, 2], "b": ["x||y", "z"], "c": [3, 4]}


def test_embedded_newline_in_quoted_field_raises(tmp_path, session):
    # the byte-range chunker splits records on raw newlines, so a multiline
    # quoted field cannot be parsed faithfully — fail loudly, never corrupt
    p = _write(tmp_path, 'a,b\n1,"x\ny"\n2,z\n')
    with pytest.raises(ValueError, match="line break"):
        read_csv(p).collect()


def test_seed_path_rejects_unsupported_args(tmp_path, session, monkeypatch):
    p = _write(tmp_path, 'a,b\n1,x\n')
    monkeypatch.setenv("REPRO_CSV_STREAM", "0")
    with pytest.raises(ValueError, match="seed parser"):
        read_csv(p, usecols=["a"])
    with pytest.raises(ValueError, match="seed parser"):
        read_csv(p, keep_default_na=False)
    assert read_csv(p).to_pydict() == {"a": [1], "b": ["x"]}


def test_extra_fields_raise_short_rows_pad(tmp_path, session):
    # pandas raises ParserError on surplus fields; short rows fill NaN
    p = _write(tmp_path, 'a,b\n1,x\n2,y,z\n')
    with pytest.raises(pd.errors.ParserError):
        pd.read_csv(p)
    with pytest.raises(ValueError, match="expected 2 fields"):
        read_csv(p).collect()
    p2 = _write(tmp_path, 'a,b\n1,x\n2\n3,z\n', name="short.csv")
    _assert_matches_pandas(read_csv(p2), pd.read_csv(p2))


def test_blank_lines_skipped(tmp_path, session):
    p = _write(tmp_path, 'a,b\n1,x\n\n2,y\n\n\n3,z\n')
    _assert_matches_pandas(read_csv(p), pd.read_csv(p))


def test_header_only_file(tmp_path, session):
    p = _write(tmp_path, 'a,b,c\n')
    df = read_csv(p)
    assert df.columns == ["a", "b", "c"]
    assert len(df) == 0


def test_matches_seed_parser_on_plain_files(tmp_path, session):
    """On the files the seed parser handled correctly (no quotes, LF, no
    empty-vs-missing subtleties) the streaming parser is value-identical —
    the budget-0 fast-path contract."""
    rng = np.random.default_rng(9)
    lines = ["k,v,x,s"]
    for i in range(2000):
        lines.append(f"{i % 8},{rng.integers(0, 50)},"
                     f"{rng.integers(0, 12) * 0.25},s{i % 12:02d}")
    p = _write(tmp_path, "\n".join(lines) + "\n")
    a = read_csv(p)
    b = _read_csv_seed(p)
    assert a.to_pydict() == b.to_pydict()
    assert a.collect().row_labels.to_list() == b.collect().row_labels.to_list()
    assert a.dtypes == b.dtypes
