"""Multi-session service suite (PR 9).

Session-scoped config isolation (no cross-tenant knob clobbering), the async
statement surface (cancellation, typed close errors), shared-budget
multi-tenancy with per-session attribution, admission control, and the
progressive-aggregate termination fix — plus a 16-session concurrent
differential: every tenant's concurrent result must be bit-identical to its
serial, isolated run.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core import (EvalMode, ExecutorClosedError, QueryService, Session,
                        StatementCancelled, get_session, set_session)
from repro.core import algebra as alg
from repro.core import faults, schedule
from repro.core.algebra import GroupBy, Map, Selection, Udf, col, lit
from repro.core.approx import progressive_aggregate
from repro.core.config import scope
from repro.core.dtypes import Domain
from repro.core.faults import TaskError
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame
from repro.core.store import get_store


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    yield
    faults.reset()


def _frame(n=120, seed=0):
    rng = np.random.default_rng(seed)
    return Frame(
        [Column(np.asarray(rng.integers(0, 8, n, dtype=np.int32)), Domain.INT),
         Column(np.asarray((rng.integers(0, 12, n) * np.float32(0.25))
                           .astype(np.float32)), Domain.FLOAT)],
        RangeLabels(n), labels_from_values(["k", "x"]))


def _plan(src, scale=2.0, name="svc_scale"):
    def fn(cols, frame, scale=scale):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * scale + 1.0, Domain.FLOAT, c.mask, None)
        return out

    udf = Udf(name=f"{name}_{scale}", fn=fn, deps=frozenset(["x"]),
              elementwise=True)
    return GroupBy(Selection(Map(src, udf), col("k") < lit(6)),
                   ("k",), [("x", "sum", "x"), ("x", "count", "n")])


def _slow_plan(src, delay_s, started=None, name="svc_slow"):
    def fn(cols, frame, delay_s=delay_s, started=started):
        if started is not None:
            started.set()
        time.sleep(delay_s)
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data + 1.0, Domain.FLOAT, c.mask, None)
        return out

    udf = Udf(name=name, fn=fn, deps=frozenset(["x"]), elementwise=True)
    return Map(src, udf)


# =============================================================================
# session-scoped config: no cross-tenant contamination
# =============================================================================
def test_fault_plan_is_session_scoped():
    """A session with an always-fire fault plan fails ITS statements; a
    concurrent knob-less session runs clean, and the process-wide fault
    machinery never activates."""
    poisoned = Session(mode=EvalMode.LAZY, task_retries=0,
                       fault_plan="worker:1.0!", fault_seed=3)
    clean = Session(mode=EvalMode.LAZY)
    try:
        f = _frame(seed=1)
        with pytest.raises(TaskError):
            poisoned.collect(_plan(poisoned.register_frame(f, row_parts=4)))
        out = clean.collect(_plan(clean.register_frame(f, row_parts=4)))
        assert out.nrows > 0
        assert clean.executor.stats.faults_injected == 0
        assert poisoned.executor.stats.task_failures > 0
        assert not faults.active()          # process default untouched
    finally:
        poisoned.close()
        clean.close()


def test_retry_knobs_are_session_scoped():
    s = Session(mode=EvalMode.LAZY, task_retries=7, retry_backoff_ms=0)
    try:
        base = schedule.task_retries()
        with scope(s.config):
            assert schedule.task_retries() == 7
        assert schedule.task_retries() == base
    finally:
        s.close()


def test_private_budget_does_not_touch_process_store(tmp_path):
    """Session-private out-of-core store: its spills never hit the process
    store (this test carries NO @pytest.mark.spill — the global
    no-unexpected-spills guard watches the process store and must see
    nothing), and close() drops every spill file."""
    before = get_store().stats.spills
    s = Session(mode=EvalMode.LAZY, mem_budget_bytes=4096,
                spill_dir=str(tmp_path))
    try:
        src = s.register_frame(_frame(4000, seed=2), row_parts=8)
        out = s.collect(_plan(src))
        assert out.nrows > 0
        assert s.executor.stats.spills > 0          # budget actually bound
        assert s.executor.stats.faults > 0
        assert get_store().stats.spills == before   # process store untouched
    finally:
        s.close()
    leftovers = [p for p in tmp_path.rglob("*") if p.is_file()]
    assert leftovers == []                          # zero leaked spill files


# =============================================================================
# async surface: cancellation + typed close errors
# =============================================================================
def test_cancel_mid_statement_then_rerun_is_bit_identical():
    s = Session(mode=EvalMode.LAZY)
    try:
        started = threading.Event()
        src = s.register_frame(_frame(64, seed=4), row_parts=8)
        node = _slow_plan(src, 0.15, started=started, name="svc_cancel")
        h = s.submit(node)
        assert started.wait(5.0)
        h.cancel()
        with pytest.raises(StatementCancelled):
            h.result(timeout=10.0)
        assert h.cancelled
        # cancellation left no partial state: a fresh run of the SAME plan
        # completes and matches the never-cancelled reference
        out = s.collect(node).to_pydict()
        ref = Session(mode=EvalMode.LAZY)
        try:
            rsrc = ref.register_frame(_frame(64, seed=4), row_parts=8)
            expect = ref.collect(
                _slow_plan(rsrc, 0.0, name="svc_cancel_ref")).to_pydict()
        finally:
            ref.close()
        assert out == expect
    finally:
        s.close()


def test_collect_after_close_raises_typed_error():
    s = Session(mode=EvalMode.LAZY)
    src = s.register_frame(_frame(seed=5), row_parts=4)
    node = _plan(src)
    s.close()
    with pytest.raises(ExecutorClosedError):
        s.collect(node)
    with pytest.raises(ExecutorClosedError):
        s.submit(node)


def test_collect_racing_close_fails_typed_not_hang():
    """A collect JOINING an in-flight statement when the session closes must
    raise the typed error promptly — the old shutdown abandoned the in-flight
    promise and the joiner hung forever."""
    s = Session(mode=EvalMode.LAZY)
    release = threading.Event()
    started = threading.Event()

    def fn(cols, frame):
        started.set()
        release.wait(10.0)
        return dict(cols)

    udf = Udf(name="svc_race_close", fn=fn, deps=frozenset(["x"]),
              elementwise=True)
    src = s.register_frame(_frame(48, seed=6), row_parts=4)
    node = Map(src, udf)
    s.submit(node)                       # background producer
    assert started.wait(5.0)

    errs: list = []

    def join():
        try:
            s.collect(node)
            errs.append(None)
        except BaseException as e:       # noqa: BLE001 - recorded for assert
            errs.append(e)

    t = threading.Thread(target=join)
    t.start()
    time.sleep(0.2)                      # let the joiner reach the promise
    try:
        s.close()
        t.join(timeout=10.0)
        assert not t.is_alive(), "collect hung across close()"
        assert len(errs) == 1 and isinstance(errs[0], ExecutorClosedError)
    finally:
        release.set()


def test_get_session_singleton_is_race_free_and_close_aware():
    set_session(Session(mode=EvalMode.LAZY)).close()   # vacate the default
    got: list = []
    barrier = threading.Barrier(8)

    def grab():
        barrier.wait()
        got.append(get_session())

    threads = [threading.Thread(target=grab) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len({id(s) for s in got}) == 1
    s = got[0]
    s.close()
    s2 = get_session()                   # closed default is replaced
    try:
        assert s2 is not s and not s2._closed
    finally:
        s2.close()


# =============================================================================
# zero-block progressive aggregate terminates (bugfix)
# =============================================================================
@pytest.mark.parametrize("func,expect", [("sum", 0.0), ("count", 0.0),
                                         ("mean", float("nan"))])
def test_zero_row_progressive_aggregate_terminates(func, expect):
    empty = Frame([Column(np.zeros(0, dtype=np.float64), Domain.FLOAT)],
                  RangeLabels(0), labels_from_values(["x"]))
    pf = PartitionedFrame.from_frame(empty, 1, 1)
    ests = list(progressive_aggregate(pf, "x", func))
    assert len(ests) == 1 and ests[0].final
    if expect != expect:                 # NaN
        assert ests[0].value != ests[0].value
    else:
        assert ests[0].value == expect


def test_all_null_progressive_mean_is_nan():
    x = Column(np.zeros(8, dtype=np.float64), Domain.FLOAT,
               np.zeros(8, dtype=bool))
    f = Frame([x], RangeLabels(8), labels_from_values(["x"]))
    pf = PartitionedFrame.from_frame(f, 2, 1)
    final = [e for e in progressive_aggregate(pf, "x", "mean") if e.final]
    assert len(final) == 1
    assert final[0].value != final[0].value      # NaN, not 0.0


# =============================================================================
# QueryService: shared budget, admission, MQO, attribution
# =============================================================================
def test_service_cross_session_mqo_on_shared_table():
    with QueryService(background_workers=2) as svc:
        shared = svc.register_frame(_frame(300, seed=7), row_parts=4)
        a = svc.session(mode=EvalMode.LAZY)
        b = svc.session(mode=EvalMode.LAZY)
        node = _plan(shared, name="svc_mqo")
        ra = a.collect(node).to_pydict()
        hits0 = svc.stats.cache_hits
        rb = b.collect(node).to_pydict()
        assert rb == ra
        assert svc.stats.cache_hits > hits0      # b reused a's materialization


def test_service_per_session_stats_sum_to_global():
    with QueryService(background_workers=2) as svc:
        sessions = [svc.session(mode=EvalMode.LAZY) for _ in range(3)]
        for i, s in enumerate(sessions):
            src = s.register_frame(_frame(200, seed=10 + i), row_parts=4)
            out = s.collect(_plan(src, scale=1.0 + i, name=f"svc_attr{i}"))
            assert out.nrows > 0
            assert s.stats.evaluated_nodes > 0
        for fld in dataclasses.fields(type(svc.stats)):
            if fld.name == "peak_resident_bytes":
                continue                         # gauge: max, not additive
            total = getattr(svc.stats, fld.name)
            per = sum(getattr(s.stats, fld.name) for s in sessions)
            assert per == total, (fld.name, per, total)


def test_service_shared_budget_attributes_spills_per_session(tmp_path):
    with QueryService(background_workers=2, mem_budget_bytes=4096,
                      spill_dir=str(tmp_path)) as svc:
        a = svc.session(mode=EvalMode.LAZY)
        b = svc.session(mode=EvalMode.LAZY)
        sa = a.register_frame(_frame(4000, seed=12), row_parts=8)
        sb = b.register_frame(_frame(4000, seed=13), row_parts=8)
        a.collect(_plan(sa, name="svc_budget_a"))
        b.collect(_plan(sb, name="svc_budget_b"))
        assert svc.stats.spills > 0              # ONE budget, both charged
        assert a.stats.spills + b.stats.spills == svc.stats.spills
        assert a.stats.spills > 0 and b.stats.spills > 0
        assert get_store().stats.spills == 0     # process store untouched
    leftovers = [p for p in tmp_path.rglob("*") if p.is_file()]
    assert leftovers == []


def test_service_admission_respects_cap_and_fairness():
    """Per-session max_inflight bounds admitted statements; a second tenant's
    first statement overtakes a busy tenant's backlog (fewest-running-first),
    and everything completes."""
    with QueryService(background_workers=2, admission_slots=2) as svc:
        shared = svc.register_frame(_frame(40, seed=14), row_parts=2)
        a = svc.session(mode=EvalMode.LAZY, max_inflight=1)
        b = svc.session(mode=EvalMode.LAZY, max_inflight=1)
        done: list = []
        lock = threading.Lock()

        def tracked(tag, delay):
            def fn(cols, frame, tag=tag, delay=delay):
                time.sleep(delay)
                return dict(cols)
            return Map(shared, Udf(name=f"svc_admit_{tag}", fn=fn,
                                   deps=frozenset(["x"]), elementwise=True))

        handles = []
        for i in range(3):
            h = a.submit(tracked(f"a{i}", 0.15))
            handles.append(("a", i, h))
        hb = b.submit(tracked("b0", 0.05))
        handles.append(("b", 0, hb))
        assert svc.admission.queued() >= 1       # a's backlog actually queued
        for sid, i, h in handles:
            h.result(timeout=30.0)
            with lock:
                done.append((sid, i))
        # b0 was admitted while a's queue drained one-at-a-time: it must
        # finish before a's LAST statement
        finish = {(sid, i): pos for pos, (sid, i) in enumerate(done)}
        # join order above is submission order, so use wall-clock via
        # futures: b0 must already be done when a2 completes
        assert hb._future.done()
        assert finish[("b", 0)] is not None


def test_service_sixteen_session_concurrent_differential():
    """16 tenants with per-session knobs run CONCURRENTLY on one service;
    each result must be bit-identical to the tenant's serial run in its own
    isolated session."""
    n_sessions = 16
    frames = [_frame(240, seed=20 + i) for i in range(n_sessions)]

    # serial reference: isolated single-tenant sessions
    expected = []
    for i in range(n_sessions):
        ref = Session(mode=EvalMode.LAZY)
        try:
            src = ref.register_frame(frames[i], row_parts=4)
            expected.append(
                ref.collect(_plan(src, scale=1.0 + (i % 4),
                                  name=f"svc_diff{i}")).to_pydict())
        finally:
            ref.close()

    with QueryService(background_workers=2) as svc:
        sessions = [
            svc.session(mode=EvalMode.OPPORTUNISTIC,
                        task_retries=(i % 3),
                        shuffle_buckets=2 + (i % 3))
            for i in range(n_sessions)]
        results: dict = {}
        errors: list = []

        def run(i, s):
            try:
                src = s.register_frame(frames[i], row_parts=4)
                node = s.statement(_plan(src, scale=1.0 + (i % 4),
                                         name=f"svc_diff{i}"))
                results[i] = s.collect(node).to_pydict()
            except BaseException as e:   # noqa: BLE001 - surfaced below
                errors.append((i, e))

        threads = [threading.Thread(target=run, args=(i, s))
                   for i, s in enumerate(sessions)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60.0)
        assert errors == []
        for i in range(n_sessions):
            assert results[i] == expected[i], f"session {i} diverged"
        # attribution invariant holds under full concurrency too
        total = sum(s.stats.evaluated_nodes for s in sessions)
        assert total == svc.stats.evaluated_nodes


def test_service_close_fails_queued_statements_typed():
    with QueryService(background_workers=1, admission_slots=1) as svc:
        shared = svc.register_frame(_frame(40, seed=15), row_parts=2)
        s = svc.session(mode=EvalMode.LAZY, max_inflight=1)

        def fn(cols, frame):
            time.sleep(0.2)
            return dict(cols)

        mk = lambda i: Map(shared, Udf(name=f"svc_close_q{i}", fn=fn,  # noqa: E731
                                       deps=frozenset(["x"]),
                                       elementwise=True))
        h1 = s.submit(mk(0))
        h2 = s.submit(mk(1))             # queued behind h1 (cap 1)
        svc.close()
        with pytest.raises((ExecutorClosedError, StatementCancelled)):
            h2.result(timeout=10.0)
        # h1 either finished or failed typed — never hangs
        try:
            h1.result(timeout=10.0)
        except (ExecutorClosedError, StatementCancelled):
            pass
