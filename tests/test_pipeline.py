"""Data pipeline (the paper's technique feeding training): dataframe-stage
semantics, determinism, exactly-once resume, background prefetch."""
import numpy as np
import pytest

from repro.data import DataPipeline, PipelineConfig, synthetic_corpus
from repro.data.tokenizer import HashTokenizer


def _pipe(**kw):
    corpus = synthetic_corpus(600, seed=2, mean_len=30)
    # inject short docs (filtered) and duplicates (deduped)
    corpus[10] = "short"
    corpus[11] = corpus[12]
    pc = PipelineConfig(seq_len=24, global_batch=4, shard_docs=150, **kw)
    return DataPipeline(corpus, 1024, pc), corpus


def test_batches_shapes_and_determinism():
    p1, _ = _pipe()
    p2, _ = _pipe()
    b1 = [np.asarray(b["tokens"]) for _, b in zip(range(5), p1.batches())]
    b2 = [np.asarray(b["tokens"]) for _, b in zip(range(5), p2.batches())]
    for a, b in zip(b1, b2):
        assert a.shape == (4, 24)
        np.testing.assert_array_equal(a, b)


def test_resume_cursor_exactly_once():
    p1, _ = _pipe()
    all_batches = [np.asarray(b["tokens"]) for _, b in zip(range(6), p1.batches())]
    p2, _ = _pipe()
    resumed = [np.asarray(b["tokens"]) for _, b in zip(range(3), p2.batches(start_batch=3))]
    for a, b in zip(all_batches[3:], resumed):
        np.testing.assert_array_equal(a, b)


def test_dataframe_stages_filter_and_dedup():
    p, corpus = _pipe()
    frame = p.session.collect(p._shard_plan(0))
    texts = frame.col("text").to_pylist()
    assert "short" not in texts                      # SELECTION applied
    assert len(texts) == len(set(texts))             # DROP-DUPLICATES applied
    counts = frame.col("token_count").to_pylist()    # SORT by token_count
    assert counts == sorted(counts)


def test_background_prefetch_runs():
    p, _ = _pipe()
    list(zip(range(4), p.batches()))
    assert p.stats()["background_tasks"] >= 1


def test_tokenizer_stable_and_in_range():
    t = HashTokenizer(512)
    a = t.encode("the quick brown fox")
    assert a == t.encode("the quick brown fox")
    assert all(0 <= x < 512 for x in a)
    assert a[0] == 1  # BOS
