"""Out-of-core block store: residency states, budget-governed spill/fault,
pin scopes, benefit-density eviction, and the end-to-end acceptance pipeline
(map→filter→groupby→drop_duplicates over data 4× the budget, bit-identical
to the unbudgeted run, pandas-oracle checked).

Data uses exactly-representable floats (multiples of 0.25 — the repo
convention from the scheduling/dedup sweeps), so per-grid partial-combine
order cannot introduce ulp noise and bit-identity across budgets is exact.
"""
import os

import numpy as np
import pytest

from repro.core import EvalMode, Session, set_session
from repro.core import algebra as alg
from repro.core import schedule
from repro.core.api import read_csv
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame
from repro.core.store import (BlockHandle, get_store, reset_store, as_handle,
                              resolve)

pytestmark = pytest.mark.spill


@pytest.fixture
def fresh_store(monkeypatch, tmp_path):
    """Rebuild the store from the env after each (monkeypatched) change and
    tear it down afterwards so no spill files leak into later tests."""
    monkeypatch.setenv("REPRO_SPILL_DIR", str(tmp_path))
    reset_store()
    yield
    reset_store()


def _frame(n=100, seed=0):
    rng = np.random.default_rng(seed)
    return Frame(
        [Column(np.asarray(rng.integers(0, 8, n, dtype=np.int32)), Domain.INT),
         Column(np.asarray((rng.integers(0, 12, n) * np.float32(0.25))
                           .astype(np.float32)), Domain.FLOAT),
         Column(np.asarray(rng.integers(0, 5, n, dtype=np.int32)),
                Domain.STR, None, ("a", "b", "c", "d", "e"))],
        RangeLabels(n), labels_from_values(["k", "x", "s"]))


# =============================================================================
# store unit behaviour
# =============================================================================
def test_budget_zero_is_untracked_fast_path(fresh_store):
    f = _frame()
    h = as_handle(f)
    assert isinstance(h, BlockHandle)
    assert not h.is_tracked
    assert h.is_resident
    assert h.frame() is f               # same object, zero-copy wrap
    assert get_store().stats.spills == 0


def test_spill_and_fault_roundtrip(fresh_store, monkeypatch):
    f = _frame(200)
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(f.nbytes() + 16))
    reset_store()
    h1 = as_handle(_frame(200, seed=1))
    h2 = as_handle(_frame(200, seed=2))   # evicts h1
    st = get_store().stats
    assert st.spills == 1 and not h1.is_resident and h2.is_resident
    # fault h1 back: h2 spills to make room
    back = h1.frame()
    assert st.faults == 1 and h1.is_resident and not h2.is_resident
    # bit-identical round trip (values, masks, labels, dictionary)
    ref = _frame(200, seed=1)
    assert back.to_pydict() == ref.to_pydict()
    assert back.row_labels.to_list() == ref.row_labels.to_list()
    assert back.col(
        "s").dictionary == ref.col("s").dictionary
    assert st.peak_resident_bytes <= get_store().budget + ref.nbytes()


def test_pinned_blocks_never_evicted(fresh_store, monkeypatch):
    f = _frame(200, seed=1)
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(f.nbytes() + 16))
    reset_store()
    h1 = as_handle(_frame(200, seed=1))
    with h1.pinned():
        h2 = as_handle(_frame(200, seed=2))  # over budget, but h1 is pinned
        assert h1.is_resident              # overshoot instead of eviction
    h3 = as_handle(_frame(200, seed=3))    # unpinned now: h1 is fair game
    assert not h1.is_resident
    del h2, h3


def test_eviction_order_lru_then_benefit(fresh_store, monkeypatch):
    one = _frame(200).nbytes()
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(2 * one + 16))
    reset_store()
    h1 = as_handle(_frame(200, seed=1))
    h2 = as_handle(_frame(200, seed=2))
    h1.frame()                             # touch h1: h2 becomes LRU
    h3 = as_handle(_frame(200, seed=3))
    assert not h2.is_resident and h1.is_resident
    # benefit beats recency: stamp h1 as a valuable cached result
    h1.benefit = 10.0
    h2.frame()                             # fault h2 back (someone spills)
    h4 = as_handle(_frame(200, seed=4))
    assert h1.is_resident                  # high benefit density survives
    del h3, h4


def test_spill_files_cleaned_on_reset(fresh_store, monkeypatch, tmp_path):
    f = _frame(200)
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(f.nbytes() + 16))
    reset_store()
    keep = [as_handle(_frame(200, seed=1)), as_handle(_frame(200, seed=2))]
    assert get_store().stats.spills >= 1
    assert any(tmp_path.rglob("blk*.npz"))
    reset_store()
    assert not any(tmp_path.rglob("blk*.npz"))
    del keep


def test_handle_gc_deletes_spill_file(fresh_store, monkeypatch, tmp_path):
    import gc
    f = _frame(200)
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(f.nbytes() + 16))
    reset_store()
    h1 = as_handle(_frame(200, seed=1))
    h2 = as_handle(_frame(200, seed=2))
    assert not h1.is_resident
    files = list(tmp_path.rglob("blk*.npz"))
    assert files
    del h1
    gc.collect()
    assert not any(p.exists() for p in files)


def test_finalizer_under_store_lock_does_not_deadlock(fresh_store, tmp_path):
    """A dead handle's finalizer (_reap) takes the store lock — and the
    cyclic GC can run it on a thread that is ALREADY inside a locked store
    section (any allocation can trigger a collection).  With a non-reentrant
    lock that is a self-deadlock that froze the whole multi-tenant service
    (every store user piles up behind the stuck thread)."""
    import gc
    import threading
    from repro.core.store import BlockStore

    store = BlockStore(10**6, str(tmp_path))
    holder = [None]
    holder[0] = store.put(_frame(64))      # cycle: only the gc collects it,
    holder.append(holder)                  # so the finalizer runs IN the gc
    del holder
    gc.collect()                           # clear unrelated garbage first

    class Cyc:
        pass

    c = Cyc()
    c.h = store.put(_frame(64, seed=1))
    c.self = c
    del c
    gc.disable()                           # keep the dead cycle pending
    try:
        done = []

        def inside():
            with store._lock:              # a mid-operation store section
                gc.collect()               # runs _reap -> store lock again
            done.append(True)

        t = threading.Thread(target=inside, daemon=True)
        t.start()
        t.join(10)
        assert done, "finalizer deadlocked against the held store lock"
    finally:
        gc.enable()
    store.shutdown()


def test_configure_same_settings_is_nondestructive(fresh_store, monkeypatch):
    """Re-configuring with the current budget must NOT reset the store —
    a second Session(mem_budget_bytes=N) would otherwise delete the first
    session's spill files."""
    from repro.core import store as st_mod
    f = _frame(200)
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(f.nbytes() + 16))
    reset_store()
    before = get_store()
    h1 = as_handle(_frame(200, seed=1))
    h2 = as_handle(_frame(200, seed=2))   # spills h1
    assert not h1.is_resident
    same = st_mod.configure(budget_bytes=f.nbytes() + 16)
    assert same is before                  # no reset
    assert h1.frame().to_pydict() == _frame(200, seed=1).to_pydict()
    # actually CHANGING the budget resets (documented destructive path):
    # a later fault of a previously spilled block fails loudly, not opaquely
    h2.frame()                             # spill h1 again
    assert not h1.is_resident
    st_mod.configure(budget_bytes=f.nbytes() + 32)
    with pytest.raises(RuntimeError, match="spill"):
        h1.frame()
    st_mod.unconfigure()                   # public undo of the sticky override
    assert get_store().budget == f.nbytes() + 16   # env knob visible again


def test_wide_int64_survives_spill(fresh_store, monkeypatch):
    big = np.asarray([2 ** 53 + 1, 2 ** 53 + 2, 5], dtype=np.int64)
    f = Frame([Column(big, Domain.INT)], RangeLabels(3),
              labels_from_values(["w"]))
    monkeypatch.setenv("REPRO_MEM_BUDGET", "1")
    reset_store()
    h = as_handle(f)
    h2 = as_handle(_frame(50))            # evict the wide column
    assert not h.is_resident
    back = h.frame()
    # int64 host storage must come back as host numpy, not a jax array
    # (jnp.asarray would truncate through int32)
    assert isinstance(back.col("w").data, np.ndarray)
    assert back.col("w").data.dtype == np.int64
    assert back.to_pydict() == {"w": [2 ** 53 + 1, 2 ** 53 + 2, 5]}


# =============================================================================
# zero-copy planning over handles (no faults for untouched blocks)
# =============================================================================
def test_regroup_passthrough_never_faults(fresh_store, monkeypatch):
    one = _frame(100).nbytes()
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(2 * one + 16))
    reset_store()
    pf = PartitionedFrame([[as_handle(_frame(100, seed=i))] for i in range(4)])
    spilled = [h for row in pf.handles for h in row if not h.is_resident]
    assert spilled                         # budget forced some out
    st = get_store().stats
    faults0 = st.faults
    # identity regroup (same boundaries) + metadata queries: no faults
    same = pf.repartition(row_parts=4)
    assert same.row_sizes == pf.row_sizes
    assert pf.nbytes() == 4 * one
    assert pf.prefix(150).row_parts == 2
    assert st.faults == faults0
    # pass-through handles are forwarded, not copied
    assert same.handles[0][0] is pf.handles[0][0]


def test_union_is_metadata_only(fresh_store, monkeypatch):
    one = _frame(100).nbytes()
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(one + 16))
    reset_store()
    a = PartitionedFrame([[as_handle(_frame(100, seed=1))]])
    b = PartitionedFrame([[as_handle(_frame(100, seed=2))]])
    st = get_store().stats
    faults0 = st.faults
    store = {"a": a, "b": b}
    ex = Executor(store, optimize=False)
    out = ex.evaluate(alg.Union(alg.Source("a", 100, 3),
                                alg.Source("b", 100, 3)))
    assert out.nrows == 200
    assert st.faults == faults0            # union itself faulted nothing


# =============================================================================
# equivalence sweep: grids {1, W, 4W} × budget {0, tiny}   (satellite)
# =============================================================================
def _pipeline_plan(src):
    from repro.core.algebra import Map, Selection, GroupBy, DropDuplicates, col, lit, Udf

    def scale(cols, frame):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * 2.0 + 1.0, Domain.FLOAT, c.mask, None)
        return out

    udf = Udf(name="store_sweep_scale", fn=scale, deps=frozenset(["x"]),
              elementwise=True)
    g = GroupBy(Selection(Map(src, udf), col("k") < lit(6)),
                ("k",), [("x", "sum", "x"), ("x", "count", "n")])
    return DropDuplicates(g, None)


@pytest.mark.parametrize("grid", [1, None, "4w"])
@pytest.mark.parametrize("fused", [True, False])
def test_budget_equivalence_sweep(grid, fused, fresh_store, monkeypatch):
    monkeypatch.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    w = schedule.pool_width()
    rp = {1: 1, None: w, "4w": 4 * w}[grid]
    frame = _frame(4000, seed=7)

    def run():
        pf = PartitionedFrame.from_frame(frame, row_parts=rp)
        ex = Executor({"f": pf}, optimize=fused)
        out = ex.evaluate(_pipeline_plan(alg.Source("f", 4000, 3)))
        return out.to_frame().to_pydict(), ex.stats

    monkeypatch.delenv("REPRO_MEM_BUDGET", raising=False)
    reset_store()
    ref, st_ref = run()
    assert st_ref.spills == 0 and st_ref.faults == 0

    monkeypatch.setenv("REPRO_MEM_BUDGET", str(max(frame.nbytes() // 4, 1)))
    reset_store()
    got, st = run()
    assert got == ref                       # bit-identical under the budget
    if rp > 1:
        assert st.spills > 0                # the budget actually engaged
    schedule.reset_pool()


# =============================================================================
# acceptance: pipeline over data 4× the budget (+ pandas oracle)
# =============================================================================
def _write_csv(path, n, seed=3):
    rng = np.random.default_rng(seed)
    k = rng.integers(0, 8, n)
    v = rng.integers(0, 50, n)
    x = rng.integers(0, 12, n) * 0.25
    s = rng.integers(0, 12, n)
    with open(path, "w") as f:
        f.write("k,v,x,s\n")
        for i in range(n):
            f.write(f"{k[i]},{v[i]},{x[i]},s{s[i]:02d}\n")


def test_outofcore_pipeline_4x_budget(fresh_store, monkeypatch, tmp_path):
    pd = pytest.importorskip("pandas")
    monkeypatch.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    csv = tmp_path / "big.csv"
    _write_csv(csv, 30_000)

    def run():
        s = set_session(Session(mode=EvalMode.LAZY))
        try:
            df = read_csv(str(csv))
            df["y"] = df["x"] * 2.0 + 1.0
            out = (df[df["v"] > 10].groupby("k")
                   .agg({"y": "sum", "x": "mean"}).drop_duplicates())
            res = out.collect().to_pydict()
            total = s.frames["frame_0"].nbytes()
            # snapshot while the frames are live: _handles is a WeakSet, and
            # close() vacates the default-session slot, so the handles are
            # collectable afterwards
            biggest = max((h.nbytes for h in get_store()._handles), default=0)
            return res, total, s.executor.stats, biggest
        finally:
            s.close()

    monkeypatch.delenv("REPRO_MEM_BUDGET", raising=False)
    reset_store()
    ref, total, st0, _ = run()
    assert st0.spills == 0 and st0.peak_resident_bytes == 0

    budget = total // 4                    # data is 4× the budget
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(budget))
    reset_store()
    got, _, st, ingest_block = run()

    # bit-identical to the unbudgeted run
    assert got == ref
    # residency counters: the budget engaged, and the peak held the bound
    assert st.spills > 0 and st.faults > 0 and st.spilled_bytes > 0
    store_stats = get_store().stats
    assert store_stats.spills > 0
    one_block = schedule.budget_max_block_bytes()
    assert ingest_block > 0
    assert store_stats.peak_resident_bytes <= budget + max(one_block,
                                                           ingest_block)

    # pandas oracle on the same file + pipeline
    pdf = pd.read_csv(csv)
    pdf["y"] = pdf["x"] * 2.0 + 1.0
    g = (pdf[pdf["v"] > 10].groupby("k", as_index=False)
         .agg(y=("y", "sum"), x=("x", "mean")))
    np.testing.assert_array_equal(np.asarray(got["k"]), g["k"].to_numpy())
    np.testing.assert_allclose(np.asarray(got["y"]), g["y"].to_numpy(),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got["x"]), g["x"].to_numpy(),
                               rtol=1e-5)
    schedule.reset_pool()


def test_read_csv_larger_than_budget_streams_to_spill(fresh_store,
                                                      monkeypatch, tmp_path):
    """A CSV bigger than the budget must ingest into a spill-backed
    PartitionedFrame without ever holding the whole file resident."""
    monkeypatch.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    csv = tmp_path / "huge.csv"
    _write_csv(csv, 20_000)
    monkeypatch.setenv("REPRO_MEM_BUDGET", "40000")   # ≪ device payload
    reset_store()
    s = set_session(Session(mode=EvalMode.LAZY))
    try:
        df = read_csv(str(csv))
        pf = s.frames["frame_0"]
        st = get_store().stats
        assert pf.nbytes() > 40000
        assert st.spills > 0                       # ingest spilled en route
        assert st.peak_resident_bytes <= 40000 + max(
            h.nbytes for h in get_store()._handles)
        assert not all(h.is_resident for row in pf.handles for h in row)
        # and the data still reads back correctly (faulting on demand)
        assert len(df) == 20_000
        got = df[["k"]].collect().to_pydict()["k"][:5]
        import pandas as pd_mod
        assert got == pd_mod.read_csv(csv)["k"].tolist()[:5]
    except ImportError:
        pass
    finally:
        s.close()
    schedule.reset_pool()


# =============================================================================
# executor attribution + shared budget
# =============================================================================
def test_execstats_attribution_and_shared_budget(fresh_store, monkeypatch):
    monkeypatch.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    frame = _frame(4000, seed=9)
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(max(frame.nbytes() // 3, 1)))
    reset_store()
    pf = PartitionedFrame.from_frame(frame, row_parts=8)
    ex = Executor({"f": pf}, optimize=True)
    plan = _pipeline_plan(alg.Source("f", 4000, 3))
    out1 = ex.evaluate(plan)
    assert ex.stats.faults > 0             # spilled source blocks faulted
    assert ex.stats.peak_resident_bytes > 0
    assert ex.stats.peak_resident_bytes <= get_store().stats.peak_resident_bytes
    # the cached result's handles carry the entry's benefit density, so the
    # store's eviction ranks them above plain working blocks
    key = ex._prepared(plan).cache_key()
    ent = ex.cache[key]
    assert ent.benefit_density() > 0
    for row in ent.result.handles:
        for h in row:
            if h.is_tracked:
                assert h.benefit >= ent.benefit_density() * 0.99
    # re-evaluation is a cache hit and faults at most the cached result
    out2 = ex.evaluate(plan)
    assert ex.stats.cache_hits >= 1
    assert out2.to_frame().to_pydict() == out1.to_frame().to_pydict()
    schedule.reset_pool()


def test_residency_aware_dispatch_order(fresh_store, monkeypatch):
    """Resident blocks run before spilled ones; results stay in block
    order.  A 1-worker pool makes the execution order deterministic."""
    monkeypatch.setenv("REPRO_POOL_WORKERS", "1")
    schedule.reset_pool()
    one = _frame(100).nbytes()
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(2 * one + 16))
    reset_store()
    try:
        handles = [as_handle(_frame(100, seed=i)) for i in range(4)]
        spilled_idx = {i for i, h in enumerate(handles) if not h.is_resident}
        assert spilled_idx                     # some spilled
        seen = []

        def probe(h):
            seen.append(h)
            return resolve(h).nrows

        out = schedule.dispatch_blocks(probe, handles)
        assert out == [100] * 4                # block order restored
        ranks = [1 if handles.index(h) in spilled_idx else 0 for h in seen]
        assert ranks == sorted(ranks)          # residents first
    finally:
        schedule.reset_pool()
