"""Statement tracing & metrics layer (observability PR).

The tracer must be invisible when off (the autouse conftest guard watches
``trace.recorded_total()`` in every OTHER test of the suite) and exact when
on: span parenting survives pool-thread hops, retries/cancellation/shutdown
never leak open spans, the Chrome export validates against the trace-event
schema, and per-statement counter deltas attached to spans sum exactly to
the global ``ExecStats`` movement — even under a seeded chaos plan with a
4x-over-budget spill pipeline.
"""
import dataclasses
import json
import threading
import time

import numpy as np
import pytest

from repro.core import EvalMode, Session
from repro.core import algebra as alg
from repro.core import faults, schedule, trace
from repro.core.algebra import GroupBy, Map, Selection, Udf, col, lit
from repro.core.dtypes import Domain
from repro.core.executor import ExecStats
from repro.core.faults import StatementCancelled
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.service import QueryService

pytestmark = pytest.mark.trace

_DELTA_KEYS = ("spills", "faults", "spilled_bytes", "checksum_failures",
               "recomputed_blocks", "budget_overruns", "faults_injected")


@pytest.fixture(autouse=True)
def clean_trace(monkeypatch):
    """Isolate the process tracer state around every test here."""
    for knob in ("REPRO_TRACE", "REPRO_TRACE_RING", "REPRO_FAULT_PLAN",
                 "REPRO_FAULT_SEED", "REPRO_MEM_BUDGET"):
        monkeypatch.delenv(knob, raising=False)
    trace.reset()
    faults.reset()
    yield monkeypatch
    trace.reset()
    faults.reset()
    schedule.reset_pool()


def _frame(n=120, seed=0):
    rng = np.random.default_rng(seed)
    return Frame(
        [Column(np.asarray(rng.integers(0, 8, n, dtype=np.int32)), Domain.INT),
         Column(np.asarray((rng.integers(0, 12, n) * np.float32(0.25))
                           .astype(np.float32)), Domain.FLOAT)],
        RangeLabels(n), labels_from_values(["k", "x"]))


def _plan(src, name="trace_scale"):
    def fn(cols, frame):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * 2.0 + 1.0, Domain.FLOAT, c.mask, None)
        return out

    udf = Udf(name=name, fn=fn, deps=frozenset(["x"]), elementwise=True)
    return GroupBy(Selection(Map(src, udf), col("k") < lit(6)),
                   ("k",), [("x", "sum", "xs"), ("x", "count", "n")])


def _slow_plan(src, delay_s, started=None, release=None, name="trace_slow"):
    def fn(cols, frame):
        if started is not None:
            started.set()
        if release is not None:
            release.wait(10.0)
        time.sleep(delay_s)
        return dict(cols)

    return Map(src, Udf(name=name, fn=fn, deps=frozenset(["x"]),
                        elementwise=True))


def _drain_open(tr, timeout=10.0):
    """Unwinding worker threads close their spans asynchronously."""
    deadline = time.monotonic() + timeout
    while tr.open_spans() and time.monotonic() < deadline:
        time.sleep(0.01)
    return tr.open_spans()


# =============================================================================
# disabled path: a true no-op
# =============================================================================
def test_disabled_records_nothing():
    before = trace.recorded_total()
    assert trace.current() is None
    s = Session(mode=EvalMode.LAZY)
    try:
        src = s.register_frame(_frame(200, seed=1), row_parts=4)
        assert s.collect(_plan(src)).nrows > 0
        assert s.tracer is None
        assert s.explain_stats()["traced"] is False
    finally:
        s.close()
    assert trace.recorded_total() == before


def test_session_trace_false_forces_off(clean_trace):
    clean_trace.setenv("REPRO_TRACE", "1")
    trace.reset()
    assert isinstance(trace.current(), trace.Tracer)   # process tracer on
    s = Session(mode=EvalMode.LAZY, trace=False)
    try:
        assert s.tracer is None                        # session forced off
    finally:
        s.close()


# =============================================================================
# span parenting: plan → dispatch → pool-thread chunks
# =============================================================================
def test_span_parenting_across_pool_threads(clean_trace):
    clean_trace.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    tr = trace.Tracer(session_id="t")
    trace.configure(tr)
    try:
        with schedule.node_scope("parenting"):
            out = schedule.dispatch_blocks(lambda x: x * 2, list(range(16)))
        assert out == [i * 2 for i in range(16)]
    finally:
        trace.reset()
    spans = tr.snapshot()
    disp = [s for s in spans if s.cat == "dispatch"]
    chunks = [s for s in spans if s.cat == "task"]
    assert len(disp) == 1 and chunks
    assert disp[0].args["blocks"] == 16
    assert disp[0].args["chunks"] == len(chunks)
    for c in chunks:
        assert c.parent == disp[0].id            # carried via propagate()
        assert c.stmt == disp[0].stmt
    assert {c.tid for c in chunks} != {disp[0].tid}   # crossed threads
    assert sum(c.args["blocks"] for c in chunks) == 16
    assert tr.open_spans() == 0


def test_failed_chunk_split_retry_records_backoff_spans(clean_trace):
    clean_trace.setenv("REPRO_POOL_WORKERS", "2")
    clean_trace.setenv("REPRO_RETRY_BACKOFF_MS", "1")
    schedule.reset_pool()
    ref = schedule.dispatch_blocks(lambda x: x * 2, list(range(16)))
    clean_trace.setenv("REPRO_FAULT_PLAN", "worker:0.5")
    clean_trace.setenv("REPRO_FAULT_SEED", "3")
    tr = trace.Tracer(session_id="t")
    trace.configure(tr)
    st = ExecStats()
    try:
        got = schedule.dispatch_blocks(lambda x: x * 2, list(range(16)),
                                       stats=st)
    finally:
        trace.reset()
    assert got == ref                            # chaos recovered, identical
    assert st.retries > 0
    retries = [s for s in tr.snapshot() if s.cat == "retry"]
    assert len(retries) == st.retries            # one backoff span per retry
    stmts = {s.stmt for s in tr.snapshot()}
    assert len(stmts) == 1                       # all under one statement
    for r in retries:
        assert r.args["attempt"] >= 1 and "block" in r.args
    assert tr.open_spans() == 0


# =============================================================================
# cancellation / shutdown: spans never leak open
# =============================================================================
def test_cancellation_closes_open_spans():
    s = Session(mode=EvalMode.LAZY, trace=True)
    tr = s.tracer
    try:
        started = threading.Event()
        src = s.register_frame(_frame(64, seed=4), row_parts=8)
        h = s.submit(_slow_plan(src, 0.15, started=started,
                                name="trace_cancel"))
        assert started.wait(5.0)
        h.cancel()
        with pytest.raises(StatementCancelled):
            h.result(timeout=10.0)
        assert _drain_open(tr) == 0
        errs = [sp for sp in tr.snapshot()
                if sp.args and "error" in sp.args]
        assert any("Cancel" in sp.args["error"] for sp in errs)
    finally:
        s.close()


def test_executor_shutdown_mid_statement_closes_spans():
    s = Session(mode=EvalMode.LAZY, trace=True)
    tr = s.tracer
    started, release = threading.Event(), threading.Event()
    src = s.register_frame(_frame(48, seed=6), row_parts=4)
    s.submit(_slow_plan(src, 0.0, started=started, release=release,
                        name="trace_close"))
    assert started.wait(5.0)
    try:
        s.close()                                # shutdown under the statement
    finally:
        release.set()
    assert _drain_open(tr) == 0                  # every span closed on unwind


# =============================================================================
# profile / explain surfaces
# =============================================================================
def test_statement_profile_and_explain_stats():
    s = Session(mode=EvalMode.LAZY, trace=True)
    try:
        src = s.register_frame(_frame(300, seed=7), row_parts=4)
        h = s.submit(_plan(src, name="trace_prof"))
        h.result(timeout=30.0)
        prof = h.profile()
        assert prof is not None and prof["stmt"] == h.stmt_id
        assert prof["wall_ns"] > 0 and prof["spans"] > 0
        assert prof["nodes"]                     # per-node attribution
        assert prof["dispatch"]["dispatched_blocks"] > 0
        ex = s.explain_stats(h.stmt_id)
        assert ex["traced"] is True
        assert ex["profile"]["stmt"] == h.stmt_id
        assert ex["stats"]["metrics"]["evaluated_nodes"] > 0
        assert ex["stats"]["metrics"]["node_wall_ns"] > 0
        # timing counters move even with tracing off (always-on ExecStats)
        assert s.stats.plan_prep_ns >= 0
    finally:
        s.close()


@pytest.mark.spill
def test_counter_deltas_sum_exactly_under_chaos(tmp_path):
    import repro.core.api as api
    n = 50_000
    data = {"a": np.arange(n, dtype=np.float64),
            "b": (np.arange(n) % 97).astype(np.float64)}
    s = Session(mode=EvalMode.LAZY, trace=True, mem_budget_bytes=n * 8 // 2,
                spill_dir=str(tmp_path),
                fault_plan="worker:0.2,corrupt:0.5,enospc:0.5", fault_seed=7)
    try:
        df = api.from_pydict(data, session=s)
        q = df[df["a"] > 1000.0].groupby("b").agg({"a": ["sum", "mean"]})
        st0 = dataclasses.replace(s.stats)
        q.collect()
        st1, tr = s.stats, s.tracer
        assert tr.open_spans() == 0
        totals = tr.counter_totals(tr.last_stmt)
        for k in _DELTA_KEYS:
            assert totals.get(k, 0) == getattr(st1, k) - getattr(st0, k), k
    finally:
        s.close()
    leftovers = [p for p in tmp_path.rglob("*") if p.is_file()]
    assert leftovers == []                       # zero leaked spill files


# =============================================================================
# Chrome trace export
# =============================================================================
def test_chrome_export_validates_and_names_threads(clean_trace, tmp_path):
    clean_trace.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    s = Session(mode=EvalMode.LAZY, trace=True)
    try:
        src = s.register_frame(_frame(300, seed=8), row_parts=4)
        assert s.collect(_plan(src, name="trace_export")).nrows > 0
        path = s.trace_json(str(tmp_path / "trace.json"))
        doc = json.load(open(path))
    finally:
        s.close()
    n = trace.validate_chrome_trace(doc)
    assert n == len(doc["traceEvents"]) and n > 0
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert "X" in phases and "M" in phases       # spans + thread names
    names = [e["args"]["name"] for e in doc["traceEvents"] if e["ph"] == "M"]
    assert names and all(isinstance(x, str) and x for x in names)
    durs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert all(isinstance(e["ts"], (int, float)) and e["dur"] >= 0
               for e in durs)


def test_chrome_validation_rejects_malformed():
    with pytest.raises(ValueError):
        trace.validate_chrome_trace({"no": "events"})
    with pytest.raises(ValueError):
        trace.validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "x"}]})   # missing ts/dur
    with pytest.raises(ValueError):
        trace.validate_chrome_trace(
            {"traceEvents": [{"ph": "?", "name": "x", "pid": 1, "tid": 1,
                              "ts": 0}]})                  # unknown phase


def test_ring_buffer_bounds_retention():
    tr = trace.Tracer(ring=4, session_id="ring")
    before = trace.recorded_total()
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr.snapshot()) == 4               # bounded retention
    assert trace.recorded_total() == before + 10  # but every record counted
    assert tr.open_spans() == 0


# =============================================================================
# metrics registry (shared shape: core ExecStats + serve engine)
# =============================================================================
def test_metrics_registry_shape_and_serve_unification():
    m = trace.Metrics("m", steps=0)
    m.inc("steps")
    m["tokens_out"] = 3
    m.gauge("depth", 7)
    assert m["steps"] == 1 and m["missing"] == 0
    assert dict(m) == {"steps": 1, "tokens_out": 3, "depth": 7}
    exp = m.export()
    assert exp["name"] == "m" and exp["metrics"]["tokens_out"] == 3

    st = ExecStats()
    st.evaluated_nodes = 5
    proj = trace.stats_metrics(st)
    assert set(proj.export()) == set(exp)        # ONE export shape
    assert proj["evaluated_nodes"] == 5

    from repro.serve import engine as serve_engine
    assert serve_engine.Metrics is trace.Metrics  # serve tier unified


# =============================================================================
# service: admission phases + per-tenant attribution
# =============================================================================
def test_service_tenant_report_and_admission_phases():
    with QueryService(background_workers=2) as svc:
        busy = svc.session(mode=EvalMode.LAZY)
        idle = svc.session(mode=EvalMode.LAZY)
        src = busy.register_frame(_frame(400, seed=9), row_parts=4)
        busy.submit(_plan(src, name="trace_tenant")).result(timeout=30.0)
        rows = svc.tenant_report()
        assert len(rows) == 2
        by_sid = {r["session"]: r for r in rows}
        bid = busy.config.session_id
        assert by_sid[bid]["evaluated_nodes"] > 0
        assert by_sid[bid]["node_wall_ns"] > 0
        assert by_sid[bid]["slot_hold_ns"] > 0   # admission slot was held
        assert by_sid[bid]["queue_wait_ns"] >= 0
        assert by_sid[idle.config.session_id]["evaluated_nodes"] == 0
        assert rows[0]["session"] == bid         # pool-pressure sort
        # the per-tenant gauges sum to the service-global timing counters
        assert sum(r["slot_hold_ns"] for r in rows) == svc.stats.slot_hold_ns
        assert sum(r["node_wall_ns"] for r in rows) == svc.stats.node_wall_ns


def test_service_traced_statement_records_admission_spans():
    with QueryService(background_workers=2) as svc:
        tr = trace.Tracer(session_id="tenant")
        s = svc.session(mode=EvalMode.LAZY, trace=tr)
        src = s.register_frame(_frame(200, seed=11), row_parts=4)
        h = s.submit(_plan(src, name="trace_admit"))
        h.result(timeout=30.0)
        assert _drain_open(tr) == 0
        names = {sp.name for sp in tr.snapshot()}
        assert "queue_wait" in names and "slot_hold" in names
        prof = tr.profile(h.stmt_id)
        assert prof["service"]["slot_hold_ns"] > 0
