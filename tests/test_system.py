"""End-to-end behaviour: the paper's Figure-1 workflow (data ingest →
cleaning → wrangling → analysis) through the pandas-flavoured API, plus the
Fig.-6 operator mix at a partitioned scale."""
import numpy as np
import pytest

from repro.core import DataFrame, EvalMode, Session, get_dummies, set_session
from repro.data.synthetic import taxi_like_frame


@pytest.fixture
def sess():
    s = set_session(Session(mode=EvalMode.EAGER, default_row_parts=2))
    yield s
    s.close()


def test_figure1_workflow_end_to_end(sess):
    # In[1]: ingest (scraped table: products as columns)
    products = DataFrame({
        "iPhone 11 Pro": ["5.8-inch", "12MP", "120MP", "Yes"],
        "iPhone 11 Pro Max": ["6.5-inch", "12MP", "12MP", "Yes"],
        "iPhone XR": ["6.1-inch", "12MP", "7MP", "No"],
        "iPhone 8 Plus": ["5.5-inch", "12MP", "7MP", "No"],
    }, row_labels=["Display", "Camera", "Front Camera", "Wireless Charging"])

    # C1: ordered point update fixes the 120MP data-entry error
    products.iloc[2, 0] = "12MP"
    assert products.iloc[2, 0] == "12MP"

    # C2: matrix-like transpose → products become rows
    pt = products.T
    f = pt.collect()
    assert f.row_labels.to_list()[0] == "iPhone 11 Pro"
    assert f.col_labels.to_list() == ["Display", "Camera", "Front Camera",
                                      "Wireless Charging"]

    # C3: column transformation via map (Yes/No → 1/0); S(·) induces int
    pt["Wireless Charging"] = pt["Wireless Charging"].map(
        lambda v: 1 if v == "Yes" else 0)
    f = pt.collect().induce()
    assert f.col("Wireless Charging").to_pylist() == [1, 1, 0, 0]
    assert f.schema[-1].value == "int"

    # C4: second dataset (prices/ratings)
    prices = DataFrame({
        "model": ["iPhone 11 Pro", "iPhone 11 Pro Max", "iPhone XR",
                  "iPhone 8 Plus"],
        "price": [999, 1099, 599, 449],
        "rating": [4.5, 4.6, 4.4, 4.3],
    })

    # A1: one-hot encode the categorical Display column
    one_hot = get_dummies(pt.reset_index("model"), ["Display"])
    assert any(c.startswith("Display_") for c in one_hot.columns)

    # A2: join on model names
    joined = one_hot.merge(prices, on="model")
    assert joined.shape[0] == 4

    # A3: covariance over the numeric (matrix) sub-frame
    num = joined[[c for c in joined.columns
                  if c not in ("model", "Camera", "Front Camera")]]
    cov = num.cov()
    assert cov.shape[0] == cov.shape[1] == num.shape[1]
    mat, _ = cov.as_matrix()
    np.testing.assert_allclose(np.asarray(mat), np.asarray(mat).T, atol=1e-4)


def test_fig6_operator_mix_partitioned(sess):
    frame = taxi_like_frame(20_000, seed=1)
    df = DataFrame(frame)

    # map: null-scrub over the float columns
    filled = df.fillna(0.0)
    assert filled.shape == (20_000, 8)

    # groupby(n)
    g = df.groupby("passenger_count").count().collect()
    assert g.nrows <= 6
    assert sum(g.col("payment_type").to_pylist()) == 20_000

    # groupby(1)
    total = df["f0"].count()
    assert 19_000 < total <= 20_000  # ~1% nulls

    # transpose on the numeric sub-frame + map (paper's transpose benchmark)
    num = df[[f"f{i}" for i in range(6)]]
    t = num.T
    back = t.T.collect()
    assert back.shape == (20_000, 6)
