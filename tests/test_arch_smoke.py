"""Per-architecture smoke tests (deliverable (f)): reduced config of the same
family — one forward/train step + one decode step on CPU, asserting output
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES, shape_applicable
from repro.models import build_model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.family == get_config(arch).family
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.cross_memory_len:
        batch["memory"] = jax.random.normal(
            key, (B, cfg.cross_memory_len, cfg.d_model)).astype(jnp.bfloat16)

    logits = model.forward(params, tokens, batch.get("memory"))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))

    # one SGD-flavoured step moves the loss
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert gn > 0, "gradients are identically zero"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B = 2
    memory = None
    if cfg.cross_memory_len:
        memory = jax.random.normal(
            key, (B, cfg.cross_memory_len, cfg.d_model)).astype(jnp.bfloat16)
    cache = model.cache_init(B, 32)
    tok = jax.random.randint(key, (B,), 0, cfg.vocab)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache, memory)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert int(cache["length"][0]) == 3


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forced_forward(arch):
    """Cache-path correctness: decoding token-by-token must reproduce the
    forward pass logits at every position (same params, same inputs).

    The heaviest equivalence sweep in the suite (token-by-token decode per
    architecture): excluded from the fast check.sh gate, still in tier-1 and
    ``check.sh --full``.  ``test_smoke_decode_step`` keeps every arch's decode
    path exercised in the fast gate."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, S = 2, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    memory = None
    if cfg.cross_memory_len:
        memory = jax.random.normal(
            key, (B, cfg.cross_memory_len, cfg.d_model)).astype(jnp.bfloat16)

    from repro.models import transformer
    enc_memory = memory
    if cfg.encoder_layers and memory is not None:
        enc_memory = transformer.encode(params["encoder"], memory, cfg)

    full = model.forward(params, tokens, memory)          # (B,S,V)

    cache = model.cache_init(B, S + 4)
    outs = []
    for i in range(S):
        logits, cache = model.decode_step(params, tokens[:, i], cache, enc_memory)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)                         # (B,S,V)

    diff = jnp.max(jnp.abs(dec - full))
    assert bool(jnp.isfinite(diff))
    assert float(diff) < 0.75, f"decode/forward divergence {float(diff)}"
    # top-1 agreement at (nearly) every position
    agree = jnp.mean((jnp.argmax(dec, -1) == jnp.argmax(full, -1)).astype(jnp.float32))
    assert float(agree) >= 0.9


def test_shape_applicability_matrix():
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, sh in SHAPES.items():
            ok, reason = shape_applicable(cfg, sh)
            rows.append((arch, sname, ok))
            if sname == "long_500k":
                assert ok == cfg.sub_quadratic, (arch, reason)
            else:
                assert ok
    assert len(rows) == 40  # the assigned 40 cells


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "granite-moe-3b-a800m"])
def test_moe_router_load_balance_loss_present(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    _, metrics = model.loss_fn(params, {"tokens": tokens, "labels": tokens,
                                        "mask": jnp.ones((B, S))})
    assert float(metrics["moe_aux"]) > 0
