"""Barrier fusion: row-local chains fused THROUGH blocking operators.

Invariants:
  * fused and unfused plans are **result-equivalent** (values, labels, null
    masks) for producer-into-GROUPBY, consumer-after-SORT/JOIN, and
    WINDOW-carry chains, over multi-block grids;
  * consumer fusion gathers strictly fewer payload rows than the unfused
    path on selective chains (``ExecStats.gather_rows``);
  * WINDOW carry composition at partition seams survives pre/post stage
    fusion (block boundaries are invisible in the result);
  * null masks propagate through fused selections exactly as per-node;
  * MQO: a sub-plan recorded in the session statement history splits the
    fused group so the materialization cache still serves the shared prefix;
  * counter invariant: ``fused_stage_ops`` == pipeline stage ops
    + ``producer_stage_ops`` + ``consumer_stage_ops`` (one source of truth);
  * jit-traced whole-chain map runs are adopted only when bit-identical to
    the eager path; host-numpy udf chains fall back and stay correct.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import algebra as alg
from repro.core import physical, rewrite
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.partition import PartitionedFrame
from repro.core.session import EvalMode, Session


def _mk_frame(n=211, with_nulls=True, seed=11):
    rng = np.random.default_rng(seed)
    k = rng.integers(0, 6, n).astype(object)
    v = rng.integers(-50, 50, n).astype(object)
    x = rng.standard_normal(n).astype(np.float32).astype(object)
    s = np.asarray([("a", "b", "c")[i % 3] for i in range(n)], dtype=object)
    if with_nulls:
        for arr, step in ((k, 17), (v, 13), (x, 7)):
            arr[::step] = None
    return Frame.from_pydict({
        "k": k.tolist(), "v": v.tolist(), "x": x.tolist(), "s": s.tolist(),
    }, row_labels=[f"r{i}" for i in range(n)])


def _scale_udf(name="x", a=2.0, b=1.0):
    def fn(cols, frame):
        out = dict(cols)
        c = cols[name]
        out[name] = Column(c.data * a + b, Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name=f"scale_{name}_{a}_{b}", fn=fn,
                   deps=frozenset([name]), elementwise=True)


def _both(plan, store):
    fused_ex = Executor(store, optimize=True)
    plain_ex = Executor(store, optimize=False)
    a = fused_ex.evaluate(plan).to_frame()
    b = plain_ex.evaluate(plan).to_frame()
    return a, b, fused_ex, plain_ex


def _assert_frames_equal(a: Frame, b: Frame):
    assert a.col_labels.to_list() == b.col_labels.to_list()
    assert a.row_labels.to_list() == b.row_labels.to_list()
    ad, bd = a.to_pydict(), b.to_pydict()
    for name in ad:
        av, bv = ad[name], bd[name]
        assert [x is None for x in av] == [x is None for x in bv], name
        fa = np.asarray([0 if x is None else x for x in av])
        fb = np.asarray([0 if x is None else x for x in bv])
        np.testing.assert_array_equal(fa, fb, err_msg=str(name))


# -----------------------------------------------------------------------------
# producer fusion into GROUPBY
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("row_parts", [1, 4, 7])
def test_producer_into_groupby_dense_int_key(row_parts):
    f = _mk_frame()
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=row_parts)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.GroupBy(
        alg.Selection(alg.Map(src, _scale_udf()), alg.col("v") > alg.lit(0)),
        ("k",),
        [("x", "sum", "xs"), ("x", "mean", "xm"), ("v", "min", "vmin"),
         ("v", "max", "vmax"), ("v", "count", "vc"), ("x", "std", "xstd")])
    a, b, fx, _ = _both(plan, store)
    assert fx.stats.barrier_fused_groups == 1
    assert fx.stats.producer_stage_ops == 2
    assert fx._prepared(plan).op == "fused_groupby"
    _assert_frames_equal(a, b)


def test_producer_into_groupby_under_pallas_kernels(use_pallas_kernels):
    # the combined partial program (kernels.ops.segment_reduce_multi) must
    # also lower through the Pallas kernels (interpret mode on CPU): the
    # dispatch mode is part of its jit cache key
    f = _mk_frame(120)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=3)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.GroupBy(alg.Selection(src, alg.col("v") > alg.lit(0)),
                       ("k",), [("x", "sum", "xs"), ("v", "max", "vx")])
    a, b, fx, _ = _both(plan, store)
    assert fx._prepared(plan).op == "fused_groupby"
    _assert_frames_equal(a, b)


def test_producer_into_groupby_string_key_general_path():
    # coded (string) key cannot take the dense-int path: the general
    # factorization must still run over the staged (fused) blocks
    f = _mk_frame()
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=5)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.GroupBy(
        alg.Selection(alg.Map(src, _scale_udf()), alg.col("v") > alg.lit(-10)),
        ("s",), [("x", "sum", "xs"), ("v", "mean", "vm")])
    a, b, fx, _ = _both(plan, store)
    assert fx.stats.barrier_fused_groups == 1
    _assert_frames_equal(a, b)


def test_producer_into_groupby_null_keys_dropped():
    # rows whose key is null must vanish from the aggregate either way
    f = _mk_frame(with_nulls=True)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=3)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.GroupBy(alg.Selection(src, alg.col("v") != alg.lit(3)),
                       ("k",), [("v", "sum", "vs")])
    a, b, fx, _ = _both(plan, store)
    assert fx._prepared(plan).op == "fused_groupby"   # lone op absorbed too
    _assert_frames_equal(a, b)


def test_producer_into_groupby_empty_selection():
    f = _mk_frame(64, with_nulls=False)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=3)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.GroupBy(alg.Selection(src, alg.col("v") > alg.lit(10 ** 6)),
                       ("k",), [("v", "sum", "vs")])
    a, b, _, _ = _both(plan, store)
    assert a.nrows == b.nrows == 0


# -----------------------------------------------------------------------------
# consumer fusion after SORT / JOIN
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("row_parts", [1, 4])
def test_consumer_after_sort_filters_index_before_gather(row_parts):
    f = _mk_frame()
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=row_parts)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.Projection(
        alg.Selection(alg.Sort(src, ("v",)), alg.col("v") > alg.lit(5)),
        ("k", "v"))
    a, b, fx, px = _both(plan, store)
    assert fx._prepared(plan).op == "fused_sort"
    # THE consumer-fusion win: strictly fewer payload rows gathered
    assert 0 < fx.stats.gather_rows < px.stats.gather_rows
    assert px.stats.gather_rows == f.nrows
    _assert_frames_equal(a, b)


def test_consumer_after_sort_with_trailing_map():
    f = _mk_frame()
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=3)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.Map(
        alg.Selection(alg.Sort(src, ("v",), ascending=False),
                      alg.col("x").notna()),
        _scale_udf())
    a, b, fx, _ = _both(plan, store)
    assert fx._prepared(plan).op == "fused_sort"
    _assert_frames_equal(a, b)


@pytest.mark.parametrize("how", ["inner", "left", "outer"])
def test_consumer_after_join_filters_match_index(how):
    f = _mk_frame(97)
    g = Frame.from_pydict({"k": [0, 1, 2, 3, 9],
                           "w": [10.0, None, 30.0, 40.0, 50.0]})
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=3),
             "f1": PartitionedFrame.from_frame(g)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    src2 = alg.Source("f1", nrows=g.nrows, ncols=g.ncols)
    plan = alg.Selection(alg.Join(src, src2, on=("k",), how=how),
                         alg.col("w") > alg.lit(15.0))
    a, b, fx, px = _both(plan, store)
    assert fx._prepared(plan).op == "fused_join"
    assert fx.stats.gather_rows < px.stats.gather_rows
    _assert_frames_equal(a, b)


def test_consumer_after_join_projection_prunes_gather():
    f = _mk_frame(80, with_nulls=False)
    g = Frame.from_pydict({"k": [0, 1, 2], "w": [1.0, 2.0, 3.0]})
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=2),
             "f1": PartitionedFrame.from_frame(g)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    src2 = alg.Source("f1", nrows=g.nrows, ncols=g.ncols)
    plan = alg.Projection(
        alg.Selection(alg.Join(src, src2, on=("k",), how="inner"),
                      alg.col("v") > alg.lit(0)),
        ("k", "w"))
    a, b, fx, _ = _both(plan, store)
    assert fx._prepared(plan).op == "fused_join"
    _assert_frames_equal(a, b)


# -----------------------------------------------------------------------------
# WINDOW stage fusion with carry composition at seams
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("func", ["cumsum", "cummax", "cummin", "cumprod"])
def test_window_scan_chain_seams(func):
    f = _mk_frame(150)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=6)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.Map(
        alg.Window(alg.Selection(src, alg.col("v") % alg.lit(3) != alg.lit(0)),
                   func, ("x",)),
        _scale_udf())
    a, b, fx, _ = _both(plan, store)
    prep = fx._prepared(plan)
    assert prep.op == "fused_window"
    assert [s.op for s in prep.pre_stages] == ["selection"]
    assert [s.op for s in prep.post_stages] == ["map"]
    _assert_frames_equal(a, b)


def test_window_seam_exactness_single_vs_many_blocks():
    # block boundaries must be invisible: the fused multi-block result equals
    # the single-block result row for row
    f = _mk_frame(120, with_nulls=False)
    src_cols = f.nrows, f.ncols
    plan_of = lambda src: alg.Map(
        alg.Window(alg.Selection(src, alg.col("v") > alg.lit(-100)),
                   "cumsum", ("x",)), _scale_udf())
    multi = {"f0": PartitionedFrame.from_frame(f, row_parts=8)}
    single = {"f0": PartitionedFrame.from_frame(f, row_parts=1)}
    src = alg.Source("f0", nrows=src_cols[0], ncols=src_cols[1])
    a = Executor(multi, optimize=True).evaluate(plan_of(src)).to_frame()
    b = Executor(single, optimize=True).evaluate(plan_of(src)).to_frame()
    ad = np.asarray(a.to_pydict()["x"], dtype=np.float32)
    bd = np.asarray(b.to_pydict()["x"], dtype=np.float32)
    np.testing.assert_allclose(ad, bd, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("func,size", [("diff", None), ("shift", None),
                                       ("rolling_sum", 8)])
def test_window_halo_and_rolling_chains(func, size):
    f = _mk_frame(100)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=4)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.Map(
        alg.Window(alg.Selection(src, alg.col("v").notna()), func, ("x",),
                   size=size, periods=2),
        _scale_udf())
    a, b, fx, _ = _both(plan, store)
    assert fx._prepared(plan).op == "fused_window"
    _assert_frames_equal(a, b)


def test_fused_window_stays_prefix_safe():
    # barrier-fusing a forward window must not disable §6.1.2 prefix
    # evaluation: head(k) on the fused plan still touches only a prefix
    f = _mk_frame(300, with_nulls=False)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=6)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.Map(alg.Window(alg.Selection(src, alg.col("v") > alg.lit(-200)),
                              "cumsum", ("x",)), _scale_udf())
    ex = Executor(store, optimize=True)
    assert ex._prepared(plan).op == "fused_window"
    got = ex.evaluate_prefix(plan, 4).to_frame().head(4).to_pydict()
    assert ex.stats.prefix_evals == 1, "fused window fell back to full eval"
    want = Executor(store, optimize=False).evaluate(plan).to_frame().head(4).to_pydict()
    np.testing.assert_allclose(np.asarray(got["x"], dtype=np.float32),
                               np.asarray(want["x"], dtype=np.float32), rtol=1e-5)


# -----------------------------------------------------------------------------
# MQO-aware fusion boundaries (session statement history)
# -----------------------------------------------------------------------------
def test_history_splits_fused_group_and_reuses_cache():
    f = _mk_frame(128, with_nulls=False)
    sess = Session(mode=EvalMode.LAZY)
    src = sess.register_frame(PartitionedFrame.from_frame(f, row_parts=3))

    shared = alg.Selection(alg.Map(src, _scale_udf()), alg.col("v") > alg.lit(0))
    sess.statement(shared)
    r_shared = sess.collect(shared)

    plan = alg.GroupBy(shared, ("k",), [("x", "sum", "xs")])
    prep = sess.executor._prepared(plan)
    # the shared prefix is NOT absorbed into the groupby: split at history
    assert prep.op == "groupby"
    assert prep.children[0].op == "fused_pipeline"
    hits = sess.executor.stats.cache_hits
    out = sess.collect(plan)
    assert sess.executor.stats.cache_hits > hits   # prefix served from cache

    # a fresh session with no history fuses straight through
    sess2 = Session(mode=EvalMode.LAZY)
    src2 = sess2.register_frame(PartitionedFrame.from_frame(f, row_parts=3))
    shared2 = alg.Selection(alg.Map(src2, _scale_udf()), alg.col("v") > alg.lit(0))
    plan2 = alg.GroupBy(shared2, ("k",), [("x", "sum", "xs")])
    assert sess2.executor._prepared(plan2).op == "fused_groupby"
    # and both strategies agree on the result
    out2 = sess2.collect(plan2)
    _assert_frames_equal(out, out2)
    sess.close()
    sess2.close()


def test_resubmitting_same_statement_reproduces_fused_key():
    # a statement must never act as a fusion barrier against itself: the
    # second submission re-fuses to the identical plan and hits the cache
    f = _mk_frame(90, with_nulls=False)
    sess = Session(mode=EvalMode.EAGER)
    src = sess.register_frame(PartitionedFrame.from_frame(f, row_parts=2))
    plan = alg.GroupBy(alg.Selection(src, alg.col("v") > alg.lit(0)),
                       ("k",), [("v", "sum", "vs")])
    sess.statement(plan)
    evaluated = sess.executor.stats.evaluated_nodes
    sess.statement(plan)
    assert sess.executor.stats.evaluated_nodes == evaluated  # pure cache hit
    sess.close()


# -----------------------------------------------------------------------------
# counters: one source of truth
# -----------------------------------------------------------------------------
def test_counter_invariant_across_mixed_plan():
    f = _mk_frame(96, with_nulls=False)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=2)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    g = alg.GroupBy(alg.Selection(alg.Map(src, _scale_udf()),
                                  alg.col("v") > alg.lit(0)),
                    ("k",), [("x", "sum", "xs")])
    plan = alg.Rename(alg.Selection(g, alg.col("xs") > alg.lit(0.0)),
                      {"xs": "total"})
    out, fs = rewrite.fuse_pipelines(plan)
    pipeline_ops = sum(len(n.params["stages"]) for n in out.walk()
                      if n.op == "fused_pipeline")
    assert fs.fused_ops == pipeline_ops + fs.producer_ops + fs.consumer_ops
    assert fs.barrier_groups == 1 and fs.producer_ops == 2
    assert fs.groups == 1   # the consumer chain above the groupby

    ex = Executor(store, optimize=True)
    ex.evaluate(plan)
    assert ex.stats.fused_stage_ops == (
        pipeline_ops + ex.stats.producer_stage_ops + ex.stats.consumer_stage_ops)


def test_shared_blocking_node_not_absorbed():
    # two consumers of one SORT: absorbing it into either chain would
    # re-execute the sort per branch
    f = _mk_frame(60, with_nulls=False)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=2)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    srt = alg.Sort(src, ("v",))
    b1 = alg.Projection(alg.Selection(srt, alg.col("v") > alg.lit(0)), ("v",))
    b2 = alg.Projection(alg.Selection(srt, alg.col("v") < alg.lit(0)), ("v",))
    plan = alg.Union(b1, b2)
    out, fs = rewrite.fuse_pipelines(plan)
    assert fs.barrier_groups == 0
    assert sum(1 for n in out.walk() if n.op == "sort") == 1
    a, b, _, _ = _both(plan, store)
    _assert_frames_equal(a, b)


# -----------------------------------------------------------------------------
# jit-traced whole-chain map runs
# -----------------------------------------------------------------------------
def test_map_run_jit_adopted_and_bit_identical(monkeypatch):
    monkeypatch.setenv("REPRO_JIT_UDFS", "1")   # CPU defaults to eager
    physical._MAP_JIT.clear()
    f = Frame.from_pydict({"a": [1.5, 2.5, 3.5, 4.5, 5.5, 6.5],
                           "b": [1, 2, 3, 4, 5, 6]})
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=3)}
    src = alg.Source("f0", nrows=6, ncols=2)
    u1 = alg.Udf(name="jit1", elementwise=True, fn=lambda c, fr: {
        "a": Column(c["a"].data + 1.0, Domain.FLOAT), "b": c["b"]})
    u2 = alg.Udf(name="jit2", elementwise=True, fn=lambda c, fr: {
        "a": Column(c["a"].data * 3.0, Domain.FLOAT), "b": c["b"]})
    plan = alg.Map(alg.Selection(alg.Map(src, u1), alg.col("a") > alg.lit(2.0)), u2)
    a, b, _, _ = _both(plan, store)
    _assert_frames_equal(a, b)
    assert any(v is not None for v in physical._MAP_JIT.values()), \
        "no map chain adopted a compiled program"


def test_map_run_host_numpy_falls_back(monkeypatch):
    monkeypatch.setenv("REPRO_JIT_UDFS", "1")
    physical._MAP_JIT.clear()
    f = Frame.from_pydict({"a": [1.5, 2.5, 3.5, 4.5]})
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=2)}
    src = alg.Source("f0", nrows=4, ncols=1)
    # np.asarray on a tracer raises → per-chain fallback to eager dispatch
    uh = alg.Udf(name="hostnp", elementwise=True, fn=lambda c, fr: {
        "a": Column(jnp.asarray(np.asarray(c["a"].data) ** 2), Domain.FLOAT)})
    plan = alg.Selection(alg.Map(src, uh), alg.col("a") > alg.lit(3.0))
    a, b, _, _ = _both(plan, store)
    _assert_frames_equal(a, b)
    keys = [k for k in physical._MAP_JIT
            if any(u[1] == "hostnp" for u in k[0])]
    assert keys and all(physical._MAP_JIT[k] is None for k in keys), \
        "host-numpy chain should be marked eager-only"
