import os
import sys

# src-layout import path (tests run with PYTHONPATH=src, but be robust)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    # scripts/check.sh runs `-m "not slow"` by default and the full suite in
    # --full mode; tier-1 verify (plain `pytest -x -q`) still runs everything
    config.addinivalue_line(
        "markers", "slow: long equivalence sweeps (excluded from the fast "
                   "check.sh gate; included in tier-1 and check.sh --full)")
    config.addinivalue_line(
        "markers", "spill: tests that intentionally run the block store "
                   "under a memory budget (exempt from the global "
                   "no-unexpected-spills guard)")
    config.addinivalue_line(
        "markers", "trace: tests that intentionally enable the statement "
                   "tracer (exempt from the global zero-spans guard)")


@pytest.fixture(autouse=True)
def _no_unexpected_spills(request):
    """Residency must never regress silently: with the default
    ``REPRO_MEM_BUDGET=0`` no test may cause a block spill.  Tests that
    budget the store on purpose opt out with ``@pytest.mark.spill``."""
    from repro.core.store import get_store
    st = get_store()
    before = st.stats.spills
    yield
    if request.node.get_closest_marker("spill") is None:
        from repro.core.store import get_store as _get
        cur = _get()
        after = cur.stats.spills if cur is st else 0
        assert after == before, (
            f"unexpected block-store spills during {request.node.nodeid}: "
            f"{after - before} (mark the test @pytest.mark.spill if "
            "budget-governed residency is intended)")


@pytest.fixture(autouse=True)
def _no_unexpected_spans(request):
    """The disabled path must be a true no-op: with tracing off (the test
    default) no span may be recorded anywhere in the process.  Tests that
    turn the tracer on opt out with ``@pytest.mark.trace``."""
    from repro.core import trace
    before = trace.recorded_total()
    yield
    if request.node.get_closest_marker("trace") is None:
        after = trace.recorded_total()
        assert after == before, (
            f"unexpected trace spans recorded during {request.node.nodeid}: "
            f"{after - before} (mark the test @pytest.mark.trace if tracing "
            "is intended)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def eager_session():
    """Fresh eager-mode session per test (pandas-semantics baseline)."""
    from repro.core import EvalMode, Session, set_session
    s = set_session(Session(mode=EvalMode.EAGER, default_row_parts=3))
    yield s
    s.close()


@pytest.fixture
def lazy_session():
    from repro.core import EvalMode, Session, set_session
    s = set_session(Session(mode=EvalMode.LAZY, default_row_parts=3))
    yield s
    s.close()


@pytest.fixture
def use_pallas_kernels(monkeypatch):
    """Force the Pallas kernels (interpret mode on CPU) for this test."""
    monkeypatch.setenv("REPRO_USE_KERNELS", "1")
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
