"""Sharding policy: spec construction rules (divisibility degradation, TP
pairing, EP/FSDP placement) — pure metadata, no device games."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as shlib
from repro.models import model as model_lib


class _FakeMesh:
    """Duck-typed mesh: only .shape and .axis_names are consulted."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})


def _specs_for(arch):
    cfg = get_config(arch)
    shapes = model_lib.params_specs(cfg)
    return cfg, shapes, shlib.param_specs(cfg, shapes, MESH)


def _flat(specs, shapes):
    fs = jax.tree_util.tree_flatten_with_path(shapes)[0]
    fp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    return [("/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path),
             leaf.shape, spec) for (path, leaf), spec in zip(fs, fp)]


def test_divisibility_everywhere():
    for arch in ("yi-6b", "qwen3-moe-235b-a22b", "whisper-base", "rwkv6-1.6b"):
        cfg, shapes, specs = _specs_for(arch)
        for name, shape, spec in _flat(specs, shapes):
            assert len(spec) <= len(shape), (name, shape, spec)
            for dim, entry in zip(shape, tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                size = 1
                for a in axes:
                    size *= MESH.shape[a]
                assert dim % size == 0, (arch, name, shape, spec)


def test_tp_pairing_dense():
    cfg, shapes, specs = _specs_for("yi-6b")
    flat = dict((n, (s, sp)) for n, s, sp in _flat(specs, shapes))
    wq = [v for k, v in flat.items() if k.endswith("attn/wq")][0]
    wo = [v for k, v in flat.items() if k.endswith("attn/wo")][0]
    # stacked over repeats: leading None, then (in,out)
    assert tuple(wq[1])[-1] == "model"       # column-parallel out
    assert tuple(wo[1])[-2] == "model"       # row-parallel in


def test_moe_expert_parallel():
    cfg, shapes, specs = _specs_for("qwen3-moe-235b-a22b")
    flat = dict((n, (s, sp)) for n, s, sp in _flat(specs, shapes))
    wg = [v for k, v in flat.items() if k.endswith("mlp/w_gate")][0]
    assert tuple(wg[1])[1] == "model"        # experts dim sharded (EP)


def test_fsdp_toggle_by_size():
    assert shlib.use_fsdp(get_config("qwen3-moe-235b-a22b"), MESH)
    assert shlib.use_fsdp(get_config("llama-3.2-vision-90b"), MESH)
    assert not shlib.use_fsdp(get_config("whisper-base"), MESH)
    assert not shlib.use_fsdp(get_config("rwkv6-1.6b"), MESH)


def test_opt_state_specs_follow_params():
    from repro.train import optimizer as opt_lib
    cfg = get_config("yi-6b")
    pshapes = model_lib.params_specs(cfg)
    pspecs = shlib.param_specs(cfg, pshapes, MESH)
    opt = opt_lib.get_optimizer("adamw")
    oshapes = jax.eval_shape(opt.init, pshapes)
    ospecs = shlib.opt_state_specs(pspecs, pshapes, oshapes)
    # the m-moment of wq shards like wq itself
    fm = _flat(ospecs["m"], oshapes["m"])
    fp = _flat(pspecs, pshapes)
    dm = {n: sp for n, _, sp in fm}
    dp = {n: sp for n, _, sp in fp}
    for n in dp:
        assert dm[n] == dp[n], n


def test_adafactor_factored_specs_drop_reduced_dim():
    from repro.train import optimizer as opt_lib
    cfg = get_config("gemma3-12b")   # adafactor arch
    pshapes = model_lib.params_specs(cfg)
    pspecs = shlib.param_specs(cfg, pshapes, MESH)
    opt = opt_lib.get_optimizer("adafactor")
    oshapes = jax.eval_shape(opt.init, pshapes)
    ospecs = shlib.opt_state_specs(pspecs, pshapes, oshapes)
    flat = _flat(ospecs, oshapes)
    for name, shape, spec in flat:
        assert len(tuple(spec)) == len(shape), (name, shape, spec)
