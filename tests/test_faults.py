"""Chaos differential suite for the fault-tolerance layer (PR 6).

Deterministic, seeded fault plans (``core.faults``) × {worker exception,
slow task, corrupt spill, missing spill, ENOSPC} × grids {1, W, 4W}: every
run must either complete **bit-identical** to its fault-free counterpart
(retry / recompute / graceful degradation) or raise ONE typed error with
full provenance — and everything the recovery machinery did must be
attributed exactly in ``ExecStats``.

The destructive unit tests (corrupt/missing/closed-store) manipulate real
spill files directly, so they are deterministic without any injection plan.
"""
import gc
import os
import threading
import warnings

import numpy as np
import pytest

from repro.core import EvalMode, Session, set_session
from repro.core import algebra as alg
from repro.core import faults, schedule
from repro.core.api import read_csv
from repro.core.dtypes import Domain
from repro.core.executor import ExecStats, Executor
from repro.core.faults import (FaultPlan, IngestError, InjectedWorkerError,
                               SpillIntegrityError, StoreClosedError,
                               TaskError, env_int, is_retryable)
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame
from repro.core.store import as_handle, get_store, reset_store

pytestmark = pytest.mark.spill


@pytest.fixture(autouse=True)
def _fault_counters():
    """Plan matching records into module counters even in the pure-parsing
    tests — keep every test's view of them clean."""
    faults.reset()
    yield
    faults.reset()


@pytest.fixture
def chaos(monkeypatch, tmp_path):
    """Clean fault/retry/store/pool state around every test."""
    for knob in ("REPRO_FAULT_PLAN", "REPRO_FAULT_SEED", "REPRO_FAULT_SLOW_MS",
                 "REPRO_TASK_RETRIES", "REPRO_TASK_TIMEOUT_MS",
                 "REPRO_RETRY_BACKOFF_MS", "REPRO_MEM_BUDGET"):
        monkeypatch.delenv(knob, raising=False)
    monkeypatch.setenv("REPRO_SPILL_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_RETRY_BACKOFF_MS", "1")   # fast test retries
    faults.reset()
    schedule.configure_retries(clear=True)
    reset_store()
    yield monkeypatch
    faults.reset()
    schedule.configure_retries(clear=True)
    reset_store()
    schedule.reset_pool()


def _frame(n=100, seed=0):
    rng = np.random.default_rng(seed)
    return Frame(
        [Column(np.asarray(rng.integers(0, 8, n, dtype=np.int32)), Domain.INT),
         Column(np.asarray((rng.integers(0, 12, n) * np.float32(0.25))
                           .astype(np.float32)), Domain.FLOAT)],
        RangeLabels(n), labels_from_values(["k", "x"]))


def _pipeline_plan(src):
    from repro.core.algebra import (DropDuplicates, GroupBy, Map, Selection,
                                    Udf, col, lit)

    def scale(cols, frame):
        out = dict(cols)
        c = cols["x"]
        out["x"] = Column(c.data * 2.0 + 1.0, Domain.FLOAT, c.mask, None)
        return out

    udf = Udf(name="faults_sweep_scale", fn=scale, deps=frozenset(["x"]),
              elementwise=True)
    g = GroupBy(Selection(Map(src, udf), col("k") < lit(6)),
                ("k",), [("x", "sum", "x"), ("x", "count", "n")])
    return DropDuplicates(g, None)


def _write_csv(path, n, seed=3):
    rng = np.random.default_rng(seed)
    k = rng.integers(0, 8, n)
    v = rng.integers(0, 50, n)
    x = rng.integers(0, 12, n) * 0.25
    with open(path, "w") as f:
        f.write("k,v,x\n")
        for i in range(n):
            f.write(f"{k[i]},{v[i]},{x[i]}\n")


# =============================================================================
# the shared env parser (satellite: silent-except holes)
# =============================================================================
def test_env_int_malformed_warns_once_and_falls_back(chaos):
    chaos.setenv("REPRO_TEST_BOGUS_KNOB", "not-an-int")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert env_int("REPRO_TEST_BOGUS_KNOB", 7) == 7
        assert env_int("REPRO_TEST_BOGUS_KNOB", 7) == 7   # second parse
    hits = [x for x in w if "REPRO_TEST_BOGUS_KNOB" in str(x.message)]
    assert len(hits) == 1                                 # warned ONCE
    assert issubclass(hits[0].category, RuntimeWarning)


def test_env_int_minimum_and_defaults(chaos):
    chaos.setenv("REPRO_TEST_NEG_KNOB", "-5")
    assert env_int("REPRO_TEST_NEG_KNOB", 3, minimum=0) == 0
    assert env_int("REPRO_TEST_UNSET_KNOB", 42) == 42


def test_malformed_mem_budget_warns_not_silently_zero(chaos):
    from repro.core.store import _env_budget
    chaos.setenv("REPRO_MEM_BUDGET", "lots")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert _env_budget() == 0
    assert any("REPRO_MEM_BUDGET" in str(x.message) for x in w)


# =============================================================================
# the plan: grammar + deterministic draws
# =============================================================================
def test_fault_plan_grammar_and_errors():
    p = FaultPlan("worker:0.1, corrupt@blk3:1.0!, enospc:0.5", seed=1)
    assert p.match("corrupt", "spill_read/blk3/orphan", recoverable=False)
    assert not p.match("corrupt", "spill_read/blk4/orphan", recoverable=False)
    for bad in ("worker", "bogus:0.5", "worker:abc", "worker@x"):
        with pytest.raises(ValueError):
            FaultPlan(bad)


def test_draws_are_deterministic_per_address():
    a = faults._draw(7, "worker", "dispatch/node=map/blk=0/try=0")
    b = faults._draw(7, "worker", "dispatch/node=map/blk=0/try=0")
    assert a == b and 0.0 <= a < 1.0
    # a different seed or address decides independently
    assert faults._draw(8, "worker", "dispatch/node=map/blk=0/try=0") != a
    p0 = FaultPlan("worker:0.0", seed=7)
    p1 = FaultPlan("worker:1.0", seed=7)
    assert not p0.match("worker", "x", attempt=0)
    assert p1.match("worker", "x", attempt=0)
    assert not p1.match("worker", "x", attempt=1)     # non-sticky: try 0 only


def test_nonsticky_corrupt_spares_orphan_reads():
    p = FaultPlan("corrupt:1.0", seed=0)
    assert p.match("corrupt", "spill_read/blk1/lineage", recoverable=True)
    assert not p.match("corrupt", "spill_read/blk1/orphan", recoverable=False)
    sticky = FaultPlan("corrupt:1.0!", seed=0)
    assert sticky.match("corrupt", "spill_read/blk1/orphan", recoverable=False)


# =============================================================================
# dispatch retry policy
# =============================================================================
def test_transient_worker_faults_recovered_by_retry(chaos):
    chaos.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    ref = schedule.dispatch_blocks(lambda x: x * 2, list(range(16)))
    chaos.setenv("REPRO_FAULT_PLAN", "worker:0.5")
    chaos.setenv("REPRO_FAULT_SEED", "3")
    st = ExecStats()
    got = schedule.dispatch_blocks(lambda x: x * 2, list(range(16)), stats=st)
    assert got == ref                       # bit-identical despite the chaos
    assert faults.injected_total() > 0
    assert st.retries > 0 and st.task_failures == st.retries


def test_poison_block_isolated_with_provenance(chaos):
    chaos.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    chaos.setenv("REPRO_FAULT_PLAN", "worker@blk=2/:1.0!")   # sticky poison
    st = ExecStats()
    with schedule.node_scope("probe"):
        with pytest.raises(TaskError) as ei:
            schedule.dispatch_blocks(lambda x: x, list(range(6)), stats=st)
    e = ei.value
    assert e.node == "probe" and e.block == 2
    assert e.attempts == schedule.task_retries() + 1
    assert isinstance(e.cause, InjectedWorkerError)
    assert "probe" in str(e) and "block=2" in str(e)
    assert st.task_failures == e.attempts and st.retries == e.attempts - 1


def test_deterministic_errors_propagate_unchanged(chaos):
    st = ExecStats()

    def boom(x):
        raise ValueError("bad value, not transient")

    with pytest.raises(ValueError, match="not transient"):
        schedule.dispatch_blocks(boom, [1, 2, 3], stats=st)
    assert st.retries == 0                  # never retried
    assert not is_retryable(ValueError("x"))
    assert is_retryable(OSError("x")) and is_retryable(TimeoutError())
    assert not is_retryable(TaskError("x"))


def test_retries_zero_fails_fast(chaos):
    chaos.setenv("REPRO_TASK_RETRIES", "0")
    chaos.setenv("REPRO_FAULT_PLAN", "worker@blk=1/:1.0!")
    with pytest.raises(TaskError) as ei:
        schedule.dispatch_blocks(lambda x: x, [10, 11, 12])
    assert ei.value.attempts == 1           # no retry budget spent


def test_slow_tasks_and_dispatch_deadline(chaos):
    chaos.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    chaos.setenv("REPRO_FAULT_PLAN", "slow:1.0")
    chaos.setenv("REPRO_FAULT_SLOW_MS", "1")
    assert schedule.dispatch_blocks(lambda x: x + 1, list(range(8))) == \
        list(range(1, 9))                   # slow alone: completes
    chaos.setenv("REPRO_FAULT_SLOW_MS", "200")
    chaos.setenv("REPRO_TASK_TIMEOUT_MS", "40")
    with schedule.node_scope("slowpoke"):
        with pytest.raises(TaskError) as ei:
            schedule.dispatch_blocks(lambda x: x + 1, list(range(8)))
    assert ei.value.kind == "timeout" and ei.value.node == "slowpoke"


def test_kill_pool_worker_mid_dispatch_recovers(chaos):
    """reset_pool() (shutdown wait=False) under an in-flight dispatch models
    losing the worker set: the dispatch must still complete, and later
    dispatches run on the rebuilt pool."""
    chaos.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    started, release = threading.Event(), threading.Event()

    def fn(i):
        started.set()
        release.wait(10)
        return i * 3

    out: dict = {}
    t = threading.Thread(
        target=lambda: out.update(r=schedule.dispatch_blocks(fn, list(range(8)))))
    t.start()
    assert started.wait(10)
    schedule.reset_pool()                   # kill the pool under the dispatch
    release.set()
    t.join(30)
    assert out.get("r") == [i * 3 for i in range(8)]
    # the rebuilt pool serves new dispatches — with injected worker deaths
    # recovered by retry on top
    chaos.setenv("REPRO_FAULT_PLAN", "worker:1.0")   # every block, try 0
    st = ExecStats()
    assert schedule.dispatch_blocks(lambda x: -x, [1, 2, 3], stats=st) == \
        [-1, -2, -3]
    assert st.retries == 3


# =============================================================================
# spill integrity: corrupt / missing / orphan / closed store
# =============================================================================
def _spill_out(h, filler_seeds=(91, 92)):
    """Force ``h`` to disk by registering fresher blocks."""
    keep = [as_handle(_frame(200, seed=s)) for s in filler_seeds]
    assert not h.is_resident
    return keep


def test_corrupt_spill_recomputed_from_lineage(chaos):
    src = _frame(200, seed=1)
    chaos.setenv("REPRO_MEM_BUDGET", str(src.nbytes() + 16))
    reset_store()
    hsrc = as_handle(src)                   # stays faultable via its own file

    def produce():
        f = hsrc.frame()
        return Frame([Column(np.asarray(f.columns[1].data) * 2.0,
                             Domain.FLOAT)],
                     RangeLabels(f.nrows), labels_from_values(["x2"]))

    h = as_handle(produce(), recompute=produce)
    ref = h.frame().to_pydict()
    keep = _spill_out(h)
    path = h._rec.path
    with open(path, "r+b") as f:            # flip one payload byte
        f.seek(os.path.getsize(path) // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    st = get_store().stats
    assert h.frame().to_pydict() == ref     # recomputed, bit-identical
    assert st.checksum_failures == 1 and st.recomputed_blocks == 1
    # the bad file was discarded: a later eviction rewrites cleanly
    keep2 = _spill_out(h, filler_seeds=(93, 94))
    assert h.frame().to_pydict() == ref
    del keep, keep2


def test_missing_spill_recomputed_from_lineage(chaos):
    src = _frame(200, seed=2)
    chaos.setenv("REPRO_MEM_BUDGET", str(src.nbytes() + 16))
    reset_store()
    h = as_handle(src, recompute=lambda: _frame(200, seed=2))
    ref = src.to_pydict()
    keep = _spill_out(h)
    os.unlink(h._rec.path)                  # the file vanishes
    st = get_store().stats
    assert h.frame().to_pydict() == ref
    assert st.checksum_failures == 1 and st.recomputed_blocks == 1
    del keep


def test_corrupt_orphan_spill_raises_typed_error(chaos):
    src = _frame(200, seed=3)
    chaos.setenv("REPRO_MEM_BUDGET", str(src.nbytes() + 16))
    reset_store()
    h = as_handle(src)                      # no lineage: orphan
    keep = _spill_out(h)
    with open(h._rec.path, "r+b") as f:
        f.seek(os.path.getsize(h._rec.path) // 2)
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(SpillIntegrityError, match="no recorded producer"):
        h.frame()
    assert get_store().stats.checksum_failures == 1
    del keep


def test_fault_after_shutdown_raises_store_closed(chaos):
    src = _frame(200, seed=4)
    chaos.setenv("REPRO_MEM_BUDGET", str(src.nbytes() + 16))
    reset_store()
    h = as_handle(src)
    keep = _spill_out(h)
    reset_store()                           # shutdown: spill files deleted
    with pytest.raises(StoreClosedError) as ei:
        h.frame()
    msg = str(ei.value)
    assert f"block id {h._id}" in msg       # names the handle
    assert "shutdown" in msg and ".py:" in msg   # and the shutdown site
    assert isinstance(ei.value, RuntimeError) and "spill" in msg
    del keep


# =============================================================================
# graceful degradation under resource exhaustion
# =============================================================================
def test_enospc_keeps_victim_resident_and_counts_overrun(chaos):
    one = _frame(200).nbytes()
    chaos.setenv("REPRO_MEM_BUDGET", str(one + 16))
    chaos.setenv("REPRO_FAULT_PLAN", "enospc:1.0")
    reset_store()
    h1 = as_handle(_frame(200, seed=1))
    h2 = as_handle(_frame(200, seed=2))     # wants to evict h1 — can't write
    st = get_store().stats
    assert h1.is_resident and h2.is_resident    # both stayed (overshoot)
    assert st.budget_overruns > 0 and st.spills == 0
    assert st.resident_bytes > get_store().budget
    # data is still fully correct
    assert h1.frame().to_pydict() == _frame(200, seed=1).to_pydict()


def test_spill_dir_failover_list(chaos, tmp_path):
    bad = tmp_path / "full-disk"
    good = tmp_path / "overflow"
    bad.mkdir()
    good.mkdir()
    chaos.setenv("REPRO_SPILL_DIR", f"{bad}{os.pathsep}{good}")
    chaos.setenv("REPRO_FAULT_PLAN", "enospc@dir0:1.0")   # dir 0 always full
    one = _frame(200).nbytes()
    chaos.setenv("REPRO_MEM_BUDGET", str(one + 16))
    reset_store()
    h1 = as_handle(_frame(200, seed=1))
    h2 = as_handle(_frame(200, seed=2))
    assert not h1.is_resident               # spilled — via the failover dir
    assert get_store().stats.spills == 1
    assert not any(bad.rglob("blk*.npz"))
    assert any(good.rglob("blk*.npz"))
    assert h1.frame().to_pydict() == _frame(200, seed=1).to_pydict()
    del h2


def test_reap_unlink_failure_counts_leak(chaos, monkeypatch):
    one = _frame(200).nbytes()
    chaos.setenv("REPRO_MEM_BUDGET", str(one + 16))
    reset_store()
    h1 = as_handle(_frame(200, seed=1))
    h2 = as_handle(_frame(200, seed=2))
    assert not h1.is_resident
    st = get_store().stats
    real_unlink = os.unlink

    def deny(p, *a, **k):
        if "repro-spill-" in str(p):
            raise PermissionError(13, "Permission denied", str(p))
        return real_unlink(p, *a, **k)

    monkeypatch.setattr(os, "unlink", deny)
    del h1
    gc.collect()
    assert st.leaked_spill_files == 1       # counted, not swallowed
    monkeypatch.setattr(os, "unlink", real_unlink)
    del h2


# =============================================================================
# read_csv: file changed between planning and tokenization (satellite)
# =============================================================================
@pytest.mark.parametrize("change", ["truncated", "grew"])
def test_read_csv_file_changed_mid_ingest(chaos, monkeypatch, tmp_path,
                                          change):
    import repro.core.api as api_mod
    csv = tmp_path / "racy.csv"
    _write_csv(csv, 2000)
    orig = api_mod._csv_chunk_ranges

    def plan_then_change(path, sep):
        header, ranges = orig(path, sep)
        if change == "truncated":
            with open(path, "r+b") as f:    # concurrently-truncated file
                f.truncate(os.path.getsize(path) - 123)
        else:
            with open(path, "ab") as f:     # concurrently-appended rows
                f.write(b"9,9,9.0\n")
        return header, ranges

    monkeypatch.setattr(api_mod, "_csv_chunk_ranges", plan_then_change)
    s = set_session(Session(mode=EvalMode.LAZY))
    try:
        with pytest.raises(IngestError, match=change):
            read_csv(str(csv))
    finally:
        s.close()


# =============================================================================
# chaos differential: fault plans × grids, bit-identical + attributed
# =============================================================================
@pytest.mark.parametrize("grid", [1, None, "4w"])
def test_worker_chaos_differential_across_grids(grid, chaos):
    chaos.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    w = schedule.pool_width()
    rp = {1: 1, None: w, "4w": 4 * w}[grid]
    frame = _frame(4000, seed=7)

    def run():
        pf = PartitionedFrame.from_frame(frame, row_parts=rp)
        ex = Executor({"f": pf}, optimize=True)
        out = ex.evaluate(_pipeline_plan(alg.Source("f", 4000, 2)))
        return out.to_frame().to_pydict(), ex.stats

    ref, st0 = run()
    assert st0.faults_injected == 0 and st0.retries == 0

    chaos.setenv("REPRO_FAULT_PLAN", "worker:0.4,slow:0.2")
    chaos.setenv("REPRO_FAULT_SEED", "11")
    chaos.setenv("REPRO_FAULT_SLOW_MS", "1")
    got, st = run()
    assert got == ref                       # bit-identical under chaos
    assert st.faults_injected > 0
    assert st.retries > 0 and st.task_failures == st.retries


def test_acceptance_all_fault_classes_4x_budget_pipeline(chaos, tmp_path):
    """ISSUE 6 acceptance: a seeded plan injecting ≥1 of each fault class
    (worker exception, spill corruption/missing, ENOSPC) into the 4×-budget
    groupby+dedup pipeline completes bit-identical to the fault-free run
    with every retry/recompute/overrun attributed in ExecStats."""
    chaos.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    csv = tmp_path / "big.csv"
    _write_csv(csv, 20_000)
    plan = "worker:0.3,slow:0.1,corrupt:0.5,missing:0.3,enospc:0.4"

    def run(inject=False):
        # chaos is scoped to *statement execution* — configured after the
        # plan is built and cleared before the final result materialization
        # — so every injection lands inside the executor's attribution
        # windows and ExecStats can be asserted EXACTLY, not just >= 1
        s = set_session(Session(mode=EvalMode.LAZY))
        try:
            df = read_csv(str(csv))
            total = s.frames["frame_0"].nbytes()
            df["y"] = df["x"] * 2.0 + 1.0
            out = (df[df["v"] > 10].groupby("k")
                   .agg({"y": "sum", "x": "mean"}).drop_duplicates())
            if inject:
                faults.configure(plan=plan, seed=5)
            pf = s.executor.evaluate(out._node)    # the chaos window
            fired = faults.injected_snapshot()
            injected = faults.injected_total()
            faults.reset()
            res = pf.to_frame().to_pydict()
            return res, total, s.executor.stats, fired, injected
        finally:
            s.close()

    ref, total, st0, _, _ = run()           # fault-free, unbudgeted
    assert st0.spills == 0 and st0.faults_injected == 0

    chaos.setenv("REPRO_MEM_BUDGET", str(total // 4))
    chaos.setenv("REPRO_FAULT_SLOW_MS", "1")
    reset_store()
    got, _, st, fired, injected = run(inject=True)

    assert got == ref                       # bit-identical under full chaos
    assert fired.get("worker", 0) >= 1      # ≥1 of each injected class
    assert fired.get("corrupt", 0) + fired.get("missing", 0) >= 1
    assert fired.get("enospc", 0) >= 1
    # exact attribution: ExecStats saw what the store and the plan recorded
    store_stats = get_store().stats
    assert st.retries > 0
    assert st.task_failures >= st.retries
    assert st.checksum_failures == store_stats.checksum_failures > 0
    assert st.recomputed_blocks == store_stats.recomputed_blocks > 0
    assert st.budget_overruns == store_stats.budget_overruns > 0
    assert st.faults_injected == injected > 0
    assert store_stats.leaked_spill_files == 0


def test_zero_fault_run_stays_clean(chaos):
    """With injection disabled the whole layer is inert: no injected
    faults, no retries, no integrity work — the production path."""
    chaos.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    frame = _frame(2000, seed=5)
    pf = PartitionedFrame.from_frame(frame, row_parts=4)
    ex = Executor({"f": pf}, optimize=True)
    out = ex.evaluate(_pipeline_plan(alg.Source("f", 2000, 2)))
    assert out.nrows > 0
    assert not faults.active()
    assert ex.stats.faults_injected == 0 and ex.stats.retries == 0
    assert ex.stats.task_failures == 0 and ex.stats.checksum_failures == 0


def test_session_knobs_configure_retries_and_plan(chaos):
    """Session knobs are SESSION-scoped: they apply inside the session's
    statements (the installed config scope) and leave the process defaults
    untouched — two concurrent sessions can no longer clobber each other."""
    from repro.core import config

    base_retries = schedule.task_retries()
    s = set_session(Session(mode=EvalMode.LAZY, task_retries=5,
                            retry_backoff_ms=0, task_timeout_ms=0,
                            fault_plan="worker:0.0", fault_seed=9))
    try:
        with config.scope(s.config):
            assert schedule.task_retries() == 5
            assert schedule.retry_backoff_ms() == 0
            assert faults.active()
            p = faults._plan()
            assert p is not None and p.seed == 9
        # outside the session's scope the process defaults still hold
        assert schedule.task_retries() == base_retries
        assert not faults.active()
        assert faults._plan() is None
    finally:
        s.close()
