"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes × dtypes (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.block_transpose import block_transpose
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.linear_scan import linear_scan
from repro.kernels.onehot_encode import onehot_encode
from repro.kernels.segment_reduce import segment_reduce
from repro.kernels.window_scan import window_scan


@pytest.mark.parametrize("shape", [(8, 128), (100, 37), (257, 129), (5, 1000), (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_block_transpose(rng, shape, dtype):
    x = jnp.asarray(rng.standard_normal(shape) * 10).astype(dtype)
    np.testing.assert_array_equal(np.asarray(block_transpose(x)),
                                  np.asarray(ref.transpose(x)))


@pytest.mark.parametrize("m,g", [(64, 4), (1000, 7), (5000, 129), (17, 1)])
@pytest.mark.parametrize("op", ["sum", "count", "min", "max"])
def test_segment_reduce(rng, m, g, op):
    v = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    c = jnp.asarray(rng.integers(-1, g, m).astype(np.int32))
    out = segment_reduce(v, c, g, op)
    exp = ref.segment_reduce(v, c, g, op)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cols", [1, 3, 7])
def test_segment_reduce_multicolumn(rng, cols):
    m, g = 777, 13
    v = jnp.asarray(rng.standard_normal((m, cols)).astype(np.float32))
    c = jnp.asarray(rng.integers(0, g, m).astype(np.int32))
    np.testing.assert_allclose(np.asarray(segment_reduce(v, c, g, "sum")),
                               np.asarray(ref.segment_reduce(v, c, g, "sum")),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,n", [(64, 1), (1000, 5), (2049, 3)])
@pytest.mark.parametrize("op", ["cumsum", "cummax", "cummin"])
def test_window_scan(rng, m, n, op):
    x = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(window_scan(x, op)),
                               np.asarray(ref.window_scan(x, op)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,n", [(16, 8), (300, 17), (1025, 64)])
def test_linear_scan(rng, t, n):
    a = jnp.asarray((rng.random((t, n)) * 0.95).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((t, n)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(linear_scan(a, b)),
                               np.asarray(ref.linear_scan(a, b)),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("m,g", [(32, 4), (500, 13), (1000, 300)])
def test_onehot_encode(rng, m, g):
    c = jnp.asarray(rng.integers(-1, g, m).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(onehot_encode(c, g)),
                                  np.asarray(ref.onehot_encode(c, g)))


@pytest.mark.parametrize("h,sq,sk,d", [(2, 128, 128, 64), (4, 200, 200, 64),
                                       (1, 64, 256, 128), (2, 333, 333, 80)])
@pytest.mark.parametrize("window", [None, 32])
def test_flash_attention(rng, h, sq, sk, d, window):
    q = jnp.asarray(rng.standard_normal((h, sq, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((h, sk, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((h, sk, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, window=window)
    exp = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16(rng):
    h, s, d = 2, 128, 64
    q = jnp.asarray(rng.standard_normal((h, s, d))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((h, s, d))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((h, s, d))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    exp = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(exp, dtype=np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("h,kvh,s,length,d", [(8, 2, 256, 100, 64),
                                              (16, 4, 333, 217, 64),
                                              (4, 4, 128, 128, 128),
                                              (8, 1, 700, 1, 64)])
def test_decode_attention(rng, h, kvh, s, length, d):
    q = jnp.asarray(rng.standard_normal((h, d)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal((s, kvh, d)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((s, kvh, d)).astype(np.float32))
    out = decode_attention(q, kc, vc, length)
    exp = ref.decode_attention(q, kc, vc, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-3, atol=2e-3)


def test_ops_dispatch_matches_both_paths(rng, use_pallas_kernels):
    """ops.py with kernels forced == ref path (same API surface)."""
    from repro.kernels import ops
    assert ops.use_pallas()
    x = jnp.asarray(rng.standard_normal((65, 33)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(ops.transpose(x)), np.asarray(x.T))
    v = jnp.asarray(rng.standard_normal(257).astype(np.float32))
    c = jnp.asarray(rng.integers(0, 5, 257).astype(np.int32))
    np.testing.assert_allclose(np.asarray(ops.segment_reduce(v, c, 5, "sum")),
                               np.asarray(ref.segment_reduce(v, c, 5, "sum")),
                               rtol=1e-4, atol=1e-4)
