"""Data model tests: schema induction S(·), labels, the (A, R, C, D) tuple,
transpose recovery, point updates (paper §3.2–3.3)."""
import numpy as np
import pytest

from repro.core.dtypes import Domain, induce_schema, parse_column
from repro.core.frame import Frame
from repro.core.labels import CodedLabels, RangeLabels, labels_from_values


class TestSchemaInduction:
    def test_most_specific_domain(self):
        assert induce_schema(["1", "2", "3"]) is Domain.INT
        assert induce_schema(["1.5", "2"]) is Domain.FLOAT
        assert induce_schema(["true", "false", "yes"]) is Domain.BOOL
        assert induce_schema(["apple", "1"]) is Domain.STR
        assert induce_schema([1, 2, None]) is Domain.INT
        assert induce_schema([None, None]) is Domain.UNSPECIFIED

    def test_parse_column_nulls(self):
        p = parse_column(["1", None, "3"])
        assert p.domain is Domain.INT
        assert p.mask is not None
        assert list(np.asarray(p.mask)) == [True, False, True]

    def test_parse_fallback_to_str(self):
        p = parse_column(["1", "x"], Domain.INT)  # doesn't parse as int
        assert p.domain is Domain.STR
        assert p.dictionary == ("1", "x")

    def test_dictionary_first_occurrence_order(self):
        p = parse_column(["b", "a", "b", "c"])
        assert p.dictionary == ("b", "a", "c")
        assert list(np.asarray(p.data)) == [0, 1, 0, 2]


class TestLabels:
    def test_range_labels_cheap_ops(self):
        r = RangeLabels(10)
        assert r.position_of(7) == 7
        assert isinstance(r.take(np.arange(3, 8)), RangeLabels)
        assert r.take(np.arange(3, 8)).to_list() == [3, 4, 5, 6, 7]

    def test_range_concat_contiguous(self):
        a, b = RangeLabels(5), RangeLabels(5, start=5)
        assert isinstance(a.concat(b), RangeLabels)
        assert len(a.concat(b)) == 10

    def test_coded_labels_duplicates_and_nulls(self):
        l = labels_from_values(["x", "y", "x", None])
        assert isinstance(l, CodedLabels)
        assert l.to_list() == ["x", "y", "x", None]
        assert l.position_of("x") == 0  # first occurrence


class TestFrame:
    def test_shape_and_schema(self):
        f = Frame.from_pydict({"a": [1, 2], "b": ["x", "y"], "c": [1.5, 2.5]})
        assert f.shape == (2, 3)
        assert f.schema == (Domain.INT, Domain.STR, Domain.FLOAT)

    def test_iloc_point_update(self):
        f = Frame.from_pydict({"a": ["p", "q"]})
        g = f.iloc_set(1, 0, "r")
        assert g.col("a").to_pylist() == ["p", "r"]
        assert f.col("a").to_pylist() == ["p", "q"]  # immutable original

    def test_matrix_check(self):
        assert Frame.from_pydict({"a": [1, 2], "b": [1.0, 2.0]}).is_matrix()
        assert not Frame.from_pydict({"a": ["x", "y"], "b": [1, 2]}).is_matrix()

    def test_concat_rows_unifies_dictionaries(self):
        a = Frame.from_pydict({"k": ["x", "y"]})
        b = Frame.from_pydict({"k": ["z", "x"]})
        c = a.concat_rows(b)
        assert c.col("k").to_pylist() == ["x", "y", "z", "x"]

    def test_row_domains_recovery_metadata(self):
        f = Frame.from_pydict({"a": [1, 2], "b": [1.5, 2.5]})
        # slicing rows of a frame with row_domains keeps them aligned
        g = Frame(f.columns, f.row_labels, f.col_labels,
                  row_domains=(Domain.INT, Domain.FLOAT))
        h = g.take_rows(np.asarray([1]))
        assert h.row_domains == (Domain.FLOAT,)
