"""Direct coverage of the executor's benefit-density eviction policy
(``Executor._store``) — eviction order, never-evict-sources — and its new
shared-budget interaction with the block store (cached results' handles are
stamped with the entry's benefit density so the ONE ``REPRO_MEM_BUDGET``
evicts low-value working blocks before reusable cached sub-plans)."""
import numpy as np
import pytest

from repro.core import algebra as alg
from repro.core.dtypes import Domain
from repro.core.executor import CacheEntry, Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame
from repro.core.store import as_handle, get_store, reset_store


def _pf(n=64, seed=0):
    rng = np.random.default_rng(seed)
    f = Frame([Column(np.asarray(rng.integers(0, 9, n, dtype=np.int32)),
                      Domain.INT),
               Column(np.asarray((rng.integers(0, 8, n) * np.float32(0.5))
                                 .astype(np.float32)), Domain.FLOAT)],
              RangeLabels(n), labels_from_values(["k", "x"]))
    return PartitionedFrame.from_frame(f, row_parts=2)


def _entry(ex, key, pf, cost_s):
    ex._store(key, pf, cost_s)
    return ex.cache[key]


# =============================================================================
# eviction order: lowest benefit density goes first
# =============================================================================
def test_eviction_order_by_benefit_density():
    pf = _pf()
    per_entry = pf.nbytes()
    ex = Executor({}, cache_budget_bytes=3 * per_entry + 8)
    # benefit density = cost × (1 + hits) / bytes; equal bytes → cost ranks
    _entry(ex, ("map", 1), _pf(seed=1), cost_s=0.001)   # lowest — dies first
    _entry(ex, ("map", 2), _pf(seed=2), cost_s=1.0)
    _entry(ex, ("map", 3), _pf(seed=3), cost_s=0.1)
    assert len(ex.cache) == 3
    _entry(ex, ("map", 4), _pf(seed=4), cost_s=0.5)     # over budget now
    assert ("map", 1) not in ex.cache                    # cheapest evicted
    assert ("map", 2) in ex.cache and ("map", 3) in ex.cache
    # push again: next-lowest density goes, the expensive entry survives
    _entry(ex, ("map", 5), _pf(seed=5), cost_s=0.8)
    assert ("map", 3) not in ex.cache
    assert ("map", 2) in ex.cache


def test_hits_raise_benefit_density():
    pf = _pf()
    per_entry = pf.nbytes()
    ex = Executor({}, cache_budget_bytes=2 * per_entry + 8)
    a = _entry(ex, ("map", 1), _pf(seed=1), cost_s=0.1)
    b = _entry(ex, ("map", 2), _pf(seed=2), cost_s=0.1)
    a.hits += 9                     # ten uses: density × 10
    assert a.benefit_density() > b.benefit_density()
    _entry(ex, ("map", 3), _pf(seed=3), cost_s=0.1)
    assert ("map", 1) in ex.cache and ("map", 2) not in ex.cache


def test_sources_never_evicted():
    pf = _pf()
    per_entry = pf.nbytes()
    ex = Executor({}, cache_budget_bytes=2 * per_entry + 8)
    # a source entry with the WORST density — still immune
    _entry(ex, ("source", "f0"), _pf(seed=1), cost_s=1e-9)
    _entry(ex, ("map", 1), _pf(seed=2), cost_s=10.0)
    _entry(ex, ("map", 2), _pf(seed=3), cost_s=10.0)    # over budget
    assert ("source", "f0") in ex.cache
    assert ("map", 1) not in ex.cache                    # evicted instead


# =============================================================================
# shared budget with the block store
# =============================================================================
@pytest.mark.spill
def test_cached_results_outlive_working_blocks_in_store(monkeypatch, tmp_path):
    """Under one REPRO_MEM_BUDGET the store must spill plain working blocks
    (benefit 0) before the handles of a cached executor result (benefit =
    the entry's density)."""
    monkeypatch.setenv("REPRO_SPILL_DIR", str(tmp_path))
    pf = _pf(256, seed=1)
    budget = pf.nbytes() * 2 + 64
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(budget))
    reset_store()
    try:
        cached = PartitionedFrame([[as_handle(b)] for row in
                                   _pf(256, seed=2).parts for b in row])
        ex = Executor({}, cache_budget_bytes=1 << 30)
        ex._store(("map", 99), cached, cost_s=5.0)
        ent = ex.cache[("map", 99)]
        assert all(h.benefit >= ent.benefit_density() * 0.99
                   for row in cached.handles for h in row)
        # now flood the store with plain (benefit-0) blocks: they should
        # cycle through disk while the cached result stays resident
        plain = [as_handle(Frame(
            [Column(np.zeros(256, dtype=np.float32), Domain.FLOAT)],
            RangeLabels(256), labels_from_values(["z"]))) for _ in range(6)]
        assert get_store().stats.spills > 0
        assert all(h.is_resident for row in cached.handles for h in row)
        assert any(not h.is_resident for h in plain)
        del plain
    finally:
        reset_store()


@pytest.mark.spill
def test_cache_entry_nbytes_uses_handle_metadata(monkeypatch, tmp_path):
    """CacheEntry accounting must not fault spilled blocks — nbytes comes
    from handle metadata."""
    monkeypatch.setenv("REPRO_SPILL_DIR", str(tmp_path))
    one = _pf(256).nbytes()
    monkeypatch.setenv("REPRO_MEM_BUDGET", str(one + 64))
    reset_store()
    try:
        a = PartitionedFrame([[as_handle(b)] for row in _pf(256, seed=1).parts
                              for b in row])
        b = PartitionedFrame([[as_handle(blk)] for row in
                              _pf(256, seed=2).parts for blk in row])
        st = get_store().stats
        assert st.spills > 0               # a was pushed out by b
        faults0 = st.faults
        assert a.nbytes() == one           # metadata only
        assert st.faults == faults0        # no fault to account
    finally:
        reset_store()
