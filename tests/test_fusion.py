"""Fused blockwise pipelines (paper §5 "Pipelining") + zero-copy repartition.

Invariants:
  * fused execution of a row-local chain is **byte-identical** to the unfused
    per-node path, over 1×1 and multi-block grids;
  * the fusion pass only forms standalone groups of ≥ 2 operators; chains
    adjacent to a blocking operator fuse INTO it as barrier-fused nodes
    (see tests/test_blocking_fusion.py for those paths);
  * row-only / col-only repartitioning performs no full-frame concat
    (``to_frame`` is never called) and preserves row order and labels;
  * int⊕int expression arithmetic keeps integer dtypes (no float32 round-trip
    corrupting values above 2²⁴).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import algebra as alg
from repro.core import rewrite
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.dtypes import Domain
from repro.core.partition import PartitionedFrame
from repro.core.physical import eval_expr


def _mk_frame(n=97):
    rng = np.random.default_rng(7)
    return Frame.from_pydict({
        "k": [("a", "b", "c")[i % 3] for i in range(n)],
        "v": rng.integers(-50, 50, n).tolist(),
        "f": rng.standard_normal(n).astype(np.float32).tolist(),
        "g": rng.standard_normal(n).astype(np.float32).tolist(),
    }, row_labels=[f"r{i}" for i in range(n)])


def _scale_udf():
    def fn(cols, frame):
        out = dict(cols)
        c = cols["f"]
        out["f"] = Column(c.data * 2.0 + 1.0, Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name="scale_f", fn=fn, deps=frozenset(["f"]), elementwise=True)


def _chain(src):
    m = alg.Map(src, _scale_udf())
    s = alg.Selection(m, alg.col("v") > alg.lit(0))
    p = alg.Projection(s, ("k", "v", "f"))
    return alg.Rename(p, {"f": "F"})


@pytest.mark.parametrize("row_parts,col_parts", [(1, 1), (3, 1), (4, 2), (1, 2)])
def test_fused_chain_matches_per_node_path(row_parts, col_parts):
    f = _mk_frame()
    pf = PartitionedFrame.from_frame(f, row_parts=row_parts, col_parts=col_parts)
    store = {"f0": pf}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = _chain(src)

    fused_ex = Executor(store, optimize=True)
    plain_ex = Executor(store, optimize=False)
    a = fused_ex.evaluate(plan).to_frame()
    b = plain_ex.evaluate(plan).to_frame()

    assert fused_ex.stats.fused_groups >= 1, "chain never fused"
    assert a.row_labels.to_list() == b.row_labels.to_list()
    assert a.col_labels.to_list() == b.col_labels.to_list() == ["k", "v", "F"]
    ad, bd = a.to_pydict(), b.to_pydict()
    assert ad["k"] == bd["k"]
    assert ad["v"] == bd["v"]
    # float column must be byte-identical: same op order on device either way
    np.testing.assert_array_equal(np.asarray(ad["F"], dtype=np.float32),
                                  np.asarray(bd["F"], dtype=np.float32))


def test_fused_chain_with_udf_predicate_and_multiple_selections():
    f = _mk_frame(64)
    pf = PartitionedFrame.from_frame(f, row_parts=3)
    store = {"f0": pf}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    pred = alg.Udf(name="even_v", elementwise=True, deps=frozenset(["v"]),
                   fn=lambda cols, frame: np.asarray(cols["v"].data) % 2 == 0)
    plan = alg.Selection(alg.Selection(alg.Map(src, _scale_udf()), pred),
                         alg.col("f") > alg.lit(0.0))

    fused_ex = Executor(store, optimize=False)  # keep both selections distinct
    fused_plan, fs = rewrite.fuse_pipelines(plan)
    assert fused_plan.op == "fused_pipeline" and fs.fused_ops == 3
    a = fused_ex._eval(fused_plan).to_frame().to_pydict()
    b = fused_ex._eval(plan).to_frame().to_pydict()
    assert list(a.keys()) == list(b.keys())
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_fusion_pass_structure():
    src = alg.Source("f0", nrows=10, ncols=3)
    sel = alg.Selection(src, alg.col("v") > alg.lit(0))
    # single row-local op: NOT fused (keeps its own cache identity)
    out, fs = rewrite.fuse_pipelines(sel)
    assert out is sel or out == sel
    assert fs.groups == 0

    # GROUPBY absorbs its producer chain (barrier fusion); the consumer chain
    # above it stays a plain FusedPipeline (no gather to prune after groupby)
    g = alg.GroupBy(alg.Rename(sel, {"v": "w"}), ("k",), [("w", "sum", "ws")])
    top = alg.Projection(alg.Selection(g, alg.col("ws") > alg.lit(1)), ("k",))
    out, fs = rewrite.fuse_pipelines(top)
    assert fs.groups == 1 and fs.barrier_groups == 1
    assert fs.producer_ops == 2 and fs.consumer_ops == 0
    # one-source-of-truth counter invariant: every absorbed op is attributed
    assert fs.fused_ops == 2 + fs.producer_ops + fs.consumer_ops
    assert out.op == "fused_pipeline"
    assert out.children[0].op == "fused_groupby"
    assert [s.op for s in out.children[0].stages] == ["selection", "rename"]
    # stages run bottom-up
    assert [s.op for s in out.stages] == ["selection", "projection"]

    # non-elementwise maps never fuse
    whole = alg.Udf(name="whole", fn=lambda c, f: f, elementwise=False)
    plan = alg.Selection(alg.Map(src, whole), alg.col("v") > alg.lit(0))
    _, fs = rewrite.fuse_pipelines(plan)
    assert fs.groups == 0

    # limit never joins a fused group
    plan = alg.Limit(alg.Selection(alg.Rename(src, {"a": "b"}),
                                   alg.col("v") > alg.lit(0)), 5)
    out, fs = rewrite.fuse_pipelines(plan)
    assert out.op == "limit" and fs.groups == 1 and fs.fused_ops == 2


def test_shared_subplan_is_a_fusion_barrier():
    """A sub-plan referenced by two branches keeps its own node identity so
    the per-node cache still dedupes it (fusing it into both chains would
    re-execute the shared work per branch)."""
    src = alg.Source("f0", nrows=50, ncols=2)
    sel = alg.Selection(src, alg.col("v") > alg.lit(4))
    b1 = alg.Rename(alg.Projection(sel, ("v",)), {"v": "a"})
    b2 = alg.Rename(alg.Projection(sel, ("w",)), {"w": "b"})
    plan = alg.Union(b1, b2)

    fused, fs = rewrite.fuse_pipelines(plan)
    assert fs.groups == 2 and fs.fused_ops == 4  # shared selection not absorbed
    assert sum(1 for n in fused.walk() if n.op == "selection") == 1
    for g in (n for n in fused.walk() if n.op == "fused_pipeline"):
        assert g.children[0].op == "selection"

    f = Frame.from_pydict({"v": list(range(50)), "w": [i * 10 for i in range(50)]})
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=3)}
    ex = Executor(store, optimize=True)
    out = ex.evaluate(plan).to_frame().to_pydict()
    assert ex.stats.cache_hits >= 1  # second branch served from the cache
    assert out == Executor(store, optimize=False).evaluate(plan).to_frame().to_pydict()


def test_fused_group_has_single_cache_entry():
    f = _mk_frame(60)
    pf = PartitionedFrame.from_frame(f, row_parts=2)
    store = {"f0": pf}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = _chain(src)
    ex = Executor(store, optimize=True)
    ex.evaluate(plan)
    # source + fused group = 2 entries; the per-node path would cache 5
    non_source = [k for k in ex.cache if k[0] != "source"]
    assert len(non_source) == 1
    assert non_source[0][0] == "fused_pipeline"
    # second evaluation is a pure cache hit on the fused key
    before = ex.stats.cache_hits
    ex.evaluate(plan)
    assert ex.stats.cache_hits == before + 1


def test_fused_prefix_head():
    f = _mk_frame(90)
    pf = PartitionedFrame.from_frame(f, row_parts=3)
    store = {"f0": pf}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = _chain(src)
    ex = Executor(store, optimize=True)
    got = ex.evaluate_prefix(plan, 4).to_frame().head(4).to_pydict()
    want_full = Executor(store, optimize=False).evaluate(plan).to_frame().head(4).to_pydict()
    assert got["v"] == want_full["v"]
    assert ex.stats.prefix_evals == 1


# -----------------------------------------------------------------------------
# zero-copy repartition
# -----------------------------------------------------------------------------
def _count_to_frame(monkeypatch):
    calls = {"n": 0}
    orig = PartitionedFrame.to_frame

    def counting(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(PartitionedFrame, "to_frame", counting)
    return calls


@pytest.mark.parametrize("src_parts,dst_parts", [(4, 2), (2, 4), (3, 5), (5, 1)])
def test_row_repartition_no_full_concat(monkeypatch, src_parts, dst_parts):
    f = _mk_frame(83)
    pf = PartitionedFrame.from_frame(f, row_parts=src_parts)
    calls = _count_to_frame(monkeypatch)
    out = pf.repartition(row_parts=dst_parts)
    assert calls["n"] == 0, "row-only repartition must not concat the full frame"
    assert out.row_parts == dst_parts
    g = out.to_frame()
    assert g.to_pydict() == f.to_pydict()
    assert g.row_labels.to_list() == f.row_labels.to_list()


def test_col_repartition_is_metadata_only(monkeypatch):
    f = _mk_frame(40)
    pf = PartitionedFrame.from_frame(f, row_parts=2, col_parts=2)
    calls = _count_to_frame(monkeypatch)
    out = pf.repartition(col_parts=1)
    assert calls["n"] == 0
    # column regroup re-uses the very Column objects: zero-copy
    assert out.parts[0][0].columns[0] is pf.parts[0][0].columns[0]
    assert out.to_frame().to_pydict() == f.to_pydict()


def test_row_repartition_identity_blocks_pass_through(monkeypatch):
    f = _mk_frame(60)
    base = PartitionedFrame.from_frame(f, row_parts=1)
    # hand-build aligned stripes: [20, 10, 10, 20] → [30, 30]
    idx = np.cumsum([20, 10, 10, 20])[:-1]
    blocks = []
    lo = 0
    for hi in list(idx) + [60]:
        blocks.append([base.parts[0][0].take_rows(np.arange(lo, hi))])
        lo = hi
    pf = PartitionedFrame(blocks)
    calls = _count_to_frame(monkeypatch)
    out = pf.repartition(row_parts=2)
    assert calls["n"] == 0
    assert out.row_sizes == [30, 30]
    assert out.to_frame().to_pydict() == f.to_pydict()
    # and a boundary-aligned regroup to the identical layout is `self`
    assert pf.repartition(row_parts=4) is pf


# -----------------------------------------------------------------------------
# integer expression arithmetic keeps integer dtypes
# -----------------------------------------------------------------------------
def test_int_arithmetic_preserves_precision_above_2_24():
    big = 20_000_001          # > 2**24: float32 cannot represent big+1 exactly
    f = Frame.from_pydict({"v": [big, -7, 5]})
    for expr, want in [
        (alg.col("v") + alg.lit(1), [big + 1, -6, 6]),
        (alg.col("v") - alg.lit(2), [big - 2, -9, 3]),
        (alg.col("v") * alg.lit(2), [2 * big, -14, 10]),
        (alg.col("v") % alg.lit(10), [1, 3, 5]),
        (alg.col("v") // alg.lit(10), [2_000_000, -1, 0]),
    ]:
        v, m = eval_expr(expr, f)
        assert jnp.issubdtype(v.dtype, jnp.integer), expr
        assert np.asarray(v).tolist() == want
    # comparisons on big ints don't collapse through float32 either
    v, _ = eval_expr(alg.col("v") == alg.lit(big + 1), f)
    assert not bool(np.asarray(v)[0])
    # true division still promotes to float
    v, _ = eval_expr(alg.col("v") / alg.lit(2), f)
    assert jnp.issubdtype(v.dtype, jnp.floating)


def test_int_and_float_literals_do_not_collide_in_caches():
    """1 == 1.0 in Python, but the int path is exact where float32 rounds:
    plans differing only in literal *type* must have distinct cache keys."""
    assert alg.lit(1).key() != alg.lit(1.0).key()
    assert alg.lit(1).key() != alg.lit(True).key()

    big = 2 ** 24
    f = Frame.from_pydict({"v": [big]})
    store = {"f0": PartitionedFrame.from_frame(f)}
    src = alg.Source("f0", nrows=1, ncols=1)
    pa = alg.Selection(src, (alg.col("v") + alg.lit(1.0)) == alg.lit(float(big)))
    pb = alg.Selection(src, (alg.col("v") + alg.lit(1)) == alg.lit(big))
    assert pa.cache_key() != pb.cache_key()
    ex = Executor(store, optimize=True)
    got_a = ex.evaluate(pa).nrows   # float32: 2**24 + 1 rounds back to 2**24
    got_b = ex.evaluate(pb).nrows   # exact int: no match
    assert (got_a, got_b) == (1, 0)


# -----------------------------------------------------------------------------
# repartition edge cases: zero-row / zero-column frames and post-transpose
# row_domains through repartition / to_frame round trips
# -----------------------------------------------------------------------------
def test_zero_row_frames_survive_repartition_round_trips():
    f = _mk_frame(30)
    pf = PartitionedFrame.from_frame(f, row_parts=3)
    emptied = pf.map_blockwise(lambda b: b.filter_rows(np.zeros(b.nrows, bool)))
    assert emptied.nrows == 0
    for rp in (1, 2, 5):
        out = emptied.repartition(row_parts=rp)
        g = out.to_frame()
        assert g.nrows == 0
        assert g.col_labels.to_list() == f.col_labels.to_list()
    # column regroup over all-empty stripes keeps the (empty) row structure
    assert emptied.repartition(col_parts=2).to_frame().nrows == 0


def test_zero_col_frames_survive_repartition_round_trips():
    f = _mk_frame(20)
    squeezed = PartitionedFrame.from_frame(f, row_parts=2).map_blockwise(
        lambda b: b.take_cols([]))
    assert squeezed.ncols == 0 and squeezed.nrows == 20
    for rp in (1, 3):
        out = squeezed.repartition(row_parts=rp)
        g = out.to_frame()
        # fabricated empty cells must keep the stripe's row count and labels
        assert g.nrows == 20 and g.ncols == 0
        assert g.row_labels.to_list() == f.row_labels.to_list()
    assert squeezed.repartition(col_parts=3).to_frame().ncols == 0


def test_take_cols_preserves_row_domains():
    # take_cols used to index the per-ROW row_domains vector with COLUMN
    # positions: silent truncation when ncols ≤ nrows, IndexError as soon as
    # a column index reached nrows (any wider-than-tall post-transpose frame)
    f = Frame.from_pydict({"a": [1, 2], "b": [3, 4], "c": [5, 6]})
    doms = (Domain.INT, Domain.INT)
    g = Frame(f.columns, f.row_labels, f.col_labels, row_domains=doms)
    took = g.take_cols([1, 2])          # col index 2 ≥ nrows 2: used to raise
    assert took.row_domains == doms     # per-row vector rides along unchanged
    assert took.col_labels.to_list() == ["b", "c"]


def test_post_transpose_frame_repartitions_by_columns():
    # end-to-end: a wider-than-tall transpose output (row_domains set) through
    # a column regroup and a full round trip
    from repro.core.physical import _transpose

    f = Frame.from_pydict({c: [float(i), float(i + 10)]
                           for i, c in enumerate("abcde")})   # 2x5
    t = _transpose(PartitionedFrame.from_frame(f, 1, 1))       # 5x2
    t2 = _transpose(t)                                         # 2x5, row_domains len 2
    back = t2.to_frame()
    assert back.row_domains is not None and len(back.row_domains) == 2
    pf = PartitionedFrame.from_frame(back, 1, 2)               # used to IndexError
    assert pf.col_parts == 2
    round_tripped = pf.repartition(col_parts=1).to_frame().induce()
    np.testing.assert_allclose(
        np.asarray(round_tripped.as_matrix()[0]), np.asarray(f.as_matrix()[0]))
