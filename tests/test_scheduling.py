"""Adaptive block scheduling (``repro.core.schedule``).

Invariants:
  * coalesced dispatch is **bit-identical** to per-block dispatch — blocks are
    processed independently in block order, only the pool-task packaging
    changes (property-style sweeps over MAP/SELECTION/GROUPBY/WINDOW chains,
    grids both ≪ and ≫ the worker count);
  * every workload — including a single block — runs on pool workers, so
    exception provenance and thread-local state don't depend on the partition
    count (the old ``_pmap`` ran 1-item workloads inline on the caller);
  * ``default_grid`` sizes from the configured pool width
    (``REPRO_POOL_WORKERS``), not ``os.cpu_count()``;
  * plan-time grid adaptation (``preferred_row_parts``) only coarsens, only
    past 2× oversubscription, and fused plans stay bit-identical to unfused
    ones under it;
  * ``ExecStats.dispatches`` / ``dispatched_blocks`` attribute the coalescing
    win, and the PR-2 ``fused_stage_ops`` counter semantics hold unchanged
    under coalescing.
"""
import threading

import numpy as np
import pytest

from repro.core import algebra as alg
from repro.core import rewrite, schedule
from repro.core.dtypes import Domain
from repro.core.executor import ExecStats, Executor
from repro.core.frame import Column, Frame
from repro.core.partition import PartitionedFrame, default_grid
from repro.core.physical import _frames_bit_equal


@pytest.fixture
def fresh_pool(monkeypatch):
    """Rebuild the shared pool around a test that changes scheduling env."""
    schedule.reset_pool()
    yield monkeypatch
    schedule.reset_pool()


@pytest.fixture
def small_pool(monkeypatch):
    """Pin a 2-worker pool so the partitions ≫ workers regime is exercised
    regardless of the host's core count — without this, a many-core CI box
    would never coalesce or coarsen and the equivalence sweeps would compare
    two identical executions."""
    monkeypatch.setenv("REPRO_POOL_WORKERS", "2")
    schedule.reset_pool()
    yield monkeypatch
    schedule.reset_pool()


def _mk_frame(n, seed=3):
    rng = np.random.default_rng(seed)
    return Frame.from_pydict({
        "k": rng.integers(0, 6, n).tolist(),
        "v": rng.integers(-100, 100, n).tolist(),
        "x": rng.standard_normal(n).astype(np.float32).tolist(),
    })


def _scale(name="x"):
    def fn(cols, frame):
        out = dict(cols)
        c = cols[name]
        out[name] = Column(c.data * 2.0 + 1.0, Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name=f"sched_scale_{name}", fn=fn,
                   deps=frozenset([name]), elementwise=True)


# -----------------------------------------------------------------------------
# dispatch_blocks mechanics
# -----------------------------------------------------------------------------
def test_dispatch_returns_ordered_results_for_small_and_large_workloads():
    for n in (1, 3, 100):
        assert schedule.dispatch_blocks(lambda x: x * x, range(n)) == [
            i * i for i in range(n)]


def test_dispatch_coalesces_past_the_task_target():
    st = ExecStats()
    n = schedule.pool_width() * schedule.coalesce_factor() * 8
    out = schedule.dispatch_blocks(lambda x: x + 1, range(n), stats=st)
    assert out == list(range(1, n + 1))
    assert st.dispatched_blocks == n
    # pool tasks bounded by width × factor, NOT by the block count
    assert st.dispatches == schedule.pool_width() * schedule.coalesce_factor()
    assert st.blocks_per_dispatch == n / st.dispatches


def test_dispatch_stays_per_block_below_the_target_and_when_disabled(monkeypatch):
    st = ExecStats()
    few = schedule.pool_width()           # ≤ width × factor: one task per block
    schedule.dispatch_blocks(lambda x: x, range(few), stats=st)
    assert st.dispatches == st.dispatched_blocks == few

    monkeypatch.setenv("REPRO_COALESCE", "0")
    st2 = ExecStats()
    many = schedule.pool_width() * schedule.coalesce_factor() * 8
    schedule.dispatch_blocks(lambda x: x, range(many), stats=st2)
    assert st2.dispatches == st2.dispatched_blocks == many


def test_single_and_multi_block_workloads_share_the_worker_path():
    """Satellite bugfix: _pmap used to run 1-item workloads inline on the
    caller thread but multi-item workloads on pool workers — thread-local
    device state and exception provenance differed by partition count."""
    def where_am_i(_):
        return threading.current_thread().name

    solo = schedule.dispatch_blocks(where_am_i, [0])
    crowd = schedule.dispatch_blocks(where_am_i, range(40))
    for name in solo + crowd:
        assert name.startswith("repro-pool"), name
    assert not threading.current_thread().name.startswith("repro-pool")


@pytest.mark.parametrize("nblocks", [1, 40])
def test_exception_provenance_is_partition_count_independent(nblocks):
    class Boom(RuntimeError):
        pass

    def blow(i):
        if i == nblocks - 1:
            raise Boom(f"block {i}")
        return i

    with pytest.raises(Boom, match=f"block {nblocks - 1}"):
        schedule.dispatch_blocks(blow, range(nblocks))


def test_nested_dispatch_from_a_worker_runs_inline_instead_of_deadlocking():
    def outer(i):
        return schedule.dispatch_blocks(lambda j: (i, j), range(3))

    # saturate the pool with outer tasks, each dispatching again
    out = schedule.dispatch_blocks(outer, range(schedule.pool_width() * 4))
    assert out[0] == [(0, 0), (0, 1), (0, 2)]
    assert len(out) == schedule.pool_width() * 4


# -----------------------------------------------------------------------------
# pool-width plumbing (the default_grid regression)
# -----------------------------------------------------------------------------
def test_default_grid_sizes_from_configured_pool_width(fresh_pool):
    fresh_pool.setenv("REPRO_POOL_WORKERS", "4")
    # a frame big enough for 64 parts must still be capped at the POOL width,
    # no matter how many cores the host reports
    rp, _cp = default_grid(64 * 4096, 3)
    assert rp == 4
    assert schedule.pool_width() == 4
    assert schedule.get_pool()._max_workers == 4

    fresh_pool.setenv("REPRO_POOL_WORKERS", "16")
    schedule.reset_pool()
    rp, _cp = default_grid(64 * 4096, 3)
    assert rp == 16


def test_pool_width_reflects_built_pool_not_later_env(fresh_pool):
    fresh_pool.setenv("REPRO_POOL_WORKERS", "3")
    schedule.get_pool()
    fresh_pool.setenv("REPRO_POOL_WORKERS", "11")
    # the pool exists: grid decisions must describe the ACTUAL worker set
    assert schedule.pool_width() == 3


# -----------------------------------------------------------------------------
# plan-time grid sizing
# -----------------------------------------------------------------------------
def test_preferred_row_parts_policy(monkeypatch):
    w = schedule.pool_width()
    f = schedule.coalesce_factor()
    # mild oversubscription: keep the grid (coalesced dispatch absorbs it)
    assert schedule.preferred_row_parts(2 * w * f, "workers") == 2 * w * f
    # heavy oversubscription: coarsen to the preference target
    assert schedule.preferred_row_parts(2 * w * f + 1, "workers") == w * f
    assert schedule.preferred_row_parts(64 * w, "few_seams") == w
    # never splits, never adapts when told not to
    assert schedule.preferred_row_parts(1, "workers") == 1
    assert schedule.preferred_row_parts(64 * w, None) == 64 * w
    monkeypatch.setenv("REPRO_ADAPT_GRID", "0")
    assert schedule.preferred_row_parts(64 * w, "workers") == 64 * w


def test_fusion_pass_records_grid_preferences():
    src = alg.Source("f0", nrows=1000, ncols=3)
    gplan = alg.GroupBy(alg.Map(src, _scale()), ("k",), [("x", "sum", "xs")])
    fused, _ = rewrite.fuse_pipelines(gplan)
    assert fused.op == "fused_groupby"
    assert fused.params["grid"] == "workers"

    wplan = alg.Map(alg.Window(alg.Map(src, _scale()), "cumsum", ("x",)),
                    _scale())
    fusedw, _ = rewrite.fuse_pipelines(wplan)
    assert fusedw.op == "fused_window"
    assert fusedw.params["grid"] == "few_seams"


def test_blocking_outputs_regrid_to_pool_width():
    n = schedule.pool_width() * 8192
    pf = PartitionedFrame.from_frame(_mk_frame(n), row_parts=4)
    store = {"f0": pf}
    src = alg.Source("f0", nrows=n, ncols=3)
    ex = Executor(store, optimize=False)
    out = ex.evaluate(alg.Sort(src, ("v",)))
    # a big sorted result must not come back as one serializing block
    assert out.row_parts == schedule.pool_width()
    small = Executor(store, optimize=False).evaluate(
        alg.GroupBy(src, ("k",), [("x", "sum", "xs")]))
    assert small.row_parts == 1   # tiny results keep the old layout


# -----------------------------------------------------------------------------
# scheduling equivalence: coalesced ≡ per-block, adapted ≡ fixed — bit-exact
# -----------------------------------------------------------------------------
def _plans(src):
    ident = _scale()
    return {
        "map_chain": alg.Map(alg.Map(src, ident), ident),
        "map_filter": alg.Selection(alg.Map(src, ident),
                                    alg.col("v") > alg.lit(0)),
        "map_filter_groupby": alg.GroupBy(
            alg.Selection(alg.Map(src, ident), alg.col("v") > alg.lit(0)),
            ("k",), [("x", "sum", "xs"), ("x", "var", "xv"),
                     ("v", "count", "vc")]),
        "window_carry_chain": alg.Map(
            alg.Window(alg.Selection(src, alg.col("v") % alg.lit(3)
                                     > alg.lit(0)), "cumsum", ("x",)), ident),
        "rolling_seams": alg.Window(src, "rolling_mean", ("x",), 7),
    }


def _run(plan, store, optimize=True):
    ex = Executor(store, optimize=optimize)
    out = ex.evaluate(plan).to_frame().induce()
    return out, ex.stats


@pytest.mark.parametrize("row_parts", [2, 32])   # ≪ and ≫ the worker count
def test_coalesced_dispatch_is_bit_identical_to_per_block(small_pool, row_parts):
    monkeypatch = small_pool
    f = _mk_frame(6000)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=row_parts)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    for name, plan in _plans(src).items():
        coalesced, st = _run(plan, store)
        monkeypatch.setenv("REPRO_COALESCE", "0")
        per_block, st0 = _run(plan, store)
        monkeypatch.delenv("REPRO_COALESCE")
        assert _frames_bit_equal(coalesced, per_block), name
        assert st.dispatched_blocks == st0.dispatched_blocks, name
        if (row_parts > schedule.pool_width() * schedule.coalesce_factor()
                and name != "rolling_seams"):
            # rolling_seams regrids to "few_seams" before any dispatch, so
            # there is nothing left for coalescing to pack; every other plan
            # runs at least one pool round over the incoming grid
            assert st.dispatches < st0.dispatches, name


@pytest.mark.slow
@pytest.mark.parametrize("row_parts", [1, 2, 7, 32, 64])
def test_scheduling_equivalence_sweep(small_pool, row_parts):
    """The full sweep: coalesced-vs-per-block AND fused-vs-unfused, with grid
    adaptation both on and off, bit-exact everywhere (including the PR-2
    carry-composition seams)."""
    monkeypatch = small_pool
    f = _mk_frame(9000, seed=11)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=row_parts)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    for adapt in ("1", "0"):
        monkeypatch.setenv("REPRO_ADAPT_GRID", adapt)
        for name, plan in _plans(src).items():
            fused, _ = _run(plan, store, optimize=True)
            unfused, _ = _run(plan, store, optimize=False)
            monkeypatch.setenv("REPRO_COALESCE", "0")
            per_block, _ = _run(plan, store, optimize=True)
            monkeypatch.delenv("REPRO_COALESCE")
            assert _frames_bit_equal(fused, unfused), (name, adapt)
            assert _frames_bit_equal(fused, per_block), (name, adapt)


# -----------------------------------------------------------------------------
# out-of-core equivalence: grids {1, W, 4W} × budget {0, tiny} — bit-exact
# -----------------------------------------------------------------------------
def _mk_exact_frame(n, seed=3):
    """Like _mk_frame but with exactly-representable floats (k × 0.25), so
    bit-identity holds across ANY working grid — the budget floor in
    ``preferred_row_parts`` legitimately changes the grid, which reorders
    float partial combines; with exact data that reordering is lossless."""
    rng = np.random.default_rng(seed)
    return Frame.from_pydict({
        "k": rng.integers(0, 6, n).tolist(),
        "v": rng.integers(-100, 100, n).tolist(),
        "x": (rng.integers(0, 64, n) * 0.25).tolist(),
    })


@pytest.mark.spill
@pytest.mark.parametrize("grid_mult", [0, 1, 4])   # 0 → a single partition
def test_budget_equivalence_sweep(small_pool, grid_mult):
    """REPRO_MEM_BUDGET=0 (default) must keep the fully-resident fast path
    bit-identical to seed behaviour, and a tiny budget must still produce
    bit-identical results while actually spilling — over the same plan sweep
    the scheduling equivalence tests use, on grids {1, W, 4W}."""
    from repro.core.store import get_store, reset_store
    monkeypatch = small_pool
    w = schedule.pool_width()
    row_parts = max(1, grid_mult * w)
    f = _mk_exact_frame(8000, seed=13)

    def run_all(optimize):
        store = {"f0": PartitionedFrame.from_frame(f, row_parts=row_parts)}
        src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
        out = {}
        for name, plan in _plans(src).items():
            out[name] = _run(plan, store, optimize=optimize)
        return out

    monkeypatch.delenv("REPRO_MEM_BUDGET", raising=False)
    reset_store()
    try:
        ref = {k: v[0] for k, v in run_all(True).items()}
        assert get_store().stats.spills == 0     # fast path: untracked

        budget = max(f.nbytes() // 4, 1)
        monkeypatch.setenv("REPRO_MEM_BUDGET", str(budget))
        reset_store()
        for optimize in (True, False):
            got = run_all(optimize)
            for name, (frame_out, st) in got.items():
                assert _frames_bit_equal(frame_out, ref[name]), (
                    name, optimize, row_parts)
        if row_parts > 1:
            assert get_store().stats.spills > 0  # the budget engaged
    finally:
        reset_store()


# -----------------------------------------------------------------------------
# ExecStats plumbing + PR-2 counter semantics under coalescing
# -----------------------------------------------------------------------------
def test_executor_attributes_dispatches_and_fused_counters_still_hold(small_pool):
    f = _mk_frame(6000)
    store = {"f0": PartitionedFrame.from_frame(f, row_parts=32)}
    src = alg.Source("f0", nrows=f.nrows, ncols=f.ncols)
    plan = alg.GroupBy(
        alg.Selection(alg.Map(src, _scale()), alg.col("v") > alg.lit(0)),
        ("k",), [("x", "sum", "xs")])
    ex = Executor(store, optimize=True)
    ex.evaluate(plan)
    s = ex.stats
    assert s.dispatches > 0
    assert s.dispatched_blocks >= 32          # the staged producer sweep
    assert s.blocks_per_dispatch > 1.0        # coalescing actually engaged
    # PR-2 one-source-of-truth invariant, unchanged under coalescing
    pipeline_ops = sum(len(n.params["stages"])
                      for n in ex._prepared(plan).walk()
                      if n.op == "fused_pipeline")
    assert s.fused_stage_ops == (pipeline_ops + s.producer_stage_ops
                                 + s.consumer_stage_ops)
    assert s.producer_stage_ops == 2          # map + selection absorbed
