"""HLO analyzer: validated against XLA's own cost model on loop-free
programs, and against analytic counts on loops/collectives (deliverable (g)
substrate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import Roofline, analyze_hlo, xla_cost_analysis


def test_matmul_flops_match_cost_analysis():
    m = k = n = 512
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
    s = analyze_hlo(c.as_text())
    assert s.flops == 2 * m * k * n
    assert s.flops == xla_cost_analysis(c)["flops"]


def test_scan_loop_trip_multiplier():
    def scanned(x):
        def body(carry, _):
            return (carry @ carry) * 0.99, None
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out

    c = jax.jit(scanned).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    s = analyze_hlo(c.as_text())
    assert s.flops == 12 * 2 * 128 ** 3
    assert s.unresolved_loops == 0
    # XLA's own number counts the body once — the very bug we correct
    assert xla_cost_analysis(c)["flops"] < s.flops


def test_nested_loops_multiply():
    def inner(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    def outer(x):
        def body(c, _):
            return inner(c), None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out

    c = jax.jit(outer).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    s = analyze_hlo(c.as_text())
    assert s.flops == 15 * 2 * 64 ** 3


def test_einsum_batched_dot_flops():
    b, m, k, n = 4, 32, 64, 16
    f = jax.jit(lambda a, c: jnp.einsum("bmk,bkn->bmn", a, c))
    comp = f.lower(jax.ShapeDtypeStruct((b, m, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k, n), jnp.float32)).compile()
    s = analyze_hlo(comp.as_text())
    assert s.flops == 2 * b * m * k * n


def test_roofline_terms_and_bottleneck():
    rl = Roofline(hlo_flops=197e12, hlo_bytes=819e9 * 2, wire_bytes=0, chips=4,
                  model_flops=4 * 197e12 * 0.5)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(2.0)
    assert rl.bottleneck == "memory"
    assert rl.step_s == pytest.approx(2.0)
    assert rl.useful_flops_fraction == pytest.approx(0.5)
    assert rl.roofline_fraction == pytest.approx(0.25)


def test_fused_bytes_leq_raw_bytes():
    f = jax.jit(lambda a: jnp.tanh(a) + jnp.exp(a) * 2.0)
    c = f.lower(jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    s = analyze_hlo(c.as_text())
    assert s.hbm_bytes_fused <= s.hbm_bytes
