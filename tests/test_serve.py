"""Serving engine: continuous batching, slot reuse, drain, determinism."""
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config("yi-6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_drains_more_requests_than_slots(served):
    cfg, model, params = served
    eng = ServeEngine(model, params, max_batch=2, max_seq=64)
    for r in range(5):
        eng.submit(Request(rid=r, prompt_ids=[1, 4 + r, 7], max_new_tokens=4))
    eng.run_until_done()
    assert eng.metrics["tokens_out"] <= 5 * 4
    assert eng.metrics["tokens_out"] > 0
    assert all(s is None for s in eng.slots)


def test_greedy_decode_deterministic(served):
    cfg, model, params = served

    def run_once():
        eng = ServeEngine(model, params, max_batch=2, max_seq=64)
        req = Request(rid=0, prompt_ids=[1, 9, 12, 5], max_new_tokens=6)
        eng.submit(req)
        eng.run_until_done()
        return req.out_ids

    assert run_once() == run_once()


def test_batching_does_not_change_output(served):
    """A request decoded alone == the same request decoded alongside others
    (slot isolation: lengths/caches must not leak across slots)."""
    cfg, model, params = served
    prompt = [1, 9, 12, 5]

    eng1 = ServeEngine(model, params, max_batch=4, max_seq=64)
    r_alone = Request(rid=0, prompt_ids=prompt, max_new_tokens=5)
    eng1.submit(r_alone)
    eng1.run_until_done()

    eng2 = ServeEngine(model, params, max_batch=4, max_seq=64)
    r_mixed = Request(rid=0, prompt_ids=prompt, max_new_tokens=5)
    eng2.submit(Request(rid=1, prompt_ids=[2, 3], max_new_tokens=5))
    eng2.submit(r_mixed)
    eng2.submit(Request(rid=2, prompt_ids=[8, 8, 8], max_new_tokens=5))
    eng2.run_until_done()

    assert r_alone.out_ids == r_mixed.out_ids
