"""Training substrate: optimizers, microbatching equivalence, checkpoint
roundtrip/atomicity, failure recovery, straggler policy."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataPipeline, PipelineConfig, synthetic_corpus
from repro.models import build_model
from repro.train import CheckpointManager, adafactor, adamw
from repro.train.fault import FailurePlan, StragglerPolicy, run_with_recovery
from repro.train.trainer import TrainConfig, Trainer, init_state, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    return cfg, model


def _batch(cfg, b=4, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    return {"tokens": tokens, "labels": tokens,
            "mask": jnp.ones((b, s), jnp.float32)}


def test_loss_decreases(tiny):
    cfg, model = tiny
    opt = adamw()
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step = make_train_step(model, opt, lambda s: 1e-3, donate=False)
    batch = _batch(cfg)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_adafactor_runs_and_reduces(tiny):
    cfg, model = tiny
    opt = adafactor()
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step = make_train_step(model, opt, lambda s: 1e-2, donate=False)
    batch = _batch(cfg)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    # factored states really are factored (no full second moment for matrices)
    v = state["opt"]["v"]["embed"]
    assert set(v.keys()) == {"vr", "vc"}


@pytest.mark.slow
def test_microbatch_equivalence(tiny):
    """grad accumulation over 4 microbatches == single full-batch step."""
    cfg, model = tiny
    opt = adamw()
    batch = _batch(cfg, b=8)
    s1 = init_state(model, jax.random.PRNGKey(0), opt)
    s2 = jax.tree.map(lambda x: x, s1)
    step1 = make_train_step(model, opt, lambda s: 1e-3, microbatches=1, donate=False)
    step4 = make_train_step(model, opt, lambda s: 1e-3, microbatches=4, donate=False)
    o1, m1 = step1(s1, batch)
    o4, m4 = step4(s2, batch)
    # losses agree;  params agree to accumulation tolerance
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
    a = jax.tree.leaves(o1["params"])[0].astype(jnp.float32)
    b = jax.tree.leaves(o4["params"])[0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)


def test_checkpoint_roundtrip_and_gc(tiny):
    cfg, model = tiny
    opt = adamw()
    state = init_state(model, jax.random.PRNGKey(0), opt)
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=2)
        for step in (1, 2, 3, 4):
            mgr.save(step, state, extra={"cursor": step * 10}, blocking=True)
        assert mgr.latest_step() == 4
        # GC keeps only the last 2
        kept = sorted(n for n in os.listdir(td) if n.startswith("step_"))
        assert kept == ["step_3", "step_4"]
        restored, extra = mgr.restore(state)
        assert extra["cursor"] == 40
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, dtype=np.float32),
                                          np.asarray(b, dtype=np.float32))


def test_checkpoint_atomicity_no_tmp_left(tiny):
    cfg, model = tiny
    opt = adamw()
    state = init_state(model, jax.random.PRNGKey(0), opt)
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(7, state, blocking=True)
        assert not any(n.endswith(".tmp") for n in os.listdir(td))


def test_recovery_resumes_with_exact_cursor(tiny):
    cfg, model = tiny
    corpus = synthetic_corpus(400, seed=5, mean_len=30)
    pc = PipelineConfig(seq_len=16, global_batch=4, shard_docs=100)
    with tempfile.TemporaryDirectory() as td:
        tc = TrainConfig(lr=1e-3, total_steps=12, checkpoint_dir=td,
                         checkpoint_every=3, log_every=100)
        trainer = Trainer(model, tc)
        plan = FailurePlan(fail_at_steps=(8,))

        def source():
            return DataPipeline(corpus, cfg.vocab, pc).batches()

        state = run_with_recovery(trainer, source, steps=10, failure_plan=plan)
        assert int(state["step"]) >= 10


def test_straggler_policy_scales_with_jitter():
    pol = StragglerPolicy()
    steady = [1.0] * 32
    jittery = [1.0, 1.0, 1.0, 4.0] * 8
    assert pol.recommend_depth(steady) <= pol.recommend_depth(jittery)
    assert pol.min_depth <= pol.recommend_depth(jittery) <= pol.max_depth
