"""Evaluation modes (paper §6.1) + sharing/reuse (§6.2): opportunistic
background computation, prefix computation for head(k), materialization
cache, multi-query dedupe."""
import time

import numpy as np
import pytest

from repro.core import DataFrame, EvalMode, Session, set_session
from repro.core import algebra as alg


def test_lazy_defers_eager_computes():
    s = set_session(Session(mode=EvalMode.LAZY, default_row_parts=2))
    try:
        d = DataFrame({"v": list(range(1000))})
        filtered = d[d["v"] > 10]
        assert s.executor.stats.evaluated_nodes == 0  # nothing ran yet
        out = filtered.collect()
        assert out.nrows == 989
        assert s.executor.stats.evaluated_nodes > 0
    finally:
        s.close()


def test_opportunistic_background_computation():
    s = set_session(Session(mode=EvalMode.OPPORTUNISTIC, default_row_parts=2))
    try:
        d = DataFrame({"v": list(range(2000))})
        filtered = d[d["v"] % 1 == 0]  # statement scheduled in background
        deadline = time.monotonic() + 5.0
        node = s.executor.optimized(filtered._node)
        while time.monotonic() < deadline:
            if node.cache_key() in s.executor.cache:
                break
            time.sleep(0.01)
        assert node.cache_key() in s.executor.cache, "background eval never landed"
        # the inspect is then a cache hit
        before = s.executor.stats.cache_hits
        filtered.collect()
        assert s.executor.stats.cache_hits > before
    finally:
        s.close()


def test_prefix_computation_head(lazy_session):
    s = lazy_session
    d = DataFrame({"v": list(range(100_000)), "w": [float(i % 5) for i in range(100_000)]})
    sel = d[d["v"] > 50]
    out = sel.head(4)
    assert out.col("v").to_pylist() == [51, 52, 53, 54]
    assert s.executor.stats.prefix_evals >= 1
    # prefix path must not have evaluated the full plan
    full_key = s.executor.optimized(sel._node).cache_key()
    assert full_key not in s.executor.cache


def test_prefix_geometric_backoff_selective_filter(lazy_session):
    s = lazy_session
    # only the last rows pass the filter: prefix must back off to the full scan
    d = DataFrame({"v": list(range(20_000))})
    sel = d[d["v"] >= 19_998]
    out = sel.head(2)
    assert out.col("v").to_pylist() == [19998, 19999]


def test_reuse_cache_and_mqo_shared_subplans(lazy_session):
    s = lazy_session
    d = DataFrame({"k": ["a", "b"] * 500, "v": list(range(1000))})
    base = d[d["v"] > 10]                       # shared sub-expression
    q1 = base.groupby("k").agg({"v": "sum"})
    q2 = base.groupby("k").agg({"v": "mean"})
    q1.collect()
    evaluated_before = s.executor.stats.evaluated_nodes
    q2.collect()                                # shares SELECTION result
    # q2 only evaluates its groupby node, not the selection chain again
    assert s.executor.stats.cache_hits >= 1
    assert s.executor.stats.evaluated_nodes - evaluated_before <= 2


def test_cache_budget_eviction():
    s = set_session(Session(mode=EvalMode.LAZY, default_row_parts=2,
                            cache_budget_bytes=50_000))
    try:
        d = DataFrame({"v": list(range(30_000))})
        for off in range(6):
            d[d["v"] > off].collect()
        assert s.executor.cache_bytes() <= 50_000 * 3  # sources exempt; bounded
    finally:
        s.close()


def test_tail(lazy_session):
    d = DataFrame({"v": list(range(1000))})
    assert d.tail(3).col("v").to_pylist() == [997, 998, 999]
