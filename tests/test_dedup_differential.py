"""Pandas-differential property suite for DIFFERENCE / DROP-DUPLICATES — the
gate for the block-parallel + barrier-fused paths (PR 4).

Properties asserted for every generated case:

  * **pandas oracle** — results are value- and index-identical to pandas
    (``drop_duplicates`` directly; a pandas-mediated full-row anti-join for
    DIFFERENCE, which pandas does not expose as one call);
  * **grid invariance** — identical across partition grids of 1, ``workers``
    and ``4 × workers`` row blocks;
  * **plan invariance** — identical between fused (``optimize=True``) and
    per-node (``optimize=False``) plans, and between the block-parallel path
    and the serial seed path (``REPRO_BLOCK_DEDUP=0``).

Cases mix int / float / coded columns, null masks, duplicate-heavy and
duplicate-free distributions, and the 0-row / 0-col edges.  Floats are
float32-exact so value equality against the oracle is bitwise.

Runs property-based through hypothesis when it is installed; the seeded
parametrized sweep below covers the same generator deterministically either
way, so this gate never goes vacuous on a container without dev extras.
"""
from __future__ import annotations

import numpy as np
import pandas as pd
import pytest

from repro.core import algebra as alg
from repro.core import schedule
from repro.core.dtypes import Domain
from repro.core.executor import Executor
from repro.core.frame import Column, Frame
from repro.core.labels import RangeLabels, labels_from_values
from repro.core.partition import PartitionedFrame

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# =============================================================================
# case generation (shared by the seeded sweep and the hypothesis properties)
# =============================================================================
_STRINGS = ["aa", "bb", "cc", "dd", "ee", "ff", "gg", "hh"]
_FLOATS = [float(np.float32(x)) for x in
           (0.5, -1.25, 3.75, 7.125, -0.625, 2.5, 9.875, -4.5)]


def _gen_column(rng: np.random.Generator, kind: str, nrows: int,
                pool: int, null_p: float) -> list:
    """One host column: values drawn from a ``pool``-sized alphabet (small
    pool ⇒ duplicate-heavy, large ⇒ mostly duplicate-free), nulls injected
    with probability ``null_p``."""
    if kind == "int":
        vals = rng.integers(0, max(pool, 1), nrows).tolist()
    elif kind == "float":
        vals = [_FLOATS[i % len(_FLOATS)]
                for i in rng.integers(0, max(pool, 1), nrows)]
    else:  # coded
        vals = [_STRINGS[i % len(_STRINGS)]
                for i in rng.integers(0, max(pool, 1), nrows)]
    if null_p > 0:
        nulls = rng.random(nrows) < null_p
        vals = [None if n else v for v, n in zip(vals, nulls)]
    return vals


_KINDS = ("int", "float", "coded")
_DOMS = {"int": Domain.INT, "float": Domain.FLOAT, "coded": Domain.STR}


def _gen_case(seed: int, *, dup_heavy: bool | None = None,
              nrows: int | None = None) -> tuple[dict, list]:
    """(data dict, domains) for one random frame."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 60)) if nrows is None else nrows
    ncols = int(rng.integers(1, 5))
    heavy = bool(rng.integers(0, 2)) if dup_heavy is None else dup_heavy
    pool = 3 if heavy else 50
    null_p = float(rng.choice([0.0, 0.15, 0.4]))
    data, domains = {}, []
    for j in range(ncols):
        kind = _KINDS[int(rng.integers(0, len(_KINDS)))]
        data[f"c{j}_{kind}"] = _gen_column(rng, kind, n, pool, null_p)
        domains.append(_DOMS[kind])
    return data, domains


def _grids() -> tuple[int, ...]:
    w = schedule.pool_width()
    return (1, w, 4 * w)


# =============================================================================
# oracles (pandas-mediated) and result comparison
# =============================================================================
def _to_pandas(data: dict) -> pd.DataFrame:
    # object dtype: no int→float coercion under nulls, None stays None, and
    # drop_duplicates hashes the exact python values our frames round-trip
    if not data:
        return pd.DataFrame()
    return pd.DataFrame({k: pd.Series(v, dtype=object)
                         for k, v in data.items()})


def _pd_lists(pdf: pd.DataFrame) -> tuple[list, dict]:
    return list(pdf.index), {c: list(pdf[c]) for c in pdf.columns}


def _frame_lists(f: Frame) -> tuple[list, dict]:
    return f.row_labels.to_list(), f.to_pydict()


def _oracle_dedup(data: dict, subset) -> tuple[list, dict]:
    pdf = _to_pandas(data)
    out = pdf.drop_duplicates(subset=list(subset)) if subset else (
        pdf.drop_duplicates())
    return _pd_lists(out)


def _oracle_difference(ldata: dict, rdata: dict) -> tuple[list, dict]:
    """Full-row anti-join through pandas: left rows whose value tuple appears
    in the right input are dropped (null == null, as in pandas ``isin`` /
    ``duplicated`` hashing); survivors keep left order and index."""
    lp, rp = _to_pandas(ldata), _to_pandas(rdata)
    rset = set(rp.itertuples(index=False, name=None))
    keep = [t not in rset for t in lp.itertuples(index=False, name=None)]
    if lp.shape[1] == 0:
        keep = [len(rp) == 0] * len(lp)
    return _pd_lists(lp[np.asarray(keep, dtype=bool)] if len(lp) else lp)


def _assert_result(got: Frame, expected: tuple[list, dict], ctx: str) -> None:
    gi, gc = _frame_lists(got)
    ei, ec = expected
    assert gi == ei, f"{ctx}: row labels {gi} != {ei}"
    assert list(gc) == list(ec), f"{ctx}: columns {list(gc)} != {list(ec)}"
    for name in ec:
        assert gc[name] == ec[name], f"{ctx}/{name}: {gc[name]} != {ec[name]}"


def _sweep(plan_of, frames: dict[str, Frame], expected, ctx: str,
           monkeypatch=None) -> None:
    """Evaluate ``plan_of()`` against the oracle across partition grids ×
    fused/unfused plans (× the serial seed path when ``monkeypatch`` is
    given) — the full invariance matrix of the suite docstring."""
    for rp in _grids():
        store = {fid: PartitionedFrame.from_frame(f, row_parts=rp)
                 for fid, f in frames.items()}
        for optimize in (True, False):
            got = Executor(store, optimize=optimize).evaluate(plan_of()).to_frame()
            _assert_result(got, expected, f"{ctx}[grid={rp},opt={optimize}]")
        if monkeypatch is not None:
            monkeypatch.setenv("REPRO_BLOCK_DEDUP", "0")
            try:
                got = Executor(store).evaluate(plan_of()).to_frame()
            finally:
                monkeypatch.delenv("REPRO_BLOCK_DEDUP")
            _assert_result(got, expected, f"{ctx}[grid={rp},serial]")


# =============================================================================
# the property cores
# =============================================================================
def _check_dedup(seed: int, monkeypatch=None, subset_from_seed: bool = False,
                 **gen_kw) -> None:
    data, domains = _gen_case(seed, **gen_kw)
    subset = None
    if subset_from_seed:
        names = list(data)
        k = 1 + seed % len(names)
        subset = tuple(names[:k])
    expected = _oracle_dedup(data, subset)
    f = Frame.from_pydict(data, domains=domains)
    plan = lambda: alg.DropDuplicates(alg.Source("src"),
                                      list(subset) if subset else None)
    _sweep(plan, {"src": f}, expected, f"dedup[seed={seed},subset={subset}]",
           monkeypatch)


def _check_difference(seed: int, monkeypatch=None) -> None:
    # both sides drawn duplicate-heavy from the same pools so overlap is real
    ldata, ldom = _gen_case(seed, dup_heavy=True)
    rng = np.random.default_rng(seed + 10_000)
    n_r = int(rng.integers(0, 40))
    rdata = {}
    for name, vals in ldata.items():
        kind = name.split("_")[-1]
        rdata[name] = _gen_column(rng, kind, n_r, 3,
                                  0.3 if any(v is None for v in vals) else 0.0)
    expected = _oracle_difference(ldata, rdata)
    lf = Frame.from_pydict(ldata, domains=ldom)
    rf = Frame.from_pydict(rdata, domains=ldom)
    if rf.nrows == 0:   # PartitionedFrame requires ≥1 (possibly 0-row) block
        rf = Frame([Column(np.zeros(0, dtype=np.float32), d) for d in ldom],
                   RangeLabels(0), labels_from_values(list(ldata)))
    plan = lambda: alg.Difference(alg.Source("l"), alg.Source("r"))
    _sweep(plan, {"l": lf, "r": rf}, expected, f"difference[seed={seed}]",
           monkeypatch)


# =============================================================================
# seeded deterministic sweep (the always-on gate)
# =============================================================================
@pytest.mark.parametrize("seed", range(10))
def test_dedup_matches_pandas(seed, monkeypatch):
    _check_dedup(seed, monkeypatch)


@pytest.mark.parametrize("seed", range(10))
def test_difference_matches_pandas(seed, monkeypatch):
    _check_difference(seed, monkeypatch)


@pytest.mark.parametrize("seed", range(6))
def test_dedup_subset_matches_pandas(seed, monkeypatch):
    _check_dedup(seed + 100, monkeypatch, subset_from_seed=True)


@pytest.mark.parametrize("seed", (3, 17))
def test_dedup_duplicate_free(seed, monkeypatch):
    _check_dedup(seed, monkeypatch, dup_heavy=False, nrows=40)


@pytest.mark.parametrize("seed", (5, 23))
def test_dedup_duplicate_heavy(seed, monkeypatch):
    _check_dedup(seed, monkeypatch, dup_heavy=True, nrows=50)


# ---- hypothesis: the same properties, adversarially driven ------------------
if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_hypothesis_dedup_matches_pandas(seed):
        _check_dedup(seed)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_hypothesis_difference_matches_pandas(seed):
        _check_difference(seed)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_hypothesis_dedup_subset(seed):
        _check_dedup(seed, subset_from_seed=True)


# =============================================================================
# edges: 0-row / 0-col
# =============================================================================
def _empty_cols_frame(nrows: int) -> Frame:
    return Frame([], RangeLabels(nrows), labels_from_values([]))


def test_dedup_zero_rows(monkeypatch):
    data = {"k": [], "x": []}
    f = Frame.from_pydict(data, domains=[Domain.INT, Domain.FLOAT])
    _sweep(lambda: alg.DropDuplicates(alg.Source("src"), None), {"src": f},
           _oracle_dedup(data, None), "dedup-0row", monkeypatch)


def test_dedup_zero_cols(monkeypatch):
    # pandas keeps EVERY row of a column-less frame (nothing to compare)
    f = _empty_cols_frame(4)
    expected = _pd_lists(pd.DataFrame(index=range(4)).drop_duplicates())
    assert expected[0] == [0, 1, 2, 3]
    _sweep(lambda: alg.DropDuplicates(alg.Source("src"), None), {"src": f},
           expected, "dedup-0col", monkeypatch)


def test_difference_zero_rows_left(monkeypatch):
    z = Frame.from_pydict({"k": [], "x": []}, domains=[Domain.INT, Domain.FLOAT])
    r = Frame.from_pydict({"k": [1], "x": [0.5]}, domains=[Domain.INT, Domain.FLOAT])
    _sweep(lambda: alg.Difference(alg.Source("l"), alg.Source("r")),
           {"l": z, "r": r},
           _oracle_difference({"k": [], "x": []}, {"k": [1], "x": [0.5]}),
           "diff-0row-left", monkeypatch)


def test_difference_empty_right_keeps_left(monkeypatch):
    ldata = {"k": [1, 2, 2], "x": [0.5, 1.5, 1.5]}
    l = Frame.from_pydict(ldata, domains=[Domain.INT, Domain.FLOAT])
    r = Frame.from_pydict({"k": [], "x": []}, domains=[Domain.INT, Domain.FLOAT])
    _sweep(lambda: alg.Difference(alg.Source("l"), alg.Source("r")),
           {"l": l, "r": r}, _oracle_difference(ldata, {"k": [], "x": []}),
           "diff-empty-right", monkeypatch)


def test_difference_zero_cols():
    # no attributes ⇒ every left row matches the (empty) right tuple
    store = {"l": PartitionedFrame.from_frame(_empty_cols_frame(3)),
             "r": PartitionedFrame.from_frame(_empty_cols_frame(2))}
    out = Executor(store).evaluate(
        alg.Difference(alg.Source("l"), alg.Source("r"))).to_frame()
    assert out.shape == (0, 0)


# =============================================================================
# null-key semantics (null == null, like pandas hashing)
# =============================================================================
def test_dedup_null_keys(monkeypatch):
    data = {"k": [None, 1, None, 1, None], "s": ["aa", None, "aa", None, "bb"]}
    f = Frame.from_pydict(data, domains=[Domain.INT, Domain.STR])
    _sweep(lambda: alg.DropDuplicates(alg.Source("src"), None), {"src": f},
           _oracle_dedup(data, None), "dedup-nulls", monkeypatch)


def test_difference_null_keys(monkeypatch):
    ldata = {"k": [None, 1, 2], "x": [0.5, None, 1.5]}
    rdata = {"k": [None, 2], "x": [0.5, 1.5]}
    l = Frame.from_pydict(ldata, domains=[Domain.INT, Domain.FLOAT])
    r = Frame.from_pydict(rdata, domains=[Domain.INT, Domain.FLOAT])
    _sweep(lambda: alg.Difference(alg.Source("l"), alg.Source("r")),
           {"l": l, "r": r}, _oracle_difference(ldata, rdata),
           "diff-nulls", monkeypatch)


# =============================================================================
# coded columns: cross-dictionary equality + subset naming a coded column
# =============================================================================
def test_difference_cross_dictionary_coded(monkeypatch):
    """Same string values, different dictionary orders on the two inputs:
    equality must hold value-wise, not code-wise."""
    ldata = {"s": ["aa", "bb", "cc", "bb"], "k": [1, 2, 3, 2]}
    rdata = {"s": ["cc", "bb"], "k": [3, 2]}   # first-occurrence order differs
    l = Frame.from_pydict(ldata, domains=[Domain.STR, Domain.INT])
    r = Frame.from_pydict(rdata, domains=[Domain.STR, Domain.INT])
    assert l.col("s").dictionary != r.col("s").dictionary
    _sweep(lambda: alg.Difference(alg.Source("l"), alg.Source("r")),
           {"l": l, "r": r}, _oracle_difference(ldata, rdata),
           "diff-crossdict", monkeypatch)


def test_difference_cross_dictionary_disjoint_values(monkeypatch):
    ldata = {"s": ["aa", "bb", "aa"]}
    rdata = {"s": ["zz", "bb"]}    # partially disjoint tables
    l = Frame.from_pydict(ldata, domains=[Domain.STR])
    r = Frame.from_pydict(rdata, domains=[Domain.STR])
    _sweep(lambda: alg.Difference(alg.Source("l"), alg.Source("r")),
           {"l": l, "r": r}, _oracle_difference(ldata, rdata),
           "diff-disjointdict", monkeypatch)


def test_dedup_subset_coded_column(monkeypatch):
    data = {"s": ["aa", "bb", "aa", None, "bb", None],
            "x": [0.5, 1.5, 2.5, 3.5, 4.5, 5.5]}
    f = Frame.from_pydict(data, domains=[Domain.STR, Domain.FLOAT])
    _sweep(lambda: alg.DropDuplicates(alg.Source("src"), ["s"]), {"src": f},
           _oracle_dedup(data, ("s",)), "dedup-subset-coded", monkeypatch)


# =============================================================================
# int64 → float64 precision regression (keys 2**53 and 2**53 + 1)
# =============================================================================
def _wide_frame(values: list, extra: dict | None = None) -> Frame:
    cols = [Column(np.asarray(values, dtype=np.int64), Domain.INT)]
    names = ["k"]
    for n, (vals, dom) in (extra or {}).items():
        cols.append(Column(np.asarray(vals), dom))
        names.append(n)
    return Frame(cols, RangeLabels(len(values)), labels_from_values(names))


def test_wide_int_dedup_distinguishes_above_2_53(monkeypatch):
    f = _wide_frame([2**53, 2**53 + 1, 2**53, 2**53 + 1])
    for rp in _grids():
        store = {"src": PartitionedFrame.from_frame(f, row_parts=rp)}
        out = Executor(store).evaluate(
            alg.DropDuplicates(alg.Source("src"), None)).to_frame()
        assert out.col("k").to_pylist() == [2**53, 2**53 + 1], rp
        monkeypatch.setenv("REPRO_BLOCK_DEDUP", "0")
        try:
            ser = Executor(store).evaluate(
                alg.DropDuplicates(alg.Source("src"), None)).to_frame()
        finally:
            monkeypatch.delenv("REPRO_BLOCK_DEDUP")
        assert ser.col("k").to_pylist() == [2**53, 2**53 + 1], rp


def test_wide_int_difference_narrow_other_side():
    # the RIGHT side alone wouldn't flag the column wide — the joint decision
    # across both inputs (and across blocks) must still hash consistently
    l = _wide_frame([2**53, 2**53 + 1, 5])
    r = _wide_frame([2**53, 5])
    for rp in _grids():
        store = {"l": PartitionedFrame.from_frame(l, row_parts=rp),
                 "r": PartitionedFrame.from_frame(r, row_parts=1)}
        out = Executor(store).evaluate(
            alg.Difference(alg.Source("l"), alg.Source("r"))).to_frame()
        assert out.col("k").to_pylist() == [2**53 + 1], rp


def test_wide_int_join_no_false_match():
    l = _wide_frame([2**53, 2**53 + 1],
                    extra={"x": ([1.0, 2.0], Domain.FLOAT)})
    r = _wide_frame([2**53], extra={"y": ([9.0], Domain.FLOAT)})
    store = {"l": PartitionedFrame.from_frame(l),
             "r": PartitionedFrame.from_frame(r)}
    out = Executor(store).evaluate(
        alg.Join(alg.Source("l"), alg.Source("r"), on=["k"],
                 how="inner")).to_frame()
    assert out.col("k").to_pylist() == [2**53]
    assert out.col("x").to_pylist() == [1.0]


def test_wide_int_against_float_column_keeps_fractional_distinct():
    """A wide-flagged position shared with a FLOAT column must not truncate
    the floats: 1.5 on the right equals NOTHING on an integer left, while an
    integral 5.0 still equals int 5."""
    l = _wide_frame([1, 5, 2**53 + 1])
    r = Frame([Column(np.asarray([1.5, 5.0], dtype=np.float32), Domain.FLOAT)],
              RangeLabels(2), labels_from_values(["k"]))
    for rp in (1, 2, 3):
        store = {"l": PartitionedFrame.from_frame(l, row_parts=rp),
                 "r": PartitionedFrame.from_frame(r, row_parts=1)}
        out = Executor(store).evaluate(
            alg.Difference(alg.Source("l"), alg.Source("r"))).to_frame()
        # 5 == 5.0 drops; 1 != 1.5 and 2**53+1 survive
        assert out.col("k").to_pylist() == [1, 2**53 + 1], rp


def test_wide_int_join_against_float_no_truncated_match():
    l = _wide_frame([1, 2**53 + 1], extra={"x": ([1.0, 2.0], Domain.FLOAT)})
    r = Frame([Column(np.asarray([1.5], dtype=np.float32), Domain.FLOAT),
               Column(np.asarray([9.0], dtype=np.float32), Domain.FLOAT)],
              RangeLabels(1), labels_from_values(["k", "y"]))
    store = {"l": PartitionedFrame.from_frame(l),
             "r": PartitionedFrame.from_frame(r)}
    out = Executor(store).evaluate(
        alg.Join(alg.Source("l"), alg.Source("r"), on=["k"],
                 how="inner")).to_frame()
    assert out.nrows == 0    # 1 != 1.5 — an int64 cast would have matched


def test_wide_int_column_selection_exact():
    """Directly-constructed int64 host columns compare exactly in selections
    — both the interpreted path and the fused predicate-chain path (which
    must refuse the jit boundary: a jax literal/trace would truncate them
    through int32).  Ingest stays LOUD: `parse_column` refuses beyond-int32
    integers rather than storing something device paths would corrupt."""
    from repro.core.dtypes import parse_column
    with pytest.raises(OverflowError):
        parse_column([2**53, 2**53 + 1, 7])
    f = _wide_frame([2**53, 2**53 + 1, 7])
    store = {"s": PartitionedFrame.from_frame(f)}
    out = Executor(store).evaluate(
        alg.Selection(alg.Source("s"), alg.col("k") > alg.lit(8))).to_frame()
    assert out.col("k").to_pylist() == [2**53, 2**53 + 1]
    chain = alg.Selection(alg.Selection(alg.Source("s"),
                                        alg.col("k") > alg.lit(8)),
                          alg.col("k") < alg.lit(2**53 + 1))
    out2 = Executor(store, optimize=True).evaluate(chain).to_frame()
    assert out2.col("k").to_pylist() == [2**53]


def test_wide_int_binops_numpy_semantics():
    """Predicates over a wide int64 host column follow numpy semantics: the
    pair is pinned to host numpy (mixed np/jax ops would canonicalize the
    wide side through int32), including %, //, comparisons against int32
    device columns and against float literals."""
    import jax.numpy as jnp
    vals = np.asarray([2**40 + 3, 2**40 + 4, 7], dtype=np.int64)
    # n holds exactly the int32 truncation artifacts of k's wide values: a
    # truncating comparison would "equal" every row, the exact path none
    f = Frame([Column(vals, Domain.INT),
               Column(jnp.asarray([3, 4, 7], dtype=jnp.int32), Domain.INT)],
              RangeLabels(3), labels_from_values(["k", "n"]))
    store = {"g": PartitionedFrame.from_frame(f)}

    def sel(pred):
        return Executor(store).evaluate(
            alg.Selection(alg.Source("g"), pred)).to_frame().col("k").to_pylist()

    assert sel((alg.col("k") % alg.lit(10)) == alg.lit(9)) == \
        vals[(vals % 10) == 9].tolist()
    assert sel((alg.col("k") // alg.lit(2**20)) == alg.lit(2**20)) == \
        vals[(vals // 2**20) == 2**20].tolist()
    # wide vs int32 device column: 2**40+3 == 3 must NOT match (truncation)
    assert sel(alg.col("k") == alg.col("n")) == [7]
    # wide vs fractional float literal: numpy promotion (float64)
    ref = vals > np.float32(2**40 + 3.5)
    assert sel(alg.col("k") > alg.lit(float(2**40 + 3.5))) == vals[ref].tolist()
    # zero divisors null out, with no host-path warnings/crashes
    assert sel((alg.col("k") % alg.lit(0)).notna()) == []


def test_factorization_tasks_not_counted_as_row_blocks():
    """Per-column factorization pool tasks must not pollute the row-block
    scheduling counters (`dispatched_blocks` attributes coalescing)."""
    f = Frame.from_pydict({"k": [1, 2, 1, 2], "v": [1.5, 2.5, 1.5, 2.5],
                           "s": ["ax", "bx", "ax", "bx"]})
    store = {"s": PartitionedFrame.from_frame(f, row_parts=2)}
    ex = Executor(store)
    ex.evaluate(alg.DropDuplicates(alg.Source("s"), None))
    assert ex.stats.dispatched_blocks == 4   # 2 key blocks + 2 filter blocks
    assert ex.stats.dedup_blocks == 2


def test_wide_int_groupby_distinct_groups():
    f = _wide_frame([0, 2**53, 2**53 + 1, 2**53],
                    extra={"v": ([1.0, 2.0, 3.0, 4.0], Domain.FLOAT)})
    store = {"g": PartitionedFrame.from_frame(f, row_parts=2)}
    out = Executor(store).evaluate(
        alg.GroupBy(alg.Source("g"), ("k",), [("v", "sum", "vs")])).to_frame()
    assert out.col("k").to_pylist() == [0, 2**53, 2**53 + 1]
    assert out.col("vs").to_pylist() == [1.0, 6.0, 3.0]


# =============================================================================
# fused ≡ unfused through producer/consumer chains (+ counters)
# =============================================================================
def _scale_udf(name: str = "x") -> alg.Udf:
    def fn(cols, frame):
        out = dict(cols)
        c = cols[name]
        # ×2 is exact in float32 AND float64 → the pandas mirror is trivial
        out[name] = Column(c.data * np.float32(2.0), Domain.FLOAT, c.mask, None)
        return out
    return alg.Udf(name=f"dedup_diff_scale_{name}", fn=fn,
                   deps=frozenset([name]), elementwise=True)


def _chain_case(seed: int) -> tuple[dict, list]:
    rng = np.random.default_rng(seed)
    n = 40
    return {
        "k": _gen_column(rng, "int", n, 3, 0.1),
        "x": _gen_column(rng, "float", n, 3, 0.1),
        "s": _gen_column(rng, "coded", n, 3, 0.1),
    }, [Domain.INT, Domain.FLOAT, Domain.STR]


def _pd_chain_dedup(data: dict) -> tuple[list, dict]:
    """pandas mirror of map(x*2) → filter(k>0) → drop_duplicates."""
    mapped = dict(data, x=[None if v is None else v * 2 for v in data["x"]])
    pdf = _to_pandas(mapped)   # object dtype: mapped Nones stay None, not NaN
    keep = [v is not None and v > 0 for v in pdf["k"]]
    return _pd_lists(pdf[np.asarray(keep, dtype=bool)].drop_duplicates())


@pytest.mark.parametrize("seed", (1, 9))
def test_fused_producer_chain_dedup(seed, monkeypatch):
    data, domains = _chain_case(seed)
    expected = _pd_chain_dedup(data)
    f = Frame.from_pydict(data, domains=domains)
    plan = lambda: alg.DropDuplicates(
        alg.Selection(alg.Map(alg.Source("src"), _scale_udf()),
                      alg.col("k") > alg.lit(0)), None)
    _sweep(plan, {"src": f}, expected, f"fused-chain-dedup[{seed}]",
           monkeypatch)
    # plan shape: the chain was absorbed as producer stages
    store = {"src": PartitionedFrame.from_frame(f, row_parts=4)}
    ex = Executor(store, optimize=True)
    prepared = ex._prepared(plan())
    assert prepared.op == "fused_drop_duplicates"
    assert len(prepared.params["pre_stages"]) == 2
    assert prepared.params["grid"] == "workers"
    ex.evaluate(plan())
    assert ex.stats.barrier_fused_groups == 1
    assert ex.stats.producer_stage_ops == 2
    assert ex.stats.dedup_blocks > 0 and ex.stats.dedup_key_rows > 0


def test_fused_producer_chains_difference_both_sides(monkeypatch):
    ldata, ldom = _chain_case(2)
    rdata, _ = _chain_case(3)
    lf = Frame.from_pydict(ldata, domains=ldom)
    rf = Frame.from_pydict(rdata, domains=ldom)
    plan = lambda: alg.Difference(
        alg.Map(alg.Source("l"), _scale_udf()),
        alg.Map(alg.Source("r"), _scale_udf()))

    def mapped(d):
        return dict(d, x=[None if v is None else v * 2 for v in d["x"]])

    expected = _oracle_difference(mapped(ldata), mapped(rdata))
    _sweep(plan, {"l": lf, "r": rf}, expected, "fused-diff-both",
           monkeypatch)
    store = {"l": PartitionedFrame.from_frame(lf, row_parts=4),
             "r": PartitionedFrame.from_frame(rf, row_parts=4)}
    ex = Executor(store, optimize=True)
    prepared = ex._prepared(plan())
    assert prepared.op == "fused_difference"
    assert len(prepared.params["pre_stages"]) == 1
    assert len(prepared.params["right_pre_stages"]) == 1
    ex.evaluate(plan())
    assert ex.stats.barrier_fused_groups == 1
    assert ex.stats.producer_stage_ops == 2


def test_fused_consumer_chain_filters_keep_mask_before_gather(monkeypatch):
    data, domains = _chain_case(4)
    f = Frame.from_pydict(data, domains=domains)
    plan = lambda: alg.Projection(
        alg.Selection(alg.DropDuplicates(alg.Source("src"), None),
                      alg.col("k") > alg.lit(0)), ("k", "x"))
    pdf = _to_pandas(data).drop_duplicates()
    keep = [v is not None and v > 0 for v in pdf["k"]]
    expected = _pd_lists(pdf[np.asarray(keep, dtype=bool)][["k", "x"]])
    _sweep(plan, {"src": f}, expected, "consumer-dedup", monkeypatch)
    # THE consumer-fusion win: strictly fewer rows materialized than unfused
    store = {"src": PartitionedFrame.from_frame(f, row_parts=4)}
    exf = Executor(store, optimize=True)
    exu = Executor(store, optimize=False)
    prepared = exf._prepared(plan())
    assert prepared.op == "fused_drop_duplicates"
    assert len(prepared.params["post_stages"]) == 2
    exf.evaluate(plan())
    exu.evaluate(plan())
    assert 0 < exf.stats.gather_rows < exu.stats.gather_rows
    assert exf.stats.consumer_stage_ops == 2


def test_no_to_frame_on_dedup_inputs(monkeypatch):
    """The acceptance criterion itself: the block-parallel paths never
    concatenate their inputs."""
    data, domains = _chain_case(6)
    f = Frame.from_pydict(data, domains=domains)
    store = {"l": PartitionedFrame.from_frame(f, row_parts=4),
             "r": PartitionedFrame.from_frame(f, row_parts=3)}
    calls = []
    orig = PartitionedFrame.to_frame

    def spy(self):
        calls.append(1)
        return orig(self)

    monkeypatch.setattr(PartitionedFrame, "to_frame", spy)
    Executor(store).evaluate(alg.DropDuplicates(alg.Source("l"), None))
    Executor(store).evaluate(alg.Difference(alg.Source("l"), alg.Source("r")))
    assert not calls


def test_dedup_api_level(eager_session):
    """Fluent-API round trip (session history + MQO path included)."""
    from repro.core.api import from_pydict
    df = from_pydict({"k": [1, 2, 1, 2, 3], "x": [0.5, 1.5, 0.5, 1.5, 2.5]})
    assert df.drop_duplicates().collect().col("k").to_pylist() == [1, 2, 3]
    other = from_pydict({"k": [2], "x": [1.5]})
    assert df.difference(other).collect().col("k").to_pylist() == [1, 1, 3]
    assert df.drop_duplicates(subset=["x"]).collect().col("k").to_pylist() == [1, 2, 3]
