"""Session model (paper §3.5 Workflow Definitions + §6).

A *session* owns the frame store, the executor (with its reuse cache), the
evaluation mode, and statement bookkeeping.  Statements create plan nodes;
queries are the DAGs those statements compose; the session-level machinery
(§6) — opportunistic scheduling, multi-query sharing, materialization reuse —
lives in the executor and is configured here.

Multi-tenancy (ROADMAP serving tier): a session's store / retry / fault /
shuffle knobs are **session-scoped** — they live in a ``config.SessionConfig``
installed (contextvar) around every statement, never in process-wide state —
so two concurrent sessions with different knobs cannot clobber each other.
The ``REPRO_*`` env knobs and the modules' ``configure()`` functions remain
the *process defaults* a knob-less session inherits.

Async surface (§6.1.1): under OPPORTUNISTIC mode every statement is scheduled
in the background and carries a cancellable :class:`StatementHandle`
(``node.handle``); :meth:`Session.submit` is the explicit async entry point in
any mode.  Cancellation is cooperative — the run stops at the next dispatch
boundary with the typed ``faults.StatementCancelled`` — and a ``collect``
racing a ``close`` raises ``faults.ExecutorClosedError`` instead of hanging.

Sessions can also be *service-managed* (``core.service.QueryService``): the
service owns ONE executor / frame store / byte budget shared by all tenant
sessions, and each tenant session contributes its ``SessionConfig`` (with a
per-session ``ExecStats`` attribution target) instead of owning an executor.
"""
from __future__ import annotations

import concurrent.futures as _fut
import itertools
import threading
from typing import Any

from . import algebra as alg
from . import config as _config
from . import store as block_store
from . import trace as _trace
from .config import CancelToken, SessionConfig
from .executor import ExecStats, Executor
from .faults import ExecutorClosedError, StatementCancelled
from .frame import Frame
from .partition import PartitionedFrame, default_grid

__all__ = ["Session", "EvalMode", "StatementHandle", "get_session",
           "set_session"]


class EvalMode:
    EAGER = "eager"                  # pandas semantics (paper-faithful baseline)
    LAZY = "lazy"                    # Spark semantics
    OPPORTUNISTIC = "opportunistic"  # §6.1.1 — background compute in think time


class StatementHandle:
    """Grip on one asynchronously submitted statement (§6.1.1 async surface).

    ``cancel()`` requests cooperative cancellation: the background run stops
    at its next dispatch boundary (block kernels are pure, so a cancelled
    statement never leaves partial state — a later re-run is bit-identical).
    ``result()`` joins the run and raises the run's typed error:
    ``faults.StatementCancelled`` after a cancel, ``faults.ExecutorClosedError``
    when the owning session/service was closed while the statement was in
    flight.

    Traced sessions: the handle carries its trace statement id, so
    :meth:`profile` answers *where this statement's wall-clock went* (per-node
    time with counter deltas, dispatch/coalescing ratio, spill/retry/queue
    stalls, cache-hit provenance) once the run is done."""

    __slots__ = ("node", "token", "_future", "stmt_id", "_tracer")

    def __init__(self, node: alg.Node, token: CancelToken,
                 future: _fut.Future, *, stmt: int | None = None,
                 tracer: Any | None = None):
        self.node = node
        self.token = token
        self._future = future
        self.stmt_id = stmt
        self._tracer = tracer

    def profile(self) -> dict | None:
        """Per-statement time attribution (``trace.Tracer.profile``), or
        None when the owning session is untraced."""
        if self._tracer is None or self.stmt_id is None:
            return None
        return self._tracer.profile(self.stmt_id)

    def cancel(self) -> None:
        """Request cancellation (cooperative; a statement that already
        finished is unaffected and its cached result stays valid)."""
        self.token.cancel()

    @property
    def cancelled(self) -> bool:
        return self.token.cancelled

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: float | None = None) -> PartitionedFrame:
        try:
            return self._future.result(timeout)
        except _fut.CancelledError:
            # the pool dropped the queued task before it ever started
            # (executor shutdown with cancel_futures=True)
            if self.token.cancelled:
                raise StatementCancelled(
                    "statement cancelled before it started") from None
            raise ExecutorClosedError(
                "executor shut down before this statement started") from None

    def exception(self, timeout: float | None = None) -> BaseException | None:
        try:
            return self._future.exception(timeout)
        except _fut.CancelledError:
            if self.token.cancelled:
                return StatementCancelled("statement cancelled before it started")
            return ExecutorClosedError(
                "executor shut down before this statement started")

    def __repr__(self) -> str:
        state = ("cancelled" if self.token.cancelled
                 else "done" if self._future.done() else "running")
        return f"StatementHandle({self.node.op}, {state})"


_SESSION_IDS = itertools.count()


class Session:
    def __init__(self, *, mode: str = EvalMode.OPPORTUNISTIC,
                 cache_budget_bytes: int = 1 << 30, optimize: bool = True,
                 default_row_parts: int | None = None,
                 mem_budget_bytes: int | None = None,
                 spill_dir: str | None = None,
                 task_retries: int | None = None,
                 task_timeout_ms: int | None = None,
                 retry_backoff_ms: int | None = None,
                 fault_plan: str | None = None,
                 fault_seed: int | None = None,
                 shuffle_buckets: int | None = None,
                 shuffle_skew_factor: int | None = None,
                 max_inflight: int | None = None,
                 trace: Any = None,
                 _service: Any | None = None,
                 _executor: Executor | None = None,
                 _frames: dict[str, PartitionedFrame] | None = None,
                 _store: Any | None = None,
                 _session_id: str | None = None):
        sid = _session_id or f"s{next(_SESSION_IDS)}"
        # every knob is SESSION-scoped: it lives in this config, which is
        # installed (contextvar) around each statement — never written into
        # process-wide state, so concurrent sessions cannot clobber each
        # other.  None fields inherit the process default (programmatic
        # configure() override, else the REPRO_* env knob) — see the table
        # in core/schedule.py.
        self._private_store = None
        store = _store
        if store is None and (mem_budget_bytes is not None
                              or spill_dir is not None):
            # session-PRIVATE out-of-core store: this session's frames and
            # cached sub-plans charge against its own budget and spill into
            # its own directory, torn down on close()
            store = self._private_store = block_store.BlockStore(
                mem_budget_bytes or 0, spill_dir)
        # trace=True builds a session-private tracer (bounded span ring);
        # trace=False pins tracing OFF for this session even under a
        # process-wide REPRO_TRACE; None inherits the process default
        if trace is True:
            trace = _trace.Tracer(session_id=sid)
        self.config = SessionConfig(
            session_id=sid, store=store,
            task_retries=task_retries, task_timeout_ms=task_timeout_ms,
            retry_backoff_ms=retry_backoff_ms,
            fault_plan=fault_plan, fault_seed=fault_seed,
            shuffle_buckets=shuffle_buckets,
            shuffle_skew_factor=shuffle_skew_factor,
            stats=ExecStats() if _executor is not None else None,
            max_inflight=max_inflight, trace=trace)
        self.mode = mode
        self.service = _service
        self._closed = False
        if _executor is not None:
            # service-managed: share the service's executor + frame store
            # (cross-session MQO and one cache); fid prefix keeps tenants'
            # source tables distinct
            self.frames = _frames if _frames is not None else _executor.frames
            self.executor = _executor
            self._fid_prefix = f"{sid}_"
        else:
            self.frames = {}
            self.executor = Executor(self.frames,
                                     cache_budget_bytes=cache_budget_bytes,
                                     optimize=optimize)
            self._fid_prefix = ""
        self._ids = itertools.count()
        self._lock = threading.Lock()
        self.default_row_parts = default_row_parts
        self.statements: list[alg.Node] = []   # session history (§3.5)

    @property
    def stats(self) -> ExecStats:
        """This session's attribution target: the per-session stats under a
        shared service executor, else the owned executor's globals."""
        return self.config.stats or self.executor.stats

    def _require_open(self) -> None:
        if self._closed:
            raise ExecutorClosedError(
                f"session {self.config.session_id} is closed")

    # ------------------------------------------------------------------
    def register_frame(self, frame: Frame | PartitionedFrame,
                       row_parts: int | None = None, col_parts: int = 1) -> alg.Source:
        """Ingest a materialized frame; returns its Source node."""
        self._require_open()
        with _config.scope(self.config):
            if isinstance(frame, Frame):
                rp = row_parts or self.default_row_parts
                if rp is None:
                    rp, col_parts = default_grid(frame.nrows, frame.ncols)
                pf = PartitionedFrame.from_frame(frame, rp, col_parts)
            else:
                pf = frame
            fid = f"{self._fid_prefix}frame_{next(self._ids)}"
            with self._lock:
                self.frames[fid] = pf
            return alg.Source(fid, nrows=pf.nrows, ncols=pf.ncols)

    # ------------------------------------------------------------------
    def statement(self, node: alg.Node) -> alg.Node:
        """Record a statement; under opportunistic mode, schedule it now —
        the background work the user gets for free during think time.  The
        scheduled run is cancellable: the returned node carries a
        :class:`StatementHandle` as ``node.handle``."""
        self._require_open()
        self.statements.append(node)
        with _config.scope(self.config):
            if self.mode == EvalMode.OPPORTUNISTIC:
                node.handle = self._submit_scoped(node)
            elif self.mode == EvalMode.EAGER:
                self.executor.evaluate(node)
            # AFTER preparation: this statement becomes an MQO fusion boundary
            # for *later* plans (§6.2.1), never a barrier against its own
            # fusion
            self.executor.note_statement(node)
        return node

    def submit(self, node: alg.Node) -> StatementHandle:
        """Async statement submission (any mode): schedule ``node`` in the
        background and return its cancellable :class:`StatementHandle`."""
        self._require_open()
        self.statements.append(node)
        with _config.scope(self.config):
            handle = self._submit_scoped(node)
            self.executor.note_statement(node)
        return handle

    def _submit_scoped(self, node: alg.Node) -> StatementHandle:
        if self.service is not None:
            return self.service._submit(self, node)
        token = CancelToken()
        tr = _trace.current()
        stmt = tr.next_stmt() if tr is not None else None
        fut = self.executor.submit(node, cancel=token, stmt=stmt)
        return StatementHandle(node, token, fut, stmt=stmt, tracer=tr)

    def collect(self, node: alg.Node) -> Frame:
        self._require_open()
        with _config.scope(self.config):
            return self.executor.evaluate(node).to_frame()

    def head(self, node: alg.Node, k: int = 5) -> Frame:
        self._require_open()
        with _config.scope(self.config):
            return self.executor.evaluate_prefix(node, k).to_frame().head(k)

    def tail(self, node: alg.Node, k: int = 5) -> Frame:
        self._require_open()
        with _config.scope(self.config):
            return self.executor.evaluate(alg.Limit(node, k, tail=True)).to_frame()

    # ------------------------------------------------------------------
    # observability surfaces (core.trace)
    # ------------------------------------------------------------------
    @property
    def tracer(self) -> Any | None:
        """This session's resolved tracer: its private one
        (``Session(trace=True)``), else the process tracer (``REPRO_TRACE``),
        else None — the same resolution every instrumentation site uses."""
        with _config.scope(self.config):
            return _trace.current()

    def trace_json(self, path: str) -> str | None:
        """Export this session's span ring as Chrome trace-event JSON (open
        in Perfetto / chrome://tracing; pool threads appear as named tracks,
        cross-thread span parentage as flow arrows).  Returns the path, or
        None when the session is untraced."""
        tr = self.tracer
        return tr.export(path) if tr is not None else None

    def explain_stats(self, stmt: int | None = None) -> dict:
        """Where did the time go?  This session's counter totals
        (``ExecStats`` projected through the shared metrics shape) plus — for
        traced sessions — the per-statement profile of ``stmt`` (default:
        the most recent statement): per-node wall time with counter deltas,
        dispatch/coalescing ratio, spill/retry/queue attribution, and
        cache-hit provenance."""
        tr = self.tracer
        out = {
            "session": self.config.session_id,
            "stats": _trace.stats_metrics(
                self.stats, name=self.config.session_id).export(),
            "traced": tr is not None,
        }
        if tr is not None:
            out["statements"] = tr.statements()
            out["profile"] = tr.profile(stmt)
        return out

    def close(self):
        """Tear the session down: in-flight statements FAIL with the typed
        ``faults.ExecutorClosedError`` (they are never silently abandoned),
        the session-private store (if any) drops its spill files, and the
        default-session slot is vacated if this session held it.
        Idempotent."""
        global _DEFAULT
        if self._closed:
            return
        self._closed = True
        if self.service is not None:
            # shared executor/store belong to the service — only detach
            self.service._session_closed(self)
        else:
            self.executor.shutdown()
            self.frames.clear()
        if self._private_store is not None:
            self._private_store.shutdown()
        with _DEFAULT_LOCK:
            if _DEFAULT is self:
                _DEFAULT = None


_DEFAULT: Session | None = None
_DEFAULT_LOCK = threading.Lock()


def get_session() -> Session:
    """The process default session, created on first use.  Thread-safe
    (double-checked under a lock — two racing first calls used to build two
    sessions and leak one executor's background pool) and close-aware: a
    closed default is replaced, never handed out again."""
    global _DEFAULT
    s = _DEFAULT
    if s is not None and not s._closed:
        return s
    with _DEFAULT_LOCK:
        if _DEFAULT is None or _DEFAULT._closed:
            _DEFAULT = Session()
        return _DEFAULT


def set_session(s: Session) -> Session:
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = s
    return s
