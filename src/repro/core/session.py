"""Session model (paper §3.5 Workflow Definitions + §6).

A *session* owns the frame store, the executor (with its reuse cache), the
evaluation mode, and statement bookkeeping.  Statements create plan nodes;
queries are the DAGs those statements compose; the session-level machinery
(§6) — opportunistic scheduling, multi-query sharing, materialization reuse —
lives in the executor and is configured here.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any

from . import algebra as alg
from . import faults as _faults
from . import schedule as _schedule
from . import shuffle as _shuffle
from . import store as block_store
from .executor import Executor
from .frame import Frame
from .partition import PartitionedFrame, default_grid

__all__ = ["Session", "EvalMode", "get_session", "set_session"]


class EvalMode:
    EAGER = "eager"                  # pandas semantics (paper-faithful baseline)
    LAZY = "lazy"                    # Spark semantics
    OPPORTUNISTIC = "opportunistic"  # §6.1.1 — background compute in think time


class Session:
    def __init__(self, *, mode: str = EvalMode.OPPORTUNISTIC,
                 cache_budget_bytes: int = 1 << 30, optimize: bool = True,
                 default_row_parts: int | None = None,
                 mem_budget_bytes: int | None = None,
                 spill_dir: str | None = None,
                 task_retries: int | None = None,
                 task_timeout_ms: int | None = None,
                 retry_backoff_ms: int | None = None,
                 fault_plan: str | None = None,
                 fault_seed: int | None = None,
                 shuffle_buckets: int | None = None,
                 shuffle_skew_factor: int | None = None):
        # out-of-core residency knob (process-wide — the block store is
        # shared; see the REPRO_MEM_BUDGET / REPRO_SPILL_DIR env knobs in
        # core/schedule.py's table).  Set it before ingesting data: blocks
        # registered under an earlier store configuration stay fully
        # resident.
        if mem_budget_bytes is not None or spill_dir is not None:
            block_store.configure(budget_bytes=mem_budget_bytes,
                                  spill_dir=spill_dir)
        # fault-tolerance knobs (process-wide, like the store config): retry
        # policy for transient block-task failures and the deterministic
        # fault-injection plan — programmatic forms of REPRO_TASK_RETRIES /
        # REPRO_TASK_TIMEOUT_MS / REPRO_RETRY_BACKOFF_MS and
        # REPRO_FAULT_PLAN / REPRO_FAULT_SEED (see core/schedule.py's table)
        if (task_retries is not None or task_timeout_ms is not None
                or retry_backoff_ms is not None):
            _schedule.configure_retries(retries=task_retries,
                                        timeout_ms=task_timeout_ms,
                                        backoff_ms=retry_backoff_ms)
        if fault_plan is not None or fault_seed is not None:
            _faults.configure(plan=fault_plan, seed=fault_seed)
        # shuffle/exchange knobs (process-wide, like the store config):
        # programmatic forms of REPRO_SHUFFLE_BUCKETS /
        # REPRO_SHUFFLE_SKEW_FACTOR (see core/schedule.py's table)
        if shuffle_buckets is not None or shuffle_skew_factor is not None:
            _shuffle.configure(buckets=shuffle_buckets,
                               skew_factor=shuffle_skew_factor)
        self.mode = mode
        self.frames: dict[str, PartitionedFrame] = {}
        self.executor = Executor(self.frames, cache_budget_bytes=cache_budget_bytes,
                                 optimize=optimize)
        self._ids = itertools.count()
        self._lock = threading.Lock()
        self.default_row_parts = default_row_parts
        self.statements: list[alg.Node] = []   # session history (§3.5)

    # ------------------------------------------------------------------
    def register_frame(self, frame: Frame | PartitionedFrame,
                       row_parts: int | None = None, col_parts: int = 1) -> alg.Source:
        """Ingest a materialized frame; returns its Source node."""
        if isinstance(frame, Frame):
            rp = row_parts or self.default_row_parts
            if rp is None:
                rp, col_parts = default_grid(frame.nrows, frame.ncols)
            pf = PartitionedFrame.from_frame(frame, rp, col_parts)
        else:
            pf = frame
        fid = f"frame_{next(self._ids)}"
        with self._lock:
            self.frames[fid] = pf
        return alg.Source(fid, nrows=pf.nrows, ncols=pf.ncols)

    # ------------------------------------------------------------------
    def statement(self, node: alg.Node) -> alg.Node:
        """Record a statement; under opportunistic mode, schedule it now —
        the background work the user gets for free during think time."""
        self.statements.append(node)
        if self.mode == EvalMode.OPPORTUNISTIC:
            self.executor.submit(node)
        elif self.mode == EvalMode.EAGER:
            self.executor.evaluate(node)
        # AFTER preparation: this statement becomes an MQO fusion boundary for
        # *later* plans (§6.2.1), never a barrier against its own fusion
        self.executor.note_statement(node)
        return node

    def collect(self, node: alg.Node) -> Frame:
        return self.executor.evaluate(node).to_frame()

    def head(self, node: alg.Node, k: int = 5) -> Frame:
        return self.executor.evaluate_prefix(node, k).to_frame().head(k)

    def tail(self, node: alg.Node, k: int = 5) -> Frame:
        return self.executor.evaluate(alg.Limit(node, k, tail=True)).to_frame()

    def close(self):
        self.executor.shutdown()
        self.frames.clear()


_DEFAULT: Session | None = None


def get_session() -> Session:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Session()
    return _DEFAULT


def set_session(s: Session) -> Session:
    global _DEFAULT
    _DEFAULT = s
    return s
