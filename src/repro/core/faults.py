"""Deterministic fault injection + the typed fault-tolerance error surface.

Production engines treat task failure and storage faults as *expected events*
(Cylon's recoverable task execution; the paper's scalability agenda).  This
module is the chaos half of that contract: seeded, plan-addressable injection
points that the scheduler and the block store consult at every dispatch
boundary and every spill read/write, so robustness is **gated** by a
deterministic differential suite (``tests/test_faults.py``) instead of
claimed.

Injection points and addresses
------------------------------
Every injection point has a stable string *address*:

* ``dispatch/node=<op>/blk=<i>/try=<a>`` — a per-block task about to run on a
  pool worker (``schedule.dispatch_blocks``); can inject a worker exception
  (:class:`InjectedWorkerError`) or a slow task (sleep
  ``REPRO_FAULT_SLOW_MS``).  The shuffle/exchange layer (``core.shuffle``)
  runs each JOIN/SORT round under a suffixed node label —
  ``node=<join|sort|fused_join|fused_sort>:<exchange|local|gather>`` — so a
  plan rule like ``worker@join:exchange:1.0`` targets exactly the exchange
  boundary (bucketization / local kernels / payload gather are independently
  addressable);
* ``spill_write/blk<id>/dir<i>`` — a block about to be spilled; can inject
  ``OSError(ENOSPC)``;
* ``spill_read/blk<id>/<lineage|orphan>`` — a spilled block about to be
  faulted back; can corrupt the spill file (one flipped byte — caught by the
  CRC32 stamp) or delete it.

Fault plans (``REPRO_FAULT_PLAN`` / ``REPRO_FAULT_SEED``)
---------------------------------------------------------
A plan is a comma-separated list of rules::

    kind[@addr_substr]:rate[!]

with ``kind`` ∈ {``worker``, ``slow``, ``corrupt``, ``missing``, ``enospc``},
``rate`` ∈ [0, 1], ``@addr_substr`` restricting the rule to addresses that
contain the substring, and a trailing ``!`` making the rule *sticky*.
Examples::

    worker:0.1                     10% of first-attempt block tasks raise
    worker@blk=2:1.0!              block 2 fails on EVERY attempt (poison)
    corrupt:0.5,enospc:1.0         flip bits in half the recoverable spill
                                   reads; every spill write hits ENOSPC

Decisions are **deterministic**: each (kind, address) pair hashes with the
seed (splitmix64 over an FNV-1a digest) to a uniform draw in [0, 1) — the
same plan + seed + address always decides the same way, with no RNG state
shared between sites.  Non-sticky rules model *transient* faults: ``worker``
/ ``slow`` fire only on attempt 0 (so bounded retry recovers), and
``corrupt`` / ``missing`` fire only on reads of blocks that carry a recorded
producer (so recompute recovers).  Sticky rules (``!``) drop those guards —
the way to exercise the poison-block / unrecoverable-integrity typed-error
paths on purpose.

The shared warn-once env parser
-------------------------------
:func:`env_int` is the one parser for every ``REPRO_*`` integer knob: a
malformed value warns ONCE (per knob, per process) and falls back to the
default instead of silently returning 0 or crashing mid-statement.
"""
from __future__ import annotations

import errno
import os
import threading
import time
import warnings

from . import config as _config

__all__ = [
    "TaskError", "InjectedWorkerError", "SpillIntegrityError",
    "StoreClosedError", "IngestError", "StatementCancelled",
    "ExecutorClosedError", "is_retryable",
    "env_int", "active", "fault_point", "spill_write_fault",
    "spill_read_chaos", "injected_total", "injected_snapshot",
    "configure", "reset", "FaultPlan",
]


# =============================================================================
# typed errors — the "completes or raises ONE typed error" surface
# =============================================================================
class TaskError(RuntimeError):
    """A dispatched block task failed past the retry budget (or a dispatch
    blew its deadline).  Carries full provenance: plan node, block index,
    attempt count, and the underlying cause."""

    def __init__(self, message: str, *, node: str | None = None,
                 block: int | None = None, attempts: int = 0,
                 kind: str = "task", cause: BaseException | None = None):
        self.node = node
        self.block = block
        self.attempts = attempts
        self.kind = kind
        self.cause = cause
        where = f"node={node or '?'}"
        if block is not None:
            where += f", block={block}"
        detail = f" [{kind}; {where}; attempts={attempts}]"
        if cause is not None:
            detail += f" caused by {type(cause).__name__}: {cause}"
        super().__init__(message + detail)


class InjectedWorkerError(RuntimeError):
    """The exception a ``worker`` fault rule raises inside a pool task —
    retryable by definition (it models a transient worker crash)."""


class SpillIntegrityError(RuntimeError):
    """A spill file failed its CRC32 / header verification (or is missing)
    and the block has no recorded producer to recompute from."""


class StoreClosedError(RuntimeError):
    """A spilled block was faulted after ``BlockStore.shutdown()`` — its
    spill file is gone by design.  Names the handle and the shutdown site."""


class IngestError(RuntimeError):
    """``read_csv`` detected that the file changed (truncated or grew)
    between the byte-range planning pass and chunk tokenization."""


class StatementCancelled(RuntimeError):
    """An async statement's :class:`config.CancelToken` was set: the dispatch
    layer stopped at the next block boundary.  Never retried; a waiter joined
    on the cancelled statement's in-flight future re-evaluates instead of
    inheriting the cancellation (``Executor._eval``)."""

    def __init__(self, message: str, *, node: str | None = None):
        self.node = node
        super().__init__(message + (f" [node={node}]" if node else ""))


class ExecutorClosedError(RuntimeError):
    """A statement was submitted to — or was still in flight on — an executor
    that has been shut down (``Session.close`` racing a ``collect``).  The
    typed replacement for the old behavior of abandoning in-flight promise
    futures, which left waiters blocked forever."""


#: Exception classes the dispatch layer treats as transient and retries.
#: Deterministic user errors (ValueError, KeyError, OverflowError, ...)
#: propagate unchanged — retrying them wastes the budget and masks the
#: original type the caller's tests expect.
_RETRYABLE = (InjectedWorkerError, OSError, TimeoutError, ConnectionError)
_NEVER_RETRY = (TaskError, SpillIntegrityError, StoreClosedError, IngestError,
                StatementCancelled, ExecutorClosedError)


def is_retryable(exc: BaseException) -> bool:
    return isinstance(exc, _RETRYABLE) and not isinstance(exc, _NEVER_RETRY)


# =============================================================================
# shared warn-once env parser for REPRO_* integer knobs
# =============================================================================
_WARNED: set[str] = set()
_WARNED_LOCK = threading.Lock()


def env_int(name: str, default: int, *, minimum: int | None = None) -> int:
    """Parse an integer env knob; a malformed value warns ONCE per knob and
    falls back to ``default`` (never a silent 0)."""
    raw = os.environ.get(name)
    if raw is None or raw == "":
        return default
    try:
        v = int(raw)
    except (TypeError, ValueError):
        with _WARNED_LOCK:
            if name not in _WARNED:
                _WARNED.add(name)
                warnings.warn(
                    f"{name}={raw!r} is not an integer; using the default "
                    f"({default})", RuntimeWarning, stacklevel=2)
        return default
    if minimum is not None and v < minimum:
        v = minimum
    return v


# =============================================================================
# deterministic per-address draws
# =============================================================================
_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


def _draw(seed: int, kind: str, address: str) -> float:
    """Uniform [0, 1) decided purely by (seed, kind, address) — FNV-1a over
    the site name finished with one splitmix64 round."""
    h = 0xCBF29CE484222325
    for b in f"{kind}|{address}".encode():
        h = ((h ^ b) * 0x100000001B3) & _M64
    return _splitmix64(h ^ _splitmix64(seed & _M64)) / 2.0 ** 64


# =============================================================================
# the plan
# =============================================================================
_KINDS = ("worker", "slow", "corrupt", "missing", "enospc")


class _Rule:
    __slots__ = ("kind", "substr", "rate", "sticky")

    def __init__(self, kind: str, substr: str, rate: float, sticky: bool):
        self.kind = kind
        self.substr = substr
        self.rate = rate
        self.sticky = sticky


class FaultPlan:
    """A parsed ``REPRO_FAULT_PLAN`` + seed.  ``match`` is the one decision
    point; it also records the injection in the module counters."""

    def __init__(self, spec: str, seed: int = 0):
        self.spec = spec
        self.seed = seed
        self._rules: dict[str, list[_Rule]] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            head, sep, rate_s = part.rpartition(":")
            if not sep:
                raise ValueError(
                    f"REPRO_FAULT_PLAN rule {part!r}: expected "
                    "kind[@addr_substr]:rate[!]")
            sticky = rate_s.endswith("!")
            if sticky:
                rate_s = rate_s[:-1]
            kind, _, substr = head.partition("@")
            kind = kind.strip()
            if kind not in _KINDS:
                raise ValueError(
                    f"REPRO_FAULT_PLAN rule {part!r}: unknown fault kind "
                    f"{kind!r} (want one of {', '.join(_KINDS)})")
            try:
                rate = float(rate_s)
            except ValueError:
                raise ValueError(
                    f"REPRO_FAULT_PLAN rule {part!r}: rate {rate_s!r} is "
                    "not a float") from None
            self._rules.setdefault(kind, []).append(
                _Rule(kind, substr.strip(), min(max(rate, 0.0), 1.0), sticky))

    def match(self, kind: str, address: str, *, attempt: int = 0,
              recoverable: bool = True) -> bool:
        for r in self._rules.get(kind, ()):
            if r.substr and r.substr not in address:
                continue
            if not r.sticky:
                # transient semantics: retry / recompute can always recover
                if kind in ("worker", "slow") and attempt > 0:
                    continue
                if kind in ("corrupt", "missing") and not recoverable:
                    continue
            if _draw(self.seed, kind, address) < r.rate:
                _record(kind, address)
                return True
        return False


# =============================================================================
# module state: plan resolution, injected-fault counters
# =============================================================================
_LOCK = threading.Lock()
_OVERRIDE_PLAN: str | None = None
_OVERRIDE_SEED: int | None = None
_CACHED: tuple[str, int, FaultPlan] | None = None
_COUNTS: dict[str, int] = {}
_TOTAL = 0


def _record(kind: str, address: str = "") -> None:
    global _TOTAL
    with _LOCK:
        _COUNTS[kind] = _COUNTS.get(kind, 0) + 1
        _TOTAL += 1
    # trace imports this module, so the tracer is resolved lazily — and only
    # on the fault-firing path, which is never the production hot path
    from . import trace as _trace
    tr = _trace.current()
    if tr is not None:
        tr.instant(f"fault:{kind}", "fault", args={"at": address})


def injected_total() -> int:
    """Monotonic count of every injected fault (the executor snapshots this
    around plan-node evaluation → ``ExecStats.faults_injected``)."""
    return _TOTAL


def injected_snapshot() -> dict[str, int]:
    with _LOCK:
        return dict(_COUNTS)


def active() -> bool:
    """Cheap per-dispatch gate: is ANY fault plan configured?  False is the
    production path — injection costs one contextvar + env lookup and nothing
    else.  Session-scoped resolution: the active :class:`config.SessionConfig`
    wins (``fault_plan=""`` explicitly *shields* a session from a process-wide
    plan), then the programmatic override, then ``REPRO_FAULT_PLAN``."""
    cfg = _config.current()
    if cfg is not None and cfg.fault_plan is not None:
        return bool(cfg.fault_plan)
    return (_OVERRIDE_PLAN is not None
            or bool(os.environ.get("REPRO_FAULT_PLAN")))


def _plan() -> FaultPlan | None:
    global _CACHED
    cfg = _config.current()
    if cfg is not None and cfg.fault_plan is not None:
        if not cfg.fault_plan:
            return None              # "" = injection off for this session
        seed = cfg.fault_seed if cfg.fault_seed is not None else \
            env_int("REPRO_FAULT_SEED", 0)
        p = cfg._plan_cache
        if p is None or p.spec != cfg.fault_plan or p.seed != seed:
            p = cfg._plan_cache = FaultPlan(cfg.fault_plan, seed)
        return p
    raw = _OVERRIDE_PLAN if _OVERRIDE_PLAN is not None else \
        os.environ.get("REPRO_FAULT_PLAN", "")
    if not raw:
        return None
    seed = _OVERRIDE_SEED if _OVERRIDE_SEED is not None else \
        env_int("REPRO_FAULT_SEED", 0)
    cached = _CACHED
    if cached is not None and cached[0] == raw and cached[1] == seed:
        return cached[2]
    plan = FaultPlan(raw, seed)
    _CACHED = (raw, seed, plan)
    return plan


def configure(plan: str | None = None, seed: int | None = None) -> None:
    """Process-wide programmatic override of ``REPRO_FAULT_PLAN`` /
    ``REPRO_FAULT_SEED`` (CI smokes, chaos harnesses).  Sticky until
    :func:`reset`.  ``Session(fault_plan=...)`` no longer calls this — its
    plan is session-scoped via ``config.SessionConfig`` and shadows this
    override only inside that session's statements."""
    global _OVERRIDE_PLAN, _OVERRIDE_SEED
    if plan is not None:
        FaultPlan(plan)          # validate eagerly: fail at configure time
        _OVERRIDE_PLAN = plan
    if seed is not None:
        _OVERRIDE_SEED = int(seed)


def reset() -> None:
    """Clear overrides, the parsed-plan cache, and the injected counters."""
    global _OVERRIDE_PLAN, _OVERRIDE_SEED, _CACHED, _COUNTS, _TOTAL
    with _LOCK:
        _OVERRIDE_PLAN = None
        _OVERRIDE_SEED = None
        _CACHED = None
        _COUNTS = {}
        _TOTAL = 0


# =============================================================================
# the injection points
# =============================================================================
def fault_point(address: str, *, attempt: int = 0) -> None:
    """Dispatch-boundary injection: may sleep (``slow``) and/or raise
    :class:`InjectedWorkerError` (``worker``).  Called by the scheduling
    layer just before a block task's function runs."""
    p = _plan()
    if p is None:
        return
    if p.match("slow", address, attempt=attempt):
        time.sleep(env_int("REPRO_FAULT_SLOW_MS", 25, minimum=0) / 1000.0)
    if p.match("worker", address, attempt=attempt):
        raise InjectedWorkerError(f"injected worker fault at {address}")


def spill_write_fault(address: str) -> None:
    """Spill-write injection: may raise ``OSError(ENOSPC)`` — exercised by
    the store's graceful-degradation path (victim stays resident, budget
    marked overrun, eviction moves on)."""
    p = _plan()
    if p is None:
        return
    if p.match("enospc", address):
        raise OSError(errno.ENOSPC,
                      f"injected ENOSPC (no space left) at {address}")


def spill_read_chaos(path: str, address: str, *, recoverable: bool) -> None:
    """Spill-read injection: may corrupt the on-disk file (one flipped byte
    — the CRC32 stamp catches it) or delete it.  ``recoverable`` says the
    block carries a recompute thunk; non-sticky rules only strike
    recoverable reads so the chaos suite stays completion-guaranteed."""
    p = _plan()
    if p is None:
        return
    if p.match("missing", address, recoverable=recoverable):
        try:
            os.unlink(path)
        except OSError:
            pass
        return
    if p.match("corrupt", address, recoverable=recoverable):
        try:
            with open(path, "r+b") as f:
                f.seek(0, os.SEEK_END)
                size = f.tell()
                if size == 0:
                    return
                f.seek(size // 2)
                b = f.read(1)
                f.seek(size // 2)
                f.write(bytes([b[0] ^ 0xFF]) if b else b"\xff")
        except OSError:
            pass
