"""Statement tracing & metrics: where each statement's wall-clock went.

The paper's signature interaction model (§7) is piecemeal trial-and-error —
users iterate statement-by-statement and steer by what the last one cost.
``ExecStats`` already attributes recovery and residency *work* exactly
(counters, snapshot-delta per plan node); this module adds the missing
dimension: **time**, recorded as a span tree per statement,

    statement → plan prep (rewrite/fusion)
              → per-plan-node eval          (``schedule.node_scope`` labels)
                → dispatch_blocks           (caller thread)
                  → per-chunk pool tasks    (worker threads; parent span
                                             carried via ``config.propagate``)
                    → store spill / fault, retry backoff, injected faults
              → shuffle bucketize/exchange/local/gather phases
    service   → admission queue-wait + slot-hold per tenant

into a bounded per-session ring buffer, exported as Chrome trace-event JSON
(loadable in Perfetto — pool threads appear as named tracks, cross-thread
parent→child edges as flow arrows) and summarized by
``Session.explain_stats()`` / ``StatementHandle.profile()``.

Design constraints (the reason this file is small and boring):

* **Disabled is a no-op.**  Every instrumentation site is guarded by
  ``current()`` returning ``None`` — one contextvar read plus an attribute
  check, no span allocation, no lock.  The ≤1% gate lives in
  ``benchmarks/bench_trace.py`` (``BENCH_trace.json``) and the conftest
  autouse guard asserts zero spans recorded in every non-``@pytest.mark.trace``
  test, so tracing can never leak into the default path silently.
* **ExecStats stays the counter source of truth.**  Spans carry counter
  *deltas* computed by the executor's existing snapshot-delta mechanism
  (``Executor._attribute_store_delta``), so the span-attached deltas of one
  statement sum exactly to that statement's global ``ExecStats`` movement —
  asserted by the bench and the CI trace smoke.
* **Bounded.**  The ring holds ``REPRO_TRACE_RING`` finished spans (default
  65536); old spans fall off the back.  Open spans are only tracked as a
  count (leak detection) — an exception unwinding a ``with`` scope closes
  its span with an ``error`` arg, so cancellation / executor shutdown can
  never leave spans open.

Enabling: ``REPRO_TRACE=1`` turns on a process-wide tracer; a path value
(``REPRO_TRACE=/tmp/t.json``) additionally exports the ring there at process
exit.  ``Session(trace=True)`` gives one session its own tracer (bounded
ring, independent of the process one), resolved through the session's
``config.SessionConfig`` exactly like the store / fault / retry knobs.

The metrics half: :class:`Metrics` is the one named-counter/gauge registry
shape shared by the serve tier (``serve.engine.ServeEngine.metrics``) and the
core tier (:func:`stats_metrics` projects an ``ExecStats`` into it), so both
export the same ``{"name": ..., "metrics": {...}}`` dict.
"""
from __future__ import annotations

import atexit
import collections
import itertools
import json
import os
import threading
import time
from typing import Any, Iterator

from . import config as _config
from .faults import env_int

__all__ = [
    "Span", "Tracer", "Metrics", "current", "configure", "reset",
    "recorded_total", "ring_size", "stats_metrics", "export",
    "chrome_trace_events", "validate_chrome_trace",
]

_now = time.perf_counter_ns


def ring_size() -> int:
    """Bounded span-ring capacity (``REPRO_TRACE_RING``, default 65536)."""
    return env_int("REPRO_TRACE_RING", 65536, minimum=16)


# total spans/instants recorded by ANY tracer in this process — the conftest
# autouse guard asserts this does not move in non-@pytest.mark.trace tests
_TOTAL = 0
_TOTAL_LOCK = threading.Lock()


def recorded_total() -> int:
    return _TOTAL


class Span:
    """One finished (or in-flight) span.  ``args`` is attached by the
    instrumentation site — the executor stores its snapshot-delta counter
    dict here, which is what makes span deltas sum to ``ExecStats``."""

    __slots__ = ("id", "parent", "stmt", "name", "cat", "tid", "t0", "dur",
                 "args")

    def __init__(self, sid: int, parent: int | None, stmt: int, name: str,
                 cat: str):
        self.id = sid
        self.parent = parent
        self.stmt = stmt
        self.name = name
        self.cat = cat
        self.tid = threading.current_thread().name
        self.t0 = _now()
        self.dur = 0
        self.args: dict | None = None


class _SpanScope:
    """``with``-shaped span: installs the span as the current trace context
    (so children — including ones opened on pool threads via
    ``config.propagate`` — parent to it) and records it on exit.  An
    exception closes the span with an ``error`` arg instead of leaking it."""

    __slots__ = ("_tr", "span", "_tok")

    def __init__(self, tr: "Tracer", span: Span):
        self._tr = tr
        self.span = span

    def __enter__(self) -> Span:
        self._tok = _config._TRACE_CTX.set(self.span)
        return self.span

    def __exit__(self, et, ev, tb) -> bool:
        _config._TRACE_CTX.reset(self._tok)
        if et is not None:
            a = self.span.args
            self.span.args = dict(a) if a else {}
            self.span.args["error"] = et.__name__
        self._tr.end(self.span)
        return False


class Tracer:
    """Per-session (or process-wide) span recorder: a bounded ring of
    finished spans plus a statement-id allocator.  Thread-safe — spans are
    begun/ended from caller, pool-worker, background-executor, and admission
    threads concurrently."""

    def __init__(self, ring: int | None = None, session_id: str = "proc"):
        self.session_id = session_id
        self.events: collections.deque[Span] = collections.deque(
            maxlen=ring if ring is not None else ring_size())
        self._ids = itertools.count(1)
        self._stmts = itertools.count(1)
        self._open = 0
        self._lock = threading.Lock()
        self.last_stmt: int | None = None

    # -- statement ids --------------------------------------------------
    def next_stmt(self) -> int:
        s = next(self._stmts)
        self.last_stmt = s
        return s

    def open_spans(self) -> int:
        """Spans begun but not yet ended — 0 whenever no statement is
        actively running (cancellation and shutdown unwind their ``with``
        scopes, which close spans; asserted in tests/test_trace.py)."""
        return self._open

    # -- low-level begin/end (manual pairing; no contextvar mutation) ---
    def begin(self, name: str, cat: str = "span", *,
              parent: Span | None | object = _config._TRACE_UNSET,
              stmt: int | None = None) -> Span:
        if parent is _config._TRACE_UNSET:
            parent = _config.current_trace_ctx()
        pid = parent.id if isinstance(parent, Span) else None
        if stmt is None:
            stmt = parent.stmt if isinstance(parent, Span) else self.next_stmt()
        sp = Span(next(self._ids), pid, stmt, name, cat)
        with self._lock:
            self._open += 1
        return sp

    def end(self, sp: Span) -> None:
        global _TOTAL
        sp.dur = _now() - sp.t0
        with self._lock:
            self._open -= 1
            self.events.append(sp)
        with _TOTAL_LOCK:
            _TOTAL += 1

    # -- with-shaped API -------------------------------------------------
    def span(self, name: str, cat: str = "span", *, args: dict | None = None,
             parent: Span | None | object = _config._TRACE_UNSET,
             stmt: int | None = None) -> _SpanScope:
        sp = self.begin(name, cat, parent=parent, stmt=stmt)
        sp.args = args
        return _SpanScope(self, sp)

    def statement(self, name: str, *, stmt: int | None = None) -> _SpanScope:
        """Root span for one statement.  Called under an existing trace
        context (a statement evaluated *inside* another traced region) it
        degrades to a plain child span of the same statement."""
        parent = _config.current_trace_ctx()
        if stmt is None and parent is None:
            stmt = self.next_stmt()
        elif stmt is not None:
            self.last_stmt = stmt
        return self.span(name, "statement", parent=parent, stmt=stmt)

    def instant(self, name: str, cat: str = "instant", *,
                args: dict | None = None) -> None:
        """Zero-duration event (cache hits, injected faults): records where
        in the tree something happened without a begin/end pair."""
        global _TOTAL
        sp = self.begin(name, cat)
        sp.args = args
        sp.dur = 0
        with self._lock:
            self._open -= 1
            self.events.append(sp)
        with _TOTAL_LOCK:
            _TOTAL += 1

    # -- profiling / export ----------------------------------------------
    def snapshot(self) -> list[Span]:
        with self._lock:
            return list(self.events)

    def statements(self) -> list[int]:
        return sorted({s.stmt for s in self.snapshot()})

    def profile(self, stmt: int | None = None) -> dict:
        """Per-statement time attribution: where did the wall-clock go?
        Sums the statement's spans by category — per-node wall time with
        their counter deltas, dispatch/coalescing ratio, pool-task busy
        time, spill/fault/backoff stalls, queue wait — the numbers §7's
        trial-and-error loop steers by."""
        if stmt is None:
            stmt = self.last_stmt
        spans = [s for s in self.snapshot() if s.stmt == stmt]
        prof: dict[str, Any] = {
            "stmt": stmt, "session": self.session_id, "spans": len(spans),
            "wall_ns": sum(s.dur for s in spans if s.cat == "statement"),
            "plan_prep_ns": sum(s.dur for s in spans if s.cat == "prep"),
            "nodes": {}, "cache_hits": [], "faults_fired": [],
        }
        disp = [s for s in spans if s.cat == "dispatch"]
        chunks = [s for s in spans if s.cat == "task"]
        nd = sum((s.args or {}).get("chunks", 0) for s in disp)
        nb = sum((s.args or {}).get("blocks", 0) for s in disp)
        prof["dispatch"] = {
            "dispatches": nd, "dispatched_blocks": nb,
            "blocks_per_dispatch": round(nb / max(1, nd), 2),
            "dispatch_ns": sum(s.dur for s in disp),
            "task_busy_ns": sum(s.dur for s in chunks),
            "backoff_ns": sum(s.dur for s in spans if s.cat == "retry"),
            "retries": sum(1 for s in spans if s.cat == "retry"),
        }
        prof["store"] = {
            "spill_ns": sum(s.dur for s in spans if s.name == "spill"),
            "spills": sum(1 for s in spans if s.name == "spill"),
            "fault_ns": sum(s.dur for s in spans if s.name == "fault"),
            "faults": sum(1 for s in spans if s.name == "fault"),
        }
        prof["service"] = {
            "queue_wait_ns": sum(s.dur for s in spans
                                 if s.name == "queue_wait"),
            "slot_hold_ns": sum(s.dur for s in spans
                                if s.name == "slot_hold"),
        }
        for s in spans:
            if s.cat == "node":
                ent = prof["nodes"].setdefault(
                    s.name, {"wall_ns": 0, "count": 0, "counters": {}})
                ent["wall_ns"] += s.dur
                ent["count"] += 1
                for k, v in (s.args or {}).items():
                    if isinstance(v, int):
                        ent["counters"][k] = ent["counters"].get(k, 0) + v
            elif s.cat == "cache":
                prof["cache_hits"].append(s.name)
            elif s.cat == "fault":
                prof["faults_fired"].append(
                    {"kind": s.name, **(s.args or {})})
        return prof

    def counter_totals(self, stmt: int | None = None,
                       cats: tuple = ("node", "prep")) -> dict[str, int]:
        """Sum the span-attached counter deltas (the executor's
        snapshot-delta dicts) over one statement — by construction equal to
        the statement's global ``ExecStats`` movement for those counters."""
        spans = self.snapshot()
        if stmt is not None:
            spans = [s for s in spans if s.stmt == stmt]
        out: dict[str, int] = {}
        for s in spans:
            if s.cat in cats:
                for k, v in (s.args or {}).items():
                    if isinstance(v, int):
                        out[k] = out.get(k, 0) + v
        return out

    def chrome_trace(self) -> dict:
        return {"traceEvents": chrome_trace_events(self.snapshot()),
                "displayTimeUnit": "ms",
                "otherData": {"session": self.session_id}}

    def export(self, path: str) -> str:
        """Write the ring as Chrome trace-event JSON (open in Perfetto /
        chrome://tracing; pool threads are named tracks)."""
        doc = self.chrome_trace()
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        os.replace(tmp, path)
        return path

    def clear(self) -> None:
        with self._lock:
            self.events.clear()


# =============================================================================
# Chrome trace-event projection
# =============================================================================
def chrome_trace_events(spans: list[Span]) -> list[dict]:
    """Project spans to the Chrome trace-event JSON array: one complete
    (``ph: X``) event per span on its thread's track, thread-name metadata
    events, instants as ``ph: i``, and flow arrows (``ph: s``/``f``) for
    parent→child edges that cross threads (dispatch → pool chunk)."""
    events: list[dict] = []
    tids: dict[str, int] = {}
    by_id: dict[int, Span] = {s.id: s for s in spans}

    def tid(name: str) -> int:
        t = tids.get(name)
        if t is None:
            t = tids[name] = len(tids) + 1
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": t, "args": {"name": name}})
        return t

    flows: set[int] = set()
    for s in spans:
        ev = {"name": s.name, "cat": s.cat, "pid": 1, "tid": tid(s.tid),
              "ts": s.t0 / 1000.0,
              "args": dict(s.args or {}, stmt=s.stmt, span=s.id)}
        if s.dur == 0 and s.cat in ("instant", "cache", "fault"):
            ev["ph"] = "i"
            ev["s"] = "t"
        else:
            ev["ph"] = "X"
            ev["dur"] = s.dur / 1000.0
        events.append(ev)
        parent = by_id.get(s.parent) if s.parent is not None else None
        if parent is not None and parent.tid != s.tid:
            # cross-thread edge: draw a flow arrow parent → child
            if parent.id not in flows:
                flows.add(parent.id)
                events.append({"ph": "s", "id": parent.id, "name": "parent",
                               "cat": "flow", "pid": 1, "tid": tid(parent.tid),
                               "ts": parent.t0 / 1000.0})
            events.append({"ph": "f", "bp": "e", "id": parent.id,
                           "name": "parent", "cat": "flow", "pid": 1,
                           "tid": tid(s.tid), "ts": s.t0 / 1000.0})
    return events


_PHASES = {"X", "i", "M", "s", "f"}


def validate_chrome_trace(doc: dict) -> int:
    """Schema check for an exported trace (the CI trace smoke gates on it).
    Returns the number of events; raises ``ValueError`` on any violation."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace document must be an object with traceEvents")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("traceEvents must be a list")
    for i, ev in enumerate(evs):
        for k in ("ph", "pid", "tid", "ts", "name") if ev.get("ph") != "M" \
                else ("ph", "pid", "tid", "name"):
            if k not in ev:
                raise ValueError(f"event {i} missing required key {k!r}")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if ev["ph"] == "X":
            if "dur" not in ev or ev["dur"] < 0:
                raise ValueError(f"event {i} (complete) needs dur >= 0")
        if ev["ph"] in ("s", "f") and "id" not in ev:
            raise ValueError(f"event {i} (flow) needs an id")
    return len(evs)


# =============================================================================
# resolution: active session's tracer → process override → REPRO_TRACE env
# =============================================================================
_UNSET = object()
_PROC: Tracer | None = None
_PROC_KEY: tuple | None = None      # (env value, ring) the tracer was built for
_OVERRIDE: Tracer | None | object = _UNSET
_PROC_LOCK = threading.Lock()


def _process_tracer() -> Tracer | None:
    """The process-wide tracer per ``REPRO_TRACE`` (lazy; rebuilt when the
    env value changes — tests flip it).  A path-shaped value also registers
    an atexit export to that path."""
    global _PROC, _PROC_KEY
    raw = os.environ.get("REPRO_TRACE", "")
    if raw in ("", "0"):
        return None
    key = (raw, ring_size())
    if _PROC is not None and _PROC_KEY == key:
        return _PROC
    with _PROC_LOCK:
        if _PROC is None or _PROC_KEY != key:
            _PROC = Tracer(session_id="proc")
            _PROC_KEY = key
            if raw not in ("1", "true", "on"):
                # path-shaped value: export the ring at process exit
                atexit.register(_atexit_export, _PROC, raw)
    return _PROC


def _atexit_export(tr: Tracer, path: str) -> None:
    try:
        tr.export(path)
    except OSError:
        pass


def current(cfg: Any = _UNSET) -> Tracer | None:
    """The tracer for the calling context, or None (tracing disabled — the
    production path: one contextvar read + an attribute check).  Resolution:
    active ``SessionConfig.trace`` → programmatic :func:`configure` override
    → ``REPRO_TRACE`` env.  Pass ``cfg`` when the caller already fetched
    ``config.current()`` (the dispatch hot path)."""
    if cfg is _UNSET:
        cfg = _config.current()
    if cfg is not None and cfg.trace is not None:
        return cfg.trace or None     # False/"" = explicitly off this session
    if _OVERRIDE is not _UNSET:
        return _OVERRIDE
    return _process_tracer()


def configure(tracer: Tracer | None) -> None:
    """Process-wide programmatic override (CI smokes, benches): sticky until
    :func:`reset`.  ``configure(None)`` forces tracing OFF regardless of
    ``REPRO_TRACE``."""
    global _OVERRIDE
    _OVERRIDE = tracer


def reset() -> None:
    """Clear the override and the cached process tracer (next use rebuilds
    from the environment)."""
    global _OVERRIDE, _PROC, _PROC_KEY
    _OVERRIDE = _UNSET
    with _PROC_LOCK:
        _PROC = None
        _PROC_KEY = None


def export(path: str) -> str | None:
    """Export the currently-resolved tracer's ring to ``path`` (None when
    tracing is disabled)."""
    tr = current()
    return tr.export(path) if tr is not None else None


# =============================================================================
# the metrics registry (shared export shape: serve tier + core tier)
# =============================================================================
class Metrics:
    """Named counters/gauges behind one export shape.  Dict-style access
    (``m["steps"] += 1``) keeps existing serve-tier call sites working;
    missing names read as 0 so counters need no pre-registration."""

    __slots__ = ("name", "_vals", "_lock")

    def __init__(self, name: str = "", **initial: float):
        self.name = name
        self._vals: dict[str, float] = dict(initial)
        self._lock = threading.Lock()

    def __getitem__(self, key: str) -> float:
        return self._vals.get(key, 0)

    def __setitem__(self, key: str, value: float) -> None:
        with self._lock:
            self._vals[key] = value

    def __contains__(self, key: str) -> bool:
        return key in self._vals

    def __iter__(self) -> Iterator[str]:
        return iter(dict(self._vals))

    def keys(self):
        """Mapping protocol — lets ``dict(metrics)`` snapshot the registry."""
        return self.as_dict().keys()

    def items(self):
        return self.as_dict().items()

    def inc(self, key: str, d: float = 1) -> None:
        with self._lock:
            self._vals[key] = self._vals.get(key, 0) + d

    def gauge(self, key: str, value: float) -> None:
        with self._lock:
            self._vals[key] = value

    def as_dict(self) -> dict[str, float]:
        with self._lock:
            return dict(self._vals)

    def export(self) -> dict:
        """The ONE export shape both tiers share (serve engine metrics and
        ``ExecStats`` projections serialize identically)."""
        return {"name": self.name, "metrics": self.as_dict()}

    def __repr__(self) -> str:
        return f"Metrics({self.name!r}, {self.as_dict()!r})"


def stats_metrics(stats: Any, name: str = "core") -> Metrics:
    """Project an ``ExecStats`` (or any object with int/float attributes,
    e.g. through a ``StatsTee``) into the shared registry shape."""
    m = Metrics(name)
    src = stats
    fields = getattr(type(src), "__dataclass_fields__", None)
    names = list(fields) if fields else [
        a for a in dir(src) if not a.startswith("_")]
    for a in names:
        v = getattr(src, a, None)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            m[a] = v
    return m
