"""Approximate / progressive execution (paper §6.1.3).

Online-aggregation-style progressive evaluation: aggregates are computed one
row-block at a time; after each block the running estimate is re-scaled and a
CLT confidence interval is attached, so the user sees a result converge
instead of waiting for the full pass.  Works for sum/count/mean per group
(the paper's "produce an estimate of the first k groups" is the
``first_k_groups`` helper).

This is the immediate-feedback counterpart to the exact prefix computation in
``executor.evaluate_prefix`` — semantics change (estimates, not answers), in
exchange for latency proportional to the blocks consumed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterator, Sequence

import jax.numpy as jnp
import numpy as np

from .frame import Frame
from .partition import PartitionedFrame

__all__ = ["Estimate", "progressive_aggregate", "first_k_groups"]

_Z95 = 1.96


@dataclasses.dataclass
class Estimate:
    value: float
    ci_low: float
    ci_high: float
    rows_seen: int
    rows_total: int
    final: bool

    @property
    def fraction(self) -> float:
        return self.rows_seen / max(1, self.rows_total)


def progressive_aggregate(pf: PartitionedFrame, column: Any,
                          func: str = "sum") -> Iterator[Estimate]:
    """Yield progressively refined estimates of an aggregate over ``column``.

    Block order is the frame's row order — for order-correlated data a
    production system would randomize block order first (online aggregation
    [35]); we keep frame order so the estimate composes with prefix semantics.
    """
    assert func in ("sum", "count", "mean")
    total_rows = pf.nrows
    pf1 = pf.repartition(col_parts=1)
    if pf1.row_parts == 0 or total_rows == 0:
        # zero-block / zero-row frame: the block loop would yield NOTHING,
        # so a caller draining until final=True never terminates.  Emit one
        # final exact estimate: the empty sum/count are 0, the empty mean is
        # undefined (NaN).
        value = float("nan") if func == "mean" else 0.0
        yield Estimate(value, value, value, 0, total_rows, True)
        return
    seen = 0
    vals_sum = 0.0
    vals_sumsq = 0.0
    vals_cnt = 0
    for i in range(pf1.row_parts):
        block = pf1.parts[i][0].induce()
        c = block.col(column)
        v = np.asarray(c.data, dtype=np.float64)
        valid = np.asarray(c.valid_mask())
        v = v[valid]
        seen += block.nrows
        vals_sum += float(v.sum())
        vals_sumsq += float((v * v).sum())
        vals_cnt += int(v.size)
        final = i == pf1.row_parts - 1

        n = max(1, vals_cnt)
        mean = vals_sum / n
        var = max(0.0, vals_sumsq / n - mean * mean)
        se_mean = math.sqrt(var / n)
        if func == "mean":
            est, se = mean, se_mean
        elif func == "sum":
            scale = total_rows * (vals_cnt / max(1, seen))  # est. valid rows
            est, se = mean * scale, se_mean * scale
        else:  # count (valid rows)
            frac = vals_cnt / max(1, seen)
            est = frac * total_rows
            # CI denominator: the VALID-row count, consistently with the
            # n used for the mean/variance estimates above — the previous
            # max(1, seen) denominator understated the interval on sparse
            # (mostly-null) columns
            se = total_rows * math.sqrt(frac * (1 - frac) / max(1, vals_cnt))
        if final:
            if func == "mean":
                # the exact mean of zero valid rows is undefined, not the
                # running 0.0 the estimator would report
                est, se = (mean if vals_cnt else float("nan")), 0.0
            elif func == "sum":
                est, se = vals_sum, 0.0
            else:
                est, se = float(vals_cnt), 0.0
        yield Estimate(est, est - _Z95 * se, est + _Z95 * se, seen, total_rows, final)


def first_k_groups(pf: PartitionedFrame, key: Any, k: int) -> list:
    """§6.1.3: the approximate *structure* of a GROUP BY — the first k groups
    in input order, from the input prefix, without computing any aggregates
    ("placeholder" output: row-wise groups without values)."""
    pf1 = pf.repartition(col_parts=1)
    seen: list = []
    seen_set = set()
    for i in range(pf1.row_parts):
        block = pf1.parts[i][0].induce()
        for v in block.col(key).to_pylist():
            if v is not None and v not in seen_set:
                seen_set.add(v)
                seen.append(v)
                if len(seen) >= k:
                    return seen
    return seen
