"""Pandas-flavoured user API over the dataframe algebra (paper §4.1 API layer).

Every method rewrites a pandas-style call into algebra nodes — the paper's
"rewrites pandas API calls into a sequence of algebraic operators, allowing
pandas code to run as-is".  The surface covers the workflow of Figure 1
(iloc point updates, .T, column map, get_dummies, merge, cov) plus the
high-density functions of §3.6 (head/shape/sum/mean/groupby/sort_values/
drop/append/fillna/isna/cumsum/diff/shift/pivot/agg/...).

Evaluation follows the session mode: eager (pandas), lazy (Spark) or
opportunistic (§6.1.1, the default).
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Iterable, Sequence

import jax.numpy as jnp
import numpy as np

from . import algebra as alg
from .dtypes import Domain, parse_column
from .frame import Column, Frame
from .labels import labels_from_values
from .partition import PartitionedFrame
from .session import EvalMode, Session, get_session
from ..kernels import ops as kops

__all__ = ["DataFrame", "read_csv", "from_pydict", "concat", "get_dummies"]

_ANON = itertools.count()


# =============================================================================
# column expression wrapper (Series-lite, enough for predicates & arithmetic)
# =============================================================================
class ColumnExpr:
    def __init__(self, df: "DataFrame", expr: alg.Expr):
        self._df = df
        self._expr = expr

    # comparisons → predicates
    def _wrap(self, e: alg.Expr) -> "ColumnExpr":
        return ColumnExpr(self._df, e)

    def __eq__(self, o):  # type: ignore[override]
        return self._wrap(self._expr == _unwrap(o))

    def __ne__(self, o):  # type: ignore[override]
        return self._wrap(self._expr != _unwrap(o))

    def __lt__(self, o):
        return self._wrap(self._expr < _unwrap(o))

    def __le__(self, o):
        return self._wrap(self._expr <= _unwrap(o))

    def __gt__(self, o):
        return self._wrap(self._expr > _unwrap(o))

    def __ge__(self, o):
        return self._wrap(self._expr >= _unwrap(o))

    def __add__(self, o):
        return self._wrap(self._expr + _unwrap(o))

    def __sub__(self, o):
        return self._wrap(self._expr - _unwrap(o))

    def __mul__(self, o):
        return self._wrap(self._expr * _unwrap(o))

    def __truediv__(self, o):
        return self._wrap(self._expr / _unwrap(o))

    def __mod__(self, o):
        return self._wrap(self._expr % _unwrap(o))

    def __floordiv__(self, o):
        return self._wrap(self._expr // _unwrap(o))

    def __and__(self, o):
        return self._wrap(alg.BinExpr("&", self._expr, _unwrap(o)))

    def __or__(self, o):
        return self._wrap(alg.BinExpr("|", self._expr, _unwrap(o)))

    def __invert__(self):
        return self._wrap(~self._expr)

    def isna(self):
        return self._wrap(self._expr.isna())

    def notna(self):
        return self._wrap(self._expr.notna())

    # value-level map (paper §2 C3): host fn per value, schema re-induced
    def map(self, fn: Callable[[Any], Any]) -> "DataFrame":
        assert isinstance(self._expr, alg.ColRef)
        return self._df._map_values(fn, [self._expr.name])

    # aggregates → scalars
    def _agg(self, func: str):
        assert isinstance(self._expr, alg.ColRef)
        name = self._expr.name
        node = alg.GroupBy(self._df._node, (), [(name, func, name)])
        f = self._df._session.collect(node)
        return f.col(name).to_pylist()[0]

    def sum(self):
        return self._agg("sum")

    def mean(self):
        return self._agg("mean")

    def max(self):
        return self._agg("max")

    def min(self):
        return self._agg("min")

    def count(self):
        return self._agg("count")

    def to_list(self) -> list:
        assert isinstance(self._expr, alg.ColRef)
        f = self._df._session.collect(alg.Projection(self._df._node, [self._expr.name]))
        return f.columns[0].to_pylist()


def _unwrap(o):
    if isinstance(o, ColumnExpr):
        return o._expr
    if isinstance(o, alg.Expr):
        return o
    return alg.Lit(o)


# =============================================================================
# the DataFrame handle
# =============================================================================
class DataFrame:
    """A handle: (session, plan node).  Composing methods builds the query
    DAG; inspection triggers evaluation per the session mode."""

    def __init__(self, data: Any = None, *, session: Session | None = None,
                 node: alg.Node | None = None, row_labels: Sequence | None = None):
        self._session = session or get_session()
        if node is not None:
            self._node = node
        elif isinstance(data, dict):
            self._node = self._session.register_frame(
                Frame.from_pydict(data, row_labels=row_labels))
        elif isinstance(data, Frame):
            self._node = self._session.register_frame(data)
        elif isinstance(data, PartitionedFrame):
            self._node = self._session.register_frame(data)
        else:
            raise TypeError(f"cannot construct DataFrame from {type(data)}")
        self._session.statement(self._node)

    # ------------------------------------------------------------------
    def _derive(self, node: alg.Node) -> "DataFrame":
        out = DataFrame.__new__(DataFrame)
        out._session = self._session
        out._node = node
        self._session.statement(node)
        return out

    def _collect(self) -> Frame:
        return self._session.collect(self._node)

    # ------------------------------------------------------------------
    # inspection (§3.6 high-density functions)
    # ------------------------------------------------------------------
    def head(self, k: int = 5) -> Frame:
        return self._session.head(self._node, k)

    def tail(self, k: int = 5) -> Frame:
        return self._session.tail(self._node, k)

    def collect(self) -> Frame:
        return self._collect()

    def to_pydict(self) -> dict:
        return self._collect().to_pydict()

    def to_records(self) -> list[tuple]:
        return self._collect().to_records()

    @property
    def shape(self) -> tuple[int, int]:
        f = self._collect()
        return f.shape

    @property
    def columns(self) -> list:
        f = self._collect()
        return f.col_labels.to_list()

    @property
    def index(self) -> list:
        return self._collect().row_labels.to_list()

    @property
    def dtypes(self) -> list:
        return [d.value for d in self._collect().induce().schema]

    def __repr__(self) -> str:
        try:
            f = self.head(5)
            return f"DataFrame(plan={self._node.op}, head=\n{f.to_pydict()})"
        except Exception as e:  # plans can fail lazily, like any dataframe lib
            return f"DataFrame(plan={self._node.op}, error={e})"

    def __len__(self) -> int:
        return self._collect().nrows

    # ------------------------------------------------------------------
    # selection / projection / indexing
    # ------------------------------------------------------------------
    def __getitem__(self, key):
        if isinstance(key, str):
            return ColumnExpr(self, alg.col(key))
        if isinstance(key, list):
            return self._derive(alg.Projection(self._node, key))
        if isinstance(key, ColumnExpr):
            return self._derive(alg.Selection(self._node, key._expr))
        if isinstance(key, alg.Expr):
            return self._derive(alg.Selection(self._node, key))
        raise TypeError(type(key))

    def __setitem__(self, key: str, value) -> None:
        """Column assign (paper C3: ``df[c] = df[c].map(f)`` etc.)."""
        if isinstance(value, DataFrame):
            # ``df[c] = df[c].map(f)``: the map produced a full-frame plan with
            # the column transformed in place — adopt it lazily when it derives
            # from this frame's plan, else splice the named column eagerly.
            if (value._node.op == "map" and value._node.children
                    and value._node.children[0] == self._node):
                self._node = value._node
                return
            src = value._collect()
            names = src.col_labels.to_list()
            col = src.columns[names.index(key)] if key in names else src.columns[0]
            self._assign_materialized(key, col)
            return
        if isinstance(value, ColumnExpr):
            expr = value._expr
            udf = alg.Udf.wrap(_expr_assign_fn(key, expr), name=f"assign_{key}_{expr!r}",
                               deps=frozenset(expr.refs()), elementwise=True)
            self._node = self._session.statement(alg.Map(self._node, udf))
            return
        # host array/list: eager materialize + splice
        vals = list(value)
        p = parse_column(vals)
        self._assign_materialized(key, Column(p.data, p.domain, p.mask, p.dictionary))

    def _assign_materialized(self, key: str, col: Column) -> None:
        f = self._collect()
        names = f.col_labels.to_list()
        cols = list(f.columns)
        if key in names:
            cols[names.index(key)] = col
        else:
            names.append(key)
            cols.append(col)
        nf = Frame(cols, f.row_labels, labels_from_values(names))
        self._node = self._session.statement(self._session.register_frame(nf))

    # iloc point get/set (paper C1 — ordered point updates)
    @property
    def iloc(self) -> "_ILoc":
        return _ILoc(self)

    def drop(self, columns: Sequence[str]) -> "DataFrame":
        keep = [c for c in self.columns if c not in set(columns)]
        return self._derive(alg.Projection(self._node, keep))

    def dropna(self) -> "DataFrame":
        pred = None
        for c in self.columns:
            e = alg.col(c).notna()
            pred = e if pred is None else alg.BinExpr("&", pred, e)
        return self._derive(alg.Selection(self._node, pred))

    # ------------------------------------------------------------------
    # maps & user-defined transforms
    # ------------------------------------------------------------------
    def map_udf(self, udf: alg.Udf) -> "DataFrame":
        return self._derive(alg.Map(self._node, udf))

    def _map_values(self, fn: Callable, columns: Sequence[str]) -> "DataFrame":
        """Per-value host function over given columns (schema re-induced —
        the S(·) interplay of paper §3.3 MAP)."""
        cols = tuple(columns)

        def apply(cdict, frame):
            out_cols, out_names = [], []
            for n, c in cdict.items():
                if n in cols:
                    vals = [None if v is None else fn(v) for v in c.to_pylist()]
                    p = parse_column(vals)
                    out_cols.append(Column(p.data, p.domain, p.mask, p.dictionary))
                else:
                    out_cols.append(c)
                out_names.append(n)
            return Frame(out_cols, frame.row_labels, labels_from_values(out_names))

        udf = alg.Udf.wrap(apply, name=f"map_values_{fn.__name__}_{cols}_{next(_ANON)}",
                           deps=frozenset(cols), elementwise=True)
        return self._derive(alg.Map(self._node, udf))

    def fillna(self, value) -> "DataFrame":
        def apply(cdict, frame):
            out = {}
            for n, c in cdict.items():
                if c.mask is not None:
                    if c.domain.is_coded:
                        vals = [value if v is None else v for v in c.to_pylist()]
                        p = parse_column([str(v) for v in vals], Domain.STR)
                        out[n] = Column(p.data, p.domain, p.mask, p.dictionary)
                    else:
                        data = jnp.where(c.mask, c.data,
                                         jnp.asarray(value, dtype=c.data.dtype))
                        out[n] = Column(data, c.domain, None, None)
                else:
                    out[n] = c
            return Frame(list(out.values()), frame.row_labels,
                         labels_from_values(list(out.keys())))

        udf = alg.Udf.wrap(apply, name=f"fillna_{value!r}", elementwise=True)
        return self._derive(alg.Map(self._node, udf))

    def isna(self) -> "DataFrame":
        def apply(cdict, frame):
            out = {}
            for n, c in cdict.items():
                out[n] = Column(~c.valid_mask(), Domain.BOOL, None, None)
            return Frame(list(out.values()), frame.row_labels,
                         labels_from_values(list(out.keys())))
        udf = alg.Udf.wrap(apply, name="isna", elementwise=True)
        return self._derive(alg.Map(self._node, udf))

    # ------------------------------------------------------------------
    # relational
    # ------------------------------------------------------------------
    def merge(self, other: "DataFrame", on: str | Sequence[str] | None = None,
              how: str = "inner", left_on=None, right_on=None) -> "DataFrame":
        on_t = [on] if isinstance(on, str) else on
        lo = [left_on] if isinstance(left_on, str) else left_on
        ro = [right_on] if isinstance(right_on, str) else right_on
        return self._derive(alg.Join(self._node, other._node, on=on_t, how=how,
                                     left_on=lo, right_on=ro))

    def cross(self, other: "DataFrame") -> "DataFrame":
        return self._derive(alg.Join(self._node, other._node, on=None, how="inner"))

    def append(self, other: "DataFrame") -> "DataFrame":
        return self._derive(alg.Union(self._node, other._node))

    def difference(self, other: "DataFrame") -> "DataFrame":
        return self._derive(alg.Difference(self._node, other._node))

    def drop_duplicates(self, subset: Sequence[str] | None = None) -> "DataFrame":
        return self._derive(alg.DropDuplicates(self._node, subset))

    def sort_values(self, by: str | Sequence[str], ascending: bool = True) -> "DataFrame":
        by_t = [by] if isinstance(by, str) else list(by)
        return self._derive(alg.Sort(self._node, by_t, ascending))

    def rename(self, columns: dict) -> "DataFrame":
        return self._derive(alg.Rename(self._node, columns))

    def groupby(self, keys: str | Sequence[str]) -> "_GroupBy":
        return _GroupBy(self, [keys] if isinstance(keys, str) else list(keys))

    # ------------------------------------------------------------------
    # dataframe-specific
    # ------------------------------------------------------------------
    @property
    def T(self) -> "DataFrame":
        return self._derive(alg.Transpose(self._node))

    def transpose(self) -> "DataFrame":
        return self.T

    def set_index(self, column: str) -> "DataFrame":
        return self._derive(alg.ToLabels(self._node, column))

    def reset_index(self, name: str = "index") -> "DataFrame":
        return self._derive(alg.FromLabels(self._node, name))

    # ------------------------------------------------------------------
    # windows (§3.4: cummax, diff, shift, ...)
    # ------------------------------------------------------------------
    def cumsum(self, cols=None):
        return self._derive(alg.Window(self._node, "cumsum", cols))

    def cummax(self, cols=None):
        return self._derive(alg.Window(self._node, "cummax", cols))

    def cummin(self, cols=None):
        return self._derive(alg.Window(self._node, "cummin", cols))

    def diff(self, periods: int = 1, cols=None):
        return self._derive(alg.Window(self._node, "diff", cols, periods=periods))

    def shift(self, periods: int = 1, cols=None):
        return self._derive(alg.Window(self._node, "shift", cols, periods=periods))

    def rolling_sum(self, size: int, cols=None):
        return self._derive(alg.Window(self._node, "rolling_sum", cols, size=size))

    def rolling_mean(self, size: int, cols=None):
        return self._derive(alg.Window(self._node, "rolling_mean", cols, size=size))

    # ------------------------------------------------------------------
    # aggregation sugar
    # ------------------------------------------------------------------
    def _numeric_cols(self) -> list:
        f = self._collect().induce()
        return [n for n, c in zip(f.col_labels.to_list(), f.columns)
                if c.domain.is_numeric]

    def agg(self, funcs: Sequence[str]) -> "DataFrame":
        """Paper §3.4: one GROUPBY per aggregate + UNION, in listed order."""
        cols = self._numeric_cols()
        node = None
        for fn in funcs:
            g = alg.GroupBy(self._node, (), [(c, fn, c) for c in cols])
            node = g if node is None else alg.Union(node, g)
        return self._derive(node)

    def sum(self):
        return self.agg(["sum"])

    def mean(self):
        return self.agg(["mean"])

    def count(self):
        return self.agg(["count"])

    def max(self):
        return self.agg(["max"])

    def min(self):
        return self.agg(["min"])

    def cov(self) -> Frame:
        """Matrix covariance (paper §2 A3): requires a matrix dataframe."""
        f = self._collect().induce()
        assert f.is_matrix(), "cov() requires a homogeneous numeric (matrix) dataframe"
        mat, _ = f.as_matrix(Domain.FLOAT)
        x = mat - mat.mean(axis=0, keepdims=True)
        c = (x.T @ x) / max(1, (f.nrows - 1))
        return Frame.from_matrix(c, Domain.FLOAT, row_labels=f.col_labels,
                                 col_labels=f.col_labels)

    # ------------------------------------------------------------------
    def pivot(self, index: str, columns: str, values: str) -> "DataFrame":
        """Paper §3.4 pivot.  Composed from algebra ops: one shared-scan
        SELECTION+PROJECTION per pivot value joined on the index (MQO turns
        these into shared sub-plans), finishing with TOLABELS."""
        f = self._collect().induce()
        pcol = f.col(columns)
        distinct = sorted(set(v for v in pcol.to_pylist() if v is not None),
                          key=lambda v: str(v))
        node = None
        for v in distinct:
            sel = alg.Selection(self._node, alg.col(columns) == alg.lit(v))
            proj = alg.Projection(sel, [index, values])
            ren = alg.Rename(proj, {values: v})
            node = ren if node is None else alg.Join(node, ren, on=[index], how="outer")
        return self._derive(alg.ToLabels(node, index))


def _expr_assign_fn(key: str, expr: alg.Expr):
    from .physical import eval_expr

    def apply(cdict, frame):
        v, mask = eval_expr(expr, frame)
        dom = (Domain.BOOL if v.dtype == jnp.bool_
               else Domain.INT if jnp.issubdtype(v.dtype, jnp.integer) else Domain.FLOAT)
        out = dict(cdict)
        out[key] = Column(v, dom, None if bool(mask.all()) else mask, None)
        return Frame(list(out.values()), frame.row_labels,
                     labels_from_values(list(out.keys())))

    return apply


# =============================================================================
class _ILoc:
    def __init__(self, df: DataFrame):
        self._df = df

    def __getitem__(self, rc):
        r, c = rc
        return self._df._collect().iloc_get(r, c)

    def __setitem__(self, rc, value):
        r, c = rc
        f = self._df._collect().iloc_set(r, c, value)
        self._df._node = self._df._session.statement(
            self._df._session.register_frame(f))


class _GroupBy:
    def __init__(self, df: DataFrame, keys: list):
        self._df = df
        self._keys = keys

    def agg(self, spec: dict) -> DataFrame:
        aggs = []
        for c, fns in spec.items():
            for fn in ([fns] if isinstance(fns, str) else fns):
                out = f"{c}_{fn}" if not isinstance(fns, str) else c
                aggs.append((c, fn, out))
        return self._df._derive(alg.GroupBy(self._df._node, self._keys, aggs))

    def _all(self, fn: str) -> DataFrame:
        cols = [c for c in self._df.columns if c not in self._keys]
        f = self._df._collect().induce()
        numeric = {n for n, c in zip(f.col_labels.to_list(), f.columns)
                   if c.domain.is_numeric}
        aggs = [(c, fn, c) for c in cols if fn == "count" or c in numeric]
        return self._df._derive(alg.GroupBy(self._df._node, self._keys, aggs))

    def count(self):
        return self._all("count")

    def sum(self):
        return self._all("sum")

    def mean(self):
        return self._all("mean")

    def max(self):
        return self._all("max")

    def min(self):
        return self._all("min")


# =============================================================================
# module-level constructors
# =============================================================================
def from_pydict(data: dict, session: Session | None = None,
                row_labels: Sequence | None = None) -> DataFrame:
    return DataFrame(data, session=session, row_labels=row_labels)


def read_csv(path: str, session: Session | None = None, sep: str = ",") -> DataFrame:
    """CSV ingest: parse on host, induce schema per column via S(·)."""
    with open(path) as f:
        header = f.readline().rstrip("\n").split(sep)
        rows = [line.rstrip("\n").split(sep) for line in f if line.strip()]
    data = {h: [r[i] if i < len(r) and r[i] != "" else None for r in rows]
            for i, h in enumerate(header)}
    return DataFrame(data, session=session)


def concat(dfs: Sequence[DataFrame]) -> DataFrame:
    out = dfs[0]
    for d in dfs[1:]:
        out = out.append(d)
    return out


def get_dummies(df: DataFrame, columns: Sequence[str]) -> DataFrame:
    """One-hot encoding (paper §2 A1) via the onehot kernel."""
    cols = tuple(columns)

    def apply(cdict, frame):
        out_cols, out_names = [], []
        for n, c in cdict.items():
            if n in cols and c.domain.is_coded:
                table = c.dictionary or ()
                hot = kops.onehot_encode(c.data, len(table))
                for g, val in enumerate(table):
                    out_names.append(f"{n}_{val}")
                    out_cols.append(Column(hot[:, g].astype(np.int32), Domain.INT,
                                           c.mask, None))
            else:
                out_names.append(n)
                out_cols.append(c)
        return Frame(out_cols, frame.row_labels, labels_from_values(out_names))

    udf = alg.Udf.wrap(apply, name=f"get_dummies_{cols}", deps=frozenset(cols),
                       elementwise=True)
    return df.map_udf(udf)
