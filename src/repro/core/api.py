"""Pandas-flavoured user API over the dataframe algebra (paper §4.1 API layer).

Every method rewrites a pandas-style call into algebra nodes — the paper's
"rewrites pandas API calls into a sequence of algebraic operators, allowing
pandas code to run as-is".  The surface covers the workflow of Figure 1
(iloc point updates, .T, column map, get_dummies, merge, cov) plus the
high-density functions of §3.6 (head/shape/sum/mean/groupby/sort_values/
drop/append/fillna/isna/cumsum/diff/shift/pivot/agg/...).

Evaluation follows the session mode: eager (pandas), lazy (Spark) or
opportunistic (§6.1.1, the default).
"""
from __future__ import annotations

import itertools
import os
from typing import Any, Callable, Iterable, Sequence

import jax.numpy as jnp
import numpy as np

from . import algebra as alg
from .dtypes import Domain, parse_column, storage_dtype
from .faults import IngestError, env_int
from .frame import Column, Frame
from .labels import RangeLabels, labels_from_values
from .partition import PartitionedFrame
from .session import EvalMode, Session, get_session
from ..kernels import ops as kops

__all__ = ["DataFrame", "read_csv", "from_pydict", "concat", "get_dummies"]

_ANON = itertools.count()


# =============================================================================
# column expression wrapper (Series-lite, enough for predicates & arithmetic)
# =============================================================================
class ColumnExpr:
    def __init__(self, df: "DataFrame", expr: alg.Expr):
        self._df = df
        self._expr = expr

    # comparisons → predicates
    def _wrap(self, e: alg.Expr) -> "ColumnExpr":
        return ColumnExpr(self._df, e)

    def __eq__(self, o):  # type: ignore[override]
        return self._wrap(self._expr == _unwrap(o))

    def __ne__(self, o):  # type: ignore[override]
        return self._wrap(self._expr != _unwrap(o))

    def __lt__(self, o):
        return self._wrap(self._expr < _unwrap(o))

    def __le__(self, o):
        return self._wrap(self._expr <= _unwrap(o))

    def __gt__(self, o):
        return self._wrap(self._expr > _unwrap(o))

    def __ge__(self, o):
        return self._wrap(self._expr >= _unwrap(o))

    def __add__(self, o):
        return self._wrap(self._expr + _unwrap(o))

    def __sub__(self, o):
        return self._wrap(self._expr - _unwrap(o))

    def __mul__(self, o):
        return self._wrap(self._expr * _unwrap(o))

    def __truediv__(self, o):
        return self._wrap(self._expr / _unwrap(o))

    def __mod__(self, o):
        return self._wrap(self._expr % _unwrap(o))

    def __floordiv__(self, o):
        return self._wrap(self._expr // _unwrap(o))

    def __and__(self, o):
        return self._wrap(alg.BinExpr("&", self._expr, _unwrap(o)))

    def __or__(self, o):
        return self._wrap(alg.BinExpr("|", self._expr, _unwrap(o)))

    def __invert__(self):
        return self._wrap(~self._expr)

    def isna(self):
        return self._wrap(self._expr.isna())

    def notna(self):
        return self._wrap(self._expr.notna())

    # value-level map (paper §2 C3): host fn per value, schema re-induced
    def map(self, fn: Callable[[Any], Any]) -> "DataFrame":
        assert isinstance(self._expr, alg.ColRef)
        return self._df._map_values(fn, [self._expr.name])

    # aggregates → scalars
    def _agg(self, func: str):
        assert isinstance(self._expr, alg.ColRef)
        name = self._expr.name
        node = alg.GroupBy(self._df._node, (), [(name, func, name)])
        f = self._df._session.collect(node)
        return f.col(name).to_pylist()[0]

    def sum(self):
        return self._agg("sum")

    def mean(self):
        return self._agg("mean")

    def max(self):
        return self._agg("max")

    def min(self):
        return self._agg("min")

    def count(self):
        return self._agg("count")

    def to_list(self) -> list:
        assert isinstance(self._expr, alg.ColRef)
        f = self._df._session.collect(alg.Projection(self._df._node, [self._expr.name]))
        return f.columns[0].to_pylist()


def _unwrap(o):
    if isinstance(o, ColumnExpr):
        return o._expr
    if isinstance(o, alg.Expr):
        return o
    return alg.Lit(o)


# =============================================================================
# the DataFrame handle
# =============================================================================
class DataFrame:
    """A handle: (session, plan node).  Composing methods builds the query
    DAG; inspection triggers evaluation per the session mode."""

    def __init__(self, data: Any = None, *, session: Session | None = None,
                 node: alg.Node | None = None, row_labels: Sequence | None = None):
        self._session = session or get_session()
        if node is not None:
            self._node = node
        elif isinstance(data, dict):
            self._node = self._session.register_frame(
                Frame.from_pydict(data, row_labels=row_labels))
        elif isinstance(data, Frame):
            self._node = self._session.register_frame(data)
        elif isinstance(data, PartitionedFrame):
            self._node = self._session.register_frame(data)
        else:
            raise TypeError(f"cannot construct DataFrame from {type(data)}")
        self._session.statement(self._node)

    # ------------------------------------------------------------------
    def _derive(self, node: alg.Node) -> "DataFrame":
        out = DataFrame.__new__(DataFrame)
        out._session = self._session
        out._node = node
        self._session.statement(node)
        return out

    def _collect(self) -> Frame:
        return self._session.collect(self._node)

    # ------------------------------------------------------------------
    # inspection (§3.6 high-density functions)
    # ------------------------------------------------------------------
    def head(self, k: int = 5) -> Frame:
        return self._session.head(self._node, k)

    def tail(self, k: int = 5) -> Frame:
        return self._session.tail(self._node, k)

    def collect(self) -> Frame:
        return self._collect()

    def to_pydict(self) -> dict:
        return self._collect().to_pydict()

    def to_records(self) -> list[tuple]:
        return self._collect().to_records()

    @property
    def shape(self) -> tuple[int, int]:
        f = self._collect()
        return f.shape

    @property
    def columns(self) -> list:
        f = self._collect()
        return f.col_labels.to_list()

    @property
    def index(self) -> list:
        return self._collect().row_labels.to_list()

    @property
    def dtypes(self) -> list:
        return [d.value for d in self._collect().induce().schema]

    def __repr__(self) -> str:
        try:
            f = self.head(5)
            return f"DataFrame(plan={self._node.op}, head=\n{f.to_pydict()})"
        except Exception as e:  # plans can fail lazily, like any dataframe lib
            return f"DataFrame(plan={self._node.op}, error={e})"

    def __len__(self) -> int:
        return self._collect().nrows

    # ------------------------------------------------------------------
    # selection / projection / indexing
    # ------------------------------------------------------------------
    def __getitem__(self, key):
        if isinstance(key, str):
            return ColumnExpr(self, alg.col(key))
        if isinstance(key, list):
            return self._derive(alg.Projection(self._node, key))
        if isinstance(key, ColumnExpr):
            return self._derive(alg.Selection(self._node, key._expr))
        if isinstance(key, alg.Expr):
            return self._derive(alg.Selection(self._node, key))
        raise TypeError(type(key))

    def __setitem__(self, key: str, value) -> None:
        """Column assign (paper C3: ``df[c] = df[c].map(f)`` etc.)."""
        if isinstance(value, DataFrame):
            # ``df[c] = df[c].map(f)``: the map produced a full-frame plan with
            # the column transformed in place — adopt it lazily when it derives
            # from this frame's plan, else splice the named column eagerly.
            if (value._node.op == "map" and value._node.children
                    and value._node.children[0] == self._node):
                self._node = value._node
                return
            src = value._collect()
            names = src.col_labels.to_list()
            col = src.columns[names.index(key)] if key in names else src.columns[0]
            self._assign_materialized(key, col)
            return
        if isinstance(value, ColumnExpr):
            expr = value._expr
            udf = alg.Udf.wrap(_expr_assign_fn(key, expr), name=f"assign_{key}_{expr!r}",
                               deps=frozenset(expr.refs()), elementwise=True)
            self._node = self._session.statement(alg.Map(self._node, udf))
            return
        # host array/list: eager materialize + splice
        vals = list(value)
        p = parse_column(vals)
        self._assign_materialized(key, Column(p.data, p.domain, p.mask, p.dictionary))

    def _assign_materialized(self, key: str, col: Column) -> None:
        f = self._collect()
        names = f.col_labels.to_list()
        cols = list(f.columns)
        if key in names:
            cols[names.index(key)] = col
        else:
            names.append(key)
            cols.append(col)
        nf = Frame(cols, f.row_labels, labels_from_values(names))
        self._node = self._session.statement(self._session.register_frame(nf))

    # iloc point get/set (paper C1 — ordered point updates)
    @property
    def iloc(self) -> "_ILoc":
        return _ILoc(self)

    def drop(self, columns: Sequence[str]) -> "DataFrame":
        keep = [c for c in self.columns if c not in set(columns)]
        return self._derive(alg.Projection(self._node, keep))

    def dropna(self) -> "DataFrame":
        pred = None
        for c in self.columns:
            e = alg.col(c).notna()
            pred = e if pred is None else alg.BinExpr("&", pred, e)
        return self._derive(alg.Selection(self._node, pred))

    # ------------------------------------------------------------------
    # maps & user-defined transforms
    # ------------------------------------------------------------------
    def map_udf(self, udf: alg.Udf) -> "DataFrame":
        return self._derive(alg.Map(self._node, udf))

    def _map_values(self, fn: Callable, columns: Sequence[str]) -> "DataFrame":
        """Per-value host function over given columns (schema re-induced —
        the S(·) interplay of paper §3.3 MAP)."""
        cols = tuple(columns)

        def apply(cdict, frame):
            out_cols, out_names = [], []
            for n, c in cdict.items():
                if n in cols:
                    vals = [None if v is None else fn(v) for v in c.to_pylist()]
                    p = parse_column(vals)
                    out_cols.append(Column(p.data, p.domain, p.mask, p.dictionary))
                else:
                    out_cols.append(c)
                out_names.append(n)
            return Frame(out_cols, frame.row_labels, labels_from_values(out_names))

        udf = alg.Udf.wrap(apply, name=f"map_values_{fn.__name__}_{cols}_{next(_ANON)}",
                           deps=frozenset(cols), elementwise=True)
        return self._derive(alg.Map(self._node, udf))

    def fillna(self, value) -> "DataFrame":
        def apply(cdict, frame):
            out = {}
            for n, c in cdict.items():
                if c.mask is not None:
                    if c.domain.is_coded:
                        vals = [value if v is None else v for v in c.to_pylist()]
                        p = parse_column([str(v) for v in vals], Domain.STR)
                        out[n] = Column(p.data, p.domain, p.mask, p.dictionary)
                    else:
                        data = jnp.where(c.mask, c.data,
                                         jnp.asarray(value, dtype=c.data.dtype))
                        out[n] = Column(data, c.domain, None, None)
                else:
                    out[n] = c
            return Frame(list(out.values()), frame.row_labels,
                         labels_from_values(list(out.keys())))

        udf = alg.Udf.wrap(apply, name=f"fillna_{value!r}", elementwise=True)
        return self._derive(alg.Map(self._node, udf))

    def isna(self) -> "DataFrame":
        def apply(cdict, frame):
            out = {}
            for n, c in cdict.items():
                out[n] = Column(~c.valid_mask(), Domain.BOOL, None, None)
            return Frame(list(out.values()), frame.row_labels,
                         labels_from_values(list(out.keys())))
        udf = alg.Udf.wrap(apply, name="isna", elementwise=True)
        return self._derive(alg.Map(self._node, udf))

    # ------------------------------------------------------------------
    # relational
    # ------------------------------------------------------------------
    def merge(self, other: "DataFrame", on: str | Sequence[str] | None = None,
              how: str = "inner", left_on=None, right_on=None) -> "DataFrame":
        on_t = [on] if isinstance(on, str) else on
        lo = [left_on] if isinstance(left_on, str) else left_on
        ro = [right_on] if isinstance(right_on, str) else right_on
        return self._derive(alg.Join(self._node, other._node, on=on_t, how=how,
                                     left_on=lo, right_on=ro))

    def cross(self, other: "DataFrame") -> "DataFrame":
        return self._derive(alg.Join(self._node, other._node, on=None, how="inner"))

    def append(self, other: "DataFrame") -> "DataFrame":
        return self._derive(alg.Union(self._node, other._node))

    def difference(self, other: "DataFrame") -> "DataFrame":
        return self._derive(alg.Difference(self._node, other._node))

    def drop_duplicates(self, subset: Sequence[str] | None = None) -> "DataFrame":
        return self._derive(alg.DropDuplicates(self._node, subset))

    def sort_values(self, by: str | Sequence[str], ascending: bool = True) -> "DataFrame":
        by_t = [by] if isinstance(by, str) else list(by)
        return self._derive(alg.Sort(self._node, by_t, ascending))

    def rename(self, columns: dict) -> "DataFrame":
        return self._derive(alg.Rename(self._node, columns))

    def groupby(self, keys: str | Sequence[str]) -> "_GroupBy":
        return _GroupBy(self, [keys] if isinstance(keys, str) else list(keys))

    # ------------------------------------------------------------------
    # dataframe-specific
    # ------------------------------------------------------------------
    @property
    def T(self) -> "DataFrame":
        return self._derive(alg.Transpose(self._node))

    def transpose(self) -> "DataFrame":
        return self.T

    def set_index(self, column: str) -> "DataFrame":
        return self._derive(alg.ToLabels(self._node, column))

    def reset_index(self, name: str = "index") -> "DataFrame":
        return self._derive(alg.FromLabels(self._node, name))

    # ------------------------------------------------------------------
    # windows (§3.4: cummax, diff, shift, ...)
    # ------------------------------------------------------------------
    def cumsum(self, cols=None):
        return self._derive(alg.Window(self._node, "cumsum", cols))

    def cummax(self, cols=None):
        return self._derive(alg.Window(self._node, "cummax", cols))

    def cummin(self, cols=None):
        return self._derive(alg.Window(self._node, "cummin", cols))

    def diff(self, periods: int = 1, cols=None):
        return self._derive(alg.Window(self._node, "diff", cols, periods=periods))

    def shift(self, periods: int = 1, cols=None):
        return self._derive(alg.Window(self._node, "shift", cols, periods=periods))

    def rolling_sum(self, size: int, cols=None):
        return self._derive(alg.Window(self._node, "rolling_sum", cols, size=size))

    def rolling_mean(self, size: int, cols=None):
        return self._derive(alg.Window(self._node, "rolling_mean", cols, size=size))

    # ------------------------------------------------------------------
    # aggregation sugar
    # ------------------------------------------------------------------
    def _numeric_cols(self) -> list:
        f = self._collect().induce()
        return [n for n, c in zip(f.col_labels.to_list(), f.columns)
                if c.domain.is_numeric]

    def agg(self, funcs: Sequence[str]) -> "DataFrame":
        """Paper §3.4: one GROUPBY per aggregate + UNION, in listed order."""
        cols = self._numeric_cols()
        node = None
        for fn in funcs:
            g = alg.GroupBy(self._node, (), [(c, fn, c) for c in cols])
            node = g if node is None else alg.Union(node, g)
        return self._derive(node)

    def sum(self):
        return self.agg(["sum"])

    def mean(self):
        return self.agg(["mean"])

    def count(self):
        return self.agg(["count"])

    def max(self):
        return self.agg(["max"])

    def min(self):
        return self.agg(["min"])

    def cov(self) -> Frame:
        """Matrix covariance (paper §2 A3): requires a matrix dataframe."""
        f = self._collect().induce()
        assert f.is_matrix(), "cov() requires a homogeneous numeric (matrix) dataframe"
        mat, _ = f.as_matrix(Domain.FLOAT)
        x = mat - mat.mean(axis=0, keepdims=True)
        c = (x.T @ x) / max(1, (f.nrows - 1))
        return Frame.from_matrix(c, Domain.FLOAT, row_labels=f.col_labels,
                                 col_labels=f.col_labels)

    # ------------------------------------------------------------------
    def pivot(self, index: str, columns: str, values: str) -> "DataFrame":
        """Paper §3.4 pivot.  Composed from algebra ops: one shared-scan
        SELECTION+PROJECTION per pivot value joined on the index (MQO turns
        these into shared sub-plans), finishing with TOLABELS."""
        f = self._collect().induce()
        pcol = f.col(columns)
        distinct = sorted(set(v for v in pcol.to_pylist() if v is not None),
                          key=lambda v: str(v))
        node = None
        for v in distinct:
            sel = alg.Selection(self._node, alg.col(columns) == alg.lit(v))
            proj = alg.Projection(sel, [index, values])
            ren = alg.Rename(proj, {values: v})
            node = ren if node is None else alg.Join(node, ren, on=[index], how="outer")
        return self._derive(alg.ToLabels(node, index))


def _expr_assign_fn(key: str, expr: alg.Expr):
    from .physical import eval_expr

    def apply(cdict, frame):
        v, mask = eval_expr(expr, frame)
        dom = (Domain.BOOL if v.dtype == jnp.bool_
               else Domain.INT if jnp.issubdtype(v.dtype, jnp.integer) else Domain.FLOAT)
        out = dict(cdict)
        out[key] = Column(v, dom, None if bool(mask.all()) else mask, None)
        return Frame(list(out.values()), frame.row_labels,
                     labels_from_values(list(out.keys())))

    return apply


# =============================================================================
class _ILoc:
    def __init__(self, df: DataFrame):
        self._df = df

    def __getitem__(self, rc):
        r, c = rc
        return self._df._collect().iloc_get(r, c)

    def __setitem__(self, rc, value):
        r, c = rc
        f = self._df._collect().iloc_set(r, c, value)
        self._df._node = self._df._session.statement(
            self._df._session.register_frame(f))


class _GroupBy:
    def __init__(self, df: DataFrame, keys: list):
        self._df = df
        self._keys = keys

    def agg(self, spec: dict) -> DataFrame:
        aggs = []
        for c, fns in spec.items():
            for fn in ([fns] if isinstance(fns, str) else fns):
                out = f"{c}_{fn}" if not isinstance(fns, str) else c
                aggs.append((c, fn, out))
        return self._df._derive(alg.GroupBy(self._df._node, self._keys, aggs))

    def _all(self, fn: str) -> DataFrame:
        cols = [c for c in self._df.columns if c not in self._keys]
        f = self._df._collect().induce()
        numeric = {n for n, c in zip(f.col_labels.to_list(), f.columns)
                   if c.domain.is_numeric}
        aggs = [(c, fn, c) for c in cols if fn == "count" or c in numeric]
        return self._df._derive(alg.GroupBy(self._df._node, self._keys, aggs))

    def count(self):
        return self._all("count")

    def sum(self):
        return self._all("sum")

    def mean(self):
        return self._all("mean")

    def max(self):
        return self._all("max")

    def min(self):
        return self._all("min")


# =============================================================================
# module-level constructors
# =============================================================================
def from_pydict(data: dict, session: Session | None = None,
                row_labels: Sequence | None = None) -> DataFrame:
    return DataFrame(data, session=session, row_labels=row_labels)


# =============================================================================
# CSV ingest: chunk-parallel streaming parser into store-backed blocks
# =============================================================================
# Two-pass schema induction over byte-range chunks (paper §3.2 S(·) at scale):
# pass 1 tokenizes each chunk in a pool worker and votes per-column
# *castability* flags (bool/int/float — conjunctive across chunks, so the
# merged domain equals what the seed's whole-column induce_schema would have
# chosen); pass 2 re-tokenizes and parses each chunk directly into a
# store-registered Frame block with vectorized numpy casts.  The whole file
# is never held as host lists — a CSV larger than REPRO_MEM_BUDGET streams
# straight into a spill-backed PartitionedFrame, earlier blocks spilling
# while later chunks still parse.
#
# Correctness over the seed parser: quoted fields may contain the separator
# (RFC-4180 quoting incl. doubled quotes), CRLF line endings are stripped,
# and a quoted empty field ("") is tokenized distinctly from a missing field
# — with pandas-default NA handling both become null (keep_default_na=True),
# with keep_default_na=False both surface as the empty string, exactly like
# ``pandas.read_csv`` (differential suite:
# tests/test_read_csv_differential.py).
#
# ``REPRO_CSV_STREAM=0`` routes through the seed parser (kept below as
# ``_read_csv_seed`` — the benchmark baseline and a fallback oracle).

_BOOL_TRUE = ("true", "yes", "t", "1")
_BOOL_FALSE = ("false", "no", "f", "0")


def _read_csv_seed(path: str, session: Session | None = None,
                   sep: str = ",") -> DataFrame:
    """The seed parser: whole file as host lists, per-value Python casts.
    Baseline for BENCH_outofcore and the ``REPRO_CSV_STREAM=0`` escape
    hatch.  Known gaps (fixed by the streaming parser): no quoting, no CRLF,
    empty conflated with missing."""
    with open(path) as f:
        header = f.readline().rstrip("\n").split(sep)
        rows = [line.rstrip("\n").split(sep) for line in f if line.strip()]
    data = {h: [r[i] if i < len(r) and r[i] != "" else None for r in rows]
            for i, h in enumerate(header)}
    return DataFrame(data, session=session)


def _split_line(line: str, sep: str) -> list[str]:
    """Tokenize one record into str fields.  Both an unquoted empty field
    and a quoted empty ("") surface as '' — exactly pandas' behaviour in
    both NA modes (default: '' → null; keep_default_na=False: '' stays a
    string value), so '' is the single missing sentinel downstream.  Quoted
    fields may contain the separator; doubled quotes escape a quote
    (RFC 4180)."""
    if '"' not in line:
        return line.split(sep)
    fields: list[str] = []
    i, n = 0, len(line)
    step = len(sep)
    while True:
        if i < n and line[i] == '"':
            buf = []
            i += 1
            closed = False
            while i < n:
                ch = line[i]
                if ch == '"':
                    if i + 1 < n and line[i + 1] == '"':
                        buf.append('"')
                        i += 2
                        continue
                    i += 1
                    closed = True
                    break
                buf.append(ch)
                i += 1
            if not closed:
                # a quoted field that never closes on this line is the
                # start of a multiline quoted field — the byte-range
                # chunker splits records on raw newlines, so supporting it
                # would silently corrupt data.  Fail loudly instead.
                raise ValueError(
                    "read_csv: quoted field contains a line break "
                    "(unterminated quote) — embedded newlines are not "
                    f"supported by the streaming parser: {line[:80]!r}")
            j = line.find(sep, i)
            if j == -1:
                buf.append(line[i:])
                fields.append("".join(buf))
                return fields
            buf.append(line[i:j])
            fields.append("".join(buf))
            i = j + step
        else:
            j = line.find(sep, i)
            if j == -1:
                fields.append(line[i:])
                return fields
            fields.append(line[i:j])
            i = j + step


_PAD: dict[int, list[str]] = {}


def _chunk_rows(raw: bytes, sep: str, width: int) -> list[list[str]]:
    """Decode + tokenize a byte-range chunk into width-padded field rows
    (CRLF-stripped, blank lines skipped — pandas skip_blank_lines).  A row
    with MORE fields than the header raises, like pandas' ParserError —
    silently truncating would drop data; short rows pad with missing
    fields, also pandas semantics."""
    rows: list[list[str]] = []
    pad = _PAD.setdefault(width, [""] * width)
    quote_free = b'"' not in raw
    for line in raw.decode("utf-8", errors="replace").split("\n"):
        if line.endswith("\r"):
            line = line[:-1]
        if not line:
            continue
        r = line.split(sep) if quote_free else _split_line(line, sep)
        m = len(r)
        if m != width:
            if m > width:
                raise ValueError(
                    f"read_csv: expected {width} fields, saw {m}: "
                    f"{line[:80]!r}")
            r = r + pad[m:]
        rows.append(r)
    return rows


def _chunk_columns(rows: list[list[str]], width: int) -> list[np.ndarray]:
    """Transpose to per-column numpy string arrays — the vectorized substrate
    every cast below runs on."""
    if not rows:
        return [np.empty(0, dtype="U1") for _ in range(width)]
    return [np.asarray(col) for col in zip(*rows)]


_BOOLSET = frozenset(_BOOL_TRUE + _BOOL_FALSE)


def _encode_str_column(arr: np.ndarray, valid: np.ndarray | None) -> tuple[np.ndarray, tuple]:
    """Dictionary-encode in first-occurrence order (order-stable, like
    ``dtypes.encode_dictionary``, but via one vectorized unique) →
    (codes int32 with -1 at nulls, table)."""
    n = int(arr.shape[0])
    codes = np.full(n, -1, dtype=np.int32)
    vals = arr if valid is None else arr[valid]
    if vals.size == 0:
        return codes, ()
    uniq, first, inv = np.unique(vals, return_index=True, return_inverse=True)
    order = np.argsort(first, kind="stable")
    rank = np.empty(order.shape[0], dtype=np.int32)
    rank[order] = np.arange(order.shape[0], dtype=np.int32)
    if valid is None:
        codes[:] = rank[inv]
    else:
        codes[valid] = rank[inv]
    return codes, tuple(str(u) for u in uniq[order])


def _scan_column(arr: np.ndarray, na_empty: bool):
    """Per-chunk castability flags + optimistic local parse, ONE vector cast
    per column: ``(flags, local_domain, data, valid_or_None, dictionary)``.

    ``flags = (bool_ok, int_ok, float_ok, any_value)`` are conjunctive
    across chunks, so the merged decision equals the seed's whole-column
    S(·) (bool ≺ int ≺ float ≺ Σ*).  The expensive vector casts are gated
    on a first-value probe — a float column never pays a full int attempt,
    a numeric column never pays the lowercase/isin bool sweep — and the
    successful cast IS the parse, kept for the finalize pass.  INT parses
    stay int64 host arrays here: the chunk cannot know yet whether the
    global domain is INT (int32 range-check applies, seed parity) or FLOAT
    (no range limit)."""
    n = int(arr.shape[0])
    miss = (arr == "") if na_empty else None
    any_miss = bool(miss.any()) if miss is not None else False
    valid = ~miss if any_miss else None
    present = arr[valid] if any_miss else arr
    if present.size == 0:
        return ((True, True, True, False), Domain.UNSPECIFIED,
                np.zeros(n, dtype=np.float32), None, None)
    probe = str(present[0])
    # ---- bool: probe, then one strip/lower + isin sweep --------------------
    bool_ok = False
    low = None
    if probe.strip().lower() in _BOOLSET:
        low = np.char.lower(np.char.strip(arr))
        sub = low[valid] if any_miss else low
        bool_ok = bool(np.isin(sub, _BOOL_TRUE + _BOOL_FALSE).all())
    # ---- int: probe, then the real cast (kept) -----------------------------
    int_ok, ints = False, None
    try:
        np.asarray([probe]).astype(np.int64)
        int_ok = True
    except (ValueError, OverflowError):
        pass
    if int_ok:
        try:
            ints = (np.where(miss, "0", arr) if any_miss else arr).astype(np.int64)
        except (ValueError, OverflowError):
            int_ok = False
    # ---- float: implied by int; else probe + cast (kept) -------------------
    flts = None
    if int_ok:
        float_ok = True
    else:
        float_ok = False
        try:
            np.asarray([probe]).astype(np.float64)
            float_ok = True
        except ValueError:
            pass
        if float_ok:
            try:
                flts = (np.where(miss, "0", arr) if any_miss else arr).astype(np.float64)
            except ValueError:
                float_ok = False
    flags = (bool_ok, int_ok, float_ok, True)
    if bool_ok:
        # ``low`` spans the full array; missing slots lower to '' → False,
        # and the mask hides them anyway
        return flags, Domain.BOOL, np.isin(low, _BOOL_TRUE), valid, None
    if int_ok:
        return flags, Domain.INT, ints, valid, None
    if float_ok:
        return flags, Domain.FLOAT, flts.astype(np.float32), valid, None
    codes, table = _encode_str_column(arr, valid)
    return flags, Domain.STR, codes, valid, table


def _finalize_column(data: np.ndarray, valid: np.ndarray | None,
                     dictionary: tuple | None, local: Domain,
                     dom: Domain, text: np.ndarray | None,
                     na_empty: bool) -> Column:
    """Convert a chunk column's optimistic local parse to the merged global
    domain — pure vector casts, except the (rare) demotion to Σ*, which
    re-reads the chunk's text.  Outputs match ``parse_column``: same
    storage dtypes, mask=None when all valid, jnp device arrays."""
    n = int(data.shape[0])
    mask = None if valid is None else jnp.asarray(valid)
    if dom is Domain.UNSPECIFIED:          # whole COLUMN all-null
        return Column(jnp.asarray(np.zeros(n, dtype=np.float32)), dom,
                      jnp.asarray(np.zeros(n, dtype=np.bool_)), None)
    if local is Domain.UNSPECIFIED:        # all-null CHUNK of a typed column
        zero = np.zeros(n, dtype=storage_dtype(dom))
        if dom.is_coded:
            zero = np.full(n, -1, dtype=np.int32)
        return Column(jnp.asarray(zero), dom,
                      jnp.asarray(np.zeros(n, dtype=np.bool_)),
                      () if dom.is_coded else None)
    if dom is Domain.STR and local is not Domain.STR:
        # demotion: another chunk had non-numeric text — re-encode from the
        # original characters (the parsed numbers can't reproduce them)
        assert text is not None
        miss = (text == "") if na_empty else None
        v = None if miss is None or not miss.any() else ~miss
        codes, table = _encode_str_column(text, v)
        return Column(jnp.asarray(codes), Domain.STR,
                      None if v is None else jnp.asarray(v), table)
    if dom is Domain.BOOL:                 # global BOOL ⇒ local BOOL
        return Column(jnp.asarray(data), dom, mask, None)
    if dom is Domain.INT:
        ints = data.astype(np.int64)       # local BOOL or INT
        if ints.size and (int(ints.max(initial=0)) > 2 ** 31 - 1
                          or int(ints.min(initial=0)) < -2 ** 31):
            # seed parity: ints beyond int32 must not silently wrap through
            # device storage (see dtypes.parse_column)
            raise OverflowError("integer column exceeds int32 storage")
        return Column(jnp.asarray(ints.astype(np.int32)), dom, mask, None)
    if dom is Domain.FLOAT:
        if local is Domain.FLOAT:
            return Column(jnp.asarray(data), dom, mask, None)
        # widening from BOOL/INT: exact (every int the chunk held is a
        # parsed text literal, so float64→float32 equals parsing as float)
        f = data.astype(np.float64).astype(np.float32)
        return Column(jnp.asarray(f), dom, mask, None)
    # dom is STR and local is STR: codes/table are already final
    return Column(jnp.asarray(data), Domain.STR, mask, dictionary)


def _csv_chunk_ranges(path: str, sep: str) -> tuple[list[str], list[tuple[int, int]]]:
    """Header + newline-aligned byte ranges.  Chunk count targets one task
    per (worker × coalesce slack); under a memory budget the chunk size is
    additionally capped at budget/4 so ingest blocks are spillable units."""
    from .schedule import budget_max_block_bytes, coalesce_factor, pool_width
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        hdr = f.readline()
        body0 = f.tell()
        header = _split_line(hdr.decode("utf-8", errors="replace")
                             .rstrip("\r\n"), sep)
        body = size - body0
        target = pool_width() * coalesce_factor()
        chunk_env = env_int("REPRO_CSV_CHUNK_BYTES", 0, minimum=0)
        if chunk_env:
            chunk_bytes = chunk_env
        else:
            chunk_bytes = max(1 << 16, body // max(1, target))
            mb = budget_max_block_bytes()
            if mb:
                # parsed block bytes can exceed the CSV bytes that produced
                # them (int64 intermediates, masks) — halve the cap so the
                # workers' pinned in/out pairs stay inside the budget
                chunk_bytes = min(chunk_bytes, max(1 << 12, mb // 2))
        bounds = [body0]
        pos = body0 + chunk_bytes
        while pos < size:
            f.seek(pos)
            f.readline()                 # align to the next record start
            pos = f.tell()
            if pos >= size:
                break
            bounds.append(pos)
            pos += chunk_bytes
        bounds.append(size)
    ranges = [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)
              if bounds[i + 1] > bounds[i]]
    return header, ranges or [(body0, body0)]


def read_csv(path: str, session: Session | None = None, sep: str = ",",
             usecols: Sequence[str] | None = None,
             keep_default_na: bool = True) -> DataFrame:
    """CSV ingest: chunk-parallel streaming parse straight into block-store
    partitions (schema induced by a two-pass per-chunk vote + parse).

    ``usecols`` pushes the projection into the parser — unselected columns
    are tokenized but never materialized.  ``keep_default_na=False`` keeps
    empty fields as empty strings instead of nulls (pandas semantics).
    """
    if os.environ.get("REPRO_CSV_STREAM", "") == "0":
        if usecols is not None or not keep_default_na:
            raise ValueError(
                "REPRO_CSV_STREAM=0 routes through the seed parser, which "
                "supports neither usecols nor keep_default_na=False")
        return _read_csv_seed(path, session=session, sep=sep)
    from .partition import PartitionedFrame
    from .schedule import dispatch_blocks
    from .store import as_handle, pinned, resolve

    header, ranges = _csv_chunk_ranges(path, sep)
    planned_size = ranges[-1][1]      # file size the byte ranges were cut for
    width = len(header)
    if usecols is not None:
        want = set(usecols)
        missing = want - set(header)
        if missing:
            raise KeyError(f"usecols not in header: {sorted(missing)}")
        sel = [j for j, h in enumerate(header) if h in want]
    else:
        sel = list(range(width))
    names = [header[j] for j in sel]

    def read_range(rng: tuple[int, int]) -> bytes:
        # the byte ranges are only meaningful against the file they were
        # planned over: a file that is truncated or grows between planning
        # and chunk tokenization must fail as ONE clear error, not silently
        # parse a torn record (or drop the appended tail)
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            actual = f.tell()
            if actual != planned_size:
                raise IngestError(
                    f"{path} changed during streaming ingest: byte ranges "
                    f"were planned over {planned_size} bytes but the file "
                    f"is now {actual} bytes "
                    f"({'truncated' if actual < planned_size else 'grew'} "
                    "between range planning and chunk tokenization)")
            f.seek(rng[0])
            data = f.read(rng[1] - rng[0])
        if len(data) != rng[1] - rng[0]:
            raise IngestError(
                f"{path} truncated during streaming ingest: chunk "
                f"[{rng[0]}, {rng[1]}) returned only {len(data)} bytes")
        return data

    na_empty = keep_default_na

    # ---- pass 1: per-chunk domain vote + optimistic local parse ------------
    # Each worker tokenizes its byte range once, votes castability flags per
    # column, and parses to the chunk-LOCAL domain, registering the result
    # with the block store immediately — under a budget, early chunks spill
    # while later chunks still parse, so the file is never fully resident.
    def scan_chunk(rng):
        def parse():
            rows = _chunk_rows(read_range(rng), sep, width)
            cols = _chunk_columns(rows, width)
            scanned = [_scan_column(cols[j], na_empty) for j in sel]
            parts = [Column(jnp.asarray(s[2]) if s[1] is not Domain.INT
                            else s[2],
                            s[1],
                            None if s[3] is None else jnp.asarray(s[3]),
                            s[4])
                     for s in scanned]
            f = Frame(parts, RangeLabels(len(rows)), labels_from_values(names))
            return f, scanned

        f, scanned = parse()
        # lineage: the CSV byte range IS this block's producer — a corrupt
        # spill re-parses the chunk from the source file
        return (as_handle(f, recompute=lambda: parse()[0]), f.nrows,
                [s[0] for s in scanned], [s[1] for s in scanned])

    scans = dispatch_blocks(scan_chunk, ranges, attribute=False)

    # ---- merge the votes: conjunctive flags ≡ whole-column S(·) ------------
    domains: list[Domain] = []
    for k in range(len(sel)):
        bool_ok = all(s[2][k][0] for s in scans)
        int_ok = all(s[2][k][1] for s in scans)
        float_ok = all(s[2][k][2] for s in scans)
        any_val = any(s[2][k][3] for s in scans)
        if not any_val:
            domains.append(Domain.UNSPECIFIED)
        elif bool_ok:
            domains.append(Domain.BOOL)
        elif int_ok:
            domains.append(Domain.INT)
        elif float_ok:
            domains.append(Domain.FLOAT)
        else:
            domains.append(Domain.STR)

    # ---- pass 2: finalize each chunk to the merged domains -----------------
    # Pure vector casts on the already-parsed blocks; only a demotion to Σ*
    # (this chunk parsed numbers, another chunk proved the column textual)
    # re-reads the chunk's bytes.
    offsets = [0]
    for s in scans:
        offsets.append(offsets[-1] + s[1])

    def finalize_chunk(args):
        (handle, m, _flags, local_doms), rng, start = args
        needs_text = [j for j, (ld, gd) in enumerate(zip(local_doms, domains))
                      if gd is Domain.STR and ld not in (Domain.STR,
                                                         Domain.UNSPECIFIED)]
        text_cols = None
        if needs_text:
            cols = _chunk_columns(_chunk_rows(read_range(rng), sep, width),
                                  width)
            text_cols = {j: cols[sel[j]] for j in needs_text}
        if (start == 0 and not needs_text
                and all(ld is gd and gd in (Domain.BOOL, Domain.FLOAT,
                                            Domain.STR)
                        for ld, gd in zip(local_doms, domains))):
            # first chunk, every column already in final storage form (INT
            # stays int64 in the intermediate — range-checked at finalize)
            return handle
        def build(f):
            out = []
            for j, (ld, gd) in enumerate(zip(local_doms, domains)):
                c = f.columns[j]
                if ld is gd and gd in (Domain.BOOL, Domain.FLOAT, Domain.STR):
                    # already in final storage form: reuse the column object
                    # — no host/device round trip in the ingest hot path
                    out.append(c)
                    continue
                data = np.asarray(c.data)
                valid = None if c.mask is None else np.asarray(c.mask)
                out.append(_finalize_column(
                    data, valid, c.dictionary, ld, gd,
                    text_cols.get(j) if text_cols else None, na_empty))
            return Frame(out, RangeLabels(m, start), labels_from_values(names))

        with pinned(handle) as f:
            return as_handle(build(f),
                             recompute=lambda: build(resolve(handle)))

    handles = dispatch_blocks(
        finalize_chunk,
        [(scans[i], rng, offsets[i]) for i, rng in enumerate(ranges)],
        attribute=False)
    pf = PartitionedFrame([[h] for h in handles])
    return DataFrame(pf, session=session)


def concat(dfs: Sequence[DataFrame]) -> DataFrame:
    out = dfs[0]
    for d in dfs[1:]:
        out = out.append(d)
    return out


def get_dummies(df: DataFrame, columns: Sequence[str]) -> DataFrame:
    """One-hot encoding (paper §2 A1) via the onehot kernel."""
    cols = tuple(columns)

    def apply(cdict, frame):
        out_cols, out_names = [], []
        for n, c in cdict.items():
            if n in cols and c.domain.is_coded:
                table = c.dictionary or ()
                hot = kops.onehot_encode(c.data, len(table))
                for g, val in enumerate(table):
                    out_names.append(f"{n}_{val}")
                    out_cols.append(Column(hot[:, g].astype(np.int32), Domain.INT,
                                           c.mask, None))
            else:
                out_names.append(n)
                out_cols.append(c)
        return Frame(out_cols, frame.row_labels, labels_from_values(out_names))

    udf = alg.Udf.wrap(apply, name=f"get_dummies_{cols}", deps=frozenset(cols),
                       elementwise=True)
    return df.map_udf(udf)
