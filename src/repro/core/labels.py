"""Row/column label vectors R_m and C_n (paper §3.2).

Labels are metadata over the same domains as data (unlike relational ``att``),
enabling TOLABELS / FROMLABELS to move values between data and metadata.
Two physical forms:

* ``RangeLabels`` — the default positional labels 0..m-1.  O(1) metadata; this
  is what keeps "billions of columns" after a TRANSPOSE cheap (the transposed
  frame's column labels are the old positional row labels).
* ``IntLabels`` — arbitrary integer labels as a host numpy vector.  This is
  what a filtered/gathered ``RangeLabels`` becomes: ``take``/``concat`` are
  vectorized numpy ops, never a per-row Python loop (the row-local fused
  pipelines filter blocks on every selection — label bookkeeping must not
  dominate the actual filter).
* ``CodedLabels`` — arbitrary labels dictionary-encoded: int32 codes (host
  numpy; labels are metadata and never need the device) + host code table.

Labels may repeat and may be null (paper §3.5: "labels can have duplicate
values or be null; so labels are not like primary keys").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Sequence

import numpy as np

from .dtypes import Domain

__all__ = ["Labels", "RangeLabels", "IntLabels", "CodedLabels", "labels_from_values"]


class Labels:
    """Abstract label vector."""

    def __len__(self) -> int:
        raise NotImplementedError

    def to_list(self) -> list:
        raise NotImplementedError

    def take(self, idx: np.ndarray) -> "Labels":
        raise NotImplementedError

    def concat(self, other: "Labels") -> "Labels":
        a, b = self.to_list(), other.to_list()
        return labels_from_values(a + b)

    def position_of(self, label: Any) -> int:
        """First position with the given label (named-notation lookup)."""
        lst = self.to_list()
        try:
            return lst.index(label)
        except ValueError as e:
            raise KeyError(label) from e

    def positions_of(self, labels: Iterable[Any]) -> list[int]:
        lst = self.to_list()
        index: dict = {}
        for i, v in enumerate(lst):
            index.setdefault(v, i)
        out = []
        for lab in labels:
            if lab not in index:
                raise KeyError(lab)
            out.append(index[lab])
        return out

    @property
    def domain(self) -> Domain:
        return Domain.STR


@dataclasses.dataclass(frozen=True)
class RangeLabels(Labels):
    """Positional labels ``start .. start+length-1`` — O(1) metadata."""

    length: int
    start: int = 0

    def __len__(self) -> int:
        return self.length

    def to_list(self) -> list:
        return list(range(self.start, self.start + self.length))

    def take(self, idx: np.ndarray) -> Labels:
        idx = np.asarray(idx)
        # A contiguous take of a range stays a range (keeps metadata O(1)).
        if idx.size and np.array_equal(idx, np.arange(idx[0], idx[0] + idx.size)):
            return RangeLabels(int(idx.size), self.start + int(idx[0]))
        # non-contiguous (filter/gather): stay vectorized — no per-row Python
        return IntLabels(self.start + idx.astype(np.int64))

    def concat(self, other: Labels) -> Labels:
        if (
            isinstance(other, RangeLabels)
            and other.start == self.start + self.length
        ):
            return RangeLabels(self.length + other.length, self.start)
        if isinstance(other, (RangeLabels, IntLabels)):
            mine = np.arange(self.start, self.start + self.length, dtype=np.int64)
            return IntLabels(mine).concat(other)
        return super().concat(other)

    def position_of(self, label: Any) -> int:
        if isinstance(label, (int, np.integer)):
            pos = int(label) - self.start
            if 0 <= pos < self.length:
                return pos
        raise KeyError(label)

    def positions_of(self, labels: Iterable[Any]) -> list[int]:
        return [self.position_of(l) for l in labels]

    @property
    def domain(self) -> Domain:
        return Domain.INT


class IntLabels(Labels):
    """Arbitrary integer labels backed by a host numpy vector — the vectorized
    form a ``RangeLabels`` collapses to after a filter or gather.  All label
    algebra (take / concat) is O(1) Python + one numpy op."""

    __slots__ = ("values",)

    def __init__(self, values: np.ndarray):
        self.values = np.asarray(values, dtype=np.int64)

    def __len__(self) -> int:
        return int(self.values.shape[0])

    def to_list(self) -> list:
        return self.values.tolist()

    def take(self, idx: np.ndarray) -> Labels:
        return IntLabels(self.values[np.asarray(idx)])

    def concat(self, other: Labels) -> Labels:
        if isinstance(other, IntLabels):
            return IntLabels(np.concatenate([self.values, other.values]))
        if isinstance(other, RangeLabels):
            return IntLabels(np.concatenate([
                self.values,
                np.arange(other.start, other.start + other.length, dtype=np.int64)]))
        return super().concat(other)

    def position_of(self, label: Any) -> int:
        if isinstance(label, (int, np.integer)):
            hits = np.nonzero(self.values == int(label))[0]
            if hits.size:
                return int(hits[0])
        raise KeyError(label)

    # positions_of: inherit the base class's one-pass dict index — a per-label
    # nonzero scan would be O(k·n) on post-transpose many-column frames

    @property
    def domain(self) -> Domain:
        return Domain.INT


@dataclasses.dataclass(frozen=True)
class CodedLabels(Labels):
    """Dictionary-encoded labels: codes (host int32) + code table.

    ``table`` holds the distinct label *values* (any hashable host value);
    code -1 encodes a null label.
    """

    codes: np.ndarray  # (m,) int32, host
    table: tuple       # distinct values, first-occurrence order
    label_domain: Domain = Domain.STR  # recorded type (paper §3.5 label types)

    def __post_init__(self):
        object.__setattr__(self, "codes", np.asarray(self.codes, dtype=np.int32))

    def __len__(self) -> int:
        return int(self.codes.shape[0])

    def to_list(self) -> list:
        return [self.table[c] if c >= 0 else None for c in self.codes]

    def take(self, idx: np.ndarray) -> Labels:
        return CodedLabels(self.codes[np.asarray(idx)], self.table, self.label_domain)

    def concat(self, other: Labels) -> Labels:
        if isinstance(other, CodedLabels) and other.table == self.table:
            return CodedLabels(
                np.concatenate([self.codes, other.codes]), self.table, self.label_domain
            )
        return super().concat(other)

    def position_of(self, label: Any) -> int:
        try:
            code = self.table.index(label)
        except ValueError as e:
            raise KeyError(label) from e
        hits = np.nonzero(self.codes == code)[0]
        if hits.size == 0:
            raise KeyError(label)
        return int(hits[0])

    @property
    def domain(self) -> Domain:
        return self.label_domain


def labels_from_values(values: Sequence[Any], domain: Domain | None = None) -> Labels:
    """Build the cheapest label representation for ``values``."""
    vals = list(values)
    if all(isinstance(v, (int, np.integer)) for v in vals) and vals == list(
        range(vals[0] if vals else 0, (vals[0] if vals else 0) + len(vals))
    ):
        return RangeLabels(len(vals), int(vals[0]) if vals else 0)
    table: list = []
    index: dict = {}
    codes = np.zeros(len(vals), dtype=np.int32)
    for i, v in enumerate(vals):
        if v is None:
            codes[i] = -1
            continue
        if v not in index:
            index[v] = len(table)
            table.append(v)
        codes[i] = index[v]
    if domain is None:
        if all(isinstance(v, (int, np.integer)) for v in table):
            domain = Domain.INT
        elif all(isinstance(v, (int, float, np.integer, np.floating)) for v in table):
            domain = Domain.FLOAT
        else:
            domain = Domain.STR
    return CodedLabels(codes, tuple(table), domain)
