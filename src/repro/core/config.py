"""Session-scoped configuration (the multi-tenancy seam, ROADMAP serving tier).

Before this module, ``Session.__init__`` *mutated process-wide state* to apply
its store / retry / fault / shuffle knobs (``store.configure``,
``schedule.configure_retries``, ``faults.configure``, ``shuffle.configure``) —
so a second concurrent ``Session`` silently clobbered the first session's
configuration: a correctness bug once two tenants share one process.

The fix is a :class:`SessionConfig` carried in a **contextvar**: each session
installs its config around every statement (and the scheduling layer
propagates it into pool-worker and background-executor threads, which have
their own contextvar storage), and the knob *accessors* in ``schedule`` /
``faults`` / ``store`` / ``shuffle`` consult the active config FIRST, falling
back to the process-wide programmatic overrides and then the ``REPRO_*``
environment knobs.  Env knobs therefore stay process defaults; per-session
values never leak across sessions.

Resolution order for every knob::

    active SessionConfig  →  process-wide configure() override  →  REPRO_* env

Cancellation rides the same channel: a :class:`CancelToken` installed via
:func:`propagate` is checked by ``schedule.dispatch_blocks`` between block
tasks, so an async statement can be cancelled at the next dispatch boundary
(raising the typed ``faults.StatementCancelled``).

This module sits below every other ``core`` module (stdlib-only imports), so
``faults`` / ``schedule`` / ``store`` / ``shuffle`` can all consult it without
import cycles.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
from typing import Any, Iterator

__all__ = [
    "SessionConfig", "CancelToken", "current", "current_cancel",
    "current_trace_ctx", "scope", "propagate",
]


@dataclasses.dataclass
class SessionConfig:
    """One session's knob overrides.  ``None`` means "inherit the process
    default" (the programmatic ``configure()`` override if set, else the
    ``REPRO_*`` env knob) — so a knob-less session behaves exactly like the
    single-tenant engine.

    ``store`` is the session's block store when it has a *private* one
    (``Session(mem_budget_bytes=...)``) or the **shared** service store under
    a ``QueryService`` (one byte budget charged across all tenants); ``None``
    routes to the process-wide singleton.

    ``stats`` is the per-session ``ExecStats`` attribution target for
    service-managed sessions sharing one executor: execution windows write
    each counter delta to BOTH the executor's global stats and this object
    (``executor.StatsTee``), so per-session attribution always sums to the
    global counters.
    """

    session_id: str = "s0"
    store: Any | None = None
    task_retries: int | None = None
    task_timeout_ms: int | None = None
    retry_backoff_ms: int | None = None
    fault_plan: str | None = None
    fault_seed: int | None = None
    shuffle_buckets: int | None = None
    shuffle_skew_factor: int | None = None
    stats: Any | None = None
    max_inflight: int | None = None
    # session tracer (trace.Tracer) — None inherits the process default
    # (REPRO_TRACE); False forces tracing off for this session
    trace: Any | None = None
    # compiled FaultPlan cache (faults._plan fills it; never hashed/compared)
    _plan_cache: Any | None = dataclasses.field(
        default=None, repr=False, compare=False)


class CancelToken:
    """Cooperative cancellation grip for one async statement.  Setting it
    makes the next dispatch boundary raise ``faults.StatementCancelled``;
    work already inside a block kernel finishes that block first (kernels
    are pure, so a cancelled statement never leaves partial state)."""

    __slots__ = ("_event",)

    def __init__(self):
        self._event = threading.Event()

    def cancel(self) -> None:
        self._event.set()

    @property
    def cancelled(self) -> bool:
        return self._event.is_set()

    def __repr__(self) -> str:
        return f"CancelToken({'cancelled' if self.cancelled else 'live'})"


_ACTIVE: contextvars.ContextVar[SessionConfig | None] = contextvars.ContextVar(
    "repro-session-config", default=None)
_CANCEL: contextvars.ContextVar[CancelToken | None] = contextvars.ContextVar(
    "repro-cancel-token", default=None)
# current trace span (trace.Span) — the parent for spans opened below it;
# propagate() carries it onto pool-worker threads so chunk spans parent to
# the dispatch span that submitted them
_TRACE_CTX: contextvars.ContextVar[Any | None] = contextvars.ContextVar(
    "repro-trace-ctx", default=None)
_TRACE_UNSET = object()


def current() -> SessionConfig | None:
    """The active session's config on this thread (None = single-tenant /
    process defaults)."""
    return _ACTIVE.get()


def current_cancel() -> CancelToken | None:
    """The active statement's cancellation token on this thread, if any."""
    return _CANCEL.get()


def current_trace_ctx() -> Any | None:
    """The current trace span on this thread (parent for new spans), if
    tracing is active; None otherwise."""
    return _TRACE_CTX.get()


@contextlib.contextmanager
def scope(cfg: SessionConfig | None) -> Iterator[SessionConfig | None]:
    """Install ``cfg`` as the active session config for the duration of a
    statement (``Session`` wraps every public entry point in one of these)."""
    token = _ACTIVE.set(cfg)
    try:
        yield cfg
    finally:
        _ACTIVE.reset(token)


@contextlib.contextmanager
def propagate(cfg: SessionConfig | None,
              cancel: CancelToken | None = None,
              trace: Any | None = None) -> Iterator[None]:
    """Re-install a config (+ cancel token, + parent trace span) captured on
    another thread — the bridge ``schedule.dispatch_blocks`` and
    ``Executor.submit`` use to carry session scope into pool-worker /
    background threads (contextvars are per-thread, so they do not cross
    ``ThreadPoolExecutor.submit``).  ``trace`` is the dispatching side's
    current span: spans the worker opens parent to it, which is how one
    statement's span tree crosses thread boundaries."""
    if cfg is None and cancel is None and trace is None:
        yield
        return
    t_cfg = _ACTIVE.set(cfg)
    t_can = _CANCEL.set(cancel)
    t_trc = _TRACE_CTX.set(trace)
    try:
        yield
    finally:
        _TRACE_CTX.reset(t_trc)
        _CANCEL.reset(t_can)
        _ACTIVE.reset(t_cfg)
