"""Dataframe domains and the schema-induction function S(·).

Paper §3.2: ``Dom = {Σ*, int, float, bool, category}``; every column has a
domain that may be left unspecified and *induced post hoc* by a schema
induction function ``S : Σ*^m → Dom`` that examines the column's values.

TPU adaptation (DESIGN.md §3): strings never reach the device.  Σ*-domain
values are dictionary-encoded to int32 codes on the host at ingest time; the
code table lives in frame metadata.  ``S`` therefore runs on host values
(Python objects / numpy arrays) and returns both the induced domain and the
parsed device representation.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Domain",
    "STR",
    "INT",
    "FLOAT",
    "BOOL",
    "CATEGORY",
    "UNSPECIFIED",
    "NULL",
    "storage_dtype",
    "induce_schema",
    "parse_column",
    "common_storage",
]

# Distinguished null value (paper: "Each domain contains a distinguished
# null value, sometimes written as NA").  We carry explicit validity masks on
# device; ``NULL`` is the host-side sentinel.
NULL = None


class Domain(enum.Enum):
    """The set *Dom* of column domains from the paper's data model."""

    STR = "str"            # Σ*  (dictionary-encoded int32 codes on device)
    INT = "int"            # int32 on device
    FLOAT = "float"        # float32 on device
    BOOL = "bool"          # bool on device
    CATEGORY = "category"  # dictionary-encoded int32 codes on device
    UNSPECIFIED = "unspecified"  # domain left unspecified; induced on demand

    # ---- storage properties -------------------------------------------------
    @property
    def is_coded(self) -> bool:
        """True if device storage is dictionary codes with a host code table."""
        return self in (Domain.STR, Domain.CATEGORY)

    @property
    def is_numeric(self) -> bool:
        return self in (Domain.INT, Domain.FLOAT, Domain.BOOL)

    def __repr__(self) -> str:  # compact reprs in schema printouts
        return self.value


STR = Domain.STR
INT = Domain.INT
FLOAT = Domain.FLOAT
BOOL = Domain.BOOL
CATEGORY = Domain.CATEGORY
UNSPECIFIED = Domain.UNSPECIFIED


def storage_dtype(domain: Domain) -> np.dtype:
    """Device dtype used to store values of ``domain``."""
    return {
        Domain.STR: np.dtype(np.int32),
        Domain.CATEGORY: np.dtype(np.int32),
        Domain.INT: np.dtype(np.int32),
        Domain.FLOAT: np.dtype(np.float32),
        Domain.BOOL: np.dtype(np.bool_),
        Domain.UNSPECIFIED: np.dtype(np.float32),
    }[domain]


def common_storage(domains: Sequence[Domain]) -> Domain:
    """Common domain for matrix coercion (paper §3.3 TRANSPOSE semantics).

    Heterogeneous transposes coerce to the most general domain present.  Any
    coded (string-like) column forces STR; any float forces FLOAT over ints;
    bools widen to int.  Mirrors "In Python, everything is coerced to Object"
    — except our Object is the widest *numeric* representation plus code
    tables, so a second TRANSPOSE can recover the original schema
    (paper: "the schema induction function can always recover the original
    D_n after two transposes").
    """
    doms = set(d for d in domains if d is not Domain.UNSPECIFIED)
    if not doms:
        return Domain.UNSPECIFIED
    if any(d.is_coded for d in doms):
        return Domain.STR
    if Domain.FLOAT in doms:
        return Domain.FLOAT
    if Domain.INT in doms:
        return Domain.INT
    return Domain.BOOL


# -----------------------------------------------------------------------------
# Schema induction S(·)
# -----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParsedColumn:
    """Result of applying the parsing function p_i of the induced domain."""

    domain: Domain
    data: jnp.ndarray        # (m,) device array in storage dtype
    mask: jnp.ndarray | None  # (m,) bool validity (True = valid); None = all valid
    dictionary: tuple | None  # host code table for coded domains


def _try_parse(values: list, caster, np_dtype) -> tuple[np.ndarray, np.ndarray] | None:
    out = np.zeros(len(values), dtype=np_dtype)
    mask = np.ones(len(values), dtype=np.bool_)
    for i, v in enumerate(values):
        if v is None or (isinstance(v, float) and np.isnan(v)):
            mask[i] = False
            continue
        try:
            out[i] = caster(v)
        except (ValueError, TypeError):
            return None
    return out, mask


def _parse_bool(v: Any) -> bool:
    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    if isinstance(v, str):
        low = v.strip().lower()
        if low in ("true", "yes", "t", "1"):
            return True
        if low in ("false", "no", "f", "0"):
            return False
    raise ValueError(v)


def _parse_int(v: Any) -> int:
    if isinstance(v, (bool, np.bool_)):
        raise ValueError(v)
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        if float(v).is_integer():
            return int(v)
        raise ValueError(v)
    if isinstance(v, str):
        return int(v.strip())
    raise ValueError(v)


def _parse_float(v: Any) -> float:
    if isinstance(v, (bool, np.bool_)):
        raise ValueError(v)
    if isinstance(v, (int, float, np.integer, np.floating)):
        return float(v)
    if isinstance(v, str):
        return float(v.strip())
    raise ValueError(v)


def induce_schema(values: Sequence[Any]) -> Domain:
    """S(·): map an array of host values to the most specific domain in Dom.

    Paper §3.2: "S must examine every value in that column to determine the
    most specific domain from Dom that can be used to classify the data".
    Specificity order: bool ≺ int ≺ float ≺ category/str.
    """
    vals = list(values)
    non_null = [v for v in vals if v is not None and not (isinstance(v, float) and np.isnan(v))]
    if not non_null:
        return Domain.UNSPECIFIED
    if _try_parse(vals, _parse_bool, np.bool_) is not None:
        return Domain.BOOL
    if _try_parse(vals, _parse_int, np.int64) is not None:
        return Domain.INT
    if _try_parse(vals, _parse_float, np.float64) is not None:
        return Domain.FLOAT
    return Domain.STR


def encode_dictionary(values: Sequence[Any]) -> tuple[np.ndarray, np.ndarray, tuple]:
    """Dictionary-encode host values → (codes int32, mask, table).

    Codes follow first-occurrence order so the encoding is order-stable
    (the dataframe model is ordered; paper §3.2).
    """
    table: list = []
    index: dict = {}
    codes = np.zeros(len(values), dtype=np.int32)
    mask = np.ones(len(values), dtype=np.bool_)
    for i, v in enumerate(values):
        if v is None or (isinstance(v, float) and np.isnan(v)):
            mask[i] = False
            codes[i] = -1
            continue
        key = str(v)
        if key not in index:
            index[key] = len(table)
            table.append(key)
        codes[i] = index[key]
    return codes, mask, tuple(table)


def parse_column(values: Sequence[Any], domain: Domain | None = None) -> ParsedColumn:
    """Apply S(·) (if needed) and the domain's parsing function p_i."""
    vals = list(values)
    dom = domain if domain is not None and domain is not Domain.UNSPECIFIED else induce_schema(vals)
    if dom is Domain.UNSPECIFIED:
        # all-null column: store zeros with an all-False mask
        data = np.zeros(len(vals), dtype=np.float32)
        mask = np.zeros(len(vals), dtype=np.bool_)
        return ParsedColumn(dom, jnp.asarray(data), jnp.asarray(mask), None)
    if dom.is_coded:
        codes, mask, table = encode_dictionary(vals)
        return ParsedColumn(dom, jnp.asarray(codes), jnp.asarray(mask) if not mask.all() else None, table)
    caster = {Domain.BOOL: _parse_bool, Domain.INT: _parse_int, Domain.FLOAT: _parse_float}[dom]
    parsed = _try_parse(vals, caster, storage_dtype(dom))
    if parsed is None:
        # values do not actually parse in the requested domain → fall back to
        # Σ*.  NOTE: integers beyond int32 deliberately raise OverflowError
        # here rather than parse — general compute paths push columns through
        # jnp.asarray (no x64), which would truncate int64 silently.  Paths
        # that handle wide ints exactly build int64 HOST columns directly
        # (``physical._host_column`` for groupby key decode; tests/benches
        # construct ``Column(np.int64…)``).
        return parse_column(vals, Domain.STR)
    data, mask = parsed
    return ParsedColumn(
        dom,
        jnp.asarray(data.astype(storage_dtype(dom))),
        jnp.asarray(mask) if not mask.all() else None,
        None,
    )
