"""Concurrent multi-session query service (ROADMAP serving tier; paper §6).

One :class:`QueryService` hosts many tenant :class:`~.session.Session`\\ s
over **shared** engine state:

* ONE executor — so the materialization cache, the in-flight dedupe table,
  and the statement history (§6.2 multi-query sharing) work *across*
  sessions: two tenants scanning the same shared table share one cache
  entry, and a sub-plan one tenant is computing is joined by another, never
  recomputed;
* ONE frame store — tenant tables are namespaced by a per-session frame-id
  prefix, while :meth:`QueryService.register_frame` publishes shared source
  tables every tenant addresses by the same id (the cross-session MQO seam);
* ONE optional byte budget — a service-level ``BlockStore`` all tenants
  charge against (``mem_budget_bytes``), with per-session attribution of the
  spill/fault work in each session's ``ExecStats`` (``executor.StatsTee``);
* an **admission controller** — async statement submissions are *admitted*
  into the shared background pool under a global slot bound and a
  per-session in-flight cap (``REPRO_MAX_INFLIGHT`` /
  ``Session(max_inflight=...)``), with FIFO-with-aging selection: a session
  with fewer running statements goes first (fairness), and a ticket's
  priority improves as it ages so a busy tenant's backlog cannot starve.

Isolation is config-level, not data-level: each tenant session carries its
own ``config.SessionConfig`` (retry / fault / shuffle knobs, per-session
stats), installed around its statements, so tenants with different knobs
coexist in one process without clobbering each other — the bug the
session-scoped config layer exists to fix.
"""
from __future__ import annotations

import concurrent.futures as _fut
import itertools
import threading
import time
from typing import Any

from . import algebra as alg
from . import config as _config
from . import schedule as _schedule
from . import store as block_store
from . import trace as _trace
from .config import CancelToken, SessionConfig
from .executor import ExecStats, Executor
from .faults import ExecutorClosedError, StatementCancelled
from .frame import Frame
from .partition import PartitionedFrame, default_grid
from .session import EvalMode, Session, StatementHandle

__all__ = ["QueryService", "AdmissionController"]

# a queued ticket's effective priority improves by one "running statement"
# per this many seconds of waiting — the aging half of FIFO-with-aging
_AGING_S = 0.25


class _Ticket:
    __slots__ = ("seq", "sid", "node", "cfg", "token", "promise", "cap",
                 "enqueued", "admitted", "stmt")

    def __init__(self, seq: int, sid: str, node: alg.Node, cfg: SessionConfig,
                 token: "_TicketToken", promise: _fut.Future, cap: int,
                 stmt: int | None = None):
        self.seq = seq
        self.sid = sid
        self.node = node
        self.cfg = cfg
        self.token = token
        self.promise = promise
        self.cap = cap
        self.enqueued = time.monotonic()
        self.admitted = self.enqueued
        # trace statement id, allocated at submission so the queue-wait span,
        # the plan-prep span, and the statement span share one tree
        self.stmt = stmt


class _TicketToken(CancelToken):
    """Cancel token that also pulls its still-queued ticket out of the
    admission queue — a cancelled statement that was never admitted fails
    promptly with ``StatementCancelled`` instead of waiting for a slot."""

    __slots__ = ("_ctl", "_ticket")

    def __init__(self, ctl: "AdmissionController"):
        super().__init__()
        self._ctl = ctl
        self._ticket = None

    def cancel(self) -> None:
        super().cancel()
        t = self._ticket
        if t is not None:
            self._ctl._cancelled(t)


class AdmissionController:
    """Bounded, fair admission of async statements into a shared executor.

    * global bound: at most ``slots`` statements admitted (running) at once —
      matching the executor's background pool width, so admitted work never
      queues invisibly inside the pool;
    * per-session bound: at most ``ticket.cap`` (``schedule.max_inflight()``,
      resolved per session) admitted per tenant;
    * selection: among eligible tickets, minimize
      ``(running[session] - age / 0.25s, seq)`` — FIFO within a session,
      fewest-running-first across sessions, with aging so no eligible ticket
      waits unboundedly behind fresher ones.
    """

    def __init__(self, executor: Executor, slots: int):
        self._executor = executor
        self._slots = max(1, slots)
        self._cond = threading.Condition()
        self._queue: list[_Ticket] = []
        self._running: dict[str, int] = {}
        self._running_total = 0
        self._seq = itertools.count()
        self._closed = False
        self._thread = threading.Thread(target=self._dispatch_loop,
                                        name="repro-admit", daemon=True)
        self._thread.start()

    # -- submission ----------------------------------------------------
    def submit(self, session: Session, node: alg.Node) -> StatementHandle:
        """Enqueue a statement for admission; returns its handle at once.
        Runs inside the session's config scope (``Session`` installs it), so
        the per-session cap resolves against that session's knobs."""
        cfg = _config.current() or session.config
        cap = _schedule.max_inflight()
        token = _TicketToken(self)
        promise: _fut.Future = _fut.Future()
        tr = _trace.current(cfg)
        stmt = tr.next_stmt() if tr is not None else None
        t = _Ticket(next(self._seq), session.config.session_id, node, cfg,
                    token, promise, cap, stmt)
        token._ticket = t
        with self._cond:
            if self._closed:
                raise ExecutorClosedError("query service is closed")
            self._queue.append(t)
            self._cond.notify_all()
        return StatementHandle(node, token, promise, stmt=stmt, tracer=tr)

    # -- dispatcher ----------------------------------------------------
    def _pick_locked(self) -> _Ticket | None:
        if self._running_total >= self._slots:
            return None
        eligible = [t for t in self._queue
                    if self._running.get(t.sid, 0) < t.cap]
        if not eligible:
            return None
        now = time.monotonic()
        return min(eligible, key=lambda t: (
            self._running.get(t.sid, 0) - (now - t.enqueued) / _AGING_S,
            t.seq))

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                t = None
                while t is None:
                    if self._closed:
                        return
                    # fail cancelled tickets while they are still queued
                    for c in [q for q in self._queue if q.token.cancelled]:
                        self._queue.remove(c)
                        self._fail(c, StatementCancelled(
                            "statement cancelled while queued for admission"))
                    t = self._pick_locked()
                    if t is None:
                        self._cond.wait(timeout=0.1)
                self._queue.remove(t)
                self._running[t.sid] = self._running.get(t.sid, 0) + 1
                self._running_total += 1
            self._launch(t)

    def _launch(self, t: _Ticket) -> None:
        t.admitted = time.monotonic()
        try:
            with _config.scope(t.cfg):
                self._note_phase(t, "queue_wait",
                                 int((t.admitted - t.enqueued) * 1e9))
                fut = self._executor.submit(t.node, cancel=t.token,
                                            stmt=t.stmt)
        except BaseException as e:
            self._release(t)
            self._fail(t, e)
            return

        def _done(f: _fut.Future, t: _Ticket = t) -> None:
            self._release(t)
            try:
                r = f.result()
            except _fut.CancelledError:
                self._fail(t, StatementCancelled(
                    "statement cancelled before it started")
                    if t.token.cancelled else ExecutorClosedError(
                        "executor shut down before this statement started"))
            except BaseException as e:
                self._fail(t, e)
            else:
                try:
                    t.promise.set_result(r)
                except _fut.InvalidStateError:
                    pass

        fut.add_done_callback(_done)

    @staticmethod
    def _fail(t: _Ticket, err: BaseException) -> None:
        try:
            t.promise.set_exception(err)
        except _fut.InvalidStateError:
            pass    # shutdown / cancel raced us — the promise already failed

    def _note_phase(self, t: _Ticket, name: str, dur_ns: int) -> None:
        """Attribute an admission phase (queue wait / slot hold) to the
        tenant: bump the timing counter through the executor's stats tee
        (global + this session's ``ExecStats``, under the ticket's config
        scope) and, when the session is traced, record a span of the elapsed
        duration — backdated, since the phase just ended."""
        st = self._executor._stats()
        setattr(st, f"{name}_ns", getattr(st, f"{name}_ns") + dur_ns)
        tr = _trace.current()
        if tr is not None:
            sp = tr.begin(name, "service", parent=None, stmt=t.stmt)
            sp.t0 -= dur_ns
            sp.args = {"session": t.sid}
            tr.end(sp)

    def _release(self, t: _Ticket) -> None:
        with _config.scope(t.cfg):
            self._note_phase(t, "slot_hold",
                             int((time.monotonic() - t.admitted) * 1e9))
        with self._cond:
            self._running[t.sid] = self._running.get(t.sid, 1) - 1
            self._running_total -= 1
            self._cond.notify_all()

    # -- cancellation / teardown ---------------------------------------
    def _cancelled(self, t: _Ticket) -> None:
        with self._cond:
            if t in self._queue:
                self._queue.remove(t)
                self._fail(t, StatementCancelled(
                    "statement cancelled while queued for admission"))
            self._cond.notify_all()

    def drop_session(self, sid: str) -> None:
        """Fail every queued ticket of a closing session with the typed
        closed error (admitted statements run to completion — their promises
        resolve normally)."""
        with self._cond:
            for t in [q for q in self._queue if q.sid == sid]:
                self._queue.remove(t)
                self._fail(t, ExecutorClosedError(
                    f"session {sid} closed with statements queued"))
            self._cond.notify_all()

    def close(self) -> None:
        with self._cond:
            if self._closed:
                return
            self._closed = True
            queued, self._queue = self._queue, []
            self._cond.notify_all()
        for t in queued:
            self._fail(t, ExecutorClosedError(
                "query service shut down with statements queued"))
        self._thread.join(timeout=2.0)

    def queued(self) -> int:
        with self._cond:
            return len(self._queue)


class QueryService:
    """Multi-tenant query service: shared executor / frame store / byte
    budget, per-session config isolation, admission-controlled async
    statement execution.  See the module docstring."""

    def __init__(self, *, mem_budget_bytes: int | None = None,
                 spill_dir: str | None = None,
                 cache_budget_bytes: int = 1 << 30, optimize: bool = True,
                 background_workers: int = 2,
                 admission_slots: int | None = None):
        self.frames: dict[str, PartitionedFrame] = {}
        self.executor = Executor(self.frames,
                                 cache_budget_bytes=cache_budget_bytes,
                                 optimize=optimize,
                                 background_workers=background_workers)
        self.store = None
        if mem_budget_bytes is not None or spill_dir is not None:
            # ONE budget charged across every tenant (shared-budget
            # multi-tenancy); per-session spill/fault attribution happens in
            # each session's ExecStats via the executor's stats tee
            self.store = block_store.BlockStore(mem_budget_bytes or 0,
                                                spill_dir)
        self.config = SessionConfig(session_id="svc", store=self.store)
        self.admission = AdmissionController(
            self.executor, slots=admission_slots or background_workers)
        self._sessions: dict[str, Session] = {}
        self._sids = itertools.count()
        self._fids = itertools.count()
        self._lock = threading.Lock()
        self._closed = False

    def _require_open(self) -> None:
        if self._closed:
            raise ExecutorClosedError("query service is closed")

    # ------------------------------------------------------------------
    def session(self, *, mode: str = EvalMode.OPPORTUNISTIC,
                default_row_parts: int | None = None,
                task_retries: int | None = None,
                task_timeout_ms: int | None = None,
                retry_backoff_ms: int | None = None,
                fault_plan: str | None = None,
                fault_seed: int | None = None,
                shuffle_buckets: int | None = None,
                shuffle_skew_factor: int | None = None,
                max_inflight: int | None = None,
                trace: Any = None,
                session_id: str | None = None) -> Session:
        """Open a tenant session.  Knobs are session-scoped — they shadow the
        process defaults inside this session's statements only.  ``trace``
        (True, or a ``trace.Tracer``) gives the tenant its own span ring —
        ``Session.trace_json`` / ``explain_stats`` / handle ``profile`` then
        cover exactly that tenant's statements."""
        self._require_open()
        sid = session_id or f"t{next(self._sids)}"
        s = Session(mode=mode, default_row_parts=default_row_parts,
                    task_retries=task_retries, task_timeout_ms=task_timeout_ms,
                    retry_backoff_ms=retry_backoff_ms,
                    fault_plan=fault_plan, fault_seed=fault_seed,
                    shuffle_buckets=shuffle_buckets,
                    shuffle_skew_factor=shuffle_skew_factor,
                    max_inflight=max_inflight, trace=trace,
                    _service=self, _executor=self.executor,
                    _frames=self.frames, _store=self.store, _session_id=sid)
        with self._lock:
            self._sessions[sid] = s
        return s

    def register_frame(self, frame: Frame | PartitionedFrame,
                       row_parts: int | None = None,
                       col_parts: int = 1) -> alg.Source:
        """Publish a SHARED source table: every tenant addresses it by the
        same frame id, so their plans over it share cache keys — the seam
        cross-session MQO (shared cache entries, in-flight joins) runs
        through."""
        self._require_open()
        with _config.scope(self.config):
            if isinstance(frame, Frame):
                if row_parts is None:
                    row_parts, col_parts = default_grid(frame.nrows,
                                                        frame.ncols)
                pf = PartitionedFrame.from_frame(frame, row_parts, col_parts)
            else:
                pf = frame
            fid = f"shared_{next(self._fids)}"
            with self._lock:
                self.frames[fid] = pf
            return alg.Source(fid, nrows=pf.nrows, ncols=pf.ncols)

    # ------------------------------------------------------------------
    def _submit(self, session: Session, node: alg.Node) -> StatementHandle:
        self._require_open()
        return self.admission.submit(session, node)

    def _session_closed(self, session: Session) -> None:
        sid = session.config.session_id
        self.admission.drop_session(sid)
        prefix = f"{sid}_"
        with self._lock:
            self._sessions.pop(sid, None)
            for fid in [f for f in self.frames if f.startswith(prefix)]:
                self.frames.pop(fid, None)

    # ------------------------------------------------------------------
    @property
    def stats(self) -> ExecStats:
        """Global (cross-tenant) counters; each session's share is in
        ``session.stats`` and the per-session shares sum to these."""
        return self.executor.stats

    def session_stats(self) -> dict[str, ExecStats]:
        with self._lock:
            return {sid: s.stats for sid, s in self._sessions.items()}

    def tenant_report(self) -> list[dict]:
        """Which session is burning the pool: per-tenant timing gauges
        (node wall time, plan prep, admission queue wait, slot hold) plus the
        work counters behind them, sorted by pool pressure (slot hold + node
        wall) descending.  The per-tenant numbers come from each session's
        ``ExecStats`` — the same tee the counter attribution uses — so they
        sum to the service-global stats like every other counter."""
        with self._lock:
            items = list(self._sessions.items())
        rows = []
        for sid, s in items:
            st = s.stats
            rows.append({
                "session": sid,
                "node_wall_ns": st.node_wall_ns,
                "plan_prep_ns": st.plan_prep_ns,
                "queue_wait_ns": st.queue_wait_ns,
                "slot_hold_ns": st.slot_hold_ns,
                "evaluated_nodes": st.evaluated_nodes,
                "dispatches": st.dispatches,
                "dispatched_blocks": st.dispatched_blocks,
                "spills": st.spills,
                "faults": st.faults,
                "retries": st.retries,
            })
        rows.sort(key=lambda r: r["slot_hold_ns"] + r["node_wall_ns"],
                  reverse=True)
        return rows

    def close(self) -> None:
        """Shut the service down: queued admissions and in-flight statements
        fail with the typed ``ExecutorClosedError`` (never a hang), tenant
        sessions close, and the shared store drops its spill files.
        Idempotent."""
        if self._closed:
            return
        self._closed = True
        with self._lock:
            sessions = list(self._sessions.values())
        for s in sessions:
            s.close()
        self.admission.close()
        self.executor.shutdown()
        if self.store is not None:
            self.store.shutdown()
        self.frames.clear()

    def __enter__(self) -> "QueryService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
