"""Shuffle/exchange layer: grace-hash JOIN and sample-sort SORT (paper §4/§6).

JOIN and SORT were the last whole-frame serial operators — both opened with
``to_frame().induce()``, concatenating their inputs into one host frame and
concentrating residency exactly where ``REPRO_MEM_BUDGET`` pinches.  This
module decomposes them the way Cylon's local-pattern decomposition does
(Perera et al., PAPERS.md): a reusable **exchange** primitive turns a
partitioned input into per-bucket *key frames* (equality keys for join, rank
keys for sort, plus each row's global position), and the operator itself
becomes a per-bucket local kernel whose outputs merge back by index — the
payload is never concatenated, only *gathered*, in budget-sized chunks,
straight from the original input blocks.

Exchange rounds (all through ``schedule.dispatch_blocks``, so coalescing,
residency-first ordering, retry, and fault injection apply):

1. ``<op>:exchange`` — per input block: normalize keys (``physical._row_keys``
   / ``_sort_rank_keys`` with wide-int flags OR-ed across every block of both
   inputs), assign buckets (splitmix64 of the key bit patterns for join;
   sampled splitters → range buckets for sort), and register a per-block key
   frame; then per bucket: select + concat that bucket's rows from every
   block key frame.  Bucket frames are ordinary ``store.BlockHandle``s with
   producer lineage — they spill under the budget and recompute after a
   corrupt/missing spill like any other block.
2. ``<op>:local`` — per bucket: vectorized local hash join
   (``physical._match_ids``) or local lexsort.  Only *index arrays* leave the
   bucket.
3. ``<op>:gather`` — chunked payload gather over the original input blocks
   (one pinned block at a time, chunk sized to
   ``schedule.budget_max_block_bytes``), re-gridded via the zero-copy
   ``physical._output_pf`` regroup.

Ordering/null semantics are preserved **bit-identically** with the serial
path: every left row lives in exactly one hash bucket and bucket rows keep
ascending global position, so a global stable sort of the per-bucket pairs by
left position reproduces the serial left-major / right-tie-break order;
unmatched-right rows append in right order; sample-sort buckets are ranges of
the primary transformed key (NaN→+inf so nulls sort last either direction),
so local stable lexsorts concatenate into the exact global permutation.

Skew: a bucket larger than ``skew_factor × mean`` splits instead of OOMing —
join buckets split the larger side positionally (replicating the smaller
side; exactness restored by the same global merge), sort buckets refine
recursively on successive key columns (a positional split is taken only once
every key column is tied, where stability makes it exact).  Splits are
counted in ``ExecStats.skew_splits``.

Knobs (see the single table in ``core/schedule.py``):
``REPRO_SHUFFLE=0`` retains the serial whole-frame path as the differential
oracle; ``REPRO_SHUFFLE_BUCKETS`` pins the bucket count (default: pool width
× coalesce factor, with a budget floor so one bucket's key frame stays a
spillable unit); ``REPRO_SHUFFLE_SKEW_FACTOR`` sets the oversize threshold.
"""
from __future__ import annotations

import contextlib
import os
from typing import Any, Callable, Sequence

import numpy as np

from . import algebra as alg
from . import config as _config
from . import trace as _trace
from .dtypes import Domain
from .faults import env_int
from .frame import Column, Frame
from .labels import RangeLabels, labels_from_values
from .partition import PartitionedFrame
from .schedule import (GRID_PREFS, budget_max_block_bytes, coalesce_factor,
                       dispatch_blocks, node_scope, pool_width)
from .store import as_handle, pinned, resolve
from . import physical as P

__all__ = ["enabled", "configure", "bucket_count", "skew_factor",
           "shuffled_join", "shuffled_sort", "take_global"]


# =============================================================================
# configuration
# =============================================================================
_BUCKETS_OVERRIDE: int | None = None
_SKEW_OVERRIDE: int | None = None


def enabled() -> bool:
    """``REPRO_SHUFFLE=0`` falls back to the serial whole-frame JOIN/SORT
    (the pre-shuffle seed behavior) — benchmark baseline and the bit-identity
    oracle the differential suite sweeps against."""
    return os.environ.get("REPRO_SHUFFLE", "") != "0"


def configure(buckets: int | None = None, skew_factor: int | None = None, *,
              clear: bool = False) -> None:
    """Process-wide programmatic override of the shuffle knobs — sticky,
    like ``schedule.configure_retries``.  ``Session(shuffle_buckets=...)``
    no longer calls this: its values are session-scoped
    (``config.SessionConfig``) and shadow this override only inside that
    session's statements."""
    global _BUCKETS_OVERRIDE, _SKEW_OVERRIDE
    if clear:
        _BUCKETS_OVERRIDE = None
        _SKEW_OVERRIDE = None
    if buckets is not None:
        _BUCKETS_OVERRIDE = max(1, int(buckets))
    if skew_factor is not None:
        _SKEW_OVERRIDE = max(1, int(skew_factor))


def bucket_count(total_rows: int, key_bytes: int) -> int:
    """Exchange bucket count: pinned by ``REPRO_SHUFFLE_BUCKETS`` when set,
    else pool width × coalesce factor (every worker gets a couple of local
    kernels), raised to the budget floor so a single bucket's key frame never
    exceeds ``schedule.budget_max_block_bytes`` — buckets must stay spillable
    units under ``REPRO_MEM_BUDGET``."""
    cfg = _config.current()
    if cfg is not None and cfg.shuffle_buckets is not None:
        b = max(1, cfg.shuffle_buckets)
    else:
        b = (_BUCKETS_OVERRIDE if _BUCKETS_OVERRIDE is not None
             else env_int("REPRO_SHUFFLE_BUCKETS", 0, minimum=0))
    if b <= 0:
        b = max(1, pool_width() * coalesce_factor())
    mb = budget_max_block_bytes()
    if mb and key_bytes > 0:
        b = max(b, -(-key_bytes // mb))          # ceil
    return max(1, min(b, max(1, total_rows)))


def skew_factor() -> int:
    """A bucket holding more than ``skew_factor × mean`` rows splits."""
    cfg = _config.current()
    if cfg is not None and cfg.shuffle_skew_factor is not None:
        return max(1, cfg.shuffle_skew_factor)
    if _SKEW_OVERRIDE is not None:
        return _SKEW_OVERRIDE
    return env_int("REPRO_SHUFFLE_SKEW_FACTOR", 4, minimum=1)


# =============================================================================
# shared plumbing: block handles, key frames, global gather
# =============================================================================
def _grid_handles(pf: PartitionedFrame, grid: str | None, pref_key: str):
    """Full-width row-block handles coarsened to the operator's grid
    preference (same policy as the dedup path), plus their global row
    offsets — metadata only, nothing is faulted."""
    blocks = P._dedup_grid_blocks(pf, grid, pref_key)
    offs = [0]
    for h in blocks:
        offs.append(offs[-1] + h.nrows)
    return blocks, np.asarray(offs, dtype=np.int64)


def _key_frame(mat: np.ndarray, pos: np.ndarray,
               bucket: np.ndarray | None = None) -> Frame:
    """Pack a normalized key matrix + global positions (+ optional bucket
    assignment) into a spillable host Frame: K float64 key columns
    ``k0..k{K-1}``, an int64 ``pos`` column, optionally an int64 ``b``."""
    cols = [Column(np.ascontiguousarray(mat[:, j]), Domain.FLOAT)
            for j in range(mat.shape[1])]
    names: list[Any] = [f"k{j}" for j in range(mat.shape[1])]
    cols.append(Column(pos.astype(np.int64), Domain.INT))
    names.append("pos")
    if bucket is not None:
        cols.append(Column(bucket.astype(np.int64), Domain.INT))
        names.append("b")
    return Frame(cols, RangeLabels(int(mat.shape[0])),
                 labels_from_values(names))


def _key_mat(kf: Frame, ncols: int) -> np.ndarray:
    if ncols == 0:
        return np.zeros((kf.nrows, 0), dtype=np.float64)
    return np.stack([np.asarray(kf.col(f"k{j}").data) for j in range(ncols)],
                    axis=1)


def _key_pos(kf: Frame) -> np.ndarray:
    return np.asarray(kf.col("pos").data, dtype=np.int64)


def _hash_buckets(mat: np.ndarray, nbuckets: int) -> np.ndarray:
    """Bucket id per row: splitmix64 of each normalized key column's float64
    bit pattern, mixed across columns.  Bitwise on purpose — the local
    factorization (``physical._keys_to_ids``) compares keys by bit view, so
    bit-equal keys always co-locate (including canonical-NaN null keys) and
    bit-distinct keys never falsely match across buckets."""
    if mat.shape[1] == 0 or nbuckets <= 1:
        return np.zeros(mat.shape[0], dtype=np.int64)
    h = np.zeros(mat.shape[0], dtype=np.uint64)
    for j in range(mat.shape[1]):
        z = np.ascontiguousarray(mat[:, j]).view(np.uint64).copy()
        z += np.uint64(0x9E3779B97F4A7C15)
        z ^= z >> np.uint64(30)
        z *= np.uint64(0xBF58476D1CE4E5B9)
        z ^= z >> np.uint64(27)
        z *= np.uint64(0x94D049BB133111EB)
        z ^= z >> np.uint64(31)
        h ^= z + np.uint64(0x9E3779B97F4A7C15) + (h << np.uint64(6)) \
            + (h >> np.uint64(2))
    return (h % np.uint64(nbuckets)).astype(np.int64)


def _bucket_frame(bid: int, key_handles: Sequence, select: Callable) -> Frame:
    """Concat bucket ``bid``'s rows from every block key frame, in block
    order (rows stay in ascending global position).  ``select(kf) -> int64
    bucket ids`` recomputes the assignment, so nothing but the key frames is
    captured.  The ``b`` column (when present) is dropped from the output."""
    parts: list[Frame] = []
    schema: Frame | None = None
    for kh in key_handles:
        with pinned(kh) as kf:
            if schema is None:
                schema = kf
            sel = np.nonzero(select(kf) == bid)[0]
            if sel.size:
                parts.append(kf.take_rows(sel))
    if not parts:
        with pinned(key_handles[0]) as kf:
            parts = [kf.take_rows(np.empty(0, dtype=np.int64))]
    out = parts[0]
    for p in parts[1:]:
        out = out.concat_rows(p)
    names = [n for n in out.col_labels.to_list() if n != "b"]
    out = out.take_cols(out.col_labels.positions_of(names))
    # lean labels: bucket frames are working state, not user data
    return Frame(out.columns, RangeLabels(out.nrows), out.col_labels)


def _phase(name: str):
    """Trace span for one shuffle phase (bucketize / exchange / local /
    gather) — a null context when tracing is off.  The phase spans sit
    between the node span and the dispatch spans, so a traced profile shows
    which *phase* of a JOIN/SORT the wall-clock went to."""
    tr = _trace.current()
    if tr is None:
        return contextlib.nullcontext()
    return tr.span(name, "phase")


def _exchange(key_handles: Sequence, nb: int, select: Callable) -> list:
    """The exchange proper: bucket ids are computed ONCE per block key frame
    (one split task per block, stable-sorted so each bucket's piece keeps
    ascending in-block positions), then one task per bucket concatenates its
    pieces in block order — bit-identical to re-scanning every block per
    bucket (:func:`_bucket_frame`), which stays on as each bucket handle's
    recompute lineage, at 1/``nb`` the id-computation cost."""
    def split_task(kh):
        with pinned(kh) as kf:
            ids = select(kf)
            names = [n for n in kf.col_labels.to_list() if n != "b"]
            cols = [np.asarray(kf.col(nm).data) for nm in names]
        # bucket ids live in [0, nb): a counting split (one flatnonzero pass
        # per bucket) beats a comparison sort and is equally stable
        rows = [np.flatnonzero(ids == b) for b in range(nb)]
        return names, [[c[r] for c in cols] for r in rows]

    pieces = dispatch_blocks(split_task, list(key_handles))
    names = pieces[0][0]

    def bucket_task(bid):
        arrs = [np.concatenate([p[bid][j] for _, p in pieces])
                for j in range(len(names))]
        frame = Frame([Column(a, Domain.INT if nm == "pos" else Domain.FLOAT)
                       for a, nm in zip(arrs, names)],
                      RangeLabels(int(arrs[0].shape[0]) if arrs else 0),
                      labels_from_values(names))
        return as_handle(
            frame, recompute=lambda: _bucket_frame(bid, key_handles, select))

    return dispatch_blocks(bucket_task, list(range(nb)))


def take_global(handles: Sequence, offsets: np.ndarray, idx: np.ndarray,
                cols: Sequence[Any] | None = None) -> Frame:
    """Distributed gather: the rows at global positions ``idx`` (into the
    concat of ``handles``), in ``idx`` order, touching ONE pinned block at a
    time — the shuffle-native replacement for ``to_frame().take_rows(idx)``.
    Row labels come through the per-block ``take_rows``, so label semantics
    match the whole-frame gather exactly.  ``cols`` prunes the gathered
    columns (the fused-projection path)."""
    idx = np.asarray(idx, dtype=np.int64)
    k = int(idx.shape[0])
    restore: np.ndarray | None = None
    if k == 0 or bool(np.all(idx[1:] >= idx[:-1])):
        sidx = idx                       # already ascending: gather in order
    else:
        # O(n) scatter sort over the global row space: mark each requested
        # position with its output slot, then read the marks back in
        # ascending position order — no comparison sort for the common
        # unique-index case (sort permutations, join left gathers)
        nglobal = int(offsets[-1])
        slot = np.full(nglobal, -1, dtype=np.int64)
        slot[idx] = np.arange(k, dtype=np.int64)
        sidx = np.flatnonzero(slot >= 0)
        if sidx.shape[0] == k:           # unique indices
            slot[sidx] = np.arange(k, dtype=np.int64)   # rank in sidx
            restore = slot[idx]
        else:                            # repeats: general stable sort
            order = np.argsort(idx, kind="stable")
            sidx = idx[order]
            restore = np.empty(k, dtype=np.int64)
            restore[order] = np.arange(k, dtype=np.int64)
    cuts = np.searchsorted(sidx, np.asarray(offsets, dtype=np.int64))
    parts: list[Frame] = []
    for bi, h in enumerate(handles):
        s, e = int(cuts[bi]), int(cuts[bi + 1])
        if e <= s:
            continue
        with pinned(h) as f:
            g = f.induce()
            if cols is not None:
                g = P._project_block(g, cols)
            parts.append(g.take_rows(sidx[s:e] - int(offsets[bi])))
    if not parts:                       # empty gather: keep the schema
        with pinned(handles[0]) as f:
            g = f.induce()
            if cols is not None:
                g = P._project_block(g, cols)
            parts = [g.take_rows(np.empty(0, dtype=np.int64))]
    out = parts[0]
    for p in parts[1:]:
        out = out.concat_rows(p)
    return out if restore is None else out.take_rows(restore)


def _chunk_bounds(total: int, row_bytes: float) -> list[tuple[int, int]]:
    """Split ``total`` output rows into gather chunks no larger than one
    budget block (``schedule.budget_max_block_bytes``) — and, independent of
    any budget, into roughly one chunk per pool slot so the payload gather
    runs in parallel (one serialized gather would cap the whole operator at
    a single worker).  Tiny outputs stay one chunk: fan-out overhead would
    swamp the work."""
    if total <= 0:
        return [(0, 0)]
    step = total
    mb = budget_max_block_bytes()
    if mb and row_bytes > 0:
        step = max(1024, int(mb // max(1.0, row_bytes)))
    fan = max(1, pool_width() * coalesce_factor())
    step = min(step, max(4096, -(-total // fan)))
    return [(lo, min(lo + step, total)) for lo in range(0, total, step)]


def _row_bytes(handles: Sequence) -> float:
    rows = sum(h.nrows for h in handles)
    return (sum(h.nbytes for h in handles) / rows) if rows else 0.0


def _schema_names(handles: Sequence) -> list:
    with pinned(handles[0]) as f:
        return f.col_labels.to_list()


def _gather_chunks(builders: Sequence[Callable[[], Frame]],
                   label: str) -> PartitionedFrame:
    """Materialize gather chunks through the pool, each registered with its
    builder as producer lineage (chunks spill and recompute like any other
    block)."""
    def one(build):
        return as_handle(build(), recompute=build)

    with node_scope(label), _phase(label):
        out = dispatch_blocks(one, list(builders))
    return PartitionedFrame([[h] for h in out])


# =============================================================================
# JOIN: grace-hash exchange + per-bucket vectorized local join
# =============================================================================
def _join_key_handles(blocks, offsets, subset, joint, B):
    """Round 1b: per-block normalized key frames (+ bucket assignment),
    registered with producer lineage against the source block."""
    def task(args):
        h, off, joint_, B_ = args

        def build(f: Frame) -> Frame:
            f = f.induce()
            mat = P._row_keys(f, subset, joint_)
            pos = np.arange(off, off + f.nrows, dtype=np.int64)
            return _key_frame(mat, pos, _hash_buckets(mat, B_))

        with pinned(h) as f:
            kf = build(f)
        return as_handle(kf, recompute=lambda: build(resolve(h)))

    items = [(h, int(offsets[i]), joint, B) for i, h in enumerate(blocks)]
    return dispatch_blocks(task, items)


def _join_bucket_handles(key_handles, B):
    """Round 1c: per-bucket key frames (the exchange output)."""
    return _exchange(key_handles, B,
                     lambda kf: np.asarray(kf.col("b").data, dtype=np.int64))


def _local_join_tasks(lbuckets, rbuckets, mean_rows, stats):
    """Per-bucket local-join work items, splitting skewed buckets: the larger
    side of an oversized bucket splits positionally into parts (each part
    sees the whole smaller side), which is exact because the global merge
    sorts pairs by left position and derives unmatched rows from the pair
    set.  Each item is (lbh, rbh, llo, lhi, rlo, rhi)."""
    thresh = skew_factor() * max(1, mean_rows)
    tasks = []
    for lbh, rbh in zip(lbuckets, rbuckets):
        ln, rn = lbh.nrows, rbh.nrows
        total = ln + rn
        if total <= thresh or max(ln, rn) < 2:
            tasks.append((lbh, rbh, 0, ln, 0, rn))
            continue
        k = min(max(2, -(-total // max(1, thresh))), 32)
        if stats is not None:
            stats.skew_splits += k - 1
        big = ln if ln >= rn else rn
        cuts = np.linspace(0, big, k + 1).astype(np.int64)
        for p in range(k):
            lo, hi = int(cuts[p]), int(cuts[p + 1])
            if ln >= rn:
                tasks.append((lbh, rbh, lo, hi, 0, rn))
            else:
                tasks.append((lbh, rbh, 0, ln, lo, hi))
    return tasks


def _local_join(args, K: int):
    """One local join kernel: factorize the bucket slice jointly, match with
    the shared vectorized matcher, and return global-position results —
    (pairs_l, pairs_r, left_pos_seen, right_pos_seen)."""
    lbh, rbh, llo, lhi, rlo, rhi = args
    with pinned(lbh) as lkf, pinned(rbh) as rkf:
        if K == 1:
            # single-key fast path: ``_keys_to_ids`` factorizes by the int64
            # bit view, so the raw bit patterns are already an
            # equality-consistent id space (canonical NaN included) — the
            # matcher only needs equality plus any total order, no dense
            # O(n log n) unique required
            lids = np.asarray(lkf.col("k0").data).view(np.int64)[llo:lhi]
            rids = np.asarray(rkf.col("k0").data).view(np.int64)[rlo:rhi]
        else:
            lmat = _key_mat(lkf, K)[llo:lhi]
            rmat = _key_mat(rkf, K)[rlo:rhi]
            lids, rids = P._keys_to_ids(lmat, rmat)
        lpos = _key_pos(lkf)[llo:lhi]
        rpos = _key_pos(rkf)[rlo:rhi]
    li, ri, _, _ = P._match_ids(lids, rids, "inner")
    return lpos[li], rpos[ri], lpos, rpos


def _merge_join_results(results, how: str, npairs_hint=None):
    """Fold per-bucket/part local results into the serial-order global match
    indices (lidx, ridx, lvalid, rvalid) — see the module docstring for the
    ordering argument."""
    pl = [r[0] for r in results]
    pr = [r[1] for r in results]
    main_l = (np.concatenate(pl) if pl
              else np.empty(0, dtype=np.int64))
    main_r = (np.concatenate(pr) if pr
              else np.empty(0, dtype=np.int64))
    main_rv = np.ones(main_l.shape[0], dtype=bool)
    if how in ("left", "outer"):
        # unmatched-left: every left row was seen by ≥1 task; matched ones
        # appear in some task's pair set
        seen_l = (np.unique(np.concatenate([r[2] for r in results]))
                  if results else np.empty(0, dtype=np.int64))
        matched_l = np.unique(main_l)
        un_l = np.setdiff1d(seen_l, matched_l, assume_unique=True)
        main_l = np.concatenate([main_l, un_l])
        main_r = np.concatenate([main_r, np.zeros(un_l.shape[0],
                                                  dtype=np.int64)])
        main_rv = np.concatenate([main_rv, np.zeros(un_l.shape[0],
                                                    dtype=bool)])
    # global stable sort by left position: per-bucket pairs are already
    # left-major with right-order ties, and a left row lives in exactly one
    # bucket, so this reproduces the serial emission order exactly
    order = np.argsort(main_l, kind="stable")
    lidx, ridx, rvalid = main_l[order], main_r[order], main_rv[order]
    lvalid = np.ones(lidx.shape[0], dtype=bool)
    if how in ("right", "outer"):
        seen_r = (np.unique(np.concatenate([r[3] for r in results]))
                  if results else np.empty(0, dtype=np.int64))
        matched_r = np.unique(main_r[main_rv]) if main_rv.any() else \
            np.empty(0, dtype=np.int64)
        un_r = np.setdiff1d(seen_r, matched_r, assume_unique=True)  # sorted
        lidx = np.concatenate([lidx, np.zeros(un_r.shape[0],
                                              dtype=np.int64)])
        ridx = np.concatenate([ridx, un_r])
        lvalid = np.concatenate([lvalid, np.zeros(un_r.shape[0],
                                                  dtype=bool)])
        rvalid = np.concatenate([rvalid, np.ones(un_r.shape[0], dtype=bool)])
    return lidx, ridx, lvalid, rvalid


def _gather_pred_keep(preds, refs, lh, loffs, rh, roffs, lidx, ridx,
                      lvalid, rvalid, drop_right, row_bytes) -> np.ndarray:
    """Evaluate the fused consumer predicates against chunked mini-gathers of
    only the referenced columns (the distributed ``_gather_join_cols``)."""
    lnames = set(_schema_names(lh))
    rnames = {n for n in _schema_names(rh) if n not in drop_right}
    lref = [n for n in refs if n in lnames]
    rref = [n for n in refs if n not in lnames and n in rnames]
    for n in refs:
        if n not in lnames and n not in rnames:
            raise KeyError(n)
    keeps = []
    for lo, hi in _chunk_bounds(int(lidx.shape[0]), row_bytes):
        mini = None
        if lref:
            part = take_global(lh, loffs, lidx[lo:hi], cols=lref)
            mini = P._mask_all(part, None if lvalid is None
                               else lvalid[lo:hi])
        if rref:
            part = take_global(rh, roffs, ridx[lo:hi], cols=rref)
            part = P._mask_all(part, None if rvalid is None
                               else rvalid[lo:hi])
            mini = part if mini is None else mini.concat_cols(part)
        keeps.append(np.asarray(P._fused_selection_mask(preds, mini),
                                dtype=bool))
    return (np.concatenate(keeps) if keeps
            else np.empty(0, dtype=bool))


def shuffled_join(left: PartitionedFrame, right: PartitionedFrame,
                  params: dict, stages: Sequence[alg.Stage] = (),
                  stats=None) -> PartitionedFrame:
    """Grace-hash JOIN over the exchange layer — bit-identical to the serial
    ``REPRO_SHUFFLE=0`` path, with neither input ever concatenated."""
    how = params["how"]
    on = params["on"]
    left_on = params["left_on"] or on
    right_on = params["right_on"] or on
    label = "fused_join" if stages else "join"
    grid = params.get("grid")

    lh, loffs = _grid_handles(left, grid, "join")
    rh, roffs = _grid_handles(right, grid, "join")

    if left_on is None:
        # CROSS-PRODUCT: pure index arithmetic — no keys, no exchange
        ml, mr = left.nrows, right.nrows
        lidx = np.repeat(np.arange(ml, dtype=np.int64), mr)
        ridx = np.tile(np.arange(mr, dtype=np.int64), ml)
        lvalid = rvalid = None
        drop_right: tuple = ()
    else:
        K = len(left_on)
        total_rows = left.nrows + right.nrows
        key_bytes = total_rows * (K + 1) * 8
        B = bucket_count(total_rows, key_bytes)
        with node_scope(f"{label}:exchange"), _phase(f"{label}:exchange"):
            # wide-int flags must agree across every block of BOTH inputs
            flag_items = ([(h, left_on) for h in lh]
                          + [(h, right_on) for h in rh])

            def flags_task(args):
                h, sub = args
                with pinned(h) as f:
                    return P._wide_int_flags(f.induce(), sub)

            all_flags = dispatch_blocks(flags_task, flag_items)
            joint = np.zeros_like(all_flags[0])
            for fl in all_flags:
                joint = joint | fl
            with _phase(f"{label}:bucketize"):
                lkeys = _join_key_handles(lh, loffs, left_on, joint, B)
                rkeys = _join_key_handles(rh, roffs, right_on, joint, B)
                lbuckets = _join_bucket_handles(lkeys, B)
                rbuckets = _join_bucket_handles(rkeys, B)
        if stats is not None:
            stats.shuffle_buckets += 2 * B
            stats.shuffle_bytes += sum(
                (K + 1) * 8 * h.nrows for h in lbuckets + rbuckets)
        mean_rows = max(1, total_rows // max(1, B))
        tasks = _local_join_tasks(lbuckets, rbuckets, mean_rows, stats)
        with node_scope(f"{label}:local"), _phase(f"{label}:local"):
            results = dispatch_blocks(lambda a: _local_join(a, K), tasks)
        lidx, ridx, lvalid, rvalid = _merge_join_results(results, how)
        drop_right = tuple(right_on) if on is not None else ()

    preds, proj, rest = P._split_consumer_stages(stages) if stages else \
        ([], None, ())
    row_bytes = _row_bytes(lh) + _row_bytes(rh)
    row_labels = None
    if preds and lidx.shape[0]:
        refs = sorted(frozenset().union(*[p.refs() for p in preds]), key=repr)
        with node_scope(f"{label}:gather"), _phase(f"{label}:gather"):
            keep = _gather_pred_keep(preds, refs, lh, loffs, rh, roffs,
                                     lidx, ridx, lvalid, rvalid, drop_right,
                                     row_bytes)
        # the unfused path filters AFTER the join resets its index (same
        # label bookkeeping as physical._fused_join)
        row_labels = RangeLabels(int(lidx.shape[0])).take(np.nonzero(keep)[0])
        lidx, ridx = lidx[keep], ridx[keep]
        lvalid = lvalid[keep] if lvalid is not None else None
        rvalid = rvalid[keep] if rvalid is not None else None
    if stats is not None:
        stats.gather_rows += int(lidx.shape[0])

    total = int(lidx.shape[0])
    labels = row_labels if row_labels is not None else RangeLabels(total)
    keep_cols = frozenset(proj) if proj is not None else None
    lnames = _schema_names(lh)
    rnames = _schema_names(rh)
    keep_l = [n for n in lnames if keep_cols is None or n in keep_cols]
    keep_r = [n for n in rnames
              if n not in drop_right and (keep_cols is None or n in keep_cols)]

    def chunk_builder(lo: int, hi: int) -> Callable[[], Frame]:
        def build() -> Frame:
            lpart = take_global(lh, loffs, lidx[lo:hi], cols=keep_l)
            rpart = take_global(rh, roffs, ridx[lo:hi], cols=keep_r)
            lpart = P._mask_all(lpart, None if lvalid is None
                                else lvalid[lo:hi])
            rpart = P._mask_all(rpart, None if rvalid is None
                                else rvalid[lo:hi])
            out = lpart.concat_cols(rpart)
            out = Frame(out.columns, labels.take(np.arange(lo, hi)),
                        out.col_labels)
            if proj is not None:
                out = out.take_cols(out.col_labels.positions_of(proj))
            return out
        return build

    builders = [chunk_builder(lo, hi)
                for lo, hi in _chunk_bounds(total, row_bytes)]
    pfo = P._output_pf(_gather_chunks(builders, f"{label}:gather"))
    if rest:
        pfo = pfo.map_blockwise(lambda b: P._run_stages_block(b, rest))
    return pfo


# =============================================================================
# SORT: sample-sort range exchange + per-bucket local lexsort
# =============================================================================
def _sort_transform(keys: list[np.ndarray], ascending: bool) -> np.ndarray:
    """The direction/null-unified transform: after it, a plain ascending
    stable lexsort reproduces ``physical._sort_perm`` for either direction
    (NaN → +inf sorts last; descending negates values)."""
    out = []
    for v in keys:
        t = np.where(np.isnan(v), np.inf, v if ascending else -v)
        out.append(np.asarray(t, dtype=np.float64))
    return np.stack(out, axis=1)


def _sort_key_handles(blocks, offsets, by, ascending, keeps=None):
    """Per-block transformed rank-key frames + deterministic per-block
    splitter samples of the primary key.  ``keeps`` (per-block bool masks,
    fused-filter path) drops filtered rows before they ever enter the
    exchange — global positions stay those of the original blocks."""
    def task(args):
        h, off, keep = args

        def build(f: Frame) -> Frame:
            f = f.induce()
            mat = _sort_transform(P._sort_rank_keys(f, by), ascending)
            pos = np.arange(off, off + f.nrows, dtype=np.int64)
            if keep is not None:
                mat, pos = mat[keep], pos[keep]
            return _key_frame(mat, pos)

        with pinned(h) as f:
            kf = build(f)
            t0 = np.asarray(kf.col("k0").data)
            s = np.sort(t0)
            if s.size > 128:
                s = s[np.linspace(0, s.size - 1, 128).astype(np.int64)]
        return as_handle(kf, recompute=lambda: build(resolve(h))), s

    items = [(h, int(offsets[i]), None if keeps is None else keeps[i])
             for i, h in enumerate(blocks)]
    out = dispatch_blocks(task, items)
    return [o[0] for o in out], [o[1] for o in out]


def _splitters(samples: list[np.ndarray], B: int) -> np.ndarray:
    cand = np.sort(np.concatenate(samples)) if samples else \
        np.empty(0, dtype=np.float64)
    if cand.size == 0 or B <= 1:
        return np.empty(0, dtype=np.float64)
    picks = np.linspace(0, cand.size - 1, B + 1).astype(np.int64)[1:-1]
    return cand[picks]


def _lex_perm(keys: list[np.ndarray]) -> np.ndarray:
    """Stable lexicographic argsort of transformed (NaN-free, see
    :func:`_sort_transform`) float64 key columns, most-significant first.
    Adjacent key pairs pack into complex128 — numpy orders complex by
    (real, imag), bit-identical to the two-pass lexsort for NaN-free floats
    (ties, ±0, ±inf included) — halving the stable-sort passes."""
    packed = [keys[j] + 1j * keys[j + 1] if j + 1 < len(keys) else keys[j]
              for j in range(0, len(keys), 2)]
    if len(packed) == 1:
        return np.argsort(packed[0], kind="stable")
    return np.lexsort(tuple(reversed(packed)))


def _refine_parts(mat: np.ndarray, rows: np.ndarray, j: int,
                  thresh: int, splits: list[int]) -> list[np.ndarray]:
    """Recursive range refinement of an oversized sort bucket.  Quantile cuts
    on key column ``j``, with values *equal to a cut* isolated into their own
    group (``lo + hi`` over left/right searchsorted) — a hot value can never
    lump together with its neighbors.  A single-valued oversized group is
    fully tied on this column and recurses on the next one; with every key
    column tied a positional split is exact (stable lexsort ⇒ tied rows keep
    bucket order).  Groups are emitted in range order, so concatenation
    preserves the global sort."""
    if rows.shape[0] <= thresh:
        return [rows]
    if j >= mat.shape[1]:
        k = -(-rows.shape[0] // max(1, thresh))
        parts = [p for p in np.array_split(rows, k) if p.shape[0]]
        splits[0] += max(0, len(parts) - 1)
        return parts
    v = mat[rows, j]
    sv = np.sort(v)
    if sv[0] == sv[-1]:
        return _refine_parts(mat, rows, j + 1, thresh, splits)
    k = max(2, -(-rows.shape[0] // max(1, thresh)))
    picks = np.linspace(0, sv.size - 1, k + 1).astype(np.int64)[1:-1]
    cuts = np.unique(sv[picks])
    lo = np.searchsorted(cuts, v, side="left")
    hi = np.searchsorted(cuts, v, side="right")
    gid = lo + hi
    out: list[np.ndarray] = []
    made = 0
    for g in range(2 * int(cuts.size) + 1):
        grp = rows[gid == g]
        if not grp.shape[0]:
            continue
        made += 1
        if grp.shape[0] > thresh and grp.shape[0] < rows.shape[0]:
            out.extend(_refine_parts(mat, grp, j, thresh, splits))
        else:
            out.append(grp)
    splits[0] += max(0, made - 1)
    return out


def shuffled_sort(pf: PartitionedFrame, by: Sequence[Any], ascending: bool,
                  stages: Sequence[alg.Stage] = (), stats=None,
                  grid: str | None = None) -> PartitionedFrame:
    """Sample-sort over the exchange layer — bit-identical to the serial
    ``REPRO_SHUFFLE=0`` permutation, with the input never concatenated."""
    label = "fused_sort" if stages else "sort"
    blocks, offs = _grid_handles(pf, grid, "sort")
    K = len(by)
    n = pf.nrows
    B = bucket_count(n, n * (K + 1) * 8)

    preds, proj, rest = P._split_consumer_stages(stages) if stages else \
        ([], None, ())
    keeps = None
    if preds:
        # fused consumer filter FIRST, on the UNSORTED blocks: row-local ⇒
        # permutation- and block-invariant, and stable sorting commutes with
        # subsetting (survivors keep their relative order either way) — so
        # filtered rows never enter the exchange, the local sorts, or the
        # payload gather
        def mask_task(h):
            with pinned(h) as f:
                return np.asarray(P._fused_selection_mask(preds, f.induce()),
                                  dtype=bool)

        with node_scope(f"{label}:exchange"), _phase(f"{label}:exchange"):
            keeps = dispatch_blocks(mask_task, blocks)

    with node_scope(f"{label}:exchange"), _phase(f"{label}:exchange"):
        with _phase(f"{label}:bucketize"):
            key_handles, samples = _sort_key_handles(blocks, offs, by,
                                                     ascending, keeps)
            cuts = _splitters(samples, B)

        nb = int(cuts.size) + 1
        buckets = _exchange(
            key_handles, nb,
            lambda kf: np.searchsorted(
                cuts, np.asarray(kf.col("k0").data),
                side="right").astype(np.int64))
    if stats is not None:
        stats.shuffle_buckets += nb
        stats.shuffle_bytes += sum((K + 1) * 8 * h.nrows for h in buckets)

    # skew refinement: oversized buckets split into range-refined parts so
    # local sorts stay balanced; parts are emitted in range order, so the
    # final concat is still the global permutation.  Sized on the rows that
    # actually entered the exchange (the fused filter may have dropped some).
    nexch = sum(h.nrows for h in buckets)
    thresh = skew_factor() * max(1, nexch // max(1, nb))
    work: list = []          # (bucket_handle, local_rows | None)
    splits = [0]

    def refine_task(bh):
        with pinned(bh) as kf:
            mat = _key_mat(kf, K)
            rows = np.arange(kf.nrows, dtype=np.int64)
            return _refine_parts(mat, rows, 0, thresh, splits)

    oversized = [bh for bh in buckets if bh.nrows > thresh]
    refined: dict[int, list[np.ndarray]] = {}
    if oversized:
        with node_scope(f"{label}:local"), _phase(f"{label}:local"):
            parts_lists = dispatch_blocks(refine_task, oversized)
        refined = {id(bh): parts for bh, parts in zip(oversized, parts_lists)}
    for bh in buckets:
        for rows in refined.get(id(bh), [None]):
            work.append((bh, rows))
    if stats is not None:
        stats.skew_splits += splits[0]

    def local_sort(args):
        bh, rows = args
        with pinned(bh) as kf:
            keys = [np.asarray(kf.col(f"k{j}").data) for j in range(K)]
            pos = _key_pos(kf)
        if rows is not None:
            keys, pos = [c[rows] for c in keys], pos[rows]
        if not keys:
            return pos
        return pos[_lex_perm(keys)]

    with node_scope(f"{label}:local"), _phase(f"{label}:local"):
        sorted_pos = dispatch_blocks(local_sort, work)
    idx = (np.concatenate(sorted_pos) if sorted_pos
           else np.empty(0, dtype=np.int64))
    if stats is not None:
        stats.gather_rows += int(idx.shape[0])

    row_bytes = _row_bytes(blocks)
    cols = list(proj) if proj is not None else None

    def chunk_builder(lo: int, hi: int) -> Callable[[], Frame]:
        def build() -> Frame:
            return take_global(blocks, offs, idx[lo:hi], cols=cols)
        return build

    builders = [chunk_builder(lo, hi)
                for lo, hi in _chunk_bounds(int(idx.shape[0]), row_bytes)]
    pfo = P._output_pf(_gather_chunks(builders, f"{label}:gather"))
    if rest:
        pfo = pfo.map_blockwise(lambda b: P._run_stages_block(b, rest))
    return pfo
