"""Evaluation engine: eager / lazy / opportunistic execution (paper §6.1).

* **eager**       — pandas semantics: each statement fully evaluated on
                    construction (the paper-faithful baseline).
* **lazy**        — Spark semantics: nothing runs until the user inspects.
* **opportunistic** — the paper's §6.1.1 middle ground: control returns
                    immediately, the plan is *scheduled in the background*
                    during "think time"; an inspect prioritizes that plan
                    (and is usually a cache hit by then).

Also implements:
* prefix computation (§6.1.2): ``head(k)`` on prefix-safe plans evaluates only
  enough *input row blocks* to produce k output rows (progressive doubling for
  selective plans), instead of the whole frame;
* materialization & reuse (§6.2.2): every evaluated sub-plan lands in a
  budget-bounded cache keyed by structural plan hash; the eviction policy
  maximizes saved-compute density (cost × hits / bytes) — the PTIME-optimal
  policy of Helix [69] approximated greedily;
* multi-query sharing (§6.2.1): common sub-expressions across concurrently
  scheduled statements dedupe through the cache *and* through an in-flight
  table, so a sub-plan running in the background is joined, never recomputed;
* pipeline fusion (§5): after rule rewriting, maximal chains of row-local
  operators collapse into ``FusedPipeline`` groups (``rewrite.fuse_pipelines``)
  evaluated as one physical sweep with a single cache entry per group —
  ``ExecStats.fused_groups`` / ``fused_stage_ops`` attribute the win.
"""
from __future__ import annotations

import concurrent.futures as _fut
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from . import algebra as alg
from . import physical, rewrite
from .frame import Frame
from .partition import PartitionedFrame, default_grid
from . import config as _config
from . import faults as _faults
from . import trace as _trace
from .faults import ExecutorClosedError, StatementCancelled
from .schedule import node_scope, stats_scope
from .store import get_store

__all__ = ["Executor", "CacheEntry", "ExecStats", "StatsTee"]


@dataclass
class CacheEntry:
    result: PartitionedFrame
    cost_s: float          # wall time it took to produce
    nbytes: int
    hits: int = 0
    created: float = field(default_factory=time.monotonic)

    def benefit_density(self) -> float:
        return (self.cost_s * (1 + self.hits)) / max(1, self.nbytes)


@dataclass
class ExecStats:
    """Counter semantics (one source of truth — mirrors ``rewrite.FusionStats``,
    asserted in tests and benches):

      * ``fused_groups``          — FusedPipeline nodes in final plans;
      * ``barrier_fused_groups``  — barrier-fused nodes (FusedGroupBy /
                                    FusedSort / FusedJoin / FusedWindow);
      * ``producer_stage_ops``    — operator nodes absorbed as producer stages
                                    of a barrier node (GROUPBY pre-aggregation
                                    sweep, WINDOW pre-stages);
      * ``consumer_stage_ops``    — operator nodes absorbed as consumer stages
                                    (SORT/JOIN post-gather chain, WINDOW
                                    post-stages);
      * ``fused_stage_ops``       — operator nodes absorbed into ANY fused
                                    construct.  Invariant::

                                      fused_stage_ops ==
                                          (ops in FusedPipeline groups)
                                          + producer_stage_ops
                                          + consumer_stage_ops

      * ``gather_rows``           — payload rows gathered / materialized by
                                    SORT/JOIN/DIFFERENCE/DROP-DUPLICATES
                                    result materialization (fused-consumer
                                    paths gather strictly fewer rows than
                                    unfused ones under selective chains);
      * ``dedup_blocks``          — key-extraction programs DIFFERENCE /
                                    DROP-DUPLICATES ran (both inputs, for
                                    DIFFERENCE): per-partition on the
                                    block-parallel path, 1 (dedup) / 2
                                    (difference) whole-frame programs on the
                                    ``REPRO_BLOCK_DEDUP=0`` serial path — the
                                    count vs the partition count shows which
                                    path ran;
      * ``dedup_key_rows``        — rows those key-extraction programs keyed
                                    (== input rows after any absorbed
                                    producer chain);
      * ``dispatches``            — pool tasks submitted on this executor's
                                    behalf (``schedule.dispatch_blocks``);
      * ``dispatched_blocks``     — blocks those tasks covered.  With block
                                    coalescing ``dispatches`` grows with the
                                    *worker* count while ``dispatched_blocks``
                                    grows with the *partition* count — their
                                    ratio ``blocks_per_dispatch`` attributes
                                    the coalescing win;
      * ``spills`` / ``faults``   — block-store residency transitions
                                    (``core.store``) that happened while this
                                    executor's plan nodes ran: blocks written
                                    to disk under ``REPRO_MEM_BUDGET``
                                    pressure / loaded back on demand.  With
                                    the default budget 0 both MUST stay 0 —
                                    every pre-existing suite asserts that
                                    (tests/conftest.py), so residency can
                                    never regress silently;
      * ``spilled_bytes``         — payload bytes those spills wrote;
      * ``peak_resident_bytes``   — the store's resident high-water mark over
                                    this executor's evaluations (0 when the
                                    store is unbudgeted — nothing is
                                    tracked).  The out-of-core invariant is
                                    peak ≤ budget + one in-flight block per
                                    pool worker.

    Fault-tolerance counters (PR 6) — a statement either completes
    bit-identical to its fault-free run or raises ONE typed error
    (``faults.TaskError`` / ``SpillIntegrityError`` / ``StoreClosedError``),
    and everything the recovery machinery did is attributed here, per plan
    node, by the same scope/snapshot-delta mechanism as the counters above:

      * ``retries``               — block-task retry attempts the dispatch
                                    layer spent on transient failures
                                    (``REPRO_TASK_RETRIES``);
      * ``task_failures``         — block/chunk task failures observed
                                    (each retry that itself fails counts
                                    again; ≥ ``retries`` on a run that
                                    ultimately raised);
      * ``checksum_failures``     — spill files that failed CRC32
                                    verification or were missing on fault;
      * ``recomputed_blocks``     — blocks rebuilt from their recorded
                                    producer after an integrity failure;
      * ``budget_overruns``       — spill writes abandoned (ENOSPC on every
                                    ``REPRO_SPILL_DIR`` entry): the victim
                                    stayed resident, over budget, rather
                                    than failing the statement;
      * ``faults_injected``       — faults the deterministic chaos plan
                                    (``REPRO_FAULT_PLAN``) actually fired
                                    during this executor's evaluations; 0
                                    whenever injection is disabled.

    Shuffle/exchange counters (PR 8, ``core/shuffle.py``) — grace-hash JOIN
    and sample-sort SORT attribute their exchange here; all three stay 0 under
    ``REPRO_SHUFFLE=0`` (the serial oracle) and for cross joins (which need
    no exchange):

      * ``shuffle_buckets``       — bucket frames the exchange registered:
                                    2·B per hash join (one per side per
                                    bucket), B per sample-sort;
      * ``shuffle_bytes``         — key-frame payload bytes exchanged —
                                    exactly ``rows × (n_keys + 1) × 8``
                                    (float64 keys + int64 global position)
                                    summed over bucket frames; the payload
                                    itself never moves through the exchange;
      * ``skew_splits``           — extra local tasks created by splitting
                                    oversized buckets
                                    (``REPRO_SHUFFLE_SKEW_FACTOR``): an
                                    oversized join bucket splits its larger
                                    side, an oversized sort bucket range-
                                    refines; 0 on balanced keys.

    Timing counters (``core.trace`` PR) — wall-clock attribution in
    nanoseconds, always on (one ``perf_counter_ns`` pair per window; the
    span *tree* itself only exists under ``REPRO_TRACE``/``Session(trace=)``):

      * ``node_wall_ns``          — time inside physical node programs (each
                                    node's own run window; children are timed
                                    in their own windows, never double-
                                    counted);
      * ``plan_prep_ns``          — time in plan preparation (rewrite +
                                    fusion) per statement;
      * ``queue_wait_ns``         — time async statements waited in the
                                    admission queue (``core.service``) before
                                    getting an inflight slot;
      * ``slot_hold_ns``          — time admitted statements held their slot
                                    (queue_wait + slot_hold ≈ the tenant's
                                    pool pressure: ``QueryService.
                                    tenant_report`` ranks sessions by these).
    """

    evaluated_nodes: int = 0
    cache_hits: int = 0
    inflight_joins: int = 0
    prefix_evals: int = 0
    rewrites_applied: int = 0
    background_tasks: int = 0
    fused_groups: int = 0
    fused_stage_ops: int = 0
    barrier_fused_groups: int = 0
    producer_stage_ops: int = 0
    consumer_stage_ops: int = 0
    gather_rows: int = 0
    dedup_blocks: int = 0
    dedup_key_rows: int = 0
    dispatches: int = 0
    dispatched_blocks: int = 0
    spills: int = 0
    faults: int = 0
    spilled_bytes: int = 0
    peak_resident_bytes: int = 0
    retries: int = 0
    task_failures: int = 0
    checksum_failures: int = 0
    recomputed_blocks: int = 0
    budget_overruns: int = 0
    faults_injected: int = 0
    shuffle_buckets: int = 0
    shuffle_bytes: int = 0
    skew_splits: int = 0
    node_wall_ns: int = 0
    plan_prep_ns: int = 0
    queue_wait_ns: int = 0
    slot_hold_ns: int = 0

    @property
    def blocks_per_dispatch(self) -> float:
        return self.dispatched_blocks / max(1, self.dispatches)


_TEE_LOCK = threading.Lock()


class StatsTee:
    """Duck-typed ``ExecStats`` writer that mirrors every counter mutation
    onto several targets — the executor's global stats plus the active
    session's per-session stats (``config.SessionConfig.stats``), used when
    many service sessions share one executor (``core.service``).

    Counter sites write ``st.counter += n``; ``__setattr__`` recovers the
    delta against the primary target under one process-wide lock and applies
    it to EVERY target, so a concurrent session sees exactly its own work
    while the global counters stay the sum of the per-session ones (lost
    updates under contention hit all targets identically, preserving the sum
    invariant).  Reads come from the primary (global) target.  Non-additive
    gauges (``peak_resident_bytes``) must not be assigned through the tee —
    ``Executor._attribute_store_delta`` handles them explicitly per target."""

    __slots__ = ("_targets",)

    def __init__(self, *targets: ExecStats):
        object.__setattr__(self, "_targets", targets)

    def __getattr__(self, name: str):
        return getattr(self._targets[0], name)

    def __setattr__(self, name: str, value) -> None:
        ts = self._targets
        with _TEE_LOCK:
            delta = value - getattr(ts[0], name)
            for t in ts:
                setattr(t, name, getattr(t, name) + delta)


class Executor:
    def __init__(self, frame_store: dict[str, PartitionedFrame], *,
                 cache_budget_bytes: int = 1 << 30, optimize: bool = True,
                 background_workers: int = 2):
        self.frames = frame_store
        self.cache: dict[tuple, CacheEntry] = {}
        self.cache_budget = cache_budget_bytes
        self.optimize = optimize
        self.stats = ExecStats()
        self._closed = False
        self._lock = threading.Lock()
        self._inflight: dict[tuple, _fut.Future] = {}
        # plan keys already counted in fusion stats (bounded FIFO: stats-only
        # bookkeeping must not grow with the life of a session)
        self._fused_seen: dict[tuple, None] = {}
        self._fused_seen_max = 4096
        # session statement history (MQO-aware fusion boundaries, §6.2.1):
        # candidate barrier key (a statement's optimized or prepared form) →
        # the statement's prepared key.  A candidate only acts as a fusion
        # barrier while its prepared result is actually materialized (cache)
        # or in flight — splitting a fused group buys nothing when there is
        # no shared result to reuse, and the fluent API records every
        # intermediate expression as a statement.
        self._history: dict[tuple, tuple] = {}
        self._history_max = 2048
        # optimized-plan key → (active history snapshot, fused plan): re-
        # evaluating a cached statement must not pay the fusion walk again
        # (bounded FIFO); the snapshot guards against stale fusion when a
        # history statement's materialization status changes
        self._fuse_memo: dict[tuple, tuple[frozenset, alg.Node]] = {}
        # raw-plan key → optimized plan: the fluent API prepares AND records
        # every statement, so the fixpoint rewrite walk must not run twice
        # per plan (bounded FIFO; sources are append-only so schemas are
        # stable).  Also keeps stats.rewrites_applied at once per plan.
        self._opt_memo: dict[tuple, alg.Node] = {}
        self._bg = _fut.ThreadPoolExecutor(max_workers=background_workers,
                                           thread_name_prefix="repro-bg")

    def _stats(self) -> Any:
        """Stats sink for the calling context: the executor's global counters,
        teed into the active session's per-session ``ExecStats`` when one is
        installed (multi-session attribution under a ``QueryService``)."""
        cfg = _config.current()
        ss = cfg.stats if cfg is not None else None
        if ss is None or ss is self.stats:
            return self.stats
        return StatsTee(self.stats, ss)

    def _require_open(self) -> None:
        if self._closed:
            raise ExecutorClosedError(
                "executor is shut down — the owning session/service was closed")

    # ------------------------------------------------------------------
    # plan optimization entry
    # ------------------------------------------------------------------
    def _source_columns(self, frame_id: str) -> list | None:
        pf = self.frames.get(frame_id)
        if pf is None:
            return None
        return pf.parts[0][0].col_labels.to_list() if pf.col_parts == 1 else (
            pf.repartition(col_parts=1).parts[0][0].col_labels.to_list())

    def optimized(self, node: alg.Node) -> alg.Node:
        if not self.optimize:
            return node
        key = node.cache_key()
        with self._lock:
            hit = self._opt_memo.get(key)
        if hit is not None:
            return hit
        out = rewrite.optimize(node, self._source_columns)
        if out is not node:
            self._stats().rewrites_applied += 1
        with self._lock:
            while len(self._opt_memo) >= self._fused_seen_max:
                self._opt_memo.pop(next(iter(self._opt_memo)))
            self._opt_memo[key] = out
        return out

    def fused(self, node: alg.Node) -> alg.Node:
        """Fusion pass (paper §5 pipelining + barrier fusion): collapse
        row-local chains into FusedPipeline groups and fuse them through
        blocking-operator boundaries — one physical sweep and one cache entry
        each.  Disabled together with ``optimize`` so the per-node path stays
        available as the comparison baseline."""
        if not self.optimize:
            return node
        st = self._stats()
        in_key = node.cache_key()
        with self._lock:
            hit = self._fuse_memo.get(in_key)
            history = frozenset(
                k for k, prep in self._history.items()
                if prep in self.cache or prep in self._inflight)
        if hit is not None and hit[0] == history:
            return hit[1]
        out, fs = rewrite.fuse_pipelines(node, history)
        with self._lock:
            while len(self._fuse_memo) >= self._fused_seen_max:
                self._fuse_memo.pop(next(iter(self._fuse_memo)))
            self._fuse_memo[in_key] = (history, out)
            if fs.groups or fs.barrier_groups:
                key = out.cache_key()   # count each distinct plan once: re-
                if key not in self._fused_seen:   # evaluating a cached plan
                    while len(self._fused_seen) >= self._fused_seen_max:  # is
                        self._fused_seen.pop(next(iter(self._fused_seen)))
                    self._fused_seen[key] = None  # not new fusion work
                    st.fused_groups += fs.groups
                    st.fused_stage_ops += fs.fused_ops
                    st.barrier_fused_groups += fs.barrier_groups
                    st.producer_stage_ops += fs.producer_ops
                    st.consumer_stage_ops += fs.consumer_ops
        return out

    def note_statement(self, node: alg.Node) -> None:
        """Record a session statement in the fusion history (MQO §6.2.1):
        while this statement's result is materialized (or in flight), later
        plans refuse to absorb its sub-plan into a bigger fused group, so the
        cached result keeps serving as a shared prefix.  Fusion is
        deterministic, so the split sub-plan re-fuses to this statement's
        prepared cache key.  Call AFTER the statement is prepared/submitted —
        a statement must not act as a fusion barrier against itself."""
        if not self.optimize:
            return
        opt = self.optimized(node)
        prep_key = self.fused(opt).cache_key()
        with self._lock:
            for k in (opt.cache_key(), prep_key):
                if k not in self._history:
                    while len(self._history) >= self._history_max:
                        self._history.pop(next(iter(self._history)))
                    self._history[k] = prep_key

    def _prepared(self, node: alg.Node) -> alg.Node:
        return self.fused(self.optimized(node))

    # ------------------------------------------------------------------
    # synchronous evaluation (with cache + in-flight dedupe)
    # ------------------------------------------------------------------
    def evaluate(self, node: alg.Node, *,
                 stmt: int | None = None) -> PartitionedFrame:
        # plan preparation can touch the store too (schema inference
        # resolves a source block, which may fault a spilled one back in) —
        # attribute that residency work here so statement execution accounts
        # for EVERY spill/fault/recompute, not just the per-node windows
        self._require_open()
        tr = _trace.current()
        st = self._stats()
        s0 = get_store().stats.snapshot()
        f0 = _faults.injected_total()
        if tr is None:
            tp0 = time.perf_counter_ns()
            prepared = self._prepared(node)
            st.plan_prep_ns += time.perf_counter_ns() - tp0
            self._attribute_store_delta(s0, f0)
            return self._eval(prepared)
        with tr.statement(f"statement:{node.op}", stmt=stmt):
            tp0 = time.perf_counter_ns()
            with tr.span("plan_prep", "prep") as sp:
                prepared = self._prepared(node)
                sp.args = self._attribute_store_delta(s0, f0, want_delta=True)
            st.plan_prep_ns += time.perf_counter_ns() - tp0
            return self._eval(prepared)

    def _attribute_store_delta(self, s0, f0,
                               want_delta: bool = False) -> dict | None:
        """Fold the store/fault counter movement since snapshot ``s0`` /
        injected-count ``f0`` into this executor's ``ExecStats`` — and into
        the active session's per-session stats when one is installed, so
        multi-tenant attribution sums to the global counters.

        ``want_delta=True`` (traced runs) additionally returns the delta as a
        dict, which the caller attaches to the window's span — spans carry
        exactly the counters ExecStats was credited with, which is why a
        statement's span-attached deltas sum to its global ExecStats movement
        (asserted by ``benchmarks/bench_trace.py`` and the CI trace smoke)."""
        s1 = get_store().stats.snapshot()
        df = _faults.injected_total() - f0
        cfg = _config.current()
        ss = cfg.stats if cfg is not None else None
        targets = ((self.stats,) if ss is None or ss is self.stats
                   else (self.stats, ss))
        with _TEE_LOCK:
            for t in targets:
                t.spills += s1[0] - s0[0]
                t.faults += s1[1] - s0[1]
                t.spilled_bytes += s1[2] - s0[2]
                t.checksum_failures += s1[4] - s0[4]
                t.recomputed_blocks += s1[5] - s0[5]
                t.budget_overruns += s1[6] - s0[6]
                t.faults_injected += df
                # peak is attributed only when this window raised the store's
                # high-water mark — a fresh executor must not inherit an
                # earlier session's peak from the process-wide gauge
                if s1[3] > s0[3] and s1[3] > t.peak_resident_bytes:
                    t.peak_resident_bytes = s1[3]
        if not want_delta:
            return None
        return {"spills": s1[0] - s0[0], "faults": s1[1] - s0[1],
                "spilled_bytes": s1[2] - s0[2],
                "checksum_failures": s1[4] - s0[4],
                "recomputed_blocks": s1[5] - s0[5],
                "budget_overruns": s1[6] - s0[6],
                "faults_injected": df}

    def _hit_event(self, node: alg.Node, *, inflight: bool = False) -> None:
        """Cache-hit provenance for traced statements: an instant event names
        the plan node a cached (or in-flight) result served, so ``profile()``
        can say which sub-plans the MQO layer reused.  No-op untraced."""
        tr = _trace.current()
        if tr is not None:
            kind = "inflight_join" if inflight else "cache_hit"
            tr.instant(f"{kind}:{node.op}", "cache")

    def _join(self, fut: _fut.Future, node: alg.Node) -> PartitionedFrame:
        """Join another statement's in-flight evaluation.  If that producer
        was *cancelled* (its session's CancelToken fired) the cancellation
        must not leak into us — re-evaluate the sub-plan ourselves.  A
        producer that failed for any other reason (including the executor
        shutting down) propagates its typed error."""
        try:
            return fut.result()
        except StatementCancelled:
            return self._eval(node)

    def _eval(self, node: alg.Node) -> PartitionedFrame:
        self._require_open()
        st = self._stats()
        key = node.cache_key()
        # cache and in-flight are consulted under ONE lock hold (a split
        # would let a finishing thread fill the cache AND retire its future
        # between our two looks — re-evaluating the whole plan); the store
        # benefit stamp runs outside the lock
        with self._lock:
            ent = self.cache.get(key)
            fut = None
            if ent is not None:
                ent.hits += 1
                st.cache_hits += 1
            else:
                fut = self._inflight.get(key)
        if ent is not None:
            self._hit_event(node)
            self._sync_store_benefit(ent)
            return ent.result
        if fut is not None:
            st.inflight_joins += 1
            self._hit_event(node, inflight=True)
            return self._join(fut, node)

        promise: _fut.Future = _fut.Future()
        with self._lock:
            # double-check under lock: cache → in-flight → register, atomic
            ent = self.cache.get(key)
            fut = None
            if ent is not None:
                ent.hits += 1
                st.cache_hits += 1
            else:
                existing = self._inflight.get(key)
                if existing is not None:
                    fut = existing
                else:
                    self._inflight[key] = promise
        if ent is not None:
            self._hit_event(node)
            self._sync_store_benefit(ent)   # same policy as the fast path
            return ent.result
        if fut is not None:
            st.inflight_joins += 1
            self._hit_event(node, inflight=True)
            return self._join(fut, node)

        try:
            t0 = time.monotonic()
            if node.op == "source":
                result = self.frames[node.params["frame_id"]]
            else:
                inputs = [self._eval(c) for c in node.children]
                # attribute block-store residency work (spills written /
                # faults served while THIS node's physical program ran) by
                # snapshot delta — faults happen on pool worker threads, so
                # the contextvar scope can't see them
                s0 = get_store().stats.snapshot()
                f0 = _faults.injected_total()
                tr = _trace.current()
                tn0 = time.perf_counter_ns()
                if tr is None:
                    with stats_scope(st), node_scope(node.op):
                        result = physical.run_node(node, inputs, st)
                    self._attribute_store_delta(s0, f0)
                else:
                    # children were evaluated above, in their own windows, so
                    # this span's duration and counter delta are exactly this
                    # node's own work — per-statement spans partition the
                    # statement's ExecStats movement
                    with tr.span(f"eval:{node.op}", "node") as span:
                        with stats_scope(st), node_scope(node.op):
                            result = physical.run_node(node, inputs, st)
                        span.args = self._attribute_store_delta(
                            s0, f0, want_delta=True)
                st.node_wall_ns += time.perf_counter_ns() - tn0
            dt = time.monotonic() - t0
            st.evaluated_nodes += 1
            self._store(key, result, dt)
            try:
                promise.set_result(result)
            except _fut.InvalidStateError:
                pass   # shutdown() failed this promise first; our own
                       # caller still gets the computed result
            return result
        except BaseException as e:
            try:
                promise.set_exception(e)
            except _fut.InvalidStateError:
                pass
            raise
        finally:
            with self._lock:
                self._inflight.pop(key, None)

    # ------------------------------------------------------------------
    # materialization cache with benefit-density eviction (§6.2.2)
    # ------------------------------------------------------------------
    def _store(self, key: tuple, result: PartitionedFrame, cost_s: float) -> None:
        try:
            nbytes = result.nbytes()
        except Exception:
            nbytes = 1
        with self._lock:
            ent = CacheEntry(result, cost_s, nbytes)
            self.cache[key] = ent
            total = sum(e.nbytes for e in self.cache.values())
            if total > self.cache_budget:
                # evict lowest benefit-density first; never evict sources
                victims = sorted(self.cache.items(), key=lambda kv: kv[1].benefit_density())
                for k, e in victims:
                    if total <= self.cache_budget:
                        break
                    if k[0] == "source":
                        continue
                    del self.cache[k]
                    total -= e.nbytes
        self._sync_store_benefit(ent)

    def _sync_store_benefit(self, ent: CacheEntry) -> None:
        """Unified budget (§6.2.2 + out-of-core store): stamp a cached
        result's block handles with the entry's benefit density, so the
        block store's eviction — which charges cached sub-plans and live
        partitions against ONE ``REPRO_MEM_BUDGET`` — spills low-value
        working blocks (benefit 0) before it spills reusable cached
        results.  Hits raise the density, so a hot entry's blocks climb the
        residency order over time."""
        if not get_store().active:
            return
        b = ent.benefit_density()
        for row in ent.result.handles:
            for h in row:
                if b > h.benefit:
                    h.benefit = b

    def cache_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self.cache.values())

    # ------------------------------------------------------------------
    # opportunistic background scheduling (§6.1.1)
    # ------------------------------------------------------------------
    def submit(self, node: alg.Node, *,
               cancel: _config.CancelToken | None = None,
               stmt: int | None = None) -> _fut.Future:
        """Schedule evaluation in the background; returns a future.  The
        user-facing handle keeps composing; an inspect call joins it.

        The caller's session config scope is captured HERE and re-installed
        on the background thread (contextvars are per-thread, so they do not
        cross ``ThreadPoolExecutor.submit`` by themselves).  ``cancel`` makes
        the background run cancellable at the next dispatch boundary — the
        run raises the typed ``faults.StatementCancelled``.  ``stmt`` is the
        trace statement id allocated at submission time (``Session.submit`` /
        the admission controller), so the plan-prep span here, the queue-wait
        span, and the statement span opened on the background thread all land
        in one per-statement tree."""
        self._require_open()
        tr = _trace.current()
        st = self._stats()
        tp0 = time.perf_counter_ns()
        if tr is None:
            node = self._prepared(node)
        else:
            if stmt is None:
                stmt = tr.next_stmt()
            s0 = get_store().stats.snapshot()
            f0 = _faults.injected_total()
            with tr.span("plan_prep", "prep", stmt=stmt) as sp:
                node = self._prepared(node)
                sp.args = self._attribute_store_delta(s0, f0, want_delta=True)
        st.plan_prep_ns += time.perf_counter_ns() - tp0
        st.background_tasks += 1
        cfg = _config.current()
        if cancel is None:
            cancel = _config.current_cancel()

        def run() -> PartitionedFrame:
            with _config.propagate(cfg, cancel):
                if tr is None:
                    return self._eval(node)
                with tr.statement(f"statement:{node.op}", stmt=stmt):
                    return self._eval(node)

        return self._bg.submit(run)

    # ------------------------------------------------------------------
    # prefix computation (§6.1.2)
    # ------------------------------------------------------------------
    def evaluate_prefix(self, node: alg.Node, k: int) -> PartitionedFrame:
        """Produce (at least) the first k result rows cheaply when legal."""
        self._require_open()
        node = self._prepared(node)
        key = node.cache_key()
        with self._lock:
            ent = self.cache.get(key)
        if ent is not None:  # full result already known
            ent.hits += 1
            return _head(ent.result, k)
        if not alg.prefix_safe(node):
            return _head(self._eval(node), k)

        self._stats().prefix_evals += 1
        src = next(n for n in node.walk() if n.op == "source")
        total = self.frames[src.params["frame_id"]].nrows
        take = max(k, 4096)
        while True:
            pref = self._eval_with_source_prefix(node, src, min(take, total))
            if pref.nrows >= k or take >= total:
                return _head(pref, k)
            take *= 4   # selective plans: geometric back-off

    def _eval_with_source_prefix(self, node: alg.Node, src: alg.Source, k: int) -> PartitionedFrame:
        def substitute(n: alg.Node) -> alg.Node:
            if n is src or n == src:
                return alg.Limit(n, k, tail=False)
            return rewrite.rebuild(n, [substitute(c) for c in n.children])
        return self._eval(substitute(node))

    def shutdown(self):
        """Close the executor: new work is refused (``ExecutorClosedError``)
        and every in-flight promise that has not resolved yet is FAILED with
        the same typed error instead of being abandoned — a ``collect``
        racing a ``close`` raises immediately, it never blocks on a future
        nobody will complete.  (A producer thread that finishes anyway hits
        ``InvalidStateError`` on its own ``set_result`` and ignores it.)
        Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pending = [f for f in self._inflight.values() if not f.done()]
        err = ExecutorClosedError("executor shut down with statements in flight")
        for f in pending:
            try:
                f.set_exception(err)
            except _fut.InvalidStateError:
                pass   # producer resolved it between our look and now — fine
        self._bg.shutdown(wait=False, cancel_futures=True)


def _head(pf: PartitionedFrame, k: int) -> PartitionedFrame:
    return PartitionedFrame.from_frame(pf.prefix(k).to_frame().head(k))
