"""Modin-style block partitioning of a dataframe (paper §4.2).

A ``PartitionedFrame`` is a 2-D grid of ``Frame`` partitions:

    parts[i][j]  — row-block i, column-block j

Row-based partitioning is the special case ``col_parts == 1``; column-based is
``row_parts == 1``; block-based is the general grid.  The partitioning scheme
is chosen *per operation* (paper: "Our current simple approach to partitioning
is to do it on a per-operation basis"), with repartitioning inserted when the
next operator prefers a different scheme.

Execution model on this CPU container mirrors Modin-on-Ray: each partition's
work is a jit-compiled function dispatched onto a shared thread pool (XLA
releases the GIL while executing, so partitions genuinely run in parallel
across cores).  Dispatch goes through the scheduling layer
(``schedule.dispatch_blocks``), which coalesces several blocks into one pool
task when partitions ≫ workers.  On the TPU mesh the same grid maps onto
(data, model) axes via shard_map — see ``physical.py`` and
``launch/dryrun.py``.

The headline trick (paper §4.2 "Supporting billions of columns"): TRANSPOSE is
a *grid* transpose — each block is transposed locally (a Pallas kernel on
TPU), then the grid metadata is swapped.  No global shuffle.

Repartitioning is **zero-copy** where the data layout allows it: scheme
changes re-slice/re-group the existing blocks by metadata instead of
round-tripping through a full ``to_frame()`` concat + re-split.  Column
regrouping never touches data (columns are independent arrays, so merging and
splitting column blocks is pure metadata).  Row regrouping concatenates only
the block *segments* that actually cross a target boundary; a source block
that lands wholly inside one target group is passed through by identity.

Out-of-core residency (the block store, ``core.store``)
-------------------------------------------------------
Every grid cell is a ``store.BlockHandle``: the block's Frame may be resident
or spilled to disk under the ``REPRO_MEM_BUDGET`` byte budget.  All grid
*planning* (row/col sizes, segment maps, pass-through regroup, ``prefix``,
``nbytes``) runs on handle metadata and never faults a spilled block; only
per-block *programs* fault, and they do so inside the pool worker that runs
them (pinned for the duration), so spill I/O overlaps other blocks' compute.
``parts`` stays the compatible Frame-level view: indexing/iterating it
resolves exactly the touched block.  With the default budget 0 the handles
are untracked wrappers and every path below is bit-identical to the
pre-store behaviour.
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from .frame import Frame
from .schedule import dispatch_blocks, get_pool, pool_width
from .store import BlockHandle, as_handle, pinned, resolve

__all__ = ["PartitionedFrame", "default_grid", "get_pool"]


def _pmap(fn: Callable, items: Sequence) -> list:
    """Parallel map over partitions (ordered results), via the scheduling
    layer's coalesced dispatch (``schedule.dispatch_blocks``).  Single-item
    and multi-item workloads take the same path — every block runs on a pool
    worker — so exception provenance and thread-local device state do not
    depend on the partition count."""
    return dispatch_blocks(fn, items)


def _block_task(fn: Callable[[Frame], Frame]) -> Callable:
    """Lift a Frame→Frame block program to handles: fault + pin the input in
    the worker, run, and register the output with the store as it is
    produced (so a large output is budget-charged immediately and earlier
    outputs can spill while later blocks still compute).  An identity output
    keeps its input handle — no double charge.

    The output handle records ``fn`` over the *input handle* as its
    recompute thunk (lineage): if the output's spill file is later found
    corrupt or missing, the store re-runs the producer instead of crashing.
    The closure keeps the input handle alive — and therefore re-faultable —
    for as long as the output exists."""
    def run(h):
        with pinned(h) as f:
            out = fn(f)
            if out is f and isinstance(h, BlockHandle):
                return h
            return as_handle(out, recompute=lambda: fn(resolve(h)))
    return run


def default_grid(nrows: int, ncols: int, *, min_block_rows: int = 4096,
                 max_row_parts: int | None = None) -> tuple[int, int]:
    """Pick a (row_parts, col_parts) grid for a frame of the given shape.

    Mirrors Modin's default: square-ish grid bounded by the *configured pool
    width* (``schedule.pool_width``, which honors ``REPRO_POOL_WORKERS`` —
    not ``os.cpu_count()``, which would hand a 4-worker pool on a 64-core box
    a 64-row-part grid), with a minimum block height so tiny frames stay
    single-partition.
    """
    cores = max_row_parts or pool_width()
    row_parts = max(1, min(cores, nrows // max(1, min_block_rows)))
    col_parts = 1 if ncols < 64 else min(4, max(1, ncols // 64))
    return row_parts, col_parts


def _split_sizes(n: int, parts: int) -> list[int]:
    parts = max(1, min(parts, n)) if n > 0 else 1
    base, rem = divmod(n, parts)
    return [base + (1 if i < rem else 0) for i in range(parts)]


def _segments(src_sizes: list[int], tgt_sizes: list[int]) -> list[list[tuple[int, int, int]]]:
    """Map a source block layout onto a target layout: for each target group,
    the covering ``(src_block, lo, hi)`` half-open local ranges.  A segment
    spanning a whole source block signals an identity pass-through."""
    out: list[list[tuple[int, int, int]]] = []
    bi, off = 0, 0
    for t in tgt_sizes:
        need, segs = t, []
        while need > 0 and bi < len(src_sizes):
            avail = src_sizes[bi] - off
            if avail == 0:
                bi += 1
                off = 0
                continue
            take = min(need, avail)
            segs.append((bi, off, off + take))
            off += take
            need -= take
            if off == src_sizes[bi]:
                bi += 1
                off = 0
        out.append(segs)
    return out


class _RowView(Sequence):
    """One grid row as Frames: indexing/iterating resolves (faults) exactly
    the touched cells.  The handles stay the source of truth."""

    __slots__ = ("_hs",)

    def __init__(self, handles: list):
        self._hs = handles

    def __len__(self) -> int:
        return len(self._hs)

    def __getitem__(self, j):
        if isinstance(j, slice):
            return [resolve(h) for h in self._hs[j]]
        return resolve(self._hs[j])

    def __iter__(self):
        return (resolve(h) for h in self._hs)


class _PartsView(Sequence):
    """The grid as rows of ``_RowView``.  Supports the historical access
    patterns (``pf.parts[i][j]``, iteration) while resolving only the
    blocks actually touched; grid-level algebra (union, regroup) runs on
    ``pf.handles`` instead."""

    __slots__ = ("_rows",)

    def __init__(self, rows: list[list]):
        self._rows = rows

    def __len__(self) -> int:
        return len(self._rows)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [_RowView(r) for r in self._rows[i]]
        return _RowView(self._rows[i])

    def __iter__(self):
        return (_RowView(r) for r in self._rows)


def _cell_handles(row) -> list:
    if isinstance(row, _RowView):
        return list(row._hs)
    return [as_handle(c) for c in row]


class PartitionedFrame:
    """A grid of Frame partitions (behind store block handles) with global
    row/col split metadata."""

    def __init__(self, parts):
        if isinstance(parts, _PartsView):
            grid = [list(r) for r in parts._rows]
        else:
            grid = [_cell_handles(row) for row in parts]
        assert grid and grid[0], "grid must be non-empty"
        width = len(grid[0])
        assert all(len(row) == width for row in grid)
        self.handles: list[list[BlockHandle]] = grid

    # ------------------------------------------------------------------
    @property
    def parts(self) -> _PartsView:
        return _PartsView(self.handles)

    @property
    def row_parts(self) -> int:
        return len(self.handles)

    @property
    def col_parts(self) -> int:
        return len(self.handles[0])

    @property
    def row_sizes(self) -> list[int]:
        return [self.handles[i][0].nrows for i in range(self.row_parts)]

    @property
    def col_sizes(self) -> list[int]:
        return [self.handles[0][j].ncols for j in range(self.col_parts)]

    @property
    def nrows(self) -> int:
        return sum(self.row_sizes)

    @property
    def ncols(self) -> int:
        return sum(self.col_sizes)

    # ------------------------------------------------------------------
    # conversion
    # ------------------------------------------------------------------
    @staticmethod
    def from_frame(frame: Frame, row_parts: int = 1, col_parts: int = 1) -> "PartitionedFrame":
        row_sz = _split_sizes(frame.nrows, row_parts)
        col_sz = _split_sizes(frame.ncols, col_parts)
        grid: list[list[Frame]] = []
        r0 = 0
        for rs in row_sz:
            row_block = frame.take_rows(np.arange(r0, r0 + rs))
            r0 += rs
            row_cells: list[Frame] = []
            c0 = 0
            for cs in col_sz:
                row_cells.append(row_block.take_cols(range(c0, c0 + cs)))
                c0 += cs
            grid.append(row_cells)
        return PartitionedFrame(grid)

    def to_frame(self) -> Frame:
        rows = []
        for i in range(self.row_parts):
            block = resolve(self.handles[i][0])
            for j in range(1, self.col_parts):
                block = block.concat_cols(resolve(self.handles[i][j]))
            rows.append(block)
        out = rows[0]
        for block in rows[1:]:
            out = out.concat_rows(block)
        return out

    # ------------------------------------------------------------------
    # partition-wise application
    # ------------------------------------------------------------------
    def map_blockwise(self, fn: Callable[[Frame], Frame]) -> "PartitionedFrame":
        """Apply ``fn`` to every block in parallel (embarrassingly parallel
        operators: MAP, SELECTION with per-row predicates, RENAME...).
        Spilled inputs fault inside the worker task; outputs register with
        the store as they are produced."""
        flat = [h for row in self.handles for h in row]
        out = _pmap(_block_task(fn), flat)
        w = self.col_parts
        return PartitionedFrame([out[i * w:(i + 1) * w] for i in range(self.row_parts)])

    def map_row_blocks(self, fn: Callable[[Frame], Frame]) -> "PartitionedFrame":
        """Apply ``fn`` to each *full-width* row block (row partitioning)."""
        pf = self.repartition(col_parts=1)
        out = _pmap(_block_task(fn), [row[0] for row in pf.handles])
        return PartitionedFrame([[f] for f in out])

    def map_col_blocks(self, fn: Callable[[Frame], Frame]) -> "PartitionedFrame":
        """Apply ``fn`` to each *full-height* column block (column partitioning)."""
        pf = self.repartition(row_parts=1)
        out = _pmap(_block_task(fn), pf.handles[0])
        return PartitionedFrame([out])

    # ------------------------------------------------------------------
    # repartitioning (the paper's scheme changes between operators)
    # ------------------------------------------------------------------
    def repartition(self, row_parts: int | None = None, col_parts: int | None = None) -> "PartitionedFrame":
        """Change the grid scheme without a full-frame materialization.

        Column regrouping is pure metadata (zero-copy); row regrouping copies
        only the segments that cross target-group boundaries and forwards
        boundary-aligned blocks by identity — as *handles*, so a spilled
        block that passes through untouched is never faulted.  Never calls
        ``to_frame()``.
        """
        rp = row_parts if row_parts is not None else self.row_parts
        cp = col_parts if col_parts is not None else self.col_parts
        out = self
        if cp != out.col_parts:
            out = out._regroup_cols(cp)
        if rp != out.row_parts:
            out = out._regroup_rows(rp)
        return out

    def _regroup_cols(self, col_parts: int) -> "PartitionedFrame":
        """Re-split column blocks per row stripe.  Zero-copy: ``concat_cols``
        merges column lists and ``take_cols`` picks column objects — no device
        array is touched.  Whole-block segments forward the handle."""
        tgt = _split_sizes(self.ncols, col_parts)
        segs = _segments(self.col_sizes, tgt)
        grid: list[list] = []
        for stripe in self.handles:
            row: list = []
            for seglist in segs:
                if (len(seglist) == 1 and seglist[0][1] == 0
                        and seglist[0][2] == stripe[seglist[0][0]].ncols):
                    row.append(stripe[seglist[0][0]])   # identity: the handle
                    continue
                pieces = []
                for (bj, lo, hi) in seglist:
                    blk = resolve(stripe[bj])
                    pieces.append(blk if (lo == 0 and hi == blk.ncols)
                                  else blk.take_cols(range(lo, hi)))
                if not pieces:
                    cell = resolve(stripe[0]).take_cols([])
                else:
                    cell = pieces[0]
                    for p in pieces[1:]:
                        cell = cell.concat_cols(p)
                row.append(cell)
            grid.append(row)
        return PartitionedFrame(grid)

    def _regroup_rows(self, row_parts: int) -> "PartitionedFrame":
        """Re-split row blocks per column block.  Segments that cover a whole
        source block pass through by identity (the *handle* — untouched
        spilled blocks stay spilled); partial segments slice only their own
        rows; merged groups concatenate only their own segments — no
        full-frame concat ever happens."""
        tgt = _split_sizes(self.nrows, row_parts)
        segs = _segments(self.row_sizes, tgt)
        grid: list[list] = []
        for seglist in segs:
            row: list = []
            for j in range(self.col_parts):
                if (len(seglist) == 1 and seglist[0][1] == 0
                        and seglist[0][2] == self.handles[seglist[0][0]][j].nrows):
                    row.append(self.handles[seglist[0][0]][j])
                    continue
                pieces = []
                for (bi, lo, hi) in seglist:
                    blk = resolve(self.handles[bi][j])
                    pieces.append(blk if (lo == 0 and hi == blk.nrows)
                                  else blk.take_rows(np.arange(lo, hi)))
                if not pieces:
                    cell = resolve(self.handles[0][j]).take_rows(np.arange(0))
                else:
                    cell = pieces[0]
                    for p in pieces[1:]:
                        cell = cell.concat_rows(p)
                row.append(cell)
            grid.append(row)
        return PartitionedFrame(grid)

    # ------------------------------------------------------------------
    # grid transpose (metadata swap; per-block op supplied by caller)
    # ------------------------------------------------------------------
    def transpose_grid(self, block_transpose: Callable[[Frame], Frame]) -> "PartitionedFrame":
        flat = [self.handles[i][j] for j in range(self.col_parts)
                for i in range(self.row_parts)]
        out = _pmap(_block_task(block_transpose), flat)
        grid = []
        k = 0
        for _ in range(self.col_parts):
            row = []
            for _ in range(self.row_parts):
                row.append(out[k])
                k += 1
            grid.append(row)
        return PartitionedFrame(grid)

    # ------------------------------------------------------------------
    def row_block_offsets(self) -> list[int]:
        offs = [0]
        for s in self.row_sizes:
            offs.append(offs[-1] + s)
        return offs

    def row_handles(self) -> list:
        """The row-block handles of a single-col-part frame, in row order —
        metadata only, nothing faulted.  The exchange layer
        (``core.shuffle``) and the dedup key extraction iterate these to
        stage per-block work without ever concatenating the frame."""
        if self.col_parts != 1:
            raise ValueError("row_handles requires col_parts == 1 "
                             f"(have {self.col_parts})")
        return [row[0] for row in self.handles]

    def prefix(self, k: int) -> "PartitionedFrame":
        """First row blocks covering ≥ k rows (prefix computation, §6.1.2).
        Metadata-only: untouched suffix blocks are never faulted."""
        need, keep = k, []
        for i in range(self.row_parts):
            keep.append(self.handles[i])
            need -= self.handles[i][0].nrows
            if need <= 0:
                break
        return PartitionedFrame(keep)

    def nbytes(self) -> int:
        """Payload bytes across all blocks — handle metadata, so cache
        accounting never faults a spilled block."""
        return sum(h.nbytes for row in self.handles for h in row)

    def __repr__(self) -> str:
        return f"PartitionedFrame[{self.nrows}x{self.ncols}; grid {self.row_parts}x{self.col_parts}]"
