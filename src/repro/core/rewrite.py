"""Query rewriting for the dataframe algebra (paper §5 "Pipelining and
rewriting").

Ordered semantics restrict the classical rule set — set-operator
commutativity fails without compensating sorts — but the paper identifies the
rules that *do* hold, plus dataframe-specific transpose eliminations:

  R1  TRANSPOSE(TRANSPOSE(x))                  → x
  R2  TRANSPOSE(SORT(TRANSPOSE(x)))            → COLUMN_SORT(x)      (MAP+RENAME)
  R3  TRANSPOSE(SELECTION(TRANSPOSE(x)))       → COLUMN_FILTER(x)
  R4  SELECTION(SELECTION(x, p1), p2)          → SELECTION(x, p2 & p1)
      (filters commute / fuse under ordered semantics)
  R5  SELECTION(UNION(l, r), p)                → UNION(SEL(l,p), SEL(r,p))
  R6  SELECTION(MAP(x, u), p)                  → MAP(SELECTION(x, p), u)
      when u is elementwise and p only references columns u passes through
  R7  SELECTION(CROSS(l, r), l.a == r.b)       → JOIN(l, r, a=b)
      (the paper's §6.2 incremental-join pattern)
  R8  MAP(MAP(x, u1), u2)                      → MAP(x, u2 ∘ u1)     (pipelining)
  R9  PROJECTION(PROJECTION(x, c1), c2)        → PROJECTION(x, c2)
  R10 LIMIT(LIMIT(x, k1), k2)                  → LIMIT(x, min)
  R11 LIMIT(k) pushdown through row-local ops  → evaluate less input
      (prefix computation §6.1.2 exploits this dynamically; the static rule
      pushes LIMIT below SELECTION-free row-local chains)

Rules apply bottom-up to a fixpoint.  Column-name inference threads through
static-schema operators so R6/R7 only fire when provably safe.

After rule rewriting, a separate **fusion pass** (``fuse_pipelines``) collapses
maximal chains of row-local operators (elementwise MAP, SELECTION, PROJECTION,
RENAME) into single ``FusedPipeline`` nodes, which the physical layer executes
as one per-partition program — the paper's §5 pipelining argument made
explicit in the plan language.

Barrier fusion (fusing *through* blocking operators)
----------------------------------------------------
Blocking operators (GROUPBY / SORT / JOIN / WINDOW) remain materialization
boundaries for the *shuffled* data, but the row-local chains adjacent to them
fuse into the blocking operator's own per-block programs (Cylon-style
local-pattern fusion into the shuffle stage):

  * GROUPBY absorbs its row-local *producer* chain — the map/filter sweep runs
    inside the same per-block program as the ``segment_reduce`` partial
    aggregation (``FusedGroupBy``);
  * SORT / JOIN absorb their row-local *consumer* chain — leading structured
    selections filter the permutation / match *index* before the payload
    gather, and a leading projection prunes the gathered columns
    (``FusedSort`` / ``FusedJoin``);
  * WINDOW absorbs chains on both sides — pre-stages join the local-scan
    block program, post-stages join the carry-application block program, with
    carry composition preserved at partition seams (``FusedWindow``);
  * DIFFERENCE / DROP-DUPLICATES absorb chains on both sides — producer
    chains (either DIFFERENCE input) run inside the per-block key-extraction
    program, and consumer selections/projections filter the keep mask before
    the surviving rows are materialized (``FusedDifference`` /
    ``FusedDropDuplicates``, the SORT/JOIN index-first pattern).

What still blocks fusion, and why:

  * **In-plan sharing** — a sub-plan referenced by ≥ 2 parents keeps its own
    node and cache identity; absorbing it would re-execute shared work per
    branch where the cache serves it once.
  * **Session history (MQO, §6.2.1)** — a sub-plan whose structural key
    matches a prior session statement is never absorbed or descended through
    *while that statement's result is materialized or in flight*, so the
    materialization cache can still serve the shared prefix.  (An uncached
    statement is no barrier: splitting there would cost fusion and buy no
    reuse.)  Fusion is deterministic, so the split sub-plan re-fuses to
    exactly the prior statement's cache key.
  * **Non-row-local operators** — LIMIT (its k is global, not per block),
    non-elementwise MAPs (whole-frame), TRANSPOSE / TOLABELS / FROMLABELS
    (metadata movement), and consumer chains *after* GROUPBY (its output is
    already aggregate-sized — there is no gather to prune, so plain chain
    fusion above it is already optimal).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from . import algebra as alg
from .schedule import GRID_PREFS

__all__ = ["optimize", "infer_columns", "rebuild", "fuse_pipelines",
           "FusionStats"]


# -----------------------------------------------------------------------------
# static column-label inference (None ⇒ unknown/dynamic)
# -----------------------------------------------------------------------------
def infer_columns(node: alg.Node, source_columns: Callable[[str], list | None]) -> list | None:
    op = node.op

    def child(i=0):
        return infer_columns(node.children[i], source_columns)

    if op == "source":
        return source_columns(node.params["frame_id"])
    if op in ("selection", "sort", "drop_duplicates", "limit", "window",
              "column_sort", "column_filter"):
        return child()
    if op == "projection":
        return list(node.params["cols"])
    if op == "rename":
        base = child()
        if base is None:
            return None
        mapping = dict(node.params["mapping"])
        return [mapping.get(c, c) for c in base]
    if op in ("union", "difference"):
        return child(0)
    if op == "join":
        l, r = child(0), infer_columns(node.children[1], source_columns)
        if l is None or r is None:
            return None
        drop = set(node.params["on"] or ())
        return l + [c for c in r if c not in drop]
    if op == "map":
        u: alg.Udf = node.params["udf"]
        return list(u.out_cols) if u.out_cols is not None else None
    if op == "to_labels":
        base = child()
        if base is None:
            return None
        return [c for c in base if c != node.params["column"]]
    if op == "from_labels":
        base = child()
        if base is None:
            return None
        return [node.params["label"]] + base
    if op == "groupby":
        return list(node.params["keys"]) + [a[2] for a in node.params["aggs"]]
    return None  # transpose & anything else: dynamic


# -----------------------------------------------------------------------------
# node reconstruction
# -----------------------------------------------------------------------------
_CTORS: dict[str, Callable] = {}


def _ctor(op: str):
    def reg(fn):
        _CTORS[op] = fn
        return fn
    return reg


@_ctor("source")
def _(n, ch):
    return n


@_ctor("selection")
def _(n, ch):
    return alg.Selection(ch[0], n.params["predicate"])


@_ctor("projection")
def _(n, ch):
    return alg.Projection(ch[0], n.params["cols"])


@_ctor("union")
def _(n, ch):
    return alg.Union(ch[0], ch[1])


@_ctor("difference")
def _(n, ch):
    return alg.Difference(ch[0], ch[1])


@_ctor("join")
def _(n, ch):
    return alg.Join(ch[0], ch[1], on=n.params["on"], how=n.params["how"],
                    left_on=n.params["left_on"], right_on=n.params["right_on"])


@_ctor("drop_duplicates")
def _(n, ch):
    return alg.DropDuplicates(ch[0], n.params["subset"])


@_ctor("groupby")
def _(n, ch):
    return alg.GroupBy(ch[0], n.params["keys"], n.params["aggs"])


@_ctor("sort")
def _(n, ch):
    return alg.Sort(ch[0], n.params["by"], n.params["ascending"])


@_ctor("rename")
def _(n, ch):
    return alg.Rename(ch[0], dict(n.params["mapping"]))


@_ctor("window")
def _(n, ch):
    return alg.Window(ch[0], n.params["func"], n.params["cols"],
                      n.params["size"], n.params["periods"])


@_ctor("transpose")
def _(n, ch):
    return alg.Transpose(ch[0])


@_ctor("map")
def _(n, ch):
    return alg.Map(ch[0], n.params["udf"])


@_ctor("to_labels")
def _(n, ch):
    return alg.ToLabels(ch[0], n.params["column"])


@_ctor("from_labels")
def _(n, ch):
    return alg.FromLabels(ch[0], n.params["label"])


@_ctor("limit")
def _(n, ch):
    return alg.Limit(ch[0], n.params["k"], n.params["tail"])


@_ctor("column_sort")
def _(n, ch):
    return alg.ColumnSort(ch[0], n.params["by"], n.params["ascending"])


@_ctor("column_filter")
def _(n, ch):
    return alg.ColumnFilter(ch[0], n.params["predicate"])


@_ctor("fused_pipeline")
def _(n, ch):
    return alg.FusedPipeline(ch[0], n.params["stages"])


@_ctor("fused_groupby")
def _(n, ch):
    return alg.FusedGroupBy(ch[0], n.params["stages"], n.params["keys"],
                            n.params["aggs"], grid=n.params.get("grid"))


@_ctor("fused_sort")
def _(n, ch):
    return alg.FusedSort(ch[0], n.params["by"], n.params["ascending"],
                         n.params["stages"], grid=n.params.get("grid"))


@_ctor("fused_join")
def _(n, ch):
    return alg.FusedJoin(ch[0], ch[1], n.params["on"], n.params["how"],
                         n.params["left_on"], n.params["right_on"],
                         n.params["stages"], grid=n.params.get("grid"))


@_ctor("fused_window")
def _(n, ch):
    return alg.FusedWindow(ch[0], n.params["func"], n.params["cols"],
                           n.params["size"], n.params["periods"],
                           n.params["pre_stages"], n.params["post_stages"],
                           grid=n.params.get("grid"))


@_ctor("fused_drop_duplicates")
def _(n, ch):
    return alg.FusedDropDuplicates(ch[0], n.params["subset"],
                                   n.params["pre_stages"],
                                   n.params["post_stages"],
                                   grid=n.params.get("grid"))


@_ctor("fused_difference")
def _(n, ch):
    return alg.FusedDifference(ch[0], ch[1], n.params["pre_stages"],
                               n.params["right_pre_stages"],
                               n.params["post_stages"],
                               grid=n.params.get("grid"))


def rebuild(node: alg.Node, children: Sequence[alg.Node]) -> alg.Node:
    if tuple(children) == node.children:
        return node
    return _CTORS[node.op](node, list(children))


# -----------------------------------------------------------------------------
# the rules
# -----------------------------------------------------------------------------
def _and(p1: alg.Expr, p2: alg.Expr) -> alg.Expr:
    return alg.BinExpr("&", p1, p2)


def _rule_once(node: alg.Node, cols_of: Callable[[alg.Node], list | None]) -> alg.Node | None:
    """Try every rule at ``node``; return the rewritten node or None."""
    op = node.op
    ch = node.children

    # R1: TRANSPOSE∘TRANSPOSE → identity
    if op == "transpose" and ch[0].op == "transpose":
        return ch[0].children[0]

    # R2: TRANSPOSE∘SORT∘TRANSPOSE → COLUMN_SORT
    if op == "transpose" and ch[0].op == "sort" and ch[0].children[0].op == "transpose":
        inner = ch[0].children[0].children[0]
        return alg.ColumnSort(inner, ch[0].params["by"], ch[0].params["ascending"])

    # R3: TRANSPOSE∘SELECTION∘TRANSPOSE → COLUMN_FILTER (structured preds only)
    if (op == "transpose" and ch[0].op == "selection"
            and ch[0].children[0].op == "transpose"
            and isinstance(ch[0].params["predicate"], alg.Expr)):
        inner = ch[0].children[0].children[0]
        return alg.ColumnFilter(inner, ch[0].params["predicate"])

    # R4: fuse stacked selections (filters commute under ordered semantics)
    if (op == "selection" and ch[0].op == "selection"
            and isinstance(node.params["predicate"], alg.Expr)
            and isinstance(ch[0].params["predicate"], alg.Expr)):
        return alg.Selection(ch[0].children[0],
                             _and(node.params["predicate"], ch[0].params["predicate"]))

    # R5: push selection through union
    if op == "selection" and ch[0].op == "union":
        p = node.params["predicate"]
        u = ch[0]
        return alg.Union(alg.Selection(u.children[0], p), alg.Selection(u.children[1], p))

    # R6: push selection below an elementwise pass-through MAP
    if (op == "selection" and ch[0].op == "map"
            and isinstance(node.params["predicate"], alg.Expr)):
        u: alg.Udf = ch[0].params["udf"]
        pred: alg.Expr = node.params["predicate"]
        in_cols = cols_of(ch[0].children[0])
        out_cols = cols_of(ch[0])
        if (u.elementwise and in_cols is not None and out_cols is not None
                and pred.refs() <= (set(in_cols) & set(out_cols))
                and _passes_through(u, pred.refs())):
            return alg.Map(alg.Selection(ch[0].children[0], pred), u)

    # R7: selection(cross, l.a == r.b) → join  (paper §6.2)
    if (op == "selection" and ch[0].op == "join" and ch[0].params["on"] is None
            and ch[0].params["left_on"] is None and ch[0].params["how"] == "inner"):
        pred = node.params["predicate"]
        if (isinstance(pred, alg.BinExpr) and pred.op == "=="
                and isinstance(pred.left, alg.ColRef) and isinstance(pred.right, alg.ColRef)):
            l, r = ch[0].children
            lcols, rcols = cols_of(l), cols_of(r)
            if lcols is not None and rcols is not None:
                a, b = pred.left.name, pred.right.name
                if a in lcols and b in rcols and a not in rcols and b not in lcols:
                    return alg.Join(l, r, how="inner", left_on=[a], right_on=[b])
                if b in lcols and a in rcols and b not in rcols and a not in lcols:
                    return alg.Join(l, r, how="inner", left_on=[b], right_on=[a])

    # R8: fuse stacked elementwise MAPs (pipelining)
    if op == "map" and ch[0].op == "map":
        u2: alg.Udf = node.params["udf"]
        u1: alg.Udf = ch[0].params["udf"]
        if u1.elementwise and u2.elementwise:
            fused = _fuse_udfs(u1, u2)
            return alg.Map(ch[0].children[0], fused)

    # R9: collapse stacked projections
    if op == "projection" and ch[0].op == "projection":
        return alg.Projection(ch[0].children[0], node.params["cols"])

    # R10: collapse stacked limits (same direction)
    if op == "limit" and ch[0].op == "limit" and node.params["tail"] == ch[0].params["tail"]:
        return alg.Limit(ch[0].children[0],
                         min(node.params["k"], ch[0].params["k"]),
                         node.params["tail"])

    # R11: push head-LIMIT below row-local order-preserving unary ops
    if (op == "limit" and not node.params["tail"]
            and ch[0].op in ("map", "rename", "projection") and len(ch[0].children) == 1):
        u = ch[0]
        if u.op != "map" or u.params["udf"].elementwise:
            pushed = alg.Limit(u.children[0], node.params["k"], False)
            return rebuild(u, [pushed])

    return None


def _passes_through(u: alg.Udf, names) -> bool:
    """Best-effort: MAP passes a column through unchanged if it's declared in
    out_cols and not in deps (the udf never reads it, so by the elementwise
    contract it must be forwarding it)."""
    if u.out_cols is None:
        return False
    if u.deps is None:
        return False
    return all(n in u.out_cols and n not in u.deps for n in names)


def _fuse_udfs(u1: alg.Udf, u2: alg.Udf) -> alg.Udf:
    def fused(cols, frame):
        from .frame import Frame  # local import to avoid cycle at module load
        mid = u1.fn(cols, frame)
        if not isinstance(mid, Frame):
            from .labels import labels_from_values
            from .frame import Column
            import jax.numpy as jnp
            names, cs = [], []
            for name, v in mid.items():
                names.append(name)
                cs.append(v if isinstance(v, Column) else Column(jnp.asarray(v), _dom_of(v)))
            mid = Frame(cs, frame.row_labels, labels_from_values(names))
        cols2 = {n: c for n, c in zip(mid.col_labels.to_list(), mid.columns)}
        return u2.fn(cols2, mid)

    return alg.Udf(
        name=f"{u2.name}∘{u1.name}",
        fn=fused,
        deps=u1.deps,
        elementwise=True,
        out_cols=u2.out_cols,
        version=max(u1.version, u2.version),
    )


def _dom_of(v):
    import jax.numpy as jnp
    from .dtypes import Domain
    d = jnp.asarray(v).dtype
    if d == jnp.bool_:
        return Domain.BOOL
    if jnp.issubdtype(d, jnp.integer):
        return Domain.INT
    return Domain.FLOAT


# -----------------------------------------------------------------------------
# driver
# -----------------------------------------------------------------------------
def optimize(node: alg.Node, source_columns: Callable[[str], list | None] | None = None,
             max_passes: int = 10) -> alg.Node:
    """Bottom-up rewriting to a fixpoint."""
    src = source_columns or (lambda _fid: None)
    memo: dict = {}

    def cols_of(n: alg.Node):
        if n not in memo:
            memo[n] = infer_columns(n, src)
        return memo[n]

    def rewrite_tree(n: alg.Node) -> alg.Node:
        new_children = [rewrite_tree(c) for c in n.children]
        cur = rebuild(n, new_children)
        for _ in range(max_passes):
            nxt = _rule_once(cur, cols_of)
            if nxt is None:
                return cur
            cur = nxt
            # rule may expose new opportunities below; re-descend once
            cur = rebuild(cur, [rewrite_tree(c) for c in cur.children])
        return cur

    prev = None
    cur = node
    passes = 0
    while cur is not prev and passes < max_passes:
        prev = cur
        cur = rewrite_tree(cur)
        passes += 1
    return cur


# -----------------------------------------------------------------------------
# fusion pass (paper §5 pipelining; runs after rule rewriting, before physical)
# -----------------------------------------------------------------------------
@dataclasses.dataclass
class FusionStats:
    """What the fusion pass did to one plan — surfaced through ``ExecStats``
    so fused-vs-unfused benchmark wins are attributable.

    Counter semantics (one source of truth, asserted in tests and benches):
      * ``groups``          — FusedPipeline nodes in the *final* plan;
      * ``barrier_groups``  — barrier-fused nodes (FusedGroupBy/FusedSort/
                              FusedJoin/FusedWindow) in the final plan;
      * ``producer_ops``    — operator nodes absorbed as producer stages of a
                              barrier node (GROUPBY pre-aggregation sweep,
                              WINDOW pre_stages);
      * ``consumer_ops``    — operator nodes absorbed as consumer stages
                              (SORT/JOIN post-gather chain, WINDOW post_stages);
      * ``fused_ops``       — total operator nodes absorbed into *any* fused
                              construct.  Invariant::

                                fused_ops == pipeline_ops + producer_ops
                                             + consumer_ops

                              where ``pipeline_ops`` is the stage count of the
                              surviving FusedPipeline groups.
    """

    groups: int = 0          # FusedPipeline nodes in the final plan
    fused_ops: int = 0       # operator nodes absorbed into any fused construct
    barrier_groups: int = 0  # barrier-fused nodes in the final plan
    producer_ops: int = 0    # stages absorbed on the producer side of a barrier
    consumer_ops: int = 0    # stages absorbed on the consumer side of a barrier


def fuse_pipelines(node: alg.Node,
                   history: "frozenset | set | None" = None) -> tuple[alg.Node, FusionStats]:
    """Collapse maximal chains of row-local operators into ``FusedPipeline``
    nodes, then fuse the surviving chains *through* blocking-operator
    boundaries (barrier pass) — see the module docstring for the barrier
    rules.

    Only chains of **two or more** operators fuse into a FusedPipeline — a
    lone SELECTION keeps its own node (and cache identity), so single-statement
    plans are unchanged and sub-plan reuse across queries still hits the
    cache.  (A lone row-local op *adjacent to a blocking operator* is still
    absorbed by the barrier pass: there the win is a saved materialization,
    not just a saved dispatch.)  A fused group gets one cache entry keyed on
    the whole chain instead of one per node.

    A sub-plan referenced by more than one parent **within** the plan is a
    fusion barrier: absorbing it into each branch's chain would re-execute the
    shared work per branch, where the per-node path evaluates it once and
    serves the other branches from the cache.

    ``history`` (MQO-aware fusion boundaries, paper §6.2.1): structural cache
    keys of *prior session statements whose results are live* (materialized
    or in flight — the executor filters; see ``Executor.note_statement``).  A
    chain never descends through — and the barrier pass never absorbs — a
    node whose key is in the history: the sub-plan keeps its own identity, is
    re-fused exactly as the prior statement was (fusion is deterministic),
    and therefore re-produces the prior statement's cache key, so the
    materialization cache serves the shared prefix instead of re-executing it
    inside a bigger fused group.
    """
    stats = FusionStats()
    history = history or frozenset()

    # structural reference counts: how many parent edges point at each
    # (structurally-identified) sub-plan — shared nodes must keep their own
    # node/cache identity, so chains may not absorb them mid-run
    refs: dict[alg.Node, int] = {}
    for n in node.walk():
        for c in n.children:
            refs[c] = refs.get(c, 0) + 1

    memo: dict[alg.Node, alg.Node] = {}

    def visit(n: alg.Node) -> alg.Node:
        hit = memo.get(n)
        if hit is not None:
            return hit
        out = None
        if alg.fusible(n):
            chain = [n]                      # top-down collection
            tail = n.children[0]
            while (alg.fusible(tail) and refs.get(tail, 0) <= 1
                   and tail.cache_key() not in history):
                chain.append(tail)
                tail = tail.children[0]
            if len(chain) >= 2:
                stats.groups += 1
                stats.fused_ops += len(chain)
                stages = tuple(alg.Stage(m.op, m.params) for m in reversed(chain))
                out = alg.FusedPipeline(visit(tail), stages)
        if out is None:
            out = rebuild(n, [visit(c) for c in n.children])
        memo[n] = out
        return out

    fused = visit(node)
    return _fuse_barriers(fused, stats, history), stats


# -----------------------------------------------------------------------------
# barrier pass: fuse row-local chains THROUGH blocking operators
# -----------------------------------------------------------------------------
def _chain_stages(n: alg.Node) -> tuple | None:
    """The absorbable stage tuple of ``n``: a FusedPipeline's stages, or a
    single-op tuple for a lone fusible operator.  None ⇒ not absorbable."""
    if n.op == "fused_pipeline":
        return n.params["stages"]
    if alg.fusible(n):
        return (alg.Stage(n.op, n.params),)
    return None


def _fuse_barriers(node: alg.Node, stats: FusionStats, history) -> alg.Node:
    """Bottom-up pattern match over the chain-fused plan:

      * GROUPBY(chain)           → FusedGroupBy     (producer fusion)
      * chain(SORT) / chain(JOIN) → FusedSort/Join  (consumer fusion)
      * chain?(WINDOW(chain?))   → FusedWindow      (pre/post stage fusion)
      * chain?(DROPDUP(chain?))  → FusedDropDuplicates  (pre/post fusion)
      * chain?(DIFFERENCE(chain?, chain?)) → FusedDifference (both inputs'
        producer chains + the consumer chain)

    A "chain" is a FusedPipeline or a lone fusible op.  Absorption respects
    the same sharing barriers as chain fusion: a node referenced twice within
    the plan, or present in the session statement history, keeps its identity.
    """
    refs: dict[alg.Node, int] = {}
    for n in node.walk():
        for c in n.children:
            refs[c] = refs.get(c, 0) + 1

    def absorbable(n: alg.Node) -> tuple | None:
        if refs.get(n, 0) > 1 or n.cache_key() in history:
            return None
        return _chain_stages(n)

    def on_absorb(n: alg.Node, side: str, count: int) -> None:
        if n.op == "fused_pipeline":      # chain group dissolves into barrier
            stats.groups -= 1
            stats.fused_ops -= count      # re-attributed below
        stats.fused_ops += count
        if side == "producer":
            stats.producer_ops += count
        else:
            stats.consumer_ops += count

    memo: dict[alg.Node, alg.Node] = {}

    def visit(n: alg.Node) -> alg.Node:
        hit = memo.get(n)
        if hit is not None:
            return hit
        out = rebuild(n, [visit(c) for c in n.children])

        # producer fusion into GROUPBY: the row-local sweep joins the
        # per-block partial-aggregation program
        if out.op == "groupby":
            stages = absorbable(out.children[0])
            if stages:
                child = out.children[0]
                grand = child.children[0]
                on_absorb(child, "producer", len(stages))
                stats.barrier_groups += 1
                out = alg.FusedGroupBy(grand, stages, out.params["keys"],
                                       out.params["aggs"], grid=GRID_PREFS["fused_groupby"])

        # producer fusion into DROP-DUPLICATES: the row-local sweep joins the
        # per-block key-extraction program
        elif out.op == "drop_duplicates":
            stages = absorbable(out.children[0])
            if stages:
                child = out.children[0]
                on_absorb(child, "producer", len(stages))
                stats.barrier_groups += 1
                out = alg.FusedDropDuplicates(
                    child.children[0], out.params["subset"], stages, (),
                    grid=GRID_PREFS["fused_drop_duplicates"])

        # producer fusion into DIFFERENCE: either input's row-local chain
        # joins that side's per-block key-extraction program
        elif out.op == "difference":
            sl = absorbable(out.children[0])
            sr = absorbable(out.children[1])
            if sl or sr:
                l, r = out.children
                if sl:
                    on_absorb(l, "producer", len(sl))
                    l = l.children[0]
                if sr:
                    on_absorb(r, "producer", len(sr))
                    r = r.children[0]
                stats.barrier_groups += 1
                out = alg.FusedDifference(l, r, sl or (), sr or (), (),
                                          grid=GRID_PREFS["fused_difference"])

        # producer fusion into WINDOW (no consumer chain above — the
        # consumer-side variant is handled from the chain node below)
        elif out.op == "window":
            stages = absorbable(out.children[0])
            if stages:
                child = out.children[0]
                on_absorb(child, "producer", len(stages))
                stats.barrier_groups += 1
                out = alg.FusedWindow(child.children[0], out.params["func"],
                                      out.params["cols"], out.params["size"],
                                      out.params["periods"], stages, (),
                                      grid=GRID_PREFS["fused_window"])

        # consumer fusion: a chain sitting on a SORT/JOIN/WINDOW
        chain_stages = _chain_stages(out)
        if chain_stages:
            below = out.children[0]
            if refs.get(below, 0) <= 1 and below.cache_key() not in history:
                if below.op == "sort":
                    on_absorb(out, "consumer", len(chain_stages))
                    stats.barrier_groups += 1
                    out = alg.FusedSort(below.children[0], below.params["by"],
                                        below.params["ascending"], chain_stages,
                                        grid=GRID_PREFS["fused_sort"])
                elif below.op == "join":
                    on_absorb(out, "consumer", len(chain_stages))
                    stats.barrier_groups += 1
                    out = alg.FusedJoin(below.children[0], below.children[1],
                                        below.params["on"], below.params["how"],
                                        below.params["left_on"],
                                        below.params["right_on"], chain_stages,
                                        grid=GRID_PREFS["fused_join"])
                elif below.op == "window":
                    # (an absorbable pre-chain would already have turned this
                    # child into a fused_window in its own visit — see below)
                    on_absorb(out, "consumer", len(chain_stages))
                    stats.barrier_groups += 1
                    out = alg.FusedWindow(below.children[0], below.params["func"],
                                          below.params["cols"],
                                          below.params["size"],
                                          below.params["periods"],
                                          (), chain_stages,
                                          grid=GRID_PREFS["fused_window"])
                elif below.op == "fused_window" and not below.params["post_stages"]:
                    # window already producer-fused on the way up: attach the
                    # consumer chain as its post stages
                    on_absorb(out, "consumer", len(chain_stages))
                    out = alg.FusedWindow(below.children[0],
                                          below.params["func"],
                                          below.params["cols"],
                                          below.params["size"],
                                          below.params["periods"],
                                          below.params["pre_stages"],
                                          chain_stages,
                                          grid=below.params.get("grid")
                                          or GRID_PREFS["fused_window"])
                elif below.op == "drop_duplicates":
                    on_absorb(out, "consumer", len(chain_stages))
                    stats.barrier_groups += 1
                    out = alg.FusedDropDuplicates(
                        below.children[0], below.params["subset"], (),
                        chain_stages,
                        grid=GRID_PREFS["fused_drop_duplicates"])
                elif below.op == "difference":
                    on_absorb(out, "consumer", len(chain_stages))
                    stats.barrier_groups += 1
                    out = alg.FusedDifference(
                        below.children[0], below.children[1], (), (),
                        chain_stages, grid=GRID_PREFS["fused_difference"])
                elif (below.op == "fused_drop_duplicates"
                      and not below.params["post_stages"]):
                    # dedup already producer-fused on the way up: attach the
                    # consumer chain as its post stages
                    on_absorb(out, "consumer", len(chain_stages))
                    out = alg.FusedDropDuplicates(
                        below.children[0], below.params["subset"],
                        below.params["pre_stages"], chain_stages,
                        grid=below.params.get("grid")
                        or GRID_PREFS["fused_drop_duplicates"])
                elif (below.op == "fused_difference"
                      and not below.params["post_stages"]):
                    on_absorb(out, "consumer", len(chain_stages))
                    out = alg.FusedDifference(
                        below.children[0], below.children[1],
                        below.params["pre_stages"],
                        below.params["right_pre_stages"], chain_stages,
                        grid=below.params.get("grid")
                        or GRID_PREFS["fused_difference"])
        if out is not n:
            # a rebuilt node inherits the original's parent-edge count, so a
            # shared sub-plan stays unabsorbable after its subtree changed
            refs[out] = refs.get(out, 0) + refs.get(n, 0)
        memo[n] = out
        return out

    return visit(node)
