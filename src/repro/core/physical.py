"""Physical operators: dataframe algebra over partitioned frames (paper §4).

Each logical operator picks a partitioning scheme per the paper's §4.2 table:

  MAP / SELECTION / RENAME      → embarrassingly parallel, any partitioning
  GROUPBY(n)                    → row-parallel partial aggregation (MXU
                                  segment_reduce) + small combine — the
                                  shuffle-free plan the paper motivates
  GROUPBY(1)                    → same with G = 1 (pure reduction)
  WINDOW                        → blocked scan with cross-block carry
                                  composition (order-exact, still parallel)
  TRANSPOSE                     → per-block kernel transpose + grid swap
  SORT / JOIN / DIFFERENCE / DROP-DUPLICATES → blocking; key extraction is
                                  device-side, index building host-side
                                  (numpy), payload gathers device-side.

The same operator bodies double as the shard_map shard-level programs for the
TPU mesh (see ``launch/dryrun.py`` — the pipeline dry-run lowers MAP/GROUPBY/
WINDOW over the production mesh with psums standing in for the combines).

Fused pipelines (paper §5 "Pipelining")
---------------------------------------
``FUSED_PIPELINE`` executes a whole chain of row-local operators (elementwise
MAP, SELECTION, PROJECTION, RENAME) as **one** per-row-partition program on
the shared pool: a single sweep over each block with column values staying on
device between stages, no intermediate ``PartitionedFrame``s, and one pool
dispatch for the whole chain instead of one per operator.  Runs of
consecutive structured-``Expr`` selections additionally collapse into a
single jit-compiled mask program (one XLA executable per predicate chain,
cached across blocks), so a k-predicate chain costs one device dispatch and
one filter instead of k of each.
"""
from __future__ import annotations

import functools
import threading
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import algebra as alg
from .dtypes import Domain, common_storage, parse_column, storage_dtype
from .frame import Column, Frame
from .labels import CodedLabels, IntLabels, Labels, RangeLabels, labels_from_values
from .partition import PartitionedFrame, get_pool
from ..kernels import ops as kops

__all__ = ["run_node", "eval_expr", "NULL_CODE"]

NULL_CODE = -1


# =============================================================================
# Expression evaluation (structured predicates / scalar exprs)
# =============================================================================
def _col_values(frame: Frame, name: Any) -> tuple[jnp.ndarray, jnp.ndarray, Column]:
    c = frame.col(name)
    return c.data, c.valid_mask(), c


def _eval_expr_core(expr: alg.Expr, getcol: Callable, nrows: int,
                    bin_hook: Callable | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The one expression interpreter, shared by the interpreted per-frame
    path (``eval_expr``) and the jit-traced fused-predicate path
    (``_eval_expr_env``) so the two can never diverge.

    ``getcol(name) → (values, mask)``; ``bin_hook(BinExpr) → result | None``
    lets the frame path intercept coded-column comparisons (host code-table
    translation that cannot run under jit)."""
    if isinstance(expr, alg.ColRef):
        return getcol(expr.name)
    if isinstance(expr, alg.Lit):
        return jnp.full((nrows,), expr.value), jnp.ones((nrows,), jnp.bool_)
    if isinstance(expr, alg.UnaryExpr):
        v, mask = _eval_expr_core(expr.operand, getcol, nrows, bin_hook)
        if expr.op == "~":
            return ~v.astype(jnp.bool_), mask
        if expr.op == "isna":
            return ~mask, jnp.ones_like(mask)
        if expr.op == "notna":
            return mask, jnp.ones_like(mask)
        raise ValueError(expr.op)
    if isinstance(expr, alg.BinExpr):
        if bin_hook is not None:
            hit = bin_hook(expr)
            if hit is not None:
                return hit
        lv, lm = _eval_expr_core(expr.left, getcol, nrows, bin_hook)
        rv, rm = _eval_expr_core(expr.right, getcol, nrows, bin_hook)
        return _bin_numeric(expr.op, lv, lm, rv, rm)
    raise TypeError(expr)


def eval_expr(expr: alg.Expr, frame: Frame) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorized evaluation → (values, valid_mask) device arrays."""
    def getcol(name):
        data, mask, _ = _col_values(frame, name)
        return data, mask

    def bin_hook(e: alg.BinExpr):
        # coded-column vs literal comparisons translate to code-space
        if isinstance(e.left, alg.ColRef) and isinstance(e.right, alg.Lit):
            c = frame.col(e.left.name)
            if c.domain.is_coded and e.op in ("==", "!="):
                code = _lit_to_code(c, e.right.value)
                v = c.data == code if e.op == "==" else c.data != code
                return v, c.valid_mask()
        return None

    return _eval_expr_core(expr, getcol, frame.nrows, bin_hook)


def _lit_to_code(column: Column, value: Any) -> int:
    table = column.dictionary or ()
    key = str(value)
    return table.index(key) if key in table else -2  # -2 never matches


def _bin_numeric(op: str, lv, lm, rv, rm) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Binary op over (values, mask) pairs.  int⊕int stays in integer dtypes
    for ``+ - * % //`` and comparisons — a float32 round-trip corrupts values
    above 2²⁴ (int32 storage holds up to 2³¹−1).  Like numpy/pandas integer
    dtypes, ``+ - *`` wrap on int32 overflow; ``% //`` by zero yield null."""
    mask = lm & rm
    if op in ("&", "|"):
        lb, rb = lv.astype(jnp.bool_), rv.astype(jnp.bool_)
        return (lb & rb if op == "&" else lb | rb), mask
    both_int = (jnp.issubdtype(lv.dtype, jnp.integer)
                and jnp.issubdtype(rv.dtype, jnp.integer))
    if op in ("+", "-", "*", "%", "//") and both_int:
        if op in ("%", "//"):
            # int division by 0 is XLA-defined garbage (unlike float inf/nan):
            # mark those rows null instead of surfacing a plausible integer
            mask = mask & (rv != 0)
        out = {"+": lv + rv, "-": lv - rv, "*": lv * rv,
               "%": jnp.mod(lv, rv), "//": jnp.floor_divide(lv, rv)}[op]
        return out, mask
    if op in ("+", "-", "*", "/", "%", "//"):
        lf, rf = lv.astype(jnp.float32), rv.astype(jnp.float32)
        out = {"+": lf + rf, "-": lf - rf, "*": lf * rf, "/": lf / rf,
               "%": jnp.mod(lf, rf), "//": jnp.floor_divide(lf, rf)}[op]
        return out, mask
    if both_int:
        lf, rf = lv, rv
    else:
        lf, rf = lv.astype(jnp.float32), rv.astype(jnp.float32)
    out = {
        "==": lf == rf, "!=": lf != rf, "<": lf < rf,
        "<=": lf <= rf, ">": lf > rf, ">=": lf >= rf,
    }[op]
    return out, mask


def _predicate_mask(frame: Frame, predicate) -> np.ndarray:
    if isinstance(predicate, alg.Udf):
        out = predicate.fn({n: c for n, c in zip(frame.col_labels.to_list(), frame.columns)}, frame)
        return np.asarray(out, dtype=bool)
    v, mask = eval_expr(predicate, frame)
    return np.asarray(v.astype(jnp.bool_) & mask)  # null comparisons → False


# =============================================================================
# Per-operator physical implementations
# =============================================================================
def _selection(pf: PartitionedFrame, predicate) -> PartitionedFrame:
    if pf.col_parts == 1:
        return pf.map_blockwise(lambda f: f.filter_rows(_predicate_mask(f, predicate)))
    # predicate may span column blocks: evaluate per row-stripe, filter blocks
    def stripe(i: int) -> list[Frame]:
        full = pf.parts[i][0]
        for j in range(1, pf.col_parts):
            full = full.concat_cols(pf.parts[i][j])
        keep = _predicate_mask(full, predicate)
        return [blk.filter_rows(keep) for blk in pf.parts[i]]
    rows = list(get_pool().map(stripe, range(pf.row_parts)))
    return PartitionedFrame(rows)


def _project_block(frame: Frame, cols: Sequence[Any]) -> Frame:
    return frame.take_cols(frame.col_labels.positions_of(cols))


def _projection(pf: PartitionedFrame, cols: Sequence[Any]) -> PartitionedFrame:
    f = pf.repartition(col_parts=1)
    return f.map_blockwise(lambda frame: _project_block(frame, cols))


def _union(left: PartitionedFrame, right: PartitionedFrame) -> PartitionedFrame:
    l = left.repartition(col_parts=1)
    r = right.repartition(col_parts=1)
    return PartitionedFrame(l.parts + r.parts)


_HASH_MASK = (1 << 52) - 1  # exactly-representable ints in float64


def _fnv64(s: str) -> int:
    h = 0xCBF29CE484222325
    for ch in s.encode():
        h ^= ch
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def _row_keys(frame: Frame, subset: Sequence[Any] | None) -> np.ndarray:
    """Normalized per-row key matrix (host) for equality (dedup / difference /
    join / groupby).  Coded (Σ*) columns map through a *value* hash so keys
    compare correctly across frames with different dictionaries; numerics are
    their float64 values; nulls are NaN (never equal a valid key)."""
    cols = frame.columns if subset is None else [frame.col(n) for n in subset]
    mats = []
    for c in cols:
        if c.domain.is_coded:
            table = c.dictionary or ()
            lut = np.asarray([float(_fnv64(str(v)) & _HASH_MASK) for v in table]
                             or [0.0], dtype=np.float64)
            codes = np.asarray(c.data)
            v = lut[np.clip(codes, 0, len(lut) - 1)]
            v = np.where(codes >= 0, v, np.nan)
        else:
            v = np.asarray(c.data, dtype=np.float64)
        if c.mask is not None:
            v = np.where(np.asarray(c.mask), v, np.nan)
        mats.append(v)
    return np.stack(mats, axis=1) if mats else np.zeros((frame.nrows, 0))


def _sort_rank_keys(frame: Frame, subset: Sequence[Any]) -> list[np.ndarray]:
    """Per-column sort keys: lexicographic rank for coded columns, values for
    numerics (ordering, unlike equality, needs real value order)."""
    out = []
    for name in subset:
        c = frame.col(name)
        if c.domain.is_coded:
            table = list(c.dictionary or ())
            rank = np.empty(max(len(table), 1), dtype=np.float64)
            for r, idx in enumerate(sorted(range(len(table)), key=lambda i: str(table[i]))):
                rank[idx] = r
            codes = np.asarray(c.data)
            v = rank[np.clip(codes, 0, len(table) - 1 if table else 0)]
            v = np.where(codes >= 0, v, np.nan)
        else:
            v = np.asarray(c.data, dtype=np.float64)
        if c.mask is not None:
            v = np.where(np.asarray(c.mask), v, np.nan)
        out.append(v)
    return out


def _keys_to_ids(*key_mats: np.ndarray) -> list[np.ndarray]:
    """Jointly factorize row-key matrices → dense ids (NaN-safe)."""
    all_rows = np.concatenate(key_mats, axis=0)
    # use bit-view so NaN == NaN for grouping purposes
    view = all_rows.view(np.int64).reshape(all_rows.shape)
    if view.shape[1] == 1:
        # single-key fast path: 1-D unique (axis=0 unique void-sorts, ~30×
        # slower — this is the groupby(n) hot path)
        _, inv = np.unique(view[:, 0], return_inverse=True)
    else:
        _, inv = np.unique(view, axis=0, return_inverse=True)
    out, off = [], 0
    for m in key_mats:
        out.append(inv[off:off + m.shape[0]].astype(np.int64))
        off += m.shape[0]
    return out


def _difference(left: PartitionedFrame, right: PartitionedFrame) -> PartitionedFrame:
    lf, rf = left.to_frame(), right.to_frame()
    lids, rids = _keys_to_ids(_row_keys(lf, None), _row_keys(rf, None))
    keep = ~np.isin(lids, np.unique(rids))
    return PartitionedFrame.from_frame(lf.filter_rows(keep))


def _drop_duplicates(pf: PartitionedFrame, subset) -> PartitionedFrame:
    f = pf.to_frame()
    ids = _keys_to_ids(_row_keys(f, subset))[0]
    _, first = np.unique(ids, return_index=True)
    keep = np.zeros(f.nrows, dtype=bool)
    keep[first] = True
    return PartitionedFrame.from_frame(f.filter_rows(keep))


# ---- JOIN -------------------------------------------------------------------
def _join(left: PartitionedFrame, right: PartitionedFrame, params: dict) -> PartitionedFrame:
    lf, rf = left.to_frame().induce(), right.to_frame().induce()
    how = params["how"]
    on = params["on"]
    left_on = params["left_on"] or on
    right_on = params["right_on"] or on

    if left_on is None:  # CROSS-PRODUCT: nested order, left outer (Table 1 †)
        ml, mr = lf.nrows, rf.nrows
        lidx = np.repeat(np.arange(ml), mr)
        ridx = np.tile(np.arange(mr), ml)
        out = _assemble_join(lf, rf, lidx, ridx, None, None, drop_right=())
        return PartitionedFrame.from_frame(out)

    lids, rids = _keys_to_ids(_row_keys(lf, left_on), _row_keys(rf, right_on))
    groups: dict[int, list[int]] = {}
    for pos, gid in enumerate(rids):
        groups.setdefault(int(gid), []).append(pos)

    lidx_l, ridx_l, lnull, rnull = [], [], [], []
    for i, gid in enumerate(lids):
        match = groups.get(int(gid))
        if match:
            for r in match:          # right order breaks ties (Table 1 †)
                lidx_l.append(i)
                ridx_l.append(r)
                rnull.append(True)
        elif how in ("left", "outer"):
            lidx_l.append(i)
            ridx_l.append(0)
            rnull.append(False)
    if how in ("right", "outer"):
        lseen = set(np.unique(lids).tolist())
        for r, gid in enumerate(rids):
            if int(gid) not in lseen:
                lidx_l.append(0)
                lnull.append(len(lidx_l) - 1)
                ridx_l.append(r)
                rnull.append(True)
    lidx = np.asarray(lidx_l, dtype=np.int64)
    ridx = np.asarray(ridx_l, dtype=np.int64)
    rvalid = np.asarray(rnull, dtype=bool)
    lvalid = np.ones(len(lidx), dtype=bool)
    lvalid[np.asarray(lnull, dtype=np.int64)] = False

    drop_right = tuple(right_on) if on is not None else ()
    out = _assemble_join(lf, rf, lidx, ridx, lvalid, rvalid, drop_right)
    return PartitionedFrame.from_frame(out)


def _assemble_join(lf: Frame, rf: Frame, lidx, ridx, lvalid, rvalid, drop_right) -> Frame:
    lpart = lf.take_rows(lidx)
    keep_r = [j for j, n in enumerate(rf.col_labels.to_list()) if n not in drop_right]
    rpart = rf.take_cols(keep_r).take_rows(ridx)
    lpart = _mask_all(lpart, lvalid)
    rpart = _mask_all(rpart, rvalid)
    out = lpart.concat_cols(rpart)
    return Frame(out.columns, RangeLabels(out.nrows), out.col_labels)  # reset index


def _mask_all(frame: Frame, valid: np.ndarray | None) -> Frame:
    if valid is None or valid.all():
        return frame
    vmask = jnp.asarray(valid)
    cols = [Column(c.data, c.domain, c.valid_mask() & vmask, c.dictionary) for c in frame.columns]
    return Frame(cols, frame.row_labels, frame.col_labels, frame.row_domains)


# ---- GROUPBY ----------------------------------------------------------------
_COMBINE = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


def _groupby(pf: PartitionedFrame, keys: Sequence[Any], aggs: Sequence[tuple]) -> PartitionedFrame:
    """Row-parallel partial aggregation + tree combine (paper §4.2 Fig. 6).

    groupby(1) is ``keys == ()``: all rows fall into segment 0 and the combine
    is a pure reduction (any partitioning scheme works — paper's point).
    """
    pf = pf.repartition(col_parts=1)
    row_blocks = [row[0].induce() for row in pf.parts]

    # ---- dense small-range INT key: no host factorization ------------------
    # (paper's groupby(n) benchmark shape: "passenger_count"-like keys).
    # codes = v - min, computed per block in parallel; empty groups dropped
    # after the combine.  Avoids the serial np.unique Amdahl term.
    dense = _dense_int_key(row_blocks, keys) if len(keys) == 1 else None
    if dense is not None:
        vmin, G = dense
        codes_per_block = []
        for b in row_blocks:
            c = b.col(keys[0])
            codes = np.asarray(c.data, dtype=np.int64) - vmin
            if c.mask is not None:
                codes = np.where(np.asarray(c.mask), codes, -1)
            codes_per_block.append(codes.astype(np.int32))
        return _groupby_with_codes(row_blocks, keys, aggs, codes_per_block,
                                   int(G), key_values=[int(vmin) + i for i in range(int(G))],
                                   drop_empty=True)

    # ---- global key factorization (one column set to host) -----------------
    if keys:
        key_mats = [_row_keys(b, keys) for b in row_blocks]
        ids_per_block = _keys_to_ids(*key_mats)
        all_ids = np.concatenate(ids_per_block)
        all_keys = np.concatenate(key_mats, axis=0)
        valid_rows = ~np.isnan(all_keys).any(axis=1)  # pandas drops null keys
        valid_idx = np.nonzero(valid_rows)[0]
        uniq_ids, first = np.unique(all_ids[valid_rows], return_index=True)
        first_global = valid_idx[first]
        # decode representative key VALUES (O(G·K) single lookups) so output
        # groups sort lexicographically by value, not by hash/code
        offsets = np.cumsum([0] + [b.nrows for b in row_blocks])
        def decode_row(gidx: int) -> tuple:
            bi = int(np.searchsorted(offsets, gidx, side="right") - 1)
            local = int(gidx - offsets[bi])
            return tuple(row_blocks[bi].col(k).value_at(local) for k in keys)
        rep_vals = [decode_row(int(gi)) for gi in first_global]
        perm = sorted(range(len(rep_vals)), key=lambda i: tuple(
            (str(type(v)), v) if not isinstance(v, (int, float, bool)) else ("num", v)
            for v in rep_vals[i]))
        order = uniq_ids[np.asarray(perm, dtype=np.int64)] if len(perm) else uniq_ids
        rep_sorted = [rep_vals[i] for i in perm]
        G = len(order)
        n_ids = int(all_ids.max()) + 1 if all_ids.size else 0
        remap = np.full(n_ids, NULL_CODE, dtype=np.int32)
        remap[order] = np.arange(G, dtype=np.int32)
        codes_per_block = [remap[ids] if ids.size else ids.astype(np.int32)
                           for ids in ids_per_block]
    else:
        G = 1
        rep_sorted = None
        codes_per_block = [np.zeros(b.nrows, dtype=np.int32) for b in row_blocks]
    return _groupby_with_codes(row_blocks, keys, aggs, codes_per_block, G,
                               rep_sorted=rep_sorted)


def _dense_int_key(row_blocks: list[Frame], keys) -> tuple[int, int] | None:
    """(vmin, G) when the single key column is INT with a small value range —
    codes are then ``v - vmin`` with no host factorization."""
    try:
        cols = [b.col(keys[0]) for b in row_blocks]
    except KeyError:
        return None
    if any(c.domain is not Domain.INT for c in cols):
        return None
    vmin, vmax = None, None
    for c in cols:
        v = np.asarray(c.data, dtype=np.int64)
        if c.mask is not None:
            mask = np.asarray(c.mask)
            if not mask.any():
                continue
            v = v[mask]
        if v.size == 0:
            continue
        lo, hi = int(v.min()), int(v.max())
        vmin = lo if vmin is None else min(vmin, lo)
        vmax = hi if vmax is None else max(vmax, hi)
    if vmin is None:
        return None
    g = vmax - vmin + 1
    if g > 65536:
        return None
    return vmin, g


def _groupby_with_codes(row_blocks: list[Frame], keys, aggs, codes_per_block,
                        G: int, rep_sorted=None, key_values=None,
                        drop_empty: bool = False) -> PartitionedFrame:
    # ---- per-block partials (parallel; MXU segment_reduce) ------------------
    need: list[tuple[Any, str]] = []
    for col_label, func, _ in aggs:
        for base in _bases_for(func):
            if (col_label, base) not in need:
                need.append((col_label, base))
    need_main = tuple(need)

    def block_partial(args) -> dict:
        block, codes = args
        codes_dev = jnp.asarray(codes)
        out = {}
        if drop_empty:
            # group presence = #rows with a valid key code (independent of
            # value nulls) so empty dense-range slots drop after the combine
            ones = jnp.ones(block.nrows, jnp.float32)
            out[("__presence__", "sum")] = kops.segment_reduce(
                ones, codes_dev, G, "sum")
        for col_label, base in need_main:
            c = block.col(col_label)
            v = c.data.astype(jnp.float32)
            valid = c.valid_mask()
            if base == "count":
                out[(col_label, base)] = kops.segment_reduce(
                    valid.astype(jnp.float32), codes_dev, G, "sum")
            elif base == "sum":
                out[(col_label, base)] = kops.segment_reduce(
                    jnp.where(valid, v, 0.0), codes_dev, G, "sum")
            elif base == "sumsq":
                out[(col_label, base)] = kops.segment_reduce(
                    jnp.where(valid, v * v, 0.0), codes_dev, G, "sum")
            elif base == "min":
                out[(col_label, base)] = kops.segment_reduce(
                    jnp.where(valid, v, jnp.finfo(jnp.float32).max), codes_dev, G, "min")
            elif base == "max":
                out[(col_label, base)] = kops.segment_reduce(
                    jnp.where(valid, v, jnp.finfo(jnp.float32).min), codes_dev, G, "max")
        return out

    if drop_empty:
        need.append(("__presence__", "sum"))

    partials = list(get_pool().map(block_partial, list(zip(row_blocks, codes_per_block))))

    # ---- combine (G-sized, tiny vs data) ------------------------------------
    combined: dict[tuple, jnp.ndarray] = {}
    for key in need:
        base = key[1]
        parts = [p[key] for p in partials]
        acc = parts[0]
        for nxt in parts[1:]:
            if base in ("sum", "count", "sumsq"):
                acc = acc + nxt
            elif base == "min":
                acc = jnp.minimum(acc, nxt)
            else:
                acc = jnp.maximum(acc, nxt)
        combined[key] = acc

    # ---- finalize -----------------------------------------------------------
    out_cols: list[Column] = []
    out_names: list[Any] = []
    # key columns first (representative decoded values, sorted order)
    if keys and key_values is not None:      # dense-int fast path
        out_cols.append(_host_column(list(key_values), Domain.INT))
        out_names.append(keys[0])
    elif keys:
        template = row_blocks[0]
        for kpos, kname in enumerate(keys):
            src = template.col(kname)
            vals = [r[kpos] for r in rep_sorted]
            dom = src.domain if src.domain is not Domain.UNSPECIFIED else None
            out_cols.append(_host_column(vals, dom))
            out_names.append(kname)
    for col_label, func, out_label in aggs:
        cnt = combined.get((col_label, "count"))
        if func == "count":
            vals = cnt
        elif func == "sum":
            vals = combined[(col_label, "sum")]
        elif func == "mean":
            vals = combined[(col_label, "sum")] / jnp.maximum(cnt, 1.0)
        elif func in ("min", "max"):
            vals = combined[(col_label, func)]
        elif func in ("var", "std"):
            s, ss = combined[(col_label, "sum")], combined[(col_label, "sumsq")]
            var = (ss - s * s / jnp.maximum(cnt, 1.0)) / jnp.maximum(cnt - 1.0, 1.0)
            vals = jnp.sqrt(jnp.maximum(var, 0.0)) if func == "std" else var
        elif func == "any":
            vals = (combined[(col_label, "max")] > 0).astype(jnp.float32)
        elif func == "all":
            vals = (combined[(col_label, "min")] > 0).astype(jnp.float32)
        else:
            raise ValueError(func)
        mask = cnt > 0 if cnt is not None else None
        dom = Domain.INT if func == "count" else (Domain.BOOL if func in ("any", "all") else Domain.FLOAT)
        data = vals.astype(storage_dtype(dom))
        out_cols.append(Column(data, dom, mask if func != "count" else None, None))
        out_names.append(out_label)

    frame = Frame(out_cols, RangeLabels(G), labels_from_values(out_names))
    if drop_empty:
        present = np.asarray(combined[("__presence__", "sum")]) > 0
        frame = frame.filter_rows(present)
    return PartitionedFrame.from_frame(frame)


def _bases_for(func: str) -> tuple[str, ...]:
    return {
        "sum": ("sum", "count"), "count": ("count",), "mean": ("sum", "count"),
        "min": ("min", "count"), "max": ("max", "count"),
        "var": ("sum", "sumsq", "count"), "std": ("sum", "sumsq", "count"),
        "any": ("max", "count"), "all": ("min", "count"),
    }[func]


def _host_column(values: list, domain: Domain) -> Column:
    p = parse_column(values, domain)
    return Column(p.data, p.domain, p.mask, p.dictionary)


# ---- SORT ---------------------------------------------------------------
def _sort(pf: PartitionedFrame, by: Sequence[Any], ascending: bool) -> PartitionedFrame:
    f = pf.to_frame().induce()
    key_cols = []
    for v in _sort_rank_keys(f, by):
        # nulls (NaN) sort last regardless of direction
        v = np.where(np.isnan(v), np.inf if ascending else -np.inf, v)
        key_cols.append(v)
    if ascending:
        idx = np.lexsort(tuple(reversed(key_cols)))   # stable; first key primary
    else:
        idx = np.lexsort(tuple(-k for k in reversed(key_cols)))
    return PartitionedFrame.from_frame(f.take_rows(idx))


# ---- WINDOW -------------------------------------------------------------
def _window(pf: PartitionedFrame, func: str, cols, size, periods) -> PartitionedFrame:
    pf = pf.repartition(col_parts=1)
    template = pf.parts[0][0].induce()
    names = template.col_labels.to_list()
    targets = list(cols) if cols else [n for n, c in zip(names, template.columns)
                                       if c.domain.is_numeric]

    if func in ("cumsum", "cummax", "cummin"):
        return _window_scan_blocks(pf, func, targets)
    if func in ("diff", "shift"):
        return _window_halo(pf, func, targets, periods)
    if func in ("rolling_sum", "rolling_mean"):
        assert size is not None, "rolling window requires size"
        # rolling(w) = cumsum − shift(cumsum, w); first w−1 rows are null
        csum = _window_scan_blocks(pf, "cumsum", targets)
        shifted = _window_halo(csum, "shift", targets, size)
        return _rolling_combine(csum, shifted, targets, size, mean=(func == "rolling_mean"))
    if func == "cumprod":
        # via linear_scan: h_t = x_t * h_{t-1}  (a = x, b = 0, h0 = 1) → use
        # log-space cumsum? keep exact: per-block scan + multiplicative carry
        return _window_scan_blocks(pf, "cumprod", targets)
    raise ValueError(func)


def _apply_cols(frame: Frame, targets, fn: Callable[[Column], Column]) -> Frame:
    cols = list(frame.columns)
    names = frame.col_labels.to_list()
    for j, n in enumerate(names):
        if n in targets:
            cols[j] = fn(cols[j])
    return Frame(cols, frame.row_labels, frame.col_labels, frame.row_domains)


def _window_scan_blocks(pf: PartitionedFrame, func: str, targets) -> PartitionedFrame:
    blocks = [row[0].induce() for row in pf.parts]

    def local(block: Frame) -> Frame:
        def scan_col(c: Column) -> Column:
            v = jnp.where(c.valid_mask(), c.data.astype(jnp.float32),
                          _scan_identity(func))
            if func == "cumprod":
                out = jnp.cumprod(v, axis=0)
            else:
                out = kops.window_scan(v, func)
            return Column(out.astype(jnp.float32), Domain.FLOAT, c.mask, None)
        return _apply_cols(block, targets, scan_col)

    locals_ = list(get_pool().map(local, blocks))

    # cross-block carry composition: exclusive combine of block totals
    out_blocks: list[Frame] = []
    carries: dict[Any, float | jnp.ndarray] = {}
    for bi, (orig, loc) in enumerate(zip(blocks, locals_)):
        if bi == 0:
            out_blocks.append(loc)
        else:
            cols = list(loc.columns)
            names = loc.col_labels.to_list()
            for j, n in enumerate(names):
                if n in targets and n in carries:
                    cr = carries[n]
                    v = cols[j].data
                    if func == "cumsum":
                        v = v + cr
                    elif func == "cummax":
                        v = jnp.maximum(v, cr)
                    elif func == "cummin":
                        v = jnp.minimum(v, cr)
                    elif func == "cumprod":
                        v = v * cr
                    cols[j] = Column(v, cols[j].domain, cols[j].mask, None)
            out_blocks.append(Frame(cols, loc.row_labels, loc.col_labels, loc.row_domains))
        # update carries from the *combined* block tails
        last = out_blocks[-1]
        for n in targets:
            if last.nrows:
                carries[n] = last.col(n).data[-1]
    return PartitionedFrame([[b] for b in out_blocks])


def _scan_identity(func: str):
    return {"cumsum": 0.0, "cummax": -jnp.inf, "cummin": jnp.inf, "cumprod": 1.0}[func]


def _window_halo(pf: PartitionedFrame, func: str, targets, periods: int) -> PartitionedFrame:
    """diff/shift via a ``periods``-row halo — the running tail of everything
    before the block (a single block may be shorter than ``periods``)."""
    blocks = [row[0].induce() for row in pf.parts]
    halos: list[Frame | None] = [None]
    running: Frame | None = None
    for b in blocks[:-1]:
        running = b.tail(periods) if running is None else (
            running.concat_rows(b).tail(periods))
        halos.append(running)

    def local(args) -> Frame:
        block, halo = args
        ext = halo.concat_rows(block) if halo is not None else block
        pad = ext.nrows - block.nrows

        def do(c_name) -> Column:
            c = ext.col(c_name)
            v = c.data.astype(jnp.float32)
            valid = c.valid_mask()
            prev = jnp.roll(v, periods)
            prev_valid = jnp.roll(valid, periods)
            rowpos = jnp.arange(ext.nrows)
            in_range = rowpos >= periods
            if func == "diff":
                out = v - prev
                mask = valid & prev_valid & in_range
            else:  # shift
                out = prev
                mask = prev_valid & in_range
            return Column(out[pad:], Domain.FLOAT, mask[pad:], None)

        cols = list(block.columns)
        names = block.col_labels.to_list()
        for j, n in enumerate(names):
            if n in targets:
                cols[j] = do(n)
        return Frame(cols, block.row_labels, block.col_labels, block.row_domains)

    out = list(get_pool().map(local, list(zip(blocks, halos))))
    return PartitionedFrame([[b] for b in out])


def _rolling_combine(csum: PartitionedFrame, shifted: PartitionedFrame, targets,
                     size: int, mean: bool) -> PartitionedFrame:
    rows = []
    offset = 0
    for (crow, srow) in zip(csum.parts, shifted.parts):
        cb, sb = crow[0], srow[0]
        cols = list(cb.columns)
        names = cb.col_labels.to_list()
        rowpos = jnp.arange(cb.nrows) + offset
        full = rowpos >= size - 1
        for j, n in enumerate(names):
            if n in targets:
                c, s = cb.col(n), sb.col(n)
                base = jnp.where(s.valid_mask(), s.data, 0.0)
                out = c.data - base
                if mean:
                    out = out / size
                cols[j] = Column(out, Domain.FLOAT, c.valid_mask() & full, None)
        rows.append([Frame(cols, cb.row_labels, cb.col_labels)])
        offset += cb.nrows
    return PartitionedFrame(rows)


# ---- TRANSPOSE ----------------------------------------------------------
def _transpose(pf: PartitionedFrame) -> PartitionedFrame:
    """Grid transpose: per-block kernel transpose + grid metadata swap."""
    def block_t(frame: Frame) -> Frame:
        # No induction: coded-ness is decidable from declared domains, and
        # UNSPECIFIED columns (a prior transpose's output) are numeric storage
        # whose logical schema is recovered via row_domains downstream.
        f = frame
        tgt = common_storage(f.schema)
        if tgt.is_coded:
            return _transpose_coded(f.induce())
        mat, dom = f.as_matrix(tgt if tgt is not Domain.UNSPECIFIED else Domain.FLOAT)
        out = kops.transpose(mat)
        masks = [c.mask for c in f.columns]
        out_mask = None
        if any(m is not None for m in masks):
            mm = jnp.stack([c.valid_mask() for c in f.columns], axis=1)
            out_mask = np.asarray(kops.transpose(mm))
        # Wide-output fast path ("billions of columns", paper §4.2): one
        # device→host materialization, then zero-copy numpy views per column —
        # NOT n_cols separate device slices (O(µs) dispatch each).
        out_np = np.asarray(out)
        # second-transpose schema recovery (paper §3.3): the child's recorded
        # row-type vector (length == child.nrows == our ncols) gives the
        # output schema without re-running S(·) over values.
        rec = f.row_domains if (f.row_domains is not None
                                and len(f.row_domains) == f.nrows) else None
        new_cols = []
        for i in range(f.nrows):
            dom = rec[i] if rec is not None else Domain.UNSPECIFIED
            data = out_np[:, i]
            if rec is not None:
                data = data.astype(storage_dtype(dom))
            new_cols.append(Column(
                data, dom,
                None if out_mask is None else out_mask[:, i],
                None))
        return Frame(new_cols, f.col_labels, f.row_labels, row_domains=f.schema)

    return pf.transpose_grid(block_t)


def _transpose_coded(f: Frame) -> Frame:
    """Heterogeneous/string transpose: host re-encode (paper: coerce to
    Object; schema induction recovers on a second transpose)."""
    records = f.to_records()
    rec = f.row_domains if (f.row_domains is not None
                            and len(f.row_domains) == f.nrows) else None
    new_cols = []
    for i in range(f.nrows):
        vals = [records[i][j] for j in range(f.ncols)]
        if rec is not None:
            new_cols.append(_host_column(vals, rec[i]))
        else:
            new_cols.append(_host_column(
                [None if v is None else str(v) for v in vals], Domain.STR))
    return Frame(new_cols, f.col_labels, f.row_labels, row_domains=f.schema)


# ---- MAP ------------------------------------------------------------------
def _apply_udf_block(frame: Frame, udf: alg.Udf) -> Frame:
    """Run a Udf over one block (also the per-stage body of fused pipelines)."""
    f = frame.induce()
    cols_in = {n: c for n, c in zip(f.col_labels.to_list(), f.columns)}
    out = udf.fn(cols_in, f)
    if isinstance(out, Frame):
        return out
    # dict {label: Column | array | (array, mask)} preserving row count
    names, cols = [], []
    for name, v in out.items():
        names.append(name)
        if isinstance(v, Column):
            cols.append(v)
        elif isinstance(v, tuple):
            data, mask = v
            cols.append(Column(jnp.asarray(data), _infer_dom(data), mask, None))
        else:
            arr = jnp.asarray(v)
            cols.append(Column(arr, _infer_dom(arr), None, None))
    return Frame(cols, f.row_labels, labels_from_values(names))


def _map(pf: PartitionedFrame, udf: alg.Udf) -> PartitionedFrame:
    if udf.elementwise:
        return pf.repartition(col_parts=1).map_blockwise(
            lambda f: _apply_udf_block(f, udf))
    return PartitionedFrame.from_frame(_apply_udf_block(pf.to_frame(), udf))


def _infer_dom(arr) -> Domain:
    d = jnp.asarray(arr).dtype
    if d == jnp.bool_:
        return Domain.BOOL
    if jnp.issubdtype(d, jnp.integer):
        return Domain.INT
    return Domain.FLOAT


# ---- label movement ---------------------------------------------------------
def _to_labels(pf: PartitionedFrame, column: Any) -> PartitionedFrame:
    def conv(frame: Frame) -> Frame:
        f = frame.induce()
        j = f.col_labels.position_of(column)
        c = f.columns[j]
        labels = labels_from_values(c.to_pylist(), c.domain)
        keep = [x for x in range(f.ncols) if x != j]
        g = f.take_cols(keep)
        return Frame(g.columns, labels, g.col_labels)
    return pf.repartition(col_parts=1).map_blockwise(conv)


def _from_labels(pf: PartitionedFrame, label: Any) -> PartitionedFrame:
    pf = pf.repartition(col_parts=1)
    offsets = pf.row_block_offsets()

    def conv(args) -> Frame:
        (frame, start) = args
        f = frame
        vals = f.row_labels.to_list()
        c = _host_column(vals, Domain.INT if isinstance(f.row_labels, (RangeLabels, IntLabels)) else None)
        new = Frame([c] + list(f.columns),
                    RangeLabels(f.nrows, start),
                    labels_from_values([label]).concat(f.col_labels))
        return new

    out = list(get_pool().map(conv, [(row[0], offsets[i]) for i, row in enumerate(pf.parts)]))
    return PartitionedFrame([[b] for b in out])


def _rename_block(frame: Frame, mapping: dict) -> Frame:
    names = [mapping.get(n, n) for n in frame.col_labels.to_list()]
    return Frame(frame.columns, frame.row_labels, labels_from_values(names), frame.row_domains)


def _rename(pf: PartitionedFrame, mapping_items) -> PartitionedFrame:
    mapping = dict(mapping_items)
    return pf.map_blockwise(lambda frame: _rename_block(frame, mapping))


def _limit(pf: PartitionedFrame, k: int, tail: bool) -> PartitionedFrame:
    # Touch only the row blocks the prefix/suffix needs (§6.1.2).
    if not tail:
        f = pf.prefix(k).to_frame()
        return PartitionedFrame.from_frame(f.head(k))
    need, keep = k, []
    for i in range(pf.row_parts - 1, -1, -1):
        keep.insert(0, pf.parts[i])
        need -= pf.parts[i][0].nrows
        if need <= 0:
            break
    f = PartitionedFrame(keep).to_frame()
    return PartitionedFrame.from_frame(f.tail(k))


# ---- rewrite targets: column-space ops without any TRANSPOSE (paper §5) ------
def _key_rows_matrix(pf: PartitionedFrame, row_names: Sequence[Any]) -> np.ndarray:
    """(len(row_names), ncols) float64 matrix of the named rows' values."""
    pf1 = pf.repartition(col_parts=1)
    offsets = pf1.row_block_offsets()
    rows = []
    for name in row_names:
        found = None
        for bi, row in enumerate(pf1.parts):
            try:
                local = row[0].row_labels.position_of(name)
                found = (bi, local)
                break
            except KeyError:
                continue
        if found is None:
            raise KeyError(name)
        bi, local = found
        one = pf1.parts[bi][0].take_rows(np.asarray([local]))
        rows.append(_row_keys(one.induce(), None)[0])
    return np.stack(rows, axis=0)


def _column_sort(pf: PartitionedFrame, by: Sequence[Any], ascending: bool) -> PartitionedFrame:
    keys = _key_rows_matrix(pf, by)                       # (K, n)
    if ascending:
        perm = np.lexsort(tuple(reversed([k for k in keys])))
    else:
        perm = np.lexsort(tuple(reversed([-k for k in keys])))
    pf1 = pf.repartition(col_parts=1)
    return pf1.map_blockwise(lambda f: f.take_cols(perm.tolist()))


def _column_filter(pf: PartitionedFrame, predicate: alg.Expr) -> PartitionedFrame:
    refs = sorted(predicate.refs(), key=repr)
    keys = _key_rows_matrix(pf, refs)                     # (K, n)
    n = keys.shape[1]
    temp = Frame(
        [Column(jnp.asarray(keys[i].astype(np.float32)), Domain.FLOAT) for i in range(len(refs))],
        RangeLabels(n),
        labels_from_values(list(refs)),
    )
    keep = _predicate_mask(temp, predicate)
    idx = np.nonzero(keep)[0].tolist()
    pf1 = pf.repartition(col_parts=1)
    return pf1.map_blockwise(lambda f: f.take_cols(idx))


# =============================================================================
# FUSED PIPELINE (paper §5): one per-block program for a row-local chain
# =============================================================================
def _eval_expr_env(expr: alg.Expr, env: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``eval_expr`` over a plain {name: (values, mask)} environment — the
    jit-traceable entry used by compiled predicate chains (no Frame objects,
    no coded columns; callers gate on that).  Same interpreter core as
    ``eval_expr``, so fused and unfused predicates cannot diverge."""
    nrows = next(iter(env.values()))[0].shape[0]
    return _eval_expr_core(expr, env.__getitem__, nrows)


# Compiled predicate-chain programs, keyed by the combined expression's
# structural key.  One XLA executable evaluates the whole chain → bool keep
# mask; jit's own shape cache handles the (±1-row) block-size variants.
# Bounded FIFO: predicates with varying literals each get a distinct key, so
# an unbounded dict would leak one compiled program per literal seen.
_PRED_JIT: dict[tuple, Callable] = {}
_PRED_JIT_LOCK = threading.Lock()
_PRED_JIT_MAX = 256


def _compiled_predicate(expr: alg.Expr, refs: tuple) -> Callable:
    key = expr.key()
    with _PRED_JIT_LOCK:
        fn = _PRED_JIT.get(key)
        if fn is None:
            def prog(datas, masks):
                env = {r: (d, m) for r, d, m in zip(refs, datas, masks)}
                v, mask = _eval_expr_env(expr, env)
                return v.astype(jnp.bool_) & mask
            while len(_PRED_JIT) >= _PRED_JIT_MAX:
                _PRED_JIT.pop(next(iter(_PRED_JIT)))
            fn = _PRED_JIT[key] = jax.jit(prog)
    return fn


def _fused_selection_mask(preds: Sequence[alg.Expr], frame: Frame) -> np.ndarray:
    """keep-mask for a run of structured predicates, as ONE device program.

    ANDing before filtering is exact: predicates are row-local, so a row
    removed by an earlier selection contributes False to the conjunction
    regardless of its later-predicate value."""
    combined = preds[0]
    for p in preds[1:]:
        combined = alg.BinExpr("&", combined, p)
    refs = tuple(sorted(combined.refs(), key=repr))
    if not refs:
        return _predicate_mask(frame, combined)
    try:
        cols = [frame.col(r) for r in refs]
    except KeyError:
        return _predicate_mask(frame, combined)
    if any(c.domain.is_coded for c in cols):
        # coded columns need host code-table translation → interpreted path
        return _predicate_mask(frame, combined)
    fn = _compiled_predicate(combined, refs)
    keep = fn([c.data for c in cols], [c.valid_mask() for c in cols])
    return np.asarray(keep)


def _run_fused(pf: PartitionedFrame, stages: Sequence[alg.Stage]) -> PartitionedFrame:
    """Execute a fused row-local chain: one sweep per row partition, values
    staying on device across stages, one pool dispatch for the whole chain."""
    pf1 = pf.repartition(col_parts=1)

    def run_block(frame: Frame) -> Frame:
        cur = frame
        i = 0
        while i < len(stages):
            st = stages[i]
            if st.op == "selection":
                # coalesce a run of structured-Expr selections → one jit mask
                preds = []
                while (i < len(stages) and stages[i].op == "selection"
                       and isinstance(stages[i].params["predicate"], alg.Expr)):
                    preds.append(stages[i].params["predicate"])
                    i += 1
                if preds:
                    cur = cur.filter_rows(_fused_selection_mask(preds, cur))
                else:  # opaque Udf predicate
                    cur = cur.filter_rows(_predicate_mask(cur, st.params["predicate"]))
                    i += 1
            elif st.op == "map":
                cur = _apply_udf_block(cur, st.params["udf"])
                i += 1
            elif st.op == "projection":
                cur = _project_block(cur, st.params["cols"])
                i += 1
            elif st.op == "rename":
                cur = _rename_block(cur, dict(st.params["mapping"]))
                i += 1
            else:
                raise ValueError(f"non-fusible stage {st.op}")
        return cur

    return pf1.map_blockwise(run_block)


# =============================================================================
# dispatcher
# =============================================================================
def run_node(node: alg.Node, inputs: list[PartitionedFrame]) -> PartitionedFrame:
    op = node.op
    if op == "fused_pipeline":
        return _run_fused(inputs[0], node.params["stages"])
    if op == "selection":
        return _selection(inputs[0], node.params["predicate"])
    if op == "projection":
        return _projection(inputs[0], node.params["cols"])
    if op == "union":
        return _union(inputs[0], inputs[1])
    if op == "difference":
        return _difference(inputs[0], inputs[1])
    if op == "join":
        return _join(inputs[0], inputs[1], node.params)
    if op == "drop_duplicates":
        return _drop_duplicates(inputs[0], node.params["subset"])
    if op == "groupby":
        return _groupby(inputs[0], node.params["keys"], node.params["aggs"])
    if op == "sort":
        return _sort(inputs[0], node.params["by"], node.params["ascending"])
    if op == "rename":
        return _rename(inputs[0], node.params["mapping"])
    if op == "window":
        return _window(inputs[0], node.params["func"], node.params["cols"],
                       node.params["size"], node.params["periods"])
    if op == "transpose":
        return _transpose(inputs[0])
    if op == "map":
        return _map(inputs[0], node.params["udf"])
    if op == "to_labels":
        return _to_labels(inputs[0], node.params["column"])
    if op == "from_labels":
        return _from_labels(inputs[0], node.params["label"])
    if op == "limit":
        return _limit(inputs[0], node.params["k"], node.params["tail"])
    if op == "column_sort":
        return _column_sort(inputs[0], node.params["by"], node.params["ascending"])
    if op == "column_filter":
        return _column_filter(inputs[0], node.params["predicate"])
    raise ValueError(f"no physical implementation for {op}")
