"""Physical operators: dataframe algebra over partitioned frames (paper §4).

Each logical operator picks a partitioning scheme per the paper's §4.2 table:

  MAP / SELECTION / RENAME      → embarrassingly parallel, any partitioning
  GROUPBY(n)                    → row-parallel partial aggregation (MXU
                                  segment_reduce) + small combine — the
                                  shuffle-free plan the paper motivates
  GROUPBY(1)                    → same with G = 1 (pure reduction)
  WINDOW                        → blocked scan with cross-block carry
                                  composition (order-exact, still parallel)
  TRANSPOSE                     → per-block kernel transpose + grid swap
  SORT / JOIN                   → shuffle-native (``core/shuffle.py``):
                                  grace-hash join buckets / sample-sort range
                                  buckets exchanged through the pool, local
                                  per-bucket kernels, chunked payload gather —
                                  the inputs are never concatenated.
                                  ``REPRO_SHUFFLE=0`` retains the serial
                                  whole-frame path below as the oracle.
  DIFFERENCE / DROP-DUPLICATES  → blocking, but block-parallel: per-block key
                                  extraction through the scheduling layer,
                                  one host-side joint factorization, then
                                  blockwise keep-mask filters — the input is
                                  never concatenated (no ``to_frame()``).

The same operator bodies double as the shard_map shard-level programs for the
TPU mesh (see ``launch/dryrun.py`` — the pipeline dry-run lowers MAP/GROUPBY/
WINDOW over the production mesh with psums standing in for the combines).

Fused pipelines (paper §5 "Pipelining")
---------------------------------------
``FUSED_PIPELINE`` executes a whole chain of row-local operators (elementwise
MAP, SELECTION, PROJECTION, RENAME) as **one** per-row-partition program on
the shared pool: a single sweep over each block with column values staying on
device between stages, no intermediate ``PartitionedFrame``s, and one pool
dispatch for the whole chain instead of one per operator.  Runs of
consecutive structured-``Expr`` selections additionally collapse into a
single jit-compiled mask program (one XLA executable per predicate chain,
cached across blocks), so a k-predicate chain costs one device dispatch and
one filter instead of k of each.  Runs of consecutive elementwise MAPs are
likewise jit-traced as one XLA program per (udf-chain, schema), with a
per-chain fallback to eager dispatch when tracing fails or diverges.

Barrier-fused operators (fusion THROUGH the blocking boundary)
--------------------------------------------------------------
``FUSED_GROUPBY`` runs the row-local producer chain inside the groupby's own
per-block programs: one dispatch per partition stages the sweep and extracts
key spans, and (for dense INT keys) one dispatch per partition computes codes
plus every ``segment_reduce`` partial as a single compiled program — no
materialization boundary between the chain and the pre-shuffle stage.
``FUSED_SORT`` / ``FUSED_JOIN`` run the row-local consumer chain against the
permutation / match *index*: leading structured selections filter the index
before the payload gather and a leading projection prunes the gathered
columns, so the materialized frame is built once, post-filter, instead of
gathered-then-filtered.  ``FUSED_WINDOW`` folds pre-stages into the local-scan
block program and post-stages into the carry-application block program, with
the carry combine between them exactly where the unfused path placed it.
``FUSED_DROP_DUPLICATES`` / ``FUSED_DIFFERENCE`` run the row-local producer
chain inside the same per-block program that extracts the equality keys, and
consumer selections/projections filter the *keep mask* before the survivors
are materialized (the index-first pattern of ``FUSED_SORT``/``FUSED_JOIN``,
attributed via ``ExecStats.gather_rows``).

``REPRO_BLOCK_DEDUP=0`` routes DIFFERENCE / DROP-DUPLICATES through the
serial whole-frame path (the pre-PR-4 behavior) — the benchmark baseline and
an equivalence oracle for the block-parallel path.
"""
from __future__ import annotations

import functools
import os
import threading
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import algebra as alg
from .dtypes import Domain, common_storage, parse_column, storage_dtype
from .frame import Column, Frame, _host_exec as _frame_host_exec
from .labels import CodedLabels, IntLabels, Labels, RangeLabels, labels_from_values
from .partition import PartitionedFrame
from .schedule import (GRID_PREFS, dispatch_blocks, output_row_parts,
                       preferred_row_parts)
from .store import as_handle, pinned, resolve
from ..kernels import ops as kops

__all__ = ["run_node", "eval_expr", "NULL_CODE"]

NULL_CODE = -1


# =============================================================================
# Expression evaluation (structured predicates / scalar exprs)
# =============================================================================
def _col_values(frame: Frame, name: Any) -> tuple[jnp.ndarray, jnp.ndarray, Column]:
    c = frame.col(name)
    return c.data, c.valid_mask(), c


def _eval_expr_core(expr: alg.Expr, getcol: Callable, nrows: int,
                    bin_hook: Callable | None = None,
                    full: Callable = jnp.full) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The one expression interpreter, shared by the interpreted per-frame
    path (``eval_expr``) and the jit-traced fused-predicate path
    (``_eval_expr_env``) so the two can never diverge.

    ``getcol(name) → (values, mask)``; ``bin_hook(BinExpr) → result | None``
    lets the frame path intercept coded-column comparisons (host code-table
    translation that cannot run under jit).  ``full`` builds literal arrays —
    the host path passes ``np.full`` so wide int64 columns compare in int64
    (a jax literal would promote the pair through int32 and truncate)."""
    if isinstance(expr, alg.ColRef):
        return getcol(expr.name)
    if isinstance(expr, alg.Lit):
        return full((nrows,), expr.value), jnp.ones((nrows,), jnp.bool_)
    if isinstance(expr, alg.UnaryExpr):
        v, mask = _eval_expr_core(expr.operand, getcol, nrows, bin_hook, full)
        if expr.op == "~":
            return ~v.astype(jnp.bool_), mask
        if expr.op == "isna":
            return ~mask, jnp.ones_like(mask)
        if expr.op == "notna":
            return mask, jnp.ones_like(mask)
        raise ValueError(expr.op)
    if isinstance(expr, alg.BinExpr):
        if bin_hook is not None:
            hit = bin_hook(expr)
            if hit is not None:
                return hit
        lv, lm = _eval_expr_core(expr.left, getcol, nrows, bin_hook, full)
        rv, rm = _eval_expr_core(expr.right, getcol, nrows, bin_hook, full)
        return _bin_numeric(expr.op, lv, lm, rv, rm)
    raise TypeError(expr)


def _host_full(shape, value):
    """Host literal arrays for the interpreted path, typed to match the
    jit-compiled fused path wherever both can run: in-range int literals in
    int32 (identical wrap semantics), float literals in float32 (identical
    arithmetic).  Only out-of-int32-range literals take int64 — they cannot
    be traced at all, and against a wide int64 host column the int⊕int
    promotion then compares exactly where a jax literal would truncate."""
    if not isinstance(value, bool) and isinstance(value, int):
        dt = np.int32 if -2 ** 31 <= value < 2 ** 31 else np.int64
        return np.full(shape, value, dtype=dt)
    if isinstance(value, float):
        return np.full(shape, value, dtype=np.float32)
    return np.full(shape, value)


def _has_wide_lit(expr: alg.Expr) -> bool:
    """True if any int literal in ``expr`` falls outside int32 — such a
    literal cannot be jit-traced (jax is 32-bit here), so predicate chains
    containing one run on the interpreted host path."""
    if isinstance(expr, alg.Lit):
        v = expr.value
        return (isinstance(v, int) and not isinstance(v, bool)
                and not -2 ** 31 <= v < 2 ** 31)
    if isinstance(expr, alg.BinExpr):
        return _has_wide_lit(expr.left) or _has_wide_lit(expr.right)
    if isinstance(expr, alg.UnaryExpr):
        return _has_wide_lit(expr.operand)
    return False


def eval_expr(expr: alg.Expr, frame: Frame) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorized evaluation → (values, valid_mask) device arrays."""
    host = _frame_host_exec()

    def getcol(name):
        data, mask, _ = _col_values(frame, name)
        return data, mask

    def bin_hook(e: alg.BinExpr):
        # coded-column vs literal comparisons translate to code-space
        if isinstance(e.left, alg.ColRef) and isinstance(e.right, alg.Lit):
            c = frame.col(e.left.name)
            if c.domain.is_coded and e.op in ("==", "!="):
                code = _lit_to_code(c, e.right.value)
                v = c.data == code if e.op == "==" else c.data != code
                return v, c.valid_mask()
        return None

    return _eval_expr_core(expr, getcol, frame.nrows, bin_hook,
                           _host_full if host else jnp.full)


def _lit_to_code(column: Column, value: Any) -> int:
    table = column.dictionary or ()
    key = str(value)
    return table.index(key) if key in table else -2  # -2 never matches


def _wide_host_int(a) -> bool:
    """True for a 64-bit integer HOST array — the one operand kind that must
    never meet jax arithmetic (canonicalization truncates int64 → int32)."""
    return (isinstance(a, np.ndarray) and a.dtype.kind in "iu"
            and a.dtype.itemsize > 4)


def _bin_numeric(op: str, lv, lm, rv, rm) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Binary op over (values, mask) pairs.  int⊕int stays in integer dtypes
    for ``+ - * % //`` and comparisons — a float32 round-trip corrupts values
    above 2²⁴ (int32 storage holds up to 2³¹−1).  Like numpy/pandas integer
    dtypes, ``+ - *`` wrap on int32 overflow; ``% //`` by zero yield null.
    A wide int64 host operand pins the pair to host numpy (a mixed np/jax op
    would canonicalize the wide side through int32 and truncate)."""
    mask = lm & rm
    if op in ("&", "|"):
        lb, rb = lv.astype(jnp.bool_), rv.astype(jnp.bool_)
        return (lb & rb if op == "&" else lb | rb), mask
    both_int = (jnp.issubdtype(lv.dtype, jnp.integer)
                and jnp.issubdtype(rv.dtype, jnp.integer))
    if both_int and (_wide_host_int(lv) or _wide_host_int(rv)):
        lv = np.asarray(lv, dtype=np.int64)
        rv = np.asarray(rv, dtype=np.int64)
    if op in ("+", "-", "*", "%", "//") and both_int:
        if op == "+":
            return lv + rv, mask
        if op == "-":
            return lv - rv, mask
        if op == "*":
            return lv * rv, mask
        # int division by 0 is XLA-defined garbage (unlike float inf/nan):
        # mark those rows null instead of surfacing a plausible integer.  On
        # the host-numpy substrate a zero divisor would also warn, so feed
        # the masked slots a dummy 1 (their values are never observed).
        mask = mask & (rv != 0)
        if isinstance(lv, np.ndarray) and isinstance(rv, np.ndarray):
            rv = np.where(rv == 0, np.ones((), rv.dtype), rv)
            return (np.mod(lv, rv) if op == "%"
                    else np.floor_divide(lv, rv)), mask
        return (jnp.mod(lv, rv) if op == "%"
                else jnp.floor_divide(lv, rv)), mask
    if op in ("+", "-", "*", "/", "%", "//"):
        lf, rf = _as_float_pair(lv, rv)
        if op in ("%", "//"):
            if isinstance(lf, np.ndarray) and lf.dtype.itemsize > 4:
                # the wide/f64 pair stays on host numpy end to end (jax mod
                # would truncate it back through f32); numpy warns where XLA
                # silently produces nan, so mute — the nan itself is kept
                with np.errstate(all="ignore"):
                    out = np.mod(lf, rf) if op == "%" else np.floor_divide(lf, rf)
            else:
                out = jnp.mod(lf, rf) if op == "%" else jnp.floor_divide(lf, rf)
        else:
            out = {"+": lf + rf, "-": lf - rf,
                   "*": lf * rf, "/": lf / rf}[op]
        return out, mask
    if both_int:
        lf, rf = lv, rv
    else:
        lf, rf = _as_float_pair(lv, rv)
    out = {
        "==": lf == rf, "!=": lf != rf, "<": lf < rf,
        "<=": lf <= rf, ">": lf > rf, ">=": lf >= rf,
    }[op]
    return out, mask


def _as_float_pair(lv, rv):
    """Float substrate for a mixed binary op: float32 (device semantics,
    matching the jit-compiled fused path) unless either operand carries
    64-bit storage — then float64 on HOST numpy, the promotion numpy/pandas
    apply to int64⊕float (jax would truncate both sides through 32 bits).
    64-bit operands never reach the jit trace (the fused predicate path
    guards them out), so fused and unfused plans still agree."""
    try:
        wide = lv.dtype.itemsize > 4 or rv.dtype.itemsize > 4
    except AttributeError:
        wide = False
    if wide:
        return np.asarray(lv, np.float64), np.asarray(rv, np.float64)
    return lv.astype(jnp.float32), rv.astype(jnp.float32)


def _predicate_mask(frame: Frame, predicate) -> np.ndarray:
    if isinstance(predicate, alg.Udf):
        out = predicate.fn({n: c for n, c in zip(frame.col_labels.to_list(), frame.columns)}, frame)
        return np.asarray(out, dtype=bool)
    v, mask = eval_expr(predicate, frame)
    return np.asarray(v.astype(jnp.bool_) & mask)  # null comparisons → False


# =============================================================================
# Per-operator physical implementations
# =============================================================================
def _selection(pf: PartitionedFrame, predicate) -> PartitionedFrame:
    if pf.col_parts == 1:
        return pf.map_blockwise(lambda f: f.filter_rows(_predicate_mask(f, predicate)))
    # predicate may span column blocks: evaluate per row-stripe, filter blocks
    def stripe(i: int) -> list[Frame]:
        full = pf.parts[i][0]
        for j in range(1, pf.col_parts):
            full = full.concat_cols(pf.parts[i][j])
        keep = _predicate_mask(full, predicate)
        return [blk.filter_rows(keep) for blk in pf.parts[i]]
    rows = dispatch_blocks(stripe, range(pf.row_parts))
    return PartitionedFrame(rows)


def _project_block(frame: Frame, cols: Sequence[Any]) -> Frame:
    return frame.take_cols(frame.col_labels.positions_of(cols))


def _projection(pf: PartitionedFrame, cols: Sequence[Any]) -> PartitionedFrame:
    f = pf.repartition(col_parts=1)
    return f.map_blockwise(lambda frame: _project_block(frame, cols))


def _union(left: PartitionedFrame, right: PartitionedFrame) -> PartitionedFrame:
    l = left.repartition(col_parts=1)
    r = right.repartition(col_parts=1)
    # handle-level stack: pure metadata, no block is faulted
    return PartitionedFrame(l.handles + r.handles)


def _output_pf(out: Frame | PartitionedFrame) -> PartitionedFrame:
    """Re-grid a blocking operator's output to the pool width
    (``schedule.output_row_parts``): SORT/JOIN/... build a fresh frame, and
    handing it downstream as a single block would serialize every later
    operator.  Small results keep the old single-partition layout.  A
    PartitionedFrame input (DIFFERENCE / DROP-DUPLICATES keep the partitioned
    form all the way through) re-grids via the zero-copy segment regroup
    instead of a concat + re-split."""
    if isinstance(out, PartitionedFrame):
        return out.repartition(row_parts=output_row_parts(out.nrows),
                               col_parts=1)
    return PartitionedFrame.from_frame(out,
                                       row_parts=output_row_parts(out.nrows))


_HASH_MASK = (1 << 52) - 1  # exactly-representable ints in float64
_WIDE_INT_LIMIT = 1 << 53   # |v| beyond this, float64 merges distinct int64s


def _fnv64(s: str) -> int:
    h = 0xCBF29CE484222325
    for ch in s.encode():
        h ^= ch
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def _hash_wide_ints(v: np.ndarray) -> np.ndarray:
    """splitmix64-style mix of int64 key values, masked into the float64-exact
    range: keys for integers float64 cannot represent (a plain cast collides
    2**53 with 2**53 + 1).  Like the coded-column value hash, equality is
    probabilistic with a ~2**-52 per-pair collision chance — distinct wide
    keys separate, at the same odds strings already accept."""
    z = v.astype(np.int64).view(np.uint64).copy()
    z += np.uint64(0x9E3779B97F4A7C15)
    z ^= z >> np.uint64(30)
    z *= np.uint64(0xBF58476D1CE4E5B9)
    z ^= z >> np.uint64(27)
    z *= np.uint64(0x94D049BB133111EB)
    z ^= z >> np.uint64(31)
    return (z & np.uint64(_HASH_MASK)).astype(np.float64)


def _wide_key_values(arr: np.ndarray) -> np.ndarray:
    """Key values for a column at a wide-flagged position.  Integer (and
    bool) storage hashes directly.  A float/other column sharing the position
    (the OTHER frame's column was the wide one) hashes only its *integral*
    in-int64-range values — ``5.0`` must still equal int ``5`` — while
    fractional and non-finite values keep their raw float64 form: hash
    outputs are integers, so a fractional value can never falsely equal one."""
    if arr.dtype.kind in "iub":
        return _hash_wide_ints(arr)
    f = np.asarray(arr, dtype=np.float64)
    intlike = np.isfinite(f) & (np.floor(f) == f) & (np.abs(f) < 2.0 ** 63)
    hashed = _hash_wide_ints(np.where(intlike, f, 0.0).astype(np.int64))
    return np.where(intlike, hashed, f)


def _wide_int_flags(frame: Frame, subset: Sequence[Any] | None) -> np.ndarray:
    """Per-key-column bool: INT column holding values outside ±2**53 (only
    possible with int64 host storage — int32 device storage can't reach it).
    Every frame participating in one joint factorization must agree on these
    flags, or a wide column would hash on one side and value-cast on the
    other; callers OR the flags across frames/blocks before ``_row_keys``."""
    cols = frame.columns if subset is None else [frame.col(n) for n in subset]
    out = np.zeros(len(cols), dtype=bool)
    for i, c in enumerate(cols):
        # dtype check BEFORE np.asarray: only host int64 storage can be wide,
        # and materializing int32 device columns here would pay a per-column
        # per-block device→host copy just to skip them
        if c.domain is not Domain.INT or c.data.dtype.itemsize <= 4:
            continue
        v = np.asarray(c.data)
        if c.mask is not None:
            v = v[np.asarray(c.mask)]
        if v.size and bool(((v > _WIDE_INT_LIMIT) | (v < -_WIDE_INT_LIMIT)).any()):
            out[i] = True
    return out


def _row_keys(frame: Frame, subset: Sequence[Any] | None,
              wide: np.ndarray | None = None) -> np.ndarray:
    """Normalized per-row key matrix (host) for equality (dedup / difference /
    join / groupby).  Coded (Σ*) columns map through a *value* hash so keys
    compare correctly across frames with different dictionaries; numerics are
    their float64 values; nulls are NaN (never equal a valid key).  ``wide``
    (from ``_wide_int_flags``, OR-ed across all frames being compared) routes
    int64 columns exceeding the float64-exact range through the hash path."""
    cols = frame.columns if subset is None else [frame.col(n) for n in subset]
    mats = []
    for i, c in enumerate(cols):
        if c.domain.is_coded:
            table = c.dictionary or ()
            lut = np.asarray([float(_fnv64(str(v)) & _HASH_MASK) for v in table]
                             or [0.0], dtype=np.float64)
            codes = np.asarray(c.data)
            v = lut[np.clip(codes, 0, len(lut) - 1)]
            v = np.where(codes >= 0, v, np.nan)
        elif wide is not None and bool(wide[i]):
            v = _wide_key_values(np.asarray(c.data))
        else:
            v = np.asarray(c.data, dtype=np.float64)
        if c.mask is not None:
            v = np.where(np.asarray(c.mask), v, np.nan)
        mats.append(v)
    return np.stack(mats, axis=1) if mats else np.zeros((frame.nrows, 0))


def _sort_rank_keys(frame: Frame, subset: Sequence[Any]) -> list[np.ndarray]:
    """Per-column sort keys: lexicographic rank for coded columns, values for
    numerics (ordering, unlike equality, needs real value order)."""
    out = []
    for name in subset:
        c = frame.col(name)
        if c.domain.is_coded:
            table = list(c.dictionary or ())
            rank = np.empty(max(len(table), 1), dtype=np.float64)
            for r, idx in enumerate(sorted(range(len(table)), key=lambda i: str(table[i]))):
                rank[idx] = r
            codes = np.asarray(c.data)
            v = rank[np.clip(codes, 0, len(table) - 1 if table else 0)]
            v = np.where(codes >= 0, v, np.nan)
        else:
            v = np.asarray(c.data, dtype=np.float64)
        if c.mask is not None:
            v = np.where(np.asarray(c.mask), v, np.nan)
        out.append(v)
    return out


def _keys_to_ids(*key_mats: np.ndarray) -> list[np.ndarray]:
    """Jointly factorize row-key matrices → dense ids (NaN-safe)."""
    all_rows = np.concatenate(key_mats, axis=0)
    # use bit-view so NaN == NaN for grouping purposes
    view = all_rows.view(np.int64).reshape(all_rows.shape)
    n, ncols = view.shape
    if ncols == 0:
        # no key columns: every row carries the same (empty) key
        inv = np.zeros(n, dtype=np.int64)
    elif ncols == 1:
        # single-key fast path: 1-D unique (axis=0 unique void-sorts, ~30×
        # slower — this is the groupby(n) hot path)
        _, inv = np.unique(view[:, 0], return_inverse=True)
    else:
        # multi-key: column-wise factorization — k cheap 1-D uniques instead
        # of one void-sorted row unique (~30× constant).  Exact, no hashing.
        # The per-column uniques go through the pool (numpy's sort drops the
        # GIL, so the columns genuinely factorize in parallel).
        def col_inv(j: int):
            _, invj = np.unique(view[:, j], return_inverse=True)
            return (invj.astype(np.int64),
                    int(invj.max()) + 1 if invj.size else 1)

        # attribute=False: these tasks are key COLUMNS, not row blocks — they
        # must not skew the row-block scheduling counters
        per_col = dispatch_blocks(col_inv, range(ncols), attribute=False)
        invs = [p[0] for p in per_col]
        cards = [p[1] for p in per_col]
        space = 1
        for c in cards:
            space *= c
        if space < 2 ** 62:
            # mixed-radix combine in ONE pass + one final unique: the code
            # (…(inv0·c1 + inv1)·c2 + inv2…) is the lexicographic rank in
            # the per-column rank space, so equal rows get equal codes
            code = invs[0]
            for invj, c in zip(invs[1:], cards[1:]):
                code = code * np.int64(c) + invj
            _, inv = np.unique(code, return_inverse=True)
        else:
            # huge code space: re-densify after every combine — the pair
            # code (prefix id × stride + column id) then never overflows
            # int64 because both factors are < n ≤ 2**31-ish
            inv = invs[0]
            for invj, c in zip(invs[1:], cards[1:]):
                _, inv = np.unique(inv * np.int64(c) + invj,
                                   return_inverse=True)
                inv = inv.astype(np.int64)
    out, off = [], 0
    for m in key_mats:
        out.append(inv[off:off + m.shape[0]].astype(np.int64))
        off += m.shape[0]
    return out


# ---- DIFFERENCE / DROP-DUPLICATES -------------------------------------------
# Block-parallel local-dedup → joint-factorize → blockwise-filter (the
# local-pattern decomposition Perera et al. describe for distinct/set ops):
# per-block key extraction runs through ``schedule.dispatch_blocks``, the
# per-block key matrices are jointly factorized in one host pass, and the
# first-occurrence / anti-join keep masks are applied blockwise — the input
# keeps its partitioned form end to end (no ``to_frame()`` concat).


def _block_dedup_enabled() -> bool:
    """``REPRO_BLOCK_DEDUP=0`` falls back to the serial whole-frame path (the
    pre-PR-4 seed behavior) — benchmark baseline and equivalence oracle."""
    return os.environ.get("REPRO_BLOCK_DEDUP", "") != "0"


def _dedup_grid_blocks(pf: PartitionedFrame, grid: str | None,
                       pref_key: str) -> list:
    """Full-width row blocks coarsened to the recorded grid preference (key
    extraction wants blocks ≈ workers: fewer per-block fixed costs — LUT
    builds, key-matrix stacks — and fewer pieces in the joint factorization).
    Unlike GROUPBY partials or WINDOW seams, dedup results are invariant to
    the blocking (keys are per-row, the factorization is joint), so the
    regrid may precede the absorbed producer chain: fused and unfused plans
    stay bit-identical on ANY grid, which lets the producer sweep and the key
    extraction share one pool round."""
    pf1 = pf.repartition(col_parts=1)
    rp = preferred_row_parts(pf1.row_parts, grid or GRID_PREFS[pref_key],
                             total_bytes=pf1.nbytes())
    if rp != pf1.row_parts:
        pf1 = pf1.repartition(row_parts=rp)
    return pf1.row_handles()


def _key_block(args) -> tuple[Any, np.ndarray, np.ndarray, np.ndarray | None]:
    """The per-block key-extraction program, ONE dispatch per partition: run
    the absorbed producer chain, induce, flag wide ints, build the key
    matrix, and evaluate pushable consumer predicates (row-local ⇒ legal on
    the pre-filter block, exactly like ``_fused_sort`` evaluates them on the
    unsorted frame).  Runs on a pool worker: the input faults under a pin,
    and the (possibly staged) block returns as a store handle so it can
    spill again before the keep-mask pass comes back for it."""
    block, subset, stages, preds = args
    with pinned(block) as src:
        f = (_run_stages_block(src, stages) if stages else src).induce()
        flags = _wide_int_flags(f, subset)
        mat = _row_keys(f, subset, flags)
        keep = None
        if preds:
            keep = np.asarray(_fused_selection_mask(preds, f), dtype=bool)
        hout = block if f is src else as_handle(
            f, recompute=lambda: (_run_stages_block(resolve(block), stages)
                                  if stages else resolve(block)).induce())
    return hout, flags, mat, keep


def _joint_key_mats(results, subset):
    """OR the per-block wide-int flags and re-key the (rare) blocks whose
    local decision disagrees — every block in one joint factorization must
    hash-or-cast each column identically (see ``_wide_int_flags``)."""
    blocks = [r[0] for r in results]
    flags = [r[1] for r in results]
    mats = [r[2] for r in results]
    keeps = [r[3] for r in results]
    joint = np.zeros_like(flags[0])
    for fl in flags:
        joint = joint | fl
    if joint.any():
        # re-key through the pool: serially re-keying the disagreeing blocks
        # would undo the block parallelism exactly on the wide-int inputs
        # this reconciliation exists for
        redo = [i for i, fl in enumerate(flags)
                if not bool((fl == joint).all())]

        def rekey(i):
            with pinned(blocks[i]) as f:
                return _row_keys(f, subset, joint)

        fixed = dispatch_blocks(rekey, redo)
        for i, m in zip(redo, fixed):
            mats[i] = m
    return blocks, mats, keeps


def _apply_keep_blocks(blocks: Sequence, keeps: Sequence[np.ndarray],
                       proj) -> PartitionedFrame:
    """Blockwise keep-mask filter (+ gather-time projection): the survivors
    are materialized once, post-filter, in their original partitioned form.
    Blocks are store handles — spilled ones fault inside the worker."""
    def filt(args):
        h, keep = args

        def build(f):
            g = f.filter_rows(keep)
            if proj is not None:
                g = _project_block(g, proj)
            return g

        with pinned(h) as f:
            return as_handle(build(f), recompute=lambda: build(resolve(h)))

    out = dispatch_blocks(filt, list(zip(blocks, keeps)))
    return PartitionedFrame([[b] for b in out])


def _dedup_finish(pfo: PartitionedFrame, rest) -> PartitionedFrame:
    out = _output_pf(pfo)
    if rest:
        out = out.map_blockwise(lambda b: _run_stages_block(b, rest))
    return out


def _difference(left: PartitionedFrame, right: PartitionedFrame, stats=None,
                pre_l: Sequence[alg.Stage] = (),
                pre_r: Sequence[alg.Stage] = (),
                post: Sequence[alg.Stage] = (),
                grid: str | None = None) -> PartitionedFrame:
    """Ordered anti-join on all columns: left rows whose full-row key appears
    in the right input are dropped, survivors keep left order and labels.
    Block-parallel: both sides' key extraction runs in ONE pool round, the
    anti-join membership test is a host np.isin over dense ids, and the keep
    masks filter the left blocks in place."""
    if not _block_dedup_enabled():
        return _difference_serial(left, right, stats, pre_l, pre_r, post)
    lblocks = _dedup_grid_blocks(left, grid, "difference")
    rblocks = _dedup_grid_blocks(right, grid, "difference")
    preds, proj, rest = _split_consumer_stages(post)
    items = ([(b, None, pre_l, preds) for b in lblocks]
             + [(b, None, pre_r, ()) for b in rblocks])
    results = dispatch_blocks(_key_block, items)
    frames, mats, pred_keeps = _joint_key_mats(results, None)
    nl = len(lblocks)
    if stats is not None:
        stats.dedup_blocks += len(frames)
        stats.dedup_key_rows += sum(int(m.shape[0]) for m in mats)
    ids = _keys_to_ids(*mats)
    lids, rids = ids[:nl], ids[nl:]
    rset = np.unique(np.concatenate(rids))
    keeps = []
    for lid, pk in zip(lids, pred_keeps[:nl]):
        k = ~np.isin(lid, rset)
        if pk is not None:
            k = k & pk
        keeps.append(k)
    if stats is not None:
        stats.gather_rows += int(sum(int(k.sum()) for k in keeps))
    return _dedup_finish(_apply_keep_blocks(frames[:nl], keeps, proj), rest)


def _drop_duplicates(pf: PartitionedFrame, subset, stats=None,
                     pre: Sequence[alg.Stage] = (),
                     post: Sequence[alg.Stage] = (),
                     grid: str | None = None) -> PartitionedFrame:
    """First-occurrence dedup over the (subset) equality keys, block-parallel
    (see the section comment above).  A frame with no key columns has nothing
    to compare, so every row survives — pandas semantics."""
    if not _block_dedup_enabled():
        return _drop_duplicates_serial(pf, subset, stats, pre, post)
    blocks = _dedup_grid_blocks(pf, grid, "drop_duplicates")
    preds, proj, rest = _split_consumer_stages(post)
    results = dispatch_blocks(_key_block,
                              [(b, subset, pre, preds) for b in blocks])
    frames, mats, pred_keeps = _joint_key_mats(results, subset)
    total = sum(int(m.shape[0]) for m in mats)
    if stats is not None:
        stats.dedup_blocks += len(frames)
        stats.dedup_key_rows += total
    if mats[0].shape[1] == 0:
        keep_global = np.ones(total, dtype=bool)
    else:
        all_ids = np.concatenate(_keys_to_ids(*mats))
        _, first = np.unique(all_ids, return_index=True)
        keep_global = np.zeros(total, dtype=bool)
        keep_global[first] = True
    keeps, off = [], 0
    for m, pk in zip(mats, pred_keeps):
        k = keep_global[off:off + m.shape[0]]
        off += m.shape[0]
        if pk is not None:
            k = k & pk
        keeps.append(k)
    if stats is not None:
        stats.gather_rows += int(sum(int(k.sum()) for k in keeps))
    return _dedup_finish(_apply_keep_blocks(frames, keeps, proj), rest)


def _difference_serial(left: PartitionedFrame, right: PartitionedFrame,
                       stats=None, pre_l=(), pre_r=(), post=()) -> PartitionedFrame:
    """The seed path: whole-frame concat + single-threaded host numpy."""
    if pre_l:
        left = _run_fused(left, pre_l)
    if pre_r:
        right = _run_fused(right, pre_r)
    lf, rf = left.to_frame().induce(), right.to_frame().induce()
    flags = _wide_int_flags(lf, None) | _wide_int_flags(rf, None)
    lids, rids = _keys_to_ids(_row_keys(lf, None, flags),
                              _row_keys(rf, None, flags))
    keep = ~np.isin(lids, np.unique(rids))
    if stats is not None:
        stats.dedup_blocks += 2
        stats.dedup_key_rows += lf.nrows + rf.nrows
        stats.gather_rows += int(keep.sum())
    out = _output_pf(lf.filter_rows(keep))
    if post:
        out = out.map_blockwise(lambda b: _run_stages_block(b, post))
    return out


def _drop_duplicates_serial(pf: PartitionedFrame, subset, stats=None,
                            pre=(), post=()) -> PartitionedFrame:
    """The seed path: whole-frame concat + single-threaded host numpy."""
    if pre:
        pf = _run_fused(pf, pre)
    f = pf.to_frame().induce()
    mat = _row_keys(f, subset, _wide_int_flags(f, subset))
    if mat.shape[1] == 0:
        keep = np.ones(f.nrows, dtype=bool)
    else:
        ids = _keys_to_ids(mat)[0]
        _, first = np.unique(ids, return_index=True)
        keep = np.zeros(f.nrows, dtype=bool)
        keep[first] = True
    if stats is not None:
        stats.dedup_blocks += 1
        stats.dedup_key_rows += f.nrows
        stats.gather_rows += int(keep.sum())
    out = _output_pf(f.filter_rows(keep))
    if post:
        out = out.map_blockwise(lambda b: _run_stages_block(b, post))
    return out


# ---- JOIN -------------------------------------------------------------------
def _match_ids(lids: np.ndarray, rids: np.ndarray, how: str):
    """Vectorized equality matching over factorized key ids — the shared
    kernel behind both the serial ``_join_indices`` path and the per-bucket
    local joins in ``core/shuffle.py``.  Reproduces the historical dict-loop
    matcher's exact emission order: left-major, right order breaking ties,
    unmatched-left rows interleaved in place (left/outer), unmatched-right
    rows appended in right order (right/outer).  Returns (lidx, ridx, lvalid,
    rvalid)."""
    nl, nr = int(lids.shape[0]), int(rids.shape[0])
    order_r = np.argsort(rids, kind="stable")
    srids = rids[order_r]
    # probe with SORTED queries (cache-friendly binary search: ~5× cheaper
    # than random-order probes), then scatter the results back to left order
    order_l = np.argsort(lids, kind="stable")
    slids = lids[order_l]
    starts = np.empty(nl, dtype=np.int64)
    ends = np.empty(nl, dtype=np.int64)
    starts[order_l] = np.searchsorted(srids, slids, side="left")
    ends[order_l] = np.searchsorted(srids, slids, side="right")
    counts = (ends - starts).astype(np.int64)
    matched = counts > 0
    if how in ("left", "outer"):
        out_counts = np.where(matched, counts, 1)
    else:
        out_counts = counts
    total = int(out_counts.sum())
    lidx = np.repeat(np.arange(nl, dtype=np.int64), out_counts)
    offs = np.cumsum(out_counts) - out_counts
    within = np.arange(total, dtype=np.int64) - np.repeat(offs, out_counts)
    rvalid = np.repeat(matched, out_counts)
    pos = np.repeat(starts.astype(np.int64), out_counts) + within
    if nr:
        gather = order_r[np.minimum(pos, nr - 1)].astype(np.int64)
    else:
        gather = np.zeros(total, dtype=np.int64)
    ridx = np.where(rvalid, gather, 0)
    lvalid = np.ones(total, dtype=bool)
    if how in ("right", "outer"):
        rpos = np.nonzero(~np.isin(rids, lids))[0].astype(np.int64)
        lidx = np.concatenate([lidx, np.zeros(rpos.shape[0], dtype=np.int64)])
        ridx = np.concatenate([ridx, rpos])
        lvalid = np.concatenate([lvalid,
                                 np.zeros(rpos.shape[0], dtype=bool)])
        rvalid = np.concatenate([rvalid, np.ones(rpos.shape[0], dtype=bool)])
    return lidx, ridx, lvalid, rvalid


def _join_indices(lf: Frame, rf: Frame, params: dict):
    """Build the match indices: (lidx, ridx, lvalid, rvalid, drop_right).
    No payload row is gathered here — that happens in ``_assemble_join``, and
    the fused-consumer path filters these indices first."""
    how = params["how"]
    on = params["on"]
    left_on = params["left_on"] or on
    right_on = params["right_on"] or on

    if left_on is None:  # CROSS-PRODUCT: nested order, left outer (Table 1 †)
        ml, mr = lf.nrows, rf.nrows
        lidx = np.repeat(np.arange(ml), mr)
        ridx = np.tile(np.arange(mr), ml)
        return lidx, ridx, None, None, ()

    flags = _wide_int_flags(lf, left_on) | _wide_int_flags(rf, right_on)
    lids, rids = _keys_to_ids(_row_keys(lf, left_on, flags),
                              _row_keys(rf, right_on, flags))
    lidx, ridx, lvalid, rvalid = _match_ids(lids, rids, how)
    drop_right = tuple(right_on) if on is not None else ()
    return lidx, ridx, lvalid, rvalid, drop_right


def _join(left: PartitionedFrame, right: PartitionedFrame, params: dict,
          stats=None) -> PartitionedFrame:
    from . import shuffle as _shuffle
    if _shuffle.enabled():
        return _shuffle.shuffled_join(left, right, params, (), stats)
    return _join_serial(left, right, params, stats)


def _join_serial(left: PartitionedFrame, right: PartitionedFrame, params: dict,
                 stats=None) -> PartitionedFrame:
    """The whole-frame oracle path (``REPRO_SHUFFLE=0``)."""
    lf, rf = left.to_frame().induce(), right.to_frame().induce()
    lidx, ridx, lvalid, rvalid, drop_right = _join_indices(lf, rf, params)
    if stats is not None:
        stats.gather_rows += int(lidx.shape[0])
    out = _assemble_join(lf, rf, lidx, ridx, lvalid, rvalid, drop_right)
    return _output_pf(out)


def _gather_join_cols(lf: Frame, rf: Frame, lidx, ridx, lvalid, rvalid,
                      drop_right, names: Sequence[Any]) -> Frame:
    """Gather ONLY the named columns of the (virtual) join result — the
    predicate's working set, not the payload.  Left columns shadow right ones
    on name collision, matching ``_assemble_join``'s concat order."""
    lnames = set(lf.col_labels.to_list())
    rnames = {n for n in rf.col_labels.to_list() if n not in drop_right}
    cols, out_names = [], []
    for n in names:
        if n in lnames:
            c, side_valid = lf.col(n).take(lidx), lvalid
        elif n in rnames:
            c, side_valid = rf.col(n).take(ridx), rvalid
        else:
            raise KeyError(n)
        if side_valid is not None and not side_valid.all():
            vm = jnp.asarray(c.valid_mask()) & jnp.asarray(side_valid)
            c = Column(c.data, c.domain, vm, c.dictionary)
        cols.append(c)
        out_names.append(n)
    return Frame(cols, RangeLabels(int(lidx.shape[0])), labels_from_values(out_names))


def _fused_join(left: PartitionedFrame, right: PartitionedFrame, params: dict,
                stages: Sequence[alg.Stage], stats=None) -> PartitionedFrame:
    from . import shuffle as _shuffle
    if _shuffle.enabled():
        return _shuffle.shuffled_join(left, right, params, stages, stats)
    return _fused_join_serial(left, right, params, stages, stats)


def _fused_join_serial(left: PartitionedFrame, right: PartitionedFrame,
                       params: dict, stages: Sequence[alg.Stage],
                       stats=None) -> PartitionedFrame:
    """Consumer fusion into JOIN: leading structured selections run against a
    gather of only the predicate's columns and filter the (lidx, ridx) match
    indices; the payload gather then builds only the surviving rows (and only
    the projected columns)."""
    lf, rf = left.to_frame().induce(), right.to_frame().induce()
    lidx, ridx, lvalid, rvalid, drop_right = _join_indices(lf, rf, params)
    preds, proj, rest = _split_consumer_stages(stages)
    row_labels = None
    if preds and lidx.shape[0]:
        refs = sorted(frozenset().union(*[p.refs() for p in preds]), key=repr)
        mini = _gather_join_cols(lf, rf, lidx, ridx, lvalid, rvalid,
                                 drop_right, refs)
        keep = np.asarray(_fused_selection_mask(preds, mini), dtype=bool)
        # the unfused path filters AFTER the join resets its index: surviving
        # rows keep their position in the unfiltered join result as label
        row_labels = RangeLabels(int(lidx.shape[0])).take(np.nonzero(keep)[0])
        lidx, ridx = lidx[keep], ridx[keep]
        lvalid = lvalid[keep] if lvalid is not None else None
        rvalid = rvalid[keep] if rvalid is not None else None
    if stats is not None:
        stats.gather_rows += int(lidx.shape[0])
    keep_cols = frozenset(proj) if proj is not None else None
    out = _assemble_join(lf, rf, lidx, ridx, lvalid, rvalid, drop_right,
                         keep_cols=keep_cols, row_labels=row_labels)
    if proj is not None:
        out = out.take_cols(out.col_labels.positions_of(proj))
    pfo = _output_pf(out)
    if rest:
        pfo = pfo.map_blockwise(lambda b: _run_stages_block(b, rest))
    return pfo


def _assemble_join(lf: Frame, rf: Frame, lidx, ridx, lvalid, rvalid, drop_right,
                   keep_cols: frozenset | None = None, row_labels=None) -> Frame:
    lsrc = lf
    if keep_cols is not None:
        lsrc = lf.take_cols([j for j, n in enumerate(lf.col_labels.to_list())
                             if n in keep_cols])
    lpart = lsrc.take_rows(lidx)
    keep_r = [j for j, n in enumerate(rf.col_labels.to_list())
              if n not in drop_right and (keep_cols is None or n in keep_cols)]
    rpart = rf.take_cols(keep_r).take_rows(ridx)
    lpart = _mask_all(lpart, lvalid)
    rpart = _mask_all(rpart, rvalid)
    out = lpart.concat_cols(rpart)
    if row_labels is None:
        row_labels = RangeLabels(out.nrows)   # reset index
    return Frame(out.columns, row_labels, out.col_labels)


def _mask_all(frame: Frame, valid: np.ndarray | None) -> Frame:
    if valid is None or valid.all():
        return frame
    vmask = jnp.asarray(valid)
    cols = [Column(c.data, c.domain, c.valid_mask() & vmask, c.dictionary) for c in frame.columns]
    return Frame(cols, frame.row_labels, frame.col_labels, frame.row_domains)


# ---- GROUPBY ----------------------------------------------------------------
_COMBINE = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


def _groupby(pf: PartitionedFrame, keys: Sequence[Any], aggs: Sequence[tuple]) -> PartitionedFrame:
    """Row-parallel partial aggregation + tree combine (paper §4.2 Fig. 6).

    groupby(1) is ``keys == ()``: all rows fall into segment 0 and the combine
    is a pure reduction (any partitioning scheme works — paper's point).

    The working grid adapts to the pool width at plan time (same preference
    the fusion pass records on ``FusedGroupBy`` — blocks ≈ workers), so a
    256-partition frame on a 4-worker pool computes ~8 partials, not 256.
    """
    rp = preferred_row_parts(pf.row_parts, GRID_PREFS["groupby"],
                             total_bytes=pf.nbytes())
    pf = pf.repartition(row_parts=rp, col_parts=1)
    row_blocks = [row[0].induce() for row in pf.parts]
    return _groupby_blocks(row_blocks, keys, aggs)


def _groupby_blocks(row_blocks: list, keys: Sequence[Any],
                    aggs: Sequence[tuple]) -> PartitionedFrame:
    # the general factorization needs a global view of every block's keys, so
    # this path materializes all blocks (handles fault here); the fused
    # dense-int path above it is the memory-governed one
    row_blocks = [resolve(b) for b in row_blocks]
    # ---- dense small-range INT key: no host factorization ------------------
    # (paper's groupby(n) benchmark shape: "passenger_count"-like keys).
    # codes = v - min, computed per block in parallel; empty groups dropped
    # after the combine.  Avoids the serial np.unique Amdahl term.
    dense = _dense_int_key(row_blocks, keys) if len(keys) == 1 else None
    if dense is not None:
        vmin, G = dense
        codes_per_block = []
        for b in row_blocks:
            c = b.col(keys[0])
            codes = np.asarray(c.data, dtype=np.int64) - vmin
            if c.mask is not None:
                codes = np.where(np.asarray(c.mask), codes, -1)
            codes_per_block.append(codes.astype(np.int32))
        return _groupby_with_codes(row_blocks, keys, aggs, codes_per_block,
                                   int(G), key_values=[int(vmin) + i for i in range(int(G))],
                                   drop_empty=True)

    # ---- global key factorization (one column set to host) -----------------
    if keys:
        flags = np.zeros(len(keys), dtype=bool)
        for b in row_blocks:
            flags |= _wide_int_flags(b, keys)
        key_mats = [_row_keys(b, keys, flags) for b in row_blocks]
        ids_per_block = _keys_to_ids(*key_mats)
        all_ids = np.concatenate(ids_per_block)
        all_keys = np.concatenate(key_mats, axis=0)
        valid_rows = ~np.isnan(all_keys).any(axis=1)  # pandas drops null keys
        valid_idx = np.nonzero(valid_rows)[0]
        uniq_ids, first = np.unique(all_ids[valid_rows], return_index=True)
        first_global = valid_idx[first]
        # decode representative key VALUES (O(G·K) single lookups) so output
        # groups sort lexicographically by value, not by hash/code
        offsets = np.cumsum([0] + [b.nrows for b in row_blocks])
        def decode_row(gidx: int) -> tuple:
            bi = int(np.searchsorted(offsets, gidx, side="right") - 1)
            local = int(gidx - offsets[bi])
            return tuple(row_blocks[bi].col(k).value_at(local) for k in keys)
        rep_vals = [decode_row(int(gi)) for gi in first_global]
        perm = sorted(range(len(rep_vals)), key=lambda i: tuple(
            (str(type(v)), v) if not isinstance(v, (int, float, bool)) else ("num", v)
            for v in rep_vals[i]))
        order = uniq_ids[np.asarray(perm, dtype=np.int64)] if len(perm) else uniq_ids
        rep_sorted = [rep_vals[i] for i in perm]
        G = len(order)
        n_ids = int(all_ids.max()) + 1 if all_ids.size else 0
        remap = np.full(n_ids, NULL_CODE, dtype=np.int32)
        remap[order] = np.arange(G, dtype=np.int32)
        codes_per_block = [remap[ids] if ids.size else ids.astype(np.int32)
                           for ids in ids_per_block]
    else:
        G = 1
        rep_sorted = None
        codes_per_block = [np.zeros(b.nrows, dtype=np.int32) for b in row_blocks]
    return _groupby_with_codes(row_blocks, keys, aggs, codes_per_block, G,
                               rep_sorted=rep_sorted)


def _dense_int_key(row_blocks: list[Frame], keys) -> tuple[int, int] | None:
    """(vmin, G) when the single key column is INT with a small value range —
    codes are then ``v - vmin`` with no host factorization."""
    try:
        cols = [b.col(keys[0]) for b in row_blocks]
    except KeyError:
        return None
    if any(c.domain is not Domain.INT for c in cols):
        return None
    vmin, vmax = None, None
    for c in cols:
        v = np.asarray(c.data, dtype=np.int64)
        if c.mask is not None:
            mask = np.asarray(c.mask)
            if not mask.any():
                continue
            v = v[mask]
        if v.size == 0:
            continue
        lo, hi = int(v.min()), int(v.max())
        vmin = lo if vmin is None else min(vmin, lo)
        vmax = hi if vmax is None else max(vmax, hi)
    if vmin is None:
        return None
    g = vmax - vmin + 1
    if g > 65536:
        return None
    return vmin, g


def _agg_need(aggs) -> list[tuple[Any, str]]:
    """The (column, base-statistic) partial vectors an agg list requires."""
    need: list[tuple[Any, str]] = []
    for col_label, func, _ in aggs:
        for base in _bases_for(func):
            if (col_label, base) not in need:
                need.append((col_label, base))
    return need


_PRESENCE = ("__presence__", "sum")


def _block_partial(block: Frame, codes, G: int, need: Sequence[tuple],
                   presence: bool) -> dict:
    """Per-block partial aggregates as ONE compiled program
    (``kernels.ops.segment_reduce_multi``): null masking, squaring, presence
    (so empty dense-range slots drop after the combine), and one
    ``segment_reduce`` per reduce op with same-op columns batched (M, C)."""
    outs = kops.segment_reduce_multi(
        [block.col(col_label).data for col_label, _ in need],
        [block.col(col_label).mask for col_label, _ in need],
        codes, bases=[base for _, base in need], num_segments=G,
        presence=presence)
    result = {key: outs[i] for i, key in enumerate(need)}
    if presence:
        result[_PRESENCE] = outs[len(need)]
    return result


def _combine_partials(partials: Sequence[dict], want: Sequence[tuple]) -> dict:
    """Tree combine of per-block partials (G-sized, tiny vs data)."""
    combined: dict[tuple, jnp.ndarray] = {}
    for key in want:
        base = key[1]
        parts = [p[key] for p in partials]
        acc = parts[0]
        for nxt in parts[1:]:
            if base in ("sum", "count", "sumsq"):
                acc = acc + nxt
            elif base == "min":
                acc = jnp.minimum(acc, nxt)
            else:
                acc = jnp.maximum(acc, nxt)
        combined[key] = acc
    return combined


def _groupby_with_codes(row_blocks: list[Frame], keys, aggs, codes_per_block,
                        G: int, rep_sorted=None, key_values=None,
                        drop_empty: bool = False) -> PartitionedFrame:
    # ---- per-block partials (parallel; MXU segment_reduce) ------------------
    need = _agg_need(aggs)

    def block_partial(args) -> dict:
        block, codes = args
        return _block_partial(block, codes, G, need, presence=drop_empty)

    partials = dispatch_blocks(block_partial, list(zip(row_blocks, codes_per_block)))
    want = need + [_PRESENCE] if drop_empty else need
    combined = _combine_partials(partials, want)
    return _finalize_groupby(combined, row_blocks[0] if row_blocks else None,
                             keys, aggs, G, rep_sorted, key_values, drop_empty)


def _finalize_groupby(combined: dict, template: Frame | None, keys, aggs,
                      G: int, rep_sorted=None, key_values=None,
                      drop_empty: bool = False) -> PartitionedFrame:
    out_cols: list[Column] = []
    out_names: list[Any] = []
    # key columns first (representative decoded values, sorted order)
    if keys and key_values is not None:      # dense-int fast path
        out_cols.append(_host_column(list(key_values), Domain.INT))
        out_names.append(keys[0])
    elif keys:
        template = resolve(template)   # only this branch needs block data
        for kpos, kname in enumerate(keys):
            src = template.col(kname)
            vals = [r[kpos] for r in rep_sorted]
            dom = src.domain if src.domain is not Domain.UNSPECIFIED else None
            out_cols.append(_host_column(vals, dom))
            out_names.append(kname)
    for col_label, func, out_label in aggs:
        cnt = combined.get((col_label, "count"))
        if func == "count":
            vals = cnt
        elif func == "sum":
            vals = combined[(col_label, "sum")]
        elif func == "mean":
            vals = combined[(col_label, "sum")] / jnp.maximum(cnt, 1.0)
        elif func in ("min", "max"):
            vals = combined[(col_label, func)]
        elif func in ("var", "std"):
            s, ss = combined[(col_label, "sum")], combined[(col_label, "sumsq")]
            var = (ss - s * s / jnp.maximum(cnt, 1.0)) / jnp.maximum(cnt - 1.0, 1.0)
            vals = jnp.sqrt(jnp.maximum(var, 0.0)) if func == "std" else var
        elif func == "any":
            vals = (combined[(col_label, "max")] > 0).astype(jnp.float32)
        elif func == "all":
            vals = (combined[(col_label, "min")] > 0).astype(jnp.float32)
        else:
            raise ValueError(func)
        mask = cnt > 0 if cnt is not None else None
        dom = Domain.INT if func == "count" else (Domain.BOOL if func in ("any", "all") else Domain.FLOAT)
        data = vals.astype(storage_dtype(dom))
        out_cols.append(Column(data, dom, mask if func != "count" else None, None))
        out_names.append(out_label)

    frame = Frame(out_cols, RangeLabels(G), labels_from_values(out_names))
    if drop_empty:
        present = np.asarray(combined[("__presence__", "sum")]) > 0
        frame = frame.filter_rows(present)
    return _output_pf(frame)


def _bases_for(func: str) -> tuple[str, ...]:
    return {
        "sum": ("sum", "count"), "count": ("count",), "mean": ("sum", "count"),
        "min": ("min", "count"), "max": ("max", "count"),
        "var": ("sum", "sumsq", "count"), "std": ("sum", "sumsq", "count"),
        "any": ("max", "count"), "all": ("min", "count"),
    }[func]


def _host_column(values: list, domain: Domain) -> Column:
    if domain is Domain.INT:
        ints = [int(v) for v in values if v is not None]
        if ints and not all(-2 ** 31 <= v < 2 ** 31 for v in ints):
            # decoded groupby keys beyond int32: exact int64 HOST storage
            # (parse_column would raise — int64 must never reach jnp.asarray,
            # which truncates without x64; this column is only inspected /
            # re-keyed on host)
            data = np.asarray([0 if v is None else int(v) for v in values],
                              dtype=np.int64)
            mask = np.asarray([v is not None for v in values])
            return Column(data, Domain.INT,
                          None if mask.all() else mask, None)
    p = parse_column(values, domain)
    return Column(p.data, p.domain, p.mask, p.dictionary)


# ---- FUSED GROUPBY: producer chain inside the partial-aggregation program ----
def _fused_groupby(pf: PartitionedFrame, stages: Sequence[alg.Stage],
                   keys: Sequence[Any], aggs: Sequence[tuple],
                   grid: str | None = None) -> PartitionedFrame:
    """Producer fusion into GROUPBY (Cylon-style local-pattern fusion into the
    shuffle stage): the row-local chain runs inside the groupby's own
    per-block programs instead of materializing between the two.

    Pass A (one dispatch per partition) runs the whole producer sweep and
    extracts the block's key span — cheap host stats, no aggregation yet, so
    nothing is computed speculatively.  The spans agree on ONE global dense
    range, and pass B (one dispatch per partition) computes codes against it
    plus all ``segment_reduce`` partials in a single compiled program
    (``kernels.ops.segment_reduce_multi``) — a global static G means one XLA
    executable shared by every block and every query on the same schema,
    where per-block local ranges would recompile per distinct span.  Keys
    that don't qualify (non-INT, multi-key, range > 65536) fall back to the
    general factorization over the staged blocks — the producer sweep still
    ran fused, in one pool round instead of one per operator."""
    pf1 = pf.repartition(col_parts=1)
    blocks = [row[0] for row in pf1.handles]
    single_key = len(keys) == 1

    def stage_block(block):
        with pinned(block) as src:
            f = _run_stages_block(src, stages).induce()
            info = None
            if single_key:
                try:
                    c = f.col(keys[0])
                except KeyError:
                    c = None
                if c is not None and c.domain is Domain.INT:
                    v = np.asarray(c.data, dtype=np.int64)
                    if c.mask is not None:
                        v = v[np.asarray(c.mask)]
                    info = (int(v.min()), int(v.max())) if v.size else "empty"
            # staged output back into the store: under a budget it can spill
            # before the partial pass returns for it
            hout = block if f is src else as_handle(
                f, recompute=lambda: _run_stages_block(
                    resolve(block), stages).induce())
        return hout, info

    results = dispatch_blocks(stage_block, blocks)
    staged = [r[0] for r in results]
    infos = [r[1] for r in results]

    # plan-time grid adaptation: regroup the STAGED blocks to the recorded
    # preference (blocks ≈ workers) before the partial pass.  Staging first
    # and regridding second is what keeps the fused plan bit-identical to its
    # unfused counterpart — the unfused GROUPBY receives exactly this staged
    # block sequence as its materialized input and makes the same regroup
    # decision, so both paths compute partials over the same row groupings.
    # (Key spans are global min/max — regrouping cannot change them.)
    rp = preferred_row_parts(len(staged), grid or GRID_PREFS["fused_groupby"],
                             total_bytes=sum(h.nbytes for h in staged))
    if rp != len(staged):
        staged = [row[0] for row in
                  PartitionedFrame([[b] for b in staged])
                  .repartition(row_parts=rp).handles]

    spans = [i for i in infos if isinstance(i, tuple)]
    if single_key and spans and all(i is not None for i in infos):
        gmin = min(i[0] for i in spans)
        G = max(i[1] for i in spans) - gmin + 1
        if G <= 65536:
            need = _agg_need(aggs)

            def partial_block(block) -> dict:
                with pinned(block) as f:
                    c = f.col(keys[0])
                    codes = np.asarray(c.data, dtype=np.int64) - gmin
                    if c.mask is not None:
                        codes = np.where(np.asarray(c.mask), codes, -1)
                    return _block_partial(f, codes.astype(np.int32), G, need,
                                          presence=True)

            partials = dispatch_blocks(partial_block, staged)
            combined = _combine_partials(partials, need + [_PRESENCE])
            return _finalize_groupby(combined, staged[0], keys, aggs, G,
                                     key_values=[gmin + i for i in range(G)],
                                     drop_empty=True)

    # general path over the staged blocks: factorization needs a global view,
    # but the whole producer sweep still ran as one fused pool round
    return _groupby_blocks(staged, keys, aggs)


# ---- SORT ---------------------------------------------------------------
def _sort_perm(f: Frame, by: Sequence[Any], ascending: bool) -> np.ndarray:
    """The sort permutation: position i of the result comes from row idx[i]."""
    key_cols = []
    for v in _sort_rank_keys(f, by):
        # nulls (NaN) sort last regardless of direction
        v = np.where(np.isnan(v), np.inf if ascending else -np.inf, v)
        key_cols.append(v)
    if ascending:
        return np.lexsort(tuple(reversed(key_cols)))   # stable; first key primary
    return np.lexsort(tuple(-k for k in reversed(key_cols)))


def _sort(pf: PartitionedFrame, by: Sequence[Any], ascending: bool,
          stats=None) -> PartitionedFrame:
    from . import shuffle as _shuffle
    if _shuffle.enabled() and len(by):
        return _shuffle.shuffled_sort(pf, by, ascending, (), stats)
    return _sort_serial(pf, by, ascending, stats)


def _sort_serial(pf: PartitionedFrame, by: Sequence[Any], ascending: bool,
                 stats=None) -> PartitionedFrame:
    """The whole-frame oracle path (``REPRO_SHUFFLE=0``; also empty ``by``,
    which must raise exactly like ``np.lexsort(())``)."""
    f = pf.to_frame().induce()
    idx = _sort_perm(f, by, ascending)
    if stats is not None:
        stats.gather_rows += int(idx.shape[0])
    return _output_pf(f.take_rows(idx))


def _split_consumer_stages(stages: Sequence[alg.Stage]):
    """Split a consumer chain into (pushable predicates, gather projection,
    remaining stages).  Leading structured-``Expr`` selections are evaluated
    against the *pre-gather* frame (row-local predicates are permutation-
    invariant) and filter the gather index; an immediately following
    projection prunes the gathered columns.  Everything after the first
    MAP/RENAME (value/name changes) runs post-gather."""
    preds: list[alg.Expr] = []
    i = 0
    while (i < len(stages) and stages[i].op == "selection"
           and isinstance(stages[i].params["predicate"], alg.Expr)):
        preds.append(stages[i].params["predicate"])
        i += 1
    proj = None
    if i < len(stages) and stages[i].op == "projection":
        proj = stages[i].params["cols"]
        i += 1
    return preds, proj, stages[i:]


def _fused_sort(pf: PartitionedFrame, by: Sequence[Any], ascending: bool,
                stages: Sequence[alg.Stage], stats=None,
                grid: str | None = None) -> PartitionedFrame:
    from . import shuffle as _shuffle
    if _shuffle.enabled() and len(by):
        return _shuffle.shuffled_sort(pf, by, ascending, stages, stats,
                                      grid=grid)
    return _fused_sort_serial(pf, by, ascending, stages, stats)


def _fused_sort_serial(pf: PartitionedFrame, by: Sequence[Any],
                       ascending: bool, stages: Sequence[alg.Stage],
                       stats=None) -> PartitionedFrame:
    """Consumer fusion into SORT: selections filter the permutation *index*
    before the payload gather, so the materialized frame is built once,
    post-filter, instead of gathered-then-filtered."""
    f = pf.to_frame().induce()
    idx = _sort_perm(f, by, ascending)
    preds, proj, rest = _split_consumer_stages(stages)
    if preds:
        # evaluate on the UNSORTED frame (row-local ⇒ permutation-invariant):
        # no gather happens before the filter
        keep = np.asarray(_fused_selection_mask(preds, f), dtype=bool)
        idx = idx[keep[idx]]
    g = f.take_cols(f.col_labels.positions_of(proj)) if proj is not None else f
    if stats is not None:
        stats.gather_rows += int(idx.shape[0])
    out = _output_pf(g.take_rows(idx))
    if rest:
        out = out.map_blockwise(lambda b: _run_stages_block(b, rest))
    return out


# ---- WINDOW -------------------------------------------------------------
def _window_targets(frame: Frame, cols) -> list:
    if cols:
        return list(cols)
    return [n for n, c in zip(frame.col_labels.to_list(), frame.columns)
            if c.domain.is_numeric]


def _window(pf: PartitionedFrame, func: str, cols, size, periods,
            pre: Sequence[alg.Stage] = (), post: Sequence[alg.Stage] = (),
            grid: str | None = None) -> PartitionedFrame:
    """WINDOW, optionally with fused row-local chains: ``pre`` stages run in
    the same per-block program as the local scan, ``post`` stages in the same
    per-block program as the carry application (the carry combine sits between
    the two, exactly where the unfused path placed it).

    The working grid adapts to the pool width at plan time ("few_seams" —
    every partition boundary costs a carry composition / halo build, so the
    grid never oversubscribes the worker set by more than the coalescing
    slack).  Row-dropping pre-stages are staged on the *incoming* grid before
    the regroup: the unfused plan filters per incoming block and regrids the
    filtered result, so staging first is what keeps seam placement — and
    therefore carry composition — bit-identical between the two plans.
    Row-preserving pre-stages (elementwise map / projection / rename) are
    pointwise, so they stay fused into the scan program: regridding before or
    after them lands the seams on the same rows either way."""
    rp = preferred_row_parts(pf.row_parts, grid or GRID_PREFS["window"],
                             total_bytes=pf.nbytes())
    if rp != pf.row_parts and any(st.op == "selection" for st in pre):
        pf = pf.repartition(col_parts=1).map_blockwise(
            lambda b: _run_stages_block(b, pre))
        pre = ()
    pf = pf.repartition(row_parts=rp, col_parts=1)

    if func in ("cumsum", "cummax", "cummin", "cumprod"):
        # cumprod: per-block scan + multiplicative carry (kept exact — no
        # log-space trick)
        return _window_scan_blocks(pf, func, cols, pre, post)

    # halo/rolling paths need the staged blocks before the halo tails are
    # built; the producer chain still runs as ONE fused pool round
    if pre:
        pf = pf.map_blockwise(lambda b: _run_stages_block(b, pre))
    template = pf.parts[0][0].induce()
    targets = _window_targets(template, cols)

    if func in ("diff", "shift"):
        return _window_halo(pf, func, targets, periods, post)
    if func in ("rolling_sum", "rolling_mean"):
        assert size is not None, "rolling window requires size"
        # rolling(w) = cumsum − shift(cumsum, w); first w−1 rows are null
        csum = _window_scan_blocks(pf, "cumsum", targets)
        shifted = _window_halo(csum, "shift", targets, size)
        out = _rolling_combine(csum, shifted, targets, size,
                               mean=(func == "rolling_mean"))
        if post:
            out = out.map_blockwise(lambda b: _run_stages_block(b, post))
        return out
    raise ValueError(func)


def _apply_cols(frame: Frame, targets, fn: Callable[[Column], Column]) -> Frame:
    cols = list(frame.columns)
    names = frame.col_labels.to_list()
    for j, n in enumerate(names):
        if n in targets:
            cols[j] = fn(cols[j])
    return Frame(cols, frame.row_labels, frame.col_labels, frame.row_domains)


def _carry_combine(func: str, a, b):
    if func == "cumsum":
        return a + b
    if func == "cummax":
        return jnp.maximum(a, b)
    if func == "cummin":
        return jnp.minimum(a, b)
    return a * b   # cumprod


def _window_scan_blocks(pf: PartitionedFrame, func: str, cols,
                        pre: Sequence[alg.Stage] = (),
                        post: Sequence[alg.Stage] = ()) -> PartitionedFrame:
    """Blocked scan with cross-block carry composition, in two parallel
    per-block passes: (pre-stages + local scan + block total), then a tiny
    host-side exclusive combine of the totals, then (carry application +
    post-stages).  The scan ops are associative and commutative over the
    identity-filled values, so exclusive-combining the *local* totals is
    bitwise the same carry the old serial tail-chaining produced — and the
    carry application now runs block-parallel instead of serially."""
    blocks = [row[0] for row in pf.handles]

    def local(block):
        def scan_col(c: Column) -> Column:
            v = jnp.where(c.valid_mask(), c.data.astype(jnp.float32),
                          _scan_identity(func))
            if func == "cumprod":
                out = jnp.cumprod(v, axis=0)
            else:
                out = kops.window_scan(v, func)
            return Column(out.astype(jnp.float32), Domain.FLOAT, c.mask, None)

        def build(src):
            f = _run_stages_block(src, pre).induce() if pre else src.induce()
            targets = _window_targets(f, cols)
            return _apply_cols(f, targets, scan_col), targets

        with pinned(block) as src:
            scanned, targets = build(src)
            totals = ({n: scanned.col(n).data[-1] for n in targets}
                      if scanned.nrows else {})
            return (as_handle(scanned,
                              recompute=lambda: build(resolve(block))[0]),
                    totals, targets)

    locals_ = dispatch_blocks(local, blocks)

    # exclusive combine of block totals → per-block carries (host, tiny)
    carries: list[dict] = []
    acc: dict[Any, Any] = {}
    for _scanned, totals, _targets in locals_:
        carries.append(dict(acc))
        for n, t in totals.items():
            acc[n] = t if n not in acc else _carry_combine(func, acc[n], t)

    if not post and not any(carries):
        return PartitionedFrame([[item[0]] for item in locals_])

    def apply(args):
        (block, _totals, targets), carry = args

        def build(scanned):
            if carry:
                cols_ = list(scanned.columns)
                names = scanned.col_labels.to_list()
                for j, n in enumerate(names):
                    if n in targets and n in carry:
                        v = _carry_combine(func, cols_[j].data, carry[n])
                        cols_[j] = Column(v, cols_[j].domain, cols_[j].mask, None)
                scanned = Frame(cols_, scanned.row_labels, scanned.col_labels,
                                scanned.row_domains)
            return _run_stages_block(scanned, post) if post else scanned

        with pinned(block) as scanned:
            out = build(scanned)
            return block if out is scanned else as_handle(
                out, recompute=lambda: build(resolve(block)))

    out = dispatch_blocks(apply, list(zip(locals_, carries)))
    return PartitionedFrame([[b] for b in out])


def _scan_identity(func: str):
    return {"cumsum": 0.0, "cummax": -jnp.inf, "cummin": jnp.inf, "cumprod": 1.0}[func]


def _window_halo(pf: PartitionedFrame, func: str, targets, periods: int,
                 post: Sequence[alg.Stage] = ()) -> PartitionedFrame:
    """diff/shift via a ``periods``-row halo — the running tail of everything
    before the block (a single block may be shorter than ``periods``).
    ``post`` stages run inside the same per-block program."""
    blocks = [row[0] for row in pf.handles]

    # round 1 (parallel): induce each block ONCE and extract its tail — the
    # only rows that can ever reach a later block's halo.  The induced form
    # goes back into the store, so blocks are induced exactly once even when
    # the budget spills them between the rounds.
    def prep(h):
        with pinned(h) as raw:
            f = raw.induce()
            return (h if f is raw
                    else as_handle(f,
                                   recompute=lambda: resolve(h).induce())), \
                f.tail(periods)

    prepped = dispatch_blocks(prep, blocks)

    # serial compose of the tiny tails → per-block running halos (a block's
    # rows beyond its last ``periods`` can never appear in any halo, so
    # composing tails is exact — same recurrence the per-block sweep used)
    halos: list[Frame | None] = [None]
    running: Frame | None = None
    for _h, tail in prepped[:-1]:
        running = tail if running is None else (
            running.concat_rows(tail).tail(periods))
        halos.append(running)

    def local(args):
        (blk, _tail), halo = args
        with pinned(blk) as f:
            return as_handle(_halo_block(f, halo),
                             recompute=lambda: _halo_block(resolve(blk), halo))

    def _halo_block(block: Frame, halo: Frame | None) -> Frame:
        ext = halo.concat_rows(block) if halo is not None else block
        pad = ext.nrows - block.nrows

        def do(c_name) -> Column:
            c = ext.col(c_name)
            v = c.data.astype(jnp.float32)
            valid = c.valid_mask()
            prev = jnp.roll(v, periods)
            prev_valid = jnp.roll(valid, periods)
            rowpos = jnp.arange(ext.nrows)
            in_range = rowpos >= periods
            if func == "diff":
                out = v - prev
                mask = valid & prev_valid & in_range
            else:  # shift
                out = prev
                mask = prev_valid & in_range
            return Column(out[pad:], Domain.FLOAT, mask[pad:], None)

        cols = list(block.columns)
        names = block.col_labels.to_list()
        for j, n in enumerate(names):
            if n in targets:
                cols[j] = do(n)
        got = Frame(cols, block.row_labels, block.col_labels, block.row_domains)
        return _run_stages_block(got, post) if post else got

    out = dispatch_blocks(local, list(zip(prepped, halos)))
    return PartitionedFrame([[b] for b in out])


def _rolling_combine(csum: PartitionedFrame, shifted: PartitionedFrame, targets,
                     size: int, mean: bool) -> PartitionedFrame:
    rows = []
    offset = 0
    for (crow, srow) in zip(csum.parts, shifted.parts):
        cb, sb = crow[0], srow[0]
        cols = list(cb.columns)
        names = cb.col_labels.to_list()
        rowpos = jnp.arange(cb.nrows) + offset
        full = rowpos >= size - 1
        for j, n in enumerate(names):
            if n in targets:
                c, s = cb.col(n), sb.col(n)
                base = jnp.where(s.valid_mask(), s.data, 0.0)
                out = c.data - base
                if mean:
                    out = out / size
                cols[j] = Column(out, Domain.FLOAT, c.valid_mask() & full, None)
        rows.append([Frame(cols, cb.row_labels, cb.col_labels)])
        offset += cb.nrows
    return PartitionedFrame(rows)


# ---- TRANSPOSE ----------------------------------------------------------
def _transpose(pf: PartitionedFrame) -> PartitionedFrame:
    """Grid transpose: per-block kernel transpose + grid metadata swap."""
    def block_t(frame: Frame) -> Frame:
        # No induction: coded-ness is decidable from declared domains, and
        # UNSPECIFIED columns (a prior transpose's output) are numeric storage
        # whose logical schema is recovered via row_domains downstream.
        f = frame
        tgt = common_storage(f.schema)
        if tgt.is_coded:
            return _transpose_coded(f.induce())
        mat, dom = f.as_matrix(tgt if tgt is not Domain.UNSPECIFIED else Domain.FLOAT)
        out = kops.transpose(mat)
        masks = [c.mask for c in f.columns]
        out_mask = None
        if any(m is not None for m in masks):
            mm = jnp.stack([c.valid_mask() for c in f.columns], axis=1)
            out_mask = np.asarray(kops.transpose(mm))
        # Wide-output fast path ("billions of columns", paper §4.2): one
        # device→host materialization, then zero-copy numpy views per column —
        # NOT n_cols separate device slices (O(µs) dispatch each).
        out_np = np.asarray(out)
        # second-transpose schema recovery (paper §3.3): the child's recorded
        # row-type vector (length == child.nrows == our ncols) gives the
        # output schema without re-running S(·) over values.
        rec = f.row_domains if (f.row_domains is not None
                                and len(f.row_domains) == f.nrows) else None
        new_cols = []
        for i in range(f.nrows):
            dom = rec[i] if rec is not None else Domain.UNSPECIFIED
            data = out_np[:, i]
            if rec is not None:
                data = data.astype(storage_dtype(dom))
            new_cols.append(Column(
                data, dom,
                None if out_mask is None else out_mask[:, i],
                None))
        return Frame(new_cols, f.col_labels, f.row_labels, row_domains=f.schema)

    return pf.transpose_grid(block_t)


def _transpose_coded(f: Frame) -> Frame:
    """Heterogeneous/string transpose: host re-encode (paper: coerce to
    Object; schema induction recovers on a second transpose)."""
    records = f.to_records()
    rec = f.row_domains if (f.row_domains is not None
                            and len(f.row_domains) == f.nrows) else None
    new_cols = []
    for i in range(f.nrows):
        vals = [records[i][j] for j in range(f.ncols)]
        if rec is not None:
            new_cols.append(_host_column(vals, rec[i]))
        else:
            new_cols.append(_host_column(
                [None if v is None else str(v) for v in vals], Domain.STR))
    return Frame(new_cols, f.col_labels, f.row_labels, row_domains=f.schema)


# ---- MAP ------------------------------------------------------------------
def _apply_udf_block(frame: Frame, udf: alg.Udf) -> Frame:
    """Run a Udf over one block (also the per-stage body of fused pipelines)."""
    f = frame.induce()
    cols_in = {n: c for n, c in zip(f.col_labels.to_list(), f.columns)}
    out = udf.fn(cols_in, f)
    if isinstance(out, Frame):
        return out
    # dict {label: Column | array | (array, mask)} preserving row count
    names, cols = [], []
    for name, v in out.items():
        names.append(name)
        if isinstance(v, Column):
            cols.append(v)
        elif isinstance(v, tuple):
            data, mask = v
            cols.append(Column(jnp.asarray(data), _infer_dom(data), mask, None))
        else:
            arr = jnp.asarray(v)
            cols.append(Column(arr, _infer_dom(arr), None, None))
    return Frame(cols, f.row_labels, labels_from_values(names))


def _map(pf: PartitionedFrame, udf: alg.Udf) -> PartitionedFrame:
    if udf.elementwise:
        return pf.repartition(col_parts=1).map_blockwise(
            lambda f: _apply_udf_block(f, udf))
    return PartitionedFrame.from_frame(_apply_udf_block(pf.to_frame(), udf))


def _infer_dom(arr) -> Domain:
    d = jnp.asarray(arr).dtype
    if d == jnp.bool_:
        return Domain.BOOL
    if jnp.issubdtype(d, jnp.integer):
        return Domain.INT
    return Domain.FLOAT


# ---- label movement ---------------------------------------------------------
def _to_labels(pf: PartitionedFrame, column: Any) -> PartitionedFrame:
    def conv(frame: Frame) -> Frame:
        f = frame.induce()
        j = f.col_labels.position_of(column)
        c = f.columns[j]
        labels = labels_from_values(c.to_pylist(), c.domain)
        keep = [x for x in range(f.ncols) if x != j]
        g = f.take_cols(keep)
        return Frame(g.columns, labels, g.col_labels)
    return pf.repartition(col_parts=1).map_blockwise(conv)


def _from_labels(pf: PartitionedFrame, label: Any) -> PartitionedFrame:
    pf = pf.repartition(col_parts=1)
    offsets = pf.row_block_offsets()

    def conv(args):
        (block, start) = args

        def build(f):
            vals = f.row_labels.to_list()
            c = _host_column(vals, Domain.INT if isinstance(f.row_labels, (RangeLabels, IntLabels)) else None)
            return Frame([c] + list(f.columns),
                         RangeLabels(f.nrows, start),
                         labels_from_values([label]).concat(f.col_labels))

        with pinned(block) as f:
            return as_handle(build(f), recompute=lambda: build(resolve(block)))

    out = dispatch_blocks(conv, [(row[0], offsets[i])
                                 for i, row in enumerate(pf.handles)])
    return PartitionedFrame([[b] for b in out])


def _rename_block(frame: Frame, mapping: dict) -> Frame:
    names = [mapping.get(n, n) for n in frame.col_labels.to_list()]
    return Frame(frame.columns, frame.row_labels, labels_from_values(names), frame.row_domains)


def _rename(pf: PartitionedFrame, mapping_items) -> PartitionedFrame:
    mapping = dict(mapping_items)
    return pf.map_blockwise(lambda frame: _rename_block(frame, mapping))


def _limit(pf: PartitionedFrame, k: int, tail: bool) -> PartitionedFrame:
    # Touch only the row blocks the prefix/suffix needs (§6.1.2).
    if not tail:
        f = pf.prefix(k).to_frame()
        return PartitionedFrame.from_frame(f.head(k))
    need, keep = k, []
    for i in range(pf.row_parts - 1, -1, -1):
        keep.insert(0, pf.handles[i])
        need -= pf.handles[i][0].nrows
        if need <= 0:
            break
    f = PartitionedFrame(keep).to_frame()
    return PartitionedFrame.from_frame(f.tail(k))


# ---- rewrite targets: column-space ops without any TRANSPOSE (paper §5) ------
def _key_rows_matrix(pf: PartitionedFrame, row_names: Sequence[Any]) -> np.ndarray:
    """(len(row_names), ncols) float64 matrix of the named rows' values."""
    pf1 = pf.repartition(col_parts=1)
    offsets = pf1.row_block_offsets()
    rows = []
    for name in row_names:
        found = None
        for bi, row in enumerate(pf1.parts):
            try:
                local = row[0].row_labels.position_of(name)
                found = (bi, local)
                break
            except KeyError:
                continue
        if found is None:
            raise KeyError(name)
        bi, local = found
        one = pf1.parts[bi][0].take_rows(np.asarray([local]))
        rows.append(_row_keys(one.induce(), None)[0])
    return np.stack(rows, axis=0)


def _column_sort(pf: PartitionedFrame, by: Sequence[Any], ascending: bool) -> PartitionedFrame:
    keys = _key_rows_matrix(pf, by)                       # (K, n)
    if ascending:
        perm = np.lexsort(tuple(reversed([k for k in keys])))
    else:
        perm = np.lexsort(tuple(reversed([-k for k in keys])))
    pf1 = pf.repartition(col_parts=1)
    return pf1.map_blockwise(lambda f: f.take_cols(perm.tolist()))


def _column_filter(pf: PartitionedFrame, predicate: alg.Expr) -> PartitionedFrame:
    refs = sorted(predicate.refs(), key=repr)
    keys = _key_rows_matrix(pf, refs)                     # (K, n)
    n = keys.shape[1]
    temp = Frame(
        [Column(jnp.asarray(keys[i].astype(np.float32)), Domain.FLOAT) for i in range(len(refs))],
        RangeLabels(n),
        labels_from_values(list(refs)),
    )
    keep = _predicate_mask(temp, predicate)
    idx = np.nonzero(keep)[0].tolist()
    pf1 = pf.repartition(col_parts=1)
    return pf1.map_blockwise(lambda f: f.take_cols(idx))


# =============================================================================
# FUSED PIPELINE (paper §5): one per-block program for a row-local chain
# =============================================================================
def _eval_expr_env(expr: alg.Expr, env: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``eval_expr`` over a plain {name: (values, mask)} environment — the
    jit-traceable entry used by compiled predicate chains (no Frame objects,
    no coded columns; callers gate on that).  Same interpreter core as
    ``eval_expr``, so fused and unfused predicates cannot diverge."""
    nrows = next(iter(env.values()))[0].shape[0]
    return _eval_expr_core(expr, env.__getitem__, nrows)


# Compiled predicate-chain programs, keyed by the combined expression's
# structural key.  One XLA executable evaluates the whole chain → bool keep
# mask; jit's own shape cache handles the (±1-row) block-size variants.
# Bounded FIFO: predicates with varying literals each get a distinct key, so
# an unbounded dict would leak one compiled program per literal seen.
_PRED_JIT: dict[tuple, Callable] = {}
_PRED_JIT_LOCK = threading.Lock()
_PRED_JIT_MAX = 256


def _compiled_predicate(expr: alg.Expr, refs: tuple) -> Callable:
    key = expr.key()
    with _PRED_JIT_LOCK:
        fn = _PRED_JIT.get(key)
        if fn is None:
            def prog(datas, masks):
                env = {r: (d, m) for r, d, m in zip(refs, datas, masks)}
                v, mask = _eval_expr_env(expr, env)
                return v.astype(jnp.bool_) & mask
            while len(_PRED_JIT) >= _PRED_JIT_MAX:
                _PRED_JIT.pop(next(iter(_PRED_JIT)))
            fn = _PRED_JIT[key] = jax.jit(prog)
    return fn


def _fused_selection_mask(preds: Sequence[alg.Expr], frame: Frame) -> np.ndarray:
    """keep-mask for a run of structured predicates, as ONE device program.

    ANDing before filtering is exact: predicates are row-local, so a row
    removed by an earlier selection contributes False to the conjunction
    regardless of its later-predicate value."""
    combined = preds[0]
    for p in preds[1:]:
        combined = alg.BinExpr("&", combined, p)
    refs = tuple(sorted(combined.refs(), key=repr))
    if not refs:
        return _predicate_mask(frame, combined)
    try:
        cols = [frame.col(r) for r in refs]
    except KeyError:
        return _predicate_mask(frame, combined)
    if any(c.domain.is_coded for c in cols):
        # coded columns need host code-table translation → interpreted path
        return _predicate_mask(frame, combined)
    if any(c.domain is Domain.INT and c.data.dtype.itemsize > 4
           for c in cols) or _has_wide_lit(combined):
        # wide int64 host columns / out-of-int32 literals would truncate (or
        # fail to trace) through the jit boundary (no x64): the interpreted
        # path handles them in 64-bit host arithmetic.  dtype check on the
        # array object itself — np.asarray here would device-transfer every
        # predicate column on an accelerator backend.
        return _predicate_mask(frame, combined)
    fn = _compiled_predicate(combined, refs)
    keep = fn([c.data for c in cols], [c.valid_mask() for c in cols])
    return np.asarray(keep)


# Compiled map-run programs: a run of consecutive elementwise MAP stages
# traced as ONE XLA program per (udf chain, input schema).  Value None marks a
# chain that failed to trace (host-side numpy, data-dependent structure, ...)
# or whose traced output diverged from the eager path on the probe block —
# those chains stay on eager per-stage dispatch.  Bounded FIFO like _PRED_JIT.
_MAP_JIT: dict[tuple, tuple | None] = {}
_MAP_JIT_LOCK = threading.Lock()
_MAP_JIT_MAX = 128
_MAP_JIT_MISS = object()


def _run_map_stages_eager(frame: Frame, udfs: Sequence[alg.Udf]) -> Frame:
    cur = frame
    for u in udfs:
        cur = _apply_udf_block(cur, u)
    return cur


def _jit_udfs_enabled() -> bool:
    """Same dispatch policy as ``kernels.ops.use_pallas``: on CPU the host
    numpy eager path is the tuned one (a per-block XLA dispatch plus the
    pass-through column round-trips costs more than the memcpy-level work it
    replaces); on an accelerator the one-program-per-chain form wins.  Set
    ``REPRO_JIT_UDFS=1`` to force jit-traced map runs anywhere, ``=0`` to
    force eager anywhere."""
    flag = os.environ.get("REPRO_JIT_UDFS", "")
    if flag == "0":
        return False
    if flag:
        return True
    return jax.default_backend() != "cpu"


def _map_run_program(udfs: Sequence[alg.Udf], names: tuple, domains: tuple):
    """jit-traced whole-chain map run over a plain (datas, masks) environment.
    Output metadata (names/domains/mask-presence) is captured at trace time —
    static for an elementwise chain, or the trace fails and we fall back."""
    meta: dict = {}

    def prog(datas, masks):
        n = int(datas[0].shape[0])
        cols = [Column(d, dom, m, None)
                for d, dom, m in zip(datas, domains, masks)]
        f = Frame(cols, RangeLabels(n), labels_from_values(list(names)))
        for u in udfs:
            f = _apply_udf_block(f, u)
        meta["names"] = f.col_labels.to_list()
        meta["domains"] = tuple(c.domain for c in f.columns)
        return (tuple(c.data for c in f.columns),
                tuple(c.mask for c in f.columns))

    return jax.jit(prog), meta


def _frames_bit_equal(a: Frame, b: Frame) -> bool:
    if a.col_labels.to_list() != b.col_labels.to_list():
        return False
    if a.row_labels.to_list() != b.row_labels.to_list():
        return False
    for ca, cb in zip(a.columns, b.columns):
        if ca.domain is not cb.domain:
            return False
        va, vb = np.asarray(ca.valid_mask()), np.asarray(cb.valid_mask())
        if not np.array_equal(va, vb):
            return False
        da, db = np.asarray(ca.data), np.asarray(cb.data)
        if da.dtype != db.dtype:
            return False
        if not np.array_equal(np.where(va, da, 0), np.where(vb, db, 0)):
            return False
    return True


def _run_map_stages(frame: Frame, udfs: Sequence[alg.Udf]) -> Frame:
    """Run a consecutive run of elementwise MAP stages over one block as one
    XLA program when the chain traces; per-chain eager fallback otherwise.
    The first block through a chain is executed BOTH ways and compared — the
    compiled program is only adopted if it reproduces the eager result
    bit-for-bit, so fused and unfused plans can never diverge."""
    f = frame.induce()
    if not _jit_udfs_enabled():
        return _run_map_stages_eager(f, udfs)
    names = f.col_labels.to_list()
    domains = tuple(c.domain for c in f.columns)
    if any(d.is_coded for d in domains):
        return _run_map_stages_eager(f, udfs)
    key = (tuple(u.key() for u in udfs), tuple(names), domains,
           tuple(c.mask is None for c in f.columns))
    try:
        hash(key)
    except TypeError:   # unhashable labels
        return _run_map_stages_eager(f, udfs)

    with _MAP_JIT_LOCK:
        entry = _MAP_JIT.get(key, _MAP_JIT_MISS)
    if entry is None:
        return _run_map_stages_eager(f, udfs)

    datas = [c.data for c in f.columns]
    masks = [c.mask for c in f.columns]

    if entry is not _MAP_JIT_MISS:
        fn, meta = entry
        out_datas, out_masks = fn(datas, masks)
        cols = [Column(d, dom, m, None)
                for d, dom, m in zip(out_datas, meta["domains"], out_masks)]
        return Frame(cols, f.row_labels, labels_from_values(meta["names"]))

    # probe: trace, run, and verify against the eager path on this block
    eager = _run_map_stages_eager(f, udfs)
    entry = None
    try:
        fn, meta = _map_run_program(udfs, tuple(names), domains)
        out_datas, out_masks = fn(datas, masks)
        cols = [Column(d, dom, m, None)
                for d, dom, m in zip(out_datas, meta["domains"], out_masks)]
        traced = Frame(cols, f.row_labels, labels_from_values(meta["names"]))
        if _frames_bit_equal(eager, traced):
            entry = (fn, meta)
    except Exception:
        entry = None
    with _MAP_JIT_LOCK:
        while len(_MAP_JIT) >= _MAP_JIT_MAX:
            _MAP_JIT.pop(next(iter(_MAP_JIT)))
        _MAP_JIT[key] = entry
    return eager


def _run_stages_block(frame: Frame, stages: Sequence[alg.Stage]) -> Frame:
    """Execute a row-local stage chain over ONE block: the shared per-block
    program body of FusedPipeline and of every barrier-fused operator."""
    cur = frame
    i = 0
    while i < len(stages):
        st = stages[i]
        if st.op == "selection":
            # coalesce a run of structured-Expr selections → one jit mask
            preds = []
            while (i < len(stages) and stages[i].op == "selection"
                   and isinstance(stages[i].params["predicate"], alg.Expr)):
                preds.append(stages[i].params["predicate"])
                i += 1
            if preds:
                cur = cur.filter_rows(_fused_selection_mask(preds, cur))
            else:  # opaque Udf predicate
                cur = cur.filter_rows(_predicate_mask(cur, st.params["predicate"]))
                i += 1
        elif st.op == "map":
            # coalesce a run of elementwise maps → one jit-traced program
            udfs = []
            while i < len(stages) and stages[i].op == "map":
                udfs.append(stages[i].params["udf"])
                i += 1
            cur = _run_map_stages(cur, udfs)
        elif st.op == "projection":
            cur = _project_block(cur, st.params["cols"])
            i += 1
        elif st.op == "rename":
            cur = _rename_block(cur, dict(st.params["mapping"]))
            i += 1
        else:
            raise ValueError(f"non-fusible stage {st.op}")
    return cur


def _run_fused(pf: PartitionedFrame, stages: Sequence[alg.Stage]) -> PartitionedFrame:
    """Execute a fused row-local chain: one sweep per row partition, values
    staying on device across stages, one pool dispatch for the whole chain."""
    pf1 = pf.repartition(col_parts=1)
    return pf1.map_blockwise(lambda f: _run_stages_block(f, stages))


# =============================================================================
# dispatcher
# =============================================================================
def run_node(node: alg.Node, inputs: list[PartitionedFrame],
             stats=None) -> PartitionedFrame:
    """Dispatch one plan node.  ``stats`` (duck-typed ``ExecStats``) receives
    physical-level counters — ``gather_rows``, the payload rows gathered /
    materialized by SORT/JOIN/DIFFERENCE/DROP-DUPLICATES (the fused-consumer
    paths gather strictly fewer rows than their unfused counterparts on
    selective chains), and ``dedup_blocks`` / ``dedup_key_rows``, the blocks
    and rows the block-parallel dedup key extraction processed."""
    op = node.op
    if op == "fused_pipeline":
        return _run_fused(inputs[0], node.params["stages"])
    if op == "fused_groupby":
        return _fused_groupby(inputs[0], node.params["stages"],
                              node.params["keys"], node.params["aggs"],
                              node.params.get("grid"))
    if op == "fused_sort":
        return _fused_sort(inputs[0], node.params["by"], node.params["ascending"],
                           node.params["stages"], stats,
                           grid=node.params.get("grid"))
    if op == "fused_join":
        return _fused_join(inputs[0], inputs[1], node.params,
                           node.params["stages"], stats)
    if op == "fused_window":
        return _window(inputs[0], node.params["func"], node.params["cols"],
                       node.params["size"], node.params["periods"],
                       node.params["pre_stages"], node.params["post_stages"],
                       grid=node.params.get("grid"))
    if op == "fused_difference":
        return _difference(inputs[0], inputs[1], stats,
                           node.params["pre_stages"],
                           node.params["right_pre_stages"],
                           node.params["post_stages"],
                           grid=node.params.get("grid"))
    if op == "fused_drop_duplicates":
        return _drop_duplicates(inputs[0], node.params["subset"], stats,
                                node.params["pre_stages"],
                                node.params["post_stages"],
                                grid=node.params.get("grid"))
    if op == "selection":
        return _selection(inputs[0], node.params["predicate"])
    if op == "projection":
        return _projection(inputs[0], node.params["cols"])
    if op == "union":
        return _union(inputs[0], inputs[1])
    if op == "difference":
        return _difference(inputs[0], inputs[1], stats)
    if op == "join":
        return _join(inputs[0], inputs[1], node.params, stats)
    if op == "drop_duplicates":
        return _drop_duplicates(inputs[0], node.params["subset"], stats)
    if op == "groupby":
        return _groupby(inputs[0], node.params["keys"], node.params["aggs"])
    if op == "sort":
        return _sort(inputs[0], node.params["by"], node.params["ascending"], stats)
    if op == "rename":
        return _rename(inputs[0], node.params["mapping"])
    if op == "window":
        return _window(inputs[0], node.params["func"], node.params["cols"],
                       node.params["size"], node.params["periods"])
    if op == "transpose":
        return _transpose(inputs[0])
    if op == "map":
        return _map(inputs[0], node.params["udf"])
    if op == "to_labels":
        return _to_labels(inputs[0], node.params["column"])
    if op == "from_labels":
        return _from_labels(inputs[0], node.params["label"])
    if op == "limit":
        return _limit(inputs[0], node.params["k"], node.params["tail"])
    if op == "column_sort":
        return _column_sort(inputs[0], node.params["by"], node.params["ascending"])
    if op == "column_filter":
        return _column_filter(inputs[0], node.params["predicate"])
    raise ValueError(f"no physical implementation for {op}")
