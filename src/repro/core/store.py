"""Out-of-core block store: memory-governed spill/fault residency (§4.2/§6-7).

The paper's scalability agenda asks for dataframe engines that degrade
gracefully past RAM instead of OOM-ing — the property Modin gets from its
partitioned out-of-core layer.  This module is that layer for our engine:
every partition block of a ``PartitionedFrame`` lives behind a
:class:`BlockHandle` with two residency states,

    resident  — the ``Frame`` is in host/device memory;
    spilled   — the block's arrays live in an ``.npz`` file under the spill
                directory (written with ``np.save``-family serialization) and
                the in-memory ``Frame`` reference is dropped.

A byte budget (``REPRO_MEM_BUDGET``; 0 = unlimited) governs residency: when
the resident total would exceed the budget, the store evicts the
lowest-value unpinned blocks first — ordered by **benefit density** (the same
cost×hits/bytes score the executor's materialization cache uses, §6.2.2) with
LRU as the tie-break, so cached sub-plan results and live partitions charge
ONE budget under ONE policy (the executor stamps its entries' handles with
their cache benefit; un-cached working blocks default to 0 and evict first).

Pin/unpin ref-counts protect blocks around kernel execution: the scheduling
layer faults blocks *inside pool worker tasks* (overlapping spill I/O with
other blocks' compute — see ``schedule.dispatch_blocks``, which also orders
dispatch to run resident blocks first) and pins them for the duration of the
per-block program, so eviction can never un-account memory that a kernel is
actively reading.

Budget semantics: eviction makes room *before* a fault or put charges its
bytes, so the resident gauge stays ≤ budget + one in-flight block per worker
(the acceptance bound "budget + one block" on a 2-worker pool).  Pinned
blocks are never evicted; if pins alone exceed the budget, the store
overshoots rather than deadlocks.

``REPRO_MEM_BUDGET=0`` (the default) keeps the fully-resident fast path:
``put`` wraps the frame in an untracked handle with no locking, no
accounting, and no spill machinery — bit-identical to pre-store behaviour.

Lock order: handle lock → store lock, never the reverse.  The spill write
itself holds only the victim's handle lock, so faults of *other* blocks
proceed concurrently with eviction I/O.
"""
from __future__ import annotations

import contextlib
import io
import itertools
import os
import pickle
import shutil
import tempfile
import threading
import weakref
from typing import Iterator

import numpy as np

from .frame import Column, Frame
from .dtypes import Domain

__all__ = [
    "BlockHandle", "BlockStore", "StoreStats",
    "get_store", "reset_store", "configure", "unconfigure",
    "as_handle", "resolve", "pinned",
]

_SEQ = itertools.count(1)
_IDS = itertools.count()


class StoreStats:
    """Store-level counters (one instance per store; all mutation under the
    store lock).  ``spills``/``faults`` count block state transitions;
    ``spilled_bytes``/``faulted_bytes`` the payload they moved;
    ``resident_bytes`` is the live gauge and ``peak_resident_bytes`` its
    high-water mark.  The executor snapshots these around every plan-node
    evaluation and attributes the deltas to its ``ExecStats``."""

    __slots__ = ("spills", "faults", "spilled_bytes", "faulted_bytes",
                 "resident_bytes", "peak_resident_bytes")

    def __init__(self):
        self.spills = 0
        self.faults = 0
        self.spilled_bytes = 0
        self.faulted_bytes = 0
        self.resident_bytes = 0
        self.peak_resident_bytes = 0

    def snapshot(self) -> tuple[int, int, int, int]:
        return (self.spills, self.faults, self.spilled_bytes,
                self.peak_resident_bytes)


# =============================================================================
# Frame (de)serialization: one .npz per spilled block
# =============================================================================
def _save_frame(path: str, frame: Frame) -> None:
    """Write a Frame's arrays + metadata to ``path`` (uncompressed npz).
    Column payloads are stored as plain ``.npy`` members (loadable without
    pickle); the small metadata record (domains, dictionaries, labels,
    device-ness flags) is pickled into a byte-array member."""
    arrays: dict[str, np.ndarray] = {}
    cols_meta = []
    for j, c in enumerate(frame.columns):
        arrays[f"d{j}"] = np.asarray(c.data)
        has_mask = c.mask is not None
        if has_mask:
            arrays[f"m{j}"] = np.asarray(c.mask)
        cols_meta.append({
            "domain": c.domain.value,
            "dictionary": c.dictionary,
            "jax_data": not isinstance(c.data, np.ndarray),
            "has_mask": has_mask,
            "jax_mask": has_mask and not isinstance(c.mask, np.ndarray),
        })
    meta = {"cols": cols_meta, "row_labels": frame.row_labels,
            "col_labels": frame.col_labels, "row_domains": frame.row_domains}
    arrays["__meta__"] = np.frombuffer(pickle.dumps(meta), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(buf.getbuffer())
    os.replace(tmp, path)       # a fault never sees a half-written file


def _load_frame(path: str) -> Frame:
    import jax.numpy as jnp
    with np.load(path) as z:
        meta = pickle.loads(z["__meta__"].tobytes())
        cols = []
        for j, e in enumerate(meta["cols"]):
            d = z[f"d{j}"]
            if e["jax_data"]:
                # was a device array before the spill; int64 host columns
                # never take this branch (they are always np — jnp.asarray
                # would truncate them through int32)
                d = jnp.asarray(d)
            m = None
            if e["has_mask"]:
                m = z[f"m{j}"]
                if e["jax_mask"]:
                    m = jnp.asarray(m)
            cols.append(Column(d, Domain(e["domain"]), m, e["dictionary"]))
    return Frame(cols, meta["row_labels"], meta["col_labels"],
                 meta["row_domains"])


# =============================================================================
# handles
# =============================================================================
class _Rec:
    """The part of a handle that must outlive it: how many resident bytes it
    has charged and which spill file it owns.  ``weakref.finalize`` hands this
    to the store when the handle is garbage-collected, so dead handles give
    their bytes back and delete their spill file deterministically."""
    __slots__ = ("charged", "path")

    def __init__(self):
        self.charged = 0
        self.path: str | None = None


class BlockHandle:
    """One partition block behind a residency state.  Metadata (``nrows`` /
    ``ncols`` / ``nbytes``) is always available without faulting, so grid
    planning, zero-copy regroup pass-through, and cache accounting never
    touch a spilled block's data."""

    __slots__ = ("_store", "_frame", "_nbytes", "nrows", "ncols", "_rec",
                 "_pins", "_seq", "_evicting", "benefit", "_lock", "_id",
                 "__weakref__")

    def __init__(self, store: "BlockStore | None", frame: Frame):
        self._store = store
        self._frame: Frame | None = frame
        self._nbytes: int | None = None
        self.nrows = frame.nrows
        self.ncols = frame.ncols
        self._rec = _Rec()
        self._pins = 0
        self._seq = next(_SEQ)
        self._evicting = False
        self.benefit = 0.0           # cache benefit density; 0 = evict first
        self._lock = threading.Lock()
        self._id = next(_IDS)

    # -- metadata ---------------------------------------------------------
    @property
    def nbytes(self) -> int:
        n = self._nbytes
        if n is None:
            f = self._frame
            n = self._nbytes = (f.nbytes() if f is not None else 0)
        return n

    @property
    def is_resident(self) -> bool:
        return self._frame is not None

    @property
    def is_tracked(self) -> bool:
        return self._store is not None

    # -- data access ------------------------------------------------------
    def frame(self) -> Frame:
        """The block's Frame; faults it back from disk when spilled."""
        f = self._frame
        st = self._store
        if st is None:               # untracked fast path (budget 0)
            return f
        if f is not None:
            self._seq = next(_SEQ)   # touch (benign race — LRU hint only)
            return f
        return st._fault(self)

    def pin(self) -> None:
        if self._store is not None:
            with self._store._lock:
                self._pins += 1

    def unpin(self) -> None:
        if self._store is not None:
            with self._store._lock:
                self._pins -= 1

    @contextlib.contextmanager
    def pinned(self) -> Iterator[Frame]:
        """Fault + pin for the duration of a per-block program (the physical
        layer wraps every dispatch-boundary kernel in one of these)."""
        self.pin()
        try:
            yield self.frame()
        finally:
            self.unpin()

    def __repr__(self) -> str:
        state = "resident" if self.is_resident else "spilled"
        return f"BlockHandle[{self.nrows}x{self.ncols}; {state}]"


# =============================================================================
# the store
# =============================================================================
class BlockStore:
    def __init__(self, budget_bytes: int = 0, spill_dir: str | None = None):
        self.budget = max(0, int(budget_bytes))
        self._base_dir = spill_dir
        self._dir: str | None = None
        self._lock = threading.Lock()
        self._handles: "weakref.WeakSet[BlockHandle]" = weakref.WeakSet()
        self.stats = StoreStats()

    @property
    def active(self) -> bool:
        return self.budget > 0

    # ------------------------------------------------------------------
    def put(self, frame: Frame, benefit: float = 0.0) -> BlockHandle:
        """Register a block.  Inactive store (budget 0): a zero-overhead
        untracked wrapper.  Active: charge the block's bytes, evicting
        lower-value blocks first to stay within budget."""
        if not self.active:
            return BlockHandle(None, frame)
        h = BlockHandle(self, frame)
        h.benefit = benefit
        need = h.nbytes
        self._reserve(need, register=h)
        weakref.finalize(h, BlockStore._reap, self, h._rec)
        return h

    # ------------------------------------------------------------------
    def _fault(self, h: BlockHandle) -> Frame:
        """Load a spilled block back (runs on whatever thread touched it —
        pool workers, by construction of the dispatch layer, so fault I/O
        overlaps other blocks' compute).  Pins the handle around the load so
        concurrent eviction can't un-account it mid-fault; the bytes are
        reserved (evict-until-fit + charge, atomically) BEFORE the load, so
        the resident gauge covers in-flight loads and the peak stays within
        budget whenever anything is evictable."""
        with self._lock:
            f = h._frame
            if f is not None:
                h._seq = next(_SEQ)
                return f
            h._pins += 1
        charged = False
        try:
            with h._lock:
                f = h._frame
                if f is None:
                    if h._rec.path is None:
                        raise RuntimeError(
                            "spilled block's file is gone — the store was "
                            "reset/reconfigured after this frame was "
                            "ingested (configure the budget before "
                            "ingesting data)")
                    self._reserve(h.nbytes)
                    charged = True
                    f = _load_frame(h._rec.path)
                    with self._lock:
                        h._frame = f
                        h._rec.charged = h.nbytes
                        charged = False
                        self.stats.faults += 1
                        self.stats.faulted_bytes += h.nbytes
        finally:
            if charged:              # load failed: give the reservation back
                with self._lock:
                    self.stats.resident_bytes -= h.nbytes
            with self._lock:
                h._pins -= 1
                h._seq = next(_SEQ)
        return f

    # ------------------------------------------------------------------
    def _reserve(self, incoming: int, register: BlockHandle | None = None) -> None:
        """Atomically evict-until-fit and charge ``incoming`` bytes: the
        budget check and the charge happen under one lock hold, so
        concurrent reserves cannot interleave into an overshoot.  Victims
        are selected as a BATCH per scan — one (benefit, LRU) sort covers
        the whole shortfall instead of a full rescan per victim.  Only when
        nothing is evictable (every resident block pinned or mid-eviction)
        does the charge overshoot — bounding the peak at budget + the
        in-flight blocks of the moment (≤ one per pool worker)."""
        while True:
            victims: list[BlockHandle] = []
            with self._lock:
                shortfall = self.stats.resident_bytes + incoming - self.budget
                if shortfall > 0:
                    cands = sorted(
                        (c for c in self._handles
                         if c._frame is not None and c._pins == 0
                         and not c._evicting),
                        key=lambda c: (c.benefit, c._seq))
                    freed = 0
                    for cand in cands:
                        if freed >= shortfall:
                            break
                        cand._evicting = True
                        victims.append(cand)
                        freed += cand._rec.charged
                if not victims:      # fits, or nothing evictable: charge now
                    self.stats.resident_bytes += incoming
                    if self.stats.resident_bytes > self.stats.peak_resident_bytes:
                        self.stats.peak_resident_bytes = self.stats.resident_bytes
                    if register is not None:
                        self._handles.add(register)
                        register._rec.charged = incoming
                    return
            for victim in victims:
                self._spill(victim)

    def _spill(self, h: BlockHandle) -> None:
        try:
            with h._lock:
                with self._lock:
                    f = h._frame
                    if f is None or h._pins > 0:
                        return       # raced with a fault/pin: nothing to do
                path = h._rec.path
                if path is None:
                    path = h._rec.path = os.path.join(
                        self._spill_dir(), f"blk{h._id}.npz")
                    _save_frame(path, f)
                # else: clean copy already on disk from a prior spill —
                # frames are immutable, so dropping the memory is enough
                with self._lock:
                    if h._pins > 0:
                        # pinned while we wrote: a kernel is reading this
                        # frame RIGHT NOW — keep it resident (and charged);
                        # the on-disk copy stays valid for a later eviction
                        return
                    h._frame = None
                    self.stats.resident_bytes -= h._rec.charged
                    h._rec.charged = 0
                    self.stats.spills += 1
                    self.stats.spilled_bytes += h.nbytes
        finally:
            with self._lock:
                h._evicting = False

    # ------------------------------------------------------------------
    def _spill_dir(self) -> str:
        d = self._dir
        if d is None:
            with self._lock:
                if self._dir is None:
                    base = self._base_dir or os.environ.get("REPRO_SPILL_DIR")
                    if base:
                        os.makedirs(base, exist_ok=True)
                    self._dir = tempfile.mkdtemp(prefix="repro-spill-",
                                                 dir=base or None)
                d = self._dir
        return d

    @staticmethod
    def _reap(store: "BlockStore", rec: _Rec) -> None:
        """Finalizer for a dead handle: give back its resident charge and
        delete its spill file (no leaked files once the owning frames go)."""
        with store._lock:
            store.stats.resident_bytes -= rec.charged
            rec.charged = 0
        if rec.path is not None:
            try:
                os.unlink(rec.path)
            except OSError:
                pass
            rec.path = None

    # ------------------------------------------------------------------
    def shutdown(self) -> None:
        """Drop every spill file and the spill directory.  Handles that were
        spilled become unusable — call only when the owning session is done
        (``reset_store`` / process exit / the CI spill smoke)."""
        with self._lock:
            for h in list(self._handles):
                h._rec.path = None
            d, self._dir = self._dir, None
        if d is not None:
            shutil.rmtree(d, ignore_errors=True)


# =============================================================================
# module-level singleton + helpers
# =============================================================================
_STORE: BlockStore | None = None
_STORE_LOCK = threading.Lock()
_BUDGET_OVERRIDE: int | None = None
_DIR_OVERRIDE: str | None = None


def _env_budget() -> int:
    if _BUDGET_OVERRIDE is not None:
        return _BUDGET_OVERRIDE
    try:
        return max(0, int(os.environ.get("REPRO_MEM_BUDGET", "0")))
    except ValueError:
        return 0


def get_store() -> BlockStore:
    global _STORE
    if _STORE is None:
        with _STORE_LOCK:
            if _STORE is None:
                _STORE = BlockStore(_env_budget(), _DIR_OVERRIDE)
    return _STORE


def reset_store() -> None:
    """Tear down the store (deleting spill files) and let the next use
    rebuild it from the current environment — the ``schedule.reset_pool``
    counterpart for tests and session reconfiguration.  Blocks ingested
    under the old store keep working only if they were resident."""
    global _STORE
    with _STORE_LOCK:
        if _STORE is not None:
            _STORE.shutdown()
        _STORE = None


def configure(budget_bytes: int | None = None,
              spill_dir: str | None = None) -> BlockStore:
    """Process-wide programmatic override of the env knobs (the
    ``Session(mem_budget_bytes=...)`` path).  The override is sticky — it
    outlives the session that set it and shadows ``REPRO_MEM_BUDGET`` until
    changed again.

    Re-configuring with the *current* settings is a no-op; actually
    changing them resets the store, which deletes every existing spill
    file — frames ingested earlier lose their spilled blocks — so
    configure before ingesting data."""
    global _BUDGET_OVERRIDE, _DIR_OVERRIDE
    if budget_bytes is not None:
        _BUDGET_OVERRIDE = max(0, int(budget_bytes))
    if spill_dir is not None:
        _DIR_OVERRIDE = spill_dir
    with _STORE_LOCK:
        cur = _STORE
    if (cur is not None and cur.budget == _env_budget()
            and (spill_dir is None or cur._base_dir == spill_dir)):
        return cur
    reset_store()
    return get_store()


def unconfigure() -> None:
    """Clear the sticky :func:`configure` overrides and reset the store, so
    the next use rebuilds from ``REPRO_MEM_BUDGET`` / ``REPRO_SPILL_DIR``
    again — the public undo for ``Session(mem_budget_bytes=...)``."""
    global _BUDGET_OVERRIDE, _DIR_OVERRIDE
    _BUDGET_OVERRIDE = None
    _DIR_OVERRIDE = None
    reset_store()


def as_handle(block: "Frame | BlockHandle") -> BlockHandle:
    """Wrap a Frame into the store (identity on handles)."""
    if isinstance(block, BlockHandle):
        return block
    return get_store().put(block)


def resolve(block: "Frame | BlockHandle") -> Frame:
    """The block's Frame — faulting it in if spilled (identity on Frames)."""
    if isinstance(block, BlockHandle):
        return block.frame()
    return block


@contextlib.contextmanager
def pinned(block: "Frame | BlockHandle") -> Iterator[Frame]:
    """Fault + pin scope for per-block kernel execution (identity on
    Frames).  Every dispatch-boundary block program runs inside one."""
    if isinstance(block, BlockHandle):
        with block.pinned() as f:
            yield f
    else:
        yield block
