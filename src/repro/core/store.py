"""Out-of-core block store: memory-governed spill/fault residency (§4.2/§6-7).

The paper's scalability agenda asks for dataframe engines that degrade
gracefully past RAM instead of OOM-ing — the property Modin gets from its
partitioned out-of-core layer.  This module is that layer for our engine:
every partition block of a ``PartitionedFrame`` lives behind a
:class:`BlockHandle` with two residency states,

    resident  — the ``Frame`` is in host/device memory;
    spilled   — the block's arrays live in an ``.npz`` file under the spill
                directory (written with ``np.save``-family serialization) and
                the in-memory ``Frame`` reference is dropped.

A byte budget (``REPRO_MEM_BUDGET``; 0 = unlimited) governs residency: when
the resident total would exceed the budget, the store evicts the
lowest-value unpinned blocks first — ordered by **benefit density** (the same
cost×hits/bytes score the executor's materialization cache uses, §6.2.2) with
LRU as the tie-break, so cached sub-plan results and live partitions charge
ONE budget under ONE policy (the executor stamps its entries' handles with
their cache benefit; un-cached working blocks default to 0 and evict first).

Pin/unpin ref-counts protect blocks around kernel execution: the scheduling
layer faults blocks *inside pool worker tasks* (overlapping spill I/O with
other blocks' compute — see ``schedule.dispatch_blocks``, which also orders
dispatch to run resident blocks first) and pins them for the duration of the
per-block program, so eviction can never un-account memory that a kernel is
actively reading.

Budget semantics: eviction makes room *before* a fault or put charges its
bytes, so the resident gauge stays ≤ budget + one in-flight block per worker
(the acceptance bound "budget + one block" on a 2-worker pool).  Pinned
blocks are never evicted; if pins alone exceed the budget, the store
overshoots rather than deadlocks.

``REPRO_MEM_BUDGET=0`` (the default) keeps the fully-resident fast path:
``put`` wraps the frame in an untracked handle with no locking, no
accounting, and no spill machinery — bit-identical to pre-store behaviour.

Fault tolerance (PR 6): every spill file carries a CRC32-stamped header and
is verified on fault; a corrupt or missing file is *recovered* when the block
has a recorded producer (a recompute thunk registered by the partition /
physical layers at every blockwise-map output) and raises a typed
``SpillIntegrityError`` otherwise — never a partially-deserialized frame.
``ENOSPC``/``OSError`` during a spill write degrades gracefully: the write
fails over through the ``REPRO_SPILL_DIR`` directory list (``os.pathsep``
separated), and when every directory is exhausted the victim simply stays
resident, ``budget_overruns`` is counted, and eviction moves to the next
candidate.  Faulting a handle after ``shutdown()`` raises
``StoreClosedError`` naming the handle and the shutdown site.

The shuffle/exchange layer (PR 8, ``core.shuffle``) is a lineage client:
every bucket key frame and gathered output chunk of a JOIN/SORT is registered
here via ``as_handle(frame, recompute=builder)``, so exchange intermediates
spill under the same budget as data blocks and a corrupt/missing spill mid-
exchange recomputes through the recorded builder chain (chunk → bucket →
block key frame → source block) bit-identically.

Lock order: handle lock → store lock, never the reverse.  The spill write
itself holds only the victim's handle lock, so faults of *other* blocks
proceed concurrently with eviction I/O.
"""
from __future__ import annotations

import contextlib
import io
import itertools
import os
import pickle
import shutil
import struct
import tempfile
import threading
import traceback
import weakref
import zlib
from typing import Callable, Iterator

import numpy as np

from .frame import Column, Frame
from .dtypes import Domain
from . import config as _config
from . import faults as _faults
from . import trace as _trace
from .faults import SpillIntegrityError, StoreClosedError, env_int

__all__ = [
    "BlockHandle", "BlockStore", "StoreStats",
    "SpillIntegrityError", "StoreClosedError",
    "get_store", "reset_store", "configure", "unconfigure",
    "as_handle", "resolve", "pinned",
]

_SEQ = itertools.count(1)
_IDS = itertools.count()


class StoreStats:
    """Store-level counters (one instance per store; all mutation under the
    store lock).  ``spills``/``faults`` count block state transitions;
    ``spilled_bytes``/``faulted_bytes`` the payload they moved;
    ``resident_bytes`` is the live gauge and ``peak_resident_bytes`` its
    high-water mark.  The executor snapshots these around every plan-node
    evaluation and attributes the deltas to its ``ExecStats``."""

    __slots__ = ("spills", "faults", "spilled_bytes", "faulted_bytes",
                 "resident_bytes", "peak_resident_bytes",
                 "checksum_failures", "recomputed_blocks",
                 "budget_overruns", "leaked_spill_files")

    def __init__(self):
        self.spills = 0
        self.faults = 0
        self.spilled_bytes = 0
        self.faulted_bytes = 0
        self.resident_bytes = 0
        self.peak_resident_bytes = 0
        # fault-tolerance counters (PR 6):
        self.checksum_failures = 0   # spill reads that failed CRC32 / were
        #                              missing on disk
        self.recomputed_blocks = 0   # blocks rebuilt from their recorded
        #                              producer after an integrity failure
        self.budget_overruns = 0     # spill writes abandoned (ENOSPC on every
        #                              spill dir) — the victim stayed resident
        self.leaked_spill_files = 0  # finalizer could not unlink a dead
        #                              handle's spill file (was: silent)

    def snapshot(self) -> tuple[int, int, int, int, int, int, int]:
        return (self.spills, self.faults, self.spilled_bytes,
                self.peak_resident_bytes, self.checksum_failures,
                self.recomputed_blocks, self.budget_overruns)


# =============================================================================
# Frame (de)serialization: one .npz per spilled block, prefixed with an
# integrity header:  MAGIC ++ "<IQ"(crc32(payload), len(payload)) ++ payload.
# The fault path verifies the stamp before deserializing, so a flipped bit or
# truncated file surfaces as SpillIntegrityError — never a corrupt frame.
# =============================================================================
_MAGIC = b"RSPL1\n"
_HDR = struct.Struct("<IQ")


def _save_frame(path: str, frame: Frame) -> None:
    """Write a Frame's arrays + metadata to ``path`` (uncompressed npz behind
    the CRC32 header).  Column payloads are stored as plain ``.npy`` members
    (loadable without pickle); the small metadata record (domains,
    dictionaries, labels, device-ness flags) is pickled into a byte-array
    member."""
    arrays: dict[str, np.ndarray] = {}
    cols_meta = []
    for j, c in enumerate(frame.columns):
        arrays[f"d{j}"] = np.asarray(c.data)
        has_mask = c.mask is not None
        if has_mask:
            arrays[f"m{j}"] = np.asarray(c.mask)
        cols_meta.append({
            "domain": c.domain.value,
            "dictionary": c.dictionary,
            "jax_data": not isinstance(c.data, np.ndarray),
            "has_mask": has_mask,
            "jax_mask": has_mask and not isinstance(c.mask, np.ndarray),
        })
    meta = {"cols": cols_meta, "row_labels": frame.row_labels,
            "col_labels": frame.col_labels, "row_domains": frame.row_domains}
    arrays["__meta__"] = np.frombuffer(pickle.dumps(meta), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getbuffer()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_MAGIC)
        f.write(_HDR.pack(zlib.crc32(payload) & 0xFFFFFFFF, payload.nbytes))
        f.write(payload)
    os.replace(tmp, path)       # a fault never sees a half-written file


def _load_frame(path: str) -> Frame:
    import jax.numpy as jnp
    with open(path, "rb") as fh:
        raw = fh.read()
    hdr_len = len(_MAGIC) + _HDR.size
    if len(raw) < hdr_len or raw[:len(_MAGIC)] != _MAGIC:
        raise SpillIntegrityError(
            f"spill file {path} has a bad or missing integrity header "
            "(not written by this store, or truncated below the header)")
    crc, n = _HDR.unpack_from(raw, len(_MAGIC))
    payload = memoryview(raw)[hdr_len:]
    if payload.nbytes != n or (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
        raise SpillIntegrityError(
            f"spill file {path} failed CRC32 verification "
            f"({payload.nbytes} bytes on disk vs {n} stamped)")
    with np.load(io.BytesIO(payload)) as z:
        meta = pickle.loads(z["__meta__"].tobytes())
        cols = []
        for j, e in enumerate(meta["cols"]):
            d = z[f"d{j}"]
            if e["jax_data"]:
                # was a device array before the spill; int64 host columns
                # never take this branch (they are always np — jnp.asarray
                # would truncate them through int32)
                d = jnp.asarray(d)
            m = None
            if e["has_mask"]:
                m = z[f"m{j}"]
                if e["jax_mask"]:
                    m = jnp.asarray(m)
            cols.append(Column(d, Domain(e["domain"]), m, e["dictionary"]))
    return Frame(cols, meta["row_labels"], meta["col_labels"],
                 meta["row_domains"])


# =============================================================================
# handles
# =============================================================================
class _Rec:
    """The part of a handle that must outlive it: how many resident bytes it
    has charged and which spill file it owns.  ``weakref.finalize`` hands this
    to the store when the handle is garbage-collected, so dead handles give
    their bytes back and delete their spill file deterministically."""
    __slots__ = ("charged", "path")

    def __init__(self):
        self.charged = 0
        self.path: str | None = None


class BlockHandle:
    """One partition block behind a residency state.  Metadata (``nrows`` /
    ``ncols`` / ``nbytes``) is always available without faulting, so grid
    planning, zero-copy regroup pass-through, and cache accounting never
    touch a spilled block's data."""

    __slots__ = ("_store", "_frame", "_nbytes", "nrows", "ncols", "_rec",
                 "_pins", "_seq", "_evicting", "benefit", "_lock", "_id",
                 "_recompute", "__weakref__")

    def __init__(self, store: "BlockStore | None", frame: Frame,
                 recompute: "Callable[[], Frame] | None" = None):
        self._store = store
        self._frame: Frame | None = frame
        self._nbytes: int | None = None
        self.nrows = frame.nrows
        self.ncols = frame.ncols
        self._rec = _Rec()
        self._pins = 0
        self._seq = next(_SEQ)
        self._evicting = False
        self.benefit = 0.0           # cache benefit density; 0 = evict first
        self._lock = threading.Lock()
        self._id = next(_IDS)
        # lineage hook: rebuilds this block's Frame from its recorded
        # producer when the spill file fails integrity verification.  The
        # thunk closes over the producer's *input handles*, keeping them
        # alive (and re-faultable) for as long as this block exists.
        self._recompute = recompute

    # -- metadata ---------------------------------------------------------
    @property
    def nbytes(self) -> int:
        n = self._nbytes
        if n is None:
            f = self._frame
            n = self._nbytes = (f.nbytes() if f is not None else 0)
        return n

    @property
    def is_resident(self) -> bool:
        return self._frame is not None

    @property
    def is_tracked(self) -> bool:
        return self._store is not None

    # -- data access ------------------------------------------------------
    def frame(self) -> Frame:
        """The block's Frame; faults it back from disk when spilled."""
        f = self._frame
        st = self._store
        if st is None:               # untracked fast path (budget 0)
            return f
        if f is not None:
            self._seq = next(_SEQ)   # touch (benign race — LRU hint only)
            return f
        return st._fault(self)

    def pin(self) -> None:
        if self._store is not None:
            with self._store._lock:
                self._pins += 1

    def unpin(self) -> None:
        if self._store is not None:
            with self._store._lock:
                self._pins -= 1

    @contextlib.contextmanager
    def pinned(self) -> Iterator[Frame]:
        """Fault + pin for the duration of a per-block program (the physical
        layer wraps every dispatch-boundary kernel in one of these)."""
        self.pin()
        try:
            yield self.frame()
        finally:
            self.unpin()

    def __repr__(self) -> str:
        state = "resident" if self.is_resident else "spilled"
        return f"BlockHandle[{self.nrows}x{self.ncols}; {state}]"


# =============================================================================
# the store
# =============================================================================
_UNSET = object()


class BlockStore:
    def __init__(self, budget_bytes: int = 0, spill_dir: str | None = None):
        self.budget = max(0, int(budget_bytes))
        self._base_dir = spill_dir
        self._base_list: list | None = None   # parsed spill-dir failover list
        self._dirs: list = []                 # mkdtemp'd dir per base entry
        self._dir_idx = 0                     # first dir that still has room
        self._closed = False
        self._closed_site: str | None = None
        # REENTRANT: dead-handle finalizers (_reap) take this lock, and the
        # cyclic GC can run them on a thread that is already inside a locked
        # section (any allocation — e.g. _reserve's victim sort — can trigger
        # a collection).  With a plain Lock that is a self-deadlock; with an
        # RLock the reentrant _reap is safe because it only adjusts gauges
        # (resident_bytes, leaked_spill_files), which commute with every
        # in-flight locked section.
        self._lock = threading.RLock()
        self._handles: "weakref.WeakSet[BlockHandle]" = weakref.WeakSet()
        self.stats = StoreStats()

    @property
    def active(self) -> bool:
        return self.budget > 0

    # ------------------------------------------------------------------
    def put(self, frame: Frame, benefit: float = 0.0,
            recompute: "Callable[[], Frame] | None" = None) -> BlockHandle:
        """Register a block.  Inactive store (budget 0): a zero-overhead
        untracked wrapper.  Active: charge the block's bytes, evicting
        lower-value blocks first to stay within budget.  ``recompute`` is
        the optional lineage thunk — see :class:`BlockHandle`."""
        if not self.active:
            return BlockHandle(None, frame, recompute)
        h = BlockHandle(self, frame, recompute)
        h.benefit = benefit
        need = h.nbytes
        self._reserve(need, register=h)
        weakref.finalize(h, BlockStore._reap, self, h._rec)
        return h

    # ------------------------------------------------------------------
    def _fault(self, h: BlockHandle) -> Frame:
        """Load a spilled block back (runs on whatever thread touched it —
        pool workers, by construction of the dispatch layer, so fault I/O
        overlaps other blocks' compute).  Pins the handle around the load so
        concurrent eviction can't un-account it mid-fault; the bytes are
        reserved (evict-until-fit + charge, atomically) BEFORE the load, so
        the resident gauge covers in-flight loads and the peak stays within
        budget whenever anything is evictable."""
        with self._lock:
            f = h._frame
            if f is not None:
                h._seq = next(_SEQ)
                return f
            h._pins += 1
        charged = False
        try:
            with h._lock:
                f = h._frame
                if f is None:
                    path = h._rec.path
                    if path is None:
                        if self._closed:
                            raise StoreClosedError(
                                f"cannot fault {h!r} (block id {h._id}): "
                                "its spill file was deleted by "
                                "BlockStore.shutdown() at "
                                f"[{self._closed_site}] — the store was "
                                "reset/reconfigured after this frame was "
                                "ingested (configure the budget before "
                                "ingesting data)")
                        raise RuntimeError(
                            "spilled block's file is gone — the store was "
                            "reset/reconfigured after this frame was "
                            "ingested (configure the budget before "
                            "ingesting data)")
                    self._reserve(h.nbytes)
                    charged = True
                    tr = _trace.current()
                    if tr is None:
                        f = self._load_block(h, path)
                    else:
                        # fault I/O runs on the worker that needed the block;
                        # the span lands under that worker's chunk span, so a
                        # profile shows WHICH dispatch paid the disk stall
                        with tr.span("fault", "store",
                                     args={"block": h._id,
                                           "bytes": h.nbytes}):
                            f = self._load_block(h, path)
                    with self._lock:
                        h._frame = f
                        h._rec.charged = h.nbytes
                        charged = False
                        self.stats.faults += 1
                        self.stats.faulted_bytes += h.nbytes
        finally:
            if charged:              # load failed: give the reservation back
                with self._lock:
                    self.stats.resident_bytes -= h.nbytes
            with self._lock:
                h._pins -= 1
                h._seq = next(_SEQ)
        return f

    def _load_block(self, h: BlockHandle, path: str) -> Frame:
        """Deserialize ``h``'s spill file with integrity verification (and
        the chaos hook).  A corrupt/missing file is unlinked and the block
        recomputed from its recorded producer when one exists; otherwise the
        SpillIntegrityError propagates.  Runs under ``h._lock`` — safe for
        recompute because producer lineage is a DAG, so the thunk can fault
        *other* handles but never re-enter this one."""
        recoverable = h._recompute is not None
        if _faults.active():
            _faults.spill_read_chaos(
                path,
                f"spill_read/blk{h._id}/"
                + ("lineage" if recoverable else "orphan"),
                recoverable=recoverable)
        try:
            return _load_frame(path)
        except (SpillIntegrityError, OSError) as e:
            with self._lock:
                self.stats.checksum_failures += 1
            try:
                os.unlink(path)      # a later spill must rewrite, not reuse
            except OSError:
                pass
            h._rec.path = None
            rec_fn = h._recompute
            if rec_fn is None:
                raise SpillIntegrityError(
                    f"spill file for {h!r} (block id {h._id}) is corrupt or "
                    f"missing and the block has no recorded producer to "
                    f"recompute from: {e}") from e
            f = resolve(rec_fn())
            with self._lock:
                self.stats.recomputed_blocks += 1
            return f

    # ------------------------------------------------------------------
    def _reserve(self, incoming: int, register: BlockHandle | None = None) -> None:
        """Atomically evict-until-fit and charge ``incoming`` bytes: the
        budget check and the charge happen under one lock hold, so
        concurrent reserves cannot interleave into an overshoot.  Victims
        are selected as a BATCH per scan — one (benefit, LRU) sort covers
        the whole shortfall instead of a full rescan per victim.  Only when
        nothing is evictable (every resident block pinned or mid-eviction)
        does the charge overshoot — bounding the peak at budget + the
        in-flight blocks of the moment (≤ one per pool worker).

        Victims whose spill *write* fails (ENOSPC on every spill dir) are
        skipped for the rest of this reservation — they stay resident and
        the scan moves to the next candidate.  The skip set is per-call, so
        a transient write failure is retried on the next reservation."""
        skip: set[int] = set()
        while True:
            victims: list[BlockHandle] = []
            with self._lock:
                shortfall = self.stats.resident_bytes + incoming - self.budget
                if shortfall > 0:
                    cands = sorted(
                        (c for c in self._handles
                         if c._frame is not None and c._pins == 0
                         and not c._evicting and id(c) not in skip),
                        key=lambda c: (c.benefit, c._seq))
                    freed = 0
                    for cand in cands:
                        if freed >= shortfall:
                            break
                        cand._evicting = True
                        victims.append(cand)
                        freed += cand._rec.charged
                if not victims:      # fits, or nothing evictable: charge now
                    self.stats.resident_bytes += incoming
                    if self.stats.resident_bytes > self.stats.peak_resident_bytes:
                        self.stats.peak_resident_bytes = self.stats.resident_bytes
                    if register is not None:
                        self._handles.add(register)
                        register._rec.charged = incoming
                    return
            for victim in victims:
                if not self._spill(victim):
                    skip.add(id(victim))

    def _spill(self, h: BlockHandle) -> bool:
        """Evict one block to disk.  Returns False when the spill *write*
        failed on every spill dir (graceful degradation: the victim stays
        resident and charged; ``stats.budget_overruns`` was counted)."""
        try:
            with h._lock:
                with self._lock:
                    f = h._frame
                    if f is None or h._pins > 0:
                        return True  # raced with a fault/pin: nothing to do
                path = h._rec.path
                if path is None:
                    tr = _trace.current()
                    if tr is None:
                        path = self._write_spill(h, f)
                    else:
                        with tr.span("spill", "store",
                                     args={"block": h._id,
                                           "bytes": h.nbytes}):
                            path = self._write_spill(h, f)
                    if path is None:
                        return False
                    h._rec.path = path
                # else: clean copy already on disk from a prior spill —
                # frames are immutable, so dropping the memory is enough
                with self._lock:
                    if h._pins > 0:
                        # pinned while we wrote: a kernel is reading this
                        # frame RIGHT NOW — keep it resident (and charged);
                        # the on-disk copy stays valid for a later eviction
                        return True
                    h._frame = None
                    self.stats.resident_bytes -= h._rec.charged
                    h._rec.charged = 0
                    self.stats.spills += 1
                    self.stats.spilled_bytes += h.nbytes
        finally:
            with self._lock:
                h._evicting = False
        return True

    # ------------------------------------------------------------------
    def _write_spill(self, h: BlockHandle, f: Frame) -> str | None:
        """Write ``f`` to the first spill dir that accepts it, failing over
        through the ``REPRO_SPILL_DIR`` list on any OSError (ENOSPC,
        read-only mount, ...).  Returns the written path, or None when every
        directory is exhausted — the graceful-degradation signal."""
        bases = self._bases()
        for idx in range(self._dir_idx, len(bases)):
            d = self._dir_at(idx)
            if d is None:
                continue             # this base dir itself is unusable
            path = os.path.join(d, f"blk{h._id}.npz")
            try:
                if _faults.active():
                    _faults.spill_write_fault(f"spill_write/blk{h._id}/dir{idx}")
                _save_frame(path, f)
            except OSError:
                continue             # fail over to the next spill dir
            if idx != self._dir_idx:
                self._dir_idx = idx  # later spills go straight to the
                #                      first dir that still has room
            return path
        with self._lock:
            self.stats.budget_overruns += 1
        return None

    def _bases(self) -> list:
        """The configured spill base-dir list (lazy, so tests that set
        ``REPRO_SPILL_DIR`` after store creation still take effect on first
        spill, as before).  ``None`` entries mean the system tempdir."""
        b = self._base_list
        if b is None:
            spec = self._base_dir or os.environ.get("REPRO_SPILL_DIR")
            parts = [p for p in (spec or "").split(os.pathsep) if p]
            b = self._base_list = parts or [None]
            self._dirs = [_UNSET] * len(b)
        return b

    def _dir_at(self, idx: int) -> str | None:
        """The mkdtemp'd spill directory under base dir ``idx`` (created on
        first use; None — cached — when the base dir can't be created)."""
        d = self._dirs[idx]
        if d is _UNSET:
            with self._lock:
                if self._dirs[idx] is _UNSET:
                    base = self._base_list[idx]
                    try:
                        if base:
                            os.makedirs(base, exist_ok=True)
                        self._dirs[idx] = tempfile.mkdtemp(
                            prefix="repro-spill-", dir=base or None)
                    except OSError:
                        self._dirs[idx] = None
                d = self._dirs[idx]
        return d

    @staticmethod
    def _reap(store: "BlockStore", rec: _Rec) -> None:
        """Finalizer for a dead handle: give back its resident charge and
        delete its spill file (no leaked files once the owning frames go).
        An unlink that fails for any reason other than the file already
        being gone is COUNTED (``stats.leaked_spill_files``), not
        swallowed — a leak the spill smoke and chaos suite assert on."""
        with store._lock:
            store.stats.resident_bytes -= rec.charged
            rec.charged = 0
        if rec.path is not None:
            try:
                os.unlink(rec.path)
            except FileNotFoundError:
                pass                 # already gone (shutdown, chaos): no leak
            except OSError:
                with store._lock:
                    store.stats.leaked_spill_files += 1
            rec.path = None

    # ------------------------------------------------------------------
    def shutdown(self) -> None:
        """Drop every spill file and the spill directories.  Handles that
        were spilled become unusable — call only when the owning session is
        done (``reset_store`` / process exit / the CI spill smoke).  A later
        fault of such a handle raises :class:`StoreClosedError` naming this
        call site."""
        site = "<unknown>"
        for fr in reversed(traceback.extract_stack(limit=16)[:-1]):
            if os.path.basename(fr.filename) != "store.py":
                site = (f"{os.path.basename(fr.filename)}:{fr.lineno} "
                        f"in {fr.name}")
                break
        with self._lock:
            self._closed = True
            self._closed_site = site
            for h in list(self._handles):
                h._rec.path = None
            dirs, self._dirs = self._dirs, []
            self._base_list = None
            self._dir_idx = 0
        for d in dirs:
            if isinstance(d, str):
                shutil.rmtree(d, ignore_errors=True)


# =============================================================================
# module-level singleton + helpers
# =============================================================================
_STORE: BlockStore | None = None
_STORE_LOCK = threading.Lock()
_BUDGET_OVERRIDE: int | None = None
_DIR_OVERRIDE: str | None = None


def _env_budget() -> int:
    if _BUDGET_OVERRIDE is not None:
        return _BUDGET_OVERRIDE
    # warn-once parse: a malformed REPRO_MEM_BUDGET used to silently mean
    # "unlimited" — now it still falls back to 0, but says so (faults.env_int)
    return env_int("REPRO_MEM_BUDGET", 0, minimum=0)


def get_store() -> BlockStore:
    """The block store for the *current scope*: a session with its own store
    (``Session(mem_budget_bytes=...)`` private store, or the shared
    ``QueryService`` store all tenants charge against) resolves to that store
    while its ``config.SessionConfig`` is active; everything else gets the
    process-wide singleton built from ``REPRO_MEM_BUDGET`` /
    ``REPRO_SPILL_DIR`` (or the sticky :func:`configure` override)."""
    cfg = _config.current()
    if cfg is not None and cfg.store is not None:
        return cfg.store
    global _STORE
    if _STORE is None:
        with _STORE_LOCK:
            if _STORE is None:
                _STORE = BlockStore(_env_budget(), _DIR_OVERRIDE)
    return _STORE


def reset_store() -> None:
    """Tear down the store (deleting spill files) and let the next use
    rebuild it from the current environment — the ``schedule.reset_pool``
    counterpart for tests and session reconfiguration.  Blocks ingested
    under the old store keep working only if they were resident."""
    global _STORE
    with _STORE_LOCK:
        if _STORE is not None:
            _STORE.shutdown()
        _STORE = None


def configure(budget_bytes: int | None = None,
              spill_dir: str | None = None) -> BlockStore:
    """Process-wide programmatic override of the env knobs.
    ``Session(mem_budget_bytes=...)`` no longer calls this — it builds a
    session-*private* store resolved through ``config.SessionConfig``, so two
    sessions with different budgets can no longer clobber each other's spill
    state.  The override is sticky — it
    outlives the session that set it and shadows ``REPRO_MEM_BUDGET`` until
    changed again.

    Re-configuring with the *current* settings is a no-op; actually
    changing them resets the store, which deletes every existing spill
    file — frames ingested earlier lose their spilled blocks — so
    configure before ingesting data."""
    global _BUDGET_OVERRIDE, _DIR_OVERRIDE
    if budget_bytes is not None:
        _BUDGET_OVERRIDE = max(0, int(budget_bytes))
    if spill_dir is not None:
        _DIR_OVERRIDE = spill_dir
    with _STORE_LOCK:
        cur = _STORE
    if (cur is not None and cur.budget == _env_budget()
            and (spill_dir is None or cur._base_dir == spill_dir)):
        return cur
    reset_store()
    return get_store()


def unconfigure() -> None:
    """Clear the sticky :func:`configure` overrides and reset the store, so
    the next use rebuilds from ``REPRO_MEM_BUDGET`` / ``REPRO_SPILL_DIR``
    again — the public undo for ``Session(mem_budget_bytes=...)``."""
    global _BUDGET_OVERRIDE, _DIR_OVERRIDE
    _BUDGET_OVERRIDE = None
    _DIR_OVERRIDE = None
    reset_store()


def as_handle(block: "Frame | BlockHandle",
              recompute: "Callable[[], Frame] | None" = None) -> BlockHandle:
    """Wrap a Frame into the store (identity on handles).  ``recompute`` is
    the optional lineage thunk recorded for spill-integrity recovery; on an
    existing handle it only fills a missing one (never overwrites)."""
    if isinstance(block, BlockHandle):
        if recompute is not None and block._recompute is None:
            block._recompute = recompute
        return block
    return get_store().put(block, recompute=recompute)


def resolve(block: "Frame | BlockHandle") -> Frame:
    """The block's Frame — faulting it in if spilled (identity on Frames)."""
    if isinstance(block, BlockHandle):
        return block.frame()
    return block


@contextlib.contextmanager
def pinned(block: "Frame | BlockHandle") -> Iterator[Frame]:
    """Fault + pin scope for per-block kernel execution (identity on
    Frames).  Every dispatch-boundary block program runs inside one."""
    if isinstance(block, BlockHandle):
        with block.pinned() as f:
            yield f
    else:
        yield block
