"""Adaptive block scheduling: the layer between the physical operators and
the shared thread pool (ROADMAP: "Pool scheduling when partitions ≫ cores").

The paper (§4.2) picks a partitioning scheme per operation; this module makes
the *runtime* side of that choice adaptive in two ways:

1. **Coalesced dispatch** — :func:`dispatch_blocks` is the single entry point
   through which per-block work reaches the pool.  When the number of blocks
   exceeds the worker count, several contiguous blocks are chunked into ONE
   pool task (a worker runs them back-to-back), so a 256-partition grid on a
   4-worker pool costs ~8 pool dispatches instead of 256.  Results are always
   returned in block order, and each block is still processed independently —
   coalescing is bit-identical to per-block dispatch by construction (asserted
   property-style in ``tests/test_scheduling.py``).

2. **Plan-time grid sizing** — :func:`pool_width` is the one source of truth
   for the configured parallelism (``partition.default_grid`` sizes new grids
   from it instead of ``os.cpu_count()``), and :func:`preferred_row_parts`
   adapts a blocking operator's working grid to the worker set using the
   per-operator preference recorded on the plan node by
   ``rewrite.fuse_pipelines`` (GROUPBY partial programs and DIFFERENCE /
   DROP-DUPLICATES key extraction want blocks ≈ workers; WINDOW carry chains
   want fewer seams).  On the TPU mesh the same decision becomes the
   ``shard_map`` grid choice — blocks per core, not blocks per frame.

Dispatches inside a plan-node evaluation are attributed to the executor's
``ExecStats`` through :class:`stats_scope` (``dispatches`` /
``dispatched_blocks`` / ``blocks_per_dispatch``); the block-parallel
DIFFERENCE / DROP-DUPLICATES paths additionally report ``dedup_blocks`` and
``dedup_key_rows`` (blocks and rows their per-block key extraction covered)
so the scheduling win of the dedup grid preference is attributable.

Every dispatch — including a single-block workload — runs on the pool, so
exception provenance and thread-local device state are independent of the
partition count (a single-partition frame used to run inline on the caller
thread while a two-partition frame ran on pool workers).  The only inline
path left is the nested-dispatch guard: a call *from* a pool worker runs its
blocks in place rather than deadlocking on its own pool.

Environment knobs
-----------------
======================  =====================================================
``REPRO_POOL_WORKERS``  worker threads in the shared pool; also the width all
                        grid-sizing decisions consult (default: CPU count)
``REPRO_COALESCE``      ``0`` disables coalescing — one pool task per block,
                        the pre-scheduling behavior (benchmark baseline)
``REPRO_COALESCE_FACTOR``
                        pool tasks per worker when coalescing (default 2: a
                        little slack so an unlucky chunk can't serialize the
                        whole stage behind one worker)
``REPRO_ADAPT_GRID``    ``0`` disables plan-time grid adaptation — blocking
                        operators keep the incoming row grid no matter how
                        far it oversubscribes the pool
======================  =====================================================
"""
from __future__ import annotations

import concurrent.futures as _fut
import contextvars
import os
import threading
from typing import Callable, Sequence

__all__ = [
    "get_pool", "pool_width", "reset_pool", "dispatch_blocks",
    "coalesce_factor", "preferred_row_parts", "output_row_parts",
    "stats_scope", "GRID_PREFS",
]

# Per-operator grid preferences (paper §4.2: the partitioning scheme is
# chosen per operation).  ``rewrite.fuse_pipelines`` records these on
# barrier-fused plan nodes and the physical layer resolves them — for both
# fused and unfused paths, so the two always agree on seam placement — via
# :func:`preferred_row_parts`:
#   * GROUPBY partial-aggregation programs want blocks ≈ workers (fewer
#     per-block programs to dispatch and fewer partials to combine);
#   * WINDOW carry chains want fewer seams (every partition boundary costs a
#     carry composition);
#   * DIFFERENCE / DROP-DUPLICATES key extraction wants blocks ≈ workers —
#     each worker builds a couple of per-block key matrices and the joint
#     host factorization concatenates that many pieces instead of hundreds.
GRID_PREFS: dict[str, str] = {
    "fused_groupby": "workers",
    "groupby": "workers",
    "fused_window": "few_seams",
    "window": "few_seams",
    "fused_difference": "workers",
    "difference": "workers",
    "fused_drop_duplicates": "workers",
    "drop_duplicates": "workers",
}

# Pool workers are named with this prefix; the nested-dispatch guard keys on
# it.  Distinct from the executor's background pool ("repro-bg"), whose
# threads legitimately dispatch block work here.
_WORKER_PREFIX = "repro-pool"

_POOL: _fut.ThreadPoolExecutor | None = None
_POOL_WIDTH: int | None = None
_POOL_LOCK = threading.Lock()


def pool_width() -> int:
    """The configured pool parallelism — the width every grid-sizing decision
    consults.  Once the pool exists this is its actual worker count; before
    that, the width the pool *would* be built with (``REPRO_POOL_WORKERS``,
    else CPU count)."""
    if _POOL_WIDTH is not None:
        return _POOL_WIDTH
    return max(1, int(os.environ.get("REPRO_POOL_WORKERS",
                                     str(os.cpu_count() or 4))))


def get_pool() -> _fut.ThreadPoolExecutor:
    global _POOL, _POOL_WIDTH
    if _POOL is None:
        with _POOL_LOCK:
            if _POOL is None:
                width = pool_width()
                _POOL = _fut.ThreadPoolExecutor(
                    max_workers=width, thread_name_prefix=_WORKER_PREFIX)
                _POOL_WIDTH = width
    return _POOL


def reset_pool() -> None:
    """Drop the shared pool so the next use rebuilds it from the current
    environment (tests that change ``REPRO_POOL_WORKERS``).  In-flight tasks
    finish on the old pool's threads."""
    global _POOL, _POOL_WIDTH
    with _POOL_LOCK:
        if _POOL is not None:
            _POOL.shutdown(wait=False)
        _POOL = None
        _POOL_WIDTH = None


def coalesce_factor() -> int:
    return max(1, int(os.environ.get("REPRO_COALESCE_FACTOR", "2")))


def _coalesce_enabled() -> bool:
    return os.environ.get("REPRO_COALESCE", "") != "0"


def _adapt_enabled() -> bool:
    return os.environ.get("REPRO_ADAPT_GRID", "") != "0"


def _in_worker() -> bool:
    return threading.current_thread().name.startswith(_WORKER_PREFIX)


# ---------------------------------------------------------------------------
# dispatch-stats attribution: the executor installs its ExecStats for the
# duration of a plan-node evaluation; dispatch_blocks increments whatever is
# installed on the calling thread (contextvars are thread-local, so
# concurrent executors don't cross-attribute).
# ---------------------------------------------------------------------------
_STATS: contextvars.ContextVar = contextvars.ContextVar(
    "repro-sched-stats", default=None)


class stats_scope:
    """Context manager: attribute pool dispatches inside the scope to
    ``stats`` (duck-typed ``ExecStats`` — needs ``dispatches`` and
    ``dispatched_blocks`` int attributes)."""

    def __init__(self, stats):
        self._stats = stats
        self._token = None

    def __enter__(self):
        self._token = _STATS.set(self._stats)
        return self._stats

    def __exit__(self, *exc):
        _STATS.reset(self._token)
        return False


def _chunk_sizes(n: int, tasks: int) -> list[int]:
    tasks = max(1, min(tasks, n))
    base, rem = divmod(n, tasks)
    return [base + (1 if i < rem else 0) for i in range(tasks)]


def dispatch_blocks(fn: Callable, blocks: Sequence, stats=None, *,
                    attribute: bool = True) -> list:
    """Run ``fn`` over every block on the shared pool; ordered results.

    The single dispatch entry point for per-block work.  When
    ``len(blocks)`` exceeds ``pool_width() × coalesce_factor()``, contiguous
    blocks are chunked into one pool task each (block coalescing); otherwise
    one task per block.  Either way each block is processed independently in
    block order, so the result is bit-identical to per-block dispatch.

    ``stats`` (or the executor's installed :class:`stats_scope`) receives
    ``dispatches`` (pool tasks submitted) and ``dispatched_blocks`` (blocks
    they covered) — ``blocks_per_dispatch`` attributes the coalescing win.
    ``attribute=False`` opts a call out of those counters: pool work whose
    items are NOT row blocks (e.g. per-column factorization tasks) would
    otherwise skew the row-block scheduling ratios.
    """
    items = list(blocks)
    n = len(items)
    if n == 0:
        return []
    st = stats if stats is not None else (_STATS.get() if attribute else None)
    target = pool_width() * coalesce_factor()
    if not _coalesce_enabled() or n <= target:
        chunks = [[x] for x in items]
    else:
        chunks, off = [], 0
        for size in _chunk_sizes(n, target):
            chunks.append(items[off:off + size])
            off += size
    if st is not None:
        st.dispatches += len(chunks)
        st.dispatched_blocks += n

    def run_chunk(chunk: list) -> list:
        return [fn(x) for x in chunk]

    if _in_worker():
        # nested dispatch from a pool worker: run inline — queueing behind
        # ourselves on a saturated pool would deadlock
        return [fn(x) for x in items]
    out: list = []
    for res in get_pool().map(run_chunk, chunks):
        out.extend(res)
    return out


# ---------------------------------------------------------------------------
# plan-time grid sizing
# ---------------------------------------------------------------------------
def preferred_row_parts(nblocks: int, prefer: str | None = "workers") -> int:
    """The row grid a blocking operator should work over, given ``nblocks``
    incoming row partitions and its recorded preference:

    * ``"workers"`` (GROUPBY partial programs): blocks ≈ workers ×
      coalesce-factor — each worker gets a couple of per-block programs and
      the combine folds that many partials instead of hundreds;
    * ``"few_seams"`` (WINDOW carry chains): blocks == workers — every seam
      costs a carry composition, so don't make more seams than there are
      workers to hide them behind;
    * ``None``: keep the incoming grid.

    Only *coarsens*, and only when the incoming grid oversubscribes the target
    by more than 2× — mild oversubscription is already absorbed by coalesced
    dispatch, and regrouping copies row segments, which should only be paid
    when it retires many per-block programs.  Fused and unfused paths consult
    the same preference, so plan equivalence is preserved (both sides see the
    same seams).
    """
    if prefer is None or not _adapt_enabled() or nblocks <= 1:
        return nblocks
    width = pool_width()
    target = width if prefer == "few_seams" else width * coalesce_factor()
    return nblocks if nblocks <= 2 * target else target


def output_row_parts(nrows: int, *, min_block_rows: int = 4096) -> int:
    """Row grid for a blocking operator's *output* (SORT/JOIN/... materialize
    a fresh frame): bounded by the pool width, with the same minimum block
    height as ``partition.default_grid`` so small results stay
    single-partition exactly as before."""
    if not _adapt_enabled():
        return 1
    return max(1, min(pool_width(), nrows // max(1, min_block_rows)))
