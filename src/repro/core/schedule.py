"""Adaptive block scheduling: the layer between the physical operators and
the shared thread pool (ROADMAP: "Pool scheduling when partitions ≫ cores").

The paper (§4.2) picks a partitioning scheme per operation; this module makes
the *runtime* side of that choice adaptive in two ways:

1. **Coalesced dispatch** — :func:`dispatch_blocks` is the single entry point
   through which per-block work reaches the pool.  When the number of blocks
   exceeds the worker count, several contiguous blocks are chunked into ONE
   pool task (a worker runs them back-to-back), so a 256-partition grid on a
   4-worker pool costs ~8 pool dispatches instead of 256.  Results are always
   returned in block order, and each block is still processed independently —
   coalescing is bit-identical to per-block dispatch by construction (asserted
   property-style in ``tests/test_scheduling.py``).

2. **Plan-time grid sizing** — :func:`pool_width` is the one source of truth
   for the configured parallelism (``partition.default_grid`` sizes new grids
   from it instead of ``os.cpu_count()``), and :func:`preferred_row_parts`
   adapts a blocking operator's working grid to the worker set using the
   per-operator preference recorded on the plan node by
   ``rewrite.fuse_pipelines`` (GROUPBY partial programs and DIFFERENCE /
   DROP-DUPLICATES key extraction want blocks ≈ workers; WINDOW carry chains
   want fewer seams).  On the TPU mesh the same decision becomes the
   ``shard_map`` grid choice — blocks per core, not blocks per frame.

Dispatches inside a plan-node evaluation are attributed to the executor's
``ExecStats`` through :class:`stats_scope` (``dispatches`` /
``dispatched_blocks`` / ``blocks_per_dispatch``); the block-parallel
DIFFERENCE / DROP-DUPLICATES paths additionally report ``dedup_blocks`` and
``dedup_key_rows`` (blocks and rows their per-block key extraction covered)
so the scheduling win of the dedup grid preference is attributable.

Every dispatch — including a single-block workload — runs on the pool, so
exception provenance and thread-local device state are independent of the
partition count (a single-partition frame used to run inline on the caller
thread while a two-partition frame ran on pool workers).  The only inline
path left is the nested-dispatch guard: a call *from* a pool worker runs its
blocks in place rather than deadlocking on its own pool.

3. **Residency-aware ordering** — when the block store (``core.store``) is
   budget-governed, some of a dispatch's blocks may be spilled to disk.
   :func:`dispatch_blocks` orders the pool tasks so chunks of *resident*
   blocks run first: their compute overlaps the disk faults of the spilled
   tail (which happen inside the worker task that needs the block, never on
   the caller thread).  Results are scattered back to block order, so the
   reordering is invisible — bit-identical by the same per-block-independence
   argument as coalescing.

Environment knobs (the one table — referenced from ROADMAP.md)
--------------------------------------------------------------
=========================  ==================================================
``REPRO_POOL_WORKERS``     worker threads in the shared pool; also the width
                           all grid-sizing decisions consult (default: CPU
                           count)
``REPRO_COALESCE``         ``0`` disables coalescing — one pool task per
                           block, the pre-scheduling behavior (benchmark
                           baseline)
``REPRO_COALESCE_FACTOR``  pool tasks per worker when coalescing (default 2:
                           a little slack so an unlucky chunk can't serialize
                           the whole stage behind one worker)
``REPRO_ADAPT_GRID``       ``0`` disables plan-time grid adaptation —
                           blocking operators keep the incoming row grid no
                           matter how far it oversubscribes the pool
``REPRO_JIT_UDFS``         ``1`` forces jit-traced map-stage runs, ``0``
                           forces eager; default: eager on CPU, traced on
                           accelerators (``physical._jit_udfs_enabled``)
``REPRO_BLOCK_DEDUP``      ``0`` routes DIFFERENCE / DROP-DUPLICATES through
                           the serial whole-frame seed path (baseline /
                           equivalence oracle; ``physical``)
``REPRO_SHUFFLE``          ``0`` routes JOIN / SORT through the serial
                           whole-frame seed path instead of the grace-hash /
                           sample-sort exchange (baseline / bit-identity
                           oracle; ``core.shuffle``)
``REPRO_SHUFFLE_BUCKETS``  pins the exchange bucket count (default 0 = auto:
                           pool width × coalesce factor, raised so one
                           bucket's key frame fits ``budget_max_block_bytes``
                           under ``REPRO_MEM_BUDGET``)
``REPRO_SHUFFLE_SKEW_FACTOR`` a bucket holding more than this × the mean
                           bucket rows splits into part-tasks instead of
                           OOMing one worker (default 4; counted in
                           ``ExecStats.skew_splits``)
``REPRO_MEM_BUDGET``       byte budget for resident partition blocks +
                           cached sub-plan results (``core.store``); ``0``
                           (default) = unlimited, fully-resident fast path.
                           Over budget, blocks spill to disk and fault back
                           on demand
``REPRO_SPILL_DIR``        ``os.pathsep``-separated *failover list* of
                           directories under which the block store creates
                           its spill directories (default: the system
                           tempdir).  A spill write that fails with OSError
                           (ENOSPC, read-only mount) fails over to the next
                           entry; when every entry is exhausted the victim
                           stays resident and ``budget_overruns`` is counted
``REPRO_CSV_STREAM``       ``0`` routes ``api.read_csv`` through the serial
                           seed parser (baseline / equivalence oracle)
``REPRO_CSV_CHUNK_BYTES``  target byte size of a streaming-ingest CSV chunk
                           (default: sized from pool width and mem budget)
``REPRO_TASK_RETRIES``     bounded retries per block task for *transient*
                           failures — injected worker faults, OSError,
                           TimeoutError, ConnectionError (default 2; ``0``
                           disables the retry machinery entirely).
                           Deterministic errors (ValueError, ...) are never
                           retried and propagate unchanged
``REPRO_RETRY_BACKOFF_MS`` base backoff between retry attempts, doubling per
                           attempt (default 5)
``REPRO_TASK_TIMEOUT_MS``  per-dispatch deadline; a dispatch that blows it
                           raises ``TaskError`` with ``kind="timeout"``
                           (default 0 = no deadline)
``REPRO_FAULT_PLAN``       deterministic fault-injection plan (``core.faults``):
                           comma-separated ``kind[@addr_substr]:rate[!]``
                           rules, kinds ``worker`` / ``slow`` / ``corrupt`` /
                           ``missing`` / ``enospc``; ``!`` = sticky (fires on
                           retries / lineage-less reads too).  Empty
                           (default) = no injection, zero overhead
``REPRO_FAULT_SEED``       seed for the plan's per-address uniform draws
                           (default 0; same plan + seed + address ⇒ same
                           decision)
``REPRO_FAULT_SLOW_MS``    sleep injected by a ``slow`` fault rule
                           (default 25)
``REPRO_MAX_INFLIGHT``     per-session bound on concurrently *admitted*
                           async statements under a ``core.service``
                           ``QueryService`` (default 2); excess submissions
                           queue in the admission controller
                           (FIFO-with-aging) until a slot frees
``REPRO_TRACE``            statement tracing (``core.trace``): ``1`` records
                           per-statement span trees (plan prep → node eval →
                           dispatch → pool chunk → spill/fault/backoff) into
                           a bounded process-wide ring; a *path* value also
                           exports Chrome trace-event JSON there at process
                           exit (open in Perfetto).  Default off — the
                           disabled path allocates no spans and costs ≤1%
                           (``BENCH_trace.json``)
``REPRO_TRACE_RING``       finished-span ring capacity per tracer (default
                           65536; the oldest spans fall off the back)
=========================  ==================================================

Session-scoped override semantics (``core.config``): every knob in the
store / retry / fault / shuffle groups above can also be set per ``Session``
(``Session(task_retries=..., fault_plan=..., mem_budget_bytes=...)``).  Those
values live in a ``config.SessionConfig`` carried in a contextvar that the
session installs around each statement and this module propagates into pool
workers — they shadow the process-wide ``configure*()`` overrides and the
``REPRO_*`` env values *inside that session only*.  Resolution order for
every knob: active session config → process ``configure()`` override →
``REPRO_*`` env → default.  Env knobs therefore remain process defaults; a
second session can no longer clobber the first session's configuration.

Failure semantics: a dispatched statement either completes **bit-identical**
to the fault-free run (transient failures retried with exponential backoff;
a failed coalesced chunk split and retried per block, isolating one poison
block) or raises ONE typed ``faults.TaskError`` carrying full provenance —
plan node, block index, attempt count, and the underlying cause.
"""
from __future__ import annotations

import concurrent.futures as _fut
import contextvars
import os
import threading
import time
from typing import Callable, Sequence

from . import config as _config
from . import faults as _faults
from . import trace as _trace
from .faults import StatementCancelled, TaskError, env_int, is_retryable

__all__ = [
    "get_pool", "pool_width", "reset_pool", "dispatch_blocks",
    "coalesce_factor", "preferred_row_parts", "output_row_parts",
    "budget_max_block_bytes", "stats_scope", "node_scope", "GRID_PREFS",
    "task_retries", "retry_backoff_ms", "task_timeout_ms", "max_inflight",
    "configure_retries",
]

# Per-operator grid preferences (paper §4.2: the partitioning scheme is
# chosen per operation).  ``rewrite.fuse_pipelines`` records these on
# barrier-fused plan nodes and the physical layer resolves them — for both
# fused and unfused paths, so the two always agree on seam placement — via
# :func:`preferred_row_parts`:
#   * GROUPBY partial-aggregation programs want blocks ≈ workers (fewer
#     per-block programs to dispatch and fewer partials to combine);
#   * WINDOW carry chains want fewer seams (every partition boundary costs a
#     carry composition);
#   * DIFFERENCE / DROP-DUPLICATES key extraction wants blocks ≈ workers —
#     each worker builds a couple of per-block key matrices and the joint
#     host factorization concatenates that many pieces instead of hundreds;
#   * JOIN / SORT (``core.shuffle``) bucketize per block, so the same
#     blocks ≈ workers preference bounds both the exchange fan-out and the
#     number of per-block key frames a bucket concat touches.
GRID_PREFS: dict[str, str] = {
    "fused_groupby": "workers",
    "groupby": "workers",
    "fused_window": "few_seams",
    "window": "few_seams",
    "fused_difference": "workers",
    "difference": "workers",
    "fused_drop_duplicates": "workers",
    "drop_duplicates": "workers",
    "fused_join": "workers",
    "join": "workers",
    "fused_sort": "workers",
    "sort": "workers",
}

# Pool workers are named with this prefix; the nested-dispatch guard keys on
# it.  Distinct from the executor's background pool ("repro-bg"), whose
# threads legitimately dispatch block work here.
_WORKER_PREFIX = "repro-pool"

_POOL: _fut.ThreadPoolExecutor | None = None
_POOL_WIDTH: int | None = None
_POOL_LOCK = threading.Lock()


def pool_width() -> int:
    """The configured pool parallelism — the width every grid-sizing decision
    consults.  Once the pool exists this is its actual worker count; before
    that, the width the pool *would* be built with (``REPRO_POOL_WORKERS``,
    else CPU count)."""
    if _POOL_WIDTH is not None:
        return _POOL_WIDTH
    return max(1, int(os.environ.get("REPRO_POOL_WORKERS",
                                     str(os.cpu_count() or 4))))


def get_pool() -> _fut.ThreadPoolExecutor:
    global _POOL, _POOL_WIDTH
    if _POOL is None:
        with _POOL_LOCK:
            if _POOL is None:
                width = pool_width()
                _POOL = _fut.ThreadPoolExecutor(
                    max_workers=width, thread_name_prefix=_WORKER_PREFIX)
                _POOL_WIDTH = width
    return _POOL


def reset_pool() -> None:
    """Drop the shared pool so the next use rebuilds it from the current
    environment (tests that change ``REPRO_POOL_WORKERS``).  In-flight tasks
    finish on the old pool's threads."""
    global _POOL, _POOL_WIDTH
    with _POOL_LOCK:
        if _POOL is not None:
            _POOL.shutdown(wait=False)
        _POOL = None
        _POOL_WIDTH = None


def coalesce_factor() -> int:
    return env_int("REPRO_COALESCE_FACTOR", 2, minimum=1)


# ---------------------------------------------------------------------------
# retry / deadline policy (fault tolerance, PR 6)
# ---------------------------------------------------------------------------
_RETRIES_OVERRIDE: int | None = None
_BACKOFF_OVERRIDE: int | None = None
_TIMEOUT_OVERRIDE: int | None = None


def task_retries() -> int:
    """Bounded retries per block task for transient failures (injected
    worker faults, OSError, TimeoutError, ConnectionError).  0 disables.
    Session-scoped resolution: active ``SessionConfig`` → process override →
    ``REPRO_TASK_RETRIES``."""
    cfg = _config.current()
    if cfg is not None and cfg.task_retries is not None:
        return max(0, cfg.task_retries)
    if _RETRIES_OVERRIDE is not None:
        return _RETRIES_OVERRIDE
    return env_int("REPRO_TASK_RETRIES", 2, minimum=0)


def retry_backoff_ms() -> int:
    """Base backoff between retry attempts; doubles per attempt."""
    cfg = _config.current()
    if cfg is not None and cfg.retry_backoff_ms is not None:
        return max(0, cfg.retry_backoff_ms)
    if _BACKOFF_OVERRIDE is not None:
        return _BACKOFF_OVERRIDE
    return env_int("REPRO_RETRY_BACKOFF_MS", 5, minimum=0)


def task_timeout_ms() -> int:
    """Per-dispatch deadline (0 = none).  A dispatch that blows it raises
    ``TaskError`` with ``kind="timeout"``."""
    cfg = _config.current()
    if cfg is not None and cfg.task_timeout_ms is not None:
        return max(0, cfg.task_timeout_ms)
    if _TIMEOUT_OVERRIDE is not None:
        return _TIMEOUT_OVERRIDE
    return env_int("REPRO_TASK_TIMEOUT_MS", 0, minimum=0)


def max_inflight() -> int:
    """Per-session bound on concurrently *admitted* async statements under a
    ``core.service.QueryService`` (excess submissions queue in the admission
    controller until a slot frees).  Session-scoped resolution: active
    ``SessionConfig`` → ``REPRO_MAX_INFLIGHT`` (default 2)."""
    cfg = _config.current()
    if cfg is not None and cfg.max_inflight is not None:
        return max(1, cfg.max_inflight)
    return env_int("REPRO_MAX_INFLIGHT", 2, minimum=1)


def configure_retries(retries: int | None = None,
                      timeout_ms: int | None = None,
                      backoff_ms: int | None = None,
                      *, clear: bool = False) -> None:
    """Process-wide programmatic override of the retry/deadline env knobs.
    Sticky until ``clear=True``.  ``Session(task_retries=...)`` no longer
    calls this — its values are session-scoped (``config.SessionConfig``)
    and shadow this override only inside that session's statements."""
    global _RETRIES_OVERRIDE, _TIMEOUT_OVERRIDE, _BACKOFF_OVERRIDE
    if clear:
        _RETRIES_OVERRIDE = _TIMEOUT_OVERRIDE = _BACKOFF_OVERRIDE = None
    if retries is not None:
        _RETRIES_OVERRIDE = max(0, int(retries))
    if timeout_ms is not None:
        _TIMEOUT_OVERRIDE = max(0, int(timeout_ms))
    if backoff_ms is not None:
        _BACKOFF_OVERRIDE = max(0, int(backoff_ms))


def _coalesce_enabled() -> bool:
    return os.environ.get("REPRO_COALESCE", "") != "0"


def _adapt_enabled() -> bool:
    return os.environ.get("REPRO_ADAPT_GRID", "") != "0"


def _in_worker() -> bool:
    return threading.current_thread().name.startswith(_WORKER_PREFIX)


# ---------------------------------------------------------------------------
# dispatch-stats attribution: the executor installs its ExecStats for the
# duration of a plan-node evaluation; dispatch_blocks increments whatever is
# installed on the calling thread (contextvars are thread-local, so
# concurrent executors don't cross-attribute).
# ---------------------------------------------------------------------------
_STATS: contextvars.ContextVar = contextvars.ContextVar(
    "repro-sched-stats", default=None)


class stats_scope:
    """Context manager: attribute pool dispatches inside the scope to
    ``stats`` (duck-typed ``ExecStats`` — needs ``dispatches`` and
    ``dispatched_blocks`` int attributes)."""

    def __init__(self, stats):
        self._stats = stats
        self._token = None

    def __enter__(self):
        self._token = _STATS.set(self._stats)
        return self._stats

    def __exit__(self, *exc):
        _STATS.reset(self._token)
        return False


# the plan-node label of the evaluation a dispatch belongs to — provenance
# for TaskError and the fault-injection dispatch addresses.  Installed by
# the executor around each node evaluation (like stats_scope).
_NODE: contextvars.ContextVar = contextvars.ContextVar(
    "repro-sched-node", default=None)


class node_scope:
    """Context manager: label dispatches inside the scope with the plan
    node's operator name (TaskError provenance + fault addresses)."""

    def __init__(self, label: str):
        self._label = label
        self._token = None

    def __enter__(self):
        self._token = _NODE.set(self._label)
        return self._label

    def __exit__(self, *exc):
        _NODE.reset(self._token)
        return False


# retry/failure counters are bumped from pool-worker threads, so the stats
# object can't rely on the single-threaded += the other counters use
_BUMP_LOCK = threading.Lock()


def _bump(st, name: str, d: int = 1) -> None:
    if st is not None and hasattr(st, name):
        with _BUMP_LOCK:
            setattr(st, name, getattr(st, name) + d)


def _check_cancel(cancel, label: str) -> None:
    """Cooperative cancellation check between block tasks (the cancel token
    travels with the dispatch via ``config.propagate``)."""
    if cancel is not None and cancel.cancelled:
        raise StatementCancelled(
            "statement cancelled at a dispatch boundary", node=label)


def _run_one(fn: Callable, x, bi: int, retries: int, backoff_ms: int,
             label: str, st, chaos: bool, cancel=None):
    """One block task under the retry policy: transient failures retry with
    exponential backoff up to ``retries`` times, then surface as TaskError
    with full provenance; deterministic errors propagate unchanged on the
    first attempt."""
    attempt = 0
    while True:
        _check_cancel(cancel, label)
        try:
            if chaos:
                _faults.fault_point(
                    f"dispatch/node={label}/blk={bi}/try={attempt}",
                    attempt=attempt)
            return fn(x)
        except Exception as e:
            if not is_retryable(e):
                raise
            _bump(st, "task_failures")
            if attempt >= retries:
                raise TaskError(
                    "block task failed past the retry budget",
                    node=label, block=bi, attempts=attempt + 1,
                    cause=e) from e
            _bump(st, "retries")
            if backoff_ms > 0:
                delay = backoff_ms * (1 << attempt) / 1000.0
                tr = _trace.current()
                if tr is not None:
                    # the backoff sleep is attributable stall time: record it
                    # as a span so profile() can say how long retries idled
                    with tr.span("backoff", "retry",
                                 args={"node": label, "block": bi,
                                       "attempt": attempt + 1}):
                        time.sleep(delay)
                else:
                    time.sleep(delay)
            attempt += 1


def _chunk_sizes(n: int, tasks: int) -> list[int]:
    tasks = max(1, min(tasks, n))
    base, rem = divmod(n, tasks)
    return [base + (1 if i < rem else 0) for i in range(tasks)]


def _spilled(item) -> bool:
    """True for a dispatch item that is (or carries, under any nesting of
    leading tuple elements) a spilled store block — duck-typed on
    ``is_resident`` so this module needs no store import.  Unwrapping
    nested tuples matters: several dispatch sites pack the handle as
    ``((handle, meta...), extra...)``."""
    while isinstance(item, tuple) and item:
        item = item[0]
    r = getattr(item, "is_resident", None)
    return r is not None and not r


def dispatch_blocks(fn: Callable, blocks: Sequence, stats=None, *,
                    attribute: bool = True) -> list:
    """Run ``fn`` over every block on the shared pool; ordered results.

    The single dispatch entry point for per-block work.  When
    ``len(blocks)`` exceeds ``pool_width() × coalesce_factor()``, contiguous
    blocks are chunked into one pool task each (block coalescing); otherwise
    one task per block.  Either way each block is processed independently in
    block order, so the result is bit-identical to per-block dispatch.

    Residency-aware: when some blocks are store handles that are currently
    spilled, the dispatch *order* moves resident blocks to the front (their
    compute overlaps the spilled blocks' disk faults, which the workers pay
    inside their own tasks); results are scattered back so the caller always
    sees block order.

    ``stats`` (or the executor's installed :class:`stats_scope`) receives
    ``dispatches`` (pool tasks submitted) and ``dispatched_blocks`` (blocks
    they covered) — ``blocks_per_dispatch`` attributes the coalescing win.
    ``attribute=False`` opts a call out of those counters: pool work whose
    items are NOT row blocks (e.g. per-column factorization tasks) would
    otherwise skew the row-block scheduling ratios.

    Fault tolerance: transient failures (injected worker faults, OSError,
    TimeoutError, ConnectionError) retry with exponential backoff up to
    ``REPRO_TASK_RETRIES`` times.  A failed *coalesced* chunk is split and
    retried per block, so one poison block is isolated and reported — with
    plan node, block index, and attempt count — via ``faults.TaskError``.
    Deterministic errors propagate unchanged on the first attempt.  With
    ``REPRO_TASK_TIMEOUT_MS`` set, the whole dispatch runs under a deadline
    and raises ``TaskError(kind="timeout")`` when it blows it.
    """
    items = list(blocks)
    n = len(items)
    if n == 0:
        return []
    st = stats if stats is not None else (_STATS.get() if attribute else None)

    # resident blocks first (stable within each class, so the permutation is
    # deterministic given the residency snapshot); identity when nothing is
    # spilled — the common fully-resident case costs one any() sweep
    perm: list[int] | None = None
    if n > 1 and any(_spilled(x) for x in items):
        perm = sorted(range(n), key=lambda i: _spilled(items[i]))
        items = [items[i] for i in perm]
    idxs: Sequence[int] = perm if perm is not None else range(n)

    target = pool_width() * coalesce_factor()
    if not _coalesce_enabled() or n <= target:
        chunks = [([x], [bi]) for x, bi in zip(items, idxs)]
    else:
        chunks, off = [], 0
        for size in _chunk_sizes(n, target):
            chunks.append((items[off:off + size], list(idxs[off:off + size])))
            off += size
    if st is not None:
        st.dispatches += len(chunks)
        st.dispatched_blocks += n

    retries = task_retries()
    backoff = retry_backoff_ms()
    timeout = task_timeout_ms()
    chaos = _faults.active()
    guarded = chaos or retries > 0
    label = _NODE.get() or "?"
    # session scope travels with the dispatch: the knob accessors above ran
    # on the caller thread (where the session's contextvar config is
    # installed); the per-block fn may consult the store / fault plan from a
    # POOL thread, so the config — and the statement's cancel token — are
    # captured here and re-installed inside every pool task
    cfg = _config.current()
    cancel = _config.current_cancel()
    _check_cancel(cancel, label)
    # tracing (off = None: no span allocation anywhere below).  The dispatch
    # span is begun here on the caller thread and travels to the pool workers
    # via config.propagate, so every chunk span parents to it even though the
    # two run on different threads.
    tr = _trace.current(cfg)
    dsp = None
    if tr is not None:
        dsp = tr.begin(f"dispatch:{label}", "dispatch")
        dsp.args = {"blocks": n, "chunks": len(chunks)}

    def chunk_body(chunk, cidx) -> list:
        if not guarded:
            if cancel is None:
                return [fn(x) for x in chunk]
            out = []
            for x in chunk:
                _check_cancel(cancel, label)
                out.append(fn(x))
            return out
        if not chaos:
            # hot path: one try around the plain loop — the per-block
            # retry machinery is only paid when something actually failed
            try:
                out = []
                for x in chunk:
                    _check_cancel(cancel, label)
                    out.append(fn(x))
                return out
            except Exception as e:
                if not is_retryable(e):
                    raise
                _bump(st, "task_failures")
        # chaos run, or a coalesced chunk hit a transient failure: split
        # and run per block so one poison block is isolated (fn is pure,
        # so re-running the chunk's other blocks is bit-identical)
        return [_run_one(fn, x, bi, retries, backoff, label, st, chaos,
                         cancel)
                for x, bi in zip(chunk, cidx)]

    def run_chunk(chunk_and_idxs) -> list:
        chunk, cidx = chunk_and_idxs
        with _config.propagate(cfg, cancel, dsp):
            if tr is None:
                return chunk_body(chunk, cidx)
            with tr.span(f"chunk:{label}", "task",
                         args={"blocks": len(cidx), "first_block": cidx[0]}):
                return chunk_body(chunk, cidx)

    try:
        out = _collect_dispatch(run_chunk, chunks, items, idxs, fn, retries,
                                backoff, timeout, label, st, chaos, guarded,
                                cancel)
    finally:
        if dsp is not None:
            tr.end(dsp)
    if perm is not None:
        restored: list = [None] * n
        for pos, orig in enumerate(perm):
            restored[orig] = out[pos]
        return restored
    return out


def _collect_dispatch(run_chunk, chunks, items, idxs, fn, retries, backoff,
                      timeout, label, st, chaos, guarded, cancel) -> list:
    """The placement half of :func:`dispatch_blocks`: inline (nested from a
    pool worker), deadline, or chunk-by-chunk submission with pool-loss
    recovery and fail-fast sibling drain.  Split out so the dispatch span
    brackets exactly this region."""
    if _in_worker():
        # nested dispatch from a pool worker: run inline — queueing behind
        # ourselves on a saturated pool would deadlock
        if guarded:
            out = [_run_one(fn, x, bi, retries, backoff, label, st, chaos,
                            cancel)
                   for x, bi in zip(items, idxs)]
        else:
            out = []
            for x in items:
                _check_cancel(cancel, label)
                out.append(fn(x))
    elif timeout > 0:
        pool = get_pool()
        deadline = time.monotonic() + timeout / 1000.0
        futs = [pool.submit(run_chunk, c) for c in chunks]
        out = []
        try:
            for fu in futs:
                rem = deadline - time.monotonic()
                try:
                    out.extend(fu.result(timeout=max(rem, 0.0)))
                except (_fut.TimeoutError, TimeoutError):
                    raise TaskError(
                        f"dispatch blew its {timeout}ms deadline",
                        node=label, attempts=1, kind="timeout") from None
        finally:
            for fu in futs:
                fu.cancel()
    else:
        # submit chunk-by-chunk so losing the shared pool mid-dispatch
        # (reset_pool() under an in-flight dispatch — the worker-loss
        # recovery path) is survivable: futures already submitted finish on
        # the old pool's threads; the rest move to the rebuilt pool, and if
        # that one dies too they run on the caller thread.  run_chunk is
        # pure, so any placement is bit-identical.
        pool = get_pool()
        rebuilt = False
        futs: list = []
        for c in chunks:
            fu = None
            while True:
                try:
                    fu = pool.submit(run_chunk, c)
                    break
                except RuntimeError as e:
                    if "shutdown" not in str(e).lower():
                        raise
                    if rebuilt:
                        break           # second loss: run inline below
                    pool = get_pool()   # pool was reset under us
                    rebuilt = True
            futs.append((fu, c))
        out = []
        first_err: BaseException | None = None
        for fu, c in futs:
            if first_err is not None:
                # fail-fast with DETERMINISTIC teardown: a failed chunk must
                # not leave sibling tasks running past this dispatch — their
                # store/fault work would be misattributed to whatever
                # statement (possibly another session's) runs next.  Cancel
                # what hasn't started and drain what has, then raise.
                if fu is not None:
                    fu.cancel()
                    try:
                        fu.result()
                    except BaseException:
                        pass
                continue
            try:
                out.extend(fu.result() if fu is not None else run_chunk(c))
            except BaseException as e:
                first_err = e
        if first_err is not None:
            raise first_err
    return out


# ---------------------------------------------------------------------------
# plan-time grid sizing
# ---------------------------------------------------------------------------
def budget_max_block_bytes() -> int:
    """Largest working block the memory budget tolerates, or 0 when the
    store is unbudgeted.  Sized so that every pool worker can hold one input
    block pinned AND register one output block while the resident set still
    fits the budget: budget // (2·workers + 2), the +2 leaving room for one
    in-flight fault reservation.  This is the out-of-core invariant behind
    ``peak_resident_bytes ≤ budget + one block``."""
    from .store import get_store
    b = get_store().budget
    if b <= 0:
        return 0
    return max(1, b // (2 * pool_width() + 2))


def preferred_row_parts(nblocks: int, prefer: str | None = "workers",
                        total_bytes: int | None = None) -> int:
    """The row grid a blocking operator should work over, given ``nblocks``
    incoming row partitions and its recorded preference:

    * ``"workers"`` (GROUPBY partial programs): blocks ≈ workers ×
      coalesce-factor — each worker gets a couple of per-block programs and
      the combine folds that many partials instead of hundreds;
    * ``"few_seams"`` (WINDOW carry chains): blocks == workers — every seam
      costs a carry composition, so don't make more seams than there are
      workers to hide them behind;
    * ``None``: keep the incoming grid.

    Only *coarsens*, and only when the incoming grid oversubscribes the target
    by more than 2× — mild oversubscription is already absorbed by coalesced
    dispatch, and regrouping copies row segments, which should only be paid
    when it retires many per-block programs.  Fused and unfused paths consult
    the same preference, so plan equivalence is preserved (both sides see the
    same seams).

    ``total_bytes`` (handle metadata — callers pass ``pf.nbytes()``) makes
    the decision budget-aware: under ``REPRO_MEM_BUDGET`` the coarsening
    never builds blocks larger than :func:`budget_max_block_bytes`, so the
    pinned working set of a fully busy pool stays inside the budget and
    blocks remain spillable units.  With the default budget 0 the floor is
    inert and the decision is byte-blind, exactly as before.
    """
    if prefer is None or not _adapt_enabled() or nblocks <= 1:
        return nblocks
    width = pool_width()
    target = width if prefer == "few_seams" else width * coalesce_factor()
    if total_bytes:
        mb = budget_max_block_bytes()
        if mb:
            floor = -(-total_bytes // mb)        # ceil
            if floor > target:
                target = min(nblocks, floor)
    return nblocks if nblocks <= 2 * target else target


def output_row_parts(nrows: int, *, min_block_rows: int = 4096) -> int:
    """Row grid for a blocking operator's *output* (SORT/JOIN/... materialize
    a fresh frame): bounded by the pool width, with the same minimum block
    height as ``partition.default_grid`` so small results stay
    single-partition exactly as before."""
    if not _adapt_enabled():
        return 1
    return max(1, min(pool_width(), nrows // max(1, min_block_rows)))
