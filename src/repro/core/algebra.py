"""The dataframe algebra (paper §3.3, Table 1) as a logical plan language.

Operators
---------
Ordered analogs of extended relational algebra:
  SELECTION, PROJECTION, UNION, DIFFERENCE, CROSS/JOIN, DROP-DUPLICATES,
  GROUPBY, SORT, RENAME
plus SQL's WINDOW, plus the four dataframe-specific operators:
  TRANSPOSE, MAP, TOLABELS, FROMLABELS.

Each node records the Table-1 properties that drive optimization:
  * ``schema_kind``  — static / inferred / dynamic (dynamic ⇒ output schema is
    data-dependent and must be induced by S(·) at runtime);
  * ``order``        — parent-preserving vs order-creating (SORT, GROUPBY);
  * ``touches``      — metadata / data / both (TOLABELS & co. move values
    between A_mn and R_m/C_n, which relational algebra cannot express).

Predicates and projections are *structured expressions* (``Expr``) when
analyzable — enabling pushdown rules in ``rewrite.py`` — and opaque ``Udf``
objects otherwise (MAP's general case).  Udfs carry declared column
dependencies so rewrites can still reason about commutation.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Optional, Sequence

__all__ = [
    "Expr", "ColRef", "Lit", "BinExpr", "UnaryExpr", "col", "lit",
    "Udf",
    "Node", "Source", "Selection", "Projection", "Union", "Difference",
    "Join", "DropDuplicates", "GroupBy", "Sort", "Rename", "Window",
    "Transpose", "Map", "ToLabels", "FromLabels", "Limit",
    "ColumnSort", "ColumnFilter", "Stage", "FusedPipeline",
    "FusedGroupBy", "FusedSort", "FusedJoin", "FusedWindow",
    "FusedDifference", "FusedDropDuplicates",
    "AGG_FUNCS", "WINDOW_FUNCS", "prefix_safe", "fusible", "FUSIBLE_OPS",
    "BARRIER_FUSED_OPS",
]

AGG_FUNCS = ("sum", "count", "mean", "min", "max", "any", "all", "var", "std")
WINDOW_FUNCS = ("cumsum", "cummax", "cummin", "cumprod", "diff", "shift", "rolling_sum", "rolling_mean")


# =============================================================================
# Expressions (structured, analyzable predicates / scalar transforms)
# =============================================================================
class Expr:
    """Scalar expression over a row's columns."""

    def refs(self) -> frozenset:
        raise NotImplementedError

    # operator sugar ----------------------------------------------------
    def _bin(self, op: str, other) -> "Expr":
        return BinExpr(op, self, other if isinstance(other, Expr) else Lit(other))

    def __eq__(self, other):  # type: ignore[override]
        return self._bin("==", other)

    def __ne__(self, other):  # type: ignore[override]
        return self._bin("!=", other)

    def __lt__(self, other):
        return self._bin("<", other)

    def __le__(self, other):
        return self._bin("<=", other)

    def __gt__(self, other):
        return self._bin(">", other)

    def __ge__(self, other):
        return self._bin(">=", other)

    def __add__(self, other):
        return self._bin("+", other)

    def __sub__(self, other):
        return self._bin("-", other)

    def __mul__(self, other):
        return self._bin("*", other)

    def __truediv__(self, other):
        return self._bin("/", other)

    def __mod__(self, other):
        return self._bin("%", other)

    def __floordiv__(self, other):
        return self._bin("//", other)

    def __and__(self, other):
        return self._bin("&", other)

    def __or__(self, other):
        return self._bin("|", other)

    def __invert__(self):
        return UnaryExpr("~", self)

    def isna(self):
        return UnaryExpr("isna", self)

    def notna(self):
        return UnaryExpr("notna", self)

    def __hash__(self):
        return hash(self.key())

    def key(self) -> tuple:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True, eq=False)
class ColRef(Expr):
    name: Any

    def refs(self) -> frozenset:
        return frozenset([self.name])

    def key(self) -> tuple:
        return ("col", self.name)

    def __repr__(self):
        return f"col({self.name!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class Lit(Expr):
    value: Any

    def refs(self) -> frozenset:
        return frozenset()

    def key(self) -> tuple:
        # type name included: 1 == 1.0 == True in Python, but int/float/bool
        # literals evaluate differently (integer arithmetic stays exact), so
        # their plans must not collide in the executor/predicate caches
        return ("lit", type(self.value).__name__, self.value)

    def __repr__(self):
        return f"lit({self.value!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class BinExpr(Expr):
    op: str
    left: Expr
    right: Expr

    def refs(self) -> frozenset:
        return self.left.refs() | self.right.refs()

    def key(self) -> tuple:
        return ("bin", self.op, self.left.key(), self.right.key())

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class UnaryExpr(Expr):
    op: str
    operand: Expr

    def refs(self) -> frozenset:
        return self.operand.refs()

    def key(self) -> tuple:
        return ("un", self.op, self.operand.key())

    def __repr__(self):
        return f"{self.op}({self.operand!r})"


def col(name: Any) -> ColRef:
    return ColRef(name)


def lit(v: Any) -> Lit:
    return Lit(v)


# =============================================================================
# Opaque user-defined functions (MAP's general case)
# =============================================================================
_UDF_COUNTER = itertools.count()


@dataclasses.dataclass(frozen=True)
class Udf:
    """A named row-wise function ``f : D_n → D'_{n'}`` (paper §3.3 MAP).

    ``fn`` receives a host dict {col_label: column Frame view} at the
    *vectorized* level (whole-column arrays, not scalars) and returns a dict
    of output columns — the TPU-idiomatic batch form of the paper's per-row f.

    ``deps``: column labels read (None ⇒ all — blocks pushdown through it).
    ``elementwise``: True ⇒ output row i depends only on input row i (legal to
    run per row-block with no cross-partition exchange, and commutes with
    SELECTION).  Hashing/caching is by ``name`` + ``version``: two Udfs with
    the same (name, version) are treated as the same function.
    """

    name: str
    fn: Callable
    deps: Optional[frozenset] = None
    elementwise: bool = True
    out_cols: Optional[tuple] = None     # declared output labels (else inferred)
    version: int = 0

    @staticmethod
    def wrap(fn: Callable, name: str | None = None, **kw) -> "Udf":
        return Udf(name=name or f"udf_{next(_UDF_COUNTER)}", fn=fn, **kw)

    def key(self) -> tuple:
        return ("udf", self.name, self.version)

    def __hash__(self):
        return hash(self.key())


# =============================================================================
# Logical plan nodes
# =============================================================================
class Node:
    """Logical plan node.  Immutable; structurally hashable for CSE/reuse."""

    op: str = "?"
    schema_kind: str = "static"   # static | inferred | dynamic  (Table 1)
    order: str = "parent"         # parent | new                 (Table 1)
    touches: str = "data"         # data | metadata | both       (Table 1)

    def __init__(self, children: Sequence["Node"], **params):
        self.children = tuple(children)
        self.params = params
        self._key = (self.op, tuple(c._key for c in self.children), _freeze(params))
        self._hash = hash(self._key)

    # structural identity → common-subexpression detection (paper §6.2.1)
    def __hash__(self):
        return self._hash

    def __eq__(self, other):
        return isinstance(other, Node) and self._key == other._key

    def cache_key(self) -> tuple:
        return self._key

    def __repr__(self):
        ps = ", ".join(f"{k}={v!r}" for k, v in self.params.items() if v is not None)
        return f"{self.op}({ps})<-[{', '.join(c.op for c in self.children)}]"

    # --- traversal helpers --------------------------------------------
    def walk(self):
        seen = set()
        stack = [self]
        while stack:
            n = stack.pop()
            if id(n) in seen:
                continue
            seen.add(id(n))
            yield n
            stack.extend(n.children)

    def depth(self) -> int:
        return 1 + max((c.depth() for c in self.children), default=0)


def _freeze(obj):
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    if isinstance(obj, (set, frozenset)):
        return tuple(sorted(_freeze(v) for v in obj))
    if isinstance(obj, Expr):
        return obj.key()
    if isinstance(obj, Udf):
        return obj.key()
    if isinstance(obj, Stage):
        return obj.key()
    return obj


# ---- sources ----------------------------------------------------------------
class Source(Node):
    """A materialized input dataframe (leaf).  ``frame_id`` keys the session's
    frame store; the payload itself never enters the plan (hashability)."""

    op = "source"

    def __init__(self, frame_id: str, nrows: int | None = None, ncols: int | None = None):
        super().__init__([], frame_id=frame_id, nrows=nrows, ncols=ncols)


# ---- ordered relational analogs ---------------------------------------------
class Selection(Node):
    op = "selection"

    def __init__(self, child: Node, predicate: Expr | Udf):
        super().__init__([child], predicate=predicate)

    @property
    def predicate(self):
        return self.params["predicate"]


class Projection(Node):
    op = "projection"

    def __init__(self, child: Node, cols: Sequence[Any]):
        super().__init__([child], cols=tuple(cols))

    @property
    def cols(self):
        return self.params["cols"]


class Union(Node):
    op = "union"
    # ordered by left argument first, then right (Table 1 †)

    def __init__(self, left: Node, right: Node):
        super().__init__([left, right])


class Difference(Node):
    op = "difference"

    def __init__(self, left: Node, right: Node):
        super().__init__([left, right])


class Join(Node):
    """JOIN / CROSS-PRODUCT.  ``on=None`` ⇒ cross product.  Ordered: left
    order outer, right order breaking ties (Table 1 †)."""

    op = "join"

    def __init__(self, left: Node, right: Node, on: Sequence[Any] | None = None,
                 how: str = "inner", left_on: Sequence[Any] | None = None,
                 right_on: Sequence[Any] | None = None):
        super().__init__(
            [left, right],
            on=tuple(on) if on is not None else None,
            left_on=tuple(left_on) if left_on is not None else None,
            right_on=tuple(right_on) if right_on is not None else None,
            how=how,
        )


class DropDuplicates(Node):
    op = "drop_duplicates"

    def __init__(self, child: Node, subset: Sequence[Any] | None = None):
        super().__init__([child], subset=tuple(subset) if subset else None)


class GroupBy(Node):
    """GROUPBY keys with per-column aggregates; output ordered by sorted key
    (order: New, Table 1)."""

    op = "groupby"
    order = "new"

    def __init__(self, child: Node, keys: Sequence[Any], aggs: Sequence[tuple]):
        # aggs: tuple of (col_label, func_name, out_label)
        super().__init__([child], keys=tuple(keys), aggs=tuple(tuple(a) for a in aggs))


class Sort(Node):
    op = "sort"
    order = "new"

    def __init__(self, child: Node, by: Sequence[Any], ascending: bool = True):
        super().__init__([child], by=tuple(by), ascending=ascending)


class Rename(Node):
    op = "rename"
    touches = "metadata"

    def __init__(self, child: Node, mapping: dict):
        super().__init__([child], mapping=tuple(sorted(mapping.items(), key=repr)))


class Window(Node):
    """Sliding-window function applied in order (SQL WINDOW analog)."""

    op = "window"

    def __init__(self, child: Node, func: str, cols: Sequence[Any] | None = None,
                 size: int | None = None, periods: int = 1):
        assert func in WINDOW_FUNCS, func
        super().__init__([child], func=func, cols=tuple(cols) if cols else None,
                         size=size, periods=periods)


# ---- dataframe-specific operators --------------------------------------------
class Transpose(Node):
    op = "transpose"
    schema_kind = "dynamic"   # output schema induced from data (Table 1)
    touches = "both"

    def __init__(self, child: Node):
        super().__init__([child])


class Map(Node):
    op = "map"
    schema_kind = "inferred"  # from the Udf's signature when declared
    touches = "both"

    def __init__(self, child: Node, udf: Udf):
        super().__init__([child], udf=udf)

    @property
    def udf(self) -> Udf:
        return self.params["udf"]


class ToLabels(Node):
    """Promote a data column to the row labels (paper: data → metadata)."""

    op = "to_labels"
    schema_kind = "dynamic"
    touches = "both"

    def __init__(self, child: Node, column: Any):
        super().__init__([child], column=column)


class FromLabels(Node):
    """Demote the row labels into data column 0; reset labels to positional."""

    op = "from_labels"
    schema_kind = "dynamic"
    touches = "both"

    def __init__(self, child: Node, label: Any = "index"):
        super().__init__([child], label=label)


# ---- physical-ish convenience node (head/tail prefix; §6.1.2) -----------------
class Limit(Node):
    op = "limit"

    def __init__(self, child: Node, k: int, tail: bool = False):
        super().__init__([child], k=k, tail=tail)


# ---- rewrite-target nodes (paper §5 "Pipelining and rewriting") ----------------
class ColumnSort(Node):
    """Reorder *columns* by the values in the rows named ``by`` — the rewrite
    target of TRANSPOSE∘SORT∘TRANSPOSE (paper: "can be rewritten as a MAP and
    RENAME").  Physically a single column permutation: no transpose, no data
    reshuffle beyond a take_cols."""

    op = "column_sort"
    touches = "both"

    def __init__(self, child: Node, by: Sequence[Any], ascending: bool = True):
        super().__init__([child], by=tuple(by), ascending=ascending)


class ColumnFilter(Node):
    """Drop columns by a predicate over the rows named in the predicate —
    rewrite target of TRANSPOSE∘SELECTION∘TRANSPOSE."""

    op = "column_filter"
    touches = "both"

    def __init__(self, child: Node, predicate: "Expr"):
        super().__init__([child], predicate=predicate)


# ---- fusion-target node (paper §5 "Pipelining"; Cylon local-pattern fusion) --
class Stage:
    """One row-local operator folded into a :class:`FusedPipeline`.

    Carries the original node's ``op`` and *live* params (Expr / Udf objects —
    the physical runner needs them), while hashing by the same frozen key the
    source node would have used, so fused plans stay structurally hashable for
    the executor's materialization cache."""

    __slots__ = ("op", "params", "_key")

    def __init__(self, op: str, params: dict):
        self.op = op
        self.params = dict(params)
        self._key = ("stage", op, _freeze(self.params))

    def key(self) -> tuple:
        return self._key

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, Stage) and other._key == self._key

    def __repr__(self):
        return f"stage:{self.op}"


class FusedPipeline(Node):
    """A maximal chain of row-local operators compiled into one per-block
    program (paper §5: ordered semantics still admit pipelined execution of
    row-local chains).  ``stages`` run bottom-up — ``stages[0]`` consumes the
    child's output.  Evaluated as a single pass per row partition with no
    intermediate ``PartitionedFrame``s and one cache entry for the group."""

    op = "fused_pipeline"
    schema_kind = "inferred"
    touches = "both"

    def __init__(self, child: Node, stages: Sequence[Stage]):
        super().__init__([child], stages=tuple(stages))

    @property
    def stages(self) -> tuple:
        return self.params["stages"]

    def __repr__(self):
        return ("fused_pipeline[" + "∘".join(s.op for s in reversed(self.stages))
                + f"]<-[{self.children[0].op}]")


# ---- barrier-fused nodes (fusion *through* blocking operators) ---------------
# A blocking operator (GROUPBY/SORT/JOIN/WINDOW) is a materialization boundary,
# but the row-local work adjacent to it is not: the producer chain feeding a
# GROUPBY is per-block work that can run inside the same per-partition program
# as the partial aggregation, and the consumer chain after a SORT/JOIN can
# filter/project the gather *index* before the payload gather.  These nodes are
# the rewrite targets of ``rewrite.fuse_pipelines``'s barrier pass.
class FusedGroupBy(Node):
    """GROUPBY with its row-local producer chain absorbed: ``stages`` run
    bottom-up on each row block inside the same per-partition program that
    computes the ``segment_reduce`` partial aggregates — one dispatch per
    partition for the whole pre-shuffle stage.

    ``grid`` is the plan-time grid preference recorded by the fusion pass
    (``"workers"``: partial programs want blocks ≈ workers); the physical
    layer resolves it against the configured pool width
    (``schedule.preferred_row_parts``)."""

    op = "fused_groupby"
    order = "new"
    touches = "both"

    def __init__(self, child: Node, stages: Sequence[Stage],
                 keys: Sequence[Any], aggs: Sequence[tuple],
                 grid: str | None = None):
        super().__init__([child], stages=tuple(stages), keys=tuple(keys),
                         aggs=tuple(tuple(a) for a in aggs), grid=grid)

    @property
    def stages(self) -> tuple:
        return self.params["stages"]


class FusedSort(Node):
    """SORT with its row-local consumer chain absorbed: leading structured
    selections filter the permutation *index* before the payload gather (the
    materialized frame is built once, post-filter), a leading projection prunes
    the gathered columns, and any remaining stages run on the gathered blocks."""

    op = "fused_sort"
    order = "new"
    touches = "both"

    def __init__(self, child: Node, by: Sequence[Any], ascending: bool,
                 stages: Sequence[Stage], grid: str | None = None):
        super().__init__([child], by=tuple(by), ascending=ascending,
                         stages=tuple(stages), grid=grid)

    @property
    def stages(self) -> tuple:
        return self.params["stages"]


class FusedJoin(Node):
    """JOIN with its row-local consumer chain absorbed: leading structured
    selections are evaluated on a gather of only the predicate's columns and
    filter the (lidx, ridx) match indices before the payload gather."""

    op = "fused_join"
    touches = "both"

    def __init__(self, left: Node, right: Node, on, how, left_on, right_on,
                 stages: Sequence[Stage], grid: str | None = None):
        super().__init__(
            [left, right],
            on=tuple(on) if on is not None else None,
            left_on=tuple(left_on) if left_on is not None else None,
            right_on=tuple(right_on) if right_on is not None else None,
            how=how,
            stages=tuple(stages),
            grid=grid,
        )

    @property
    def stages(self) -> tuple:
        return self.params["stages"]


class FusedWindow(Node):
    """WINDOW with adjacent row-local chains absorbed.  ``pre_stages`` run in
    the same per-block program as the local scan; ``post_stages`` run in the
    same per-block program as the carry application — carry composition at
    partition seams is preserved because the carry combine happens between the
    two, exactly where the unfused path placed it.

    ``grid`` is the plan-time grid preference recorded by the fusion pass
    (``"few_seams"``: every partition seam costs a carry composition)."""

    op = "fused_window"
    touches = "both"

    def __init__(self, child: Node, func: str, cols: Sequence[Any] | None,
                 size: int | None, periods: int,
                 pre_stages: Sequence[Stage], post_stages: Sequence[Stage],
                 grid: str | None = None):
        assert func in WINDOW_FUNCS, func
        super().__init__([child], func=func, cols=tuple(cols) if cols else None,
                         size=size, periods=periods,
                         pre_stages=tuple(pre_stages),
                         post_stages=tuple(post_stages), grid=grid)

    @property
    def pre_stages(self) -> tuple:
        return self.params["pre_stages"]

    @property
    def post_stages(self) -> tuple:
        return self.params["post_stages"]


class FusedDropDuplicates(Node):
    """DROP-DUPLICATES with adjacent row-local chains absorbed.
    ``pre_stages`` (the producer chain) run inside the same per-block program
    that extracts the equality keys — one dispatch per partition for the whole
    pre-dedup stage, like ``FusedGroupBy``'s producer sweep.  ``post_stages``
    (the consumer chain) follow the ``FusedSort``/``FusedJoin`` index-first
    pattern: leading structured selections AND into the first-occurrence keep
    mask *before* the survivors are materialized, and a leading projection
    prunes the filtered blocks.

    ``grid`` is the plan-time grid preference recorded by the fusion pass
    (``"workers"``: key extraction wants blocks ≈ workers)."""

    op = "fused_drop_duplicates"
    touches = "both"

    def __init__(self, child: Node, subset: Sequence[Any] | None,
                 pre_stages: Sequence[Stage], post_stages: Sequence[Stage],
                 grid: str | None = None):
        super().__init__([child], subset=tuple(subset) if subset else None,
                         pre_stages=tuple(pre_stages),
                         post_stages=tuple(post_stages), grid=grid)

    @property
    def pre_stages(self) -> tuple:
        return self.params["pre_stages"]

    @property
    def post_stages(self) -> tuple:
        return self.params["post_stages"]


class FusedDifference(Node):
    """DIFFERENCE with adjacent row-local chains absorbed: ``pre_stages`` /
    ``right_pre_stages`` run inside the left/right per-block key-extraction
    programs, ``post_stages`` filter the anti-join keep mask before the
    surviving left rows are materialized (see ``FusedDropDuplicates``)."""

    op = "fused_difference"
    touches = "both"

    def __init__(self, left: Node, right: Node,
                 pre_stages: Sequence[Stage],
                 right_pre_stages: Sequence[Stage],
                 post_stages: Sequence[Stage], grid: str | None = None):
        super().__init__([left, right], pre_stages=tuple(pre_stages),
                         right_pre_stages=tuple(right_pre_stages),
                         post_stages=tuple(post_stages), grid=grid)

    @property
    def pre_stages(self) -> tuple:
        return self.params["pre_stages"]

    @property
    def post_stages(self) -> tuple:
        return self.params["post_stages"]


BARRIER_FUSED_OPS = ("fused_groupby", "fused_sort", "fused_join", "fused_window",
                     "fused_difference", "fused_drop_duplicates")


# Row-local, order-preserving unary operators whose physical implementation is
# a pure per-row-block transform: legal to fuse into one per-partition program.
# LIMIT is deliberately excluded (its k applies to the *global* row order, not
# per block); non-elementwise MAPs run on the whole frame and cannot fuse.
FUSIBLE_OPS = ("map", "selection", "projection", "rename")


def fusible(node: Node) -> bool:
    """True if ``node`` may join a fused row-local pipeline."""
    if node.op not in FUSIBLE_OPS or len(node.children) != 1:
        return False
    if node.op == "map":
        return node.params["udf"].elementwise
    return True


# =============================================================================
# Prefix-safety analysis (§6.1.2): can LIMIT(k) be answered from an input
# prefix?  True for order-preserving, row-local operators.
# =============================================================================
_PREFIX_SAFE = {"selection", "projection", "map", "rename", "union", "limit",
                "from_labels", "to_labels", "source", "window",
                "fused_pipeline", "fused_window"}
# fused_pipeline: fusible ops are all row-local/order-preserving, so a fused
# group inherits prefix-safety by construction.
# window is prefix-safe for forward windows (cumsum/…): row i depends only on
# rows ≤ i — and fused_window adds only row-local pre/post stages, so it
# inherits the same property (barrier-fusing a window must not disable §6.1.2
# prefix evaluation).  fused_groupby/fused_sort/fused_join stay blocking like
# the operators they absorb.  GROUPBY/SORT/JOIN/TRANSPOSE/DIFFERENCE/
# DROP-DUPLICATES are blocking (paper: "it is hard to produce the first k
# tuples of a GROUP BY or SORT without examining the entire data first").


def prefix_safe(node: Node) -> bool:
    """Prefix-evaluable: every op row-local/order-preserving AND a single
    source (multi-source plans like UNION need completeness bookkeeping the
    simple prefix path doesn't carry)."""
    sources = 0
    for n in node.walk():
        if n.op == "source":
            sources += 1
        if n.op not in _PREFIX_SAFE:
            return False
    return sources <= 1
