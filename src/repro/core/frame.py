"""The dataframe value container: DF = (A_mn, R_m, C_n, D_n)  (paper §3.2).

``Frame`` is a *single-partition* dataframe instance: the unit that Pallas
kernels and per-shard physical operators execute on.  Distribution happens one
level up (``partition.PartitionedFrame`` / shard_map in ``physical.py``).

Representation (DESIGN.md §3 — hardware adaptation):
  * one 1-D device array per column in its domain's storage dtype,
  * optional validity mask per column (None = all valid),
  * host-side code table per coded (Σ*/category) column,
  * row labels R_m and column labels C_n as ``labels.Labels`` metadata,
  * schema D_n as a tuple of ``Domain`` (UNSPECIFIED entries are induced on
    demand by S(·) — ``induce()``),
  * optional ``row_domains``: the pre-TRANSPOSE schema, letting a second
    TRANSPOSE recover the original D_n (paper §3.3: "the schema induction
    function can always recover the original D_n after two transposes").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .dtypes import (
    Domain,
    ParsedColumn,
    common_storage,
    induce_schema,
    parse_column,
    storage_dtype,
)
from .labels import CodedLabels, Labels, RangeLabels, labels_from_values

__all__ = ["Column", "Frame"]


@functools.lru_cache(maxsize=None)
def _host_exec() -> bool:
    """On the CPU backend a per-column device gather/concat is pure dispatch
    overhead (~15× the cost of the host memcpy it performs): row takes then
    run as host numpy views that re-enter the device lazily.  TPU keeps the
    device path.  Probed lazily so importing the library doesn't force jax
    backend initialization (users may still select a platform afterwards)."""
    return jax.default_backend() == "cpu"


@dataclasses.dataclass(frozen=True)
class Column:
    """One column of A_mn with its domain, validity mask, and code table."""

    data: jnp.ndarray          # (m,) storage-dtype device array
    domain: Domain
    mask: jnp.ndarray | None = None   # (m,) bool, True = valid; None = all valid
    dictionary: tuple | None = None   # host code table when domain.is_coded

    def __len__(self) -> int:
        return int(self.data.shape[0])

    # ---- host materialization -------------------------------------------
    def to_pylist(self) -> list:
        data = np.asarray(self.data)
        mask = np.asarray(self.mask) if self.mask is not None else None
        out: list = []
        for i in range(data.shape[0]):
            if mask is not None and not mask[i]:
                out.append(None)
            elif self.domain.is_coded:
                code = int(data[i])
                out.append(self.dictionary[code] if 0 <= code < len(self.dictionary) else None)
            elif self.domain is Domain.BOOL:
                out.append(bool(data[i]))
            elif self.domain is Domain.INT:
                out.append(int(data[i]))
            else:
                out.append(float(data[i]))
        return out

    def valid_mask(self) -> jnp.ndarray | np.ndarray:
        if self.mask is not None:
            return self.mask
        if _host_exec():
            # host ones: allocating on device is a ~50µs dispatch per call on
            # CPU; consumers promote lazily when a device op needs it
            return np.ones(self.data.shape[0], dtype=np.bool_)
        return jnp.ones(self.data.shape[0], dtype=jnp.bool_)

    def value_at(self, i: int):
        """Decode a single position (host) without materializing the column."""
        if self.mask is not None and not bool(self.mask[i]):
            return None
        v = self.data[i]
        if self.domain.is_coded:
            code = int(v)
            return self.dictionary[code] if 0 <= code < len(self.dictionary) else None
        if self.domain is Domain.BOOL:
            return bool(v)
        if self.domain is Domain.INT:
            return int(v)
        return float(v)

    def take(self, idx) -> "Column":
        if isinstance(self.data, np.ndarray) or _host_exec():
            # host view: numpy fancy index (CPU jax arrays expose their buffer
            # to np.asarray at memcpy cost, far below a device dispatch)
            idx_np = np.asarray(idx)
            return Column(
                np.asarray(self.data)[idx_np], self.domain,
                None if self.mask is None else np.asarray(self.mask)[idx_np],
                self.dictionary)
        idx = jnp.asarray(idx)
        return Column(
            jnp.take(self.data, idx, axis=0),
            self.domain,
            None if self.mask is None else jnp.take(jnp.asarray(self.mask), idx, axis=0),
            self.dictionary,
        )

    def filter(self, keep: jnp.ndarray) -> "Column":
        kept = jnp.asarray(np.nonzero(np.asarray(keep))[0])
        return self.take(kept)

    def astype_storage(self, target: Domain) -> jnp.ndarray:
        """Numeric view of this column in ``target``'s storage dtype.

        Coded columns decode to their *codes* when the target is coded; when
        the target is numeric the codes are meaningless and we surface NaN —
        the same failure mode pandas produces for numeric ops over objects.
        """
        if target.is_coded:
            return self.data.astype(np.int32)
        return self.data.astype(storage_dtype(target))


def _parsed_to_column(p: ParsedColumn) -> Column:
    return Column(p.data, p.domain, p.mask, p.dictionary)


class Frame:
    """A single-partition dataframe (A_mn, R_m, C_n, D_n)."""

    def __init__(
        self,
        columns: Sequence[Column],
        row_labels: Labels,
        col_labels: Labels,
        row_domains: tuple[Domain, ...] | None = None,
    ):
        self.columns = list(columns)
        self.row_labels = row_labels
        self.col_labels = col_labels
        # Pre-transpose schema carried along for recovery after a second
        # TRANSPOSE (paper §3.3 / §5 "types maintained at both row and column
        # level ... type inference faster after a transpose").
        self.row_domains = row_domains
        m = len(row_labels)
        for c in self.columns:
            assert len(c) == m, f"column length {len(c)} != nrows {m}"
        assert len(col_labels) == len(self.columns)

    # ------------------------------------------------------------------
    # constructors
    # ------------------------------------------------------------------
    @staticmethod
    def from_pydict(
        data: dict[str, Sequence[Any]],
        row_labels: Sequence[Any] | None = None,
        domains: Sequence[Domain] | None = None,
    ) -> "Frame":
        names = list(data.keys())
        cols = []
        for j, name in enumerate(names):
            dom = domains[j] if domains is not None else None
            cols.append(_parsed_to_column(parse_column(list(data[name]), dom)))
        m = len(cols[0]) if cols else 0
        rl = labels_from_values(list(row_labels)) if row_labels is not None else RangeLabels(m)
        return Frame(cols, rl, labels_from_values(names))

    @staticmethod
    def from_matrix(
        values: jnp.ndarray,
        domain: Domain = Domain.FLOAT,
        row_labels: Labels | None = None,
        col_labels: Labels | None = None,
    ) -> "Frame":
        """Homogeneous ("matrix dataframe", paper §3.2) constructor.

        Wide-frame fast path: one host materialization + numpy column views
        (per-column device slices would cost O(n) dispatches)."""
        m, n = values.shape
        host = np.asarray(values).astype(storage_dtype(domain), copy=False)
        cols = [Column(host[:, j], domain) for j in range(n)]
        return Frame(
            cols,
            row_labels if row_labels is not None else RangeLabels(m),
            col_labels if col_labels is not None else RangeLabels(n),
        )

    # ------------------------------------------------------------------
    # basic properties
    # ------------------------------------------------------------------
    @property
    def nrows(self) -> int:
        return len(self.row_labels)

    @property
    def ncols(self) -> int:
        return len(self.columns)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nrows, self.ncols)

    @property
    def schema(self) -> tuple[Domain, ...]:
        return tuple(c.domain for c in self.columns)

    def induce(self) -> "Frame":
        """Apply S(·) to every UNSPECIFIED column (paper §3.2).

        If a pre-transpose row schema was recorded and matches the width,
        recover it directly without scanning values.
        """
        if all(c.domain is not Domain.UNSPECIFIED for c in self.columns):
            return self
        cols = []
        for c in self.columns:
            if c.domain is not Domain.UNSPECIFIED:
                cols.append(c)
                continue
            vals = c.to_pylist()
            cols.append(_parsed_to_column(parse_column(vals, induce_schema(vals))))
        return Frame(cols, self.row_labels, self.col_labels, self.row_domains)

    def is_matrix(self) -> bool:
        """Matrix dataframe (§3.2): every column in a numeric field domain.

        The paper's strict notion is a single shared domain; we accept mixed
        int/float/bool since they embed in **float** (the coercion linear
        algebra applies anyway).  Σ*-typed columns disqualify — opaque strings
        "do not satisfy the properties of a field".
        """
        f = self.induce()
        return all(d.is_numeric for d in f.schema)

    # ------------------------------------------------------------------
    # matrix coercion (for TRANSPOSE / linear-algebra ops)
    # ------------------------------------------------------------------
    def as_matrix(self, target: Domain | None = None) -> tuple[jnp.ndarray, Domain]:
        # explicit target ⇒ no schema induction needed (storage casting only);
        # induction of 10⁵⁺-column UNSPECIFIED frames is O(values) Python.
        f = self if target is not None else self.induce()
        tgt = target or common_storage(f.schema)
        if tgt is Domain.UNSPECIFIED:
            tgt = Domain.FLOAT
        if not f.ncols:
            return jnp.zeros((f.nrows, 0), storage_dtype(tgt)), tgt
        # stack on host (O(1) per column, no per-column device dispatch —
        # matters for post-transpose frames with 10⁵⁺ columns)
        mat_np = np.stack([np.asarray(c.astype_storage(tgt)) for c in f.columns],
                          axis=1)
        return jnp.asarray(mat_np), tgt

    # ------------------------------------------------------------------
    # row/column selection
    # ------------------------------------------------------------------
    def take_rows(self, idx) -> "Frame":
        idx_np = np.asarray(idx)
        rd = None
        if self.row_domains is not None and len(self.row_domains) == self.nrows:
            rd = tuple(self.row_domains[int(i)] for i in idx_np)
        return Frame(
            [c.take(idx_np) for c in self.columns],
            self.row_labels.take(idx_np),
            self.col_labels,
            rd,
        )

    def filter_rows(self, keep: np.ndarray) -> "Frame":
        idx = np.nonzero(np.asarray(keep))[0]
        return self.take_rows(idx)

    def take_cols(self, idx: Sequence[int]) -> "Frame":
        # row_domains is a per-ROW vector (the pre-transpose schema): column
        # selection leaves it intact.  Indexing it by column positions here
        # used to truncate it silently (ncols ≤ nrows) or crash with an
        # IndexError (any column index ≥ nrows — e.g. column-repartitioning a
        # wider-than-tall post-transpose frame).
        idx = list(idx)
        return Frame(
            [self.columns[j] for j in idx],
            self.row_labels,
            self.col_labels.take(np.asarray(idx, dtype=np.int64)),
            self.row_domains,
        )

    def col(self, name: Any) -> Column:
        return self.columns[self.col_labels.position_of(name)]

    def head(self, k: int) -> "Frame":
        return self.take_rows(np.arange(min(k, self.nrows)))

    def tail(self, k: int) -> "Frame":
        k = min(k, self.nrows)
        return self.take_rows(np.arange(self.nrows - k, self.nrows))

    # ------------------------------------------------------------------
    # concatenation (UNION building block — order preserved, paper Table 1)
    # ------------------------------------------------------------------
    def concat_rows(self, other: "Frame") -> "Frame":
        assert self.ncols == other.ncols, "UNION requires equal arity"
        cols = []
        for a, b in zip(self.columns, other.columns):
            a, b = _unify_pair(a, b)
            mask = None
            if a.mask is not None or b.mask is not None:
                mask = _concat_arrays(a.valid_mask(), b.valid_mask())
            cols.append(Column(_concat_arrays(a.data, b.data), a.domain, mask, a.dictionary))
        rd = None
        if (self.row_domains is not None and other.row_domains is not None
                and len(self.row_domains) == self.nrows
                and len(other.row_domains) == other.nrows):
            rd = self.row_domains + other.row_domains
        return Frame(cols, self.row_labels.concat(other.row_labels), self.col_labels, rd)

    def concat_cols(self, other: "Frame") -> "Frame":
        assert self.nrows == other.nrows
        return Frame(
            self.columns + other.columns,
            self.row_labels,
            self.col_labels.concat(other.col_labels),
        )

    # ------------------------------------------------------------------
    # point access/update (ordered point updates, paper §2 C1)
    # ------------------------------------------------------------------
    def iloc_get(self, r: int, c: int) -> Any:
        return self.columns[c].to_pylist()[r]

    def iloc_set(self, r: int, c: int, value: Any) -> "Frame":
        col = self.columns[c]
        if col.domain.is_coded:
            table = list(col.dictionary or ())
            key = str(value)
            if key not in table:
                table.append(key)
            code = table.index(key)
            data = jnp.asarray(col.data).at[r].set(np.int32(code))
            new = Column(data, col.domain, _set_valid(col, r), tuple(table))
        else:
            data = jnp.asarray(col.data).at[r].set(
                np.asarray(value, dtype=col.data.dtype))
            new = Column(data, col.domain, _set_valid(col, r), None)
        cols = list(self.columns)
        cols[c] = new
        return Frame(cols, self.row_labels, self.col_labels, self.row_domains)

    # ------------------------------------------------------------------
    # host views (display / testing)
    # ------------------------------------------------------------------
    def to_pydict(self) -> dict:
        return {
            name: col.to_pylist()
            for name, col in zip(self.col_labels.to_list(), self.columns)
        }

    def to_records(self) -> list[tuple]:
        cols = [c.to_pylist() for c in self.columns]
        return [tuple(col[i] for col in cols) for i in range(self.nrows)]

    def __repr__(self) -> str:
        names = self.col_labels.to_list()
        doms = [d.value for d in self.schema]
        return (
            f"Frame[{self.nrows}x{self.ncols}] cols={list(zip(names, doms))[:8]}"
            + ("…" if self.ncols > 8 else "")
        )

    # nbytes of device payload (for the materialization-cache cost model)
    def nbytes(self) -> int:
        total = 0
        for c in self.columns:
            total += c.data.size * c.data.dtype.itemsize
            if c.mask is not None:
                total += c.mask.size
        return total


def _concat_arrays(a, b):
    """Row-axis concat: on host for the CPU backend or pure host views (a
    device concatenate is a dispatch per call; zero-copy repartition regroups
    want a plain memcpy).  A device array on an accelerator backend stays on
    device — mixed host/device pairs promote the host side up, not down."""
    if _host_exec() or (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)):
        return np.concatenate([np.asarray(a), np.asarray(b)])
    return jnp.concatenate([jnp.asarray(a), jnp.asarray(b)])


def _set_valid(col: Column, r: int) -> jnp.ndarray | None:
    if col.mask is None:
        return None
    return jnp.asarray(col.mask).at[r].set(True)


def _unify_pair(a: Column, b: Column) -> tuple[Column, Column]:
    """Make two columns concatenable: same domain + shared dictionary."""
    if a.domain is b.domain and a.dictionary == b.dictionary:
        return a, b
    if a.domain.is_coded or b.domain.is_coded:
        # Re-encode both against a merged dictionary.
        av, bv = a.to_pylist(), b.to_pylist()
        pa = parse_column([None if v is None else str(v) for v in av], Domain.STR)
        table = list(pa.dictionary or ())
        index = {v: i for i, v in enumerate(table)}
        codes_b = np.zeros(len(bv), dtype=np.int32)
        mask_b = np.ones(len(bv), dtype=np.bool_)
        for i, v in enumerate(bv):
            if v is None:
                codes_b[i] = -1
                mask_b[i] = False
                continue
            key = str(v)
            if key not in index:
                index[key] = len(table)
                table.append(key)
            codes_b[i] = index[key]
        ca = Column(pa.data, Domain.STR, pa.mask, tuple(table))
        cb = Column(
            jnp.asarray(codes_b),
            Domain.STR,
            jnp.asarray(mask_b) if not mask_b.all() else None,
            tuple(table),
        )
        return ca, cb
    tgt = common_storage([a.domain, b.domain])
    return (
        Column(a.astype_storage(tgt), tgt, a.mask, None),
        Column(b.astype_storage(tgt), tgt, b.mask, None),
    )
