"""The paper's primary contribution: a formal dataframe data model (§3.2),
a dataframe algebra (§3.3), and a Modin-style partitioned parallel
implementation (§4) with the §5/§6 optimizations (rewriting, opportunistic
evaluation, prefix computation, approximate execution, materialization/reuse).

Public surface:
  * ``api.DataFrame`` / ``read_csv`` / ``from_pydict`` — pandas-flavoured API
  * ``algebra`` — the 14-operator algebra for direct plan construction
  * ``Session`` — evaluation modes (eager / lazy / opportunistic) + reuse
"""
from . import algebra  # noqa: F401
from .api import DataFrame, concat, from_pydict, get_dummies, read_csv  # noqa: F401
from .config import CancelToken, SessionConfig  # noqa: F401
from .dtypes import Domain  # noqa: F401
from .frame import Column, Frame  # noqa: F401
from .partition import PartitionedFrame  # noqa: F401
from .faults import (  # noqa: F401
    ExecutorClosedError, IngestError, SpillIntegrityError,
    StatementCancelled, StoreClosedError, TaskError)
from .service import QueryService  # noqa: F401
from .session import (  # noqa: F401
    EvalMode, Session, StatementHandle, get_session, set_session)
from .store import BlockHandle, BlockStore, get_store, reset_store  # noqa: F401
from .trace import Metrics, Tracer  # noqa: F401
