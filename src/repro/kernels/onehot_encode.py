"""One-hot encoding kernel (paper §2 A1 — ``get_dummies``).

Categorical codes (M,) → indicator matrix (M, G) f32, built tile-by-tile with
a broadcasted-iota compare so the one-hot never round-trips through HBM as
int8 gather indices.  Code -1 (null) yields an all-zero row.

Grid: (M/TM, G/TG); each program writes one (TM, TG) output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import LANE, SUBLANE, cdiv, ceil_to, pad_axis, pick_tile, use_interpret


def _onehot_kernel(c_ref, o_ref, *, tg: int):
    j = pl.program_id(1)
    codes = c_ref[...]                       # (TM, 1) int32
    local = codes - j * tg
    seg = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], tg), 1)
    o_ref[...] = (local == seg).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_classes", "tm", "tg"))
def _onehot_padded(codes, num_classes: int, tm: int, tg: int):
    m = codes.shape[0]
    return pl.pallas_call(
        functools.partial(_onehot_kernel, tg=tg),
        grid=(cdiv(m, tm), cdiv(num_classes, tg)),
        in_specs=[pl.BlockSpec((tm, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((tm, tg), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, num_classes), jnp.float32),
        interpret=use_interpret(),
    )(codes)


def onehot_encode(codes: jnp.ndarray, num_classes: int, *,
                  tile_m: int = 512, tile_g: int = 512) -> jnp.ndarray:
    """(M,) int32 codes → (M, num_classes) f32 one-hot (−1 → zero row)."""
    assert codes.ndim == 1
    m = codes.shape[0]
    if m == 0:
        return jnp.zeros((0, num_classes), jnp.float32)
    tm = pick_tile(m, tile_m, SUBLANE)
    tg = pick_tile(num_classes, tile_g, LANE)
    cp = pad_axis(codes.astype(jnp.int32)[:, None], 0, ceil_to(m, tm), value=-1)
    out = _onehot_padded(cp, ceil_to(num_classes, tg), tm, tg)
    return out[:m, :num_classes]
