"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy:
  * On TPU — always the Pallas kernels.
  * On CPU — the pure-jnp references by default (XLA:CPU fuses them well and
    the interpret-mode emulation is for *validation*, not speed); set
    ``REPRO_USE_KERNELS=1`` to force the kernels (interpret=True) anywhere,
    ``REPRO_FORCE_REF=1`` to force the references anywhere.

Every wrapper has an identically-shaped oracle in ``ref.py``; tests sweep
shapes × dtypes asserting allclose between the two.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from .block_transpose import block_transpose as _pallas_transpose
from .decode_attention import decode_attention as _pallas_decode
from .flash_attention import flash_attention as _pallas_flash
from .linear_scan import linear_scan as _pallas_linscan
from .onehot_encode import onehot_encode as _pallas_onehot
from .segment_reduce import segment_reduce as _pallas_segred
from .window_scan import window_scan as _pallas_winscan
from ._util import narrow_from_kernel, widen_for_kernel

__all__ = [
    "use_pallas", "transpose", "segment_reduce", "segment_reduce_multi",
    "window_scan", "linear_scan", "onehot_encode", "flash_attention",
    "decode_attention",
]


def use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_REF", "0") not in ("0", ""):
        return False
    if os.environ.get("REPRO_USE_KERNELS", "0") not in ("0", ""):
        return True
    return jax.default_backend() == "tpu"


# -----------------------------------------------------------------------------
def transpose(x: jnp.ndarray) -> jnp.ndarray:
    if use_pallas():
        w, orig = widen_for_kernel(x)
        return narrow_from_kernel(_pallas_transpose(w), orig)
    return ref.transpose(x)


def segment_reduce(values, codes, num_segments: int, op: str = "sum"):
    if use_pallas():
        return _pallas_segred(values, codes, num_segments, op)
    return ref.segment_reduce(values.astype(jnp.float32), codes, num_segments, op)


@functools.partial(jax.jit, static_argnames=("bases", "num_segments",
                                             "presence", "pallas"))
def _segment_reduce_multi_prog(vals, valids, codes, *, bases: tuple,
                               num_segments: int, presence: bool, pallas: bool):
    by_op: dict[str, list] = {}

    def put(op: str, pos: int, vec) -> None:
        by_op.setdefault(op, []).append((pos, vec))

    for i, base in enumerate(bases):
        v = vals[i].astype(jnp.float32)
        valid = valids[i]
        if valid is None:
            valid = jnp.ones(v.shape[0], jnp.bool_)
        if base == "count":
            put("sum", i, valid.astype(jnp.float32))
        elif base == "sum":
            put("sum", i, jnp.where(valid, v, 0.0))
        elif base == "sumsq":
            put("sum", i, jnp.where(valid, v * v, 0.0))
        elif base == "min":
            put("min", i, jnp.where(valid, v, jnp.finfo(jnp.float32).max))
        else:   # max
            put("max", i, jnp.where(valid, v, jnp.finfo(jnp.float32).min))
    if presence:
        # segment presence = #rows with a valid (non-negative) code,
        # independent of value nulls
        put("sum", len(bases), jnp.ones(codes.shape[0], jnp.float32))

    out: list = [None] * (len(bases) + (1 if presence else 0))
    for op, items in by_op.items():
        if len(items) == 1:
            out[items[0][0]] = segment_reduce(items[0][1], codes, num_segments, op)
        else:
            mat = jnp.stack([vec for _, vec in items], axis=1)
            res = segment_reduce(mat, codes, num_segments, op)
            for j, (pos, _) in enumerate(items):
                out[pos] = res[:, j]
    return tuple(out)


def segment_reduce_multi(vals, valids, codes, *, bases, num_segments: int,
                         presence: bool = False):
    """A whole per-block partial-aggregation stage as ONE compiled program:
    null masking, squaring, presence counting, and one ``segment_reduce`` per
    reduce op, with same-op columns stacked into the kernel's (M, C)
    multi-column batch.  ``bases[i]`` ∈ {sum,count,sumsq,min,max} names the
    statistic computed from ``(vals[i], valids[i])``; ``valids[i]`` may be
    None (all valid).  Returns one (G,)-vector per base, plus a trailing
    presence vector when ``presence``.  Eager per-op dispatch of the same
    graph was the dominant cost of the groupby hot path on the shared pool.

    ``pallas`` enters the jit cache key so a kernel-dispatch env flip between
    calls can't serve a program traced for the other mode."""
    return _segment_reduce_multi_prog(
        list(vals), list(valids), jnp.asarray(codes, jnp.int32),
        bases=tuple(bases), num_segments=num_segments, presence=presence,
        pallas=use_pallas())


def window_scan(x, op: str = "cumsum"):
    if use_pallas():
        return _pallas_winscan(x, op)
    return ref.window_scan(x.astype(jnp.float32), op)


def linear_scan(a, b):
    if use_pallas():
        return _pallas_linscan(a, b)
    return ref.linear_scan(a.astype(jnp.float32), b.astype(jnp.float32))


def onehot_encode(codes, num_classes: int):
    if use_pallas():
        return _pallas_onehot(codes, num_classes)
    return ref.onehot_encode(codes, num_classes)


def flash_attention(q, k, v, *, causal: bool = True, scale=None, window=None):
    if use_pallas():
        return _pallas_flash(q, k, v, causal=causal, scale=scale, window=window)
    return ref.flash_attention(q, k, v, causal=causal, scale=scale, window=window)


def decode_attention(q, k_cache, v_cache, length, *, scale=None):
    if use_pallas():
        return _pallas_decode(q, k_cache, v_cache, length, scale=scale)
    return ref.decode_attention(q, k_cache, v_cache, length, scale=scale)
