"""GROUPBY aggregation as one-hot matmul on the MXU (paper §4.2, Fig. 6).

Hardware adaptation (DESIGN.md §3): TPUs have no efficient scatter, so hash
aggregation is re-thought as dense linear algebra.  For a tile of TM rows with
group codes c ∈ [0, G), build the one-hot matrix H ∈ {0,1}^(TM×TG) on the fly
(broadcasted-iota compare — never materialized in HBM) and compute

    partial[j]  +=  Hᵀ · values_tile        (sum / count)
    partial[j]   =  min/max(where(H, v, ±∞)) elementwise-reduced over rows

Grid: (G/TG, M/TM) with the *segment* axis outermost so each output tile stays
resident in VMEM while the full M axis streams through (sequential-grid
accumulation).  A single psum across row shards combines partials — this is
what turns the paper's groupby shuffle into an aggregate-sized all-reduce.

Multi-column variant: values (M, C) aggregates C columns at once (C ≤ LANE),
matching the paper's observation that multi-column GROUP BY prefers
column-friendly layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import LANE, SUBLANE, cdiv, ceil_to, pad_axis, pick_tile, use_interpret

_IDENTITY = {"sum": 0.0, "count": 0.0}


def _seg_kernel(v_ref, c_ref, o_ref, *, op: str, tg: int):
    j = pl.program_id(0)   # segment tile (outer — output stays in VMEM)
    i = pl.program_id(1)   # row tile (inner — streams through)

    @pl.when(i == 0)
    def _init():
        if op in ("sum", "count"):
            o_ref[...] = jnp.zeros_like(o_ref)
        elif op == "min":
            o_ref[...] = jnp.full_like(o_ref, jnp.finfo(o_ref.dtype).max)
        else:  # max
            o_ref[...] = jnp.full_like(o_ref, jnp.finfo(o_ref.dtype).min)

    v = v_ref[...].astype(jnp.float32)          # (TM, C)
    codes = c_ref[...]                           # (TM, 1) int32
    local = codes - j * tg                       # segment id within this tile
    seg_ids = jax.lax.broadcasted_iota(jnp.int32, (v.shape[0], tg), 1)
    onehot = (local == seg_ids)                  # (TM, TG) — codes<0 never match

    if op in ("sum", "count"):
        contrib = jnp.ones_like(v) if op == "count" else v
        contrib = jnp.where(codes >= 0, contrib, 0.0)
        # MXU path: (TG, TM) @ (TM, C) → (TG, C)
        part = jax.lax.dot_general(
            onehot.astype(jnp.float32), contrib,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        o_ref[...] += part.astype(o_ref.dtype)
    else:
        fill = jnp.finfo(jnp.float32).max if op == "min" else jnp.finfo(jnp.float32).min
        # (TM, TG, C) masked broadcast reduced over rows
        expanded = jnp.where(onehot[:, :, None], v[:, None, :], fill)
        part = expanded.min(axis=0) if op == "min" else expanded.max(axis=0)
        o_ref[...] = (
            jnp.minimum(o_ref[...], part.astype(o_ref.dtype))
            if op == "min"
            else jnp.maximum(o_ref[...], part.astype(o_ref.dtype))
        )


@functools.partial(jax.jit, static_argnames=("num_segments", "op", "tm", "tg"))
def _segment_reduce_padded(values, codes, num_segments: int, op: str, tm: int, tg: int):
    m, c = values.shape
    grid = (cdiv(num_segments, tg), cdiv(m, tm))
    return pl.pallas_call(
        functools.partial(_seg_kernel, op=op, tg=tg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, c), lambda j, i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tg, c), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, c), jnp.float32),
        interpret=use_interpret(),
    )(values, codes)


def segment_reduce(values: jnp.ndarray, codes: jnp.ndarray, num_segments: int,
                   op: str = "sum", *, tile_m: int = 512, tile_g: int = 128) -> jnp.ndarray:
    """Per-segment aggregate.  values (M,) or (M,C) f32; codes (M,) int32 with
    -1 = null (contributes nothing).  Returns (G,) or (G,C) f32."""
    assert op in ("sum", "count", "min", "max"), op
    squeeze = values.ndim == 1
    v = values[:, None] if squeeze else values
    v = v.astype(jnp.float32)
    m = v.shape[0]
    if m == 0:
        from . import ref
        out = ref.segment_reduce(v, codes, num_segments, op)
        return out[:, 0] if squeeze else out
    tm = pick_tile(m, tile_m, SUBLANE)
    tg = pick_tile(num_segments, tile_g, LANE)
    g_pad = ceil_to(num_segments, tg)
    vp = pad_axis(v, 0, ceil_to(m, tm))
    cp = pad_axis(codes.astype(jnp.int32)[:, None], 0, ceil_to(m, tm), value=-1)
    out = _segment_reduce_padded(vp, cp, g_pad, op, tm, tg)[:num_segments]
    return out[:, 0] if squeeze else out
