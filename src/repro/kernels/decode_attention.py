"""Single-token GQA decode attention over a KV cache (serve_step hot-spot).

At decode, arithmetic intensity collapses: one query token attends to a long
cache, so the op is HBM-bandwidth-bound on the KV stream.  The kernel keeps
the whole (G, D) grouped-query tile resident (G = query heads per KV head —
the GQA group), streams (TS, D) cache tiles once, and fuses the softmax
normalization — every cache byte is read exactly once.

Grid: (num_cache_tiles,).  ``length`` (valid cache prefix) arrives as a
scalar-prefetch operand so masking is positional, enabling a static cache
allocation with dynamic occupancy — the serving engine's paged-lite layout.

Wrapper: q (H, D), cache (S, KVH, D) → vmap over KV heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._util import LANE, SUBLANE, cdiv, ceil_to, pad_axis, pick_tile, use_interpret

_NEG = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   scale: float, ts: int, ns: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32) * scale            # (G, D)
    k = k_ref[...].astype(jnp.float32)                    # (TS, D)
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, TS)
    kpos = j * ts + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[0], s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == ns - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "ts"))
def _decode_single(q, k, v, length, scale: float, ts: int):
    g, d = q.shape
    s = k.shape[0]
    ns = cdiv(s, ts)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((g, d), lambda j, *_: (0, 0)),
            pl.BlockSpec((ts, d), lambda j, *_: (j, 0)),
            pl.BlockSpec((ts, d), lambda j, *_: (j, 0)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda j, *_: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, ts=ts, ns=ns),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, d), q.dtype),
        interpret=use_interpret(),
    )(length, q, k, v)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: jnp.ndarray | int, *, scale: float | None = None,
                     tile_s: int = 512) -> jnp.ndarray:
    """q: (H, D) one token's query heads; cache: (S, KVH, D); returns (H, D)."""
    h, d = q.shape
    s, kvh, _ = k_cache.shape
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    scale_v = scale if scale is not None else 1.0 / (d ** 0.5)
    ts = pick_tile(s, tile_s, LANE)
    dp = ceil_to(d, LANE)
    gp = ceil_to(group, SUBLANE)
    qg = pad_axis(pad_axis(q.reshape(kvh, group, d), 1, gp), 2, dp)          # (KVH, Gp, Dp)
    kc = pad_axis(pad_axis(k_cache.transpose(1, 0, 2), 1, ceil_to(s, ts)), 2, dp)  # (KVH, Sp, Dp)
    vc = pad_axis(pad_axis(v_cache.transpose(1, 0, 2), 1, ceil_to(s, ts)), 2, dp)
    len_arr = jnp.full((1,), length, dtype=jnp.int32) if not hasattr(length, "shape") else jnp.asarray(length, jnp.int32).reshape(1)
    run = functools.partial(_decode_single, scale=scale_v, ts=ts)
    out = jax.vmap(lambda a, b, c: run(a, b, c, len_arr))(qg, kc, vc)        # (KVH, Gp, Dp)
    return out[:, :group, :d].reshape(h, d)
