"""Pallas TPU kernels for the perf-critical compute layers.

Data-system kernels (the paper's Fig. 6 hot-spots):
  block_transpose — TRANSPOSE's per-block tile transpose
  segment_reduce  — GROUPBY(n) aggregation as MXU one-hot matmul
  window_scan     — WINDOW cumulative ops as a blocked carry scan
  onehot_encode   — get_dummies (§2 A1)

LM-substrate kernels:
  flash_attention — fused online-softmax attention (train / prefill)
  decode_attention— single-token GQA attention over a KV cache
  linear_scan     — h_t = a_t·h_{t-1} + b_t (RG-LRU / RWKV6 primitive)

``ops`` is the public dispatching surface; ``ref`` holds pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
