"""Fused (flash) attention kernel — training/prefill path of the LM substrate.

Standard online-softmax tiling adapted to TPU: the (TQ, D) query tile stays
resident in VMEM while (TK, D) key/value tiles stream through the sequential
grid; running max m, denominator l and accumulator acc live in VMEM scratch.
MXU does both matmuls (QKᵀ and PV) per tile pair; nothing S×S ever
materializes in HBM.

Grid: (num_q_tiles, num_kv_tiles), kv innermost.  Causal and local-window
masking are positional (supports gemma3's 5:1 local:global pattern); query
positions are aligned to the *end* of the key axis so the same kernel serves
chunked prefill.

Wrapper handles batch/head via vmap and GQA by repeating KV heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._util import LANE, SUBLANE, cdiv, ceil_to, pad_axis, pick_tile, use_interpret

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  sq: int, sk: int, tq: int, tk: int, nk: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32) * scale            # (TQ, D)
    k = k_ref[...].astype(jnp.float32)                    # (TK, D)
    v = v_ref[...].astype(jnp.float32)                    # (TK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (TQ, TK)

    qpos = i * tq + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], k.shape[0]), 0) + (sk - sq)
    kpos = j * tk + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], k.shape[0]), 1)
    mask = kpos < sk                                      # padded keys invalid
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]                                   # (TQ, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (TQ, TK)
    alpha = jnp.exp(m_prev - m_new)                       # (TQ, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window", "sq", "sk", "tq", "tk"))
def _flash_single(q, k, v, scale: float, causal: bool, window: int | None,
                  sq: int, sk: int, tq: int, tk: int):
    sqp, d = q.shape
    skp = k.shape[0]
    nq, nk = cdiv(sqp, tq), cdiv(skp, tk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal, window=window,
                          sq=sq, sk=sk, tq=tq, tk=tk, nk=nk),
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, d), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
        ],
        interpret=use_interpret(),
    )(q, k, v)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: float | None = None,
                    window: int | None = None,
                    tile_q: int = 128, tile_k: int = 128) -> jnp.ndarray:
    """Fused attention.  q: (H, Sq, D) or (Sq, D); k/v: (H, Sk, D) or (Sk, D).

    GQA is handled by the caller (repeat kv heads to H).  Query positions are
    aligned to the end of the key axis (prefill-chunk semantics).
    """
    single = q.ndim == 2
    if single:
        q, k, v = q[None], k[None], v[None]
    h, sq, d = q.shape
    sk = k.shape[1]
    scale_v = scale if scale is not None else 1.0 / (d ** 0.5)
    tq = pick_tile(sq, tile_q, SUBLANE)
    tk = pick_tile(sk, tile_k, LANE)
    dp = ceil_to(d, LANE)
    qp = pad_axis(pad_axis(q, 1, ceil_to(sq, tq)), 2, dp)
    kp = pad_axis(pad_axis(k, 1, ceil_to(sk, tk)), 2, dp)
    vp = pad_axis(pad_axis(v, 1, ceil_to(sk, tk)), 2, dp)
    run = functools.partial(_flash_single, scale=scale_v, causal=causal,
                            window=window, sq=sq, sk=sk, tq=tq, tk=tk)
    out = jax.vmap(lambda a, b, c: run(a, b, c))(qp, kp, vp)
    out = out[:, :sq, :d]
    return out[0] if single else out
