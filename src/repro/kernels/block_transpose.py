"""Tiled VMEM transpose — the TRANSPOSE hot-spot (paper §4.2, Fig. 6).

The paper's "billions of columns" transpose is block-partitioned: each block
is transposed locally and the grid metadata is swapped.  This kernel is the
local per-block step, tiled so each (TM, TN) input tile is transposed inside
VMEM and written to the (TN, TM) mirrored output tile.

Grid: (M/TM, N/TN).  BlockSpecs:
  in : (TM, TN) tile at (i, j)
  out: (TN, TM) tile at (j, i)   ← the grid swap happens in the index_map

Tiles are LANE-aligned (128) on the last dim and SUBLANE-aligned (8) on the
second-to-last so the relayout uses full VREG shuffles on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import LANE, SUBLANE, cdiv, ceil_to, pad_axis, pick_tile, use_interpret


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def _transpose_padded(x: jnp.ndarray, tm: int, tn: int) -> jnp.ndarray:
    m, n = x.shape
    grid = (cdiv(m, tm), cdiv(n, tn))
    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, tn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=use_interpret(),
    )(x)


def block_transpose(x: jnp.ndarray, *, tile_m: int = 256, tile_n: int = 256) -> jnp.ndarray:
    """Transpose a 2-D array with MXU/VPU-aligned VMEM tiles."""
    assert x.ndim == 2, x.shape
    m, n = x.shape
    if m == 0 or n == 0:
        return x.T
    tm = pick_tile(m, tile_m, SUBLANE)
    tn = pick_tile(n, tile_n, LANE)
    xp = pad_axis(pad_axis(x, 0, ceil_to(m, tm)), 1, ceil_to(n, tn))
    out = _transpose_padded(xp, tm, tn)
    return out[:n, :m]
